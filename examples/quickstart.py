"""Quickstart: the concurrent non-blocking graph in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the paper's full ADT — batched concurrent mutations from many
logical actors, wait-free lookups, and the obstruction-free double-collect
GetPath — including the §3.5 adversary that version counters catch.
"""
import numpy as np

from repro.core import (
    OP_ADD_E, OP_ADD_V, OP_CON_E, OP_REM_E,
    RESULT_NAMES, add_edge, apply_ops_fast, collect, compare_collects,
    contains_vertex, get_path, get_path_session, get_paths_session,
    make_graph, make_op_batch, remove_edge,
)

# -- build a graph with one vectorized batch of 'concurrent' ops -------------
g = make_graph(64)
ops = [(OP_ADD_V, k) for k in range(8)]
ops += [(OP_ADD_E, a, b) for a, b in [(0, 1), (1, 2), (2, 3), (3, 7), (0, 5), (5, 6), (6, 7)]]
ops += [(OP_CON_E, 0, 1), (OP_ADD_E, 0, 1)]   # conflicting lanes are fine
g, results = apply_ops_fast(g, make_op_batch(ops))
print("batch results:", [RESULT_NAMES[int(r)] for r in results[-2:]])
print("contains_vertex(3):", bool(contains_vertex(g, 3)))

# -- reachability ---------------------------------------------------------------
pr = get_path(g, 0, 7)
print("path 0->7:", list(np.asarray(pr.keys)[: int(pr.length)]))

# -- the paper's §3.5 adversary: mutate and restore between collects ------------
# break all paths to 7 first, so GetPath(0,7) explores the full component
g, _ = remove_edge(g, 3, 7)
g, _ = remove_edge(g, 6, 7)
c1 = collect(g, 0, 7)            # not found: every reachable row was read
g2, _ = add_edge(g, 3, 7)        # adversary briefly creates a path...
g3, _ = remove_edge(g2, 3, 7)    # ...and removes it again
c2 = collect(g3, 0, 7)           # same edge set as c1 saw
print("adjacency identical:", bool((g.adj == g3.adj).all()),
      "| found:", bool(c1.found), bool(c2.found),
      "| double collect matches:", bool(compare_collects(c1, c2)),
      "(False = mutate-and-restore caught by ecnt, paper §3.5)")
# note: a found-path collect only depends on the rows it actually read —
# toggling an edge OFF the returned path does not force a retry here
# (dependency-precise validation, strictly fewer restarts than whole-tree
# comparison while remaining linearizable).

# -- obstruction-free session against a live mutator ----------------------------
g3, _ = add_edge(g3, 6, 7)       # restore a real path for the session demo
state = {"g": g3}
calls = {"n": 0}

def fetch():
    # a mutator toggles an edge under the first two fetches, then quiesces
    if 0 < calls["n"] <= 2:
        op = OP_REM_E if calls["n"] == 1 else OP_ADD_E
        state["g"], _ = apply_ops_fast(state["g"], make_op_batch([(op, 5, 6)]))
    calls["n"] += 1
    return state["g"]

pr = get_path_session(fetch, 0, 7)
print(f"session path 0->7 after {int(pr.rounds)} collects "
      f"(>2 means the query retried past concurrent mutations):",
      list(np.asarray(pr.keys)[: int(pr.length)]))

# -- batched reachability: Q queries under ONE shared double collect ------------
# the fused multi-source BFS engine advances all frontiers with a single
# [Q,V] @ [V,V] product per superstep (DESIGN.md §7)
out, rounds = get_paths_session(lambda: state["g"], [(0, 7), (1, 3), (6, 0)])
print(f"batched paths after {rounds} shared collects:", out)
