"""End-to-end driver: train an LM on reachability queries produced by the
concurrent graph engine (the paper-integration workload).

    PYTHONPATH=src python examples/train_path_lm.py --steps 200

Every batch is generated live: a mutator stream evolves the graph
(apply_ops_fast batches), GetPath answers supervise the model. Checkpoints,
crash-resume and straggler detection come from the production runtime. Use
``--arch`` to pick any assigned architecture (reduced config on CPU).
"""
import argparse

from repro.configs import get_config
from repro.data.pipeline import GraphPathData
from repro.models.model import build_model
from repro.runtime.train_loop import TrainLoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=160)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_pathlm")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    model = build_model(cfg)
    data = GraphPathData(n_vertices=12, seed=0)
    tl = TrainLoopConfig(total_steps=args.steps, checkpoint_every=50,
                         checkpoint_dir=args.ckpt, log_every=10, lr=args.lr)
    _, _, hist = train(model, data, batch_size=args.batch, seq_len=args.seq, cfg=tl)
    first, last = hist[0][1], hist[-1][1]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({'learning' if last < first else 'NOT learning'})")


if __name__ == "__main__":
    main()
