"""Serving example: batched LM decode co-hosted with non-blocking graph queries.

    PYTHONPATH=src python examples/serve_graph_queries.py

The serving runtime interleaves three traffic classes with zero locking:
LM decode steps, graph mutation batches, and snapshot-consistent GetPath
queries (the paper's obstruction-free protocol). Reports decode throughput
and the per-query collect-round counts.
"""
import numpy as np

import jax

from repro.configs import get_config
from repro.core import OP_ADD_E, OP_ADD_V, OP_REM_E
from repro.models.model import build_model
from repro.runtime.serve_loop import GraphCoServer, serve


def main():
    cfg = get_config("qwen2-1.5b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    graph = GraphCoServer(capacity=128)
    graph.submit([(OP_ADD_V, k) for k in range(24)])
    graph.submit([(OP_ADD_E, int(a), int(b))
                  for a, b in rng.integers(0, 24, (40, 2))])

    def mutator(i):
        a, b = (int(x) for x in rng.integers(0, 24, 2))
        return [(OP_ADD_E if rng.random() < 0.6 else OP_REM_E, a, b)]

    def queries(i):
        if i % 3 == 1:
            return tuple(int(x) for x in rng.integers(0, 24, 2))
        return None

    prompts = rng.integers(0, cfg.vocab, (4, 12)).astype(np.int32)
    out, stats = serve(model, params, prompts, max_new_tokens=24,
                       cache_len=64, graph=graph, mutator=mutator,
                       query_stream=queries)
    print(f"decoded {stats.decode_tokens} tokens in {stats.wall_s:.2f}s "
          f"({stats.decode_tokens / stats.wall_s:.1f} tok/s)")
    print(f"graph mutations applied: {stats.graph_ops}")
    print(f"GetPath queries: {stats.getpath_calls} "
          f"(avg collect rounds {stats.getpath_rounds / max(1, stats.getpath_calls):.2f}; "
          f"2.0 = clean double collect, >2 = retried past mutations)")


if __name__ == "__main__":
    main()
