"""Serving example: batched LM decode co-hosted with non-blocking graph queries.

    PYTHONPATH=src python examples/serve_graph_queries.py

The serving runtime interleaves three traffic classes with zero locking:
LM decode steps, graph mutation batches, and snapshot-consistent
reachability queries. With ``index=True`` the server additionally maintains
a versioned 2-hop reachability index (DESIGN.md §9): query batches are
answered from the index whenever its epoch stamp matches the live version
metadata (the freshness check doubles as the double-collect validation) and
fall back to the paper's obstruction-free BFS protocol after mutations,
while ``serve`` refreshes the index in the gaps between decode steps.
Reports decode throughput, per-query collect rounds, and the index
hit/miss/refresh balance.
"""
import numpy as np

import jax

from repro.configs import get_config
from repro.core import OP_ADD_E, OP_ADD_V, OP_REM_E
from repro.models.model import build_model
from repro.runtime.serve_loop import GraphCoServer, serve


def main():
    cfg = get_config("qwen2-1.5b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    graph = GraphCoServer(capacity=128, index=True)
    graph.submit([(OP_ADD_V, k) for k in range(24)])
    graph.submit([(OP_ADD_E, int(a), int(b))
                  for a, b in rng.integers(0, 24, (40, 2))])

    def mutator(i):
        if i % 4 != 3:        # read-heavy mix: mutate every 4th step only
            return []
        a, b = (int(x) for x in rng.integers(0, 24, 2))
        return [(OP_ADD_E if rng.random() < 0.6 else OP_REM_E, a, b)]

    def queries(i):
        if i % 3 == 1:        # a BATCH of pairs: index-served when fresh
            return [tuple(int(x) for x in rng.integers(0, 24, 2))
                    for _ in range(4)]
        return None

    prompts = rng.integers(0, cfg.vocab, (4, 12)).astype(np.int32)
    out, stats = serve(model, params, prompts, max_new_tokens=24,
                       cache_len=64, graph=graph, mutator=mutator,
                       query_stream=queries)
    print(f"decoded {stats.decode_tokens} tokens in {stats.wall_s:.2f}s "
          f"({stats.decode_tokens / stats.wall_s:.1f} tok/s)")
    print(f"graph mutations applied: {stats.graph_ops}")
    print(f"reachability queries: {stats.getpath_calls} "
          f"(index hits {stats.index_hits}, BFS fallbacks "
          f"{stats.index_misses}, refreshes {stats.index_refreshes})")
    counts = graph.get_reach_counts(list(range(6)))
    print(f"reachable-set sizes of vertices 0..5: {list(counts)}")


if __name__ == "__main__":
    main()
