"""Distributed graph example: row-sharded state, collective BFS, owner-routed
mutations — the paper's algorithm as a multi-device service.

    PYTHONPATH=src python examples/distributed_graph.py          # 1 device
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/distributed_graph.py      # 8 shards

(The env var must be set before launch; on a real fleet the same code runs
under jax.distributed with one process per host.)
"""
import numpy as np

import jax

from repro.core import (
    OP_ADD_E, OP_ADD_V, OP_REM_E, GraphOracle, make_graph, make_op_batch,
)
from repro.core.distributed import (
    dapply_ops, dget_path_session, make_graph_mesh, shard_graph,
)


def main():
    print(f"devices: {len(jax.devices())}")
    mesh = make_graph_mesh()
    g = shard_graph(mesh, make_graph(128))
    oracle = GraphOracle(128)
    rng = np.random.default_rng(0)

    ops = [(OP_ADD_V, k, -1, -1) for k in range(32)]
    ops += [((OP_ADD_E if rng.random() < 0.8 else OP_REM_E),
             int(a), int(b), -1) for a, b in rng.integers(0, 32, (96, 2))]
    for i in range(0, len(ops), 16):
        chunk = ops[i:i + 16]
        g, res = dapply_ops(mesh, g, make_op_batch(chunk))
        want = oracle.apply_batch(chunk)
        assert [int(x) for x in np.asarray(res)] == want
    print(f"applied {len(ops)} owner-routed ops across "
          f"{mesh.devices.size} shard(s); results match the oracle")

    hits = 0
    for (s, d) in [(0, 31), (5, 9), (30, 2), (1, 17)]:
        ok, n, keys, rounds = dget_path_session(mesh, lambda: g, s, d)
        assert ok == oracle.reachable(s, d)
        status = "->".join(map(str, keys)) if ok else "unreachable"
        print(f"GetPath({s},{d}) [{rounds} collects, psum-validated]: {status}")
        hits += ok
    print(f"{hits}/4 reachable; distributed double-collect verified vs oracle")


if __name__ == "__main__":
    main()
