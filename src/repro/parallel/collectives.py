"""Collective helpers + hierarchical reduction patterns.

Most distribution in this framework is GSPMD-driven (jit + NamedSharding);
these helpers serve the explicit shard_map paths (core/distributed.py, the
gradient-compression pod hop) and document the intended collective schedule
for the roofline analysis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def psum_hierarchical(x, *, fast_axes, slow_axes=()):
    """Reduce over fast (ICI) axes first, then slow (DCN) axes.

    Inside shard_map only. With gradient compression the slow hop is applied
    to the quantized tensor (optim/grad_compress.py).
    """
    for a in fast_axes:
        x = jax.lax.psum(x, a)
    for a in slow_axes:
        x = jax.lax.psum(x, a)
    return x


def all_reduce_or(x, axis):
    """Boolean OR all-reduce (frontier combine in distributed BFS)."""
    return jax.lax.psum(x.astype(jnp.int32), axis) > 0
