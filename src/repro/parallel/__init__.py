from repro.parallel import collectives, sharding  # noqa: F401
