"""GSPMD sharding rules: FSDP x TP for training, TP + batch-DP for serving.

Rules are *path + shape* based with divisibility fallbacks, so one rule set
covers all 10 assigned architectures:

  train (mode="train"):  weights sharded on BOTH the d_model-ish dim (over
    the combined data axes = ZeRO-3/FSDP) and the heads/ffn/experts dim
    (over "model" = TP). Optimizer state inherits (shard-transparent AdamW).
  serve (mode="serve"):  weights TP-sharded on "model", replicated over
    data; batch and KV caches shard over data; GQA caches shard kv-heads
    over "model" when divisible, else head_dim, else the length axis.

Multi-pod meshes contribute their "pod" axis to the data axes, so FSDP and
batch sharding span pods while TP stays intra-pod (ICI-only) — the layout
that keeps the slow DCN hop off the per-layer critical path.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(mesh, dim, axes) -> bool:
    return dim % axis_size(mesh, axes) == 0


def _pick(mesh, dim, *candidates):
    """First candidate axis (or axis tuple) that divides ``dim``; else None."""
    for c in candidates:
        if c is None:
            return None
        if _fits(mesh, dim, c):
            return c
    return None


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# ----------------------------------------------------------------------------
# parameter specs
# ----------------------------------------------------------------------------
def param_spec(mesh: Mesh, mode: str, path: str, shape: tuple[int, ...]) -> P:
    """Sharding spec for one parameter leaf.

    Modes: "train" FSDP x TP | "serve" TP only | "fsdp" ZeRO over ALL axes,
    no TP (small models: trades per-layer activation all-reduces for weight
    all-gathers) | "replicated" no weight sharding (small models at serve:
    zero weight collectives, batch/seq carry all parallelism).
    """
    if mode == "fsdp":
        da = data_axes(mesh) + ("model",)
        md = None
    elif mode == "replicated":
        da, md = None, None
    else:
        da = data_axes(mesh) if mode == "train" else None
        md = "model"
    name = path.split("/")[-1]
    stacked = ("stacks/" in path) or path.startswith(("enc/", "dec/")) or "/enc/" in path or "/dec/" in path
    lead = (None,) if stacked else ()
    dims = shape[len(lead):]

    def spec(*entries):
        return P(*(lead + tuple(entries)))

    if len(dims) <= 1:
        return spec(*([None] * len(dims)))  # norms/biases/scalars: replicate

    # --- embeddings -----------------------------------------------------------
    if name == "tok":                      # [V, d]
        return spec(_pick(mesh, dims[0], md), _pick(mesh, dims[1], da))
    if name == "unembed":                  # [d, V]
        return spec(_pick(mesh, dims[0], da), _pick(mesh, dims[1], md))

    # --- MoE ------------------------------------------------------------------
    if name == "router":                   # [d, E]
        return spec(_pick(mesh, dims[0], da), None)
    if name in ("wi", "wg", "wo") and len(dims) == 3:  # expert weights [E, a, b]
        e = dims[0]
        if _fits(mesh, e, md):             # expert parallelism
            if name == "wo":               # [E, f, d]
                return spec(md, None, _pick(mesh, dims[2], da))
            return spec(md, _pick(mesh, dims[1], da), None)
        # TP inside experts on the ffn dim
        if name == "wo":                   # [E, f, d]
            return spec(None, _pick(mesh, dims[1], md), _pick(mesh, dims[2], da))
        return spec(None, _pick(mesh, dims[1], da), _pick(mesh, dims[2], md))

    # --- attention / mlp / ssm / lru projections (2-D) --------------------------
    if name in ("wq", "wk", "wv", "wi", "wg", "in_proj", "in_x", "in_g", "w_a", "w_i"):
        return spec(_pick(mesh, dims[0], da), _pick(mesh, dims[1], md))
    if name in ("wo", "out_proj", "out"):
        return spec(_pick(mesh, dims[0], md), _pick(mesh, dims[1], da))
    if name == "conv_w":                   # [K, din]
        return spec(_pick(mesh, dims[0], None), _pick(mesh, dims[1], md or da))

    # default 2-D: FSDP on the larger dim
    if len(dims) == 2:
        return spec(_pick(mesh, dims[0], da), _pick(mesh, dims[1], md))
    return spec(*([None] * len(dims)))


def param_specs(params_abs, mesh: Mesh, mode: str):
    """Tree of PartitionSpecs matching an abstract (or concrete) param tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(mesh, mode, _path_str(path), leaf.shape),
        params_abs,
    )


# ----------------------------------------------------------------------------
# batch / cache specs
# ----------------------------------------------------------------------------
def batch_spec(mesh: Mesh, name: str, shape: tuple[int, ...], scheme: str = "tp") -> P:
    da = data_axes(mesh)
    if scheme == "fsdp":
        da = da + ("model",)
    if len(shape) == 0:
        return P()
    b = shape[0]
    lead = _pick(mesh, b, da)
    rest = [None] * (len(shape) - 1)
    if scheme == "tokpar" and len(shape) >= 2 and _fits(mesh, shape[1], "model"):
        rest[0] = "model"     # sequence dim carries the model axis
    return P(lead, *rest)


def batch_specs(specs: dict, mesh: Mesh, scheme: str = "tp"):
    return {k: batch_spec(mesh, k, v.shape, scheme) for k, v in specs.items()}


def cache_entry_spec(mesh: Mesh, shape: tuple[int, ...], kind: str) -> P:
    """Decode-cache sharding. Attention kv: [G, B, L, KV, hd]; ssm state:
    [G, B, H, N, hd]; conv: [G, B, K-1, D]; rec h: [G, B, D]."""
    da = data_axes(mesh)
    md = "model"
    dims = list(shape)
    n = len(dims)
    if n == 5 and kind == "attn":          # [G, B, L, KV, hd]
        bspec = _pick(mesh, dims[1], da)
        kvspec = _pick(mesh, dims[3], md)
        if kvspec is not None:
            return P(None, bspec, None, kvspec, None)
        # kv heads indivisible: shard the LENGTH axis (flash-decoding style —
        # local partial softmax + tiny psum). Sharding hd instead makes the
        # score contraction's operand sharded on its contracting dim and XLA
        # all-gathers the whole cache per step (observed 171 GB/step/device
        # on internvl2 decode_32k).
        lspec = _pick(mesh, dims[2], md)
        if lspec is not None:
            return P(None, bspec, lspec, None, None)
        return P(None, bspec, None, None, _pick(mesh, dims[4], md))
    if n == 5:                              # ssm state [G, B, H, N, hd]
        return P(None, _pick(mesh, dims[1], da), _pick(mesh, dims[2], md), None, None)
    if n == 4:                              # conv cache [G, B, K-1, D]
        return P(None, _pick(mesh, dims[1], da), None, _pick(mesh, dims[3], md))
    if n == 3:                              # rec h [G, B, D]
        return P(None, _pick(mesh, dims[1], da), _pick(mesh, dims[2], md))
    return P(*([None] * n))


def cache_specs(cache_abs, mesh: Mesh):
    """Specs for the nested cache structure produced by Model.init_cache."""
    def leaf_spec(path, leaf):
        # attention caches live under keys "0".."n" as (k, v) tuples of 5-D
        # arrays with a KV-head axis; ssm states are 5-D f32 with N axis.
        kind = "attn" if (leaf.ndim == 5 and leaf.shape[3] != leaf.shape[4] or leaf.ndim == 5) else "other"
        # distinguish attn [G,B,L,KV,hd] from ssm [G,B,H,N,hd] by dtype: ssm
        # states are f32, kv caches use the model dtype; fall back to attn.
        import jax.numpy as jnp
        if leaf.ndim == 5 and leaf.dtype == jnp.float32:
            return cache_entry_spec(mesh, leaf.shape, "ssm")
        return cache_entry_spec(mesh, leaf.shape, "attn" if leaf.ndim == 5 else "other")

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_abs)


def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------------------------
# graph-state sharding (the partitioned concurrent graph, DESIGN.md §8)
# ----------------------------------------------------------------------------
GRAPH_ROW_AXIS = "rows"


def graph_state_specs(axis: str = GRAPH_ROW_AXIS) -> dict:
    """PartitionSpecs for the partitioned graph state (DESIGN.md §8).

    The word-packed adjacencies — the only O(V^2/32) arrays (DESIGN.md
    §10, §11) — are row-sharded over the 1-D ``rows`` mesh axis: shard s
    owns the OUT-edge rows of its slot block in ``adj_packed`` and the
    IN-edge rows of the SAME block in ``adj_in_packed`` (the in-adjacency's
    rows are the out-adjacency's columns, so this is the column-sharded
    in-row layout the hybrid pull phase runs shard-local over). The O(V)
    version metadata (vkey/valive/vver/ecnt) is replicated so lookups, the
    double-collect validation vector, and the lane-order mutation schedule
    stay shard-local replicated compute.
    """
    rep = P()
    return {
        "vkey": rep,
        "valive": rep,
        "vver": rep,
        "ecnt": rep,
        "adj_packed": P(axis, None),
        "adj_in_packed": P(axis, None),
    }


def graph_state_shardings(mesh: Mesh, axis: str = GRAPH_ROW_AXIS) -> dict:
    """NamedShardings for ``graph_state_specs`` on a concrete mesh."""
    return {k: NamedSharding(mesh, s) for k, s in graph_state_specs(axis).items()}


# ----------------------------------------------------------------------------
# activation sharding constraints (trace-time hooks used inside model code)
# ----------------------------------------------------------------------------
# GSPMD propagation alone replicates attention activations whenever the head
# count does not divide the TP axis (qwen2 12H, granite 24H, whisper 8H on
# model=16): observed 30-80 GB/device temps on the dry-run. These hooks pin
# activation layouts with divisibility-aware fallbacks: heads over "model"
# when divisible, else sequence over "model", else replicated.
_ACTIVE: dict = {"mesh": None, "scheme": "tp"}


def set_activation_mesh(mesh: Mesh | None, scheme: str = "tp"):
    """scheme: "tp" (heads/vocab over model; default) | "tokpar" (sequence
    over model everywhere — used with replicated/fsdp weights) | "fsdp"
    (batch over data AND model; no model-axis tensor parallelism)."""
    _ACTIVE["mesh"] = mesh
    _ACTIVE["scheme"] = scheme


def _constrain(x, spec):
    mesh = _ACTIVE["mesh"]
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_activation(x, kind: str):
    """Pin an activation's sharding. No-op outside a dry-run/train context."""
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return x
    scheme = _ACTIVE.get("scheme", "tp")
    da = data_axes(mesh)
    md = "model"
    if scheme == "fsdp":            # batch carries the model axis too
        da = da + ("model",)
        md = None
    seqpar = scheme == "tokpar"
    if kind == "attn_q":            # [b, s, H, hd]
        b, s, h, hd = x.shape
        bs = _pick(mesh, b, da)
        if seqpar:
            return _constrain(x, P(bs, _pick(mesh, s, "model"), None, None))
        if md and _fits(mesh, h, md):
            return _constrain(x, P(bs, None, md, None))
        if md and _fits(mesh, s, md):
            return _constrain(x, P(bs, md, None, None))
        return _constrain(x, P(bs, None, None, None))
    if kind == "attn_kv":           # [b, t, H, hd] (repeated KV heads)
        b, t, h, hd = x.shape
        bs = _pick(mesh, b, da)
        if not seqpar and md and _fits(mesh, h, md):
            return _constrain(x, P(bs, None, md, None))
        return _constrain(x, P(bs, None, None, None))
    if kind == "hidden":            # [b, s, d]
        b = x.shape[0]
        bs = _pick(mesh, b, da)
        if seqpar and x.ndim == 3:
            return _constrain(x, P(bs, _pick(mesh, x.shape[1], "model"), None))
        return _constrain(x, P(bs, *([None] * (x.ndim - 1))))
    if kind == "logits":            # [b, s, V] or [b, V]
        v = x.shape[-1]
        b = x.shape[0]
        bs = _pick(mesh, b, da)
        vs = md if (md and _fits(mesh, v, md)) else None
        if x.ndim == 3:
            s = x.shape[1]
            ss = "model" if ((seqpar or vs is None) and _fits(mesh, s, "model") and scheme != "fsdp") else None
            if ss is not None:
                vs = None
            return _constrain(x, P(bs, ss, vs))
        return _constrain(x, P(bs, vs))
    if kind == "moe_dispatch":      # [E, C, d]
        e = x.shape[0]
        es = md if _fits(mesh, e, md) else None
        cs = md if (es is None and _fits(mesh, x.shape[1], md)) else None
        return _constrain(x, P(es, cs, None))
    if kind == "moe_dispatch4":     # [G, E, C, *] — grouped dispatch buffers
        g, e, c = x.shape[0], x.shape[1], x.shape[2]
        gsd = _pick(mesh, g, da)
        es = md if _fits(mesh, e, md) else None
        cs = md if (es is None and _fits(mesh, c, md)) else None
        return _constrain(x, P(gsd, es, cs, None))
    if kind == "ssm_intra":         # [B, nc, Q, Q, H] — SSD intra-chunk mask
        b, h = x.shape[0], x.shape[-1]
        bs = _pick(mesh, b, da)
        hs = "model" if (_ACTIVE.get("scheme") == "tp" and _fits(mesh, h, "model")) else None
        return _constrain(x, P(bs, None, None, None, hs))
    return x
