"""Typed metrics registry + registry-backed stat views (DESIGN.md §14).

The repo grew one ad-hoc stat block per subsystem (``IngestStats``,
``ServeStats``, per-server index/ring counters). This module gives them a
single canonical home:

  * ``MetricsRegistry`` — a typed (counter | gauge | histogram) name ->
    value store. Counters and gauges are plain numbers; histograms keep
    (count, sum, min, max) — enough for latency attribution without
    bucketing policy.
  * ``StatsView`` — a dataclass-shaped VIEW over a registry: subclasses
    declare ``_SPEC`` (field -> (kind, default)) and ``_PREFIX``;
    attribute reads/writes route to the registry under
    ``"<prefix>.<field>"``. ``IngestStats`` and ``ServeStats`` are now
    such views, so every existing call site (``stats.submitted += 1``,
    pinned equality asserts in tests/test_serving_stats.py) keeps working
    unchanged while ``GraphCoServer.get_metrics`` serves the same numbers
    from one registry snapshot.
  * ``GLOBAL`` — the process-global registry the *tracing-only* metrics
    land in (superstep direction counts, ring resolution depths, index
    latencies). These are updated only when ``trace.enabled()`` — the
    disabled hot path never touches them.

``OBS_METRICS`` is the static declaration of every global metric; the
drift check (tools/check_metrics_doc.py, run by the obs-tests CI step)
asserts each declared name — global and view fields alike — appears in
DESIGN.md §14's metric table.
"""
from __future__ import annotations

import threading

_KINDS = ("counter", "gauge", "histogram")


class MetricsRegistry:
    """Typed name -> metric store (DESIGN.md §14). Thread-safe."""

    def __init__(self):
        self._lock = threading.Lock()
        self._kinds: dict[str, str] = {}
        self._values: dict[str, object] = {}

    def declare(self, name: str, kind: str, default=0) -> None:
        if kind not in _KINDS:
            raise ValueError(f"unknown metric kind {kind!r} for {name!r}")
        with self._lock:
            prev = self._kinds.get(name)
            if prev is not None and prev != kind:
                raise ValueError(
                    f"metric {name!r} re-declared as {kind} (was {prev})")
            if name not in self._values:
                self._kinds[name] = kind
                self._values[name] = (
                    {"count": 0, "sum": 0.0, "min": None, "max": None}
                    if kind == "histogram" else default)

    def kind(self, name: str) -> str:
        return self._kinds[name]

    def get(self, name: str):
        with self._lock:
            return self._values[name]

    def set(self, name: str, value) -> None:
        with self._lock:
            if self._kinds.get(name) == "histogram":
                raise TypeError(f"histogram {name!r} takes observe(), not set()")
            self._values[name] = value

    def inc(self, name: str, delta=1) -> None:
        with self._lock:
            self._values[name] = self._values[name] + delta

    def observe(self, name: str, value) -> None:
        with self._lock:
            h = self._values[name]
            h["count"] += 1
            h["sum"] += value
            h["min"] = value if h["min"] is None else min(h["min"], value)
            h["max"] = value if h["max"] is None else max(h["max"], value)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._kinds)

    def snapshot(self) -> dict:
        """One flat dict of current values (histograms as sub-dicts) — the
        payload of the ``get_metrics`` serving endpoint (DESIGN.md §14)."""
        with self._lock:
            return {k: (dict(v) if isinstance(v, dict) else v)
                    for k, v in sorted(self._values.items())}


class StatsView:
    """Dataclass-shaped view over a ``MetricsRegistry`` (DESIGN.md §14).

    Subclasses declare ``_PREFIX`` and ``_SPEC``; instances expose each
    spec field as a plain attribute whose storage is the registry entry
    ``"<prefix>.<field>"`` — the pre-existing ``stats.field += n`` call
    sites and pinned test asserts keep their exact semantics while the
    values become registry-servable.
    """

    _PREFIX = ""
    _SPEC: dict[str, tuple] = {}

    def __init__(self, registry: MetricsRegistry | None = None):
        object.__setattr__(self, "registry",
                           registry if registry is not None
                           else MetricsRegistry())
        for name, (kind, default) in self._SPEC.items():
            self.registry.declare(self._qual(name), kind, default)

    @classmethod
    def _qual(cls, name: str) -> str:
        return f"{cls._PREFIX}.{name}" if cls._PREFIX else name

    def __getattr__(self, name: str):
        if name in type(self)._SPEC:
            return self.registry.get(self._qual(name))
        raise AttributeError(
            f"{type(self).__name__} has no field {name!r}")

    def __setattr__(self, name: str, value) -> None:
        if name in type(self)._SPEC:
            self.registry.set(self._qual(name), value)
        else:
            object.__setattr__(self, name, value)

    def snapshot(self) -> dict:
        """field -> current value (unprefixed, view-local)."""
        return {name: getattr(self, name) for name in self._SPEC}

    def __repr__(self) -> str:
        body = ", ".join(f"{k}={getattr(self, k)!r}" for k in self._SPEC)
        return f"{type(self).__name__}({body})"


# Tracing-only global metrics: updated exclusively under ``trace.enabled()``
# so the disabled hot path never pays for them. Every name here must appear
# in DESIGN.md §14's metric table (tools/check_metrics_doc.py enforces).
OBS_METRICS: dict[str, tuple[str, str]] = {
    "bfs.supersteps": ("counter", "traced fused supersteps executed"),
    "bfs.pull_supersteps": ("counter", "traced supersteps that chose pull"),
    "bfs.direction_flips": ("counter",
                            "push<->pull switches across traced supersteps"),
    "bfs.exchange_bytes": ("counter",
                           "sharded frontier-exchange bytes (packed words)"),
    "ingest.round_s": ("histogram", "wall seconds per admission round"),
    "ingest.fused_apply_s": ("histogram",
                             "device wall seconds per fused apply"),
    "index.query_s": ("histogram", "wall seconds per index query batch"),
    "index.ring_validate_s": ("histogram",
                              "wall seconds per ring-validated serve"),
    "index.fallback_s": ("histogram",
                         "wall seconds per BFS-fallback session"),
    "ring.occupancy": ("gauge", "delta records currently retained"),
    "ring.evictions": ("counter", "delta records dropped by retention"),
    "ring.resolve_depth": ("histogram",
                           "XOR records replayed per state_at()"),
    "wal.append_s": ("histogram",
                     "wall seconds per durable WAL append (incl. fsync)"),
    "ckpt.save_s": ("histogram",
                    "wall seconds per published graph checkpoint"),
    "recovery.restore_s": ("histogram",
                           "wall seconds per checkpoint+WAL recovery"),
    "serve.degraded": ("gauge",
                       "1 while the server recovers (pinned reads, "
                       "R_RECOVERING writes)"),
}

GLOBAL = MetricsRegistry()
for _name, (_kind, _doc) in OBS_METRICS.items():
    GLOBAL.declare(_name, _kind)


def global_registry() -> MetricsRegistry:
    """The process-global tracing-metrics registry (DESIGN.md §14)."""
    return GLOBAL
