"""Hierarchical spans + Perfetto export: the tracing half of the
observability layer (DESIGN.md §14).

One process-global ``TraceRecorder`` collects *spans* — named, timed,
attributed intervals — from every instrumented layer (admission rounds,
fused applies, BFS supersteps, index queries, epoch-ring reconstructions).
The recorder is OFF by default and the disabled path is engineered to be
free in both senses that matter on the hot path:

  * **wall time** — ``span()`` with the recorder disabled performs one
    global load, one attribute check, and returns a shared ``_NullSpan``
    singleton whose ``__enter__``/``__exit__``/``set`` are empty slots
    methods. tests/test_obs.py budgets the full per-workload cost of the
    disabled instrumentation at <5% of a scripted ingest round's wall.
  * **jit behaviour** — instrumentation lives strictly OUTSIDE jit
    boundaries (host timestamps around jitted calls; device timings via
    ``fence`` = ``jax.block_until_ready``), and traced code paths are
    selected by ``enabled()`` checked on the HOST, never inside a traced
    function. With tracing disabled every jitted entry point sees exactly
    the pre-observability call signature: zero extra retraces, pinned by
    the cache-key test in tests/test_obs.py.

Enabling: set ``REPRO_TRACE=1`` in the environment (read once at import),
or call ``enable()``/``capture()`` at runtime. ``save(path)`` writes the
Chrome trace-event JSON (``{"traceEvents": [...]}``) that
https://ui.perfetto.dev and ``chrome://tracing`` load directly;
``tools/trace_view.py`` summarizes the same file offline (DESIGN.md §14).

Span nesting is positional, the way the trace-event format defines it:
complete ("X") events on one thread nest by timestamp containment, so the
recorder never maintains an explicit tree — each layer simply opens its
span around the work, and ``ingest.round`` ends up enclosing
``ingest.fused_apply`` which encloses nothing, while ``bfs.session``
encloses one ``bfs.superstep`` per frontier expansion.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time

_TRUTHY = {"1", "true", "yes", "on"}


class _NullSpan:
    """Shared do-nothing span: the entire disabled-tracer hot path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NULL = _NullSpan()


class _LiveSpan:
    """One open interval; appends a complete ("X") event on exit."""

    __slots__ = ("_rec", "name", "attrs", "_t0")

    def __init__(self, rec: "TraceRecorder", name: str, attrs: dict):
        self._rec = rec
        self.name = name
        self.attrs = attrs
        self._t0 = 0

    def set(self, **attrs):
        """Attach/overwrite span attributes mid-flight (e.g. a direction
        tag only known after the superstep ran)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter_ns() - self._t0
        self._rec._emit(self.name, self._t0, dur, self.attrs)
        return False


class TraceRecorder:
    """Process-global span/counter sink (DESIGN.md §14).

    Thread-safe appends; each event carries the OS thread id so multi-
    client admission shows up as parallel tracks in Perfetto.
    """

    def __init__(self):
        self.enabled = False
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._epoch_ns = time.perf_counter_ns()

    # -- recording ----------------------------------------------------------
    def _emit(self, name: str, t0_ns: int, dur_ns: int, attrs: dict) -> None:
        ev = {
            "name": name,
            "ph": "X",
            "ts": (t0_ns - self._epoch_ns) / 1e3,   # microseconds
            "dur": dur_ns / 1e3,
            "pid": os.getpid(),
            "tid": threading.get_ident() & 0xFFFF,
        }
        if attrs:
            ev["args"] = attrs
        with self._lock:
            self._events.append(ev)

    def counter(self, name: str, value) -> None:
        """One counter ("C") sample — a stepped time series in Perfetto."""
        if not self.enabled:
            return
        ev = {
            "name": name,
            "ph": "C",
            "ts": (time.perf_counter_ns() - self._epoch_ns) / 1e3,
            "pid": os.getpid(),
            "tid": threading.get_ident() & 0xFFFF,
            "args": {"value": value},
        }
        with self._lock:
            self._events.append(ev)

    # -- lifecycle ----------------------------------------------------------
    def start(self, fresh: bool = False) -> None:
        with self._lock:
            if fresh:
                self._events = []
            self.enabled = True

    def stop(self) -> None:
        with self._lock:
            self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._events = []

    # -- export -------------------------------------------------------------
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def export(self) -> dict:
        """Chrome/Perfetto trace-event JSON object (DESIGN.md §14)."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.export(), f)
        return path


_RECORDER = TraceRecorder()


def recorder() -> TraceRecorder:
    """The process-global recorder."""
    return _RECORDER


def enabled() -> bool:
    """Host-side tracing switch — the ONE check every instrumented layer
    guards its traced path with (DESIGN.md §14)."""
    return _RECORDER.enabled


def span(name: str, **attrs):
    """Open a span. Disabled: returns the shared no-op singleton (no
    allocation beyond the kwargs dict the caller already built — hot paths
    with expensive attrs should guard on ``enabled()`` first)."""
    if not _RECORDER.enabled:
        return _NULL
    return _LiveSpan(_RECORDER, name, attrs)


def counter(name: str, value) -> None:
    """Record a counter sample (no-op when disabled)."""
    _RECORDER.counter(name, value)


def enable(fresh: bool = False) -> None:
    _RECORDER.start(fresh=fresh)


def disable() -> None:
    _RECORDER.stop()


def save(path: str | None = None) -> str:
    """Write the Perfetto-loadable trace JSON (DESIGN.md §14); ``None``
    uses ``REPRO_TRACE_PATH`` (default ``repro_trace.json``)."""
    return _RECORDER.save(path if path is not None else _env_path())


def fence(x):
    """Device-timing fence: block on ``x`` when tracing so the enclosing
    span measures device work, pass through untouched when disabled
    (DESIGN.md §14)."""
    if _RECORDER.enabled:
        import jax

        jax.block_until_ready(x)
    return x


@contextlib.contextmanager
def capture():
    """Enable a FRESH trace for the duration of the block and yield the
    recorder; restores the previous enabled state on exit. The test/bench
    surface: benchmarks capture a traced run to derive obs columns
    (supersteps, direction flips) without leaking global state
    (DESIGN.md §14)."""
    was = _RECORDER.enabled
    _RECORDER.start(fresh=True)
    try:
        yield _RECORDER
    finally:
        if not was:
            _RECORDER.stop()


def _env_path() -> str:
    return os.environ.get("REPRO_TRACE_PATH", "repro_trace.json")


# REPRO_TRACE=1 (or any truthy value) arms the recorder at import — the
# env-var form of enable() the launchers rely on (DESIGN.md §14).
if os.environ.get("REPRO_TRACE", "").strip().lower() in _TRUTHY:
    _RECORDER.start()
