"""Observability: structured tracing + typed metrics (DESIGN.md §14).

``repro.obs.trace`` — hierarchical spans with a process-global recorder
(env ``REPRO_TRACE=1``), Perfetto/Chrome trace-event export, and a
device-timing ``fence``. ``repro.obs.metrics`` — the typed
``MetricsRegistry`` plus the ``StatsView`` base the ad-hoc stat
dataclasses now ride on. Both halves are free when disabled: the tier-1
overhead test (tests/test_obs.py) pins zero extra jit retraces and a <5%
wall budget for the disabled instrumentation.
"""
from repro.obs.metrics import (
    GLOBAL,
    MetricsRegistry,
    OBS_METRICS,
    StatsView,
    global_registry,
)
from repro.obs.trace import (
    capture,
    counter,
    disable,
    enable,
    enabled,
    fence,
    recorder,
    save,
    span,
)

__all__ = [
    "GLOBAL",
    "MetricsRegistry",
    "OBS_METRICS",
    "StatsView",
    "capture",
    "counter",
    "disable",
    "enable",
    "enabled",
    "fence",
    "global_registry",
    "recorder",
    "save",
    "span",
]
