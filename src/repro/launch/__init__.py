# launchers: mesh.py dryrun.py train.py serve.py steps.py
