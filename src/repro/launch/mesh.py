"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run force-creates
512 host devices while tests/benches must see the real device list.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e production mesh: one pod = 16x16 = 256 chips; two pods = 512.

    Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
    "model" (TP/EP) stays intra-pod on ICI; "pod" x "data" carry FSDP/DP and
    may cross DCN.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Dev mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
