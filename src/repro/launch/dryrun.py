import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_EXTRA", "") + " --xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell, jit the appropriate step function with explicit in_shardings
on the production mesh, ``.lower()`` it against ShapeDtypeStruct inputs (no
allocation anywhere), ``.compile()``, and record:
  * memory_analysis()  — per-device bytes (proves the cell fits HBM)
  * cost_analysis()    — HLO FLOPs / bytes accessed (roofline numerator)
  * collective bytes   — parsed from the optimized HLO (see _collective_bytes)

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --cells all --mesh both --out results/dryrun.jsonl
  python -m repro.launch.dryrun --cells all --subprocess   # 1 proc / cell

NOTE the XLA_FLAGS assignment above MUST precede any jax import: jax locks
the device count at first backend init. Do not replicate this env var in
conftest/pyproject — smoke tests and benchmarks must see the real device.
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config, shape_for
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.optim import adamw
from repro.parallel import sharding

_COLL_RE = re.compile(
    r"(\S+)\s*=\s*(\((?:[^()]|\([^()]*\))*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}


def _tensor_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _replica_group_factor(line: str, op: str) -> int:
    """Estimated per-device traffic multiplier for reduce-scatter (operand =
    result x group size); 1 otherwise."""
    if op != "reduce-scatter":
        return 1
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind per-device traffic estimate, from optimized HLO.

    Methodology (documented for §Roofline): bytes = result-tensor size for
    all-gather / all-reduce / all-to-all / collective-permute (ring traffic
    ~ (n-1)/n x result, we report the upper bound), and result x group_size
    for reduce-scatter (its operand is the large tensor). `while` bodies
    appear once in the HLO; trip counts multiply in benchmarks/roofline.py
    via the loop-bound annotation when present, else are reported as-is.
    """
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?\S+\s*=\s*(.+?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(-start|-done)?\(", s)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # counted at -start
        ty, op = m.group(1), m.group(2)
        out[op] += _tensor_bytes(ty) * _replica_group_factor(s, op)
        out["count"] += 1
    return out


def _mem_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
            "code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
        }
    except Exception as e:  # CPU backend may not implement it
        return {"error": str(e)}


def _cost_analysis(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or "utilization" not in k)}
    except Exception as e:
        return {"error": str(e)}


def build_cell(arch: str, shape: str, mesh, *, microbatches: int = 1,
               layout: str = "auto", decode_unroll: bool = False):
    """Returns (step_fn, in_shardings, abstract_args, donate_argnums).

    ``layout``: "auto" (train: fsdp x tp; serve: tp) | "tp" | "fsdp"
    (ZeRO over all axes, no TP) | "tokpar" (replicated weights, batch x
    sequence parallelism — small-model serving). §Perf iterates layouts.
    """
    cfg = get_config(arch)
    model = build_model(cfg)
    kind = shape_for(shape)["kind"]
    params_abs = model.init_abstract()

    if kind == "train":
        pmode = {"auto": "train", "tp": "train", "fsdp": "fsdp",
                 "tokpar": "replicated", "zero1": "replicated"}[layout]
        scheme = {"auto": "tp", "tp": "tp", "fsdp": "fsdp", "tokpar": "tokpar",
                  "zero1": "fsdp"}[layout]
        pspecs = sharding.param_specs(params_abs, mesh, pmode)
        opt_abs = jax.eval_shape(adamw.init, params_abs)
        # zero1: replicated params, SHARDED optimizer moments (ZeRO-1)
        mspecs = sharding.param_specs(params_abs, mesh, "fsdp") if layout == "zero1" else pspecs
        ospecs = adamw.AdamWState(
            step=jax.sharding.PartitionSpec(),
            mu=mspecs, nu=mspecs)
        bspecs_in = model.input_specs(shape)
        bspecs = sharding.batch_specs(bspecs_in, mesh, scheme)
        step = steps_mod.make_train_step(
            model, microbatches=microbatches,
            grad_specs=sharding.to_named(pspecs, mesh))
        # outputs (params', opt', metrics) mirror the input layouts -> donation
        P = jax.sharding.PartitionSpec
        out_shard = (pspecs, ospecs, {"loss": P(), "aux": P()})
        return (step, (pspecs, ospecs, bspecs), (params_abs, opt_abs, bspecs_in),
                (0, 1), out_shard)

    pmode = {"auto": "serve", "tp": "serve", "fsdp": "fsdp",
             "tokpar": "replicated", "zero1": "replicated"}[layout]
    scheme = {"auto": "tp", "tp": "tp", "fsdp": "fsdp", "tokpar": "tokpar",
              "zero1": "fsdp"}[layout]

    if kind == "prefill":
        pspecs = sharding.param_specs(params_abs, mesh, pmode)
        bspecs_in = model.input_specs(shape)
        bspecs = sharding.batch_specs(bspecs_in, mesh, scheme)
        step = steps_mod.make_prefill_step(model)
        return (step, (pspecs, bspecs), (params_abs, bspecs_in), (), None)

    # decode
    sh = shape_for(shape)
    pspecs = sharding.param_specs(params_abs, mesh, pmode)
    cache_abs = model.abstract_cache(sh["global_batch"], sh["seq_len"])
    cspecs = sharding.cache_specs(cache_abs, mesh)
    ispecs = model.input_specs(shape)
    if decode_unroll:
        def step(params, caches, tokens, pos):
            return model.decode_step(params, caches, tokens, pos, unroll=True)
    else:
        step = steps_mod.make_decode_step(model)
    in_shard = (pspecs, cspecs,
                sharding.batch_spec(mesh, "tokens", ispecs["tokens"].shape),
                jax.sharding.PartitionSpec())
    args = (params_abs, cache_abs, ispecs["tokens"], ispecs["pos"])
    # out_shardings must mirror the input cache layout or XLA cannot alias
    # the donated cache buffers (observed: a full extra cache copy as temp)
    b = ispecs["tokens"].shape[0]
    logits_spec = sharding.batch_spec(mesh, "logits", (b, cfg.vocab))
    out_shard = (logits_spec, cspecs)
    return (step, in_shard, args, (1,), out_shard)


def auto_microbatches(arch: str, shape: str, mesh) -> int:
    """Grad-accumulation factor keeping per-device live activations bounded.

    Heuristic: split until tokens_local x d_model <= 48M elements (~0.2 GB
    bf16 residual per layer plus working set under full remat). Recorded per
    cell; §Perf iterates on it explicitly.
    """
    cfg = get_config(arch)
    sh = shape_for(shape)
    if sh["kind"] != "train":
        return 1
    import numpy as np
    da = sharding.axis_size(mesh, sharding.data_axes(mesh))
    b_local = max(1, sh["global_batch"] // da)
    tokens_local = b_local * sh["seq_len"]
    # enc-dec archs also hold encoder activations whose attention cannot
    # shard on this mesh (frames=1500, heads=8 vs TP=16): weight them in
    eff_d = cfg.d_model
    if cfg.enc_layers:
        tokens_local += b_local * cfg.enc_frames * max(1, cfg.enc_frames // 256)
    mb = 1
    while tokens_local // mb * eff_d > 48_000_000 and mb < min(32, b_local):
        mb *= 2
    return mb


def run_cell(arch: str, shape: str, mesh_kind: str, *, microbatches=1,
             layout: str = "auto", decode_unroll: bool = False,
             keep_hlo: bool = False) -> dict:
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind, "ok": False,
           "layout": layout, "decode_unroll": decode_unroll}
    cfg = get_config(arch)
    if shape in cfg.skip_shapes:
        rec.update(skipped=True, reason="full attention excludes long-context decode")
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        scheme = {"auto": "tp", "tp": "tp", "fsdp": "fsdp", "tokpar": "tokpar",
                  "zero1": "fsdp"}[layout]
        sharding.set_activation_mesh(mesh, scheme)
        if microbatches == 0:  # auto
            microbatches = auto_microbatches(arch, shape, mesh)
        step, in_shardings, args, donate, out_shardings = build_cell(
            arch, shape, mesh, microbatches=microbatches, layout=layout,
            decode_unroll=decode_unroll)
        in_shardings = sharding.to_named(in_shardings, mesh)
        kw = {}
        if out_shardings is not None:
            kw["out_shardings"] = sharding.to_named(out_shardings, mesh)
        with mesh:
            jitted = jax.jit(step, in_shardings=in_shardings,
                             donate_argnums=donate, **kw)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        hlo = compiled.as_text()
        rec.update(
            ok=True,
            devices=int(mesh.size),
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=_mem_analysis(compiled),
            cost=_cost_analysis(compiled),
            collectives=collective_bytes(hlo),
            microbatches=microbatches,
        )
        if keep_hlo:
            rec["hlo_len"] = len(hlo)
        print(compiled.memory_analysis())
        del compiled, lowered, jitted
    except Exception as e:
        rec.update(error=f"{type(e).__name__}: {e}", trace=traceback.format_exc()[-2000:])
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--cells", default=None, help="'all' or comma list arch:shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--layout", default="auto",
                    choices=["auto", "tp", "fsdp", "tokpar", "zero1"])
    ap.add_argument("--decode-unroll", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--subprocess", action="store_true",
                    help="run each cell in a fresh process (bounded memory)")
    args = ap.parse_args()

    cells = []
    if args.cells == "all":
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    elif args.cells:
        for c in args.cells.split(","):
            arch, shape = c.split(":")
            cells.append((arch, shape))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape))

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    outf = open(args.out, "a") if args.out else None

    for arch, shape in cells:
        for mk in meshes:
            if args.subprocess:
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mk,
                       "--microbatches", str(args.microbatches),
                       "--layout", args.layout]
                if args.decode_unroll:
                    cmd += ["--decode-unroll"]
                if args.out:
                    cmd += ["--out", args.out]
                r = subprocess.run(cmd, capture_output=True, text=True)
                tail = (r.stdout + r.stderr).strip().splitlines()[-1:]
                print(f"[{arch} x {shape} x {mk}] rc={r.returncode} {tail}")
                continue
            rec = run_cell(arch, shape, mk, microbatches=args.microbatches,
                           layout=args.layout, decode_unroll=args.decode_unroll)
            line = json.dumps(rec)
            print(line[:400])
            if outf:
                outf.write(line + "\n")
                outf.flush()
    if outf:
        outf.close()


if __name__ == "__main__":
    main()
