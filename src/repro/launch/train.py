"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Examples:
  # smoke-size run on CPU
  python -m repro.launch.train --arch qwen3-4b --smoke --steps 50 --batch 8 --seq 128
  # graph path-task corpus (the paper-integration workload)
  python -m repro.launch.train --arch olmo-1b --smoke --data graph --steps 100
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.data.pipeline import GraphPathData, SyntheticLMData
from repro.models.model import build_model
from repro.runtime.train_loop import TrainLoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--data", default="synthetic", choices=["synthetic", "graph"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    if args.data == "graph":
        data = GraphPathData(seed=0)
    else:
        data = SyntheticLMData(cfg.vocab, seed=0)

    tl = TrainLoopConfig(
        total_steps=args.steps, checkpoint_every=args.ckpt_every,
        checkpoint_dir=args.ckpt_dir, microbatches=args.microbatches, lr=args.lr)
    params, opt_state, history = train(
        model, data, batch_size=args.batch, seq_len=args.seq, cfg=tl)
    print(f"done; final loss {history[-1][1]:.4f}" if history else "done")


if __name__ == "__main__":
    main()
