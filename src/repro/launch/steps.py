"""Step functions lowered by the dry-run and driven by the runtime loops.

  train_step(params, opt_state, batch) -> (params', opt_state', metrics)
  prefill_step(params, batch)          -> (last_logits, caches)
  decode_step(params, caches, tokens, pos) -> (logits, caches')

Microbatched gradient accumulation (``microbatches > 1``) runs under a
lax.scan so XLA's latency-hiding scheduler can overlap microbatch i's
gradient reduce-scatter with microbatch i+1's compute — the standard
compute/comm overlap at scale (see DESIGN.md §6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.optim import adamw, grad_compress


def make_train_step(model, *, lr=3e-4, microbatches: int = 1, remat: bool = True,
                    compress: bool = False, weight_decay: float = 0.1,
                    grad_specs=None):
    """``grad_specs``: optional tree of NamedShardings (= the param specs).
    Gradient sharding normally propagates from the params, but MoE expert
    grads lose it through the dispatch scatter/einsum transposes (observed
    ~100 GB/device replicated expert grads on the 256-chip dry-run);
    pinning grads to the param layout keeps ZeRO semantics."""

    def loss_fn(params, batch):
        loss, metrics = model.loss_and_metrics(params, batch, remat=remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _pin(grads):
        if grad_specs is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads, grad_specs)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = _pin(grads)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc(carry, mbatch):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mbatch)
                g = _pin(g)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zeros = _pin(zeros)
            (g_sum, l_sum), _ = jax.lax.scan(acc, (zeros, jnp.float32(0.0)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, g_sum)
            loss = l_sum / microbatches
            metrics = {"loss": loss, "aux": jnp.float32(0.0)}

        if compress:
            ef = opt_state["ef"]
            grads, ef = grad_compress.compress_decompress(grads, ef)
            new_params, new_adam = adamw.update(
                params, grads, opt_state["adam"], lr=lr, weight_decay=weight_decay)
            return new_params, {"adam": new_adam, "ef": ef}, metrics

        new_params, new_opt = adamw.update(params, grads, opt_state, lr=lr,
                                           weight_decay=weight_decay)
        return new_params, new_opt, metrics

    return train_step


def init_opt_state(model_params, *, compress: bool = False):
    if compress:
        return {"adam": adamw.init(model_params), "ef": grad_compress.init(model_params)}
    return adamw.init(model_params)


def make_prefill_step(model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_decode_step(model):
    def decode_step(params, caches, tokens, pos):
        return model.decode_step(params, caches, tokens, pos)

    return decode_step
