"""Serving launcher: batched LM decode co-hosted with graph queries.

  python -m repro.launch.serve --arch qwen2-1.5b --smoke --batch 4 --new 32
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import OP_ADD_E, OP_ADD_V
from repro.models.model import build_model
from repro.runtime.serve_loop import GraphCoServer, serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)

    graph = GraphCoServer()
    for k in range(16):
        graph.submit([(OP_ADD_V, k)])

    def mutator(i):
        u, v = rng.integers(0, 16, 2)
        return [(OP_ADD_E, int(u), int(v))]

    def queries(i):
        if i % 4 == 0:
            u, v = rng.integers(0, 16, 2)
            return int(u), int(v)
        return None

    out, stats = serve(model, params, prompts, max_new_tokens=args.new,
                       cache_len=args.cache_len, graph=graph,
                       mutator=mutator, query_stream=queries)
    tps = stats.decode_tokens / max(stats.wall_s, 1e-9)
    print(f"decoded {stats.decode_tokens} tokens in {stats.wall_s:.2f}s "
          f"({tps:.1f} tok/s); graph ops {stats.graph_ops}, "
          f"getpath calls {stats.getpath_calls} "
          f"(avg rounds {stats.getpath_rounds / max(stats.getpath_calls, 1):.1f})")


if __name__ == "__main__":
    main()
