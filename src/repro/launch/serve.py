"""Serving launcher: batched LM decode co-hosted with graph queries.

  python -m repro.launch.serve --arch qwen2-1.5b --smoke --batch 4 --new 32

``--ingest`` switches the graph side to the multi-tenant admission pool
(DESIGN.md §12) and exercises the retained epoch ring (DESIGN.md §13):
several simulated clients stream conflicting mutation batches, query
sessions resolve wait-free against the published epoch when starved, and
after the decode loop the launcher issues time-travel reachability and
epoch-diff queries against retained (and one evicted) epochs.

``REPRO_TRACE=1`` arms the observability recorder (DESIGN.md §14): the
run emits a Perfetto-loadable trace (``REPRO_TRACE_PATH``, default
``repro_trace.json``) with the full span hierarchy — ingest round →
fused apply, bfs session → per-superstep direction tags, index query →
ring-validate/fallback — plus a ``get_metrics`` dump. Load the file at
https://ui.perfetto.dev or summarize it with ``tools/trace_view.py``.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.core import OP_ADD_E, OP_ADD_V
from repro.models.model import build_model
from repro.obs import trace
from repro.runtime.serve_loop import GraphCoServer, serve


def _demo_epoch_ring(graph: GraphCoServer, rng) -> None:
    """Post-serve tour of the epoch-ring endpoints (DESIGN.md §13)."""
    lo, hi = graph.epoch_window()
    mid = (lo + hi) // 2
    u, v = (int(x) for x in rng.integers(0, 16, 2))
    tt = graph.get_reach_at([(u, v)], mid)
    print(f"time-travel: reach({u},{v}) at epoch {mid} -> "
          f"{'evicted' if tt.evicted else bool(tt.found[0])} "
          f"(window {lo}..{hi})")
    gone = graph.get_reach_at([(u, v)], lo - 1)
    print(f"time-travel: epoch {lo - 1} -> "
          f"{'evicted' if gone.evicted else 'retained?!'} (typed, no raise)")
    d = graph.epoch_diff(mid, hi)
    print(f"epoch-diff {mid}->{hi}: {len(d.rows)} rows touched")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="tiny config (default; --no-smoke for full size)")
    ap.add_argument("--index", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="maintain the 2-hop reachability index "
                         "(DESIGN.md §9) so queries take the index fast "
                         "path / ring-validate / fallback routes")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--ingest", action="store_true",
                    help="multi-tenant admission pool + epoch-ring demo "
                         "(DESIGN.md §12, §13)")
    ap.add_argument("--clients", type=int, default=3,
                    help="simulated mutation clients under --ingest")
    ap.add_argument("--retain-epochs", type=int, default=16,
                    help="epoch-ring retention window under --ingest")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)

    graph = GraphCoServer(ingest=args.ingest, index=args.index,
                          retain_epochs=args.retain_epochs)
    for k in range(16):
        graph.submit([(OP_ADD_V, k)])

    def mutator(i):
        u, v = rng.integers(0, 16, 2)
        return [(OP_ADD_E, int(u), int(v))]

    def clients(i):
        # every decode step each tenant streams one edge batch; overlapping
        # entity footprints force admission conflicts so coalescing/retry
        # paths (and the epoch ring behind them) actually get exercised
        batches = []
        for c in range(args.clients):
            u, v = rng.integers(0, 16, 2)
            batches.append((f"tenant{c}", [(OP_ADD_E, int(u), int(v))]))
        return batches

    def queries(i):
        if i % 4 == 0:
            u, v = rng.integers(0, 16, 2)
            return int(u), int(v)
        return None

    out, stats = serve(model, params, prompts, max_new_tokens=args.new,
                       cache_len=args.cache_len, graph=graph,
                       mutator=None if args.ingest else mutator,
                       clients=clients if args.ingest else None,
                       query_stream=queries)
    tps = stats.decode_tokens / max(stats.wall_s, 1e-9)
    print(f"decoded {stats.decode_tokens} tokens in {stats.wall_s:.2f}s "
          f"({tps:.1f} tok/s); graph ops {stats.graph_ops}, "
          f"getpath calls {stats.getpath_calls} "
          f"(avg rounds {stats.getpath_rounds / max(stats.getpath_calls, 1):.1f})")
    if args.ingest:
        print(f"ingest: {stats.ingest_batches} batches in "
              f"{stats.ingest_fused_calls} fused applies, "
              f"{stats.ingest_epochs} epochs published; "
              f"starved sessions {stats.getpath_starved} "
              f"(epoch-resolved {stats.epoch_resolved})")
        _demo_epoch_ring(graph, rng)
        print(f"ring endpoints: tt_calls {graph.tt_calls} "
              f"(evicted {graph.tt_evicted}), "
              f"epoch_diff_calls {graph.epoch_diff_calls}")
    if args.index:
        # one query against a deliberately stale index (mutate, don't
        # refresh): exercises the ring-validate / BFS-fallback routes the
        # in-loop queries skip because index_tick refreshes first
        # (DESIGN.md §9, §13 — and their spans under REPRO_TRACE)
        u, v = (int(x) for x in rng.integers(0, 16, 2))
        graph.submit([(OP_ADD_E, u, v)])
        res = graph.get_reach([(u, v)])
        print(f"stale-index reach({u},{v}) -> {res.found[0]} "
              f"(from_index {res.from_index}, fellback {res.fellback}, "
              f"pinned {res.pinned_epoch})")
    if trace.enabled():
        path = trace.save()
        n = len(trace.recorder().events())
        print(f"trace: {n} events -> {path} "
              f"(load at https://ui.perfetto.dev, or "
              f"`python tools/trace_view.py --summarize {path}`)")
        print("metrics:", json.dumps(graph.get_metrics(), indent=2,
                                     default=str))


if __name__ == "__main__":
    main()
