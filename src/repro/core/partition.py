"""Mesh-partitioned graph state — the scale-out form of both engines
(DESIGN.md §8).

``ShardedGraphState`` holds the same logical state as ``GraphState`` with a
split placement over a 1-D device mesh (axis ``"rows"``, shared with
core/distributed.py):

  * ``adj_packed`` and ``adj_in_packed`` — the only O(V^2/32) arrays
    (word-packed out-/in-adjacency, DESIGN.md §10, §11) — are
    ROW-SHARDED: every device owns V/S contiguous packed out-edge rows
    (the edge-lists of its vertices) AND the in-edge rows of the same
    slot block (= the out-adjacency's columns — the column-sharded
    in-row layout the hybrid pull phase runs shard-local over);
  * ``vkey``/``valive``/``vver``/``ecnt`` — the O(V) version metadata — are
    REPLICATED, so lookups (LocV/LocC), the double-collect validation
    vector, and the lane-order mutation schedule are shard-local replicated
    compute with zero communication.

The placement rules live in ``parallel.sharding.graph_state_specs``; the
inside-shard_map helpers (row-block arithmetic, jax-version shims) are
shared with ``core.distributed``.

Engines (each bit-identical to its dense counterpart — the property suite
tests/test_linearizability_prop.py enforces it):

``apply_ops_fast``  distributed disjoint-access-parallel mutation: every
                    shard applies the conflict-free lanes whose source rows
                    it owns in one vectorized step, while the masked serial
                    correction pass runs on the replicated metadata with
                    only per-lane scalar exchanges (edge-presence pmax,
                    in-edge-bump all_gather) touching the wire. Lane-order
                    linearization survives sharding because every decision
                    (conflict mask, allocation schedule, result codes) is a
                    deterministic function of the replicated metadata —
                    shards can only disagree about adjacency bits, and those
                    are exchanged at the exact program points the dense
                    engine reads them (DESIGN.md §8).

``multi_bfs``       distributed fused multi-source BFS: each superstep does
                    a LOCAL [Q, V/S] @ [V/S, V] frontier-matrix product per
                    shard (``backend="pallas"`` reuses the bfs_multi_step
                    kernel on the shard's row slice) followed by ONE psum
                    frontier exchange + pmin parent combine. The packed
                    backends ("packed", "packed_pallas", DESIGN.md §10)
                    expand over the shard's packed WORDS and exchange the
                    partial next frontiers as packed uint32 bitsets —
                    [Q, V/32] words on the wire instead of [Q, V] int32, a
                    32x cut in frontier-exchange volume. Per-query early
                    exit and the double-collect version check carry over
                    unchanged because the validation vector is replicated.

``grow``/``compact`` preserve the sharding (grow re-rounds capacity up to a
                    multiple of the mesh axis so row blocks stay equal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import graph as ggraph
from repro.core import ops as gops
from repro.core.bfs import (
    DEFAULT_ALPHA,
    DEFAULT_BETA,
    HYBRID_BACKENDS,
    PACKED_BACKENDS,
    MultiBFSResult,
    _resolve_backend,
    ctz32,
    pick_direction,
)
from repro.core.distributed import (
    AXIS,
    _SM_NOCHECK,
    _pvary,
    _row_block_info,
    make_graph_mesh,
    shard_map,
)
from repro.core.graph import (
    EMPTY_KEY,
    OP_ADD_E,
    OP_ADD_V,
    OP_CON_E,
    OP_CON_V,
    OP_REM_E,
    OP_REM_V,
    R_CAS_FAIL,
    R_EDGE_ADDED,
    R_EDGE_NOT_PRESENT,
    R_EDGE_PRESENT,
    R_EDGE_REMOVED,
    R_FALSE,
    R_TABLE_FULL,
    R_TRUE,
    R_VERTEX_NOT_PRESENT,
    GraphState,
    OpBatch,
    bit_mask,
    bit_word,
    or_reduce,
    pack_bits,
    unpack_bits,
)
from repro.parallel.sharding import graph_state_shardings

INT32_MAX = jnp.int32(2**31 - 1)


@jax.tree_util.register_pytree_node_class
class ShardedGraphState:
    """Row-partitioned graph state (DESIGN.md §8).

    Same six logical fields as ``GraphState`` (duck-type compatible for
    lookups/version_vector/_materialize), plus the owning ``mesh`` carried
    as static pytree aux data so jitted engines can build shard_maps from
    the state alone. ``adj_in_packed`` shares ``adj_packed``'s row sharding:
    shard s owns the in-rows of ITS slot block — the column-sharded in-row
    layout the hybrid pull phase runs shard-local over (DESIGN.md §11).
    """

    def __init__(self, mesh, vkey, valive, vver, ecnt, adj_packed,
                 adj_in_packed):
        self.mesh = mesh
        self.vkey = vkey
        self.valive = valive
        self.vver = vver
        self.ecnt = ecnt
        self.adj_packed = adj_packed
        self.adj_in_packed = adj_in_packed

    def tree_flatten(self):
        return (self.vkey, self.valive, self.vver, self.ecnt,
                self.adj_packed, self.adj_in_packed), self.mesh

    @classmethod
    def tree_unflatten(cls, mesh, children):
        return cls(mesh, *children)

    @property
    def capacity(self) -> int:
        return self.vkey.shape[0]

    @property
    def num_shards(self) -> int:
        return int(self.mesh.shape[AXIS])

    def as_dense(self) -> GraphState:
        """View as a GraphState pytree (arrays keep their placement)."""
        return GraphState(self.vkey, self.valive, self.vver, self.ecnt,
                          self.adj_packed, self.adj_in_packed)

    @property
    def adj(self) -> jax.Array:
        """Dense uint8[V, V] adjacency view (unpacked on demand)."""
        return self.as_dense().adj

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"ShardedGraphState(capacity={self.capacity}, "
                f"shards={self.num_shards})")


# ----------------------------------------------------------------------------
# Placement / conversion
# ----------------------------------------------------------------------------
def shard_state(mesh, dense: GraphState) -> ShardedGraphState:
    """Place a dense GraphState onto the mesh (DESIGN.md §8 layout)."""
    size = int(mesh.shape[AXIS])
    if dense.capacity % size != 0:
        raise ValueError(
            f"capacity {dense.capacity} not divisible by mesh axis {size}")
    sh = graph_state_shardings(mesh, AXIS)
    return ShardedGraphState(
        mesh,
        jax.device_put(dense.vkey, sh["vkey"]),
        jax.device_put(dense.valive, sh["valive"]),
        jax.device_put(dense.vver, sh["vver"]),
        jax.device_put(dense.ecnt, sh["ecnt"]),
        jax.device_put(dense.adj_packed, sh["adj_packed"]),
        jax.device_put(dense.adj_in_packed, sh["adj_in_packed"]),
    )


def unshard(state: ShardedGraphState) -> GraphState:
    """Gather back to a fully-replicated dense GraphState (tests/host use)."""
    rep = NamedSharding(state.mesh, P())
    return GraphState(*(jax.device_put(x, rep) for x in state.as_dense()))


def grow(state: ShardedGraphState, new_capacity: int) -> ShardedGraphState:
    """Functionally grow capacity, preserving the sharding (DESIGN.md §8).

    Capacity is rounded up to a multiple of the mesh axis so row blocks stay
    equal-sized. Row blocks are redistributed (device k owns a different
    contiguous range after growth), so this is a gather + re-place — the
    same amortized O(V^2) a dense ``grow`` pays, plus one resharding.
    """
    size = int(state.mesh.shape[AXIS])
    new_capacity = -(-int(new_capacity) // size) * size
    if new_capacity <= state.capacity:
        return state
    return shard_state(state.mesh, ggraph.grow(unshard(state), new_capacity))


@jax.jit
def compact(state: ShardedGraphState) -> ShardedGraphState:
    """Physical removal of logically-deleted vertices, shard-local scrub.

    Mirrors ``ops.compact``: frees slots, clears their adjacency rows and
    columns. Each shard scrubs only its own row block; the keep mask is
    replicated metadata (DESIGN.md §8).
    """
    mesh = state.mesh
    v = state.capacity
    size = int(mesh.shape[AXIS])
    dead = (~state.valive) & (state.vkey != EMPTY_KEY)
    keep = ~dead
    vkey = jnp.where(dead, EMPTY_KEY, state.vkey)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(P(AXIS, None), P()),
        out_specs=P(AXIS, None), **_SM_NOCHECK,
    )
    def scrub(adjw_l, keep_g):
        _, _, per, row0 = _row_block_info(v, size)
        keep_l = jax.lax.dynamic_slice(keep_g, (row0,), (per,))
        return jnp.where(keep_l[:, None],
                         adjw_l & pack_bits(keep_g)[None, :], jnp.uint32(0))

    # the scrub is transpose-symmetric (dead rows zeroed, dead column bits
    # masked), so the SAME shard-local pass compacts the column-sharded
    # in-rows (DESIGN.md §11)
    return ShardedGraphState(mesh, vkey, state.valive, state.vver,
                             state.ecnt, scrub(state.adj_packed, keep),
                             scrub(state.adj_in_packed, keep))


# ----------------------------------------------------------------------------
# Distributed mutation engine
# ----------------------------------------------------------------------------
def _find_one(vkey, valive, key):
    """find_slot on the replicated metadata (no GraphState wrapper)."""
    hit = (vkey == key) & valive
    idx = jnp.argmax(hit)
    return jnp.where(jnp.any(hit), idx.astype(jnp.int32), jnp.int32(-1))


@jax.jit
def apply_ops_fast(state: ShardedGraphState, ops: OpBatch):
    """Distributed disjoint-access-parallel batch application.

    Bit-identical to the dense ``ops.apply_ops_fast`` (hence to the
    sequential spec ``ops.apply_ops``): the conflict mask, the AddVertex
    allocation schedule and the overflow fallback are the SAME dense-helper
    computations run on the replicated metadata, so every shard takes the
    same decisions; only adjacency bits differ per shard and they are
    exchanged (edge-presence pmax, in-edge-bump all_gather) at the exact
    points the dense engine reads them. See DESIGN.md §8 for why lane-order
    linearization survives the partitioning.
    """
    mesh = state.mesh
    v = state.capacity
    b = ops.lanes
    size = int(mesh.shape[AXIS])

    meta = state.as_dense()  # replicated metadata view for the dense helpers
    conflict = gops._lane_conflicts(ops)
    wants, slot, overflow = gops._alloc_schedule(meta, ops)
    clean = ~conflict & (ops.opcode != gops.OP_NOP) & ~overflow
    serial = jnp.where(overflow, jnp.ones((b,), jnp.bool_), conflict)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(AXIS, None), P(AXIS, None),
                  P(), P(), P(), P(), P(), P(), P(), P()),
        out_specs=(P(), P(), P(), P(), P(AXIS, None), P(AXIS, None), P()),
        # Metadata outputs are value-replicated (every shard computes the
        # same result from replicated inputs + deterministic collectives),
        # which 0.4.x's check_rep cannot infer through fori_loop.
        **_SM_NOCHECK,
    )
    def run(vkey, valive, vver, ecnt, adj_l, adjin_l,
            opc, k1, k2, expect, cleanv, serialv, wantsv, slotv):
        _, _, per, row0 = _row_block_info(v, size)
        vkey0, valive0, ecnt0, adj0_l = vkey, valive, ecnt, adj_l

        # ------------------------------------------------------------------
        # Clean vectorized pass (mirror of ops._apply_clean_vectorized)
        # ------------------------------------------------------------------
        hit1 = (vkey0[None, :] == k1[:, None]) & valive0[None, :] & (k1[:, None] >= 0)
        hit2 = (vkey0[None, :] == k2[:, None]) & valive0[None, :] & (k2[:, None] >= 0)
        s1 = jnp.where(jnp.any(hit1, axis=1), jnp.argmax(hit1, axis=1).astype(jnp.int32), -1)
        s2 = jnp.where(jnp.any(hit2, axis=1), jnp.argmax(hit2, axis=1).astype(jnp.int32), -1)

        is_addv = cleanv & (opc == OP_ADD_V)
        is_conv = cleanv & (opc == OP_CON_V)
        is_adde = cleanv & (opc == OP_ADD_E)
        is_reme = cleanv & (opc == OP_REM_E)
        is_cone = cleanv & (opc == OP_CON_E)
        res = jnp.full((b,), R_FALSE, jnp.int32)

        # AddVertex via the precomputed schedule
        alloc = jnp.where(is_addv & wantsv, slotv, v)
        vkey = vkey.at[alloc].set(k1, mode="drop")
        valive = valive.at[alloc].set(True, mode="drop")
        vver = vver.at[alloc].add(1, mode="drop")
        ecnt = ecnt.at[alloc].set(0, mode="drop")
        lr = alloc - row0
        lr = jnp.where((lr >= 0) & (lr < per), lr, per)
        adj_l = adj_l.at[lr, :].set(jnp.uint32(0), mode="drop")
        # the scrub is transpose-symmetric: the shard's column-sharded
        # in-rows take the identical row scatter + column mask (§11)
        adjin_l = adjin_l.at[lr, :].set(jnp.uint32(0), mode="drop")
        # column-bit scrub: one packed AND-NOT mask over the local rows
        clear_cols = jnp.zeros((v,), jnp.bool_).at[alloc].set(True, mode="drop")
        clear_mask = ~pack_bits(clear_cols)[None, :]
        adj_l = adj_l & clear_mask
        adjin_l = adjin_l & clear_mask
        res = jnp.where(is_addv, jnp.where(wantsv, R_TRUE, R_FALSE), res)

        # ContainsVertex
        res = jnp.where(is_conv, jnp.where(s1 >= 0, R_TRUE, R_FALSE), res)

        # Edge ops: presence lives on the owner shard -> masked bit read + pmax
        both = (s1 >= 0) & (s2 >= 0)
        r1, r2 = jnp.maximum(s1, 0), jnp.maximum(s2, 0)
        l1 = r1 - row0
        mine1 = (l1 >= 0) & (l1 < per)
        cur_loc = (adj0_l[jnp.clip(l1, 0, per - 1), bit_word(r2)]
                   & bit_mask(r2)) > 0
        cur = jax.lax.pmax(
            jnp.where(mine1, cur_loc.astype(jnp.int32), 0), AXIS) > 0
        cas_ok = (expect < 0) | (ecnt0[r1] == expect)

        do_add = is_adde & both & cas_ok & ~cur
        do_rem = is_reme & both & cas_ok & cur
        # masked bit set/clear on the owner's word (clean lanes own
        # pairwise-distinct rows, so the word RMWs are conflict-free)
        el = jnp.where((do_add | do_rem) & mine1, l1, per)
        wc, mb = bit_word(r2), bit_mask(r2)
        curw = adj_l[jnp.clip(el, 0, per - 1), wc]
        neww = jnp.where(do_add, curw | mb, curw & ~mb)
        adj_l = adj_l.at[el, wc].set(neww, mode="drop")
        # mirrored in-row RMW on the DESTINATION owner's shard (§11):
        # clean lanes' key sets are disjoint, so destination rows are
        # pairwise-distinct too and the scatter stays conflict-free
        l2 = r2 - row0
        mine2 = (l2 >= 0) & (l2 < per)
        el2 = jnp.where((do_add | do_rem) & mine2, l2, per)
        wc2, mb2 = bit_word(r1), bit_mask(r1)
        curw2 = adjin_l[jnp.clip(el2, 0, per - 1), wc2]
        neww2 = jnp.where(do_add, curw2 | mb2, curw2 & ~mb2)
        adjin_l = adjin_l.at[el2, wc2].set(neww2, mode="drop")
        ecnt = ecnt.at[jnp.where(do_add | do_rem, r1, v)].add(1, mode="drop")

        res = jnp.where(
            is_adde,
            jnp.where(both, jnp.where(cas_ok, jnp.where(cur, R_EDGE_PRESENT, R_EDGE_ADDED), R_CAS_FAIL), R_VERTEX_NOT_PRESENT),
            res,
        )
        res = jnp.where(
            is_reme,
            jnp.where(both, jnp.where(cas_ok, jnp.where(cur, R_EDGE_REMOVED, R_EDGE_NOT_PRESENT), R_CAS_FAIL), R_VERTEX_NOT_PRESENT),
            res,
        )
        res = jnp.where(
            is_cone,
            jnp.where(both, jnp.where(cur, R_EDGE_PRESENT, R_EDGE_NOT_PRESENT), R_VERTEX_NOT_PRESENT),
            res,
        )

        # ------------------------------------------------------------------
        # Serial correction pass (mirror of ops._apply_one, lane order).
        # Runs every lane unconditionally (uniform collectives across
        # shards); non-serial lanes are masked out of all writes.
        # ------------------------------------------------------------------
        def body(i, carry):
            vkey, valive, vver, ecnt, adj_l, adjin_l, res = carry
            m = serialv[i]
            op, a, bk, exp = opc[i], k1[i], k2[i], expect[i]
            sa = _find_one(vkey, valive, a)
            sb = _find_one(vkey, valive, bk)

            # AddVertex
            free = vkey == EMPTY_KEY
            have = jnp.any(free)
            new = jnp.argmax(free).astype(jnp.int32)
            exists = sa >= 0
            do_av = m & (op == OP_ADD_V) & ~exists & have
            tgt = jnp.where(do_av, new, v)
            vkey = vkey.at[tgt].set(a, mode="drop")
            valive = valive.at[tgt].set(True, mode="drop")
            vver = vver.at[tgt].add(1, mode="drop")
            ecnt = ecnt.at[tgt].set(0, mode="drop")
            ltgt = tgt - row0
            ltgt = jnp.where((ltgt >= 0) & (ltgt < per), ltgt, per)
            adj_l = adj_l.at[ltgt, :].set(jnp.uint32(0), mode="drop")
            adjin_l = adjin_l.at[ltgt, :].set(jnp.uint32(0), mode="drop")
            # column-bit scrub, guarded by the scalar do_av (transpose-
            # symmetric, so the in-rows take the identical mask, §11)
            tsafe = jnp.minimum(tgt, v - 1)
            colw = adj_l[:, bit_word(tsafe)]
            adj_l = adj_l.at[:, bit_word(tsafe)].set(
                jnp.where(do_av, colw & ~bit_mask(tsafe), colw))
            colw_in = adjin_l[:, bit_word(tsafe)]
            adjin_l = adjin_l.at[:, bit_word(tsafe)].set(
                jnp.where(do_av, colw_in & ~bit_mask(tsafe), colw_in))
            r_addv = jnp.where(exists, R_FALSE, jnp.where(have, R_TRUE, R_TABLE_FULL))

            # RemoveVertex (in-edge-source bumps read the pre-lane liveness)
            valive_in = valive
            do_rv = m & (op == OP_REM_V) & (sa >= 0)
            t = jnp.where(do_rv, sa, v)
            valive = valive.at[t].set(False, mode="drop")
            vver = vver.at[t].add(1, mode="drop")
            ecnt = ecnt.at[t].add(1, mode="drop")
            col = jnp.maximum(sa, 0)
            valive_l = jax.lax.dynamic_slice(valive_in, (row0,), (per,))
            bump_l = do_rv & ((adj_l[:, bit_word(col)] & bit_mask(col)) > 0) \
                & valive_l
            bump = jax.lax.all_gather(bump_l, AXIS, tiled=True)
            ecnt = ecnt + bump.astype(jnp.int32)
            r_remv = jnp.where(sa >= 0, R_TRUE, R_FALSE)

            # ContainsVertex
            r_conv = jnp.where(sa >= 0, R_TRUE, R_FALSE)

            # Edge ops
            eboth = (sa >= 0) & (sb >= 0)
            ra, rb = jnp.maximum(sa, 0), jnp.maximum(sb, 0)
            la = ra - row0
            amine = (la >= 0) & (la < per)
            cur = jax.lax.pmax(
                jnp.where(amine,
                          ((adj_l[jnp.clip(la, 0, per - 1), bit_word(rb)]
                            & bit_mask(rb)) > 0).astype(jnp.int32), 0),
                AXIS) > 0
            ecas = (exp < 0) | (ecnt[ra] == exp)
            do_ea = m & (op == OP_ADD_E) & eboth & ecas & ~cur
            do_er = m & (op == OP_REM_E) & eboth & ecas & cur
            ela = jnp.where((do_ea | do_er) & amine, la, per)
            ecurw = adj_l[jnp.clip(ela, 0, per - 1), bit_word(rb)]
            enew = jnp.where(do_ea, ecurw | bit_mask(rb), ecurw & ~bit_mask(rb))
            adj_l = adj_l.at[ela, bit_word(rb)].set(enew, mode="drop")
            # mirrored in-row RMW on the destination owner's shard (§11)
            lb = rb - row0
            bmine = (lb >= 0) & (lb < per)
            elb = jnp.where((do_ea | do_er) & bmine, lb, per)
            ecurw_in = adjin_l[jnp.clip(elb, 0, per - 1), bit_word(ra)]
            enew_in = jnp.where(do_ea, ecurw_in | bit_mask(ra),
                                ecurw_in & ~bit_mask(ra))
            adjin_l = adjin_l.at[elb, bit_word(ra)].set(enew_in, mode="drop")
            ecnt = ecnt.at[jnp.where(do_ea | do_er, ra, v)].add(1, mode="drop")
            r_adde = jnp.where(eboth, jnp.where(ecas, jnp.where(cur, R_EDGE_PRESENT, R_EDGE_ADDED), R_CAS_FAIL), R_VERTEX_NOT_PRESENT)
            r_reme = jnp.where(eboth, jnp.where(ecas, jnp.where(cur, R_EDGE_REMOVED, R_EDGE_NOT_PRESENT), R_CAS_FAIL), R_VERTEX_NOT_PRESENT)
            r_cone = jnp.where(eboth, jnp.where(cur, R_EDGE_PRESENT, R_EDGE_NOT_PRESENT), R_VERTEX_NOT_PRESENT)

            r = jax.lax.switch(
                jnp.clip(op, 0, 6),
                [lambda: jnp.int32(R_FALSE),
                 lambda: r_addv.astype(jnp.int32),
                 lambda: r_remv.astype(jnp.int32),
                 lambda: r_conv.astype(jnp.int32),
                 lambda: r_adde.astype(jnp.int32),
                 lambda: r_reme.astype(jnp.int32),
                 lambda: r_cone.astype(jnp.int32)],
            )
            res = res.at[i].set(jnp.where(m, r, res[i]))
            return vkey, valive, vver, ecnt, adj_l, adjin_l, res

        vkey, valive, vver, ecnt, adj_l, adjin_l, res = jax.lax.fori_loop(
            0, b, body, (vkey, valive, vver, ecnt, adj_l, adjin_l, res))
        return vkey, valive, vver, ecnt, adj_l, adjin_l, res

    vkey, valive, vver, ecnt, adj, adj_in, res = run(
        state.vkey, state.valive, state.vver, state.ecnt, state.adj_packed,
        state.adj_in_packed,
        ops.opcode, ops.key1, ops.key2, ops.expect,
        clean, serial, wants, slot,
    )
    return ShardedGraphState(mesh, vkey, valive, vver, ecnt, adj,
                             adj_in), res


# ----------------------------------------------------------------------------
# Distributed fused multi-source BFS
# ----------------------------------------------------------------------------
def multi_bfs(state: ShardedGraphState, src_slots, dst_slots,
              backend: str | None = None, alpha: int = DEFAULT_ALPHA,
              beta: int = DEFAULT_BETA) -> MultiBFSResult:
    """Fused BFS from Q sources over the row-sharded adjacency.

    Each superstep: every shard expands the slice of all Q frontiers it owns
    with ONE local [Q, V/S] @ [V/S, V] product (``backend="pallas"`` runs
    the bfs_multi_step kernel on the row slice), then the partial next
    frontiers are OR-combined with a single psum and parents min-combined
    with a pmin — the row-partitioned frontier exchange of DESIGN.md §8.
    Per-query early exit is the dense engine's: finished queries expose an
    all-empty frontier on every shard. Results are bit-identical to
    ``core.bfs.multi_bfs`` on the gathered state.

    The hybrid backends (DESIGN.md §11) add the direction-optimizing
    superstep: the push phase is the packed local expansion above; the pull
    phase runs SHARD-LOCAL over the column-sharded in-rows — each shard
    scans only the in-adjacency rows of the V/S destinations it owns
    against the replicated packed frontier bitsets, producing a disjoint
    [Q, V/S] partial. Either phase feeds the SAME packed uint32 frontier
    exchange (all_gather + OR-fold) and pmin parent combine, so the
    direction switch (replicated popcounts → identical on every shard,
    chosen inside the superstep with no collective in either branch) never
    changes the communication pattern. ``backend=None`` resolves via
    ``core.bfs.default_backend()`` HERE, outside the jit boundary, so the
    resolved name (not None) is the static cache key and a changed
    ``REPRO_BFS_BACKEND`` takes effect on the next call.

    Under tracing (DESIGN.md §14) the call is wrapped in one
    ``bfs.session.sharded`` span recording supersteps and the estimated
    packed frontier-exchange volume (the per-superstep psum/all_gather of
    [Q, ceil(V/32)] uint32 words across all shards). The while_loop stays
    inside shard_map, so there are no per-superstep child spans here —
    superstep-level attribution is the dense engine's traced path.
    """
    backend = _resolve_backend(backend)
    from repro.obs import trace as _trace
    if _trace.enabled() and not isinstance(state.valive, jax.core.Tracer):
        from repro.obs.metrics import global_registry as _obs_registry

        q = int(jnp.asarray(src_slots).shape[0])
        v = int(state.capacity)
        size = int(state.mesh.shape[AXIS])
        with _trace.span("bfs.session.sharded", queries=q, capacity=v,
                         shards=size, backend=backend) as sp:
            res = _multi_bfs_jit(state, src_slots, dst_slots,
                                 backend=backend, alpha=alpha, beta=beta)
            _trace.fence(res)
            steps = int(res.supersteps)
            words = (v + 31) // 32
            xbytes = steps * q * words * 4 * size
            sp.set(supersteps=steps, exchange_bytes=xbytes)
            reg = _obs_registry()
            reg.inc("bfs.supersteps", steps)
            reg.inc("bfs.exchange_bytes", xbytes)
        return res
    return _multi_bfs_jit(state, src_slots, dst_slots,
                          backend=backend, alpha=alpha,
                          beta=beta)


@functools.partial(jax.jit, static_argnames=("backend", "alpha", "beta"))
def _multi_bfs_jit(state: ShardedGraphState, src_slots, dst_slots,
                   backend: str, alpha: int,
                   beta: int) -> MultiBFSResult:
    mesh = state.mesh
    v = state.capacity
    size = int(mesh.shape[AXIS])
    src_slots = jnp.asarray(src_slots, jnp.int32)
    dst_slots = jnp.asarray(dst_slots, jnp.int32)
    q = src_slots.shape[0]

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(), P(AXIS, None), P(AXIS, None), P(), P()),
        out_specs=(P(), P(), P(), P(), P(), P()),
        # Outputs are value-replicated (combined via psum/pmin every
        # superstep), which the 0.4.x checker cannot infer past while_loop.
        **_SM_NOCHECK,
    )
    def run(alive, adjw_l, adjw_in_l, srcs, dsts):
        _, _, per, row0 = _row_block_info(v, size)
        hybrid = backend in HYBRID_BACKENDS
        packed = backend in PACKED_BACKENDS or hybrid
        alive_l = jax.lax.dynamic_slice(alive, (row0,), (per,))
        # the jnp-level edge views derive from the ONE traversable
        # predicate (row-slice form, DESIGN.md §10) — the Pallas branches
        # stream raw tiles and apply the same mask in their epilogue, per
        # the kernel contract. Loop-invariant, so hoisted out of the body.
        t_l = tw_l = None
        if backend in ("packed", "hybrid"):
            tw_l = ggraph.traversable_packed(adjw_l, alive_l,
                                             pack_bits(alive))
            # parent candidates still need per-bit rows, unpacked ONCE
            t_l = unpack_bits(tw_l, v)
        elif backend == "jnp":
            t_l = ggraph.traversable(unpack_bits(adjw_l, v), alive_l, alive)
        elif backend == "pallas":
            adj_l = unpack_bits(adjw_l, v).astype(jnp.uint8)
        src_ok = (srcs >= 0) & alive[jnp.maximum(srcs, 0)]
        s = jnp.maximum(srcs, 0)
        frontier0 = jnp.zeros((q, v), jnp.bool_).at[jnp.arange(q), s].set(src_ok)
        visited0 = frontier0
        parent0 = jnp.full((q, v), -1, jnp.int32)
        dist0 = jnp.where(frontier0, 0, -1).astype(jnp.int32)
        expanded0 = jnp.zeros((q, v), jnp.bool_)
        steps0 = jnp.zeros((q,), jnp.int32)
        frontier0, visited0, parent0, dist0, expanded0, steps0 = jax.tree.map(
            _pvary, (frontier0, visited0, parent0, dist0, expanded0, steps0))

        def _active(frontiers, visited, step):
            hit = (dsts >= 0) & visited[jnp.arange(q), jnp.maximum(dsts, 0)]
            return jnp.any(frontiers, axis=1) & ~hit & (step < v)

        def cond(c):
            frontiers, visited = c[:2]
            step = c[6]
            return jnp.any(_active(frontiers, visited, step))

        def _push_local(f, f_l, visited):
            """Local top-down partial: (reach_part [Q, V], cand [Q, V])."""
            if backend == "pallas":
                from repro.kernels.bfs_multi_step.ops import multi_bfs_step

                new_p, par_p = multi_bfs_step(f_l, adj_l, alive, visited)
                return new_p, jnp.where(par_p >= 0, par_p + row0, INT32_MAX)
            if backend in ("packed_pallas", "hybrid_pallas"):
                from repro.kernels.bfs_multi_step.ops import multi_bfs_step_packed

                new_p, par_p = multi_bfs_step_packed(f_l, adjw_l, alive,
                                                     visited)
                return new_p, jnp.where(par_p >= 0, par_p + row0, INT32_MAX)
            if backend in ("packed", "hybrid"):
                sel = jnp.where(f_l[:, :, None], tw_l[None, :, :],
                                jnp.uint32(0))
                reach_part = unpack_bits(or_reduce(sel, 1), v)
            else:
                reach_part = (f_l.astype(jnp.float32)
                              @ t_l.astype(jnp.float32)) > 0
            idx = (jnp.arange(per, dtype=jnp.int32) + row0)[:, None, None]
            cand3 = jnp.where(f_l.T[:, :, None] & t_l[:, None, :],
                              idx, INT32_MAX)
            return reach_part, jnp.min(cand3, axis=0)

        def _pull_local(f, visited):
            """Local bottom-up partial over the shard's in-rows (§11):
            disjoint [Q, V/S] destination slices embedded into [Q, V]."""
            visited_l = jax.lax.dynamic_slice(visited, (0, row0), (q, per))
            fw = pack_bits(f & alive[None, :])
            if backend == "hybrid_pallas":
                from repro.kernels.bfs_pull_step.ops import (
                    multi_bfs_pull_step_rows,
                )

                new_l, par_l = multi_bfs_pull_step_rows(
                    fw, adjw_in_l, alive_l, visited_l)
                pmin_l = jnp.where(new_l, par_l, INT32_MAX)
            else:
                cand_w = adjw_in_l[None, :, :] & fw[:, None, :]  # [Q,per,W]
                hit_l = jnp.any(cand_w != 0, axis=2)
                new_l = hit_l & alive_l[None, :] & ~visited_l
                widx = (jnp.arange(adjw_in_l.shape[1], dtype=jnp.int32)
                        * ggraph.WORD_BITS)[None, None, :]
                pc = jnp.where(cand_w != 0, widx + ctz32(cand_w), INT32_MAX)
                pmin_l = jnp.where(new_l, jnp.min(pc, axis=2), INT32_MAX)
            reach_part = jax.lax.dynamic_update_slice(
                jnp.zeros((q, v), jnp.bool_), new_l, (0, row0))
            cand = jax.lax.dynamic_update_slice(
                jnp.full((q, v), INT32_MAX, jnp.int32), pmin_l, (0, row0))
            return reach_part, cand

        def body(c):
            frontiers, visited, parent, dist, expanded, steps, step = c[:7]
            act = _active(frontiers, visited, step)
            f = frontiers & act[:, None]
            expanded = expanded | f
            f_l = jax.lax.dynamic_slice(f, (0, row0), (q, per))
            if hybrid:
                # replicated popcounts → identical decision on every shard;
                # both cond branches are collective-free, the exchange
                # below is shared (§11)
                nf = jnp.sum(f.astype(jnp.int32))
                nu = jnp.sum(((alive[None, :] & ~visited)
                              & act[:, None]).astype(jnp.int32))
                pulling = pick_direction(c[7], nf, nu, q * v, alpha, beta)
                reach_part, cand = jax.lax.cond(
                    pulling,
                    lambda ff, ff_l, vis: _pull_local(ff, vis),
                    _push_local,
                    f, f_l, visited)
            else:
                reach_part, cand = _push_local(f, f_l, visited)
            if packed:
                # the DESIGN.md §10 frontier exchange: the partial next
                # frontiers cross the wire as packed uint32 bitsets
                # ([Q, V/32] words, 32x less than the int32 psum), OR-folded
                # after ONE all_gather
                parts = jax.lax.all_gather(pack_bits(reach_part), AXIS)
                reach = unpack_bits(or_reduce(parts, 0), v)
            else:
                reach = jax.lax.psum(reach_part.astype(jnp.int32), AXIS) > 0
            par_min = jax.lax.pmin(cand, AXIS)
            new = reach & alive[None, :] & ~visited
            parent = jnp.where(new, par_min, parent)
            dist = jnp.where(new, step + 1, dist)
            visited = visited | new
            steps = steps + act.astype(jnp.int32)
            out = (new, visited, parent, dist, expanded, steps, step + 1)
            return out + (pulling,) if hybrid else out

        init = (frontier0, visited0, parent0, dist0, expanded0, steps0,
                jnp.int32(0))
        if hybrid:
            init = init + (_pvary(jnp.asarray(False)),)
        final = jax.lax.while_loop(cond, body, init)
        frontiers, visited, parent, dist, expanded, steps, supersteps = \
            final[:7]
        found = ((dsts >= 0)
                 & visited[jnp.arange(q), jnp.maximum(dsts, 0)] & src_ok)
        return found, parent, dist, expanded, steps, supersteps

    found, parent, dist, expanded, steps, supersteps = run(
        state.valive, state.adj_packed, state.adj_in_packed,
        src_slots, dst_slots)
    return MultiBFSResult(found, parent, dist, expanded, steps, supersteps)


__all__ = [
    "ShardedGraphState",
    "apply_ops_fast",
    "compact",
    "grow",
    "make_graph_mesh",
    "multi_bfs",
    "shard_state",
    "unshard",
]
