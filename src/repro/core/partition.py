"""Mesh-partitioned graph state — the scale-out form of both engines
(DESIGN.md §8).

``ShardedGraphState`` holds the same logical state as ``GraphState`` with a
split placement over a 1-D device mesh (axis ``"rows"``, shared with
core/distributed.py):

  * ``adj_packed`` — the only O(V^2/32) array (word-packed adjacency,
    DESIGN.md §10) — is ROW-SHARDED: every device owns V/S contiguous
    packed adjacency rows (the edge-lists of its vertices);
  * ``vkey``/``valive``/``vver``/``ecnt`` — the O(V) version metadata — are
    REPLICATED, so lookups (LocV/LocC), the double-collect validation
    vector, and the lane-order mutation schedule are shard-local replicated
    compute with zero communication.

The placement rules live in ``parallel.sharding.graph_state_specs``; the
inside-shard_map helpers (row-block arithmetic, jax-version shims) are
shared with ``core.distributed``.

Engines (each bit-identical to its dense counterpart — the property suite
tests/test_linearizability_prop.py enforces it):

``apply_ops_fast``  distributed disjoint-access-parallel mutation: every
                    shard applies the conflict-free lanes whose source rows
                    it owns in one vectorized step, while the masked serial
                    correction pass runs on the replicated metadata with
                    only per-lane scalar exchanges (edge-presence pmax,
                    in-edge-bump all_gather) touching the wire. Lane-order
                    linearization survives sharding because every decision
                    (conflict mask, allocation schedule, result codes) is a
                    deterministic function of the replicated metadata —
                    shards can only disagree about adjacency bits, and those
                    are exchanged at the exact program points the dense
                    engine reads them (DESIGN.md §8).

``multi_bfs``       distributed fused multi-source BFS: each superstep does
                    a LOCAL [Q, V/S] @ [V/S, V] frontier-matrix product per
                    shard (``backend="pallas"`` reuses the bfs_multi_step
                    kernel on the shard's row slice) followed by ONE psum
                    frontier exchange + pmin parent combine. The packed
                    backends ("packed", "packed_pallas", DESIGN.md §10)
                    expand over the shard's packed WORDS and exchange the
                    partial next frontiers as packed uint32 bitsets —
                    [Q, V/32] words on the wire instead of [Q, V] int32, a
                    32x cut in frontier-exchange volume. Per-query early
                    exit and the double-collect version check carry over
                    unchanged because the validation vector is replicated.

``grow``/``compact`` preserve the sharding (grow re-rounds capacity up to a
                    multiple of the mesh axis so row blocks stay equal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import graph as ggraph
from repro.core import ops as gops
from repro.core.bfs import PACKED_BACKENDS, MultiBFSResult
from repro.core.distributed import (
    AXIS,
    _SM_NOCHECK,
    _pvary,
    _row_block_info,
    make_graph_mesh,
    shard_map,
)
from repro.core.graph import (
    EMPTY_KEY,
    OP_ADD_E,
    OP_ADD_V,
    OP_CON_E,
    OP_CON_V,
    OP_REM_E,
    OP_REM_V,
    R_CAS_FAIL,
    R_EDGE_ADDED,
    R_EDGE_NOT_PRESENT,
    R_EDGE_PRESENT,
    R_EDGE_REMOVED,
    R_FALSE,
    R_TABLE_FULL,
    R_TRUE,
    R_VERTEX_NOT_PRESENT,
    GraphState,
    OpBatch,
    bit_mask,
    bit_word,
    or_reduce,
    pack_bits,
    unpack_bits,
)
from repro.parallel.sharding import graph_state_shardings

INT32_MAX = jnp.int32(2**31 - 1)


@jax.tree_util.register_pytree_node_class
class ShardedGraphState:
    """Row-partitioned graph state (DESIGN.md §8).

    Same five logical fields as ``GraphState`` (duck-type compatible for
    lookups/version_vector/_materialize), plus the owning ``mesh`` carried
    as static pytree aux data so jitted engines can build shard_maps from
    the state alone.
    """

    def __init__(self, mesh, vkey, valive, vver, ecnt, adj_packed):
        self.mesh = mesh
        self.vkey = vkey
        self.valive = valive
        self.vver = vver
        self.ecnt = ecnt
        self.adj_packed = adj_packed

    def tree_flatten(self):
        return (self.vkey, self.valive, self.vver, self.ecnt,
                self.adj_packed), self.mesh

    @classmethod
    def tree_unflatten(cls, mesh, children):
        return cls(mesh, *children)

    @property
    def capacity(self) -> int:
        return self.vkey.shape[0]

    @property
    def num_shards(self) -> int:
        return int(self.mesh.shape[AXIS])

    def as_dense(self) -> GraphState:
        """View as a GraphState pytree (arrays keep their placement)."""
        return GraphState(self.vkey, self.valive, self.vver, self.ecnt,
                          self.adj_packed)

    @property
    def adj(self) -> jax.Array:
        """Dense uint8[V, V] adjacency view (unpacked on demand)."""
        return self.as_dense().adj

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"ShardedGraphState(capacity={self.capacity}, "
                f"shards={self.num_shards})")


# ----------------------------------------------------------------------------
# Placement / conversion
# ----------------------------------------------------------------------------
def shard_state(mesh, dense: GraphState) -> ShardedGraphState:
    """Place a dense GraphState onto the mesh (DESIGN.md §8 layout)."""
    size = int(mesh.shape[AXIS])
    if dense.capacity % size != 0:
        raise ValueError(
            f"capacity {dense.capacity} not divisible by mesh axis {size}")
    sh = graph_state_shardings(mesh, AXIS)
    return ShardedGraphState(
        mesh,
        jax.device_put(dense.vkey, sh["vkey"]),
        jax.device_put(dense.valive, sh["valive"]),
        jax.device_put(dense.vver, sh["vver"]),
        jax.device_put(dense.ecnt, sh["ecnt"]),
        jax.device_put(dense.adj_packed, sh["adj_packed"]),
    )


def unshard(state: ShardedGraphState) -> GraphState:
    """Gather back to a fully-replicated dense GraphState (tests/host use)."""
    rep = NamedSharding(state.mesh, P())
    return GraphState(*(jax.device_put(x, rep) for x in state.as_dense()))


def grow(state: ShardedGraphState, new_capacity: int) -> ShardedGraphState:
    """Functionally grow capacity, preserving the sharding (DESIGN.md §8).

    Capacity is rounded up to a multiple of the mesh axis so row blocks stay
    equal-sized. Row blocks are redistributed (device k owns a different
    contiguous range after growth), so this is a gather + re-place — the
    same amortized O(V^2) a dense ``grow`` pays, plus one resharding.
    """
    size = int(state.mesh.shape[AXIS])
    new_capacity = -(-int(new_capacity) // size) * size
    if new_capacity <= state.capacity:
        return state
    return shard_state(state.mesh, ggraph.grow(unshard(state), new_capacity))


@jax.jit
def compact(state: ShardedGraphState) -> ShardedGraphState:
    """Physical removal of logically-deleted vertices, shard-local scrub.

    Mirrors ``ops.compact``: frees slots, clears their adjacency rows and
    columns. Each shard scrubs only its own row block; the keep mask is
    replicated metadata (DESIGN.md §8).
    """
    mesh = state.mesh
    v = state.capacity
    size = int(mesh.shape[AXIS])
    dead = (~state.valive) & (state.vkey != EMPTY_KEY)
    keep = ~dead
    vkey = jnp.where(dead, EMPTY_KEY, state.vkey)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(P(AXIS, None), P()),
        out_specs=P(AXIS, None), **_SM_NOCHECK,
    )
    def scrub(adjw_l, keep_g):
        _, _, per, row0 = _row_block_info(v, size)
        keep_l = jax.lax.dynamic_slice(keep_g, (row0,), (per,))
        return jnp.where(keep_l[:, None],
                         adjw_l & pack_bits(keep_g)[None, :], jnp.uint32(0))

    return ShardedGraphState(mesh, vkey, state.valive, state.vver,
                             state.ecnt, scrub(state.adj_packed, keep))


# ----------------------------------------------------------------------------
# Distributed mutation engine
# ----------------------------------------------------------------------------
def _find_one(vkey, valive, key):
    """find_slot on the replicated metadata (no GraphState wrapper)."""
    hit = (vkey == key) & valive
    idx = jnp.argmax(hit)
    return jnp.where(jnp.any(hit), idx.astype(jnp.int32), jnp.int32(-1))


@jax.jit
def apply_ops_fast(state: ShardedGraphState, ops: OpBatch):
    """Distributed disjoint-access-parallel batch application.

    Bit-identical to the dense ``ops.apply_ops_fast`` (hence to the
    sequential spec ``ops.apply_ops``): the conflict mask, the AddVertex
    allocation schedule and the overflow fallback are the SAME dense-helper
    computations run on the replicated metadata, so every shard takes the
    same decisions; only adjacency bits differ per shard and they are
    exchanged (edge-presence pmax, in-edge-bump all_gather) at the exact
    points the dense engine reads them. See DESIGN.md §8 for why lane-order
    linearization survives the partitioning.
    """
    mesh = state.mesh
    v = state.capacity
    b = ops.lanes
    size = int(mesh.shape[AXIS])

    meta = state.as_dense()  # replicated metadata view for the dense helpers
    conflict = gops._lane_conflicts(ops)
    wants, slot, overflow = gops._alloc_schedule(meta, ops)
    clean = ~conflict & (ops.opcode != gops.OP_NOP) & ~overflow
    serial = jnp.where(overflow, jnp.ones((b,), jnp.bool_), conflict)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(AXIS, None),
                  P(), P(), P(), P(), P(), P(), P(), P()),
        out_specs=(P(), P(), P(), P(), P(AXIS, None), P()),
        # Metadata outputs are value-replicated (every shard computes the
        # same result from replicated inputs + deterministic collectives),
        # which 0.4.x's check_rep cannot infer through fori_loop.
        **_SM_NOCHECK,
    )
    def run(vkey, valive, vver, ecnt, adj_l,
            opc, k1, k2, expect, cleanv, serialv, wantsv, slotv):
        _, _, per, row0 = _row_block_info(v, size)
        vkey0, valive0, ecnt0, adj0_l = vkey, valive, ecnt, adj_l

        # ------------------------------------------------------------------
        # Clean vectorized pass (mirror of ops._apply_clean_vectorized)
        # ------------------------------------------------------------------
        hit1 = (vkey0[None, :] == k1[:, None]) & valive0[None, :] & (k1[:, None] >= 0)
        hit2 = (vkey0[None, :] == k2[:, None]) & valive0[None, :] & (k2[:, None] >= 0)
        s1 = jnp.where(jnp.any(hit1, axis=1), jnp.argmax(hit1, axis=1).astype(jnp.int32), -1)
        s2 = jnp.where(jnp.any(hit2, axis=1), jnp.argmax(hit2, axis=1).astype(jnp.int32), -1)

        is_addv = cleanv & (opc == OP_ADD_V)
        is_conv = cleanv & (opc == OP_CON_V)
        is_adde = cleanv & (opc == OP_ADD_E)
        is_reme = cleanv & (opc == OP_REM_E)
        is_cone = cleanv & (opc == OP_CON_E)
        res = jnp.full((b,), R_FALSE, jnp.int32)

        # AddVertex via the precomputed schedule
        alloc = jnp.where(is_addv & wantsv, slotv, v)
        vkey = vkey.at[alloc].set(k1, mode="drop")
        valive = valive.at[alloc].set(True, mode="drop")
        vver = vver.at[alloc].add(1, mode="drop")
        ecnt = ecnt.at[alloc].set(0, mode="drop")
        lr = alloc - row0
        lr = jnp.where((lr >= 0) & (lr < per), lr, per)
        adj_l = adj_l.at[lr, :].set(jnp.uint32(0), mode="drop")
        # column-bit scrub: one packed AND-NOT mask over the local rows
        clear_cols = jnp.zeros((v,), jnp.bool_).at[alloc].set(True, mode="drop")
        adj_l = adj_l & ~pack_bits(clear_cols)[None, :]
        res = jnp.where(is_addv, jnp.where(wantsv, R_TRUE, R_FALSE), res)

        # ContainsVertex
        res = jnp.where(is_conv, jnp.where(s1 >= 0, R_TRUE, R_FALSE), res)

        # Edge ops: presence lives on the owner shard -> masked bit read + pmax
        both = (s1 >= 0) & (s2 >= 0)
        r1, r2 = jnp.maximum(s1, 0), jnp.maximum(s2, 0)
        l1 = r1 - row0
        mine1 = (l1 >= 0) & (l1 < per)
        cur_loc = (adj0_l[jnp.clip(l1, 0, per - 1), bit_word(r2)]
                   & bit_mask(r2)) > 0
        cur = jax.lax.pmax(
            jnp.where(mine1, cur_loc.astype(jnp.int32), 0), AXIS) > 0
        cas_ok = (expect < 0) | (ecnt0[r1] == expect)

        do_add = is_adde & both & cas_ok & ~cur
        do_rem = is_reme & both & cas_ok & cur
        # masked bit set/clear on the owner's word (clean lanes own
        # pairwise-distinct rows, so the word RMWs are conflict-free)
        el = jnp.where((do_add | do_rem) & mine1, l1, per)
        wc, mb = bit_word(r2), bit_mask(r2)
        curw = adj_l[jnp.clip(el, 0, per - 1), wc]
        neww = jnp.where(do_add, curw | mb, curw & ~mb)
        adj_l = adj_l.at[el, wc].set(neww, mode="drop")
        ecnt = ecnt.at[jnp.where(do_add | do_rem, r1, v)].add(1, mode="drop")

        res = jnp.where(
            is_adde,
            jnp.where(both, jnp.where(cas_ok, jnp.where(cur, R_EDGE_PRESENT, R_EDGE_ADDED), R_CAS_FAIL), R_VERTEX_NOT_PRESENT),
            res,
        )
        res = jnp.where(
            is_reme,
            jnp.where(both, jnp.where(cas_ok, jnp.where(cur, R_EDGE_REMOVED, R_EDGE_NOT_PRESENT), R_CAS_FAIL), R_VERTEX_NOT_PRESENT),
            res,
        )
        res = jnp.where(
            is_cone,
            jnp.where(both, jnp.where(cur, R_EDGE_PRESENT, R_EDGE_NOT_PRESENT), R_VERTEX_NOT_PRESENT),
            res,
        )

        # ------------------------------------------------------------------
        # Serial correction pass (mirror of ops._apply_one, lane order).
        # Runs every lane unconditionally (uniform collectives across
        # shards); non-serial lanes are masked out of all writes.
        # ------------------------------------------------------------------
        def body(i, carry):
            vkey, valive, vver, ecnt, adj_l, res = carry
            m = serialv[i]
            op, a, bk, exp = opc[i], k1[i], k2[i], expect[i]
            sa = _find_one(vkey, valive, a)
            sb = _find_one(vkey, valive, bk)

            # AddVertex
            free = vkey == EMPTY_KEY
            have = jnp.any(free)
            new = jnp.argmax(free).astype(jnp.int32)
            exists = sa >= 0
            do_av = m & (op == OP_ADD_V) & ~exists & have
            tgt = jnp.where(do_av, new, v)
            vkey = vkey.at[tgt].set(a, mode="drop")
            valive = valive.at[tgt].set(True, mode="drop")
            vver = vver.at[tgt].add(1, mode="drop")
            ecnt = ecnt.at[tgt].set(0, mode="drop")
            ltgt = tgt - row0
            ltgt = jnp.where((ltgt >= 0) & (ltgt < per), ltgt, per)
            adj_l = adj_l.at[ltgt, :].set(jnp.uint32(0), mode="drop")
            # column-bit scrub, guarded by the scalar do_av
            tsafe = jnp.minimum(tgt, v - 1)
            colw = adj_l[:, bit_word(tsafe)]
            adj_l = adj_l.at[:, bit_word(tsafe)].set(
                jnp.where(do_av, colw & ~bit_mask(tsafe), colw))
            r_addv = jnp.where(exists, R_FALSE, jnp.where(have, R_TRUE, R_TABLE_FULL))

            # RemoveVertex (in-edge-source bumps read the pre-lane liveness)
            valive_in = valive
            do_rv = m & (op == OP_REM_V) & (sa >= 0)
            t = jnp.where(do_rv, sa, v)
            valive = valive.at[t].set(False, mode="drop")
            vver = vver.at[t].add(1, mode="drop")
            ecnt = ecnt.at[t].add(1, mode="drop")
            col = jnp.maximum(sa, 0)
            valive_l = jax.lax.dynamic_slice(valive_in, (row0,), (per,))
            bump_l = do_rv & ((adj_l[:, bit_word(col)] & bit_mask(col)) > 0) \
                & valive_l
            bump = jax.lax.all_gather(bump_l, AXIS, tiled=True)
            ecnt = ecnt + bump.astype(jnp.int32)
            r_remv = jnp.where(sa >= 0, R_TRUE, R_FALSE)

            # ContainsVertex
            r_conv = jnp.where(sa >= 0, R_TRUE, R_FALSE)

            # Edge ops
            eboth = (sa >= 0) & (sb >= 0)
            ra, rb = jnp.maximum(sa, 0), jnp.maximum(sb, 0)
            la = ra - row0
            amine = (la >= 0) & (la < per)
            cur = jax.lax.pmax(
                jnp.where(amine,
                          ((adj_l[jnp.clip(la, 0, per - 1), bit_word(rb)]
                            & bit_mask(rb)) > 0).astype(jnp.int32), 0),
                AXIS) > 0
            ecas = (exp < 0) | (ecnt[ra] == exp)
            do_ea = m & (op == OP_ADD_E) & eboth & ecas & ~cur
            do_er = m & (op == OP_REM_E) & eboth & ecas & cur
            ela = jnp.where((do_ea | do_er) & amine, la, per)
            ecurw = adj_l[jnp.clip(ela, 0, per - 1), bit_word(rb)]
            enew = jnp.where(do_ea, ecurw | bit_mask(rb), ecurw & ~bit_mask(rb))
            adj_l = adj_l.at[ela, bit_word(rb)].set(enew, mode="drop")
            ecnt = ecnt.at[jnp.where(do_ea | do_er, ra, v)].add(1, mode="drop")
            r_adde = jnp.where(eboth, jnp.where(ecas, jnp.where(cur, R_EDGE_PRESENT, R_EDGE_ADDED), R_CAS_FAIL), R_VERTEX_NOT_PRESENT)
            r_reme = jnp.where(eboth, jnp.where(ecas, jnp.where(cur, R_EDGE_REMOVED, R_EDGE_NOT_PRESENT), R_CAS_FAIL), R_VERTEX_NOT_PRESENT)
            r_cone = jnp.where(eboth, jnp.where(cur, R_EDGE_PRESENT, R_EDGE_NOT_PRESENT), R_VERTEX_NOT_PRESENT)

            r = jax.lax.switch(
                jnp.clip(op, 0, 6),
                [lambda: jnp.int32(R_FALSE),
                 lambda: r_addv.astype(jnp.int32),
                 lambda: r_remv.astype(jnp.int32),
                 lambda: r_conv.astype(jnp.int32),
                 lambda: r_adde.astype(jnp.int32),
                 lambda: r_reme.astype(jnp.int32),
                 lambda: r_cone.astype(jnp.int32)],
            )
            res = res.at[i].set(jnp.where(m, r, res[i]))
            return vkey, valive, vver, ecnt, adj_l, res

        vkey, valive, vver, ecnt, adj_l, res = jax.lax.fori_loop(
            0, b, body, (vkey, valive, vver, ecnt, adj_l, res))
        return vkey, valive, vver, ecnt, adj_l, res

    vkey, valive, vver, ecnt, adj, res = run(
        state.vkey, state.valive, state.vver, state.ecnt, state.adj_packed,
        ops.opcode, ops.key1, ops.key2, ops.expect,
        clean, serial, wants, slot,
    )
    return ShardedGraphState(mesh, vkey, valive, vver, ecnt, adj), res


# ----------------------------------------------------------------------------
# Distributed fused multi-source BFS
# ----------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("backend",))
def multi_bfs(state: ShardedGraphState, src_slots, dst_slots,
              backend: str = "jnp") -> MultiBFSResult:
    """Fused BFS from Q sources over the row-sharded adjacency.

    Each superstep: every shard expands the slice of all Q frontiers it owns
    with ONE local [Q, V/S] @ [V/S, V] product (``backend="pallas"`` runs
    the bfs_multi_step kernel on the row slice), then the partial next
    frontiers are OR-combined with a single psum and parents min-combined
    with a pmin — the row-partitioned frontier exchange of DESIGN.md §8.
    Per-query early exit is the dense engine's: finished queries expose an
    all-empty frontier on every shard. Results are bit-identical to
    ``core.bfs.multi_bfs`` on the gathered state.
    """
    mesh = state.mesh
    v = state.capacity
    size = int(mesh.shape[AXIS])
    src_slots = jnp.asarray(src_slots, jnp.int32)
    dst_slots = jnp.asarray(dst_slots, jnp.int32)
    q = src_slots.shape[0]

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(), P(AXIS, None), P(), P()),
        out_specs=(P(), P(), P(), P(), P(), P()),
        # Outputs are value-replicated (combined via psum/pmin every
        # superstep), which the 0.4.x checker cannot infer past while_loop.
        **_SM_NOCHECK,
    )
    def run(alive, adjw_l, srcs, dsts):
        _, _, per, row0 = _row_block_info(v, size)
        packed = backend in PACKED_BACKENDS
        alive_l = jax.lax.dynamic_slice(alive, (row0,), (per,))
        # the jnp-level edge views derive from the ONE traversable
        # predicate (row-slice form, DESIGN.md §10) — the Pallas branches
        # stream raw tiles and apply the same mask in their epilogue, per
        # the kernel contract. Loop-invariant, so hoisted out of the body.
        t_l = tw_l = None
        if backend == "packed":
            tw_l = ggraph.traversable_packed(adjw_l, alive_l,
                                             pack_bits(alive))
            # parent candidates still need per-bit rows, unpacked ONCE
            t_l = unpack_bits(tw_l, v)
        elif backend == "jnp":
            t_l = ggraph.traversable(unpack_bits(adjw_l, v), alive_l, alive)
        elif backend == "pallas":
            adj_l = unpack_bits(adjw_l, v).astype(jnp.uint8)
        src_ok = (srcs >= 0) & alive[jnp.maximum(srcs, 0)]
        s = jnp.maximum(srcs, 0)
        frontier0 = jnp.zeros((q, v), jnp.bool_).at[jnp.arange(q), s].set(src_ok)
        visited0 = frontier0
        parent0 = jnp.full((q, v), -1, jnp.int32)
        dist0 = jnp.where(frontier0, 0, -1).astype(jnp.int32)
        expanded0 = jnp.zeros((q, v), jnp.bool_)
        steps0 = jnp.zeros((q,), jnp.int32)
        frontier0, visited0, parent0, dist0, expanded0, steps0 = jax.tree.map(
            _pvary, (frontier0, visited0, parent0, dist0, expanded0, steps0))

        def _active(frontiers, visited, step):
            hit = (dsts >= 0) & visited[jnp.arange(q), jnp.maximum(dsts, 0)]
            return jnp.any(frontiers, axis=1) & ~hit & (step < v)

        def cond(c):
            frontiers, visited, parent, dist, expanded, steps, step = c
            return jnp.any(_active(frontiers, visited, step))

        def body(c):
            frontiers, visited, parent, dist, expanded, steps, step = c
            act = _active(frontiers, visited, step)
            f = frontiers & act[:, None]
            expanded = expanded | f
            f_l = jax.lax.dynamic_slice(f, (0, row0), (q, per))
            if backend == "pallas":
                from repro.kernels.bfs_multi_step.ops import multi_bfs_step

                new_p, par_p = multi_bfs_step(f_l, adj_l, alive, visited)
                reach_part = new_p  # already masked by alive & ~visited
                cand = jnp.where(par_p >= 0, par_p + row0, INT32_MAX)
            elif backend == "packed_pallas":
                from repro.kernels.bfs_multi_step.ops import multi_bfs_step_packed

                new_p, par_p = multi_bfs_step_packed(f_l, adjw_l, alive,
                                                     visited)
                reach_part = new_p  # already masked by alive & ~visited
                cand = jnp.where(par_p >= 0, par_p + row0, INT32_MAX)
            elif backend == "packed":
                sel = jnp.where(f_l[:, :, None], tw_l[None, :, :],
                                jnp.uint32(0))
                reach_part = unpack_bits(or_reduce(sel, 1), v)
                idx = (jnp.arange(per, dtype=jnp.int32) + row0)[:, None, None]
                cand3 = jnp.where(f_l.T[:, :, None] & t_l[:, None, :],
                                  idx, INT32_MAX)
                cand = jnp.min(cand3, axis=0)
            else:
                fa = f_l.astype(jnp.float32)
                reach_part = (fa @ t_l.astype(jnp.float32)) > 0
                idx = (jnp.arange(per, dtype=jnp.int32) + row0)[:, None, None]
                cand3 = jnp.where(f_l.T[:, :, None] & t_l[:, None, :],
                                  idx, INT32_MAX)
                cand = jnp.min(cand3, axis=0)
            if packed:
                # the DESIGN.md §10 frontier exchange: the partial next
                # frontiers cross the wire as packed uint32 bitsets
                # ([Q, V/32] words, 32x less than the int32 psum), OR-folded
                # after ONE all_gather
                parts = jax.lax.all_gather(pack_bits(reach_part), AXIS)
                reach = unpack_bits(or_reduce(parts, 0), v)
            else:
                reach = jax.lax.psum(reach_part.astype(jnp.int32), AXIS) > 0
            par_min = jax.lax.pmin(cand, AXIS)
            new = reach & alive[None, :] & ~visited
            parent = jnp.where(new, par_min, parent)
            dist = jnp.where(new, step + 1, dist)
            visited = visited | new
            steps = steps + act.astype(jnp.int32)
            return new, visited, parent, dist, expanded, steps, step + 1

        frontiers, visited, parent, dist, expanded, steps, supersteps = (
            jax.lax.while_loop(
                cond, body,
                (frontier0, visited0, parent0, dist0, expanded0, steps0,
                 jnp.int32(0))))
        found = ((dsts >= 0)
                 & visited[jnp.arange(q), jnp.maximum(dsts, 0)] & src_ok)
        return found, parent, dist, expanded, steps, supersteps

    found, parent, dist, expanded, steps, supersteps = run(
        state.valive, state.adj_packed, src_slots, dst_slots)
    return MultiBFSResult(found, parent, dist, expanded, steps, supersteps)


__all__ = [
    "ShardedGraphState",
    "apply_ops_fast",
    "compact",
    "grow",
    "make_graph_mesh",
    "multi_bfs",
    "shard_state",
    "unshard",
]
