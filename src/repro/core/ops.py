"""Batched, linearizable graph mutations — the lock-free update engine.

Concurrency model (DESIGN.md §3): a batch of B ops from B logical actors is
applied in one device step. Lane order is the linearization order. Two engines:

``apply_ops``       exact reference engine: a ``lax.fori_loop`` over lanes where
                    each lane's op is itself fully vectorized. This is the
                    executable *sequential specification* of the batch
                    semantics (paper §2.2) and the ground truth for tests.

``apply_ops_fast``  disjoint-access-parallel engine: lanes whose referenced
                    keys collide with no other lane are applied in ONE
                    vectorized step (they commute with every other lane, so
                    any interleaving is linearizable); colliding lanes are
                    then applied in lane order by a masked correction loop.
                    This mirrors the paper's performance model exactly —
                    lock-free threads only serialize on CAS contention, i.e.
                    on same-location conflicts — and is where the 5-7x-style
                    scaling over a serialized engine comes from (Fig. 9/10
                    analogues in benchmarks/).

Strong equivalence contract: ``apply_ops_fast`` is BIT-identical to
``apply_ops`` — same result codes AND the same concrete arrays (slot
placement, ecnt, vver), not merely the same abstract graph. Three mechanisms
buy this (tests/test_linearizability_prop.py is the enforcing suite, and the
sharded engine in core/partition.py inherits the contract by mirroring the
same decisions, DESIGN.md §8):

  * ``_alloc_schedule`` precomputes, for every AddVertex lane, whether it
    allocates under lane-order serial execution (per-key liveness is decided
    by the LAST prior AddVertex/RemoveVertex lane on the same key — an
    AddVertex always leaves the key alive, a RemoveVertex always dead) and
    which free slot it takes (allocating lanes consume free slots in
    increasing slot order, exactly what repeated argmax-free does). Clean
    lanes allocate at their scheduled slot, leaving holes that the serial
    correction pass's argmax-free naturally lands in.
  * RemoveVertex lanes are always routed to the serial pass: their in-edge
    source ``ecnt`` bumps read the whole adjacency, so they depend on lanes
    they share no key with. Symmetrically, CAS edge lanes (expect >= 0) go
    serial whenever the batch contains any RemoveVertex — the in-edge bump
    is the one cross-key ``ecnt`` write a CAS read could miss.
  * If the scheduled allocations would exhaust free slots (R_TABLE_FULL
    territory), the whole batch falls back to the serial reference engine —
    capacity exhaustion couples every AddVertex lane, and the host is about
    to ``grow()`` anyway.

CAS semantics: ``OpBatch.expect >= 0`` makes an edge op conditional on the
source vertex's ``ecnt`` equalling ``expect`` (else R_CAS_FAIL) — the direct
analogue of the paper's CAS-with-retry protocol, surfaced to clients.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.graph import (
    EMPTY_KEY,
    OP_ADD_E,
    OP_ADD_V,
    OP_CON_E,
    OP_CON_V,
    OP_NOP,
    OP_REM_E,
    OP_REM_V,
    R_CAS_FAIL,
    R_EDGE_ADDED,
    R_EDGE_NOT_PRESENT,
    R_EDGE_PRESENT,
    R_EDGE_REMOVED,
    R_FALSE,
    R_TABLE_FULL,
    R_TRUE,
    R_VERTEX_NOT_PRESENT,
    GraphState,
    OpBatch,
    bit_mask,
    bit_word,
    find_slot,
    get_bit,
    pack_bits,
    popcount,
    traversable,
    traversable_packed,
    unpack_bits,
)


# ----------------------------------------------------------------------------
# Packed-word adjacency primitives (DESIGN.md §10): every edge mutation is a
# masked bit set/clear on one uint32 word instead of a dense row/cell write.
# ----------------------------------------------------------------------------
def _clear_row_col(adj_packed, slot, do):
    """Clear adjacency row ``slot`` and column bit ``slot`` in every row
    (the stale-adjacency scrub a slot reuse needs), when ``do``.

    The scrubbed bit set {(slot, *)} ∪ {(*, slot)} is its own transpose, so
    the SAME helper scrubs the in-adjacency (DESIGN.md §11) — every caller
    applies it to both packed matrices."""
    w, m = bit_word(slot), bit_mask(slot)
    cleared = adj_packed.at[slot, :].set(jnp.uint32(0))
    cleared = cleared.at[:, w].set(cleared[:, w] & ~m)
    return jnp.where(do, cleared, adj_packed)


def _set_edge_bit(adj_packed, row, col, present, do):
    """Masked single-bit write: bit (row, col) := present when ``do``."""
    w, m = bit_word(col), bit_mask(col)
    cur = adj_packed[row, w]
    new = jnp.where(do, jnp.where(present, cur | m, cur & ~m), cur)
    return adj_packed.at[row, w].set(new)


# ----------------------------------------------------------------------------
# Single-op primitives (each fully vectorized over the slot table)
# ----------------------------------------------------------------------------
def _free_slot(state: GraphState) -> jax.Array:
    """First truly-free slot (never-used or physically removed). -1 if full."""
    free = state.vkey == EMPTY_KEY
    idx = jnp.argmax(free)
    return jnp.where(jnp.any(free), idx.astype(jnp.int32), jnp.int32(-1))


def _add_vertex(state: GraphState, k: jax.Array):
    slot = find_slot(state, k)
    exists = slot >= 0
    new = _free_slot(state)
    full = (~exists) & (new < 0)
    do = (~exists) & (new >= 0)
    tgt = jnp.maximum(new, 0)
    vkey = state.vkey.at[tgt].set(jnp.where(do, k, state.vkey[tgt]))
    valive = state.valive.at[tgt].set(jnp.where(do, True, state.valive[tgt]))
    vver = state.vver.at[tgt].add(jnp.where(do, 1, 0))
    # A reused slot may carry stale adjacency from a dead predecessor: clear
    # (the scrub set is transpose-symmetric, so the in-adjacency takes the
    # identical clear — DESIGN.md §11).
    adj = _clear_row_col(state.adj_packed, tgt, do)
    adj_in = _clear_row_col(state.adj_in_packed, tgt, do)
    ecnt = state.ecnt.at[tgt].set(jnp.where(do, 0, state.ecnt[tgt]))
    res = jnp.where(exists, R_FALSE, jnp.where(full, R_TABLE_FULL, R_TRUE))
    return GraphState(vkey, valive, vver, ecnt, adj, adj_in), res.astype(jnp.int32)


def _remove_vertex(state: GraphState, k: jax.Array):
    slot = find_slot(state, k)
    do = slot >= 0
    tgt = jnp.maximum(slot, 0)
    # Logical removal (paper line 21): mark the vertex; leave edges lazily.
    valive = state.valive.at[tgt].set(jnp.where(do, False, state.valive[tgt]))
    vver = state.vver.at[tgt].add(jnp.where(do, 1, 0))
    ecnt = state.ecnt.at[tgt].add(jnp.where(do, 1, 0))
    # Incoming edges must invalidate their sources' collects: removing v
    # changes reachability through every u with (u -> v), and the paper's
    # adversary argument needs those rows' versions to move. Bump ecnt of all
    # sources of live in-edges — ONE maintained in-adjacency row instead of
    # a strided column gather (DESIGN.md §11).
    in_src = unpack_bits(state.adj_in_packed[tgt], state.capacity) \
        & state.valive & do
    ecnt = ecnt + in_src.astype(jnp.int32)
    res = jnp.where(do, R_TRUE, R_FALSE)
    return GraphState(state.vkey, valive, vver, ecnt, state.adj_packed,
                      state.adj_in_packed), res.astype(jnp.int32)


def _edge_op(state: GraphState, k, l, expect, *, add: bool):
    sk = find_slot(state, k)
    sl = find_slot(state, l)
    both = (sk >= 0) & (sl >= 0)
    rk, rl = jnp.maximum(sk, 0), jnp.maximum(sl, 0)
    cas_ok = (expect < 0) | (state.ecnt[rk] == expect)
    present = get_bit(state.adj_packed, rk, rl)
    if add:
        do = both & cas_ok & ~present
        ok_res = jnp.where(present, R_EDGE_PRESENT, R_EDGE_ADDED)
    else:
        do = both & cas_ok & present
        ok_res = jnp.where(present, R_EDGE_REMOVED, R_EDGE_NOT_PRESENT)
    adj = _set_edge_bit(state.adj_packed, rk, rl, jnp.asarray(add), do)
    # mirrored single-bit RMW on the in-adjacency (DESIGN.md §11)
    adj_in = _set_edge_bit(state.adj_in_packed, rl, rk, jnp.asarray(add), do)
    ecnt = state.ecnt.at[rk].add(jnp.where(do, 1, 0))  # the paper's FAA
    res = jnp.where(
        both,
        jnp.where(cas_ok, ok_res, R_CAS_FAIL),
        R_VERTEX_NOT_PRESENT,
    )
    return GraphState(state.vkey, state.valive, state.vver, ecnt, adj,
                      adj_in), res.astype(jnp.int32)


def _contains_edge_op(state: GraphState, k, l):
    sk = find_slot(state, k)
    sl = find_slot(state, l)
    both = (sk >= 0) & (sl >= 0)
    present = get_bit(state.adj_packed, jnp.maximum(sk, 0), jnp.maximum(sl, 0))
    res = jnp.where(
        both,
        jnp.where(present, R_EDGE_PRESENT, R_EDGE_NOT_PRESENT),
        R_VERTEX_NOT_PRESENT,
    )
    return state, res.astype(jnp.int32)


def _apply_one(state: GraphState, opcode, k1, k2, expect):
    """Apply a single op; returns (state', result). Branch-free lax.switch."""

    def do_nop(s):
        return s, jnp.int32(R_FALSE)

    def do_addv(s):
        return _add_vertex(s, k1)

    def do_remv(s):
        return _remove_vertex(s, k1)

    def do_conv(s):
        return s, jnp.where(find_slot(s, k1) >= 0, R_TRUE, R_FALSE).astype(jnp.int32)

    def do_adde(s):
        return _edge_op(s, k1, k2, expect, add=True)

    def do_reme(s):
        return _edge_op(s, k1, k2, expect, add=False)

    def do_cone(s):
        return _contains_edge_op(s, k1, k2)

    return jax.lax.switch(
        jnp.clip(opcode, 0, 6),
        [do_nop, do_addv, do_remv, do_conv, do_adde, do_reme, do_cone],
        state,
    )


# ----------------------------------------------------------------------------
# Reference engine: exact lane-order linearization
# ----------------------------------------------------------------------------
def _serial_masked(state: GraphState, ops: OpBatch, mask: jax.Array,
                   res0: jax.Array):
    """Apply the ``mask``-selected lanes in lane order via ``_apply_one``.

    Unselected lanes keep their ``res0`` entry. This is both the reference
    engine (mask = all lanes) and the fast engine's correction pass
    (mask = conflicting lanes).
    """

    def body(i, carry):
        st, res = carry

        def run(st):
            st2, r = _apply_one(st, ops.opcode[i], ops.key1[i], ops.key2[i], ops.expect[i])
            return st2, res.at[i].set(r)

        return jax.lax.cond(mask[i], run, lambda st: (st, res), st)

    return jax.lax.fori_loop(0, ops.lanes, body, (state, res0))


@jax.jit
def apply_ops(state: GraphState, ops: OpBatch):
    """Apply a batch with exact lane-order linearization (reference engine)."""
    res0 = jnp.full((ops.lanes,), R_FALSE, jnp.int32)
    return _serial_masked(state, ops, jnp.ones((ops.lanes,), jnp.bool_), res0)


# ----------------------------------------------------------------------------
# Fast engine: disjoint-access parallelism
# ----------------------------------------------------------------------------
def _lane_conflicts(ops: OpBatch) -> jax.Array:
    """True for lanes that must take the serial correction pass.

    Key collisions are detected sort-based O(B log B): flatten the (up to)
    two keys per lane, sort, mark duplicates, scatter the mark back to
    lanes. Read-only lanes (contains) still count as conflicting when they
    share a key with a writer — conservative and simple (reads that conflict
    only with reads are still routed to the serial pass; rare in
    benchmarks). On top of key collisions, two lane classes are serial
    unconditionally (the bit-identity contract, module docstring):

      * RemoveVertex — its in-edge-source ecnt bumps depend on adjacency
        and liveness of vertices it shares no key with;
      * CAS edge lanes (expect >= 0) whenever the batch contains any
        RemoveVertex — the CAS reads its source row's ecnt, which an
        earlier RemoveVertex lane may bump through an in-edge without
        sharing a key (the only cross-key ecnt writer);
      * any lane naming a negative key — negative keys alias EMPTY_KEY
        slot-table sentinels, so only the exact reference semantics of
        ``_apply_one`` are trusted with them.
    """
    b = ops.lanes
    is_edge = (ops.opcode == OP_ADD_E) | (ops.opcode == OP_REM_E) | (ops.opcode == OP_CON_E)
    is_vert = (ops.opcode == OP_ADD_V) | (ops.opcode == OP_REM_V) | (ops.opcode == OP_CON_V)
    k1 = jnp.where(is_edge | is_vert, ops.key1, -1)
    k2 = jnp.where(is_edge, ops.key2, -1)
    keys = jnp.concatenate([k1, k2])  # [2B]
    lane = jnp.concatenate([jnp.arange(b), jnp.arange(b)])
    order = jnp.argsort(keys)
    sk, sl = keys[order], lane[order]
    same_prev = jnp.concatenate([jnp.array([False]), (sk[1:] == sk[:-1]) & (sk[1:] >= 0)])
    same_next = jnp.concatenate([(sk[:-1] == sk[1:]) & (sk[:-1] >= 0), jnp.array([False])])
    dup = same_prev | same_next
    conflict = jnp.zeros((b,), jnp.bool_)
    conflict = conflict.at[sl].max(dup)
    conflict = conflict | (ops.opcode == OP_REM_V)
    has_remv = jnp.any(ops.opcode == OP_REM_V)
    is_cas_edge = ((ops.opcode == OP_ADD_E) | (ops.opcode == OP_REM_E)) & (ops.expect >= 0)
    conflict = conflict | (is_cas_edge & has_remv)
    conflict = conflict | (is_vert & (ops.key1 < 0))
    conflict = conflict | (is_edge & ((ops.key1 < 0) | (ops.key2 < 0)))
    return conflict


def _alive_now(state: GraphState, keys: jax.Array) -> jax.Array:
    """Alive-slot existence per key [B], WITHOUT the key >= 0 guard (a
    degenerate negative key can name a live slot; `_find_slots_masked`
    deliberately hides those from scatter targets)."""
    hit = (state.vkey[None, :] == keys[:, None]) & state.valive[None, :]
    return jnp.any(hit, axis=1)


def _alloc_schedule(state: GraphState, ops: OpBatch):
    """Lane-order-faithful AddVertex allocation schedule (module docstring).

    Returns (wants bool[B], slot int32[B], overflow bool):
      wants[i]  — lane i is an AddVertex that allocates under lane-order
                  serial execution (key not alive at its turn);
      slot[i]   — the free slot it takes (capacity-parked when ~wants);
      overflow  — the schedule needs more slots than are free, so the caller
                  must fall back to the serial reference engine (capacity
                  exhaustion couples lanes across keys).
    """
    b = ops.lanes
    is_addv = ops.opcode == OP_ADD_V
    is_vmut = is_addv | (ops.opcode == OP_REM_V)
    alive0 = _alive_now(state, ops.key1)
    lane = jnp.arange(b, dtype=jnp.int32)
    prior = (
        (ops.key1[:, None] == ops.key1[None, :])
        & is_vmut[None, :]
        & (lane[None, :] < lane[:, None])
    )
    has_prior = jnp.any(prior, axis=1)
    last_j = jnp.argmax(jnp.where(prior, lane[None, :], -1), axis=1)
    # liveness after the last prior vertex-mutating lane on the same key:
    # AddVertex always leaves the key alive, RemoveVertex always dead —
    # regardless of whether that op itself reported success.
    alive_at_turn = jnp.where(has_prior, is_addv[last_j], alive0)
    wants = is_addv & ~alive_at_turn
    rank = jnp.cumsum(wants.astype(jnp.int32)) - 1              # 0-based rank
    free = state.vkey == EMPTY_KEY
    free_cum = jnp.cumsum(free.astype(jnp.int32))               # 1-based counts
    n_free = free_cum[-1]
    # slot for rank r = first index where free_cum == r+1 and free; serial
    # argmax-free consumes free slots in exactly this increasing order.
    slot = jnp.searchsorted(free_cum, rank + 1, side="left").astype(jnp.int32)
    slot = jnp.where(wants, slot, state.capacity)               # park inactive
    overflow = jnp.sum(wants.astype(jnp.int32)) > n_free
    return wants, slot, overflow


def _apply_clean_vectorized(state: GraphState, ops: OpBatch, active: jax.Array,
                            wants: jax.Array, slot: jax.Array):
    """One vectorized pass applying all ``active`` lanes.

    Preconditions: active lanes reference pairwise-disjoint key sets (so all
    scatters below are conflict-free and the pass equals any interleaving),
    RemoveVertex lanes are never active (always serial), and AddVertex
    allocation follows the precomputed non-overflowing ``_alloc_schedule``
    (so placement is bit-identical to the lane-order serial engine).
    """
    b = ops.lanes
    cap = state.capacity
    s1 = _find_slots_masked(state, ops.key1)
    s2 = _find_slots_masked(state, ops.key2)

    is_addv = active & (ops.opcode == OP_ADD_V)
    is_conv = active & (ops.opcode == OP_CON_V)
    is_adde = active & (ops.opcode == OP_ADD_E)
    is_reme = active & (ops.opcode == OP_REM_E)
    is_cone = active & (ops.opcode == OP_CON_E)

    res = jnp.full((b,), R_FALSE, jnp.int32)

    # --- AddVertex: scheduled free-slot allocation ---------------------------
    # A clean AddVertex has no other lane on its key, so the schedule's
    # alive-at-turn is simply alive-now and ``wants`` == "will allocate"
    # (the overflow fallback guarantees a slot exists).
    alloc = jnp.where(is_addv & wants, slot, cap)               # park inactive
    vkey = state.vkey.at[alloc].set(ops.key1, mode="drop")
    valive = state.valive.at[alloc].set(True, mode="drop")
    vver = state.vver.at[alloc].add(1, mode="drop")
    ecnt = state.ecnt.at[alloc].set(0, mode="drop")
    # stale-adjacency scrub on reused slots: rows by scatter, columns by ONE
    # packed AND-NOT mask (several lanes may land in the same word). The
    # scrub set is transpose-symmetric, so the in-adjacency takes the
    # identical row scatter + column mask (DESIGN.md §11).
    adj = state.adj_packed.at[alloc, :].set(jnp.uint32(0), mode="drop")
    adj_in = state.adj_in_packed.at[alloc, :].set(jnp.uint32(0), mode="drop")
    clear_cols = jnp.zeros((cap,), jnp.bool_).at[alloc].set(True, mode="drop")
    clear_mask = ~pack_bits(clear_cols)[None, :]
    adj = adj & clear_mask
    adj_in = adj_in & clear_mask
    res = jnp.where(is_addv, jnp.where(wants, R_TRUE, R_FALSE), res)

    # --- ContainsVertex -------------------------------------------------------
    res = jnp.where(is_conv, jnp.where(s1 >= 0, R_TRUE, R_FALSE), res)

    # --- Edge ops -------------------------------------------------------------
    both = (s1 >= 0) & (s2 >= 0)
    r1, r2 = jnp.maximum(s1, 0), jnp.maximum(s2, 0)
    cur = get_bit(state.adj_packed, r1, r2)
    cas_ok = (ops.expect < 0) | (state.ecnt[r1] == ops.expect)

    do_add = is_adde & both & cas_ok & ~cur
    do_rem = is_reme & both & cas_ok & cur
    # masked bit set/clear: clean lanes own pairwise-distinct source rows, so
    # the word read-modify-writes below are scatter-conflict-free (the word is
    # re-read AFTER the AddVertex scrub so unrelated bits survive)
    fire = do_add | do_rem
    tgt_r = jnp.where(fire, r1, cap)
    wcol, mbit = bit_word(r2), bit_mask(r2)
    curw = adj[jnp.minimum(tgt_r, cap - 1), wcol]
    neww = jnp.where(do_add, curw | mbit, curw & ~mbit)
    adj = adj.at[tgt_r, wcol].set(neww, mode="drop")
    # mirrored in-adjacency RMW: firing clean lanes own pairwise-distinct
    # DESTINATION rows too (disjoint key sets), so the in-row word
    # read-modify-writes are just as conflict-free (DESIGN.md §11)
    tgt_ri = jnp.where(fire, r2, cap)
    wcol_i, mbit_i = bit_word(r1), bit_mask(r1)
    curw_i = adj_in[jnp.minimum(tgt_ri, cap - 1), wcol_i]
    neww_i = jnp.where(do_add, curw_i | mbit_i, curw_i & ~mbit_i)
    adj_in = adj_in.at[tgt_ri, wcol_i].set(neww_i, mode="drop")
    ecnt = ecnt.at[tgt_r].add(1, mode="drop")

    res = jnp.where(
        is_adde,
        jnp.where(both, jnp.where(cas_ok, jnp.where(cur, R_EDGE_PRESENT, R_EDGE_ADDED), R_CAS_FAIL), R_VERTEX_NOT_PRESENT),
        res,
    )
    res = jnp.where(
        is_reme,
        jnp.where(both, jnp.where(cas_ok, jnp.where(cur, R_EDGE_REMOVED, R_EDGE_NOT_PRESENT), R_CAS_FAIL), R_VERTEX_NOT_PRESENT),
        res,
    )
    res = jnp.where(
        is_cone,
        jnp.where(both, jnp.where(cur, R_EDGE_PRESENT, R_EDGE_NOT_PRESENT), R_VERTEX_NOT_PRESENT),
        res,
    )
    return GraphState(vkey, valive, vver, ecnt, adj, adj_in), res


def _find_slots_masked(state: GraphState, keys: jax.Array) -> jax.Array:
    hit = (state.vkey[None, :] == keys[:, None]) & state.valive[None, :] & (keys[:, None] >= 0)
    idx = jnp.argmax(hit, axis=1)
    return jnp.where(jnp.any(hit, axis=1), idx.astype(jnp.int32), jnp.int32(-1))


@jax.jit
def apply_ops_fast(state: GraphState, ops: OpBatch):
    """Disjoint-access-parallel batch application (linearizable; see module doc).

    Linearization order: all conflict-free lanes (which commute with every
    lane) at the batch start in lane order, then conflicting lanes in lane
    order via the masked correction loop. Bit-identical to ``apply_ops``
    (module docstring; tests/test_linearizability_prop.py).
    """
    conflict = _lane_conflicts(ops)
    clean = ~conflict & (ops.opcode != OP_NOP)
    wants, slot, overflow = _alloc_schedule(state, ops)
    res0 = jnp.full((ops.lanes,), R_FALSE, jnp.int32)

    def fallback(st):
        # Allocation would exhaust the slot table: capacity failures couple
        # lanes across keys, so only full serial replay is bit-exact.
        return _serial_masked(st, ops, jnp.ones((ops.lanes,), jnp.bool_), res0)

    def fast(st):
        st, res = _apply_clean_vectorized(st, ops, clean, wants, slot)
        return jax.lax.cond(
            jnp.any(conflict),
            lambda a: _serial_masked(a[0], ops, conflict, a[1]),
            lambda a: a,
            (st, res),
        )

    return jax.lax.cond(overflow, fallback, fast, state)


# ----------------------------------------------------------------------------
# Undirected extension (paper footnote a: "directly extended")
# ----------------------------------------------------------------------------
def _edge_op_undirected(state: GraphState, k, l, expect, *, add: bool):
    """Both directions mutate atomically at one linearization point; both
    endpoint rows take the FAA (so double collects through either endpoint
    observe the mutation)."""
    sk = find_slot(state, k)
    sl = find_slot(state, l)
    both = (sk >= 0) & (sl >= 0)
    rk, rl = jnp.maximum(sk, 0), jnp.maximum(sl, 0)
    cas_ok = (expect < 0) | (state.ecnt[rk] == expect)
    present = get_bit(state.adj_packed, rk, rl)
    if add:
        do = both & cas_ok & ~present
        ok_res = jnp.where(present, R_EDGE_PRESENT, R_EDGE_ADDED)
    else:
        do = both & cas_ok & present
        ok_res = jnp.where(present, R_EDGE_REMOVED, R_EDGE_NOT_PRESENT)
    adj = _set_edge_bit(state.adj_packed, rk, rl, jnp.asarray(add), do)
    adj = _set_edge_bit(adj, rl, rk, jnp.asarray(add), do)
    # an undirected edge is its own transpose: the in-adjacency takes the
    # same symmetric pair of bit writes (DESIGN.md §11)
    adj_in = _set_edge_bit(state.adj_in_packed, rl, rk, jnp.asarray(add), do)
    adj_in = _set_edge_bit(adj_in, rk, rl, jnp.asarray(add), do)
    ecnt = state.ecnt.at[rk].add(jnp.where(do, 1, 0))
    ecnt = ecnt.at[rl].add(jnp.where(do & (rk != rl), 1, 0))
    res = jnp.where(
        both,
        jnp.where(cas_ok, ok_res, R_CAS_FAIL),
        R_VERTEX_NOT_PRESENT,
    )
    return GraphState(state.vkey, state.valive, state.vver, ecnt, adj,
                      adj_in), res.astype(jnp.int32)


@jax.jit
def add_edge_undirected(state: GraphState, k, l):
    return _edge_op_undirected(state, jnp.asarray(k, jnp.int32),
                               jnp.asarray(l, jnp.int32), jnp.int32(-1), add=True)


@jax.jit
def remove_edge_undirected(state: GraphState, k, l):
    return _edge_op_undirected(state, jnp.asarray(k, jnp.int32),
                               jnp.asarray(l, jnp.int32), jnp.int32(-1), add=False)


# ----------------------------------------------------------------------------
# Wait-free neighborhood queries (the traversal-return the paper's related
# work, Kallimanis & Kanellou 2015, could not provide)
# ----------------------------------------------------------------------------
@jax.jit
def neighbors(state: GraphState, k):
    """Out-neighbor keys of v(k): (count, keys int32[V] padded with -1).

    Single bounded vectorized pass over the slot table — wait-free in the
    same sense as ContainsVertex (paper Thm 4.2(i))."""
    slot = find_slot(state, jnp.asarray(k, jnp.int32))
    ok = slot >= 0
    row = unpack_bits(state.adj_packed[jnp.maximum(slot, 0)], state.capacity)
    live = row & state.valive & ok
    n = jnp.sum(live.astype(jnp.int32))
    order = jnp.argsort(~live)  # live slots first (stable)
    keys = jnp.where(live[order], state.vkey[order], -1)
    return n, keys


@jax.jit
def degree(state: GraphState, k):
    """(out_degree, in_degree) of v(k); (-1, -1) if absent. BOTH degrees are
    one popcount over the slot's traversable row words — out over
    ``adj_packed``, in over the maintained ``adj_in_packed`` row
    (DESIGN.md §10, §11) — no strided column gather."""
    slot = find_slot(state, jnp.asarray(k, jnp.int32))
    ok = slot >= 0
    s = jnp.maximum(slot, 0)
    out_d = jnp.sum(popcount(state.adj_packed[s] & state.alive_words))
    in_d = jnp.where(
        state.valive[s],
        jnp.sum(popcount(state.adj_in_packed[s] & state.alive_words)), 0)
    return (jnp.where(ok, out_d, -1), jnp.where(ok, in_d, -1))


# ----------------------------------------------------------------------------
# Physical removal — the helping / compaction analogue
# ----------------------------------------------------------------------------
@jax.jit
def compact(state: GraphState) -> GraphState:
    """Physically remove logically-deleted vertices (paper: the deferred
    physical unlink any helping thread may perform). Frees slots and clears
    their adjacency rows/columns; versions are retained so outstanding
    double-collects still detect the change (vver moved at logical removal).
    """
    dead = (~state.valive) & (state.vkey != EMPTY_KEY)
    keep = ~dead
    vkey = jnp.where(dead, EMPTY_KEY, state.vkey)
    keep_words = pack_bits(keep)[None, :]
    # the scrub (dead rows zeroed, dead columns masked) is transpose-
    # symmetric: the in-adjacency takes the identical form (DESIGN.md §11)
    adj = jnp.where(keep[:, None],
                    state.adj_packed & keep_words, jnp.uint32(0))
    adj_in = jnp.where(keep[:, None],
                       state.adj_in_packed & keep_words, jnp.uint32(0))
    return GraphState(vkey, state.valive, state.vver, state.ecnt, adj, adj_in)


# ----------------------------------------------------------------------------
# Convenience single-op API (host-facing, used by examples/benchmarks)
# ----------------------------------------------------------------------------
@jax.jit
def add_vertex(state: GraphState, k):
    return _add_vertex(state, jnp.asarray(k, jnp.int32))


@jax.jit
def remove_vertex(state: GraphState, k):
    return _remove_vertex(state, jnp.asarray(k, jnp.int32))


@jax.jit
def add_edge(state: GraphState, k, l):
    return _edge_op(state, jnp.asarray(k, jnp.int32), jnp.asarray(l, jnp.int32), jnp.int32(-1), add=True)


@jax.jit
def remove_edge(state: GraphState, k, l):
    return _edge_op(state, jnp.asarray(k, jnp.int32), jnp.asarray(l, jnp.int32), jnp.int32(-1), add=False)
