"""BFS traversal as tiled mat-vec — the TPU-native replacement for pointer chasing.

The paper's TreeCollect walks edge-lists node by node. On TPU the same
traversal is a sequence of *frontier expansion* steps over adjacency tiles:

    reach[j]  = OR_i  frontier[i] AND adj[i, j]          (MXU tile mat-vec)
    parent[j] = min_i { i : frontier[i] AND adj[i, j] }  (VPU masked min)
    new       = reach AND alive AND NOT visited

One step costs O(V^2 / P) dense work with high arithmetic intensity instead of
O(E) random accesses — the hardware-adaptation core of this reproduction
(DESIGN.md §1). ``step_fn`` is pluggable per backend (DESIGN.md §10, §11):

  "jnp"           float32-MXU reference: unpack the packed words, expand via
                  a frontier mat-vec (always available)
  "pallas"        kernels/bfs_step on the unpacked view (interpret on CPU)
  "packed"        pure-jnp AND/OR reduction over the packed uint32 words —
                  no unpack, no matmul, ~32x less adjacency traffic
  "packed_pallas" kernels/bfs_step packed kernel (words streamed HBM->VMEM)
  "hybrid"        direction-optimizing superstep (DESIGN.md §11): per-step
                  frontier/unvisited popcounts pick the packed top-down
                  "push" expansion or a bottom-up "pull" word reduction
                  over the maintained ``adj_in_packed`` (Beamer-style
                  alpha/beta switch)
  "hybrid_pallas" same switch; push = the packed bfs_step kernel, pull =
                  kernels/bfs_pull_step

All six backends produce bit-identical BFSResults; every edge view is
derived from the ONE ``core.graph.traversable`` predicate. ``backend=None``
anywhere in this module resolves through ``default_backend()`` — the single
place the repo's fastest engine is named (DESIGN.md §11).
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as _trace
from repro.obs.metrics import global_registry as _obs_registry
from repro.core.graph import (
    WORD_BITS,
    GraphState,
    or_reduce,
    pack_bits,
    popcount,
    traversable,
    traversable_packed,
    unpack_bits,
)

INT32_MAX = jnp.int32(2**31 - 1)

# backends whose step functions consume ``state.adj_packed`` directly
PACKED_BACKENDS = ("packed", "packed_pallas")
# direction-optimizing backends: consume adj_packed AND adj_in_packed
HYBRID_BACKENDS = ("hybrid", "hybrid_pallas")

# Beamer-style direction-switch knobs (DESIGN.md §11), static jit args:
# go bottom-up when |frontier| * alpha >= |unvisited|, return top-down once
# |frontier| < V / beta. Vertex-count forms of Beamer's edge-count rules —
# the dense engines' per-step cost is row-count-, not edge-count-, shaped.
# alpha defaults to the packed WORD WIDTH: a pull superstep touches a 32x
# denser encoding per row (words, not parent-candidate lanes), so bottom-up
# pays off once the frontier reaches ~1/32 of the unvisited set — matching
# the measured push/pull crossover recorded in BENCH_fig9_throughput.json.
# On tile-skipping TPU hardware (where push cost really is
# frontier-proportional) serve paths can lower alpha toward Beamer's
# classical ~14; both knobs are static jit args precisely for that.
DEFAULT_ALPHA = WORD_BITS
DEFAULT_BETA = 64


def default_backend() -> str:
    """The fastest BFS backend for this build — the ONE resolution point
    every ``backend=None`` call site threads through (DESIGN.md §11).

    "hybrid" since the direction-optimizing engine landed (previously
    "packed"); override with the ``REPRO_BFS_BACKEND`` environment variable
    (e.g. force "packed_pallas" on a real TPU to keep the superstep in the
    Pallas kernels). tests/test_hybrid.py pins the resolution.
    """
    return os.environ.get("REPRO_BFS_BACKEND", "hybrid")


def _resolve_backend(backend: str | None) -> str:
    return default_backend() if backend is None else backend


def bfs_step_jnp(frontier, adj, alive, visited):
    """Reference frontier expansion. Returns (new_frontier[V] bool, parent[V] int32).

    parent[j] = smallest frontier index i with a traversable edge i->j (-1
    if none). Both the expansion and the parent scan read the SAME
    ``traversable`` mask, so endpoint liveness cannot drift between them.
    """
    t = traversable(adj, alive)
    f = frontier.astype(jnp.float32)
    reach = (f @ t.astype(jnp.float32)) > 0
    new = reach & ~visited
    v = adj.shape[0]
    idx = jnp.arange(v, dtype=jnp.int32)
    # candidate parent rows: masked min over i of (frontier_i & t_ij)
    cand = jnp.where(frontier[:, None] & t, idx[:, None], INT32_MAX)
    parent = jnp.min(cand, axis=0)
    parent = jnp.where(new, parent, jnp.int32(-1))
    return new, parent


def bfs_step_packed_jnp(frontier, adj_packed, alive, visited):
    """Packed frontier expansion (DESIGN.md §10): reach is a bitwise OR of
    the frontier rows' traversable words — no unpack of the streamed
    adjacency, no matmul. Bit-identical to ``bfs_step_jnp``."""
    v = alive.shape[0]
    t = traversable_packed(adj_packed, alive, pack_bits(alive))
    sel = jnp.where(frontier[:, None], t, jnp.uint32(0))
    reach = unpack_bits(or_reduce(sel, 0), v)
    new = reach & ~visited
    idx = jnp.arange(v, dtype=jnp.int32)
    cand = jnp.where(frontier[:, None] & unpack_bits(t, v),
                     idx[:, None], INT32_MAX)
    parent = jnp.min(cand, axis=0)
    parent = jnp.where(new, parent, jnp.int32(-1))
    return new, parent


def ctz32(words: jax.Array) -> jax.Array:
    """Per-word count-trailing-zeros for uint32 (int32 out; 32 for a zero
    word): isolate the lowest set bit with the two's-complement trick, then
    popcount the trailing-zero mask below it."""
    low = words & (jnp.uint32(0) - words)
    return popcount(low - jnp.uint32(1))


def bfs_step_pull_jnp(frontier, adj_in_packed, alive, visited):
    """Bottom-up ("pull") frontier expansion (DESIGN.md §11): every
    not-yet-visited vertex scans ITS OWN in-adjacency row for a frontier
    parent — one [V, W] word AND against the packed frontier bitset instead
    of the push step's frontier-row selection + [V, V] parent-candidate
    matrix. parent[j] = lowest set bit of ``adj_in[j] & frontier`` = the
    smallest frontier index with a traversable edge into j, so the result
    is bit-identical to ``bfs_step_packed_jnp`` (the masked word-min
    realizes first-parent-wins at word granularity).
    """
    w = adj_in_packed.shape[1]
    fw = pack_bits(frontier & alive)            # only live sources expand
    cand = adj_in_packed & fw[None, :]          # [V, W]
    hit = jnp.any(cand != 0, axis=1)
    new = hit & alive & ~visited
    widx = (jnp.arange(w, dtype=jnp.int32) * WORD_BITS)[None, :]
    pcand = jnp.where(cand != 0, widx + ctz32(cand), INT32_MAX)
    parent = jnp.min(pcand, axis=1)
    parent = jnp.where(new, parent, jnp.int32(-1))
    return new, parent


def pick_direction(pulling, nf, nu, v: int, alpha: int, beta: int):
    """The Beamer-style push/pull switch (DESIGN.md §11), on vertex
    popcounts: enter pull when the frontier has grown to 1/alpha of the
    unvisited set, leave it once the frontier shrinks below V/beta. The
    hysteresis (``pulling`` carried across supersteps) mirrors Beamer's
    two-threshold design; both directions are bit-identical, so the choice
    is pure cost steering. Products are formed in float32: the comparison
    is a heuristic, and nf * alpha can exceed int32 for large Q * V.
    """
    go_pull = nf.astype(jnp.float32) * alpha >= nu.astype(jnp.float32)
    stay_pull = nf.astype(jnp.float32) * beta >= jnp.float32(v)
    return jnp.where(pulling, stay_pull, go_pull)


def _get_step_fn(backend: str):
    if backend == "jnp":
        return bfs_step_jnp
    if backend == "packed":
        return bfs_step_packed_jnp
    if backend == "pallas":
        from repro.kernels.bfs_step.ops import bfs_step as bfs_step_pallas

        return bfs_step_pallas
    if backend == "packed_pallas":
        from repro.kernels.bfs_step.ops import bfs_step_packed

        return bfs_step_packed
    raise ValueError(f"unknown bfs backend {backend!r}")


def _get_hybrid_step_fns(backend: str):
    """(push_fn, pull_fn) for the direction-optimizing backends. Push is
    the packed top-down expansion, pull the bottom-up in-row reduction
    (DESIGN.md §11); "hybrid" stays in jnp, "hybrid_pallas" runs both
    directions through their Pallas kernels."""
    if backend == "hybrid":
        return bfs_step_packed_jnp, bfs_step_pull_jnp
    if backend == "hybrid_pallas":
        from repro.kernels.bfs_pull_step.ops import bfs_pull_step
        from repro.kernels.bfs_step.ops import bfs_step_packed

        return bfs_step_packed, bfs_pull_step
    raise ValueError(f"unknown hybrid bfs backend {backend!r}")


class BFSResult(NamedTuple):
    found: jax.Array    # bool   — dst reached
    parent: jax.Array   # int32[V] — BFS tree (slot -> parent slot, -1 root/unvisited)
    dist: jax.Array     # int32[V] — BFS depth (-1 unvisited)
    expanded: jax.Array  # bool[V] — rows whose adjacency was read (visited set)
    steps: jax.Array    # int32  — number of frontier expansions


def bfs(state: GraphState, src_slot, dst_slot, backend: str | None = None,
        alpha: int = DEFAULT_ALPHA, beta: int = DEFAULT_BETA) -> BFSResult:
    """Full BFS from ``src_slot``; early exit when ``dst_slot`` is reached.

    ``dst_slot < 0`` explores the full reachable set (used by benchmarks).
    Traversable edge: adj[u, w] & alive[u] & alive[w] — a dead endpoint makes
    the ENode logically absent, exactly the paper's marked-ptv rule.

    ``backend=None`` resolves via ``default_backend()`` — HERE, outside
    the jit boundary, so the resolved name (not None) is the static cache
    key and a changed ``REPRO_BFS_BACKEND`` takes effect on the next call.
    The hybrid backends run the direction-optimizing superstep
    (DESIGN.md §11): per-step popcounts of the frontier and the unvisited
    set pick push or pull via ``pick_direction`` (``alpha``/``beta`` are
    the static Beamer knobs, ignored by the single-direction backends).
    """
    return _bfs_jit(state, src_slot, dst_slot,
                    backend=_resolve_backend(backend), alpha=alpha,
                    beta=beta)


@functools.partial(jax.jit, static_argnames=("backend", "alpha", "beta"))
def _bfs_jit(state: GraphState, src_slot, dst_slot, backend: str,
             alpha: int, beta: int) -> BFSResult:
    v = state.capacity
    alive = state.valive
    src_ok = (src_slot >= 0) & alive[jnp.maximum(src_slot, 0)]
    s = jnp.maximum(src_slot, 0)

    frontier0 = jnp.zeros((v,), jnp.bool_).at[s].set(src_ok)
    visited0 = frontier0
    parent0 = jnp.full((v,), -1, jnp.int32)
    dist0 = jnp.where(frontier0, 0, -1).astype(jnp.int32)
    expanded0 = jnp.zeros((v,), jnp.bool_)
    hybrid = backend in HYBRID_BACKENDS
    if hybrid:
        push_fn, pull_fn = _get_hybrid_step_fns(backend)
        adj_arg = state.adj_packed
        adj_in_arg = state.adj_in_packed
    else:
        step_fn = _get_step_fn(backend)
        # packed backends stream the stored words; the float32-MXU backends
        # get the unpacked view, materialized once outside the superstep loop
        adj_arg = state.adj_packed if backend in PACKED_BACKENDS else state.adj

    def cond(c):
        frontier, visited, parent, dist, expanded, step = c[:6]
        hit_dst = (dst_slot >= 0) & visited[jnp.maximum(dst_slot, 0)]
        return jnp.any(frontier) & ~hit_dst & (step < v)

    def body(c):
        frontier, visited, parent, dist, expanded, step = c[:6]
        expanded = expanded | frontier
        if hybrid:
            pulling = pick_direction(
                c[6], jnp.sum(frontier.astype(jnp.int32)),
                jnp.sum((alive & ~visited).astype(jnp.int32)), v, alpha, beta)
            new, par = jax.lax.cond(
                pulling,
                lambda f, vis: pull_fn(f, adj_in_arg, alive, vis),
                lambda f, vis: push_fn(f, adj_arg, alive, vis),
                frontier, visited)
        else:
            new, par = step_fn(frontier, adj_arg, alive, visited)
        parent = jnp.where(new, par, parent)
        dist = jnp.where(new, step + 1, dist)
        visited = visited | new
        out = (new, visited, parent, dist, expanded, step + 1)
        return out + (pulling,) if hybrid else out

    init = (frontier0, visited0, parent0, dist0, expanded0, jnp.int32(0))
    if hybrid:
        init = init + (jnp.asarray(False),)
    final = jax.lax.while_loop(cond, body, init)
    frontier, visited, parent, dist, expanded, steps = final[:6]
    found = (dst_slot >= 0) & visited[jnp.maximum(dst_slot, 0)] & src_ok
    return BFSResult(found, parent, dist, expanded, steps)


@jax.jit
def extract_path(parent: jax.Array, src_slot, dst_slot):
    """Walk the BFS tree from dst back to src.

    Returns (length, slots[V]) — ``slots[:length]`` is the path src..dst in
    order, padded with -1. This is the paper's p-pointer trace in GetPath.
    """
    v = parent.shape[0]
    # reversed walk: collect dst, parent(dst), ...
    def cond(c):
        cur, n, _ = c
        return (cur >= 0) & (n < v)

    def body(c):
        cur, n, buf = c
        buf = buf.at[n].set(cur)
        nxt = jnp.where(cur == src_slot, -1, parent[cur])
        return nxt, n + 1, buf

    _, n, rev = jax.lax.while_loop(
        cond, body, (jnp.asarray(dst_slot, jnp.int32), jnp.int32(0), jnp.full((v,), -1, jnp.int32))
    )
    idx = jnp.arange(v, dtype=jnp.int32)
    fwd = jnp.where(idx < n, rev[jnp.clip(n - 1 - idx, 0, v - 1)], -1)
    return n, fwd


def reachable_count(state: GraphState, src_slot,
                    backend: str | None = None) -> jax.Array:
    """|{w : src ->* w}| — exercised by benchmarks. ``backend=None``
    resolves via ``default_backend()`` (DESIGN.md §11)."""
    r = bfs(state, src_slot, jnp.int32(-1), backend=backend)
    return jnp.sum((r.dist >= 0).astype(jnp.int32))


# ----------------------------------------------------------------------------
# Fused multi-source BFS — Q frontiers advanced by ONE [Q,V] @ [V,V] matmul
# per superstep (DESIGN.md §7)
# ----------------------------------------------------------------------------
def multi_bfs_step_jnp(frontiers, adj, alive, visited):
    """Reference fused expansion for Q frontiers at once.

    frontiers: bool[Q, V], visited: bool[Q, V], alive: bool[V].
    Returns (new bool[Q, V], parent int32[Q, V]) with
    parent[q, j] = smallest i with frontiers[q, i] and a traversable edge
    i->j (else -1) — identical per-query semantics to ``bfs_step_jnp``, but
    the frontier expansion is one real [Q,V]x[V,V] matmul instead of Q
    mat-vecs. Expansion and parent scan share the ``traversable`` mask.
    """
    t = traversable(adj, alive)
    f = frontiers.astype(jnp.float32)
    reach = (f @ t.astype(jnp.float32)) > 0
    new = reach & ~visited
    v = adj.shape[1]
    idx = jnp.arange(v, dtype=jnp.int32)
    # per-query masked min over source rows, laid out src-major
    # [V(src), Q, V(dst)] so the reduction runs over the leading axis
    # (contiguous inner [Q, V] panels — measurably faster than the
    # query-major layout on CPU/VPU)
    cand = jnp.where(frontiers.T[:, :, None] & t[:, None, :],
                     idx[:, None, None], INT32_MAX)
    parent = jnp.min(cand, axis=0)
    parent = jnp.where(new, parent, jnp.int32(-1))
    return new, parent


def multi_bfs_step_packed_jnp(frontiers, adj_packed, alive, visited):
    """Packed fused expansion (DESIGN.md §10): per query, reach is the
    bitwise OR of its frontier rows' traversable words. Bit-identical to
    ``multi_bfs_step_jnp``."""
    v = alive.shape[0]
    t = traversable_packed(adj_packed, alive, pack_bits(alive))
    sel = jnp.where(frontiers[:, :, None], t[None, :, :], jnp.uint32(0))
    reach = unpack_bits(or_reduce(sel, 1), v)
    new = reach & ~visited
    idx = jnp.arange(v, dtype=jnp.int32)
    cand = jnp.where(frontiers.T[:, :, None] & unpack_bits(t, v)[:, None, :],
                     idx[:, None, None], INT32_MAX)
    parent = jnp.min(cand, axis=0)
    parent = jnp.where(new, parent, jnp.int32(-1))
    return new, parent


def multi_bfs_step_pull_jnp(frontiers, adj_in_packed, alive, visited):
    """Fused bottom-up expansion for Q frontiers (DESIGN.md §11): per query,
    every unvisited vertex ANDs its maintained in-adjacency row against that
    query's packed frontier bitset — a [Q, V, W] word volume instead of the
    push step's [V, Q, V] parent-candidate volume (a 32x cut in the term
    that dominates each superstep). Bit-identical to
    ``multi_bfs_step_packed_jnp``."""
    w = adj_in_packed.shape[1]
    fw = pack_bits(frontiers & alive[None, :])          # [Q, W]
    cand = adj_in_packed[None, :, :] & fw[:, None, :]   # [Q, V, W]
    hit = jnp.any(cand != 0, axis=2)
    new = hit & alive[None, :] & ~visited
    widx = (jnp.arange(w, dtype=jnp.int32) * WORD_BITS)[None, None, :]
    pcand = jnp.where(cand != 0, widx + ctz32(cand), INT32_MAX)
    parent = jnp.min(pcand, axis=2)
    parent = jnp.where(new, parent, jnp.int32(-1))
    return new, parent


def _get_multi_step_fn(backend: str):
    if backend == "jnp":
        return multi_bfs_step_jnp
    if backend == "packed":
        return multi_bfs_step_packed_jnp
    if backend == "pallas":
        from repro.kernels.bfs_multi_step.ops import multi_bfs_step

        return multi_bfs_step
    if backend == "packed_pallas":
        from repro.kernels.bfs_multi_step.ops import multi_bfs_step_packed

        return multi_bfs_step_packed
    raise ValueError(f"unknown multi-bfs backend {backend!r}")


def _get_hybrid_multi_step_fns(backend: str):
    """(push_fn, pull_fn) for the fused direction-optimizing backends
    (DESIGN.md §11)."""
    if backend == "hybrid":
        return multi_bfs_step_packed_jnp, multi_bfs_step_pull_jnp
    if backend == "hybrid_pallas":
        from repro.kernels.bfs_multi_step.ops import multi_bfs_step_packed
        from repro.kernels.bfs_pull_step.ops import multi_bfs_pull_step

        return multi_bfs_step_packed, multi_bfs_pull_step
    raise ValueError(f"unknown hybrid multi-bfs backend {backend!r}")


class MultiBFSResult(NamedTuple):
    found: jax.Array     # bool[Q]    — dst reached (per query)
    parent: jax.Array    # int32[Q,V] — per-query BFS tree (-1 root/unvisited)
    dist: jax.Array      # int32[Q,V] — per-query BFS depth (-1 unvisited)
    expanded: jax.Array  # bool[Q,V]  — rows whose adjacency this query read
    steps: jax.Array     # int32[Q]   — per-query frontier expansions
    supersteps: jax.Array  # int32    — shared loop iterations actually run


def multi_bfs(state: GraphState, src_slots, dst_slots,
              backend: str | None = None, parents: bool = True,
              alpha: int = DEFAULT_ALPHA,
              beta: int = DEFAULT_BETA) -> MultiBFSResult:
    """Fused BFS from Q sources with per-query early exit (DESIGN.md §7).

    Per-query results are bit-identical to ``jax.vmap(bfs)`` over the same
    (src, dst) pairs — tests/test_multi_bfs.py asserts this — but the cost
    model is different: ONE shared ``while_loop`` whose body performs a
    single [Q,V] @ [V,V] frontier-matrix product, so the adjacency matrix is
    streamed from HBM once per superstep instead of once per query per
    superstep. Queries that have already reached their destination (or
    exhausted their frontier) are masked to an empty frontier and stop
    contributing work; the loop exits when every query is done.

    ``dst_slots[q] < 0`` explores query q's full reachable set.

    ``parents=False`` is closure-only mode (DESIGN.md §9): parent
    extraction — the [Q,V,V]-shaped masked min that dominates each
    superstep — is skipped and ``parent`` comes back all -1. found, dist,
    expanded and steps are bit-identical to the default mode. The
    reachability-index build drives this: label construction needs
    closures, never trees. The expansion operand is hoisted out of the
    loop: the float32 traversable matrix for the MXU backends (the Pallas
    superstep earns its keep on parent extraction; the matmul alone XLA
    already tiles well), the traversable WORDS for the packed backends
    (DESIGN.md §10) — the latter stream 32x less adjacency per superstep.

    The hybrid backends (DESIGN.md §11) pick push or pull per superstep
    from the popcounts of the ACTIVE queries' pooled frontier and unvisited
    sets (one shared decision — a per-query split would compute both
    directions); ``alpha``/``beta`` are the static Beamer knobs. Closure
    mode stays in jnp for both hybrid flavors (parent extraction is the
    term the kernels exist to shrink, and closure mode has none).
    ``backend=None`` resolves via ``default_backend()`` here, outside the
    jit boundary, so the resolved name is the static cache key. With the
    tracing recorder enabled (DESIGN.md §14) — and only from host context,
    never inside an enclosing jit trace — the SAME superstep body runs
    under a host-driven loop instead of the fused ``lax.while_loop``, so
    every superstep lands as one ``bfs.superstep`` span carrying its
    direction tag and frontier/unvisited popcounts: bit-identical results,
    post-hoc-explainable push/pull decisions.
    """
    backend = _resolve_backend(backend)
    if _trace.enabled() and not _is_tracer(state.valive):
        return _multi_bfs_traced(state, src_slots, dst_slots,
                                 backend=backend, parents=parents,
                                 alpha=alpha, beta=beta)
    return _multi_bfs_jit(state, src_slots, dst_slots,
                          backend=backend,
                          parents=parents, alpha=alpha, beta=beta)


def _is_tracer(x) -> bool:
    """True when called under an enclosing jit trace — the traced host
    loop must never engage there (DESIGN.md §14)."""
    return isinstance(x, jax.core.Tracer)


def _multi_init(state: GraphState, src_slots, dst_slots, hybrid: bool):
    """Shared loop-carry initialization for the fused and traced loops."""
    q = src_slots.shape[0]
    v = state.capacity
    alive = state.valive
    src_ok = (src_slots >= 0) & alive[jnp.maximum(src_slots, 0)]
    s = jnp.maximum(src_slots, 0)

    frontier0 = jnp.zeros((q, v), jnp.bool_).at[jnp.arange(q), s].set(src_ok)
    visited0 = frontier0
    parent0 = jnp.full((q, v), -1, jnp.int32)
    dist0 = jnp.where(frontier0, 0, -1).astype(jnp.int32)
    expanded0 = jnp.zeros((q, v), jnp.bool_)
    steps0 = jnp.zeros((q,), jnp.int32)
    init = (frontier0, visited0, parent0, dist0, expanded0, steps0,
            jnp.int32(0))
    if hybrid:
        init = init + (jnp.asarray(False),)
    return init, src_ok


def _multi_step_fns(state: GraphState, dst_slots, backend: str,
                    parents: bool, alpha: int, beta: int):
    """(cond, body) of the fused superstep loop — ONE implementation shared
    by the jitted ``lax.while_loop`` and the traced host-driven loop
    (DESIGN.md §14), so the traced path cannot drift from production."""
    q = dst_slots.shape[0]
    v = state.capacity
    alive = state.valive
    hybrid = backend in HYBRID_BACKENDS
    is_packed = backend in PACKED_BACKENDS or hybrid
    if hybrid:
        push_fn, pull_fn = _get_hybrid_multi_step_fns(backend)
        adj_arg = state.adj_packed
        adj_in_arg = state.adj_in_packed
    else:
        step_fn = _get_multi_step_fn(backend)
        adj_arg = state.adj_packed if is_packed else state.adj
    if not parents:
        # closure-only expansion operand, hoisted out of the superstep loop:
        # traversable words for the packed path, the float32 traversable
        # matrix for the MXU path (DESIGN.md §9, §10)
        closure_op = (
            traversable_packed(state.adj_packed, alive, pack_bits(alive))
            if is_packed else
            traversable(state.adj, alive).astype(jnp.float32))

    def _active(frontiers, visited, step):
        # mirrors the single-query cond, evaluated per query
        hit_dst = (dst_slots >= 0) & visited[jnp.arange(q), jnp.maximum(dst_slots, 0)]
        return jnp.any(frontiers, axis=1) & ~hit_dst & (step < v)

    def cond(c):
        frontiers, visited, parent, dist, expanded, steps, step = c[:7]
        return jnp.any(_active(frontiers, visited, step))

    def body(c):
        frontiers, visited, parent, dist, expanded, steps, step = c[:7]
        act = _active(frontiers, visited, step)
        # early-exit masking: finished queries expose an all-empty frontier,
        # so their tiles are skipped by the kernel's @pl.when fast path and
        # their parent/dist/expanded stay frozen exactly as if their own
        # single-query loop had terminated.
        f = frontiers & act[:, None]
        if hybrid:
            # pooled direction decision over the active queries: finished
            # queries contribute empty frontiers and nothing to nu
            nf = jnp.sum(f.astype(jnp.int32))
            nu = jnp.sum(((alive[None, :] & ~visited)
                          & act[:, None]).astype(jnp.int32))
            pulling = pick_direction(c[7], nf, nu, q * v, alpha, beta)
        expanded = expanded | f
        if parents:
            if hybrid:
                new, par = jax.lax.cond(
                    pulling,
                    lambda ff, vis: pull_fn(ff, adj_in_arg, alive, vis),
                    lambda ff, vis: push_fn(ff, adj_arg, alive, vis),
                    f, visited)
            else:
                new, par = step_fn(f, adj_arg, alive, visited)
            parent = jnp.where(new, par, parent)
        elif hybrid:
            def _push_closure(ff, vis):
                sel = jnp.where(ff[:, :, None], closure_op[None, :, :],
                                jnp.uint32(0))
                return unpack_bits(or_reduce(sel, 1), v) & ~vis

            def _pull_closure(ff, vis):
                fw = pack_bits(ff & alive[None, :])
                cand = adj_in_arg[None, :, :] & fw[:, None, :]
                return jnp.any(cand != 0, axis=2) & alive[None, :] & ~vis

            new = jax.lax.cond(pulling, _pull_closure, _push_closure,
                               f, visited)
        elif is_packed:
            sel = jnp.where(f[:, :, None], closure_op[None, :, :],
                            jnp.uint32(0))
            new = unpack_bits(or_reduce(sel, 1), v) & ~visited
        else:
            new = ((f.astype(jnp.float32) @ closure_op) > 0) & ~visited
        dist = jnp.where(new, step + 1, dist)
        visited = visited | new
        steps = steps + act.astype(jnp.int32)
        out = (new, visited, parent, dist, expanded, steps, step + 1)
        return out + (pulling,) if hybrid else out

    return cond, body


def _multi_result(final, src_ok, dst_slots) -> MultiBFSResult:
    frontiers, visited, parent, dist, expanded, steps, supersteps = final[:7]
    q = visited.shape[0]
    found = (dst_slots >= 0) & visited[jnp.arange(q), jnp.maximum(dst_slots, 0)] & src_ok
    return MultiBFSResult(found, parent, dist, expanded, steps, supersteps)


@functools.partial(jax.jit,
                   static_argnames=("backend", "parents", "alpha", "beta"))
def _multi_bfs_jit(state: GraphState, src_slots, dst_slots, backend: str,
                   parents: bool, alpha: int,
                   beta: int) -> MultiBFSResult:
    src_slots = jnp.asarray(src_slots, jnp.int32)
    dst_slots = jnp.asarray(dst_slots, jnp.int32)
    hybrid = backend in HYBRID_BACKENDS
    init, src_ok = _multi_init(state, src_slots, dst_slots, hybrid)
    cond, body = _multi_step_fns(state, dst_slots, backend, parents,
                                 alpha, beta)
    final = jax.lax.while_loop(cond, body, init)
    return _multi_result(final, src_ok, dst_slots)


@functools.partial(jax.jit,
                   static_argnames=("backend", "parents", "alpha", "beta"))
def _multi_superstep_jit(state: GraphState, dst_slots, carry, backend: str,
                         parents: bool, alpha: int, beta: int):
    """ONE fused superstep — the traced host loop's jitted unit of work.
    Applies the same ``body`` the while_loop runs (DESIGN.md §14)."""
    _, body = _multi_step_fns(state, dst_slots, backend, parents,
                              alpha, beta)
    return body(carry)


def _multi_bfs_traced(state: GraphState, src_slots, dst_slots, *,
                      backend: str, parents: bool, alpha: int,
                      beta: int) -> MultiBFSResult:
    """Host-driven superstep loop under the tracing recorder
    (DESIGN.md §14): bit-identical to ``_multi_bfs_jit`` (same init, same
    superstep body, same termination predicate), but each superstep is one
    jitted call fenced by ``jax.block_until_ready`` and recorded as a
    ``bfs.superstep`` span with its direction tag and frontier/unvisited
    popcounts — the push/pull decision trail the Perfetto trace makes
    navigable. Never runs inside an enclosing jit (see ``multi_bfs``).
    """
    reg = _obs_registry()
    src_slots = jnp.asarray(src_slots, jnp.int32)
    dst_slots = jnp.asarray(dst_slots, jnp.int32)
    hybrid = backend in HYBRID_BACKENDS
    carry, src_ok = _multi_init(state, src_slots, dst_slots, hybrid)
    q = int(src_slots.shape[0])
    v = int(state.capacity)
    dst_np = np.asarray(dst_slots)
    alive_np = np.asarray(state.valive)
    last_dir = None
    with _trace.span("bfs.session", queries=q, capacity=v,
                     backend=backend, parents=parents) as session:
        while True:
            # the while_loop cond, evaluated host-side on materialized carry
            frontiers = np.asarray(carry[0])
            visited = np.asarray(carry[1])
            step = int(carry[6])
            hit_dst = (dst_np >= 0) & visited[np.arange(q),
                                             np.maximum(dst_np, 0)]
            act = frontiers.any(axis=1) & ~hit_dst & (step < v)
            if not act.any():
                break
            nf = int(frontiers[act].sum())
            nu = int(((alive_np[None, :] & ~visited) & act[:, None]).sum())
            with _trace.span("bfs.superstep", step=step, frontier_pop=nf,
                             unvisited_pop=nu) as sp:
                carry = _multi_superstep_jit(state, dst_slots, carry,
                                             backend=backend,
                                             parents=parents, alpha=alpha,
                                             beta=beta)
                _trace.fence(carry)
                # the carried ``pulling`` flag IS the decision this
                # superstep executed — read it back, never re-derive it
                direction = ("pull" if hybrid and bool(carry[7])
                             else "push")
                sp.set(direction=direction)
            reg.inc("bfs.supersteps")
            if direction == "pull":
                reg.inc("bfs.pull_supersteps")
            if last_dir is not None and direction != last_dir:
                reg.inc("bfs.direction_flips")
            last_dir = direction
        session.set(supersteps=int(carry[6]))
    return _multi_result(carry, src_ok, dst_slots)
