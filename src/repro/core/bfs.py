"""BFS traversal as tiled mat-vec — the TPU-native replacement for pointer chasing.

The paper's TreeCollect walks edge-lists node by node. On TPU the same
traversal is a sequence of *frontier expansion* steps over adjacency tiles:

    reach[j]  = OR_i  frontier[i] AND adj[i, j]          (MXU tile mat-vec)
    parent[j] = min_i { i : frontier[i] AND adj[i, j] }  (VPU masked min)
    new       = reach AND alive AND NOT visited

One step costs O(V^2 / P) dense work with high arithmetic intensity instead of
O(E) random accesses — the hardware-adaptation core of this reproduction
(DESIGN.md §1). ``step_fn`` is pluggable: ``"jnp"`` (pure reference, always
available) or ``"pallas"`` (kernels/bfs_step, interpret=True on CPU).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.graph import GraphState

INT32_MAX = jnp.int32(2**31 - 1)


def bfs_step_jnp(frontier, adj, alive, visited):
    """Reference frontier expansion. Returns (new_frontier[V] bool, parent[V] int32).

    parent[j] = smallest frontier index i with an edge i->j (or -1).
    """
    f = frontier.astype(jnp.float32)
    reach = (f @ adj.astype(jnp.float32)) > 0
    new = reach & alive & ~visited
    v = adj.shape[0]
    idx = jnp.arange(v, dtype=jnp.int32)
    # candidate parent rows: masked min over i of (frontier_i & adj_ij)
    cand = jnp.where(frontier[:, None] & (adj > 0), idx[:, None], INT32_MAX)
    parent = jnp.min(cand, axis=0)
    parent = jnp.where(new, parent, jnp.int32(-1))
    return new, parent


def _get_step_fn(backend: str):
    if backend == "jnp":
        return bfs_step_jnp
    if backend == "pallas":
        from repro.kernels.bfs_step.ops import bfs_step as bfs_step_pallas

        return bfs_step_pallas
    raise ValueError(f"unknown bfs backend {backend!r}")


class BFSResult(NamedTuple):
    found: jax.Array    # bool   — dst reached
    parent: jax.Array   # int32[V] — BFS tree (slot -> parent slot, -1 root/unvisited)
    dist: jax.Array     # int32[V] — BFS depth (-1 unvisited)
    expanded: jax.Array  # bool[V] — rows whose adjacency was read (visited set)
    steps: jax.Array    # int32  — number of frontier expansions


@functools.partial(jax.jit, static_argnames=("backend",))
def bfs(state: GraphState, src_slot, dst_slot, backend: str = "jnp") -> BFSResult:
    """Full BFS from ``src_slot``; early exit when ``dst_slot`` is reached.

    ``dst_slot < 0`` explores the full reachable set (used by benchmarks).
    Traversable edge: adj[u, w] & alive[u] & alive[w] — a dead endpoint makes
    the ENode logically absent, exactly the paper's marked-ptv rule.
    """
    v = state.capacity
    alive = state.valive
    src_ok = (src_slot >= 0) & alive[jnp.maximum(src_slot, 0)]
    s = jnp.maximum(src_slot, 0)

    frontier0 = jnp.zeros((v,), jnp.bool_).at[s].set(src_ok)
    visited0 = frontier0
    parent0 = jnp.full((v,), -1, jnp.int32)
    dist0 = jnp.where(frontier0, 0, -1).astype(jnp.int32)
    expanded0 = jnp.zeros((v,), jnp.bool_)
    step_fn = _get_step_fn(backend)

    def cond(c):
        frontier, visited, parent, dist, expanded, step = c
        hit_dst = (dst_slot >= 0) & visited[jnp.maximum(dst_slot, 0)]
        return jnp.any(frontier) & ~hit_dst & (step < v)

    def body(c):
        frontier, visited, parent, dist, expanded, step = c
        expanded = expanded | frontier
        new, par = step_fn(frontier, state.adj, alive, visited)
        parent = jnp.where(new, par, parent)
        dist = jnp.where(new, step + 1, dist)
        visited = visited | new
        return new, visited, parent, dist, expanded, step + 1

    frontier, visited, parent, dist, expanded, steps = jax.lax.while_loop(
        cond, body, (frontier0, visited0, parent0, dist0, expanded0, jnp.int32(0))
    )
    found = (dst_slot >= 0) & visited[jnp.maximum(dst_slot, 0)] & src_ok
    return BFSResult(found, parent, dist, expanded, steps)


@jax.jit
def extract_path(parent: jax.Array, src_slot, dst_slot):
    """Walk the BFS tree from dst back to src.

    Returns (length, slots[V]) — ``slots[:length]`` is the path src..dst in
    order, padded with -1. This is the paper's p-pointer trace in GetPath.
    """
    v = parent.shape[0]
    # reversed walk: collect dst, parent(dst), ...
    def cond(c):
        cur, n, _ = c
        return (cur >= 0) & (n < v)

    def body(c):
        cur, n, buf = c
        buf = buf.at[n].set(cur)
        nxt = jnp.where(cur == src_slot, -1, parent[cur])
        return nxt, n + 1, buf

    _, n, rev = jax.lax.while_loop(
        cond, body, (jnp.asarray(dst_slot, jnp.int32), jnp.int32(0), jnp.full((v,), -1, jnp.int32))
    )
    idx = jnp.arange(v, dtype=jnp.int32)
    fwd = jnp.where(idx < n, rev[jnp.clip(n - 1 - idx, 0, v - 1)], -1)
    return n, fwd


def reachable_count(state: GraphState, src_slot, backend: str = "jnp") -> jax.Array:
    """|{w : src ->* w}| — exercised by benchmarks."""
    r = bfs(state, src_slot, jnp.int32(-1), backend=backend)
    return jnp.sum((r.dist >= 0).astype(jnp.int32))
