"""BFS traversal as tiled mat-vec — the TPU-native replacement for pointer chasing.

The paper's TreeCollect walks edge-lists node by node. On TPU the same
traversal is a sequence of *frontier expansion* steps over adjacency tiles:

    reach[j]  = OR_i  frontier[i] AND adj[i, j]          (MXU tile mat-vec)
    parent[j] = min_i { i : frontier[i] AND adj[i, j] }  (VPU masked min)
    new       = reach AND alive AND NOT visited

One step costs O(V^2 / P) dense work with high arithmetic intensity instead of
O(E) random accesses — the hardware-adaptation core of this reproduction
(DESIGN.md §1). ``step_fn`` is pluggable per backend (DESIGN.md §10):

  "jnp"           float32-MXU reference: unpack the packed words, expand via
                  a frontier mat-vec (always available)
  "pallas"        kernels/bfs_step on the unpacked view (interpret on CPU)
  "packed"        pure-jnp AND/OR reduction over the packed uint32 words —
                  no unpack, no matmul, ~32x less adjacency traffic
  "packed_pallas" kernels/bfs_step packed kernel (words streamed HBM->VMEM)

All four backends produce bit-identical BFSResults; every edge view is
derived from the ONE ``core.graph.traversable`` predicate.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.graph import (
    GraphState,
    or_reduce,
    pack_bits,
    traversable,
    traversable_packed,
    unpack_bits,
)

INT32_MAX = jnp.int32(2**31 - 1)

# backends whose step functions consume ``state.adj_packed`` directly
PACKED_BACKENDS = ("packed", "packed_pallas")


def bfs_step_jnp(frontier, adj, alive, visited):
    """Reference frontier expansion. Returns (new_frontier[V] bool, parent[V] int32).

    parent[j] = smallest frontier index i with a traversable edge i->j (-1
    if none). Both the expansion and the parent scan read the SAME
    ``traversable`` mask, so endpoint liveness cannot drift between them.
    """
    t = traversable(adj, alive)
    f = frontier.astype(jnp.float32)
    reach = (f @ t.astype(jnp.float32)) > 0
    new = reach & ~visited
    v = adj.shape[0]
    idx = jnp.arange(v, dtype=jnp.int32)
    # candidate parent rows: masked min over i of (frontier_i & t_ij)
    cand = jnp.where(frontier[:, None] & t, idx[:, None], INT32_MAX)
    parent = jnp.min(cand, axis=0)
    parent = jnp.where(new, parent, jnp.int32(-1))
    return new, parent


def bfs_step_packed_jnp(frontier, adj_packed, alive, visited):
    """Packed frontier expansion (DESIGN.md §10): reach is a bitwise OR of
    the frontier rows' traversable words — no unpack of the streamed
    adjacency, no matmul. Bit-identical to ``bfs_step_jnp``."""
    v = alive.shape[0]
    t = traversable_packed(adj_packed, alive, pack_bits(alive))
    sel = jnp.where(frontier[:, None], t, jnp.uint32(0))
    reach = unpack_bits(or_reduce(sel, 0), v)
    new = reach & ~visited
    idx = jnp.arange(v, dtype=jnp.int32)
    cand = jnp.where(frontier[:, None] & unpack_bits(t, v),
                     idx[:, None], INT32_MAX)
    parent = jnp.min(cand, axis=0)
    parent = jnp.where(new, parent, jnp.int32(-1))
    return new, parent


def _get_step_fn(backend: str):
    if backend == "jnp":
        return bfs_step_jnp
    if backend == "packed":
        return bfs_step_packed_jnp
    if backend == "pallas":
        from repro.kernels.bfs_step.ops import bfs_step as bfs_step_pallas

        return bfs_step_pallas
    if backend == "packed_pallas":
        from repro.kernels.bfs_step.ops import bfs_step_packed

        return bfs_step_packed
    raise ValueError(f"unknown bfs backend {backend!r}")


class BFSResult(NamedTuple):
    found: jax.Array    # bool   — dst reached
    parent: jax.Array   # int32[V] — BFS tree (slot -> parent slot, -1 root/unvisited)
    dist: jax.Array     # int32[V] — BFS depth (-1 unvisited)
    expanded: jax.Array  # bool[V] — rows whose adjacency was read (visited set)
    steps: jax.Array    # int32  — number of frontier expansions


@functools.partial(jax.jit, static_argnames=("backend",))
def bfs(state: GraphState, src_slot, dst_slot, backend: str = "jnp") -> BFSResult:
    """Full BFS from ``src_slot``; early exit when ``dst_slot`` is reached.

    ``dst_slot < 0`` explores the full reachable set (used by benchmarks).
    Traversable edge: adj[u, w] & alive[u] & alive[w] — a dead endpoint makes
    the ENode logically absent, exactly the paper's marked-ptv rule.
    """
    v = state.capacity
    alive = state.valive
    src_ok = (src_slot >= 0) & alive[jnp.maximum(src_slot, 0)]
    s = jnp.maximum(src_slot, 0)

    frontier0 = jnp.zeros((v,), jnp.bool_).at[s].set(src_ok)
    visited0 = frontier0
    parent0 = jnp.full((v,), -1, jnp.int32)
    dist0 = jnp.where(frontier0, 0, -1).astype(jnp.int32)
    expanded0 = jnp.zeros((v,), jnp.bool_)
    step_fn = _get_step_fn(backend)
    # packed backends stream the stored words; the float32-MXU backends get
    # the unpacked view, materialized once outside the superstep loop
    adj_arg = state.adj_packed if backend in PACKED_BACKENDS else state.adj

    def cond(c):
        frontier, visited, parent, dist, expanded, step = c
        hit_dst = (dst_slot >= 0) & visited[jnp.maximum(dst_slot, 0)]
        return jnp.any(frontier) & ~hit_dst & (step < v)

    def body(c):
        frontier, visited, parent, dist, expanded, step = c
        expanded = expanded | frontier
        new, par = step_fn(frontier, adj_arg, alive, visited)
        parent = jnp.where(new, par, parent)
        dist = jnp.where(new, step + 1, dist)
        visited = visited | new
        return new, visited, parent, dist, expanded, step + 1

    frontier, visited, parent, dist, expanded, steps = jax.lax.while_loop(
        cond, body, (frontier0, visited0, parent0, dist0, expanded0, jnp.int32(0))
    )
    found = (dst_slot >= 0) & visited[jnp.maximum(dst_slot, 0)] & src_ok
    return BFSResult(found, parent, dist, expanded, steps)


@jax.jit
def extract_path(parent: jax.Array, src_slot, dst_slot):
    """Walk the BFS tree from dst back to src.

    Returns (length, slots[V]) — ``slots[:length]`` is the path src..dst in
    order, padded with -1. This is the paper's p-pointer trace in GetPath.
    """
    v = parent.shape[0]
    # reversed walk: collect dst, parent(dst), ...
    def cond(c):
        cur, n, _ = c
        return (cur >= 0) & (n < v)

    def body(c):
        cur, n, buf = c
        buf = buf.at[n].set(cur)
        nxt = jnp.where(cur == src_slot, -1, parent[cur])
        return nxt, n + 1, buf

    _, n, rev = jax.lax.while_loop(
        cond, body, (jnp.asarray(dst_slot, jnp.int32), jnp.int32(0), jnp.full((v,), -1, jnp.int32))
    )
    idx = jnp.arange(v, dtype=jnp.int32)
    fwd = jnp.where(idx < n, rev[jnp.clip(n - 1 - idx, 0, v - 1)], -1)
    return n, fwd


def reachable_count(state: GraphState, src_slot, backend: str = "jnp") -> jax.Array:
    """|{w : src ->* w}| — exercised by benchmarks."""
    r = bfs(state, src_slot, jnp.int32(-1), backend=backend)
    return jnp.sum((r.dist >= 0).astype(jnp.int32))


# ----------------------------------------------------------------------------
# Fused multi-source BFS — Q frontiers advanced by ONE [Q,V] @ [V,V] matmul
# per superstep (DESIGN.md §7)
# ----------------------------------------------------------------------------
def multi_bfs_step_jnp(frontiers, adj, alive, visited):
    """Reference fused expansion for Q frontiers at once.

    frontiers: bool[Q, V], visited: bool[Q, V], alive: bool[V].
    Returns (new bool[Q, V], parent int32[Q, V]) with
    parent[q, j] = smallest i with frontiers[q, i] and a traversable edge
    i->j (else -1) — identical per-query semantics to ``bfs_step_jnp``, but
    the frontier expansion is one real [Q,V]x[V,V] matmul instead of Q
    mat-vecs. Expansion and parent scan share the ``traversable`` mask.
    """
    t = traversable(adj, alive)
    f = frontiers.astype(jnp.float32)
    reach = (f @ t.astype(jnp.float32)) > 0
    new = reach & ~visited
    v = adj.shape[1]
    idx = jnp.arange(v, dtype=jnp.int32)
    # per-query masked min over source rows, laid out src-major
    # [V(src), Q, V(dst)] so the reduction runs over the leading axis
    # (contiguous inner [Q, V] panels — measurably faster than the
    # query-major layout on CPU/VPU)
    cand = jnp.where(frontiers.T[:, :, None] & t[:, None, :],
                     idx[:, None, None], INT32_MAX)
    parent = jnp.min(cand, axis=0)
    parent = jnp.where(new, parent, jnp.int32(-1))
    return new, parent


def multi_bfs_step_packed_jnp(frontiers, adj_packed, alive, visited):
    """Packed fused expansion (DESIGN.md §10): per query, reach is the
    bitwise OR of its frontier rows' traversable words. Bit-identical to
    ``multi_bfs_step_jnp``."""
    v = alive.shape[0]
    t = traversable_packed(adj_packed, alive, pack_bits(alive))
    sel = jnp.where(frontiers[:, :, None], t[None, :, :], jnp.uint32(0))
    reach = unpack_bits(or_reduce(sel, 1), v)
    new = reach & ~visited
    idx = jnp.arange(v, dtype=jnp.int32)
    cand = jnp.where(frontiers.T[:, :, None] & unpack_bits(t, v)[:, None, :],
                     idx[:, None, None], INT32_MAX)
    parent = jnp.min(cand, axis=0)
    parent = jnp.where(new, parent, jnp.int32(-1))
    return new, parent


def _get_multi_step_fn(backend: str):
    if backend == "jnp":
        return multi_bfs_step_jnp
    if backend == "packed":
        return multi_bfs_step_packed_jnp
    if backend == "pallas":
        from repro.kernels.bfs_multi_step.ops import multi_bfs_step

        return multi_bfs_step
    if backend == "packed_pallas":
        from repro.kernels.bfs_multi_step.ops import multi_bfs_step_packed

        return multi_bfs_step_packed
    raise ValueError(f"unknown multi-bfs backend {backend!r}")


class MultiBFSResult(NamedTuple):
    found: jax.Array     # bool[Q]    — dst reached (per query)
    parent: jax.Array    # int32[Q,V] — per-query BFS tree (-1 root/unvisited)
    dist: jax.Array      # int32[Q,V] — per-query BFS depth (-1 unvisited)
    expanded: jax.Array  # bool[Q,V]  — rows whose adjacency this query read
    steps: jax.Array     # int32[Q]   — per-query frontier expansions
    supersteps: jax.Array  # int32    — shared loop iterations actually run


@functools.partial(jax.jit, static_argnames=("backend", "parents"))
def multi_bfs(state: GraphState, src_slots, dst_slots,
              backend: str = "jnp", parents: bool = True) -> MultiBFSResult:
    """Fused BFS from Q sources with per-query early exit (DESIGN.md §7).

    Per-query results are bit-identical to ``jax.vmap(bfs)`` over the same
    (src, dst) pairs — tests/test_multi_bfs.py asserts this — but the cost
    model is different: ONE shared ``while_loop`` whose body performs a
    single [Q,V] @ [V,V] frontier-matrix product, so the adjacency matrix is
    streamed from HBM once per superstep instead of once per query per
    superstep. Queries that have already reached their destination (or
    exhausted their frontier) are masked to an empty frontier and stop
    contributing work; the loop exits when every query is done.

    ``dst_slots[q] < 0`` explores query q's full reachable set.

    ``parents=False`` is closure-only mode (DESIGN.md §9): parent
    extraction — the [Q,V,V]-shaped masked min that dominates each
    superstep — is skipped and ``parent`` comes back all -1. found, dist,
    expanded and steps are bit-identical to the default mode. The
    reachability-index build drives this: label construction needs
    closures, never trees. The expansion operand is hoisted out of the
    loop: the float32 traversable matrix for the MXU backends (the Pallas
    superstep earns its keep on parent extraction; the matmul alone XLA
    already tiles well), the traversable WORDS for the packed backends
    (DESIGN.md §10) — the latter stream 32x less adjacency per superstep.
    """
    src_slots = jnp.asarray(src_slots, jnp.int32)
    dst_slots = jnp.asarray(dst_slots, jnp.int32)
    q = src_slots.shape[0]
    v = state.capacity
    alive = state.valive
    src_ok = (src_slots >= 0) & alive[jnp.maximum(src_slots, 0)]
    s = jnp.maximum(src_slots, 0)

    frontier0 = jnp.zeros((q, v), jnp.bool_).at[jnp.arange(q), s].set(src_ok)
    visited0 = frontier0
    parent0 = jnp.full((q, v), -1, jnp.int32)
    dist0 = jnp.where(frontier0, 0, -1).astype(jnp.int32)
    expanded0 = jnp.zeros((q, v), jnp.bool_)
    steps0 = jnp.zeros((q,), jnp.int32)
    step_fn = _get_multi_step_fn(backend)
    is_packed = backend in PACKED_BACKENDS
    adj_arg = state.adj_packed if is_packed else state.adj
    if not parents:
        # closure-only expansion operand, hoisted out of the superstep loop:
        # traversable words for the packed path, the float32 traversable
        # matrix for the MXU path (DESIGN.md §9, §10)
        closure_op = (
            traversable_packed(state.adj_packed, alive, pack_bits(alive))
            if is_packed else
            traversable(state.adj, alive).astype(jnp.float32))

    def _active(frontiers, visited, step):
        # mirrors the single-query cond, evaluated per query
        hit_dst = (dst_slots >= 0) & visited[jnp.arange(q), jnp.maximum(dst_slots, 0)]
        return jnp.any(frontiers, axis=1) & ~hit_dst & (step < v)

    def cond(c):
        frontiers, visited, parent, dist, expanded, steps, step = c
        return jnp.any(_active(frontiers, visited, step))

    def body(c):
        frontiers, visited, parent, dist, expanded, steps, step = c
        act = _active(frontiers, visited, step)
        # early-exit masking: finished queries expose an all-empty frontier,
        # so their tiles are skipped by the kernel's @pl.when fast path and
        # their parent/dist/expanded stay frozen exactly as if their own
        # single-query loop had terminated.
        f = frontiers & act[:, None]
        expanded = expanded | f
        if parents:
            new, par = step_fn(f, adj_arg, alive, visited)
            parent = jnp.where(new, par, parent)
        elif is_packed:
            sel = jnp.where(f[:, :, None], closure_op[None, :, :],
                            jnp.uint32(0))
            new = unpack_bits(or_reduce(sel, 1), v) & ~visited
        else:
            new = ((f.astype(jnp.float32) @ closure_op) > 0) & ~visited
        dist = jnp.where(new, step + 1, dist)
        visited = visited | new
        steps = steps + act.astype(jnp.int32)
        return new, visited, parent, dist, expanded, steps, step + 1

    frontiers, visited, parent, dist, expanded, steps, supersteps = jax.lax.while_loop(
        cond, body,
        (frontier0, visited0, parent0, dist0, expanded0, steps0, jnp.int32(0)),
    )
    found = (dst_slots >= 0) & visited[jnp.arange(q), jnp.maximum(dst_slots, 0)] & src_ok
    return MultiBFSResult(found, parent, dist, expanded, steps, supersteps)
