"""Wait-free epoch ring: retained snapshot history as packed deltas
(DESIGN.md §13).

The ingest pool (runtime/ingest.py) publishes one immutable functional
snapshot per admission round behind an atomic slot flip — epochs 0, 1, 2,
... in publish order. The successor paper ("Non-blocking Dynamic Unbounded
Graphs with Wait-Free Snapshot", arXiv 2310.02380) makes the collect side
wait-free by letting a reader that keeps losing the double-collect race
resolve against a *retained* consistent epoch instead of retrying forever.
This module reifies that retention: a bounded ring of

    (epoch, version_vector, packed row deltas)

records, one per published epoch, kept host-side as numpy (the device
state stays the single O(V^2/32) packed representation; the ring costs
O(touched_rows * W) per epoch plus one O(V) version vector).

Deltas are XOR patches. For every row whose bytes changed between epoch
e-1 and e the record stores ``row_index`` plus the XOR of the six field
rows (vkey/valive/vver/ecnt scalars and the packed out-adjacency row).
XOR is its own inverse, so the SAME record replays the transition in
either direction: ``state_at(e)`` starts from the newest published state
and XORs records backward until it lands on e — bit-identical history
reconstruction, proven by tests/test_epochs.py against the actually
published states. The in-adjacency is not stored: it is re-derived as the
packed transpose at reconstruction time (the DESIGN.md §11 transpose
invariant makes that lossless).

Three query surfaces ride on the ring (DESIGN.md §13):

  * **wait-free resolution** — ``snapshot.get_paths_session(
    on_conflict="epoch")`` pins its answer to one retained epoch after a
    bounded retry budget instead of spinning;
  * **time-travel reachability** — "was u→w reachable at epoch e?" via
    ``state_at(e)`` (a frozen state answers with a single collect);
  * **epoch diff** — "which rows changed between e1 and e2?" via the
    union of the retained records' row sets.

Capacity growth is a retention barrier: a ``grow`` changes every row's
shape, so the ring resets at the grown epoch and earlier epochs report
``EpochEvictedError`` — the same typed signal an epoch past the bounded
retention window produces.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

import jax.numpy as jnp

from repro.core.graph import GraphState, pack_transpose
from repro.obs import trace as _trace
from repro.obs.metrics import global_registry as _obs_registry

# The six per-row fields a delta record patches, in GraphState order
# (adj_in_packed is derived, never stored; see module docstring).
_ROW_FIELDS = ("vkey", "valive", "vver", "ecnt", "adj_packed")


class EpochEvictedError(LookupError):
    """Typed miss for a time-travel/diff query outside the retained window.

    Carries the requested epoch and the window that was available so
    servers can surface a structured "epoch evicted" result instead of a
    bare failure (DESIGN.md §13).
    """

    def __init__(self, epoch: int, window: tuple[int, int]):
        self.epoch = int(epoch)
        self.window = (int(window[0]), int(window[1]))
        super().__init__(
            f"epoch {epoch} outside retained window "
            f"[{window[0]}, {window[1]}]")


@dataclass(frozen=True)
class EpochRecord:
    """One retained epoch: its version vector + the XOR patch from e-1."""

    epoch: int
    capacity: int
    versions: np.ndarray      # int32[V, 2] — (ecnt, vver) AT this epoch
    rows: np.ndarray          # int32[K] — slots whose bytes changed
    vkey_xor: np.ndarray      # int32[K]
    valive_xor: np.ndarray    # bool[K]
    vver_xor: np.ndarray      # int32[K]
    ecnt_xor: np.ndarray      # int32[K]
    adj_xor: np.ndarray       # uint32[K, W] — packed out-adjacency rows


@dataclass(frozen=True)
class EpochDiff:
    """Epoch-diff answer: the rows touched between two retained epochs."""

    e_from: int
    e_to: int
    rows: np.ndarray          # int32[K] — union of touched slots
    keys_before: np.ndarray   # int32[K] — vkey at e_from (-1 = empty slot)
    keys_after: np.ndarray    # int32[K] — vkey at e_to


def _to_np(state) -> dict[str, np.ndarray]:
    """Host copies of the patchable fields (gathers a sharded state)."""
    return {
        "vkey": np.asarray(state.vkey),
        "valive": np.asarray(state.valive),
        "vver": np.asarray(state.vver),
        "ecnt": np.asarray(state.ecnt),
        "adj_packed": np.asarray(state.adj_packed),
    }


def _xor(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.bitwise_xor(a, b)


class EpochRing:
    """Bounded retention of published epochs as backward-replayable deltas.

    ``retain`` bounds the number of *addressable* epochs (records kept =
    retain - 1 plus the newest full state): after publishing epoch N the
    window is ``[max(reset_epoch, N - retain + 1), N]``. Push/reads are
    driven by the ingest pool under its admission mutex; the reconstruction
    surfaces only touch immutable records, so readers never block writers
    (DESIGN.md §13).
    """

    def __init__(self, retain: int = 64):
        if retain < 1:
            raise ValueError(f"retain must be >= 1, got {retain}")
        self.retain = int(retain)
        self.evicted = 0              # cumulative records dropped (stats)
        self._records: list[EpochRecord] = []
        self._latest: dict[str, np.ndarray] | None = None
        self._newest = 0

    # -- maintenance (writer side) ------------------------------------------
    def reset(self, epoch: int, state) -> None:
        """Restart retention at ``epoch`` (initial state or a grow barrier:
        a capacity change invalidates every row-shaped delta)."""
        self.evicted += len(self._records)
        self._records = []
        self._latest = _to_np(state)
        self._newest = int(epoch)

    def push(self, epoch: int, state) -> None:
        """Record the transition newest -> ``epoch`` (consecutive publishes)."""
        f = _to_np(state)
        if (self._latest is None
                or f["vkey"].shape[0] != self._latest["vkey"].shape[0]):
            self.reset(epoch, state)
            return
        if epoch != self._newest + 1:
            raise ValueError(
                f"non-consecutive publish: {self._newest} -> {epoch}")
        prev = self._latest
        scalar_changed = np.zeros(f["vkey"].shape[0], dtype=bool)
        for name in ("vkey", "valive", "vver", "ecnt"):
            scalar_changed |= prev[name] != f[name]
        adj_changed = (prev["adj_packed"] != f["adj_packed"]).any(axis=1)
        rows = np.nonzero(scalar_changed | adj_changed)[0].astype(np.int32)
        rec = EpochRecord(
            epoch=int(epoch),
            capacity=int(f["vkey"].shape[0]),
            versions=np.stack([f["ecnt"], f["vver"]], axis=-1),
            rows=rows,
            vkey_xor=_xor(prev["vkey"][rows], f["vkey"][rows]),
            valive_xor=_xor(prev["valive"][rows], f["valive"][rows]),
            vver_xor=_xor(prev["vver"][rows], f["vver"][rows]),
            ecnt_xor=_xor(prev["ecnt"][rows], f["ecnt"][rows]),
            adj_xor=_xor(prev["adj_packed"][rows], f["adj_packed"][rows]),
        )
        self._records.append(rec)
        self._latest = f
        self._newest = int(epoch)
        while len(self._records) > self.retain - 1:
            self._records.pop(0)
            self.evicted += 1
            if _trace.enabled():
                _obs_registry().inc("ring.evictions")
        if _trace.enabled():
            _obs_registry().set("ring.occupancy", len(self._records))
            _trace.counter("ring.occupancy", len(self._records))

    # -- read side ----------------------------------------------------------
    def window(self) -> tuple[int, int]:
        """(oldest addressable epoch, newest published epoch), inclusive."""
        return self._newest - len(self._records), self._newest

    def __len__(self) -> int:
        return len(self._records)

    def contains(self, epoch: int) -> bool:
        lo, hi = self.window()
        return lo <= int(epoch) <= hi

    def _fields_at(self, epoch: int) -> dict[str, np.ndarray]:
        lo, hi = self.window()
        if not lo <= int(epoch) <= hi:
            raise EpochEvictedError(epoch, (lo, hi))
        cur = {k: v.copy() for k, v in self._latest.items()}
        for rec in reversed(self._records):
            if rec.epoch <= epoch:
                break
            r = rec.rows
            cur["vkey"][r] = _xor(cur["vkey"][r], rec.vkey_xor)
            cur["valive"][r] = _xor(cur["valive"][r], rec.valive_xor)
            cur["vver"][r] = _xor(cur["vver"][r], rec.vver_xor)
            cur["ecnt"][r] = _xor(cur["ecnt"][r], rec.ecnt_xor)
            cur["adj_packed"][r] = _xor(cur["adj_packed"][r], rec.adj_xor)
        return cur

    def state_at(self, epoch: int) -> GraphState:
        """Reconstruct the published state of ``epoch`` — bit-identical to
        what ``IngestPool.snapshot()`` returned when that epoch was current
        (tests/test_epochs.py pins this against retained real states).
        Always a dense ``GraphState`` (time-travel queries are read-only;
        a sharded pool's history reconstructs to the gathered dense form).
        Raises ``EpochEvictedError`` outside the window."""
        with _trace.span("ring.state_at", epoch=int(epoch)) as sp:
            f = self._fields_at(epoch)
            if _trace.enabled():
                # replay depth: records XORed backward from the newest state
                depth = min(len(self._records),
                            max(0, self._newest - int(epoch)))
                sp.set(depth=depth)
                _obs_registry().observe("ring.resolve_depth", depth)
            adj = jnp.asarray(f["adj_packed"])
            return self._state_from_fields(f, adj)

    def _state_from_fields(self, f, adj) -> GraphState:
        return GraphState(
            vkey=jnp.asarray(f["vkey"]),
            valive=jnp.asarray(f["valive"]),
            vver=jnp.asarray(f["vver"]),
            ecnt=jnp.asarray(f["ecnt"]),
            adj_packed=adj,
            adj_in_packed=pack_transpose(adj, int(f["vkey"].shape[0])),
        )

    def versions_at(self, epoch: int) -> np.ndarray:
        """(ecnt, vver) int32[V, 2] of a retained epoch (cheap: stored for
        every record; reconstructed only for the window's oldest epoch)."""
        lo, hi = self.window()
        if not lo <= int(epoch) <= hi:
            raise EpochEvictedError(epoch, (lo, hi))
        for rec in self._records:
            if rec.epoch == epoch:
                return rec.versions
        if epoch == hi:   # no records yet (fresh ring): newest == latest
            f = self._latest
        else:             # the window's oldest epoch precedes every record
            f = self._fields_at(epoch)
        return np.stack([f["ecnt"], f["vver"]], axis=-1)

    def epoch_of_versions(self, versions, capacity: int) -> int | None:
        """Newest retained epoch whose version vector equals ``versions``
        (the index-stamp lookup of DESIGN.md §13), or None. Equal versions
        imply a byte-identical graph (monotone counters — the §9 freshness
        argument), so an index stamped with these versions answers queries
        pinned to that epoch exactly."""
        if self._latest is None or capacity != self._latest["vkey"].shape[0]:
            return None
        want = np.asarray(versions)
        lo, hi = self.window()
        for e in range(hi, lo - 1, -1):
            if np.array_equal(self.versions_at(e), want):
                return e
        return None

    # -- checkpoint serialization (DESIGN.md §16) ---------------------------
    def dump(self) -> tuple[list[np.ndarray], dict]:
        """Flatten the ring into (leaves, meta) for the graph checkpointer.

        Leaf order: the 5 ``_latest`` fields (in ``_ROW_FIELDS`` order),
        then 7 arrays per retained record (versions, rows, and the five
        XOR patches).  ``meta`` is JSON-safe and records the layout so
        ``load`` can reassemble records of any count — the reason the
        checkpointer grew ``restore_raw`` (template restores assume a
        fixed leaf count).
        """
        meta = {"retain": self.retain, "newest": self._newest,
                "evicted": self.evicted, "n_records": len(self._records),
                "has_latest": self._latest is not None,
                "record_epochs": [r.epoch for r in self._records]}
        leaves: list[np.ndarray] = []
        if self._latest is not None:
            leaves += [self._latest[k] for k in _ROW_FIELDS]
        for rec in self._records:
            leaves += [rec.versions, rec.rows, rec.vkey_xor, rec.valive_xor,
                       rec.vver_xor, rec.ecnt_xor, rec.adj_xor]
        return leaves, meta

    @classmethod
    def load(cls, leaves: list[np.ndarray], meta: dict) -> "EpochRing":
        """Rebuild a ring from ``dump`` output, bit-identical: same window,
        same records, same eviction counter."""
        ring = cls(retain=int(meta["retain"]))
        ring._newest = int(meta["newest"])
        ring.evicted = int(meta["evicted"])
        i = 0
        if meta.get("has_latest"):
            ring._latest = {k: np.asarray(leaves[i + j])
                            for j, k in enumerate(_ROW_FIELDS)}
            i += len(_ROW_FIELDS)
        cap = (int(ring._latest["vkey"].shape[0])
               if ring._latest is not None else 0)
        for epoch in meta.get("record_epochs", []):
            versions, rows, vk, va, vv, ec, adj = leaves[i:i + 7]
            i += 7
            ring._records.append(EpochRecord(
                epoch=int(epoch), capacity=cap,
                versions=np.asarray(versions),
                rows=np.asarray(rows, dtype=np.int32),
                vkey_xor=np.asarray(vk), valive_xor=np.asarray(va),
                vver_xor=np.asarray(vv), ecnt_xor=np.asarray(ec),
                adj_xor=np.asarray(adj)))
        return ring

    def diff(self, e1: int, e2: int) -> EpochDiff:
        """Rows (and their keys) that changed between two retained epochs.
        Raises ``EpochEvictedError`` if either endpoint left the window."""
        lo, hi = sorted((int(e1), int(e2)))
        w = self.window()
        for e in (lo, hi):
            if not w[0] <= e <= w[1]:
                raise EpochEvictedError(e, w)
        touched: set[int] = set()
        for rec in self._records:
            if lo < rec.epoch <= hi:
                touched.update(int(r) for r in rec.rows)
        rows = np.asarray(sorted(touched), dtype=np.int32)
        vk_lo = self._fields_at(lo)["vkey"]
        vk_hi = self._fields_at(hi)["vkey"]
        return EpochDiff(lo, hi, rows, vk_lo[rows], vk_hi[rows])
