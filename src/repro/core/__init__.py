"""Core library: the paper's concurrent non-blocking graph ADT in JAX.

Public surface:
  GraphState, OpBatch, make_graph, grow, make_op_batch   (graph.py)
  apply_ops, apply_ops_fast, compact, add_vertex, ...     (ops.py)
  bfs, multi_bfs, extract_path                            (bfs.py)
  collect, compare_collects, get_path, get_path_session,
  interleaved_getpath                                     (snapshot.py)
  EpochRing, EpochEvictedError, EpochDiff                 (epochs.py)
  ShardedGraphState, shard_state, sharded engines         (partition.py)
  row-sharded collective engines (dbfs, dapply_ops, ...)  (distributed.py)
  GraphOracle                                             (oracle.py)
"""
from repro.core.graph import (  # noqa: F401
    EMPTY_KEY,
    OP_ADD_E,
    OP_ADD_V,
    OP_CON_E,
    OP_CON_V,
    OP_NOP,
    OP_REM_E,
    OP_REM_V,
    R_CAS_FAIL,
    R_EDGE_ADDED,
    R_EDGE_NOT_PRESENT,
    R_EDGE_PRESENT,
    R_EDGE_REMOVED,
    R_FALSE,
    R_PENDING,
    R_RECOVERING,
    R_TABLE_FULL,
    R_TRUE,
    R_VERTEX_NOT_PRESENT,
    RESULT_NAMES,
    GraphState,
    OpBatch,
    contains_edge,
    contains_vertex,
    find_slot,
    find_slots,
    grow,
    make_graph,
    make_op_batch,
    num_edges,
    num_vertices,
    pack_bits,
    pack_transpose,
    packed_width,
    transpose_invariant,
    traversable,
    traversable_packed,
    unpack_bits,
    version_vector,
)
from repro.core.ops import (  # noqa: F401
    add_edge,
    add_edge_undirected,
    add_vertex,
    apply_ops,
    apply_ops_fast,
    compact,
    degree,
    neighbors,
    remove_edge,
    remove_edge_undirected,
    remove_vertex,
)
from repro.core.bfs import (  # noqa: F401
    BFSResult,
    HYBRID_BACKENDS,
    MultiBFSResult,
    PACKED_BACKENDS,
    bfs,
    default_backend,
    extract_path,
    multi_bfs,
    reachable_count,
)
from repro.core.snapshot import (  # noqa: F401
    Collect,
    PathResult,
    collect,
    collect_batch,
    compare_collect_batches,
    compare_collects,
    get_path,
    get_path_session,
    get_paths_session,
    interleaved_getpath,
)
from repro.core.epochs import (  # noqa: F401
    EpochDiff,
    EpochEvictedError,
    EpochRecord,
    EpochRing,
)
from repro.core.oracle import GraphOracle  # noqa: F401
from repro.core.partition import ShardedGraphState, shard_state, unshard  # noqa: F401
