"""Obstruction-free GetPath via double collect — the paper's §3.5, vectorized.

A *collect* = one BFS TreeCollect plus a snapshot of the validation vector
(ecnt, vver fused; see graph.version_vector) over the rows the traversal
depended on. Two consecutive collects *match* iff their dependency sets,
parent trees, found-flags and masked version vectors are equal. Matching
collects prove the traversal observed a graph state that existed unchanged
across the second collect's lifetime => the return is linearizable at any
point inside it (paper Thm 4.1 case 7a: last read of the (m-1)st collect).

Version-validated matching is *strictly stronger* than the paper's
node-by-node CompareTree/ComparePath: equal versions over the dependency set
imply byte-identical adjacency rows read, which implies identical trees; and
the §3.5 adversary (add edge, remove it between collects) necessarily bumps a
source-row ecnt it shares with the dependency set, so it is always caught.

Four surfaces:
  * ``collect`` / ``compare_collects`` / ``get_path``   — pure building blocks
  * ``get_path_session``      — host-level protocol against a live mutable
    state reference (the true concurrent setting; obstruction-free: completes
    as soon as one round-trip sees no effective mutation, and WAIT-FREE with
    ``on_conflict="epoch"``: after a bounded retry budget the answer resolves
    against one pinned published epoch instead of retrying, DESIGN.md §13)
  * ``collect_batch`` / ``get_paths_session`` — Q queries under ONE shared
    double collect, traversed by the fused multi-source BFS engine
    (DESIGN.md §7; ``engine="vmap"`` keeps the per-query reference path).
    Both accept a mesh-partitioned ``core.partition.ShardedGraphState``
    transparently: the traversal then runs per-shard with a psum frontier
    exchange, and the Collect comes back bit-identical (DESIGN.md §8)
  * ``interleaved_getpath``   — a single jitted program interleaving mutation
    batches with a pending query, demonstrating the protocol *inside* one
    device program (used by tests/benchmarks to replay paper Fig. 10).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import ops as gops
from repro.core.bfs import bfs, extract_path, multi_bfs
from repro.core.graph import GraphState, OpBatch, find_slot, find_slots, version_vector
from repro.obs import trace as _trace


class Collect(NamedTuple):
    found: jax.Array     # bool
    parent: jax.Array    # int32[V]
    touched: jax.Array   # bool[V]  — dependency set (expanded ∪ {src,dst})
    versions: jax.Array  # int32[V, 2] — (ecnt, vver) masked to touched
    src_slot: jax.Array  # int32
    dst_slot: jax.Array  # int32
    present: jax.Array   # bool — both endpoints alive at collect start


def collect(state: GraphState, k, l,
            backend: str | None = None) -> Collect:
    """One TreeCollect: locate endpoints (ConCPlus analogue), BFS, snapshot.

    ``backend=None`` resolves via ``core.bfs.default_backend()`` here,
    outside the jit boundary, so the resolved name is the static key."""
    from repro.core.bfs import _resolve_backend

    return _collect_jit(state, k, l, backend=_resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend",))
def _collect_jit(state: GraphState, k, l, backend: str) -> Collect:
    k = jnp.asarray(k, jnp.int32)
    l = jnp.asarray(l, jnp.int32)
    sk = find_slot(state, k)
    sl = find_slot(state, l)
    present = (sk >= 0) & (sl >= 0)
    res = bfs(state, sk, sl, backend=backend)
    v = state.capacity
    touched = res.expanded
    touched = touched.at[jnp.maximum(sk, 0)].set(touched[jnp.maximum(sk, 0)] | (sk >= 0))
    touched = touched.at[jnp.maximum(sl, 0)].set(touched[jnp.maximum(sl, 0)] | (sl >= 0))
    vv = jnp.where(touched[:, None], version_vector(state), jnp.int32(0))
    return Collect(res.found & present, res.parent, touched, vv, sk, sl, present)


@jax.jit
def compare_collects(a: Collect, b: Collect) -> jax.Array:
    """Paper's CompareTree + ComparePath, subsumed by version equality."""
    same_sets = jnp.all(a.touched == b.touched)
    same_vers = jnp.all(a.versions == b.versions)
    same_tree = jnp.all(jnp.where(a.touched, a.parent, -1) == jnp.where(b.touched, b.parent, -1))
    same_slots = (a.src_slot == b.src_slot) & (a.dst_slot == b.dst_slot)
    return (a.found == b.found) & (a.present == b.present) & same_sets & same_vers & same_tree & same_slots


class PathResult(NamedTuple):
    found: jax.Array   # bool — a path existed (linearizably)
    length: jax.Array  # int32 — number of vertices on the path (0 if none)
    keys: jax.Array    # int32[V] — vertex keys along the path, -1 padded
    rounds: jax.Array  # int32 — collects performed (>=2 in concurrent surfaces)
    starved: jax.Array = jnp.asarray(False)  # bool — double collect never
    # matched within the retry budget; the answer (if found is meaningful)
    # was resolved wait-free against one pinned epoch (DESIGN.md §13)


def _materialize(state: GraphState, c: Collect, rounds,
                 starved=False) -> PathResult:
    n, slots = extract_path(c.parent, c.src_slot, c.dst_slot)
    keys = jnp.where(slots >= 0, state.vkey[jnp.clip(slots, 0, state.capacity - 1)], -1)
    n = jnp.where(c.found, n, 0)
    keys = jnp.where(c.found, keys, -1)
    return PathResult(c.found, n, keys.astype(jnp.int32),
                      jnp.asarray(rounds, jnp.int32), jnp.asarray(starved))


def get_path(state: GraphState, k, l,
             backend: str | None = None) -> PathResult:
    """GetPath against a *static* state (pure function — no concurrency, so a
    single collect is trivially a valid double collect)."""
    from repro.core.bfs import _resolve_backend

    return _get_path_jit(state, k, l, backend=_resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend",))
def _get_path_jit(state: GraphState, k, l, backend: str) -> PathResult:
    c = collect(state, k, l, backend=backend)
    return _materialize(state, c, 1)


# ----------------------------------------------------------------------------
# Beyond-paper: batched multi-query GetPath under ONE shared double collect
# ----------------------------------------------------------------------------
def collect_batch(state, ks, ls, backend: str | None = None,
                  engine: str = "fused"):
    """Vectorized TreeCollect for Q query pairs. Returns a Collect whose
    leading axis is the query index; the dependency set / versions are the
    UNION over queries, so one version comparison validates all of them
    against the same pair of states — every answer linearizes at the same
    point (a consistent multi-query snapshot, strictly stronger than Q
    independent GetPaths and Q x cheaper in validation traffic).

    ``state`` may be a dense ``GraphState`` or a mesh-partitioned
    ``core.partition.ShardedGraphState`` (DESIGN.md §8): the traversal then
    runs the distributed fused engine (per-shard row products + one psum
    frontier exchange per superstep) and, because the validation metadata is
    replicated, the returned Collect is bit-identical to the dense one —
    ``compare_collect_batches`` and the whole double-collect session logic
    apply unchanged.

    ``engine`` picks the traversal (DESIGN.md §7):
      "fused" — ONE multi_bfs whose supersteps advance all Q frontiers with
                a single [Q,V] @ [V,V] frontier-matrix product (the
                adjacency is streamed once per superstep, not once per
                query). Production path.
      "vmap"  — Q independent single-query collects under jax.vmap. Kept as
                the cross-check reference: per-query results are identical
                by construction of multi_bfs (tests assert it).

    ``backend=None`` resolves via ``core.bfs.default_backend()`` here,
    outside the jit boundary, so the resolved name is the static key.

    Under the tracing recorder (DESIGN.md §14) the fused engine runs as a
    host-level composition — slot lookup, ``multi_bfs`` (whose traced form
    emits one ``bfs.superstep`` span per expansion), jitted finisher — so
    the per-superstep spans surface at the serving layer too. Results are
    bit-identical: same ops, only the jit boundary moves.
    """
    from repro.core.bfs import _is_tracer, _resolve_backend

    backend = _resolve_backend(backend)
    if (engine == "fused" and _trace.enabled()
            and not _is_tracer(state.valive)):
        ks = jnp.asarray(ks, jnp.int32)
        ls = jnp.asarray(ls, jnp.int32)
        from repro.core import partition
        from repro.core.partition import ShardedGraphState

        sk = find_slots(state, ks)
        sl = find_slots(state, ls)
        traverse = (partition.multi_bfs
                    if isinstance(state, ShardedGraphState) else multi_bfs)
        res = traverse(state, sk, sl, backend=backend)
        return _collect_batch_finish_jit(state, res, sk, sl)
    return _collect_batch_jit(state, ks, ls, backend=backend, engine=engine)


def _finish_collect_batch(state, res, sk, sl):
    """Touched-set/version bookkeeping after the fused traversal — shared
    by the end-to-end jit and the traced host path (DESIGN.md §14)."""
    present = (sk >= 0) & (sl >= 0)
    q = sk.shape[0]
    qi = jnp.arange(q)
    touched = res.expanded
    tk = jnp.maximum(sk, 0)
    tl = jnp.maximum(sl, 0)
    touched = touched.at[qi, tk].set(touched[qi, tk] | (sk >= 0))
    touched = touched.at[qi, tl].set(touched[qi, tl] | (sl >= 0))
    vv = jnp.where(touched[:, :, None], version_vector(state)[None], jnp.int32(0))
    return Collect(res.found & present, res.parent, touched, vv, sk, sl, present)


@jax.jit
def _collect_batch_finish_jit(state, res, sk, sl):
    return _finish_collect_batch(state, res, sk, sl)


@functools.partial(jax.jit, static_argnames=("backend", "engine"))
def _collect_batch_jit(state, ks, ls, backend: str, engine: str):
    from repro.core.partition import ShardedGraphState
    from repro.core import partition

    sharded = isinstance(state, ShardedGraphState)
    ks = jnp.asarray(ks, jnp.int32)
    ls = jnp.asarray(ls, jnp.int32)
    if engine == "vmap":
        dense = state.as_dense() if sharded else state
        return jax.vmap(lambda k, l: collect(dense, k, l, backend=backend))(ks, ls)
    if engine != "fused":
        raise ValueError(f"unknown collect_batch engine {engine!r}")
    sk = find_slots(state, ks)
    sl = find_slots(state, ls)
    traverse = partition.multi_bfs if sharded else multi_bfs
    res = traverse(state, sk, sl, backend=backend)
    return _finish_collect_batch(state, res, sk, sl)


@jax.jit
def compare_collect_batches(a, b) -> jax.Array:
    """True iff EVERY query's collect matches between the two rounds."""
    per_q = jax.vmap(compare_collects)(a, b)
    return jnp.all(per_q)


def _materialize_batch(state, cur, pairs, rounds):
    out = []
    for qi in range(len(pairs)):
        cq = jax.tree.map(lambda x: x[qi], cur)
        pr = _materialize(state, cq, rounds)
        keys = [int(x) for x in pr.keys[: int(pr.length)]] if bool(pr.found) else []
        out.append((bool(pr.found), keys))
    return out


def _session_stats(stats, *, rounds, starved, resolved, epoch):
    if stats is not None:
        stats.update(rounds=rounds, starved=starved, resolved=resolved,
                     epoch=epoch)


def get_paths_session(fetch_state, pairs, *, max_rounds: int | None = 16,
                      backend: str | None = None, engine: str = "fused",
                      on_conflict: str = "retry", fetch_epoch=None,
                      stats: dict | None = None):
    """Multi-query GetPath: the double-collect loop runs ONCE for the whole
    batch. Returns a list of (found, keys) per pair plus the round count.

    ``engine="fused"`` (default) drives every round through the fused
    multi-source BFS (one adjacency stream per superstep, DESIGN.md §7);
    ``engine="vmap"`` replays the reference per-query path.

    ``max_rounds`` bounds the retry loop (default 16; ``None`` restores the
    paper's unbounded obstruction-free loop, which a mutator committing
    every round starves forever — the PR-6 liveness hole). What happens at
    the budget is ``on_conflict`` (DESIGN.md §13):

      "retry" — give up: every pair reports (False, []) and the caller
                resubmits (the pre-ring capped-retry deviation);
      "epoch" — resolve WAIT-FREE: one final fetch pins a single published
                epoch — an immutable functional snapshot, so a single
                collect over it is trivially consistent (the static-state
                argument of ``get_path``) — and every answer linearizes at
                that epoch's publish point. ``fetch_epoch`` (a callable
                returning ``(epoch, state)``, e.g. the ingest pool's
                ``snapshot_epoch``) tags the pin; without it the resolution
                still terminates but the pinned epoch is unknown (None).

    ``stats`` (optional dict) receives {"rounds", "starved", "resolved",
    "epoch"} — the observability ServeStats aggregates.
    """
    if on_conflict not in ("retry", "epoch"):
        raise ValueError(f"unknown on_conflict mode {on_conflict!r}")
    ks = [p[0] for p in pairs]
    ls = [p[1] for p in pairs]
    with _trace.span("session.get_paths", pairs=len(pairs),
                     on_conflict=on_conflict) as _sp:
        state = fetch_state()
        with _trace.span("collect.round", round=1):
            prev = _trace.fence(
                collect_batch(state, ks, ls, backend=backend, engine=engine))
        rounds = 1
        while True:
            state = fetch_state()
            with _trace.span("collect.round", round=rounds + 1):
                cur = _trace.fence(
                    collect_batch(state, ks, ls, backend=backend,
                                  engine=engine))
            rounds += 1
            # a capacity grow between collects changes every row shape — by
            # definition an effective mutation, never a match (comparing would
            # be a shape error, not a False)
            if (prev.versions.shape == cur.versions.shape
                    and bool(compare_collect_batches(prev, cur))):
                _session_stats(stats, rounds=rounds, starved=False,
                               resolved="match", epoch=None)
                _sp.set(rounds=rounds, resolved="match")
                return _materialize_batch(state, cur, pairs, rounds), rounds
            prev = cur
            if max_rounds is not None and rounds >= max_rounds:
                if on_conflict == "epoch":
                    if fetch_epoch is not None:
                        epoch, state = fetch_epoch()
                    else:
                        epoch, state = None, fetch_state()
                    with _trace.span("collect.round", round=rounds + 1,
                                     pinned=True):
                        cur = _trace.fence(
                            collect_batch(state, ks, ls, backend=backend,
                                          engine=engine))
                    rounds += 1
                    _session_stats(stats, rounds=rounds, starved=True,
                                   resolved="epoch", epoch=epoch)
                    _sp.set(rounds=rounds, resolved="epoch")
                    return (_materialize_batch(state, cur, pairs, rounds),
                            rounds)
                _session_stats(stats, rounds=rounds, starved=True,
                               resolved="budget", epoch=None)
                _sp.set(rounds=rounds, resolved="budget")
                return [(False, []) for _ in pairs], rounds


# ----------------------------------------------------------------------------
# Host-level concurrent protocol (the paper's Scan loop)
# ----------------------------------------------------------------------------
def get_path_session(
    fetch_state: Callable[[], GraphState],
    k: int,
    l: int,
    max_rounds: int | None = 16,
    backend: str | None = None,
    *,
    on_conflict: str = "retry",
    fetch_epoch=None,
) -> PathResult:
    """The paper's GetPath/Scan against a live state reference.

    ``fetch_state()`` returns the mutator's latest published GraphState (the
    runtime swaps a reference; each fetch is a consistent functional snapshot,
    but consecutive fetches differ under concurrent mutation — exactly the
    adversary model of §3.5).

    Obstruction-free: terminates at the first pair of consecutive collects
    with no effective mutation in between. ``max_rounds=None`` restores the
    paper's unbounded loop (which a mutator committing every round starves
    forever); the default bounded budget ends with ``on_conflict``
    (DESIGN.md §13): "retry" returns found=False with ``starved=True`` (the
    caller resubmits — bounded-retry deviation, DESIGN.md §1); "epoch"
    resolves wait-free against one final pinned epoch fetch
    (``fetch_epoch`` — see ``get_paths_session``) and returns that epoch's
    answer with ``starved=True``.
    """
    if on_conflict not in ("retry", "epoch"):
        raise ValueError(f"unknown on_conflict mode {on_conflict!r}")
    state = fetch_state()
    prev = collect(state, k, l, backend=backend)
    rounds = 1
    while True:
        state = fetch_state()
        cur = collect(state, k, l, backend=backend)
        rounds += 1
        # capacity grow between collects = effective mutation (see
        # get_paths_session) — shapes differ, so comparing would crash
        if (prev.versions.shape == cur.versions.shape
                and bool(compare_collects(prev, cur))):
            res = _materialize(state, cur, rounds)
            return res
        prev = cur
        if max_rounds is not None and rounds >= max_rounds:
            if on_conflict == "epoch":
                state = fetch_epoch()[1] if fetch_epoch is not None \
                    else fetch_state()
                cur = collect(state, k, l, backend=backend)
                return _materialize(state, cur, rounds + 1, starved=True)
            v = state.capacity
            return PathResult(
                jnp.asarray(False), jnp.int32(0),
                jnp.full((v,), -1, jnp.int32), jnp.int32(rounds),
                jnp.asarray(True),
            )


# ----------------------------------------------------------------------------
# In-program interleaving (one jitted device program)
# ----------------------------------------------------------------------------
def interleaved_getpath(
    state: GraphState,
    batches: OpBatch,          # leading axis T: one mutation batch per round
    k,
    l,
    backend: str | None = None,
    engine: str = "fast",
):
    """Resolve ``backend=None`` outside the jit (static-key correctness)
    and run the jitted interleaving below."""
    from repro.core.bfs import _resolve_backend

    return _interleaved_getpath_jit(state, batches, k, l,
                                    backend=_resolve_backend(backend),
                                    engine=engine)


@functools.partial(jax.jit, static_argnames=("backend", "engine"))
def _interleaved_getpath_jit(
    state: GraphState,
    batches: OpBatch,
    k,
    l,
    backend: str,
    engine: str,
):
    """Run T rounds: (apply mutation batch t) then (advance the query).

    The query performs one collect per round and completes at the first round
    whose collect matches the previous round's. Returns
    (final_state, PathResult, per-round results of the mutation batches).
    This is the batch-granularity realization of 'threads running
    concurrently': mutator lanes and the query make progress in every round.
    """
    apply = gops.apply_ops_fast if engine == "fast" else gops.apply_ops
    c0 = collect(state, k, l, backend=backend)

    def step(carry, batch_t):
        st, prev, done, ans_c, done_round, rnd = carry
        st, res = apply(st, OpBatch(*batch_t))
        cur = collect(st, k, l, backend=backend)
        match = compare_collects(prev, cur) & ~done
        # freeze the answer at the first match
        ans_c = jax.tree.map(lambda a, b: jnp.where(match, b, a), ans_c, cur)
        done_round = jnp.where(match, rnd + 1, done_round)
        done = done | match
        return (st, cur, done, ans_c, done_round, rnd + 1), res

    carry0 = (state, c0, jnp.asarray(False), c0, jnp.int32(-1), jnp.int32(0))
    (state, last, done, ans, done_round, _), mut_results = jax.lax.scan(
        step, carry0, tuple(batches)
    )
    # If never matched within T rounds, report not-done (caller resubmits).
    ans = jax.tree.map(lambda a, b: jnp.where(done, a, b), ans, last)
    pr = _materialize(state, ans, jnp.where(done, done_round + 1, -1))
    pr = PathResult(pr.found & done, jnp.where(done, pr.length, 0), pr.keys,
                    pr.rounds, ~done)
    return state, pr, mut_results
