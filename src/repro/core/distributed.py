"""Row-sharded distributed graph: the paper's algorithm at cluster scale.

The vertex slot table and adjacency rows are sharded over a 1-D device mesh
axis (``rows``). Every device owns V/S contiguous slots (their adjacency rows,
keys, versions). The paper's operations map onto bulk-synchronous collectives:

  * lookup (LocV/LocC)    : local masked match + psum        (1 scalar AR)
  * edge/vertex mutation  : routed to the owner shard; owners apply locally
                            without coordination (disjoint-access parallelism
                            across the cluster = the lock-free property)
  * BFS superstep         : local tile mat-vec over owned rows + psum-OR of
                            the partial next frontier (+ min-combine parents)
  * double collect        : local (ecnt, vver) snapshots; validation is a
                            psum of mismatch counts — ONE scalar collective
                            per collect pair, so queries stay cheap relative
                            to traversal exactly as in the paper

Vertex placement: owner(key) = hash(key) mod S; each owner allocates from its
own slot range, so AddVertex never needs cross-shard coordination either.

This module is mesh-size agnostic: with one device it degenerates to the
single-pod engine (used by unit tests); tests/test_distributed.py re-runs the
suite under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in a
subprocess to exercise real sharding, and launch/dryrun.py lowers the same
code for the 256/512-chip production meshes.

This file keeps the fully-row-sharded engines (metadata AND adjacency
partitioned; owner-routed mutation). The production scale-out path is
``core.partition`` (DESIGN.md §8): adjacency rows sharded, version metadata
replicated, engines bit-identical to the dense ones. partition.py shares
this module's mesh axis (``AXIS``), row-block arithmetic
(``_row_block_info``) and jax-version shims (``shard_map`` import,
``_SM_NOCHECK``, ``_pvary``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

try:  # jax >= 0.6 exports shard_map at top level
    from jax import shard_map
except ImportError:  # jax 0.4.x keeps it in jax.experimental
    from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# "skip the replication/varying-manual-axes check" kwarg, renamed across jax
# versions (0.4.x: check_rep, >= 0.6: check_vma)
import inspect as _inspect

_HAS_VMA = "check_vma" in _inspect.signature(shard_map).parameters
_SM_NOCHECK = {"check_vma": False} if _HAS_VMA else {"check_rep": False}
# 0.4.x's check_rep cannot infer replication through fori_loop/switch at all;
# >= 0.6's VMA checker can and should stay ON where it passes (dapply_ops)
_SM_NOCHECK_LEGACY_ONLY = {} if _HAS_VMA else {"check_rep": False}

from repro.core.graph import (
    EMPTY_KEY,
    GraphState,
    OpBatch,
    pack_bits,
    pack_transpose,
    traversable,
    unpack_bits,
)
from repro.core import ops as gops

AXIS = "rows"


def make_graph_mesh(devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    import numpy as np

    return Mesh(np.asarray(devices), (AXIS,))


def shard_graph(mesh: Mesh, state: GraphState) -> GraphState:
    """Place a GraphState with rows sharded over the mesh axis."""
    row = NamedSharding(mesh, P(AXIS))
    mat = NamedSharding(mesh, P(AXIS, None))
    return GraphState(
        vkey=jax.device_put(state.vkey, row),
        valive=jax.device_put(state.valive, row),
        vver=jax.device_put(state.vver, row),
        ecnt=jax.device_put(state.ecnt, row),
        adj_packed=jax.device_put(state.adj_packed, mat),
        adj_in_packed=jax.device_put(state.adj_in_packed, mat),
    )


# ----------------------------------------------------------------------------
# Inside-shard_map helpers (operate on the LOCAL block; axis name in scope)
# ----------------------------------------------------------------------------
def _global_find(vkey_l, valive_l, keys, row0):
    """Global slot ids [B] for keys (replicated), -1 if absent anywhere."""
    hit = (vkey_l[None, :] == keys[:, None]) & valive_l[None, :] & (keys[:, None] >= 0)
    loc = jnp.argmax(hit, axis=1).astype(jnp.int32)
    has = jnp.any(hit, axis=1)
    mine = jnp.where(has, loc + row0, -1)
    return jax.lax.pmax(mine, AXIS)


def _pvary(x):
    """Mark a shard-replicated value as device-varying (no-op if it already is).

    jax < 0.6 has neither ``jax.typeof`` nor ``jax.lax.pvary`` (and no varying
    manual-axes check that would need them) — identity there.
    """
    pvary = getattr(jax.lax, "pvary", None)
    typeof = getattr(jax, "typeof", None)
    if pvary is None or typeof is None:
        return x
    vma = getattr(typeof(x), "vma", frozenset())
    return x if AXIS in vma else pvary(x, (AXIS,))


def _row_block_info(nrows_total, size):
    """(shard id, axis size, rows per shard, first owned row).

    ``size`` is the STATIC mesh-axis extent (callers pass mesh.shape[AXIS]):
    rows-per-shard feeds dynamic_slice sizes, which must be static, and
    jax 0.4.x has no ``jax.lax.axis_size`` to query it inside shard_map.
    """
    s = jax.lax.axis_index(AXIS)
    per = nrows_total // size
    return s, size, per, s * per


# ----------------------------------------------------------------------------
# Distributed BFS
# ----------------------------------------------------------------------------
def dbfs(mesh: Mesh, state: GraphState, src_slot, dst_slot):
    """Distributed BFS; returns (found, parent[V], dist[V], expanded[V], steps).

    Supersteps: each shard expands its OWNED frontier rows (local dense
    mat-vec over adj rows) and the partial next-frontiers are OR-combined
    with a psum — the standard BSP frontier exchange, here derived as the
    sharded form of the paper's TreeCollect.
    """
    v = state.capacity

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS, None), P(), P()),
        out_specs=(P(), P(), P(), P(), P()),
        # Outputs are value-replicated (every shard computes the full combined
        # frontier/parents), which the VMA analysis cannot infer past pvary.
        **_SM_NOCHECK,
    )
    def run(vkey_l, valive_l, adjw_l, src, dst):
        _, _, per, row0 = _row_block_info(v, mesh.shape[AXIS])
        alive_g = jax.lax.all_gather(valive_l, AXIS, tiled=True)  # bool[V]
        # legacy engine: dense local block, edge view via the ONE
        # traversable predicate (row-slice form, DESIGN.md §10)
        adj_l = traversable(unpack_bits(adjw_l, v), valive_l, alive_g)
        src_ok = (src >= 0) & alive_g[jnp.maximum(src, 0)]
        s = jnp.maximum(src, 0)
        frontier0 = jnp.zeros((v,), jnp.bool_).at[s].set(src_ok)
        visited0 = frontier0
        parent0 = jnp.full((v,), -1, jnp.int32)
        dist0 = jnp.where(frontier0, 0, -1).astype(jnp.int32)
        expanded0 = jnp.zeros((v,), jnp.bool_)
        # mark replicated initial carries as device-varying for the VMA check
        frontier0, visited0, parent0, dist0, expanded0 = jax.tree.map(
            _pvary, (frontier0, visited0, parent0, dist0, expanded0)
        )

        def cond(c):
            frontier, visited, parent, dist, expanded, step = c
            hit = (dst >= 0) & visited[jnp.maximum(dst, 0)]
            return jnp.any(frontier) & ~hit & (step < v)

        def body(c):
            frontier, visited, parent, dist, expanded, step = c
            expanded = expanded | frontier
            f_mine = jax.lax.dynamic_slice(frontier, (row0,), (per,))
            fa = f_mine.astype(jnp.float32)
            reach_part = (fa @ adj_l.astype(jnp.float32)) > 0
            idx = (jnp.arange(per, dtype=jnp.int32) + row0)[:, None]
            cand = jnp.where(f_mine[:, None] & adj_l, idx, jnp.int32(2**31 - 1))
            par_part = jnp.min(cand, axis=0)
            reach = jax.lax.psum(reach_part.astype(jnp.int32), AXIS) > 0
            parent_new = jax.lax.pmin(par_part, AXIS)
            new = reach & alive_g & ~visited
            parent = jnp.where(new, parent_new, parent)
            dist = jnp.where(new, step + 1, dist)
            visited = visited | new
            return new, visited, parent, dist, expanded, step + 1

        frontier, visited, parent, dist, expanded, steps = jax.lax.while_loop(
            cond, body, (frontier0, visited0, parent0, dist0, expanded0, jnp.int32(0))
        )
        found = (dst >= 0) & visited[jnp.maximum(dst, 0)] & src_ok
        return found, parent, dist, expanded, steps

    return run(
        state.vkey, state.valive, state.adj_packed,
        jnp.asarray(src_slot, jnp.int32), jnp.asarray(dst_slot, jnp.int32),
    )


# ----------------------------------------------------------------------------
# Distributed mutation batches (owner-routed)
# ----------------------------------------------------------------------------
def dapply_ops(mesh: Mesh, state: GraphState, ops: OpBatch):
    """Apply an op batch to the sharded graph, lane order = linearization.

    Ownership: a mutation's *home* is the owner of its source-vertex row
    (edge ops: key1's slot; AddVertex: hash owner). Owners apply their lanes
    locally; cross-shard information (the dst slot id of an edge, endpoint
    aliveness) is resolved with replicated lookups before application, and
    endpoint-aliveness races across shards are checked again at apply time
    (the Figure-6 recheck of the paper, here a second replicated read).
    """
    v = state.capacity
    b = ops.lanes

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS, None),
                  P(), P(), P(), P()),
        out_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS, None), P()),
        # jax 0.4.x's replication checker cannot infer through the
        # fori_loop/switch lattice here (newer jax's VMA checker can, and
        # stays enabled); the outputs are correct by the psum/pmax combines.
        **_SM_NOCHECK_LEGACY_ONLY,
    )
    def run(vkey_l, valive_l, vver_l, ecnt_l, adjw_l, opc, k1, k2, expect):
        sid, ssize, per, row0 = _row_block_info(v, mesh.shape[AXIS])
        # legacy engine: run the lane loop on the dense local block, repack
        # at the boundary (the production packed engines live in partition.py)
        adj_l = unpack_bits(adjw_l, v).astype(jnp.uint8)

        def body(i, carry):
            vkey_l, valive_l, vver_l, ecnt_l, adj_l, res = carry
            op, a, bk, exp = opc[i], k1[i], k2[i], expect[i]
            s1 = _global_find(vkey_l, valive_l, jnp.array([a]), row0)[0]
            s2 = _global_find(vkey_l, valive_l, jnp.array([bk]), row0)[0]
            alive_any = jnp.ones((), jnp.bool_)

            # --- AddVertex (owner = hash) ---------------------------------
            owner = jnp.abs(a) % ssize
            free_l = vkey_l == EMPTY_KEY
            have_free = jnp.any(free_l)
            new_loc = jnp.argmax(free_l).astype(jnp.int32)
            addv_mine = (op == 1) & (owner == sid) & (s1 < 0) & have_free
            tgt = jnp.where(addv_mine, new_loc, per)
            vkey_l = vkey_l.at[tgt].set(a, mode="drop")
            valive_l = valive_l.at[tgt].set(True, mode="drop")
            vver_l = vver_l.at[tgt].add(1, mode="drop")
            ecnt_l = ecnt_l.at[tgt].set(0, mode="drop")
            adj_l = adj_l.at[tgt, :].set(0, mode="drop")
            # clear the column for the reused slot globally
            col_clear = jax.lax.pmax(jnp.where(addv_mine, new_loc + row0, -1), AXIS)
            adj_l = jnp.where(col_clear >= 0, adj_l.at[:, jnp.maximum(col_clear, 0)].set(0), adj_l)
            r_addv = jnp.where(s1 >= 0, 0, jnp.where(jax.lax.pmax(addv_mine.astype(jnp.int32), AXIS) > 0, 1, 7))

            # --- RemoveVertex (owner = slot owner) -------------------------
            remv = (op == 2) & (s1 >= 0)
            loc1 = s1 - row0
            mine1 = (loc1 >= 0) & (loc1 < per)
            t = jnp.where(remv & mine1, loc1, per)
            valive_l = valive_l.at[t].set(False, mode="drop")
            vver_l = vver_l.at[t].add(1, mode="drop")
            ecnt_l = ecnt_l.at[t].add(1, mode="drop")
            # bump local in-edge sources of the removed column
            col = jnp.maximum(s1, 0)
            bump = remv & (adj_l[:, col] > 0) & valive_l
            ecnt_l = ecnt_l + bump.astype(jnp.int32)
            r_remv = jnp.where(s1 >= 0, 1, 0)

            # --- Contains --------------------------------------------------
            r_conv = jnp.where(s1 >= 0, 1, 0)

            # --- Edge ops (owner = key1 slot owner) -------------------------
            both = (s1 >= 0) & (s2 >= 0)
            e_mine = mine1 & both
            er, ec = jnp.where(e_mine, loc1, per), jnp.maximum(s2, 0)
            cur_mine = adj_l[jnp.minimum(er, per - 1), ec] > 0
            cur = jax.lax.pmax(jnp.where(e_mine, cur_mine.astype(jnp.int32), 0), AXIS) > 0
            my_ecnt = ecnt_l[jnp.minimum(jnp.where(mine1, loc1, 0), per - 1)]
            src_ecnt = jax.lax.pmax(jnp.where(mine1 & (s1 >= 0), my_ecnt, -(2**31)), AXIS)
            cas_ok = (exp < 0) | (src_ecnt == exp)
            do_add = (op == 4) & both & cas_ok & ~cur
            do_rem = (op == 5) & both & cas_ok & cur
            et = jnp.where((do_add | do_rem) & e_mine, er, per)
            adj_l = adj_l.at[et, ec].set(jnp.where(do_add, 1, 0).astype(adj_l.dtype), mode="drop")
            ecnt_l = ecnt_l.at[et].add(1, mode="drop")
            r_adde = jnp.where(both, jnp.where(cas_ok, jnp.where(cur, 4, 5), 8), 2)
            r_reme = jnp.where(both, jnp.where(cas_ok, jnp.where(cur, 6, 3), 8), 2)
            r_cone = jnp.where(both, jnp.where(cur, 4, 3), 2)

            r = jax.lax.switch(
                jnp.clip(op, 0, 6),
                [lambda: jnp.int32(0), lambda: r_addv.astype(jnp.int32), lambda: r_remv.astype(jnp.int32),
                 lambda: r_conv.astype(jnp.int32), lambda: r_adde.astype(jnp.int32),
                 lambda: r_reme.astype(jnp.int32), lambda: r_cone.astype(jnp.int32)],
            )
            res = res.at[i].set(r)
            return vkey_l, valive_l, vver_l, ecnt_l, adj_l, res

        res0 = jnp.zeros((b,), jnp.int32)
        vkey_l, valive_l, vver_l, ecnt_l, adj_l, res = jax.lax.fori_loop(
            0, b, body, (vkey_l, valive_l, vver_l, ecnt_l, adj_l, res0))
        return (vkey_l, valive_l, vver_l, ecnt_l,
                pack_bits(adj_l.astype(jnp.bool_)), res)

    vkey, valive, vver, ecnt, adj, res = run(
        state.vkey, state.valive, state.vver, state.ecnt, state.adj_packed,
        ops.opcode, ops.key1, ops.key2, ops.expect,
    )
    # Legacy engine: the lane loop mutates only the dense out-rows; the
    # maintained in-adjacency is restored by one packed transpose at the
    # boundary (the production partition.py engine mirrors every RMW
    # in place instead, DESIGN.md §11).
    adj_in = pack_transpose(adj, state.capacity)
    return GraphState(vkey, valive, vver, ecnt, adj, adj_in), res


# ----------------------------------------------------------------------------
# Distributed double collect (GetPath)
# ----------------------------------------------------------------------------
class DCollect(NamedTuple):
    found: jax.Array
    parent: jax.Array
    touched: jax.Array
    ver_ecnt: jax.Array
    ver_vver: jax.Array
    src_slot: jax.Array
    dst_slot: jax.Array


def dcollect(mesh: Mesh, state: GraphState, k, l) -> DCollect:
    keys = jnp.asarray([k, l], jnp.int32)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P()),
        out_specs=(P(), P()),
    )
    def lookup(vkey_l, valive_l, ks):
        _, _, per, row0 = _row_block_info(state.capacity, mesh.shape[AXIS])
        s = _global_find(vkey_l, valive_l, ks, row0)
        return s[0], s[1]

    sk, sl = lookup(state.vkey, state.valive, keys)
    found, parent, dist, expanded, steps = dbfs(mesh, state, sk, sl)
    touched = expanded
    touched = touched.at[jnp.maximum(sk, 0)].set(touched[jnp.maximum(sk, 0)] | (sk >= 0))
    touched = touched.at[jnp.maximum(sl, 0)].set(touched[jnp.maximum(sl, 0)] | (sl >= 0))
    # Version snapshot stays SHARDED — no gather; compare is local + psum.
    return DCollect(found, parent, touched, state.ecnt, state.vver, sk, sl)


def dcompare(mesh: Mesh, a: DCollect, b: DCollect) -> jax.Array:
    """Validation = ONE scalar psum over local mismatch counts."""
    v = a.parent.shape[0]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(), P()),
        out_specs=P(),
    )
    def vers_mismatch(ea, eb, va, vb, ta, tb):
        _, _, per, row0 = _row_block_info(v, mesh.shape[AXIS])
        t_a = jax.lax.dynamic_slice(ta, (row0,), (per,))
        t_b = jax.lax.dynamic_slice(tb, (row0,), (per,))
        bad = (t_a != t_b) | (t_a & ((ea != eb) | (va != vb)))
        return jax.lax.psum(jnp.sum(bad.astype(jnp.int32)), AXIS)

    mism = vers_mismatch(a.ver_ecnt, b.ver_ecnt, a.ver_vver, b.ver_vver, a.touched, b.touched)
    same_tree = jnp.all(jnp.where(a.touched, a.parent, -1) == jnp.where(b.touched, b.parent, -1))
    return (
        (a.found == b.found)
        & (a.src_slot == b.src_slot)
        & (a.dst_slot == b.dst_slot)
        & (mism == 0)
        & same_tree
    )


def dget_path_session(mesh, fetch_state, k, l, max_rounds: int = 64):
    """Distributed GetPath: host-level double-collect loop (see snapshot.py)."""
    from repro.core.bfs import extract_path

    prev_state = fetch_state()
    prev = dcollect(mesh, prev_state, k, l)
    rounds = 1
    while rounds < max_rounds:
        st = fetch_state()
        cur = dcollect(mesh, st, k, l)
        rounds += 1
        if bool(dcompare(mesh, prev, cur)):
            n, slots = extract_path(cur.parent, cur.src_slot, cur.dst_slot)
            keys = jnp.where(slots >= 0, st.vkey[jnp.clip(slots, 0, st.capacity - 1)], -1)
            ok = bool(cur.found)
            return ok, (int(n) if ok else 0), ([int(x) for x in keys[: int(n)]] if ok else []), rounds
        prev, prev_state = cur, st
    return False, 0, [], rounds
