"""Sequential Python oracle — the executable sequential specification.

Used by property tests: a concurrent (batched) execution is linearizable iff
its results and final state equal the oracle's when ops are replayed in the
claimed linearization order (lane order for ``apply_ops``; see
tests/test_linearizability.py for the fast engine's commutation argument).
"""
from __future__ import annotations

from collections import deque

from repro.core.graph import (
    OP_ADD_E,
    OP_ADD_V,
    OP_CON_E,
    OP_CON_V,
    OP_NOP,
    OP_REM_E,
    OP_REM_V,
    R_CAS_FAIL,
    R_EDGE_ADDED,
    R_EDGE_NOT_PRESENT,
    R_EDGE_PRESENT,
    R_EDGE_REMOVED,
    R_FALSE,
    R_TABLE_FULL,
    R_TRUE,
    R_VERTEX_NOT_PRESENT,
)


class GraphOracle:
    """Reference implementation over Python dict/set with identical semantics
    (result codes, ecnt evolution, slot-occupancy capacity accounting)."""

    def __init__(self, capacity: int = 1 << 30):
        self.capacity = capacity
        self.ecnt: dict[int, int] = {}     # alive vertices -> ecnt
        self.edges: set[tuple[int, int]] = set()
        self.occupied = 0                  # alive + dead-uncompacted slots

    # -- vertex ops ----------------------------------------------------------
    def add_vertex(self, k: int) -> int:
        if k in self.ecnt:
            return R_FALSE
        if self.occupied >= self.capacity:
            return R_TABLE_FULL
        self.ecnt[k] = 0
        self.occupied += 1
        return R_TRUE

    def remove_vertex(self, k: int) -> int:
        if k not in self.ecnt:
            return R_FALSE
        # bump in-edge sources (incl. self-loop source) — see ops._remove_vertex
        for (u, w) in list(self.edges):
            if w == k and u in self.ecnt:
                self.ecnt[u] += 1
        del self.ecnt[k]
        self.edges = {(u, w) for (u, w) in self.edges if u != k and w != k}
        return R_TRUE

    def contains_vertex(self, k: int) -> int:
        return R_TRUE if k in self.ecnt else R_FALSE

    # -- edge ops --------------------------------------------------------------
    def add_edge(self, k: int, l: int, expect: int = -1) -> int:
        if k not in self.ecnt or l not in self.ecnt:
            return R_VERTEX_NOT_PRESENT
        if expect >= 0 and self.ecnt[k] != expect:
            return R_CAS_FAIL
        if (k, l) in self.edges:
            return R_EDGE_PRESENT
        self.edges.add((k, l))
        self.ecnt[k] += 1
        return R_EDGE_ADDED

    def remove_edge(self, k: int, l: int, expect: int = -1) -> int:
        if k not in self.ecnt or l not in self.ecnt:
            return R_VERTEX_NOT_PRESENT
        if expect >= 0 and self.ecnt[k] != expect:
            return R_CAS_FAIL
        if (k, l) not in self.edges:
            return R_EDGE_NOT_PRESENT
        self.edges.discard((k, l))
        self.ecnt[k] += 1
        return R_EDGE_REMOVED

    def contains_edge(self, k: int, l: int) -> int:
        if k not in self.ecnt or l not in self.ecnt:
            return R_VERTEX_NOT_PRESENT
        return R_EDGE_PRESENT if (k, l) in self.edges else R_EDGE_NOT_PRESENT

    def compact(self) -> None:
        self.occupied = len(self.ecnt)

    # -- batch replay -----------------------------------------------------------
    def apply(self, opcode: int, k1: int, k2: int, expect: int = -1) -> int:
        if opcode == OP_NOP:
            return R_FALSE
        if opcode == OP_ADD_V:
            return self.add_vertex(k1)
        if opcode == OP_REM_V:
            return self.remove_vertex(k1)
        if opcode == OP_CON_V:
            return self.contains_vertex(k1)
        if opcode == OP_ADD_E:
            return self.add_edge(k1, k2, expect)
        if opcode == OP_REM_E:
            return self.remove_edge(k1, k2, expect)
        if opcode == OP_CON_E:
            return self.contains_edge(k1, k2)
        raise ValueError(f"bad opcode {opcode}")

    def apply_batch(self, ops) -> list[int]:
        """ops: iterable of (opcode, k1, k2, expect)."""
        return [self.apply(*op) for op in ops]

    # -- queries ------------------------------------------------------------------
    def reachable(self, k: int, l: int) -> bool:
        if k not in self.ecnt or l not in self.ecnt:
            return False
        seen = {k}
        dq = deque([k])
        while dq:
            u = dq.popleft()
            if u == l:
                return True
            for (a, b) in self.edges:
                if a == u and b not in seen and b in self.ecnt:
                    seen.add(b)
                    dq.append(b)
        return False

    def shortest_path_len(self, k: int, l: int) -> int:
        """#vertices on a shortest path, 0 if unreachable."""
        if k not in self.ecnt or l not in self.ecnt:
            return 0
        dist = {k: 1}
        dq = deque([k])
        while dq:
            u = dq.popleft()
            if u == l:
                return dist[u]
            for (a, b) in self.edges:
                if a == u and b not in dist and b in self.ecnt:
                    dist[b] = dist[u] + 1
                    dq.append(b)
        return 0

    def is_valid_path(self, keys: list[int], k: int, l: int) -> bool:
        """Is ``keys`` a path k..l through current edges? (path-validity check)"""
        if not keys or keys[0] != k or keys[-1] != l:
            return False
        for a in keys:
            if a not in self.ecnt:
                return False
        return all((a, b) in self.edges for a, b in zip(keys, keys[1:]))

    # -- state comparison -----------------------------------------------------------
    def state_tuple(self):
        return (dict(self.ecnt), set(self.edges))
