"""Device-resident dynamic directed graph state — the TPU-native analogue of the
paper's linked-list-of-linked-lists adjacency structure.

The paper (Chatterjee et al. 2018) stores the graph as a sorted lock-free
vertex-list where each VNode roots a sorted lock-free edge-list, and uses
marked pointers (bit-stolen CAS descriptors) for logical removal plus a
per-vertex modification counter ``ecnt`` to validate double-collect snapshots.

On a TPU there are no pointers or CAS; the same *logical* state is held in
dense, tiled device arrays:

  vkey[V]   : key occupying each slot (EMPTY_KEY if slot free) — the VNode key
  valive[V] : logical presence (True = unmarked VNode, False = "marked")
  vver[V]   : slot epoch, bumped on every vertex add AND logical remove.
              Plays the role the memory allocator plays in the paper (fresh
              address per allocation => no ABA); a (slot, vver) pair is the
              analogue of a unique VNode address.
  ecnt[V]   : the paper's ``ecnt`` — bumped by every edge add/remove whose
              source row is this vertex, and by logical vertex removal.
  adj_packed[V, ceil(V/32)] : WORD-PACKED adjacency (DESIGN.md §10): bit
              ``c % 32`` of word ``adj_packed[r, c // 32]`` is 1 iff edge
              slot_r -> slot_c. One ENode costs exactly one bit — the same
              budget the paper pays per edge — instead of the float32 lane a
              dense matmul operand would occupy; bits at column positions
              >= V in the last word are always zero (the padding invariant
              every mutation preserves). The edge-list of v is row r; an
              ENode's ``ptv`` is implicit (bit position), and "ENode marked"
              is a cleared bit. Engines that want the float32 MXU path
              unpack on the fly (``GraphState.adj``); the packed engines
              stream the words directly (~32x less adjacency HBM traffic).
  adj_in_packed[V, ceil(V/32)] : the word-packed IN-adjacency (DESIGN.md
              §11): bit ``w % 32`` of word ``adj_in_packed[v, w // 32]`` is
              1 iff edge slot_w -> slot_v — row v is v's incoming-edge
              list. Maintained FIRST-CLASS by every mutation path (the same
              masked single-bit RMWs as ``adj_packed``, mirrored), never
              derived by a transpose: ``adj_in_packed == pack_transpose(
              adj_packed)`` is the transpose invariant
              (``transpose_invariant`` checks it; the hybrid BFS pull step
              and the index's backward closures depend on it). This is the
              TPU analogue of the incoming-edge structure Chatterjee et
              al.'s dynamic-graph follow-up keeps per vertex so reverse
              traversals never re-walk the whole structure.

"Unbounded" growth is functional capacity doubling (``grow``), amortized like
a vector; the paper's unboundedness is heap allocation, ours is reallocation.
Logical vertex removal leaves the adjacency row/column in place (the paper's
optimization of leaving ENodes whose ``ptv`` is marked); ``core.ops.compact``
is the physical-removal / helping analogue.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

# ----------------------------------------------------------------------------
# Constants
# ----------------------------------------------------------------------------
EMPTY_KEY = jnp.int32(-1)

# Packed-adjacency word width (DESIGN.md §10). uint32 words: the native VPU
# lane width, and the dtype jax.lax.population_count / shifts handle on every
# backend.
WORD_BITS = 32


def packed_width(v: int) -> int:
    """Words per packed row/bitset: ceil(v / 32)."""
    return -(-int(v) // WORD_BITS)


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack a boolean bitset along the last axis: bool[..., V] -> uint32[..., W].

    Bit ``c % 32`` of word ``c // 32`` holds ``bits[..., c]``; pad bits past
    V are zero (the packing invariant, DESIGN.md §10).
    """
    v = bits.shape[-1]
    w = packed_width(v)
    pad = w * WORD_BITS - v
    b = bits.astype(jnp.uint32)
    if pad:
        b = jnp.pad(b, [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    b = b.reshape(bits.shape[:-1] + (w, WORD_BITS))
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    # bits within a word are disjoint, so the sum IS the bitwise OR
    return jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, v: int) -> jax.Array:
    """Inverse of ``pack_bits``: uint32[..., W] -> bool[..., v]."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(words.shape[:-1] + (words.shape[-1] * WORD_BITS,))
    return flat[..., :v].astype(jnp.bool_)


def pack_transpose(words: jax.Array, v: int) -> jax.Array:
    """Packed transpose: uint32[V, W] -> uint32[V, W] with bit (r, c) moved
    to (c, r). Unpack -> T -> repack — O(V^2) transient, which is exactly
    why the in-adjacency is MAINTAINED rather than derived (DESIGN.md §11);
    this helper exists for the transpose-invariant checker, oracles and the
    legacy boundary in core/distributed.py."""
    return pack_bits(unpack_bits(words, v).T)


def bit_word(col):
    """Word index of column ``col`` (int32 in, int32 out)."""
    return jnp.asarray(col, jnp.int32) // WORD_BITS


def bit_mask(col):
    """Single-bit uint32 mask for column ``col``."""
    return jnp.uint32(1) << (jnp.asarray(col, jnp.int32) % WORD_BITS).astype(jnp.uint32)


def get_bit(words: jax.Array, row, col) -> jax.Array:
    """Bool: is bit (row, col) set in a packed matrix uint32[R, W]."""
    return (words[row, bit_word(col)] & bit_mask(col)) > 0


def popcount(words: jax.Array) -> jax.Array:
    """Per-word set-bit count, int32 (same shape as ``words``)."""
    return jax.lax.population_count(words).astype(jnp.int32)


def or_reduce(words: jax.Array, axis: int) -> jax.Array:
    """Bitwise-OR reduction of uint32 words along ``axis``.

    Implemented as a static halving fold (log2 vectorized ORs) — XLA has no
    native OR-reduce, and a fori_loop would serialize the row dimension the
    packed BFS superstep reduces over.
    """
    x = jnp.moveaxis(words, axis, 0)
    n = x.shape[0]
    if n == 0:
        return jnp.zeros(x.shape[1:], jnp.uint32)
    p = 1
    while p < n:
        p *= 2
    if p != n:
        x = jnp.concatenate(
            [x, jnp.zeros((p - n,) + x.shape[1:], x.dtype)], axis=0)
    while p > 1:
        p //= 2
        x = x[:p] | x[p:2 * p]
    return x[0]


# ----------------------------------------------------------------------------
# THE traversable-edge predicate (DESIGN.md §1, §10)
# ----------------------------------------------------------------------------
def traversable(adj, alive_src, alive_dst=None):
    """The ONE traversable-edge predicate: edge u -> w is logically present
    iff ``adj[u, w] & alive[u] & alive[w]`` — a dead endpoint makes the
    ENode absent, exactly the paper's marked-ptv rule.

    Every jnp-level edge view — dense AND sharded BFS (core/bfs.py,
    core/partition.py, core/distributed.py), num_edges/degree/neighbors,
    and hence the BFS-inherited index closures — derives from this helper
    (or ``traversable_packed``) so the predicate cannot drift between
    re-implementations; the Pallas kernels stream raw tiles and apply the
    identical mask in their epilogue (their documented contract).
    tests/test_packed.py pins all call sites differentially.

    adj: (u)int/bool [R, V] (R = V, or a contiguous row slice of a sharded
    state); alive_src: bool[R] liveness of the row slice; alive_dst: bool[V]
    (defaults to ``alive_src``, valid only when R == V). Returns bool[R, V].
    """
    if alive_dst is None:
        alive_dst = alive_src
    return (adj > 0) & alive_src[:, None] & alive_dst[None, :]


def traversable_packed(adj_packed, alive_src, alive_dst_words):
    """``traversable`` on packed words: uint32[R, W] of live edge bits.

    alive_dst_words is the packed destination-liveness bitset
    (``pack_bits(alive)``); dead rows contribute all-zero words.
    """
    return jnp.where(alive_src[:, None],
                     adj_packed & alive_dst_words[None, :], jnp.uint32(0))

# Op codes for batched operations (structure-of-arrays op batches).
OP_NOP = 0
OP_ADD_V = 1
OP_REM_V = 2
OP_CON_V = 3
OP_ADD_E = 4
OP_REM_E = 5
OP_CON_E = 6

OPCODE_NAMES = {
    OP_NOP: "NOP",
    OP_ADD_V: "AddV",
    OP_REM_V: "RemV",
    OP_CON_V: "HasV",
    OP_ADD_E: "AddE",
    OP_REM_E: "RemE",
    OP_CON_E: "HasE",
}

# Result codes — the paper's indicative strings, as integers.
R_PENDING = -1
R_FALSE = 0                 # vertex ops: false
R_TRUE = 1                  # vertex ops: true
R_VERTEX_NOT_PRESENT = 2    # "VERTEX NOT PRESENT"
R_EDGE_NOT_PRESENT = 3      # "EDGE NOT PRESENT"
R_EDGE_PRESENT = 4          # "EDGE PRESENT" / "EDGE FOUND"
R_EDGE_ADDED = 5            # "EDGE ADDED"
R_EDGE_REMOVED = 6          # "EDGE REMOVED"
R_TABLE_FULL = 7            # out of slots — host must grow() and resubmit
R_CAS_FAIL = 8              # versioned op saw a stale ecnt (CAS-failure analogue)
R_RECOVERING = 9            # server-side typed rejection: write refused while
                            # the pool restarts from WAL+checkpoint (DESIGN.md §16)

RESULT_NAMES = {
    R_PENDING: "PENDING",
    R_FALSE: "false",
    R_TRUE: "true",
    R_VERTEX_NOT_PRESENT: "VERTEX NOT PRESENT",
    R_EDGE_NOT_PRESENT: "EDGE NOT PRESENT",
    R_EDGE_PRESENT: "EDGE PRESENT",
    R_EDGE_ADDED: "EDGE ADDED",
    R_EDGE_REMOVED: "EDGE REMOVED",
    R_TABLE_FULL: "TABLE FULL",
    R_CAS_FAIL: "CAS FAIL",
    R_RECOVERING: "RECOVERING",
}


class GraphState(NamedTuple):
    """Dense dynamic graph state. All fields are device arrays.

    Adjacency is STORED word-packed (``adj_packed``, DESIGN.md §10); the
    ``adj`` property materializes the uint8[V, V] dense view for engines
    that choose the float32-MXU expansion path (a transient — the packed
    words remain the only persistent O(V^2/32) representation).
    """

    vkey: jax.Array           # int32[V]
    valive: jax.Array         # bool[V]
    vver: jax.Array           # int32[V]
    ecnt: jax.Array           # int32[V]
    adj_packed: jax.Array     # uint32[V, ceil(V/32)]  (out-edges, row-major)
    adj_in_packed: jax.Array  # uint32[V, ceil(V/32)]  (in-edges, DESIGN.md §11)

    @property
    def capacity(self) -> int:
        return self.vkey.shape[0]

    @property
    def words(self) -> int:
        """Packed words per adjacency row: ceil(capacity / 32)."""
        return self.adj_packed.shape[1]

    @property
    def adj(self) -> jax.Array:
        """Dense uint8[V, V] adjacency view (unpacked on demand)."""
        return unpack_bits(self.adj_packed, self.capacity).astype(jnp.uint8)

    @property
    def adj_in(self) -> jax.Array:
        """Dense uint8[V, V] in-adjacency view: adj_in[v, w] = adj[w, v]."""
        return unpack_bits(self.adj_in_packed, self.capacity).astype(jnp.uint8)

    @property
    def alive_words(self) -> jax.Array:
        """Packed liveness bitset uint32[W] (for ``traversable_packed``)."""
        return pack_bits(self.valive)


class OpBatch(NamedTuple):
    """A batch of B operations from B logical actors ("threads").

    Lane order is the linearization order (see core.ops). ``expect`` >= 0
    turns the op into a compare-and-set on the source vertex's ``ecnt``.
    """

    opcode: jax.Array  # int32[B]
    key1: jax.Array    # int32[B]
    key2: jax.Array    # int32[B]  (edge target; ignored by vertex ops)
    expect: jax.Array  # int32[B]  (-1 = unconditional)

    @property
    def lanes(self) -> int:
        return self.opcode.shape[0]


# ----------------------------------------------------------------------------
# Construction / growth
# ----------------------------------------------------------------------------
def make_graph(capacity: int = 256) -> GraphState:
    """Fresh empty graph with the given slot capacity."""
    v = int(capacity)
    return GraphState(
        vkey=jnp.full((v,), EMPTY_KEY, dtype=jnp.int32),
        valive=jnp.zeros((v,), dtype=jnp.bool_),
        vver=jnp.zeros((v,), dtype=jnp.int32),
        ecnt=jnp.zeros((v,), dtype=jnp.int32),
        adj_packed=jnp.zeros((v, packed_width(v)), dtype=jnp.uint32),
        adj_in_packed=jnp.zeros((v, packed_width(v)), dtype=jnp.uint32),
    )


def grow(state: GraphState, new_capacity: int) -> GraphState:
    """Functionally grow capacity (the 'unbounded' part of the paper's title).

    Amortized O(V^2/32) like a vector doubling; existing slots, versions and
    edges are preserved, new slots are free. Packed rows grow in place: a
    column's (word, bit) address depends only on the column index, and the
    padding invariant guarantees the bits the new columns move into were
    zero (DESIGN.md §10).
    """
    old = state.capacity
    if new_capacity <= old:
        return state
    pad = new_capacity - old
    wpad = packed_width(new_capacity) - state.words
    return GraphState(
        vkey=jnp.concatenate([state.vkey, jnp.full((pad,), EMPTY_KEY, jnp.int32)]),
        valive=jnp.concatenate([state.valive, jnp.zeros((pad,), jnp.bool_)]),
        vver=jnp.concatenate([state.vver, jnp.zeros((pad,), jnp.int32)]),
        ecnt=jnp.concatenate([state.ecnt, jnp.zeros((pad,), jnp.int32)]),
        adj_packed=jnp.pad(state.adj_packed, ((0, pad), (0, wpad))),
        adj_in_packed=jnp.pad(state.adj_in_packed, ((0, pad), (0, wpad))),
    )


def make_op_batch(ops, lanes: int | None = None) -> OpBatch:
    """Build an OpBatch from a python list of (opcode, k1[, k2[, expect]])."""
    import numpy as np

    b = lanes if lanes is not None else len(ops)
    opc = np.zeros((b,), np.int32)
    k1 = np.full((b,), -1, np.int32)
    k2 = np.full((b,), -1, np.int32)
    exp = np.full((b,), -1, np.int32)
    for i, op in enumerate(ops):
        opc[i] = op[0]
        if len(op) > 1:
            k1[i] = op[1]
        if len(op) > 2:
            k2[i] = op[2]
        if len(op) > 3:
            exp[i] = op[3]
    return OpBatch(jnp.asarray(opc), jnp.asarray(k1), jnp.asarray(k2), jnp.asarray(exp))


# ----------------------------------------------------------------------------
# Lookups (the LocV / LocC analogues)
# ----------------------------------------------------------------------------
def find_slot(state: GraphState, key: jax.Array) -> jax.Array:
    """Slot index of the *alive* vertex with ``key``; -1 if absent.

    This is the LocC/LocV analogue. The paper traverses a sorted list; here
    membership is a single vectorized compare over the slot table — bounded
    work, hence the wait-free-lookup property (paper Thm 4.2(i)) is trivially
    inherited.
    """
    hit = (state.vkey == key) & state.valive
    # At most one alive slot holds a key (ops.py maintains this invariant).
    idx = jnp.argmax(hit)
    return jnp.where(jnp.any(hit), idx.astype(jnp.int32), jnp.int32(-1))


def find_slots(state: GraphState, keys: jax.Array) -> jax.Array:
    """Vectorized find_slot for a key vector [B] -> slot ids [B] (-1 absent)."""
    hit = (state.vkey[None, :] == keys[:, None]) & state.valive[None, :]
    idx = jnp.argmax(hit, axis=1)
    return jnp.where(jnp.any(hit, axis=1), idx.astype(jnp.int32), jnp.int32(-1))


def contains_vertex(state: GraphState, key) -> jax.Array:
    """ContainsVertex(k) — wait-free lookup."""
    return find_slot(state, jnp.asarray(key, jnp.int32)) >= 0


def contains_edge(state: GraphState, k, l) -> jax.Array:
    """ContainsEdge(k, l) — returns a result code (R_EDGE_PRESENT etc.)."""
    sk = find_slot(state, jnp.asarray(k, jnp.int32))
    sl = find_slot(state, jnp.asarray(l, jnp.int32))
    both = (sk >= 0) & (sl >= 0)
    present = get_bit(state.adj_packed, jnp.maximum(sk, 0), jnp.maximum(sl, 0))
    return jnp.where(
        both,
        jnp.where(present, R_EDGE_PRESENT, R_EDGE_NOT_PRESENT),
        R_VERTEX_NOT_PRESENT,
    ).astype(jnp.int32)


def num_vertices(state: GraphState) -> jax.Array:
    return jnp.sum(state.valive.astype(jnp.int32))


def num_edges(state: GraphState) -> jax.Array:
    """Edges between *alive* endpoints (lazy rows of dead vertices excluded,
    mirroring the paper: an ENode whose ptv is marked is logically absent).
    One popcount over the ``traversable_packed`` words (DESIGN.md §10)."""
    live = traversable_packed(state.adj_packed, state.valive,
                              state.alive_words)
    return jnp.sum(popcount(live))


def to_networkx_like(state: GraphState) -> tuple[list[int], list[tuple[int, int]]]:
    """Host-side export for tests: (vertex keys, edge key-pairs)."""
    import numpy as np

    vkey = np.asarray(state.vkey)
    valive = np.asarray(state.valive)
    adj = np.asarray(state.adj)
    verts = [int(vkey[i]) for i in range(len(vkey)) if valive[i]]
    edges = []
    for i in range(len(vkey)):
        if not valive[i]:
            continue
        for j in np.nonzero(adj[i])[0]:
            if valive[j]:
                edges.append((int(vkey[i]), int(vkey[j])))
    return verts, edges


def transpose_invariant(state) -> jax.Array:
    """The in-adjacency maintenance invariant (DESIGN.md §11): after ANY op
    stream, ``adj_in_packed == pack_transpose(adj_packed)`` — bit (r, c) of
    the out-adjacency is bit (c, r) of the in-adjacency, padding included
    (``pack_transpose`` reproduces the padding invariant, so the comparison
    also pins pad bits to zero on both sides).

    Accepts anything with ``adj_packed``/``adj_in_packed``/``capacity``
    (dense ``GraphState`` or a mesh-sharded state's gathered view). Returns
    a scalar bool; tests/test_hybrid.py drives it over arbitrary
    interleaved mutation/grow/compact streams, dense AND sharded.
    """
    want = pack_transpose(state.adj_packed, state.capacity)
    return jnp.all(state.adj_in_packed == want) & jnp.all(
        pack_transpose(state.adj_in_packed, state.capacity)
        == state.adj_packed)


@functools.partial(jax.jit, static_argnums=())
def version_vector(state: GraphState) -> jax.Array:
    """The collect-validation vector: (ecnt, vver) stacked as int32[V, 2].

    Two reads of this vector bracketing a traversal implement the paper's
    double-collect validation (ecnt check in CompareTree/ComparePath plus the
    VNode-identity check, which vver subsumes).
    """
    return jnp.stack([state.ecnt, state.vver], axis=-1)
