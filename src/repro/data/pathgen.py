"""Graph -> LM corpus: reachability-query supervision from the live engine.

This is the paper-integration workload (DESIGN.md §5(i)): a mutator stream
evolves a concurrent graph (core.ops batches); each training example
serializes the current edge set, a (src, dst) query, and the GetPath answer
obtained from the snapshot engine — teaching an LM the reachability task the
paper's data structure serves, while exercising the engine's concurrent API
as a production data pipeline would.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import (
    OP_ADD_E,
    OP_ADD_V,
    OP_REM_E,
    GraphState,
    apply_ops_fast,
    get_path,
    make_graph,
    make_op_batch,
)
from repro.core.graph import to_networkx_like
from repro.data import tokenizer as tok


class PathTaskGenerator:
    """Deterministic, restart-safe stream of (tokens, loss_mask) examples."""

    def __init__(self, *, n_vertices: int = 24, capacity: int = 64,
                 mutate_lanes: int = 16, seed: int = 0,
                 backend: str | None = None):
        self.nv = n_vertices
        self.capacity = capacity
        self.lanes = mutate_lanes
        self.backend = backend
        self.rng = np.random.default_rng(seed)
        self.state = make_graph(capacity)
        boot = [(OP_ADD_V, k) for k in range(n_vertices)]
        for i in range(0, len(boot), mutate_lanes):
            self.state, _ = apply_ops_fast(
                self.state, make_op_batch(boot[i : i + mutate_lanes], mutate_lanes))

    def _mutate(self):
        ops = []
        for _ in range(self.lanes):
            u, v = self.rng.integers(0, self.nv, 2)
            op = OP_ADD_E if self.rng.random() < 0.7 else OP_REM_E
            ops.append((op, int(u), int(v)))
        self.state, _ = apply_ops_fast(self.state, make_op_batch(ops, self.lanes))

    def example(self) -> list[int]:
        self._mutate()
        src, dst = (int(x) for x in self.rng.integers(0, self.nv, 2))
        pr = get_path(self.state, src, dst, backend=self.backend)
        path = [int(k) for k in np.asarray(pr.keys)[: int(pr.length)]] if bool(pr.found) else []
        verts, edges = to_networkx_like(self.state)
        return tok.encode_example(edges, src, dst, path)

    def batch(self, batch_size: int, seq_len: int):
        """-> tokens int32 [batch, seq_len] padded/truncated."""
        out = np.zeros((batch_size, seq_len), np.int32)
        for i in range(batch_size):
            ex = self.example()[:seq_len]
            out[i, : len(ex)] = ex
        return out
