"""Host data pipeline: deterministic, restart-safe, prefetching, shard-aware.

Determinism: batch b is a pure function of (seed, b), so a restarted worker
resumes mid-epoch exactly; the train loop passes its step counter. On a
fleet every host builds only its process-local slice (here: single process
builds the global batch and device_puts it with the batch sharding).
"""
from __future__ import annotations

import queue
import threading

import jax
import numpy as np

from repro.data.pathgen import PathTaskGenerator


class SyntheticLMData:
    """Random-token LM batches (benchmarks, memory tests)."""

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        self.seed = seed

    def batch(self, step: int, batch_size: int, seq_len: int):
        rng = np.random.default_rng((self.seed, step))
        return rng.integers(0, self.vocab, (batch_size, seq_len), dtype=np.int32)


class GraphPathData:
    """Reachability-task batches from the concurrent graph engine."""

    def __init__(self, *, n_vertices=24, seed=0):
        self.kw = dict(n_vertices=n_vertices)
        self.seed = seed
        self._gens: dict[int, PathTaskGenerator] = {}

    def batch(self, step: int, batch_size: int, seq_len: int):
        gen = self._gens.get(step)
        if gen is None:
            gen = PathTaskGenerator(seed=self.seed + step, **self.kw)
            self._gens = {step: gen}  # keep only current (deterministic per step)
        return gen.batch(batch_size, seq_len)


class Prefetcher:
    """Background-thread prefetch + device placement."""

    def __init__(self, source, *, batch_size: int, seq_len: int,
                 sharding=None, depth: int = 2, start_step: int = 0):
        self.source = source
        self.bs, self.sl = batch_size, seq_len
        self.sharding = sharding
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = False
        self.t = threading.Thread(target=self._run, daemon=True)
        self.t.start()

    def _run(self):
        while not self._stop:
            arr = self.source.batch(self.step, self.bs, self.sl)
            if self.sharding is not None:
                arr = jax.device_put(arr, self.sharding)
            self.q.put({"tokens": arr, "step": self.step})
            self.step += 1

    def __next__(self):
        return self.q.get()

    def stop(self):
        self._stop = True
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
