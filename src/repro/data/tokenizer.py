"""Minimal deterministic tokenizer for the graph-task corpora.

Vocabulary: digits/punct for serialized graphs + control tokens. Numbers are
tokenized digit-wise, so any key fits any vocab >= VOCAB_MIN.
"""
from __future__ import annotations

PAD, BOS, EOS, SEP, QUERY, PATH, NOPATH, EDGE = 0, 1, 2, 3, 4, 5, 6, 7
_DIGIT0 = 8
VOCAB_MIN = 18


def encode_int(n: int) -> list[int]:
    return [_DIGIT0 + int(c) for c in str(int(n))]


def encode_edge(u: int, v: int) -> list[int]:
    return [EDGE] + encode_int(u) + [SEP] + encode_int(v)


def encode_example(edges, src: int, dst: int, path) -> list[int]:
    """<bos> E u|v ... <query> s|t <path> v0|v1|... <eos>  (or <nopath>)."""
    toks = [BOS]
    for (u, v) in edges:
        toks += encode_edge(u, v)
    toks += [QUERY] + encode_int(src) + [SEP] + encode_int(dst)
    if path:
        toks += [PATH]
        for v in path:
            toks += encode_int(v) + [SEP]
    else:
        toks += [NOPATH]
    toks.append(EOS)
    return toks


def decode(tokens) -> str:
    names = {PAD: "_", BOS: "<s>", EOS: "</s>", SEP: "|", QUERY: "?",
             PATH: "=>", NOPATH: "=>NONE", EDGE: "E"}
    out = []
    for t in tokens:
        t = int(t)
        if t in names:
            out.append(names[t])
        elif t >= _DIGIT0:
            out.append(str(t - _DIGIT0))
    return "".join(out)
