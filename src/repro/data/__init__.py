from repro.data import pathgen, pipeline, tokenizer  # noqa: F401
