"""gemma2-27b [dense] — arXiv:2408.00118. Local(4096)/global alternating,
attn/final logit softcaps, GeGLU, sandwich norms, query scale 1/sqrt(144).
Global layers are full attention -> long_500k skipped (DESIGN.md §5)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    local_global_period=2,
    query_scale=144.0 ** -0.5,
    sandwich_norm=True,
    mlp_act="gelu",
    skip_shapes=("long_500k",),
    source="arXiv:2408.00118; hf",
)
