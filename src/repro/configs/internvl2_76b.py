"""internvl2-76b [vlm] — arXiv:2404.16821. Transformer BACKBONE only
(InternLM2/Llama3-70B-class); the InternViT frontend is a STUB:
input_specs() supplies 256 precomputed patch embeddings prepended to the
text sequence. Full attention -> long_500k skipped."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    n_vis_tokens=256,
    tie_embeddings=False,
    skip_shapes=("long_500k",),
    source="arXiv:2404.16821; unverified",
)
