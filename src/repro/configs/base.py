"""Architecture config schema + the assigned input-shape sets.

Every assigned architecture gets a module ``configs/<id>.py`` exporting
``CONFIG`` (exact published numbers) — selectable via ``--arch <id>`` in the
launchers. ``CONFIG.smoke()`` returns the family-preserving reduced config
used by per-arch CPU smoke tests (small widths, few layers/experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional


# The LM shape set (seq_len, global_batch) — identical for all 10 archs.
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


@dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str = "unnamed"
    family: str = "dense"        # dense | moe | ssm | hybrid | encdec | vlm
    # trunk
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv: int = 4
    head_dim: int = 0            # 0 => d_model // n_heads
    d_ff: int = 1024
    vocab: int = 512
    # attention options
    rope_theta: float = 10_000.0
    qk_norm: bool = False        # qwen3: per-head RMSNorm on q, k
    qkv_bias: bool = False       # qwen2
    attn_softcap: float = 0.0    # gemma2: 50.0 (0 = off)
    final_softcap: float = 0.0   # gemma2: 30.0
    sliding_window: int = 0      # 0 = global; gemma2: 4096, recurrentgemma: 2048
    local_global_period: int = 0  # gemma2: 2 (alternate local/global)
    query_scale: float = 0.0     # 0 => 1/sqrt(head_dim); gemma2-27b: 1/sqrt(144)
    # norm / mlp
    norm_eps: float = 1e-6
    parametric_norm: bool = True  # olmo: False (non-parametric LN)
    norm_type: str = "rmsnorm"    # rmsnorm | layernorm
    sandwich_norm: bool = False   # gemma2 post-norms
    mlp_act: str = "silu"         # silu (SwiGLU) | gelu (GeGLU)
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_ff: int = 0
    router_aux_coef: float = 0.01
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # hybrid (recurrentgemma / Griffin): pattern of block kinds, tiled
    block_pattern: tuple = ()     # e.g. ("rec", "rec", "attn")
    lru_width: int = 0            # 0 => d_model
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_frames: int = 1500        # stub conv frontend output length
    # vlm
    n_vis_tokens: int = 0         # stub patch embeddings prepended (internvl2)
    # numerics
    dtype: str = "bfloat16"
    # which shape cells are runnable; long_500k excluded for full attention
    skip_shapes: tuple = ()
    # provenance
    source: str = ""

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_lru(self) -> int:
        return self.lru_width or self.d_model

    def smoke(self) -> "ArchConfig":
        """Family-preserving reduced config for CPU smoke tests."""
        pattern = self.block_pattern[: len(self.block_pattern) or None]
        return replace(
            self,
            n_layers=max(2, len(pattern) or 2) if self.family != "encdec" else 2,
            d_model=64,
            n_heads=4,
            n_kv=min(self.n_kv, 4) if self.n_kv > 1 else 1,
            head_dim=16,
            d_ff=128,
            vocab=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            expert_ff=32 if self.n_experts else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            ssm_chunk=16 if self.ssm_state else 256,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            lru_width=64 if self.lru_width else 0,
            enc_layers=2 if self.enc_layers else 0,
            enc_frames=24 if self.enc_layers else 1500,
            n_vis_tokens=4 if self.n_vis_tokens else 0,
            dtype="float32",
        )

    def param_count(self) -> int:
        """Analytic parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.hd
        qdim, kvdim = self.n_heads * hd, self.n_kv * hd
        attn = d * qdim + 2 * d * kvdim + qdim * d
        if self.family == "ssm":
            din = self.ssm_expand * d
            nh = din // self.ssm_headdim
            per = d * (2 * din + 2 * self.ssm_state + nh) + din * d + din * self.ssm_conv + 2 * nh
            body = self.n_layers * (per + d)
        elif self.family == "hybrid":
            per_attn = attn + 3 * d * self.d_ff + 2 * d
            dl = self.d_lru
            per_rec = d * dl * 2 + dl * d + dl * self.ssm_conv + 4 * dl + 3 * d * self.d_ff + 2 * d
            pat = self.block_pattern or ("rec",)
            n_attn = sum(1 for i in range(self.n_layers) if pat[i % len(pat)] == "attn")
            body = n_attn * per_attn + (self.n_layers - n_attn) * per_rec
        else:
            if self.n_experts:
                ffn = self.n_experts * 3 * d * self.expert_ff + d * self.n_experts
            else:
                ffn = 3 * d * self.d_ff
            body = self.n_layers * (attn + ffn + 2 * d)
            if self.enc_layers:
                body += self.enc_layers * (attn + 3 * d * self.d_ff + 2 * d)
                body += self.n_layers * (attn + 2 * d)  # cross attention
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return body + emb + d

    def active_param_count(self) -> int:
        """N_active for MoE (6*N_active*D)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        dense_ffn = self.n_layers * (self.top_k * 3 * d * self.expert_ff + d * self.n_experts)
        all_ffn = self.n_layers * (self.n_experts * 3 * d * self.expert_ff + d * self.n_experts)
        return self.param_count() - all_ffn + dense_ffn


def shape_for(name: str) -> dict:
    return dict(SHAPES[name])
