"""qwen3-4b [dense] — hf:Qwen/Qwen3-8B family; qk_norm, GQA. Full attention."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv=8,
    head_dim=128,
    d_ff=9728,
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    skip_shapes=("long_500k",),
    source="hf:Qwen/Qwen3-8B; hf",
)
