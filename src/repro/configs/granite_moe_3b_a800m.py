"""granite-moe-3b-a800m [moe] — hf:ibm-granite; 40 experts top-8.

Assignment line also says "(32 experts top-8)" parenthetically; we follow the
primary "MoE 40e top-8" spec (matches the published granite-3.0-3b-a800m).
Full attention -> long_500k skipped (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    n_experts=40,
    top_k=8,
    expert_ff=512,
    skip_shapes=("long_500k",),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
