"""whisper-base [audio] — arXiv:2212.04356. Enc-dec backbone; the conv audio
frontend is a STUB (input_specs supplies 1500 precomputed frame embeddings).
Full attention -> long_500k skipped; decode cells exercise the decoder."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    enc_layers=6,
    enc_frames=1500,
    d_model=512,
    n_heads=8,
    n_kv=8,
    d_ff=2048,
    vocab=51865,
    mlp_act="gelu",
    norm_type="layernorm",
    skip_shapes=("long_500k",),
    source="arXiv:2212.04356; unverified",
)
