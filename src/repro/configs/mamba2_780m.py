"""mamba2-780m [ssm] — arXiv:2405.21060 (SSD). Attention-free; constant-size
state -> runs long_500k."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=128,
    source="arXiv:2405.21060; unverified",
)
