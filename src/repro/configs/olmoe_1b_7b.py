"""olmoe-1b-7b [moe] — arXiv:2409.02060; 64 experts top-8. Full attention."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=1024,
    vocab=50304,
    n_experts=64,
    top_k=8,
    expert_ff=1024,
    qk_norm=True,
    skip_shapes=("long_500k",),
    source="arXiv:2409.02060; hf",
)
