"""olmo-1b [dense] — arXiv:2402.00838; non-parametric LayerNorm, MHA."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=8192,
    vocab=50304,
    parametric_norm=False,
    norm_type="layernorm",
    skip_shapes=("long_500k",),
    source="arXiv:2402.00838; hf",
)
