"""recurrentgemma-9b [hybrid] — arXiv:2402.19427 (Griffin). Pattern
(rec, rec, local-attn) tiled over 38 blocks (12 triples + 2 recurrent);
MQA kv=1, window 2048; RG-LRU state is constant-size -> runs long_500k."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    sliding_window=2048,
    block_pattern=("rec", "rec", "attn_local"),
    lru_width=4096,
    mlp_act="gelu",
    source="arXiv:2402.19427; unverified",
)
