"""qwen2-1.5b [dense] — arXiv:2407.10671; GQA kv=2, QKV bias. Full attention."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    skip_shapes=("long_500k",),
    source="arXiv:2407.10671; hf",
)
