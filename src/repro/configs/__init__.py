"""Registry of assigned architectures: get_config("<id>") / ARCHS."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ArchConfig, shape_for  # noqa: F401

ARCHS = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "internvl2-76b": "internvl2_76b",
    "gemma2-27b": "gemma2_27b",
    "qwen3-4b": "qwen3_4b",
    "qwen2-1.5b": "qwen2_1_5b",
    "olmo-1b": "olmo_1b",
    "whisper-base": "whisper_base",
    "mamba2-780m": "mamba2_780m",
    "recurrentgemma-9b": "recurrentgemma_9b",
}


def get_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.CONFIG


def all_cells():
    """Every runnable (arch, shape) pair; skipped cells yield reason strings."""
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape in cfg.skip_shapes:
                yield arch, shape, "skip: full attention excludes long-context decode"
            else:
                yield arch, shape, None
