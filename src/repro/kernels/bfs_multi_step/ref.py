"""Pure-jnp oracles for the bfs_multi_step kernels (dense and packed)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.graph import WORD_BITS, pack_bits, unpack_bits

INT32_MAX = jnp.int32(2**31 - 1)


def multi_bfs_step_ref(frontiers, adj, alive, visited):
    """Same contract as kernel.multi_bfs_step_pallas.

    frontiers f32[Q,V] (0/1), adj (u)int8[V,V], alive int32[V] (0/1),
    visited int32[Q,V] (0/1) -> (new_frontiers int32[Q,V], parent int32[Q,V]).
    """
    v = adj.shape[0]
    f = frontiers.astype(jnp.float32)
    # repro-lint: allow(traversable-predicate) — raw tile; next line masks
    reach = (f @ adj.astype(jnp.float32)) > 0
    new = reach & (alive[None, :] > 0) & (visited == 0)
    idx = jnp.arange(v, dtype=jnp.int32)
    # parent scan over the raw tile; `new` above already gates which
    # parents survive  # repro-lint: allow(traversable-predicate)
    cand = jnp.where((frontiers[:, :, None] > 0) & (adj[None, :, :] > 0),
                     idx[None, :, None], INT32_MAX)
    parent = jnp.min(cand, axis=1)
    parent = jnp.where(new, parent, jnp.int32(-1))
    return new.astype(jnp.int32), parent


def multi_bfs_step_packed_ref(frontiers, adj_packed, alive, visited):
    """Same contract as kernel.multi_bfs_step_packed_pallas
    (unpack-then-dense-ref, including the raw reach-words output).

    frontiers f32[Q, R] (0/1), adj_packed uint32[R, W], alive int32[W*32],
    visited int32[Q, W*32] -> (new int32[Q, W*32], parent int32[Q, W*32],
    reach_words uint32[Q, W]).
    """
    q, rows = frontiers.shape
    w = adj_packed.shape[1]
    vc = w * WORD_BITS
    adj = unpack_bits(adj_packed, vc).astype(jnp.uint8)  # [R, W*32]
    # repro-lint: allow(traversable-predicate) — raw tile; next line masks
    reach = (frontiers.astype(jnp.float32) @ adj.astype(jnp.float32)) > 0
    new = reach & (alive[None, :] > 0) & (visited == 0)
    idx = jnp.arange(rows, dtype=jnp.int32)
    # parent scan over the raw tile; `new` above already gates which
    # parents survive  # repro-lint: allow(traversable-predicate)
    cand = jnp.where((frontiers[:, :, None] > 0) & (adj[None, :, :] > 0),
                     idx[None, :, None], INT32_MAX)
    parent = jnp.min(cand, axis=1)
    parent = jnp.where(new, parent, jnp.int32(-1))
    return new.astype(jnp.int32), parent, pack_bits(reach)
