"""Pure-jnp oracle for the bfs_multi_step kernel."""
from __future__ import annotations

import jax.numpy as jnp

INT32_MAX = jnp.int32(2**31 - 1)


def multi_bfs_step_ref(frontiers, adj, alive, visited):
    """Same contract as kernel.multi_bfs_step_pallas.

    frontiers f32[Q,V] (0/1), adj (u)int8[V,V], alive int32[V] (0/1),
    visited int32[Q,V] (0/1) -> (new_frontiers int32[Q,V], parent int32[Q,V]).
    """
    v = adj.shape[0]
    f = frontiers.astype(jnp.float32)
    reach = (f @ adj.astype(jnp.float32)) > 0
    new = reach & (alive[None, :] > 0) & (visited == 0)
    idx = jnp.arange(v, dtype=jnp.int32)
    cand = jnp.where((frontiers[:, :, None] > 0) & (adj[None, :, :] > 0),
                     idx[None, :, None], INT32_MAX)
    parent = jnp.min(cand, axis=1)
    parent = jnp.where(new, parent, jnp.int32(-1))
    return new.astype(jnp.int32), parent
