"""Pallas TPU kernel: ONE fused superstep for Q concurrent BFS frontiers.

The multi-query analogue of kernels/bfs_step (DESIGN.md §7). A batch of Q
reachability queries advances all frontiers with a single frontier-matrix
product per (row, col) adjacency tile:

    reach[q, c-tile] |= any_r ( frontier[q, r-tile] @ adj[r-tile, c-tile] )

The frontier block carries the WHOLE padded query slab [TQ, TR] (TQ = Q
rounded up to the f32 sublane multiple), so each adjacency tile is streamed
HBM->VMEM exactly once per superstep — not once per query as the vmapped
single-query path pays — and the MXU sees a real [TQ,TR]x[TR,TC] matmul
instead of Q rank-1 mat-vecs.

Grid = (col_tiles, row_tiles), row axis innermost so each [TQ, TC] output
tile is produced once and revisited across the reduction ("arbitrary"
dimension semantics). A row tile in which NO query has an active frontier
row is skipped entirely with @pl.when — late supersteps, where most queries
have finished (early-exit masking zeroes their frontiers, core/bfs.py) and
survivors touch few rows, cost almost nothing.

Parent extraction (smallest source row per (query, dst) pair) is a masked
min that needs a [TQ, TR, TC] candidate volume. VMEM budget decides the
strategy statically: the broadcast fits for small slabs
(8*256*256*4 = 2 MiB << 16 MiB VMEM); larger slabs fall back to a fori_loop
over query rows holding only one [TR, TC] slice (256 KiB) at a time.

VMEM footprint per program instance (TQ=64, TR=TC=256 defaults):
    adj tile       256*256 u8->f32  = 256 KiB
    frontier slab  64*256 f32       =  64 KiB
    out slabs      2 * 64*256 i32   = 128 KiB
    parent scratch (see above)      <= 4 MiB        << 16 MiB VMEM

The PACKED variant (``multi_bfs_step_packed_pallas``, DESIGN.md §10)
streams uint32[TR, TW] word tiles of the packed adjacency — 32x less HBM
per superstep, the term this kernel is bandwidth-bound on — and expands
every query's frontier with a bitwise OR fold over its active rows' words
instead of the MXU matmul. Parent extraction unpacks the word tile in
registers; the HBM stream stays packed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.graph import WORD_BITS, or_reduce, unpack_bits

INT32_MAX = 2**31 - 1  # python int: pallas kernels must not capture tracers

# static switch: largest [TQ, TR, TC] parent-candidate volume (bytes) we are
# willing to materialize in VMEM before falling back to the per-query loop
_PARENT_BCAST_BUDGET = 4 * 1024 * 1024


def _multi_bfs_step_kernel(f_ref, adj_ref, alive_ref, visited_ref,
                           reach_ref, parent_ref, *, tq: int, tr: int, tc: int,
                           bcast_budget: int):
    r = pl.program_id(1)
    nr = pl.num_programs(1)

    @pl.when(r == 0)
    def _init():
        reach_ref[...] = jnp.zeros_like(reach_ref)
        parent_ref[...] = jnp.full_like(parent_ref, INT32_MAX)

    f = f_ref[...]  # f32[TQ, TR] — all queries' slice of this row tile

    @pl.when(jnp.any(f > 0))
    def _accumulate():
        a = adj_ref[...].astype(jnp.float32)          # [TR, TC]
        hits = jnp.dot(f, a, preferred_element_type=jnp.float32)  # MXU [TQ, TC]
        reach_ref[...] = jnp.maximum(reach_ref[...], (hits > 0).astype(jnp.int32))
        row_ids = r * tr + jax.lax.iota(jnp.int32, tr)            # global rows
        if tq * tr * tc * 4 <= bcast_budget:
            cand = jnp.where((f[:, :, None] > 0) & (a[None, :, :] > 0),
                             row_ids[None, :, None], INT32_MAX)
            cand_min = jnp.min(cand, axis=1)                      # [TQ, TC]
        else:
            def qrow(qi, acc):
                fq = jax.lax.dynamic_slice_in_dim(f, qi, 1, axis=0)[0]
                c = jnp.where((fq[:, None] > 0) & (a > 0),
                              row_ids[:, None], INT32_MAX)
                return jax.lax.dynamic_update_slice_in_dim(
                    acc, jnp.min(c, axis=0)[None, :], qi, axis=0)
            cand_min = jax.lax.fori_loop(
                0, tq, qrow, jnp.full((tq, tc), INT32_MAX, jnp.int32))
        parent_ref[...] = jnp.minimum(parent_ref[...], cand_min)

    @pl.when(r == nr - 1)
    def _epilogue():
        new = ((reach_ref[...] > 0) & (alive_ref[...][None, :] > 0)
               & (visited_ref[...] == 0))
        reach_ref[...] = new.astype(jnp.int32)
        parent_ref[...] = jnp.where(new, parent_ref[...], jnp.int32(-1))


@functools.partial(
    jax.jit, static_argnames=("tr", "tc", "interpret", "parent_bcast_budget")
)
def multi_bfs_step_pallas(frontiers, adj, alive, visited, *, tr: int = 256,
                          tc: int = 256, interpret: bool = True,
                          parent_bcast_budget: int = _PARENT_BCAST_BUDGET):
    """One fused expansion of Q frontiers. R % tr == 0 and V % tc == 0.

    frontiers: f32[Q, R] (0/1)   adj: int8/uint8[R, V]
    alive:     int32[V] (0/1)    visited: int32[Q, V] (0/1)
    Returns (new_frontiers int32[Q, V], parent int32[Q, V]).

    ``adj`` may be a contiguous ROW SLICE of the global adjacency (R < V) —
    the per-shard superstep of the partitioned engine (DESIGN.md §8). Parent
    ids are then relative to the slice; the caller adds its row offset
    before the cross-shard min-combine.

    Q is the full (already padded) query-slab height; callers align it to
    the f32 sublane multiple (kernels/bfs_multi_step/ops.py pads).
    ``parent_bcast_budget`` is static (part of the jit/trace key) so the
    parent-extraction strategy is pinned per compilation — pass 0 to force
    the per-query fori_loop path.
    """
    q, rows = frontiers.shape
    v = adj.shape[1]
    assert adj.shape[0] == rows, (frontiers.shape, adj.shape)
    assert alive.shape == (v,) and visited.shape == (q, v), \
        (alive.shape, visited.shape)
    assert rows % tr == 0 and v % tc == 0, (rows, v, tr, tc)
    grid = (v // tc, rows // tr)
    return pl.pallas_call(
        functools.partial(_multi_bfs_step_kernel, tq=q, tr=tr, tc=tc,
                          bcast_budget=parent_bcast_budget),
        grid=grid,
        in_specs=[
            pl.BlockSpec((q, tr), lambda c, r: (0, r)),
            pl.BlockSpec((tr, tc), lambda c, r: (r, c)),
            pl.BlockSpec((tc,), lambda c, r: (c,)),
            pl.BlockSpec((q, tc), lambda c, r: (0, c)),
        ],
        out_specs=[
            pl.BlockSpec((q, tc), lambda c, r: (0, c)),
            pl.BlockSpec((q, tc), lambda c, r: (0, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, v), jnp.int32),
            jax.ShapeDtypeStruct((q, v), jnp.int32),
        ],
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(frontiers, adj, alive, visited)


# ----------------------------------------------------------------------------
# Packed-word variant (DESIGN.md §10)
# ----------------------------------------------------------------------------
def _multi_bfs_step_packed_kernel(f_ref, adjw_ref, alive_ref, visited_ref,
                                  reach_ref, parent_ref, words_ref, *,
                                  tq: int, tr: int, tw: int,
                                  bcast_budget: int):
    r = pl.program_id(1)
    nr = pl.num_programs(1)
    tc = tw * WORD_BITS

    @pl.when(r == 0)
    def _init():
        words_ref[...] = jnp.zeros_like(words_ref)
        reach_ref[...] = jnp.zeros_like(reach_ref)
        parent_ref[...] = jnp.full_like(parent_ref, INT32_MAX)

    f = f_ref[...]  # f32[TQ, TR] — all queries' slice of this row tile

    @pl.when(jnp.any(f > 0))
    def _accumulate():
        a = adjw_ref[...]                               # uint32[TR, TW]
        sel = jnp.where(f[:, :, None] > 0, a[None, :, :], jnp.uint32(0))
        words_ref[...] |= or_reduce(sel, 1)             # [TQ, TW] OR fold
        bits = unpack_bits(a, tc)                       # in-register unpack
        row_ids = r * tr + jax.lax.iota(jnp.int32, tr)
        if tq * tr * tc * 4 <= bcast_budget:
            cand = jnp.where((f[:, :, None] > 0) & bits[None, :, :],
                             row_ids[None, :, None], INT32_MAX)
            cand_min = jnp.min(cand, axis=1)            # [TQ, TC]
        else:
            def qrow(qi, acc):
                fq = jax.lax.dynamic_slice_in_dim(f, qi, 1, axis=0)[0]
                c = jnp.where((fq[:, None] > 0) & bits,
                              row_ids[:, None], INT32_MAX)
                return jax.lax.dynamic_update_slice_in_dim(
                    acc, jnp.min(c, axis=0)[None, :], qi, axis=0)
            cand_min = jax.lax.fori_loop(
                0, tq, qrow, jnp.full((tq, tc), INT32_MAX, jnp.int32))
        parent_ref[...] = jnp.minimum(parent_ref[...], cand_min)

    @pl.when(r == nr - 1)
    def _epilogue():
        reach = unpack_bits(words_ref[...], tc)
        new = (reach & (alive_ref[...][None, :] > 0)
               & (visited_ref[...] == 0))
        reach_ref[...] = new.astype(jnp.int32)
        parent_ref[...] = jnp.where(new, parent_ref[...], jnp.int32(-1))


@functools.partial(
    jax.jit, static_argnames=("tr", "tw", "interpret", "parent_bcast_budget")
)
def multi_bfs_step_packed_pallas(frontiers, adj_packed, alive, visited, *,
                                 tr: int = 256, tw: int = 8,
                                 interpret: bool = True,
                                 parent_bcast_budget: int = _PARENT_BCAST_BUDGET):
    """One packed fused expansion of Q frontiers. R % tr == 0, W % tw == 0.

    frontiers: f32[Q, R] (0/1)   adj_packed: uint32[R, W]
    alive:     int32[W*32]       visited: int32[Q, W*32]
    Returns (new int32[Q, W*32], parent int32[Q, W*32], reach_words
    uint32[Q, W]). Like the dense kernel, ``adj_packed`` may be a contiguous
    ROW SLICE of the packed adjacency (the per-shard superstep, DESIGN.md
    §8): parent ids come back slice-relative, and ``reach_words`` carries
    the raw pre-mask OR partial the sharded engine exchanges as packed
    uint32 frontiers. Callers slice the word padding (columns >= V) off.
    """
    q, rows = frontiers.shape
    w = adj_packed.shape[1]
    vc = w * WORD_BITS
    assert adj_packed.shape[0] == rows, (frontiers.shape, adj_packed.shape)
    assert alive.shape == (vc,) and visited.shape == (q, vc), \
        (alive.shape, visited.shape, vc)
    assert rows % tr == 0 and w % tw == 0, (rows, w, tr, tw)
    tc = tw * WORD_BITS
    grid = (w // tw, rows // tr)
    return pl.pallas_call(
        functools.partial(_multi_bfs_step_packed_kernel, tq=q, tr=tr, tw=tw,
                          bcast_budget=parent_bcast_budget),
        grid=grid,
        in_specs=[
            pl.BlockSpec((q, tr), lambda c, r: (0, r)),
            pl.BlockSpec((tr, tw), lambda c, r: (r, c)),
            pl.BlockSpec((tc,), lambda c, r: (c,)),
            pl.BlockSpec((q, tc), lambda c, r: (0, c)),
        ],
        out_specs=[
            pl.BlockSpec((q, tc), lambda c, r: (0, c)),
            pl.BlockSpec((q, tc), lambda c, r: (0, c)),
            pl.BlockSpec((q, tw), lambda c, r: (0, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, vc), jnp.int32),
            jax.ShapeDtypeStruct((q, vc), jnp.int32),
            jax.ShapeDtypeStruct((q, w), jnp.uint32),
        ],
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(frontiers, adj_packed, alive, visited)
