"""KERNEL_META for the bfs_multi_step package — checked by the
kernel-shape sanitizer (``python -m repro.analysis``, DESIGN.md §15).

Pure literal by contract (``ast.literal_eval`` is the parser): 16777216 =
16 MiB VMEM budget, 4194304 = the 4 MiB parent-broadcast scratch budget
(kernel.py's ``_PARENT_BCAST_BUDGET``). ``q`` is the full query-slab
height (the engine's admission cap pads to 64); ``tc`` = tw * 32 for the
packed kernel.
"""

KERNEL_META = {
    "package": "bfs_multi_step",
    "vmem_budget_bytes": {"tpu": 16777216},
    "dims": {"q": 64, "tc": 256},
    "kernels": {
        "multi_bfs_step_pallas": {
            "tiles": {"tr": 256, "tc": 256},
            "align": {"tr": 8, "tc": 128},
            "divides": {"rows": ["tr"], "v": ["tc"]},
            "operands": {
                "frontiers": {"block": ["q", "tr"], "dtype": "float32"},
                "adj": {"block": ["tr", "tc"], "dtype": "uint8"},
                "alive": {"block": ["tc"], "dtype": "int32"},
                "visited": {"block": ["q", "tc"], "dtype": "int32"},
            },
            "outputs": {
                "new": {"block": ["q", "tc"], "dtype": "int32"},
                "parent": {"block": ["q", "tc"], "dtype": "int32"},
            },
            "packed": False,
            "pad_safety": None,
            "wrapper": "multi_bfs_step",
            "ref": "multi_bfs_step_ref",
            "scratch_bytes": 4194304,
        },
        "multi_bfs_step_packed_pallas": {
            "tiles": {"tr": 256, "tw": 8},
            "align": {"tr": 8, "tw": 8},
            "divides": {"rows": ["tr"], "w": ["tw"]},
            "operands": {
                "frontiers": {"block": ["q", "tr"], "dtype": "float32"},
                "adj_packed": {"block": ["tr", "tw"], "dtype": "uint32"},
                "alive": {"block": ["tc"], "dtype": "int32"},
                "visited": {"block": ["q", "tc"], "dtype": "int32"},
            },
            "outputs": {
                "new": {"block": ["q", "tc"], "dtype": "int32"},
                "parent": {"block": ["q", "tc"], "dtype": "int32"},
                "reach_words": {"block": ["q", "tw"], "dtype": "uint32"},
            },
            "packed": True,
            "pad_safety": "slice",
            "wrapper": "multi_bfs_step_packed",
            "ref": "multi_bfs_step_packed_ref",
            "scratch_bytes": 4194304,
        },
    },
}
