"""jit'd public wrapper for the bfs_multi_step kernel (adapts GraphState dtypes).

Pads the query axis up to the f32 sublane multiple (8) so the frontier slab
is a legal TPU tile, runs the fused kernel, and slices the padding back off.
Padded queries carry an all-zero frontier, so they are dead weight the
@pl.when tile-skip removes — they never reach the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bfs_multi_step.kernel import multi_bfs_step_pallas
from repro.kernels.bfs_step.ops import _pick_tile

_Q_ALIGN = 8  # f32 sublane multiple


@functools.partial(jax.jit, static_argnames=())
def multi_bfs_step(frontiers, adj, alive, visited):
    """Drop-in replacement for core.bfs.multi_bfs_step_jnp (bool interface).

    frontiers: bool[Q, R]; adj: uint8[R, V]; alive: bool[V]; visited: bool[Q, V]
    -> (new_frontiers bool[Q, V], parent int32[Q, V])

    R == V for the dense engine; R = V/S rows for one shard of the
    partitioned engine (DESIGN.md §8), in which case parent ids are local to
    the row slice (the caller adds its row offset).
    """
    q, rows = frontiers.shape
    v = adj.shape[1]
    qpad = -(-q // _Q_ALIGN) * _Q_ALIGN
    tr = _pick_tile(rows)
    tc = _pick_tile(v)
    f = jnp.zeros((qpad, rows), jnp.float32).at[:q].set(frontiers.astype(jnp.float32))
    vis = jnp.zeros((qpad, v), jnp.int32).at[:q].set(visited.astype(jnp.int32))
    new, parent = multi_bfs_step_pallas(
        f,
        adj,
        alive.astype(jnp.int32),
        vis,
        tr=tr,
        tc=tc,
        interpret=True,  # CPU container; on TPU set interpret=False
    )
    return new[:q] > 0, parent[:q]
