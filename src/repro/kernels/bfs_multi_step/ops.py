"""jit'd public wrapper for the bfs_multi_step kernel (adapts GraphState dtypes).

Pads the query axis up to the f32 sublane multiple (8) so the frontier slab
is a legal TPU tile, runs the fused kernel, and slices the padding back off.
Padded queries carry an all-zero frontier, so they are dead weight the
@pl.when tile-skip removes — they never reach the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.graph import WORD_BITS
from repro.kernels.bfs_multi_step.kernel import (
    multi_bfs_step_packed_pallas,
    multi_bfs_step_pallas,
)
from repro.kernels.bfs_step.ops import _pick_tile, _pick_word_tile

_Q_ALIGN = 8  # f32 sublane multiple


@functools.partial(jax.jit, static_argnames=())
def multi_bfs_step(frontiers, adj, alive, visited):
    """Drop-in replacement for core.bfs.multi_bfs_step_jnp (bool interface).

    frontiers: bool[Q, R]; adj: uint8[R, V]; alive: bool[V]; visited: bool[Q, V]
    -> (new_frontiers bool[Q, V], parent int32[Q, V])

    R == V for the dense engine; R = V/S rows for one shard of the
    partitioned engine (DESIGN.md §8), in which case parent ids are local to
    the row slice (the caller adds its row offset).
    """
    q, rows = frontiers.shape
    v = adj.shape[1]
    qpad = -(-q // _Q_ALIGN) * _Q_ALIGN
    tr = _pick_tile(rows)
    tc = _pick_tile(v)
    f = jnp.zeros((qpad, rows), jnp.float32).at[:q].set(frontiers.astype(jnp.float32))
    vis = jnp.zeros((qpad, v), jnp.int32).at[:q].set(visited.astype(jnp.int32))
    new, parent = multi_bfs_step_pallas(
        f,
        adj,
        alive.astype(jnp.int32),
        vis,
        tr=tr,
        tc=tc,
        interpret=True,  # CPU container; on TPU set interpret=False
    )
    return new[:q] > 0, parent[:q]


@functools.partial(jax.jit, static_argnames=())
def multi_bfs_step_packed(frontiers, adj_packed, alive, visited):
    """Packed drop-in replacement for core.bfs.multi_bfs_step_packed_jnp.

    frontiers: bool[Q, R]; adj_packed: uint32[R, W]; alive: bool[V];
    visited: bool[Q, V] -> (new bool[Q, V], parent int32[Q, V])

    R == V for the dense engine, R = V/S rows of one shard otherwise
    (parent ids then local to the slice). The kernel sees the word-padded
    column range W * 32 (alive/visited zero-padded; padding sliced off).
    """
    q, rows = frontiers.shape
    v = alive.shape[0]
    w = adj_packed.shape[1]
    vc = w * WORD_BITS
    qpad = -(-q // _Q_ALIGN) * _Q_ALIGN
    f = jnp.zeros((qpad, rows), jnp.float32).at[:q].set(
        frontiers.astype(jnp.float32))
    alive_p = jnp.zeros((vc,), jnp.int32).at[:v].set(alive.astype(jnp.int32))
    vis_p = jnp.zeros((qpad, vc), jnp.int32).at[:q, :v].set(
        visited.astype(jnp.int32))
    new, parent, _words = multi_bfs_step_packed_pallas(
        f,
        adj_packed,
        alive_p,
        vis_p,
        tr=_pick_tile(rows),
        tw=_pick_word_tile(w),
        interpret=True,  # CPU container; on TPU set interpret=False
    )
    return new[:q, :v] > 0, parent[:q, :v]
