from repro.kernels.bfs_multi_step.ops import multi_bfs_step  # noqa: F401
from repro.kernels.bfs_multi_step.ref import multi_bfs_step_ref  # noqa: F401
