"""Pure-jnp oracles for the bfs_step kernels (dense and packed)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.graph import WORD_BITS, unpack_bits

INT32_MAX = jnp.int32(2**31 - 1)


def bfs_step_ref(frontier, adj, alive, visited):
    """Same contract as kernel.bfs_step_pallas.

    frontier f32[V] (0/1), adj (u)int8[V,V], alive/visited int32[V] (0/1)
    -> (new_frontier int32[V], parent int32[V]).
    """
    v = adj.shape[0]
    f = frontier.astype(jnp.float32)
    # repro-lint: allow(traversable-predicate) — raw tile; next line masks
    reach = (f @ adj.astype(jnp.float32)) > 0
    new = reach & (alive > 0) & (visited == 0)
    idx = jnp.arange(v, dtype=jnp.int32)
    # parent scan over the raw tile; `new` above already gates which
    # parents survive  # repro-lint: allow(traversable-predicate)
    cand = jnp.where((frontier[:, None] > 0) & (adj > 0), idx[:, None], INT32_MAX)
    parent = jnp.min(cand, axis=0)
    parent = jnp.where(new, parent, jnp.int32(-1))
    return new.astype(jnp.int32), parent


def bfs_step_packed_ref(frontier, adj_packed, alive, visited):
    """Same contract as kernel.bfs_step_packed_pallas (unpack-then-dense-ref).

    frontier f32[V] (0/1), adj_packed uint32[V, W], alive/visited
    int32[W*32] (0/1) -> (new int32[W*32], parent int32[W*32],
    reach_words uint32[W]).
    """
    v, w = adj_packed.shape
    vc = w * WORD_BITS
    adj = unpack_bits(adj_packed, vc).astype(jnp.uint8)
    fp = jnp.zeros((vc,), jnp.float32).at[:v].set(frontier.astype(jnp.float32))
    adj_p = jnp.zeros((vc, vc), jnp.uint8).at[:v].set(adj)
    new, parent = bfs_step_ref(fp, adj_p, alive, visited)
    # raw pre-mask OR partial: reach_words deliberately carries physical
    # reachability (DESIGN.md §10)  # repro-lint: allow(traversable-predicate)
    reach = (fp @ adj_p.astype(jnp.float32)) > 0
    from repro.core.graph import pack_bits

    return new, parent, pack_bits(reach)
