"""Pure-jnp oracle for the bfs_step kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

INT32_MAX = jnp.int32(2**31 - 1)


def bfs_step_ref(frontier, adj, alive, visited):
    """Same contract as kernel.bfs_step_pallas.

    frontier f32[V] (0/1), adj (u)int8[V,V], alive/visited int32[V] (0/1)
    -> (new_frontier int32[V], parent int32[V]).
    """
    v = adj.shape[0]
    f = frontier.astype(jnp.float32)
    reach = (f @ adj.astype(jnp.float32)) > 0
    new = reach & (alive > 0) & (visited == 0)
    idx = jnp.arange(v, dtype=jnp.int32)
    cand = jnp.where((frontier[:, None] > 0) & (adj > 0), idx[:, None], INT32_MAX)
    parent = jnp.min(cand, axis=0)
    parent = jnp.where(new, parent, jnp.int32(-1))
    return new.astype(jnp.int32), parent
