"""jit'd public wrapper for the bfs_step kernel (adapts GraphState dtypes)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bfs_step.kernel import bfs_step_pallas


def _pick_tile(v: int) -> int:
    for t in (256, 128, 64, 32, 16, 8):
        if v % t == 0:
            return t
    return v


@functools.partial(jax.jit, static_argnames=())
def bfs_step(frontier, adj, alive, visited):
    """Drop-in replacement for core.bfs.bfs_step_jnp (bool interface).

    frontier/alive/visited: bool[V]; adj: uint8[V, V]
    -> (new_frontier bool[V], parent int32[V])
    """
    v = adj.shape[0]
    t = _pick_tile(v)
    new, parent = bfs_step_pallas(
        frontier.astype(jnp.float32),
        adj,
        alive.astype(jnp.int32),
        visited.astype(jnp.int32),
        tr=t,
        tc=t,
        interpret=True,  # CPU container; on TPU set interpret=False
    )
    return new > 0, parent
