"""jit'd public wrappers for the bfs_step kernels (adapt GraphState dtypes)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.graph import WORD_BITS
from repro.kernels.bfs_step.kernel import bfs_step_packed_pallas, bfs_step_pallas


def _pick_tile(v: int) -> int:
    for t in (256, 128, 64, 32, 16, 8):
        if v % t == 0:
            return t
    return v


def _pick_word_tile(w: int) -> int:
    for t in (64, 32, 16, 8, 4, 2):
        if w % t == 0:
            return t
    return w


@functools.partial(jax.jit, static_argnames=())
def bfs_step(frontier, adj, alive, visited):
    """Drop-in replacement for core.bfs.bfs_step_jnp (bool interface).

    frontier/alive/visited: bool[V]; adj: uint8[V, V]
    -> (new_frontier bool[V], parent int32[V])
    """
    v = adj.shape[0]
    t = _pick_tile(v)
    new, parent = bfs_step_pallas(
        frontier.astype(jnp.float32),
        adj,
        alive.astype(jnp.int32),
        visited.astype(jnp.int32),
        tr=t,
        tc=t,
        interpret=True,  # CPU container; on TPU set interpret=False
    )
    return new > 0, parent


@functools.partial(jax.jit, static_argnames=())
def bfs_step_packed(frontier, adj_packed, alive, visited):
    """Packed drop-in replacement for core.bfs.bfs_step_packed_jnp.

    frontier/alive/visited: bool[V]; adj_packed: uint32[V, W = ceil(V/32)]
    -> (new_frontier bool[V], parent int32[V])

    The kernel works on the word-padded column range W * 32; alive/visited
    are zero-padded (pad columns can never enter the frontier) and the
    padding is sliced back off here.
    """
    v, w = adj_packed.shape
    vc = w * WORD_BITS
    alive_p = jnp.zeros((vc,), jnp.int32).at[:v].set(alive.astype(jnp.int32))
    vis_p = jnp.zeros((vc,), jnp.int32).at[:v].set(visited.astype(jnp.int32))
    new, parent, _words = bfs_step_packed_pallas(
        frontier.astype(jnp.float32),
        adj_packed,
        alive_p,
        vis_p,
        tr=_pick_tile(v),
        tw=_pick_word_tile(w),
        interpret=True,  # CPU container; on TPU set interpret=False
    )
    return new[:v] > 0, parent[:v]
