"""KERNEL_META for the bfs_step package — checked by the kernel-shape
sanitizer (``python -m repro.analysis``, DESIGN.md §15).

Pure literal by contract: the sanitizer reads it with ``ast.literal_eval``
(no imports, no arithmetic), so sizes are plain ints (16777216 = 16 MiB).
Tile defaults here must match the keyword-only defaults in kernel.py —
the sanitizer flags drift in either direction.
"""

KERNEL_META = {
    "package": "bfs_step",
    "vmem_budget_bytes": {"tpu": 16777216},
    # assumed sizes for non-tile block dims in the static VMEM estimate:
    # tc = tw * 32 (the packed kernel's derived column-tile width)
    "dims": {"tc": 256},
    "kernels": {
        "bfs_step_pallas": {
            "tiles": {"tr": 256, "tc": 256},
            "align": {"tr": 8, "tc": 128},
            "divides": {"v": ["tr", "tc"]},
            "operands": {
                "frontier": {"block": ["tr"], "dtype": "float32"},
                "adj": {"block": ["tr", "tc"], "dtype": "uint8"},
                "alive": {"block": ["tc"], "dtype": "int32"},
                "visited": {"block": ["tc"], "dtype": "int32"},
            },
            "outputs": {
                "new": {"block": ["tc"], "dtype": "int32"},
                "parent": {"block": ["tc"], "dtype": "int32"},
            },
            "packed": False,
            "pad_safety": None,
            "wrapper": "bfs_step",
            "ref": "bfs_step_ref",
            "scratch_bytes": 0,
        },
        "bfs_step_packed_pallas": {
            "tiles": {"tr": 256, "tw": 8},
            "align": {"tr": 8, "tw": 8},
            "divides": {"v": ["tr"], "w": ["tw"]},
            "operands": {
                "frontier": {"block": ["tr"], "dtype": "float32"},
                "adj_packed": {"block": ["tr", "tw"], "dtype": "uint32"},
                "alive": {"block": ["tc"], "dtype": "int32"},
                "visited": {"block": ["tc"], "dtype": "int32"},
            },
            "outputs": {
                "new": {"block": ["tc"], "dtype": "int32"},
                "parent": {"block": ["tc"], "dtype": "int32"},
                "reach_words": {"block": ["tw"], "dtype": "uint32"},
            },
            "packed": True,
            "pad_safety": "slice",
            "wrapper": "bfs_step_packed",
            "ref": "bfs_step_packed_ref",
            "scratch_bytes": 0,
        },
    },
}
