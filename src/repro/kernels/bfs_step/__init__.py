from repro.kernels.bfs_step.ops import bfs_step  # noqa: F401
from repro.kernels.bfs_step.ref import bfs_step_ref  # noqa: F401
