"""Pallas TPU kernel: one BFS frontier-expansion superstep.

The hot loop of the paper's TreeCollect, re-thought for the TPU memory
hierarchy (DESIGN.md §4): instead of chasing ENode pointers through HBM, we
stream (TR x TC) adjacency tiles HBM->VMEM and feed the MXU a rank-1-ish
mat-vec per tile:

    reach[c-tile]  |= any_r ( frontier[r-tile] @ adj[r-tile, c-tile] )   (MXU)
    parent[c-tile]  = min_r  first set row index                          (VPU)

Grid = (col_tiles, row_tiles), row axis innermost so each output tile is
produced once and revisited across the reduction ("arbitrary" dimension
semantics). Empty frontier tiles are skipped with @pl.when — the sparse-
frontier optimization that makes late BFS supersteps cheap (most tiles have
no active rows), the analogue of the paper only walking live edge-lists.

VMEM footprint per program instance (TR=TC=256, defaults):
    adj tile      256*256 f32   = 256 KiB
    frontier tile 256 f32       =   1 KiB
    out tiles     2 * 256 i32   =   2 KiB          << 16 MiB VMEM
MXU alignment: TR, TC multiples of 128 (f32/bf16 tiles).

The PACKED variant (``bfs_step_packed_pallas``, DESIGN.md §10) streams the
word-packed adjacency instead — uint32[TR, TW] tiles, 32x less HBM traffic
per superstep — and replaces the MXU mat-vec with a popcount-free bitwise
OR fold over the frontier rows' words (a log2(TR) halving tree on the VPU).
Parent extraction unpacks the tile IN REGISTERS (VMEM-resident compute is
free relative to the HBM stream this kernel exists to shrink).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.graph import WORD_BITS, or_reduce, unpack_bits

INT32_MAX = 2**31 - 1  # python int: pallas kernels must not capture tracers


def _bfs_step_kernel(f_ref, adj_ref, alive_ref, visited_ref, reach_ref, parent_ref, *, tr: int):
    c, r = pl.program_id(0), pl.program_id(1)
    nr = pl.num_programs(1)

    @pl.when(r == 0)
    def _init():
        reach_ref[...] = jnp.zeros_like(reach_ref)
        parent_ref[...] = jnp.full_like(parent_ref, INT32_MAX)

    f = f_ref[...]  # f32[TR]

    @pl.when(jnp.any(f > 0))
    def _accumulate():
        a = adj_ref[...].astype(jnp.float32)          # [TR, TC] (bf16 on MXU)
        hits = jnp.dot(f[None, :], a, preferred_element_type=jnp.float32)[0]
        reach_ref[...] = jnp.maximum(reach_ref[...], (hits > 0).astype(jnp.int32))
        row_ids = (r * tr + jax.lax.iota(jnp.int32, tr))[:, None]
        cand = jnp.where((f[:, None] > 0) & (a > 0), row_ids, INT32_MAX)
        parent_ref[...] = jnp.minimum(parent_ref[...], jnp.min(cand, axis=0))

    @pl.when(r == nr - 1)
    def _epilogue():
        new = (reach_ref[...] > 0) & (alive_ref[...] > 0) & (visited_ref[...] == 0)
        reach_ref[...] = new.astype(jnp.int32)
        parent_ref[...] = jnp.where(new, parent_ref[...], jnp.int32(-1))


@functools.partial(
    jax.jit, static_argnames=("tr", "tc", "interpret")
)
def bfs_step_pallas(frontier, adj, alive, visited, *, tr: int = 256, tc: int = 256,
                    interpret: bool = True):
    """One frontier expansion. All inputs length-V / VxV, V % max(tr,tc) == 0.

    frontier: f32[V] (0/1)   adj: int8/uint8[V, V]
    alive:    int32[V] (0/1) visited: int32[V] (0/1)
    Returns (new_frontier int32[V], parent int32[V]).
    """
    v = adj.shape[0]
    assert v % tr == 0 and v % tc == 0, (v, tr, tc)
    grid = (v // tc, v // tr)
    return pl.pallas_call(
        functools.partial(_bfs_step_kernel, tr=tr),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr,), lambda c, r: (r,)),
            pl.BlockSpec((tr, tc), lambda c, r: (r, c)),
            pl.BlockSpec((tc,), lambda c, r: (c,)),
            pl.BlockSpec((tc,), lambda c, r: (c,)),
        ],
        out_specs=[
            pl.BlockSpec((tc,), lambda c, r: (c,)),
            pl.BlockSpec((tc,), lambda c, r: (c,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((v,), jnp.int32),
            jax.ShapeDtypeStruct((v,), jnp.int32),
        ],
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(frontier, adj, alive, visited)


# ----------------------------------------------------------------------------
# Packed-word variant (DESIGN.md §10)
# ----------------------------------------------------------------------------
def _bfs_step_packed_kernel(f_ref, adjw_ref, alive_ref, visited_ref,
                            reach_ref, parent_ref, words_ref, *, tr: int,
                            tw: int):
    c, r = pl.program_id(0), pl.program_id(1)
    nr = pl.num_programs(1)
    tc = tw * WORD_BITS

    @pl.when(r == 0)
    def _init():
        words_ref[...] = jnp.zeros_like(words_ref)
        reach_ref[...] = jnp.zeros_like(reach_ref)
        parent_ref[...] = jnp.full_like(parent_ref, INT32_MAX)

    f = f_ref[...]  # f32[TR]

    @pl.when(jnp.any(f > 0))
    def _accumulate():
        a = adjw_ref[...]                             # uint32[TR, TW]
        sel = jnp.where(f[:, None] > 0, a, jnp.uint32(0))
        words_ref[...] |= or_reduce(sel, 0)           # VPU halving OR tree
        bits = unpack_bits(a, tc)                     # in-register unpack
        row_ids = (r * tr + jax.lax.iota(jnp.int32, tr))[:, None]
        cand = jnp.where((f[:, None] > 0) & bits, row_ids, INT32_MAX)
        parent_ref[...] = jnp.minimum(parent_ref[...], jnp.min(cand, axis=0))

    @pl.when(r == nr - 1)
    def _epilogue():
        reach = unpack_bits(words_ref[...], tc)
        new = reach & (alive_ref[...] > 0) & (visited_ref[...] == 0)
        reach_ref[...] = new.astype(jnp.int32)
        parent_ref[...] = jnp.where(new, parent_ref[...], jnp.int32(-1))


@functools.partial(
    jax.jit, static_argnames=("tr", "tw", "interpret")
)
def bfs_step_packed_pallas(frontier, adj_packed, alive, visited, *,
                           tr: int = 256, tw: int = 8,
                           interpret: bool = True):
    """One packed frontier expansion. V % tr == 0, W % tw == 0, and the
    alive/visited vectors cover the padded column range W * 32.

    frontier: f32[V] (0/1)     adj_packed: uint32[V, W]
    alive:    int32[W*32]      visited: int32[W*32]
    Returns (new_frontier int32[W*32], parent int32[W*32], reach_words
    uint32[W]); callers slice the column padding back off.
    """
    v, w = adj_packed.shape
    assert v % tr == 0 and w % tw == 0, (v, w, tr, tw)
    vc = w * WORD_BITS
    assert alive.shape == (vc,) and visited.shape == (vc,), \
        (alive.shape, visited.shape, vc)
    tc = tw * WORD_BITS
    grid = (w // tw, v // tr)
    return pl.pallas_call(
        functools.partial(_bfs_step_packed_kernel, tr=tr, tw=tw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr,), lambda c, r: (r,)),
            pl.BlockSpec((tr, tw), lambda c, r: (r, c)),
            pl.BlockSpec((tc,), lambda c, r: (c,)),
            pl.BlockSpec((tc,), lambda c, r: (c,)),
        ],
        out_specs=[
            pl.BlockSpec((tc,), lambda c, r: (c,)),
            pl.BlockSpec((tc,), lambda c, r: (c,)),
            pl.BlockSpec((tw,), lambda c, r: (c,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((vc,), jnp.int32),
            jax.ShapeDtypeStruct((vc,), jnp.int32),
            jax.ShapeDtypeStruct((w,), jnp.uint32),
        ],
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(frontier, adj_packed, alive, visited)
