"""Pure-jnp oracle for the edge_update kernel (lane-order write semantics)."""
from __future__ import annotations

import jax.numpy as jnp


def edge_update_ref(adj, ecnt, rows, cols, vals, mask):
    """Same contract as kernel.edge_update_pallas.

    Duplicate (row, col) targets: LAST masked lane wins (lane order =
    linearization order). ecnt gains one increment per masked lane on its row.
    """
    v = adj.shape[0]
    b = rows.shape[0]
    lane = jnp.arange(b, dtype=jnp.int32)
    live = mask > 0
    flat = jnp.where(live, rows * v + cols, -1)
    # stable sort by target; within a target group lanes ascend, so a lane is
    # the group's winner iff the next sorted entry targets something else.
    order = jnp.argsort(flat, stable=True)
    sflat = flat[order]
    last_of_group = jnp.concatenate([sflat[:-1] != sflat[1:], jnp.array([True])])
    winner = order[last_of_group & (sflat >= 0)] if b else order[:0]
    # jnp.where with size: use boolean scatter instead (jit-safe)
    win_mask = jnp.zeros((b,), bool).at[order].set(last_of_group & (sflat >= 0))
    wrows = jnp.where(win_mask, rows, v)  # drop non-winners
    wcols = jnp.where(win_mask, cols, v)
    adj2 = adj.at[wrows, wcols].set(jnp.asarray(vals, adj.dtype), mode="drop")
    erow = jnp.where(live, rows, v)
    ecnt2 = ecnt.at[erow].add(1, mode="drop")
    return adj2, ecnt2


def edge_update_packed_ref(adj_packed, ecnt, rows, cols, vals, mask):
    """Same contract as kernel.edge_update_packed_pallas — defined as the
    dense oracle conjugated by pack/unpack, which IS the packed semantics."""
    from repro.core.graph import WORD_BITS, pack_bits, unpack_bits

    v, w = adj_packed.shape
    vc = w * WORD_BITS
    # unpack to [V, W*32] (the ref's parked col index v stays in range: the
    # engine guarantees fired cols < v <= W*32), run the dense oracle, repack
    adj = unpack_bits(adj_packed, vc).astype(jnp.uint8)
    a2, e2 = edge_update_ref(adj, ecnt, rows, cols, vals, mask)
    return pack_bits(a2.astype(jnp.bool_)), e2
