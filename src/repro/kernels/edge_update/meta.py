"""KERNEL_META for the edge_update package — checked by the kernel-shape
sanitizer (``python -m repro.analysis``, DESIGN.md §15).

Pure literal by contract (``ast.literal_eval`` is the parser). The
adjacency/ecnt dtypes are passthrough (``"*"`` — the kernel's out_shape
reuses the operand dtype), and the whole fired batch ``b`` rides along in
every tile. The packed variant's padding story is ``"mask"``: the kernel
read-modify-writes single bits via shifted masks (``1 << (c % 32)``), so
padding bits in the uint32 words are preserved by construction rather
than sliced off by the wrapper.
"""

KERNEL_META = {
    "package": "edge_update",
    "vmem_budget_bytes": {"tpu": 16777216},
    # b = fired-batch length, v = dense column count, w = packed words
    "dims": {"b": 1024, "v": 2048, "w": 64},
    "kernels": {
        "edge_update_pallas": {
            "tiles": {"tr": 8},
            "align": {"tr": 8},
            "divides": {"v": ["tr"]},
            "operands": {
                "rows": {"block": ["b"], "dtype": "int32"},
                "cols": {"block": ["b"], "dtype": "int32"},
                "vals": {"block": ["b"], "dtype": "int32"},
                "mask": {"block": ["b"], "dtype": "int32"},
                "adj": {"block": ["tr", "v"], "dtype": "*"},
                "ecnt": {"block": ["tr"], "dtype": "*"},
            },
            "outputs": {
                "adj": {"block": ["tr", "v"], "dtype": "*"},
                "ecnt": {"block": ["tr"], "dtype": "*"},
            },
            "packed": False,
            "pad_safety": None,
            "wrapper": "edge_update",
            "ref": "edge_update_ref",
            "scratch_bytes": 0,
        },
        "edge_update_packed_pallas": {
            "tiles": {"tr": 8},
            "align": {"tr": 8},
            "divides": {"v": ["tr"]},
            "operands": {
                "rows": {"block": ["b"], "dtype": "int32"},
                "cols": {"block": ["b"], "dtype": "int32"},
                "vals": {"block": ["b"], "dtype": "int32"},
                "mask": {"block": ["b"], "dtype": "int32"},
                "adj_packed": {"block": ["tr", "w"], "dtype": "*"},
                "ecnt": {"block": ["tr"], "dtype": "*"},
            },
            "outputs": {
                "adj_packed": {"block": ["tr", "w"], "dtype": "*"},
                "ecnt": {"block": ["tr"], "dtype": "*"},
            },
            "packed": True,
            "pad_safety": "mask",
            "wrapper": "edge_update_packed",
            "ref": "edge_update_packed_ref",
            "scratch_bytes": 0,
        },
    },
}
