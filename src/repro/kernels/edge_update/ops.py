"""jit'd public wrapper for the edge_update kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.edge_update.kernel import (
    edge_update_packed_pallas,
    edge_update_pallas,
)


def _pick_tile(v: int) -> int:
    for t in (8, 4, 2):
        if v % t == 0:
            return t
    return 1


@functools.partial(jax.jit, static_argnames=())
def edge_update(adj, ecnt, rows, cols, vals, mask):
    """Apply pre-resolved edge writes; see kernel module docstring."""
    t = _pick_tile(adj.shape[0])
    return edge_update_pallas(
        adj, ecnt,
        rows.astype(jnp.int32), cols.astype(jnp.int32),
        vals.astype(jnp.int32), mask.astype(jnp.int32),
        tr=t, interpret=True,  # CPU container; on TPU set interpret=False
    )


@functools.partial(jax.jit, static_argnames=())
def edge_update_packed(adj_packed, ecnt, rows, cols, vals, mask):
    """Packed form: masked single-bit set/clear per fired op (DESIGN.md §10).

    adj_packed: uint32[V, ceil(V/32)] — the GraphState storage format.
    """
    t = _pick_tile(adj_packed.shape[0])
    return edge_update_packed_pallas(
        adj_packed, ecnt,
        rows.astype(jnp.int32), cols.astype(jnp.int32),
        vals.astype(jnp.int32), mask.astype(jnp.int32),
        tr=t, interpret=True,  # CPU container; on TPU set interpret=False
    )
