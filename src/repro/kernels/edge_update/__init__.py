from repro.kernels.edge_update.ops import edge_update  # noqa: F401
from repro.kernels.edge_update.ref import edge_update_ref  # noqa: F401
