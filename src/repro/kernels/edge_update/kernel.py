"""Pallas TPU kernel: batched versioned edge writes (the CAS-apply hot spot).

Applies B pre-resolved edge writes (row, col, val, mask) to the adjacency
tiles and bumps the per-row ``ecnt`` counters — the vectorized form of the
paper's { CAS(enxt) ; FetchAndAdd(ecnt) } pair. The *decision* of which ops
fire (EDGE ADDED vs EDGE PRESENT, CAS pass/fail) is made by the engine
(core/ops.py); this kernel is the bandwidth-bound application step.

Grid = (row_tiles,). Each program owns a (TR x V) adjacency stripe in VMEM
and scans the op batch with predicated scalar stores; writes are applied in
lane order so duplicate (row, col) targets resolve to the last lane — the
batch linearization order. ecnt increments accumulate one per fired op
(duplicates included), matching the engine and the oracle.

VMEM: TR=8, V<=8192 -> 64 KiB stripe; op batch arrays are tiny. On real TPU
the stripe copy-in/out is elided by donating buffers at the jit boundary
(the updates are in-place at the XLA level via input_output_aliasing there).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _edge_update_kernel(rows_ref, cols_ref, vals_ref, mask_ref, adj_in_ref,
                        ecnt_in_ref, adj_ref, ecnt_ref, *, tr: int):
    t = pl.program_id(0)
    b = rows_ref.shape[0]
    row0 = t * tr

    # initialize output stripe from input stripe
    adj_ref[...] = adj_in_ref[...]
    ecnt_ref[...] = ecnt_in_ref[...]

    def body(i, _):
        r = rows_ref[i]
        c = cols_ref[i]
        vmask = mask_ref[i] > 0
        local = r - row0
        in_tile = (local >= 0) & (local < tr) & vmask
        li = jnp.clip(local, 0, tr - 1)

        @pl.when(in_tile)
        def _apply():
            adj_ref[li, c] = vals_ref[i].astype(adj_ref.dtype)
            ecnt_ref[li] = ecnt_ref[li] + 1

        return 0

    jax.lax.fori_loop(0, b, body, 0)


@functools.partial(jax.jit, static_argnames=("tr", "interpret"))
def edge_update_pallas(adj, ecnt, rows, cols, vals, mask, *, tr: int = 8, interpret: bool = True):
    """adj uint8[V,V], ecnt int32[V]; rows/cols/vals/mask int32[B].

    Returns (adj', ecnt'). Rows with mask==0 are ignored. Fired ops must have
    in-range rows/cols (engine guarantees).
    """
    v = adj.shape[0]
    assert v % tr == 0
    grid = (v // tr,)
    return pl.pallas_call(
        functools.partial(_edge_update_kernel, tr=tr),
        grid=grid,
        in_specs=[
            pl.BlockSpec(rows.shape, lambda t: (0,)),
            pl.BlockSpec(cols.shape, lambda t: (0,)),
            pl.BlockSpec(vals.shape, lambda t: (0,)),
            pl.BlockSpec(mask.shape, lambda t: (0,)),
            pl.BlockSpec((tr, v), lambda t: (t, 0)),
            pl.BlockSpec((tr,), lambda t: (t,)),
        ],
        out_specs=[
            pl.BlockSpec((tr, v), lambda t: (t, 0)),
            pl.BlockSpec((tr,), lambda t: (t,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(adj.shape, adj.dtype),
            jax.ShapeDtypeStruct(ecnt.shape, ecnt.dtype),
        ],
        interpret=interpret,
    )(rows, cols, vals, mask, adj, ecnt)


# ----------------------------------------------------------------------------
# Packed-word variant (DESIGN.md §10): each fired op is a masked single-BIT
# set/clear on one uint32 word of the stripe — the row stripe it streams is
# 32x narrower than the dense kernel's.
# ----------------------------------------------------------------------------
def _edge_update_packed_kernel(rows_ref, cols_ref, vals_ref, mask_ref,
                               adj_in_ref, ecnt_in_ref, adj_ref, ecnt_ref,
                               *, tr: int):
    t = pl.program_id(0)
    b = rows_ref.shape[0]
    row0 = t * tr

    adj_ref[...] = adj_in_ref[...]
    ecnt_ref[...] = ecnt_in_ref[...]

    def body(i, _):
        r = rows_ref[i]
        c = cols_ref[i]
        vmask = mask_ref[i] > 0
        local = r - row0
        in_tile = (local >= 0) & (local < tr) & vmask
        li = jnp.clip(local, 0, tr - 1)
        wi = c // 32
        bit = jnp.uint32(1) << (c % 32).astype(jnp.uint32)

        @pl.when(in_tile)
        def _apply():
            cur = adj_ref[li, wi]
            adj_ref[li, wi] = jnp.where(vals_ref[i] > 0, cur | bit,
                                        cur & ~bit)
            ecnt_ref[li] = ecnt_ref[li] + 1

        return 0

    jax.lax.fori_loop(0, b, body, 0)


@functools.partial(jax.jit, static_argnames=("tr", "interpret"))
def edge_update_packed_pallas(adj_packed, ecnt, rows, cols, vals, mask, *,
                              tr: int = 8, interpret: bool = True):
    """adj_packed uint32[V, W], ecnt int32[V]; rows/cols/vals/mask int32[B].

    Returns (adj_packed', ecnt'). Same lane-order last-wins semantics as the
    dense kernel; a fired op flips exactly one bit of one word.
    """
    v, w = adj_packed.shape
    assert v % tr == 0
    grid = (v // tr,)
    return pl.pallas_call(
        functools.partial(_edge_update_packed_kernel, tr=tr),
        grid=grid,
        in_specs=[
            pl.BlockSpec(rows.shape, lambda t: (0,)),
            pl.BlockSpec(cols.shape, lambda t: (0,)),
            pl.BlockSpec(vals.shape, lambda t: (0,)),
            pl.BlockSpec(mask.shape, lambda t: (0,)),
            pl.BlockSpec((tr, w), lambda t: (t, 0)),
            pl.BlockSpec((tr,), lambda t: (t,)),
        ],
        out_specs=[
            pl.BlockSpec((tr, w), lambda t: (t, 0)),
            pl.BlockSpec((tr,), lambda t: (t,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(adj_packed.shape, adj_packed.dtype),
            jax.ShapeDtypeStruct(ecnt.shape, ecnt.dtype),
        ],
        interpret=interpret,
    )(rows, cols, vals, mask, adj_packed, ecnt)
