from repro.kernels.label_join.ops import label_join  # noqa: F401
from repro.kernels.label_join.ref import label_join_ref  # noqa: F401
