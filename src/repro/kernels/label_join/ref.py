"""Pure-jnp oracle for the label_join kernel."""
from __future__ import annotations

import jax.numpy as jnp

INT32_MAX = jnp.int32(2**31 - 1)


def label_join_ref(out_rows, in_rows):
    """Same contract as kernel.label_join_pallas.

    out_rows int32[Q, L] (0/1) — OUT labels of the Q query sources
    in_rows  int32[Q, L] (0/1) — IN labels of the Q query destinations
    -> (hits int32[Q]  — number of common landmarks (2-hop witnesses),
        hub  int32[Q]  — smallest common landmark index, -1 if none)
    """
    q, l = out_rows.shape
    if l == 0:
        return (jnp.zeros((q,), jnp.int32), jnp.full((q,), -1, jnp.int32))
    common = (out_rows > 0) & (in_rows > 0)
    hits = jnp.sum(common.astype(jnp.int32), axis=1)
    ids = jnp.arange(l, dtype=jnp.int32)
    hub = jnp.min(jnp.where(common, ids[None, :], INT32_MAX), axis=1)
    hub = jnp.where(hits > 0, hub, jnp.int32(-1))
    return hits, hub
