"""Pure-jnp oracles for the label_join kernels (dense and packed)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.graph import WORD_BITS

INT32_MAX = jnp.int32(2**31 - 1)


def label_join_ref(out_rows, in_rows):
    """Same contract as kernel.label_join_pallas.

    out_rows int32[Q, L] (0/1) — OUT labels of the Q query sources
    in_rows  int32[Q, L] (0/1) — IN labels of the Q query destinations
    -> (hits int32[Q]  — number of common landmarks (2-hop witnesses),
        hub  int32[Q]  — smallest common landmark index, -1 if none)
    """
    q, l = out_rows.shape
    if l == 0:
        return (jnp.zeros((q,), jnp.int32), jnp.full((q,), -1, jnp.int32))
    common = (out_rows > 0) & (in_rows > 0)
    hits = jnp.sum(common.astype(jnp.int32), axis=1)
    ids = jnp.arange(l, dtype=jnp.int32)
    hub = jnp.min(jnp.where(common, ids[None, :], INT32_MAX), axis=1)
    hub = jnp.where(hits > 0, hub, jnp.int32(-1))
    return hits, hub


def label_join_packed_ref(out_words, in_words):
    """Same contract as kernel.label_join_packed_pallas.

    out_words/in_words uint32[Q, W] packed label bitsets ->
    (hits int32[Q], hub int32[Q]): popcount of the AND-ed words, smallest
    common set-bit index via the ctz(x) = popcount(lowbit(x) - 1) identity.
    """
    q, w = out_words.shape
    if w == 0:
        return (jnp.zeros((q,), jnp.int32), jnp.full((q,), -1, jnp.int32))
    common = out_words & in_words
    hits = jnp.sum(jax.lax.population_count(common).astype(jnp.int32), axis=1)
    low = common & (jnp.uint32(0) - common)
    ctz = jax.lax.population_count(low - jnp.uint32(1)).astype(jnp.int32)
    lane0 = jnp.arange(w, dtype=jnp.int32) * WORD_BITS
    cand = jnp.where(common > 0, lane0[None, :] + ctz, INT32_MAX)
    hub = jnp.min(cand, axis=1)
    hub = jnp.where(hits > 0, hub, jnp.int32(-1))
    return hits, hub
