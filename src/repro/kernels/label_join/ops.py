"""jit'd public wrapper for the label_join kernel (adapts index dtypes).

Pads the query axis up to the sublane multiple (8) and the landmark axis up
to the lane multiple (128) so the label slabs are legal TPU tiles, runs the
masked-intersect kernel, and slices the padding back off. Padded queries and
padded landmark lanes carry all-zero labels, so they contribute neither hits
nor hub candidates — the @pl.when pruned-tile skip removes most of them
outright.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.label_join.kernel import (
    label_join_packed_pallas,
    label_join_pallas,
)
from repro.kernels.bfs_step.ops import _pick_tile, _pick_word_tile

_Q_ALIGN = 8    # sublane multiple
_L_ALIGN = 128  # lane multiple


@functools.partial(jax.jit, static_argnames=())
def label_join(out_rows, in_rows):
    """Drop-in replacement for kernels.label_join.ref.label_join_ref
    (bool interface).

    out_rows/in_rows: bool[Q, L] — OUT labels of the Q sources / IN labels
    of the Q destinations -> (hits int32[Q], hub int32[Q]).
    """
    q, l = out_rows.shape
    if q == 0 or l == 0:  # static shapes — resolved at trace time
        return (jnp.zeros((q,), jnp.int32), jnp.full((q,), -1, jnp.int32))
    qpad = -(-q // _Q_ALIGN) * _Q_ALIGN
    lpad = -(-l // _L_ALIGN) * _L_ALIGN
    a = jnp.zeros((qpad, lpad), jnp.int32).at[:q, :l].set(
        out_rows.astype(jnp.int32))
    b = jnp.zeros((qpad, lpad), jnp.int32).at[:q, :l].set(
        in_rows.astype(jnp.int32))
    hits, hub = label_join_pallas(
        a,
        b,
        tq=_pick_tile(qpad),
        tl=_pick_tile(lpad),
        interpret=True,  # CPU container; on TPU set interpret=False
    )
    return hits[:q], hub[:q]


@functools.partial(jax.jit, static_argnames=())
def label_join_packed(out_words, in_words):
    """Drop-in replacement for label_join_packed_ref (packed interface,
    DESIGN.md §10).

    out_words/in_words: uint32[Q, W] packed label bitsets
    -> (hits int32[Q], hub int32[Q]). Padded queries/words carry zero bits,
    so they contribute neither hits nor hub candidates.
    """
    q, w = out_words.shape
    if q == 0 or w == 0:  # static shapes — resolved at trace time
        return (jnp.zeros((q,), jnp.int32), jnp.full((q,), -1, jnp.int32))
    qpad = -(-q // _Q_ALIGN) * _Q_ALIGN
    a = jnp.zeros((qpad, w), jnp.uint32).at[:q].set(out_words)
    b = jnp.zeros((qpad, w), jnp.uint32).at[:q].set(in_words)
    hits, hub = label_join_packed_pallas(
        a,
        b,
        tq=_pick_tile(qpad),
        tw=_pick_word_tile(w),
        interpret=True,  # CPU container; on TPU set interpret=False
    )
    return hits[:q], hub[:q]
