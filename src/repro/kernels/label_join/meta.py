"""KERNEL_META for the label_join package — checked by the kernel-shape
sanitizer (``python -m repro.analysis``, DESIGN.md §15).

Pure literal by contract (``ast.literal_eval`` is the parser). The packed
variant reduces uint32 label words to dense int32 (hits, hub) outputs;
its padding story is ``"slice"`` — the ops.py wrapper zero-extends padded
queries in and slices ``[:q]`` back out, and zero padding bits contribute
neither hits nor hub candidates (popcount/ctz of 0).
"""

KERNEL_META = {
    "package": "label_join",
    "vmem_budget_bytes": {"tpu": 16777216},
    "dims": {},
    "kernels": {
        "label_join_pallas": {
            "tiles": {"tq": 256, "tl": 256},
            "align": {"tq": 8, "tl": 128},
            "divides": {"q": ["tq"], "l": ["tl"]},
            "operands": {
                "out_rows": {"block": ["tq", "tl"], "dtype": "int32"},
                "in_rows": {"block": ["tq", "tl"], "dtype": "int32"},
            },
            "outputs": {
                "hits": {"block": ["tq"], "dtype": "int32"},
                "hub": {"block": ["tq"], "dtype": "int32"},
            },
            "packed": False,
            "pad_safety": None,
            "wrapper": "label_join",
            "ref": "label_join_ref",
            "scratch_bytes": 0,
        },
        "label_join_packed_pallas": {
            "tiles": {"tq": 256, "tw": 8},
            "align": {"tq": 8, "tw": 8},
            "divides": {"q": ["tq"], "w": ["tw"]},
            "operands": {
                "out_words": {"block": ["tq", "tw"], "dtype": "uint32"},
                "in_words": {"block": ["tq", "tw"], "dtype": "uint32"},
            },
            "outputs": {
                "hits": {"block": ["tq"], "dtype": "int32"},
                "hub": {"block": ["tq"], "dtype": "int32"},
            },
            "packed": True,
            "pad_safety": "slice",
            "wrapper": "label_join_packed",
            "ref": "label_join_packed_ref",
            "scratch_bytes": 0,
        },
    },
}
