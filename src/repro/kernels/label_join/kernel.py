"""Pallas TPU kernel: batched 2-hop label intersection (DESIGN.md §9).

One reachability-index probe answers Q (src, dst) queries with a single
masked intersect over the landmark axis:

    hits[q] = |{ i : out_label[src_q, i] AND in_label[dst_q, i] }|
    hub[q]  = min such i   (-1 if none)

i.e. the diagonal of the [Q, L] · [L, Q] label product, computed directly as
an elementwise AND + lane reduction — no MXU needed, the whole probe is one
VPU pass over the [Q, L] label slabs. Grid = (q_tiles, l_tiles) with the
landmark axis innermost ("arbitrary" reduction semantics): each [TQ] output
tile is produced once and revisited across landmark tiles.

Pruning pays off here: the canonical-hub pruning of labels.py zeroes most of
the label matrix, so entire [TQ, TL] OUT tiles are all-zero and are skipped
with ``@pl.when`` — the same empty-tile fast path the BFS kernels use for
retired frontiers. A probe over a well-pruned index touches only the few
tiles holding surviving hub bits.

VMEM per program instance (TQ=256, TL=256): 2 label tiles * 256*256 i32
= 512 KiB, plus two [TQ] i32 accumulators — far under the 16 MiB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.graph import WORD_BITS

INT32_MAX = 2**31 - 1  # python int: pallas kernels must not capture tracers


def _label_join_kernel(out_ref, in_ref, hits_ref, hub_ref, *, tl: int):
    li = pl.program_id(1)
    nl = pl.num_programs(1)

    @pl.when(li == 0)
    def _init():
        hits_ref[...] = jnp.zeros_like(hits_ref)
        hub_ref[...] = jnp.full_like(hub_ref, INT32_MAX)

    a = out_ref[...]  # i32[TQ, TL] — OUT-label slice of this landmark tile

    # pruned-tile skip: a landmark tile none of the Q sources kept a label
    # bit in contributes nothing — canonical-hub pruning makes this the
    # common case (labels concentrate on the few high-degree hubs)
    @pl.when(jnp.any(a > 0))
    def _accumulate():
        common = (a > 0) & (in_ref[...] > 0)                  # [TQ, TL]
        hits_ref[...] += jnp.sum(common.astype(jnp.int32), axis=1)
        lane = li * tl + jax.lax.iota(jnp.int32, tl)          # global hub ids
        cand = jnp.where(common, lane[None, :], INT32_MAX)
        hub_ref[...] = jnp.minimum(hub_ref[...], jnp.min(cand, axis=1))

    @pl.when(li == nl - 1)
    def _epilogue():
        hub_ref[...] = jnp.where(hits_ref[...] > 0, hub_ref[...],
                                 jnp.int32(-1))


@functools.partial(jax.jit, static_argnames=("tq", "tl", "interpret"))
def label_join_pallas(out_rows, in_rows, *, tq: int = 256, tl: int = 256,
                      interpret: bool = True):
    """Batched label intersection. Q % tq == 0 and L % tl == 0.

    out_rows: int32[Q, L] (0/1)   in_rows: int32[Q, L] (0/1)
    Returns (hits int32[Q], hub int32[Q]) — common-landmark count per query
    and the smallest common landmark index (-1 when the intersection is
    empty). Q is the already-padded query-slab height; callers align it to
    the sublane multiple (kernels/label_join/ops.py pads).
    """
    q, l = out_rows.shape
    assert in_rows.shape == (q, l), (out_rows.shape, in_rows.shape)
    assert q % tq == 0 and l % tl == 0, (q, l, tq, tl)
    grid = (q // tq, l // tl)
    return pl.pallas_call(
        functools.partial(_label_join_kernel, tl=tl),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, tl), lambda qi, li: (qi, li)),
            pl.BlockSpec((tq, tl), lambda qi, li: (qi, li)),
        ],
        out_specs=[
            pl.BlockSpec((tq,), lambda qi, li: (qi,)),
            pl.BlockSpec((tq,), lambda qi, li: (qi,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q,), jnp.int32),
            jax.ShapeDtypeStruct((q,), jnp.int32),
        ],
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(out_rows, in_rows)


# ----------------------------------------------------------------------------
# Packed-word variant (DESIGN.md §10): labels stored as uint32 bitsets over
# the landmark axis — hits is a popcount of AND-ed words, hub a
# count-trailing-zeros on the lowest set bit. 32x less label traffic.
# ----------------------------------------------------------------------------
def _label_join_packed_kernel(out_ref, in_ref, hits_ref, hub_ref, *, tw: int):
    li = pl.program_id(1)
    nl = pl.num_programs(1)

    @pl.when(li == 0)
    def _init():
        hits_ref[...] = jnp.zeros_like(hits_ref)
        hub_ref[...] = jnp.full_like(hub_ref, INT32_MAX)

    a = out_ref[...]  # uint32[TQ, TW]

    @pl.when(jnp.any(a > 0))
    def _accumulate():
        common = a & in_ref[...]
        hits_ref[...] += jnp.sum(
            jax.lax.population_count(common).astype(jnp.int32), axis=1)
        # smallest set bit per word: ctz(x) = popcount(lowbit(x) - 1)
        low = common & (jnp.uint32(0) - common)
        ctz = jax.lax.population_count(low - jnp.uint32(1)).astype(jnp.int32)
        lane0 = (li * tw + jax.lax.iota(jnp.int32, tw)) * WORD_BITS
        cand = jnp.where(common > 0, lane0[None, :] + ctz, INT32_MAX)
        hub_ref[...] = jnp.minimum(hub_ref[...], jnp.min(cand, axis=1))

    @pl.when(li == nl - 1)
    def _epilogue():
        hub_ref[...] = jnp.where(hits_ref[...] > 0, hub_ref[...],
                                 jnp.int32(-1))


@functools.partial(jax.jit, static_argnames=("tq", "tw", "interpret"))
def label_join_packed_pallas(out_words, in_words, *, tq: int = 256,
                             tw: int = 8, interpret: bool = True):
    """Packed batched label intersection. Q % tq == 0 and W % tw == 0.

    out_words/in_words: uint32[Q, W] — packed OUT labels of the Q sources /
    IN labels of the Q destinations. Returns (hits int32[Q], hub int32[Q])
    with hub the smallest common landmark index (-1 when empty), identical
    to the dense kernel on the unpacked labels.
    """
    q, w = out_words.shape
    assert in_words.shape == (q, w), (out_words.shape, in_words.shape)
    assert q % tq == 0 and w % tw == 0, (q, w, tq, tw)
    grid = (q // tq, w // tw)
    return pl.pallas_call(
        functools.partial(_label_join_packed_kernel, tw=tw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, tw), lambda qi, li: (qi, li)),
            pl.BlockSpec((tq, tw), lambda qi, li: (qi, li)),
        ],
        out_specs=[
            pl.BlockSpec((tq,), lambda qi, li: (qi,)),
            pl.BlockSpec((tq,), lambda qi, li: (qi,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q,), jnp.int32),
            jax.ShapeDtypeStruct((q,), jnp.int32),
        ],
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(out_words, in_words)
