"""KERNEL_META for the bfs_pull_step package — checked by the
kernel-shape sanitizer (``python -m repro.analysis``, DESIGN.md §15).

Pure literal by contract (``ast.literal_eval`` is the parser): 16777216 =
16 MiB VMEM budget, 4194304 = the 4 MiB pull-broadcast scratch budget
(kernel.py's ``_PULL_BCAST_BUDGET``). ``q`` is the padded query-slab
height and ``w`` the packed frontier word count (V = 2048 -> 64 words)
assumed for the static footprint estimate. The frontier operand is
packed but the OUTPUTS are dense int32 rows, so there are no padding
bits to protect on the way out (packed: False).
"""

KERNEL_META = {
    "package": "bfs_pull_step",
    "vmem_budget_bytes": {"tpu": 16777216},
    "dims": {"q": 64, "w": 64},
    "kernels": {
        "bfs_pull_step_pallas": {
            "tiles": {"tr": 256},
            "align": {"tr": 8},
            "divides": {"r": ["tr"]},
            "operands": {
                "frontier_words": {"block": ["q", "w"], "dtype": "uint32"},
                "adj_in_rows": {"block": ["tr", "w"], "dtype": "uint32"},
                "alive": {"block": ["tr"], "dtype": "int32"},
                "visited": {"block": ["q", "tr"], "dtype": "int32"},
            },
            "outputs": {
                "new": {"block": ["q", "tr"], "dtype": "int32"},
                "parent": {"block": ["q", "tr"], "dtype": "int32"},
            },
            "packed": False,
            "pad_safety": None,
            "wrapper": "multi_bfs_pull_step_rows",
            "ref": "bfs_pull_step_ref",
            "scratch_bytes": 4194304,
        },
    },
}
