from repro.kernels.bfs_pull_step.ops import (  # noqa: F401
    bfs_pull_step,
    multi_bfs_pull_step,
    multi_bfs_pull_step_rows,
)
from repro.kernels.bfs_pull_step.ref import bfs_pull_step_ref  # noqa: F401
