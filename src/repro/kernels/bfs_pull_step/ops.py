"""jit'd public wrappers for the bfs_pull_step kernel (adapt GraphState dtypes).

Pads the query axis up to the sublane multiple (8) so the frontier-word
slab and the [Q, R] output tiles are legal TPU blocks, runs the pull
kernel, and slices the padding back off. Padded queries carry an all-zero
frontier bitset, so they can never produce a hit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.graph import pack_bits
from repro.kernels.bfs_pull_step.kernel import bfs_pull_step_pallas
from repro.kernels.bfs_step.ops import _pick_tile

_Q_ALIGN = 8  # sublane multiple for the 32-bit slabs


@functools.partial(jax.jit, static_argnames=())
def multi_bfs_pull_step_rows(frontier_words, adj_in_rows, alive_rows,
                             visited_rows):
    """Row-slice pull step — the sharded engine's form (DESIGN.md §8, §11).

    frontier_words: uint32[Q, W] (packed frontier & alive bitsets);
    adj_in_rows: uint32[R, W] (R == V, or one shard's column-sharded
    in-rows); alive_rows: bool[R]; visited_rows: bool[Q, R]
    -> (new bool[Q, R], parent int32[Q, R])

    Parent ids are GLOBAL frontier bit indices (read off the word axis),
    so the sharded caller needs no row-offset fixup.
    """
    q, w = frontier_words.shape
    rows = adj_in_rows.shape[0]
    qpad = -(-q // _Q_ALIGN) * _Q_ALIGN
    fwp = jnp.zeros((qpad, w), jnp.uint32).at[:q].set(frontier_words)
    visp = jnp.zeros((qpad, rows), jnp.int32).at[:q].set(
        visited_rows.astype(jnp.int32))
    new, parent = bfs_pull_step_pallas(
        fwp,
        adj_in_rows,
        alive_rows.astype(jnp.int32),
        visp,
        tr=_pick_tile(rows),
        interpret=True,  # CPU container; on TPU set interpret=False
    )
    return new[:q] > 0, parent[:q]


@functools.partial(jax.jit, static_argnames=())
def multi_bfs_pull_step(frontiers, adj_in_packed, alive, visited):
    """Drop-in replacement for core.bfs.multi_bfs_step_pull_jnp (bool
    interface): frontiers bool[Q, V]; adj_in_packed uint32[V, W]; alive
    bool[V]; visited bool[Q, V] -> (new bool[Q, V], parent int32[Q, V])."""
    fw = pack_bits(frontiers & alive[None, :])
    return multi_bfs_pull_step_rows(fw, adj_in_packed, alive, visited)


@functools.partial(jax.jit, static_argnames=())
def bfs_pull_step(frontier, adj_in_packed, alive, visited):
    """Single-query drop-in for core.bfs.bfs_step_pull_jnp (bool interface):
    frontier/alive/visited bool[V]; adj_in_packed uint32[V, W]
    -> (new bool[V], parent int32[V])."""
    new, parent = multi_bfs_pull_step(
        frontier[None, :], adj_in_packed, alive, visited[None, :])
    return new[0], parent[0]
