"""Pure-jnp oracle for the bfs_pull_step kernel (same words-level contract)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.bfs import ctz32
from repro.core.graph import WORD_BITS

# python int, not jnp.int32: this module is imported lazily, possibly inside
# a jit trace, and a module-level device constant would leak a tracer
INT32_MAX = 2**31 - 1


def bfs_pull_step_ref(frontier_words, adj_in_rows, alive, visited):
    """Same contract as kernel.bfs_pull_step_pallas.

    frontier_words uint32[Q, W], adj_in_rows uint32[R, W], alive int32[R]
    (0/1), visited int32[Q, R] (0/1) -> (new int32[Q, R], parent
    int32[Q, R]).
    """
    w = adj_in_rows.shape[1]
    cand = adj_in_rows[None, :, :] & frontier_words[:, None, :]  # [Q, R, W]
    nz = cand != jnp.uint32(0)
    widx = (jnp.arange(w, dtype=jnp.int32) * WORD_BITS)[None, None, :]
    pc = jnp.where(nz, widx + ctz32(cand), INT32_MAX)
    pmin = jnp.min(pc, axis=2)
    hit = jnp.any(nz, axis=2)
    new = hit & (alive[None, :] > 0) & (visited == 0)
    return new.astype(jnp.int32), jnp.where(new, pmin, jnp.int32(-1))
