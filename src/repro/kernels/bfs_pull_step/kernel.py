"""Pallas TPU kernel: one bottom-up ("pull") BFS superstep (DESIGN.md §11).

The direction-optimizing counterpart of kernels/bfs_step &
kernels/bfs_multi_step: when the frontier covers a large fraction of the
graph, top-down push streams almost every adjacency row only to rediscover
vertices it already visited. Pull inverts the scan — every NOT-yet-visited
vertex ANDs its own maintained packed in-adjacency row against the packed
frontier bitset(s):

    hit[q, r]    = any_w ( adj_in[r, w] & frontier_words[q, w] )
    parent[q, r] = lowest set bit index of adj_in[r, :] & frontier_words[q, :]

Because the in-adjacency is maintained first-class (core/ops.py mirrors
every edge RMW; the transpose invariant pins it), the kernel streams
uint32[TR, W] word tiles straight from the stored representation — no
transpose, no unpack on the HBM path.

Grid = (row_tiles,): each program owns TR destination rows and the FULL
word axis, so the kernel is embarrassingly parallel — there is NO
cross-tile reduction (the push kernels revisit each output tile across an
"arbitrary" row-tile axis; pull's reduction runs over the word axis,
entirely in-tile). Row tiles where every row is already visited or dead —
most tiles in late supersteps — skip the word scan with @pl.when, the pull
analogue of the push kernels' empty-frontier-tile skip.

Parent extraction: the first frontier parent of row r is the lowest set
bit of the AND-ed words. Any nonzero word at index w dominates every later
word in the masked min (32*w + ctz < 32*(w+1)), so the vectorized min over
words IS the per-word early exit — the scan effectively stops at the first
word containing a parent. ctz comes from the two's-complement low-bit
trick (x & -x, then popcount(x-1)); both verified native on uint32.

VMEM footprint per program instance (TQ=8, TR=256, W=32 ⇒ V=1024):
    adj_in tile    256*32 u32      =  32 KiB
    frontier slab  8*32 u32        =   1 KiB
    candidate cube 8*256*32 u32    = 256 KiB        << 16 MiB VMEM
Larger (TQ * TR * W) volumes fall back to a fori_loop over query rows
holding one [TR, W] slice at a time — the same static budget switch as
kernels/bfs_multi_step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.graph import WORD_BITS

INT32_MAX = 2**31 - 1  # python int: pallas kernels must not capture tracers

# static switch: largest [TQ, TR, W] pull-candidate volume (bytes) we are
# willing to materialize in VMEM before falling back to the per-query loop
_PULL_BCAST_BUDGET = 4 * 1024 * 1024


def _ctz32(words):
    """Count-trailing-zeros per uint32 word (32 for zero words; callers
    mask those out)."""
    low = words & (jnp.uint32(0) - words)
    return jax.lax.population_count(low - jnp.uint32(1)).astype(jnp.int32)


def _bfs_pull_step_kernel(fw_ref, adjin_ref, alive_ref, visited_ref,
                          new_ref, parent_ref, *, tq: int, tr: int, w: int,
                          bcast_budget: int):
    new_ref[...] = jnp.zeros_like(new_ref)
    parent_ref[...] = jnp.full_like(parent_ref, -1)

    fw = fw_ref[...]                                   # uint32 [TQ, W]
    todo = (alive_ref[...][None, :] > 0) & (visited_ref[...] == 0)  # [TQ, TR]

    @pl.when(jnp.any(todo) & jnp.any(fw != 0))
    def _scan():
        a = adjin_ref[...]                             # uint32 [TR, W]
        widx = jax.lax.iota(jnp.int32, w) * WORD_BITS  # global bit bases
        if tq * tr * w * 4 <= bcast_budget:
            cand = a[None, :, :] & fw[:, None, :]      # [TQ, TR, W]
            nz = cand != jnp.uint32(0)
            pc = jnp.where(nz, widx[None, None, :] + _ctz32(cand), INT32_MAX)
            pmin = jnp.min(pc, axis=2)                 # [TQ, TR]
            hit = jnp.any(nz, axis=2)
        else:
            def qrow(qi, acc):
                pm, ht = acc
                fq = jax.lax.dynamic_slice_in_dim(fw, qi, 1, axis=0)[0]
                c = a & fq[None, :]                    # [TR, W]
                nzq = c != jnp.uint32(0)
                pcq = jnp.where(nzq, widx[None, :] + _ctz32(c), INT32_MAX)
                pm = jax.lax.dynamic_update_slice_in_dim(
                    pm, jnp.min(pcq, axis=1)[None, :], qi, axis=0)
                ht = jax.lax.dynamic_update_slice_in_dim(
                    ht, jnp.any(nzq, axis=1)[None, :], qi, axis=0)
                return pm, ht

            pmin, hit = jax.lax.fori_loop(
                0, tq, qrow,
                (jnp.full((tq, tr), INT32_MAX, jnp.int32),
                 jnp.zeros((tq, tr), jnp.bool_)))
        new = hit & todo
        new_ref[...] = new.astype(jnp.int32)
        parent_ref[...] = jnp.where(new, pmin, jnp.int32(-1))


@functools.partial(
    jax.jit, static_argnames=("tr", "interpret", "pull_bcast_budget")
)
def bfs_pull_step_pallas(frontier_words, adj_in_rows, alive, visited, *,
                         tr: int = 256, interpret: bool = True,
                         pull_bcast_budget: int = _PULL_BCAST_BUDGET):
    """One pull expansion of Q frontiers over R destination rows. R % tr == 0.

    frontier_words: uint32[Q, W] — packed (frontier & alive) bitsets
    adj_in_rows:    uint32[R, W] — maintained packed in-adjacency rows
    alive:          int32[R] (0/1) — liveness of the destination rows
    visited:        int32[Q, R] (0/1)
    Returns (new int32[Q, R], parent int32[Q, R]).

    ``adj_in_rows`` may be a contiguous ROW SLICE of the in-adjacency — the
    sharded engine's column-sharded in-rows (DESIGN.md §8, §11): outputs
    then cover exactly those destination rows, while parent ids are GLOBAL
    frontier bit indices read off the word axis, so the caller needs no
    row-offset fixup (unlike the push kernels' slice-relative parents).

    Q is the full (already padded) query-slab height; callers align it to
    the sublane multiple (kernels/bfs_pull_step/ops.py pads).
    ``pull_bcast_budget`` is static (part of the jit key), pinning the
    candidate-volume strategy per compilation; pass 0 to force the
    per-query fori_loop path.
    """
    q, w = frontier_words.shape
    r = adj_in_rows.shape[0]
    assert adj_in_rows.shape[1] == w, (frontier_words.shape, adj_in_rows.shape)
    assert alive.shape == (r,) and visited.shape == (q, r), \
        (alive.shape, visited.shape, (q, r))
    assert r % tr == 0, (r, tr)
    grid = (r // tr,)
    return pl.pallas_call(
        functools.partial(_bfs_pull_step_kernel, tq=q, tr=tr, w=w,
                          bcast_budget=pull_bcast_budget),
        grid=grid,
        in_specs=[
            pl.BlockSpec((q, w), lambda i: (0, 0)),
            pl.BlockSpec((tr, w), lambda i: (i, 0)),
            pl.BlockSpec((tr,), lambda i: (i,)),
            pl.BlockSpec((q, tr), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((q, tr), lambda i: (0, i)),
            pl.BlockSpec((q, tr), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, r), jnp.int32),
            jax.ShapeDtypeStruct((q, r), jnp.int32),
        ],
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel",))
        ) if not interpret else None,
        interpret=interpret,
    )(frontier_words, adj_in_rows, alive, visited)
