"""Error-feedback int8 gradient compression for cross-pod reductions.

At 1000+ node scale the data-parallel all-reduce crosses the DCN (pod) axis;
compressing gradients 4x (f32->int8 with per-tensor scale) before the slow
hop and carrying the quantization residual forward (error feedback) is the
standard trick to keep convergence intact.

Used by runtime/train_loop.py when cfg.grad_compress is set: gradients are
(1) reduced in full precision over the fast intra-pod axes, (2) quantized,
(3) summed over "pod" via jax.lax.psum on the int-encoded tensor inside
shard_map (or, under plain jit, simulated by quantize->dequantize so XLA
still sees the reduction in low precision), (4) dequantized with residual
accumulation.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: dict  # same tree as grads, f32


def init(params) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params))


def _quant(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, ef: EFState):
    """Quantize+dequantize each gradient leaf with error feedback.

    Returns (decompressed_grads, new_EFState). The round-trip is what the
    receiving side of an int8 reduce would see; the residual keeps the
    information the quantizer dropped for the next step.
    """
    def leaf(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = _quant(gf)
        deq = _dequant(q, s)
        return deq, gf - deq

    out = jax.tree.map(leaf, grads, ef.residual)
    newg = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)
    newr = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)
    return newg, EFState(residual=newr)
