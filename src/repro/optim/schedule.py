"""LR schedules: linear warmup + cosine decay (the standard LM recipe)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr, warmup_steps, total_steps, final_frac=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    t = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = final_frac * peak_lr + (1 - final_frac) * peak_lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm, cos)


def constant(step, *, peak_lr, **_):
    return jnp.asarray(peak_lr, jnp.float32)
