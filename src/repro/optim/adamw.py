"""AdamW with decoupled weight decay — pytree-native, shard-transparent.

Optimizer state inherits the parameters' sharding (same tree structure), so
ZeRO-3-style sharding falls out of GSPMD when params are FSDP-sharded.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def update(params, grads, state: AdamWState, *, lr, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """Returns (new_params, new_state). ``lr`` may be a scalar or schedule value."""
    step = state.step + 1

    if grad_clip:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)
