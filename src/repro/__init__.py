"""repro: Chatterjee et al. (2018)'s concurrent non-blocking unbounded graph
with reachability queries, as a TPU-native multi-pod JAX framework.

Subpackages: core (the paper's ADT), kernels (Pallas), models, configs,
parallel, optim, checkpoint, data, runtime, launch. See README.md.
"""

__version__ = "0.1.0"
