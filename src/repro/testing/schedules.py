"""Schedule-exploring linearizability harness for multi-tenant ingestion.

One driver for every concurrency suite (DESIGN.md §12): it generates
N-client schedules (interleaved batch submissions, admission rounds, and
snapshot reads) with a controllable conflict rate, executes them against
the ingest pool (``repro.runtime.ingest``) on dense or sharded state, and
checks the paper's linearizability claim restated at serving scale:

  the final state of any admitted parallel execution is BIT-identical to
  *some* serial order of the client batches — concretely, to the pool's
  claimed linearization replayed through the sequential reference engine
  (``apply_ops``) and the sequential oracle (``core.oracle.GraphOracle``)
  — and every read observed a state some linearization prefix produces.

Three layers:

  * generation — ``gen_client_programs`` (randomized, conflict-rate
    controlled), ``random_schedule`` (seeded interleavings),
    ``enumerate_interleavings`` (exact enumeration for small programs),
    plus ``op_strategy``/``batch_lists_strategy`` hypothesis-style
    factories shared with tests/test_linearizability_prop.py;
  * execution — ``run_schedule`` drives a schedule through an IngestPool
    and returns a ``Trace`` (tickets, reads with their snapshot epochs,
    the claimed linearization);
  * checking + shrinking — ``check_trace_linearizable`` (program order,
    oracle results, bit-identity, read consistency, within-round
    commutativity), and ``shrink_schedule``: a deterministic greedy
    minimizer that deletes steps and lanes while a failure predicate keeps
    holding, so a falsified property lands as a readable counterexample.
"""
from __future__ import annotations

import itertools
import os
import random
from dataclasses import dataclass, field

import numpy as np

from repro.core import (
    OP_ADD_E,
    OP_ADD_V,
    OP_CON_E,
    OP_CON_V,
    OP_REM_E,
    OP_REM_V,
    R_TABLE_FULL,
    GraphOracle,
    apply_ops,
    get_paths_session,
    grow,
    make_graph,
    make_op_batch,
)
from repro.core import partition
from repro.core.graph import OPCODE_NAMES
from repro.runtime.fault import SimulatedCrash
from repro.runtime.ingest import IngestPool

# ---------------------------------------------------------------------------
# Schedule representation
# ---------------------------------------------------------------------------
# Steps (plain tuples so schedules print/shrink trivially):
#   ("submit", client_id, [op, ...])   enqueue one client batch
#   ("pump",)                          one admission round
#   ("read", [(k, l), ...])            reachability read on the published epoch
#   ("read_epoch", [(k, l), ...])      HOSTILE wait-free read: every state
#                                      fetch ships a fresh mutation touching
#                                      the query's dependency set before
#                                      returning, so the double collect can
#                                      never match and the session must
#                                      resolve against a pinned published
#                                      epoch (DESIGN.md §13)
#   ("tt", back, [(k, l), ...])        time-travel read at the epoch ``back``
#                                      publishes before the newest (clamped
#                                      to the retention window)
#   ("flush",)                         drain the queue


@dataclass
class Schedule:
    steps: list = field(default_factory=list)

    def submits(self):
        return [s for s in self.steps if s[0] == "submit"]

    def pretty(self) -> str:
        """Readable transcript — what a shrunk counterexample prints as."""
        lines = []
        for i, s in enumerate(self.steps):
            if s[0] == "submit":
                ops = ", ".join(_op_str(op) for op in s[2])
                lines.append(f"{i:3d}  submit {s[1]:<8} [{ops}]")
            elif s[0] in ("read", "read_epoch"):
                pairs = ", ".join(f"{k}->{l}" for k, l in s[1])
                lines.append(f"{i:3d}  {s[0]:<6} {pairs}")
            elif s[0] == "tt":
                pairs = ", ".join(f"{k}->{l}" for k, l in s[2])
                lines.append(f"{i:3d}  tt -{s[1]:<4} {pairs}")
            else:
                lines.append(f"{i:3d}  {s[0]}")
        return "\n".join(lines)


def _op_str(op) -> str:
    name = OPCODE_NAMES.get(op[0], f"op{op[0]}")
    body = "/".join(str(x) for x in op[1:3][: 2 if op[0] in _EDGE_OPS else 1])
    cas = f" cas={op[3]}" if len(op) > 3 and op[3] >= 0 else ""
    return f"{name} {body}{cas}"


_EDGE_OPS = (OP_ADD_E, OP_REM_E, OP_CON_E)
_ALL_OPS = (OP_ADD_V, OP_REM_V, OP_CON_V, OP_ADD_E, OP_REM_E, OP_CON_E)


# ---------------------------------------------------------------------------
# Generation
# ---------------------------------------------------------------------------
def _norm(op) -> tuple:
    """Normalize to a (opcode, k1, k2, expect) 4-tuple."""
    k1 = op[1] if len(op) > 1 else -1
    k2 = op[2] if len(op) > 2 else -1
    ex = op[3] if len(op) > 3 else -1
    return (int(op[0]), int(k1), int(k2), int(ex))


def gen_op(rng: random.Random, keys, *, remv_rate=0.15, cas_rate=0.15):
    """One random op over the given key pool."""
    r = rng.random()
    if r < remv_rate:
        opc = OP_REM_V
    else:
        opc = rng.choice([OP_ADD_V, OP_ADD_V, OP_CON_V, OP_ADD_E, OP_ADD_E,
                          OP_REM_E, OP_CON_E])
    k1, k2 = rng.choice(keys), rng.choice(keys)
    ex = rng.choice([0, 1, 2]) \
        if opc in (OP_ADD_E, OP_REM_E) and rng.random() < cas_rate else -1
    return (opc, k1, k2, ex)


def gen_client_programs(rng: random.Random, *, clients=3, batches_per_client=2,
                        max_lanes=5, hot_keys=4, private_keys=3,
                        conflict_rate=0.5, remv_rate=0.1, cas_rate=0.15):
    """Per-client batch programs with a controllable conflict rate.

    Each client owns a private key range; with probability ``conflict_rate``
    an op draws its keys from the SHARED hot set instead — ``conflict_rate=0``
    makes every batch pairwise entity-disjoint (maximal parallel admission),
    ``1.0`` funnels everything through the hot set (maximal contention,
    the colliding-entity workloads the linearizability suite needs).
    """
    hot = list(range(hot_keys))
    programs: dict[str, list[list]] = {}
    for c in range(clients):
        cid = f"c{c}"
        private = list(range(100 * (c + 1), 100 * (c + 1) + private_keys))
        batches = []
        for _ in range(batches_per_client):
            lanes = rng.randint(1, max_lanes)
            ops = []
            for _ in range(lanes):
                pool = hot if rng.random() < conflict_rate else private
                ops.append(_norm(gen_op(rng, pool, remv_rate=remv_rate,
                                        cas_rate=cas_rate)))
            batches.append(ops)
        programs[cid] = batches
    return programs


def _read_keys(programs) -> list[int]:
    keys = sorted({k for batches in programs.values() for ops in batches
                   for op in ops for k in op[1:3] if k >= 0})
    return keys or [0]


def random_schedule(rng: random.Random, programs, *, read_rate=0.3,
                    pump_rate=0.5, reads_pairs=2, epoch_read_rate=0.0,
                    tt_read_rate=0.0) -> Schedule:
    """Seeded random interleaving of the client programs.

    Per-client submission order is preserved (program order); pump and
    read steps are sprinkled between submissions; a trailing flush + read
    makes every schedule end fully drained and observed.

    ``epoch_read_rate``/``tt_read_rate`` sprinkle hostile epoch-resolved
    reads and time-travel reads (DESIGN.md §13). Both default to 0 and the
    zero case draws NOTHING from ``rng``, so pre-existing seeded schedules
    stay byte-identical.
    """
    pending = {c: list(batches) for c, batches in programs.items()}
    keys = _read_keys(programs)
    steps: list = []
    while any(pending.values()):
        c = rng.choice([c for c, b in pending.items() if b])
        steps.append(("submit", c, pending[c].pop(0)))
        if rng.random() < pump_rate:
            steps.append(("pump",))
        if rng.random() < read_rate:
            pairs = [(rng.choice(keys), rng.choice(keys))
                     for _ in range(reads_pairs)]
            steps.append(("read", pairs))
        if epoch_read_rate > 0 and rng.random() < epoch_read_rate:
            pairs = [(rng.choice(keys), rng.choice(keys))
                     for _ in range(reads_pairs)]
            steps.append(("read_epoch", pairs))
        if tt_read_rate > 0 and rng.random() < tt_read_rate:
            pairs = [(rng.choice(keys), rng.choice(keys))
                     for _ in range(reads_pairs)]
            steps.append(("tt", rng.randint(0, 4), pairs))
    steps.append(("flush",))
    steps.append(("read", [(keys[0], keys[-1]), (keys[-1], keys[0])]))
    return Schedule(steps)


def enumerate_interleavings(programs, *, pump_after_each=True, limit=64):
    """EVERY merge order of the per-client batch sequences (small programs).

    Yields at most ``limit`` schedules; the enumeration is exact when the
    multinomial count fits. Each submission is followed by an admission
    round when ``pump_after_each`` (the tightest schedule: every batch is
    exposed to conflict detection alone), and every schedule ends drained.
    """
    clients = sorted(programs)
    tokens = [c for c in clients for _ in programs[c]]
    seen = set()
    count = 0
    for perm in itertools.permutations(tokens):
        if perm in seen:
            continue
        seen.add(perm)
        idx = {c: 0 for c in clients}
        steps: list = []
        for c in perm:
            steps.append(("submit", c, programs[c][idx[c]]))
            idx[c] += 1
            if pump_after_each:
                steps.append(("pump",))
        steps.append(("flush",))
        yield Schedule(steps)
        count += 1
        if count >= limit:
            return


# ---------------------------------------------------------------------------
# Hypothesis-style strategy factories (shared with the engine prop suite)
# ---------------------------------------------------------------------------
def op_strategy(st, *, max_key=5, cas_choices=(-1, -1, -1, 0, 1, 2)):
    """(opcode, k1, k2, expect) strategy over a small colliding key space.

    ``st`` is either the real ``hypothesis.strategies`` or the
    ``repro.testing.proptest`` fallback — both expose the same factories.
    """
    keys = st.integers(min_value=0, max_value=max_key)
    opc = st.sampled_from(list(_ALL_OPS))
    return st.tuples(opc, keys, keys, st.sampled_from(list(cas_choices)))


def batch_strategy(st, *, min_size=1, max_size=10, **op_kw):
    return st.lists(op_strategy(st, **op_kw), min_size=min_size,
                    max_size=max_size)


def batch_lists_strategy(st, *, min_batches=1, max_batches=4, **batch_kw):
    """Lists of op batches — the engine property suites' input shape."""
    return st.lists(batch_strategy(st, **batch_kw), min_size=min_batches,
                    max_size=max_batches)


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------
@dataclass
class ReadObs:
    epoch: int             # the epoch the observation linearizes at
    pairs: list
    results: list          # [(found, keys)] per pair
    mode: str = "head"     # "head" | "epoch" (wait-free resolved) | "tt"
    starved: bool = False  # session exhausted its budget (mode "epoch")


@dataclass
class CrashInfo:
    """Everything the harness snapshotted at the instant a durability
    crash stage killed the pool (DESIGN.md §16): the published prefix the
    recovered process must reproduce bit-identically."""

    stage: str                 # FaultInjector stage that fired
    step_index: int            # schedule step the crash landed in
    epoch_attempted: int       # epoch the dying round would have published
    published_epoch: int       # last epoch visible to readers pre-crash
    linearization: list        # published linearization prefix at crash
    epoch_log: dict            # epoch -> prefix length map at crash
    acked: list                # batch_ids acknowledged (status "applied")
    head_fields: dict          # field -> np.ndarray of the published head
    ring_states: dict          # epoch -> {field -> np.ndarray} over window


@dataclass
class Trace:
    schedule: Schedule
    pool: IngestPool
    capacity: int          # initial capacity the pool started from
    mesh: object
    reads: list = field(default_factory=list)
    durable_dir: str | None = None   # WAL + checkpoint root (None = undurable)
    crash: CrashInfo | None = None   # set when a durability stage killed the run

    @property
    def linearization(self):
        return self.pool.linearization


def _hostile_epoch_read(pool: IngestPool, pairs, *, max_rounds=3) -> ReadObs:
    """One wait-free read under the WORST §3.5 adversary: every state fetch
    first commits a mutation that bumps the ``ecnt`` of every query source
    (a fresh sink vertex plus one out-edge per source), so consecutive
    collects can never match over the dependency set and the session must
    resolve against a pinned published epoch (DESIGN.md §13). The
    observation is tagged with that epoch, so ``check_trace_linearizable``
    obligation (4) proves the wait-free answer equals a serial prefix."""
    srcs = sorted({int(k) for k, _ in pairs})
    last_epoch = [pool.epoch]

    def hostile_fetch():
        fresh = 9000 + pool.stats.submitted   # outside every client key range
        pool.submit("_hostile", [_norm((OP_ADD_V, fresh))]
                    + [_norm((OP_ADD_E, k, fresh)) for k in srcs])
        pool.pump()
        epoch, snap = pool.snapshot_epoch()
        last_epoch[0] = epoch
        return snap

    st: dict = {}
    out, _ = get_paths_session(hostile_fetch, pairs, max_rounds=max_rounds,
                               on_conflict="epoch",
                               fetch_epoch=pool.snapshot_epoch, stats=st)
    epoch = st["epoch"] if st["epoch"] is not None else last_epoch[0]
    return ReadObs(int(epoch), list(pairs), out, mode="epoch",
                   starved=bool(st["starved"]))


def run_schedule(schedule: Schedule, *, capacity=32, mesh=None, fault=None,
                 auto_grow=True, max_inflight=8, max_coalesce_lanes=256,
                 pad_lanes=True, retain_epochs=64, durable_dir=None,
                 ckpt_every=0) -> Trace:
    """Execute a schedule against a fresh IngestPool; returns its Trace.

    Reads are taken against the pool's PUBLISHED snapshot epoch — a frozen
    functional state — so each observation is tagged with the exact
    linearization prefix it must be explained by (DESIGN.md §12).
    ``read_epoch``/``tt`` steps additionally exercise the retained epoch
    ring: their observations carry the pinned/addressed epoch and flow
    through the same prefix check (DESIGN.md §13).

    ``durable_dir`` attaches a WAL (and, with ``ckpt_every`` > 0, cadence
    checkpoints) under that directory. A ``FaultInjector`` durability
    stage then kills the run mid-schedule: the trace comes back with
    ``crash`` set to the published prefix snapshot, and
    ``check_recovery_equivalent`` proves a recovered pool reproduces it
    bit-identically (DESIGN.md §16).
    """
    dense = make_graph(capacity)
    state = partition.shard_state(mesh, dense) if mesh is not None else dense
    wal = ckpt = None
    if durable_dir is not None:
        from repro.runtime.recovery import GraphCheckpointer
        from repro.runtime.wal import WriteAheadLog

        wal = WriteAheadLog(os.path.join(durable_dir, "wal.log"))
        ckpt = GraphCheckpointer(os.path.join(durable_dir, "ckpt"))
    pool = IngestPool(state, mesh=mesh, auto_grow=auto_grow,
                      max_inflight=max_inflight,
                      max_coalesce_lanes=max_coalesce_lanes,
                      pad_lanes=pad_lanes, fault=fault,
                      retain_epochs=retain_epochs, wal=wal, ckpt=ckpt,
                      ckpt_every=ckpt_every)
    trace = Trace(schedule, pool, capacity, mesh, durable_dir=durable_dir)
    step_index = 0
    try:
        for step_index, step in enumerate(schedule.steps):
            if step[0] == "submit":
                pool.submit(step[1], step[2])
            elif step[0] == "pump":
                pool.pump()
            elif step[0] == "flush":
                pool.flush()
            elif step[0] == "read":
                epoch, snap = pool.snapshot_epoch()
                out, _ = get_paths_session(lambda: snap, step[1])
                trace.reads.append(ReadObs(epoch, list(step[1]), out))
            elif step[0] == "read_epoch":
                trace.reads.append(_hostile_epoch_read(pool, step[1]))
            elif step[0] == "tt":
                lo, hi = pool.epoch_window()
                epoch = max(lo, hi - int(step[1]))
                snap = pool.state_at(epoch)
                out, _ = get_paths_session(lambda: snap, step[2])
                trace.reads.append(ReadObs(epoch, list(step[2]), out,
                                           mode="tt"))
            else:  # pragma: no cover - schedule author error
                raise ValueError(f"unknown step {step!r}")
        step_index = len(schedule.steps)
        pool.flush()       # every trace ends drained (checkable end state)
    except SimulatedCrash as exc:
        # the process is "dead": snapshot the published prefix the
        # recovered one must be proven bit-identical to
        trace.crash = _capture_crash(pool, exc, step_index, mesh)
        if wal is not None:
            wal.close()
    return trace


def _capture_crash(pool: IngestPool, exc: SimulatedCrash, step_index: int,
                   mesh) -> CrashInfo:
    """Freeze everything a pre-crash reader could have observed: the
    published head, every retained ring epoch, the linearization prefix,
    and the set of acknowledged batches."""
    epoch, snap = pool.snapshot_epoch()
    dense = partition.unshard(snap) if mesh is not None else snap
    head = {f: np.asarray(getattr(dense, f)).copy() for f in dense._fields}
    ring_states: dict = {}
    lo, hi = pool.ring.window()
    for e in range(lo, hi + 1):
        s = pool.state_at(e)
        if mesh is not None and getattr(s, "mesh", None) is not None:
            s = partition.unshard(s)
        ring_states[e] = {f: np.asarray(getattr(s, f)).copy()
                          for f in s._fields}
    acked = sorted(bid for bid, t in pool.tickets.items()
                   if t.status == "applied")
    return CrashInfo(stage=exc.stage, step_index=step_index,
                     epoch_attempted=int(exc.epoch),
                     published_epoch=int(epoch),
                     linearization=list(pool.linearization),
                     epoch_log=dict(pool.epoch_log), acked=acked,
                     head_fields=head, ring_states=ring_states)


# ---------------------------------------------------------------------------
# Checking
# ---------------------------------------------------------------------------
def _dense_head(trace: Trace):
    head = trace.pool._head
    return partition.unshard(head) if trace.mesh is not None else head


def _serial_replay_bits(trace: Trace):
    """Replay the claimed linearization through the sequential reference
    engine (``apply_ops``), batch by batch, with the same grow-on-overflow
    discipline — the serial execution the parallel one must equal, bit for
    bit."""
    state = make_graph(trace.capacity)
    results = {}
    for bid in trace.linearization:
        t = trace.pool.tickets[bid]
        batch = make_op_batch(t.ops)
        state2, res = apply_ops(state, batch)
        res = np.asarray(res)
        while trace.pool.auto_grow and (res == R_TABLE_FULL).any():
            state = grow(state, 2 * state.capacity)
            state2, res = apply_ops(state, batch)
            res = np.asarray(res)
        state = state2
        results[bid] = res
    return state, results


def check_trace_linearizable(trace: Trace, *, permute_limit=24) -> None:
    """Assert the trace is linearizable (DESIGN.md §12). Five obligations:

    1. the claimed linearization is exactly the applied batches, once each,
       respecting every client's program (submission) order;
    2. oracle equivalence: replaying it through the sequential oracle
       reproduces every delivered result code;
    3. bit-identity: replaying it through ``apply_ops`` batch-by-batch
       reproduces the pool head state bit for bit (dense and sharded);
    4. read consistency: every read equals BFS over the oracle state at its
       snapshot epoch's linearization prefix;
    5. commutativity: batches coalesced into ONE fused call are entity-
       disjoint, so any within-round permutation must be oracle-equivalent
       (same results, same abstract state) — ``permute_limit`` caps the
       permutations tried per round.
    """
    pool = trace.pool
    lin = list(pool.linearization)
    applied = {bid for bid, t in pool.tickets.items() if t.status == "applied"}

    # (1) claimed order is a permutation of the applied set, program order kept
    assert sorted(lin) == sorted(applied), \
        f"linearization {lin} != applied set {sorted(applied)}"
    by_client: dict[str, list[int]] = {}
    for bid in lin:
        by_client.setdefault(pool.tickets[bid].client_id, []).append(bid)
    for cid, bids in by_client.items():
        assert bids == sorted(bids), \
            f"client {cid} program order violated in linearization: {bids}"

    # (2) oracle replay reproduces every delivered result code
    final_cap = _dense_head(trace).capacity
    oracle = _oracle_after(trace, lin, capacity=final_cap)

    # (3) bit-identity against the serial reference replay
    head = _dense_head(trace)
    serial_state, serial_results = _serial_replay_bits(trace)
    for name, a, b in zip(head._fields, head, serial_state):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"parallel execution diverges from its serial order "
                    f"in field {name!r}")
    for bid in lin:
        np.testing.assert_array_equal(
            pool.tickets[bid].results, serial_results[bid],
            err_msg=f"batch {bid} results diverge from serial replay")

    # (4) reads: explained by the linearization prefix at their epoch.
    # This covers plain head reads AND the §13 surfaces: a wait-free
    # epoch-resolved read and a time-travel read both linearize at the
    # epoch they carry, so the same prefix obligation applies.
    for obs in trace.reads:
        prefix = pool.epoch_log.get(obs.epoch)
        if prefix is None:
            # the epoch left the bounded retention window between the read
            # and the check (tiny retain_epochs in eviction suites) — no
            # prefix left to validate against
            continue
        ora = _oracle_after(trace, lin[:prefix], capacity=final_cap,
                            check_results=False)
        for (k, l), (found, keys) in zip(obs.pairs, obs.results):
            want = ora.reachable(k, l)
            assert found == want, \
                (f"read {k}->{l} at epoch {obs.epoch} saw found={found}, "
                 f"prefix state says {want}")
            if found:
                assert ora.is_valid_path(keys, k, l), \
                    f"read {k}->{l} returned a non-path {keys}"

    # (5) within-round commutativity: any permutation of a fused round is
    # an equally valid serial order
    for group in fused_groups(trace):
        if len(group) < 2:
            continue
        pos = {bid: i for i, bid in enumerate(lin)}
        for perm in itertools.islice(
                itertools.permutations(group), permute_limit):
            order = list(lin)
            for slot, bid in zip(sorted(pos[b] for b in group), perm):
                order[slot] = bid
            alt = _oracle_after(trace, order, capacity=final_cap)
            assert alt.state_tuple() == oracle.state_tuple(), \
                (f"round {group} does not commute: permutation {perm} "
                 f"reaches a different abstract state")


def fused_groups(trace: Trace) -> list[list[int]]:
    """Batch-id groups coalesced into one fused apply, per publish epoch."""
    log = trace.pool.epoch_log
    groups = []
    for epoch in sorted(log):
        if epoch == 0 or epoch - 1 not in log:
            # the predecessor was pruned out of the bounded retention
            # window (DESIGN.md §13) — the group boundary is unrecoverable
            continue
        lo, hi = log[epoch - 1], log[epoch]
        groups.append(trace.pool.linearization[lo:hi])
    return groups


def _oracle_after(trace: Trace, order, *, capacity, check_results=True
                  ) -> GraphOracle:
    """Oracle state after replaying ``order``; optionally asserts each
    batch's delivered result codes match the oracle's."""
    oracle = GraphOracle(capacity)
    for bid in order:
        t = trace.pool.tickets[bid]
        want = oracle.apply_batch(t.ops)
        if check_results:
            got = [int(x) for x in t.results]
            assert got == want, \
                (f"batch {bid} (client {t.client_id}) results {got} diverge "
                 f"from oracle {want} in order {list(order)}")
    return oracle


def check_aborted_invisible(trace: Trace) -> None:
    """Fault-injection obligation: aborted batches left NO trace — the head
    state is produced by the completed batches alone (no torn fused apply),
    and their entity locks were released (DESIGN.md §12)."""
    pool = trace.pool
    aborted = [t for t in pool.tickets.values() if t.status == "aborted"]
    for t in aborted:
        assert t.results is None, f"aborted batch {t.batch_id} has results"
        assert t.batch_id not in pool.linearization
        for entity in t.footprint:
            assert not pool.locks.held(entity), \
                f"aborted batch {t.batch_id} leaked lock on entity {entity}"
    check_trace_linearizable(trace)


# ---------------------------------------------------------------------------
# Crash recovery equivalence (DESIGN.md §16)
# ---------------------------------------------------------------------------
def recover_trace(trace: Trace):
    """Recover a fresh state from the crashed trace's WAL + checkpoint —
    what a restarted process would boot from. Returns a ``Recovered``."""
    from repro.runtime.recovery import GraphCheckpointer, recover
    from repro.runtime.wal import WriteAheadLog

    assert trace.durable_dir is not None, "trace ran without durable_dir"
    wal = WriteAheadLog(os.path.join(trace.durable_dir, "wal.log"))
    ckpt = GraphCheckpointer(os.path.join(trace.durable_dir, "ckpt"))
    return recover(ckpt, wal, capacity=trace.capacity, mesh=trace.mesh,
                   auto_grow=trace.pool.auto_grow,
                   retain_epochs=trace.pool.ring.retain)


def check_recovery_equivalent(trace: Trace, recovered=None):
    """Assert a recovered pool reproduces the pre-crash published prefix
    bit-identically (DESIGN.md §16). Six obligations:

    1. zero acknowledged-batch loss: every batch acked pre-crash is in the
       recovered linearization;
    2. the pre-crash published linearization is a PREFIX of the recovered
       one (``wal-fsync``/``post-publish-pre-ack`` may legally extend it
       by the durable-but-unacked round — never rewrite it);
    3. bit-identity: the recovered state AT the pre-crash published epoch
       equals the captured head, field for field;
    4. ring equality: every pre-crash retained epoch still addressable
       after recovery reconstructs bit-identically;
    5. epoch_log agreement on every shared epoch;
    6. serial-oracle prefix: replaying the recovered linearization through
       the sequential reference engine reproduces the recovered head bits
       (the crashed execution stays linearizable after resurrection).

    Returns the ``Recovered`` (recovering first if not supplied).
    """
    crash = trace.crash
    assert crash is not None, "trace did not crash — nothing to recover"
    if recovered is None:
        recovered = recover_trace(trace)

    # (1) zero acknowledged-batch loss
    rec_lin = list(recovered.linearization)
    rec_set = set(rec_lin)
    for bid in crash.acked:
        assert bid in rec_set, \
            (f"acknowledged batch {bid} lost by recovery at stage "
             f"{crash.stage!r} (recovered {rec_lin})")

    # (2) published prefix preserved verbatim
    assert rec_lin[: len(crash.linearization)] == crash.linearization, \
        (f"recovered linearization {rec_lin} rewrites the pre-crash "
         f"published prefix {crash.linearization}")
    assert recovered.epoch >= crash.published_epoch, \
        (f"recovered epoch {recovered.epoch} behind published "
         f"{crash.published_epoch}")

    # (3) bit-identity at the pre-crash published epoch
    dense = partition.unshard(recovered.state) if trace.mesh is not None \
        else recovered.state
    at_published = dense if recovered.epoch == crash.published_epoch \
        else recovered.ring.state_at(crash.published_epoch)
    for name, want in crash.head_fields.items():
        np.testing.assert_array_equal(
            np.asarray(getattr(at_published, name)), want,
            err_msg=(f"recovered state diverges from the pre-crash "
                     f"published head in field {name!r} "
                     f"(stage {crash.stage!r})"))

    # (4) retained ring epochs reconstruct bit-identically
    rlo, rhi = recovered.ring.window()
    shared = 0
    for e, fields in crash.ring_states.items():
        if not rlo <= e <= rhi:
            continue
        shared += 1
        got = recovered.ring.state_at(e)
        for name, want in fields.items():
            np.testing.assert_array_equal(
                np.asarray(getattr(got, name)), want,
                err_msg=(f"ring epoch {e} field {name!r} diverges after "
                         f"recovery (stage {crash.stage!r})"))
    assert shared > 0, \
        (f"no pre-crash epoch survived into the recovered window "
         f"[{rlo}, {rhi}] — nothing was actually proven")

    # (5) epoch_log agreement on shared epochs
    for e, prefix in crash.epoch_log.items():
        if e in recovered.epoch_log:
            assert recovered.epoch_log[e] == prefix, \
                (f"epoch {e} prefix {recovered.epoch_log[e]} != pre-crash "
                 f"{prefix}")

    # (6) serial-oracle prefix: recovered head == sequential replay of the
    # recovered linearization (grow-on-overflow discipline included)
    state = make_graph(trace.capacity)
    for bid in rec_lin:
        t = trace.pool.tickets[bid]
        batch = make_op_batch(t.ops)
        state2, res = apply_ops(state, batch)
        res = np.asarray(res)
        while trace.pool.auto_grow and (res == R_TABLE_FULL).any():
            state = grow(state, 2 * state.capacity)
            state2, res = apply_ops(state, batch)
            res = np.asarray(res)
        state = state2
    for name in dense._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(dense, name)),
            np.asarray(getattr(state, name)),
            err_msg=(f"recovered state diverges from the serial replay of "
                     f"its own linearization in field {name!r}"))
    return recovered


# ---------------------------------------------------------------------------
# Deterministic shrinking
# ---------------------------------------------------------------------------
def shrink_schedule(schedule: Schedule, still_fails) -> Schedule:
    """Greedy deterministic minimizer: repeatedly drop whole steps, then
    single ops inside submit steps, keeping any deletion under which
    ``still_fails(schedule)`` stays True. Deterministic (first-to-last
    scan to fixpoint), so a seeded failure always shrinks to the same
    readable counterexample."""
    cur = schedule
    changed = True
    while changed:
        changed = False
        # pass 1: drop whole steps
        i = 0
        while i < len(cur.steps):
            cand = Schedule(cur.steps[:i] + cur.steps[i + 1:])
            if cand.steps and still_fails(cand):
                cur, changed = cand, True
            else:
                i += 1
        # pass 2: drop individual lanes from submit steps
        i = 0
        while i < len(cur.steps):
            step = cur.steps[i]
            if step[0] == "submit" and len(step[2]) > 1:
                j = 0
                while j < len(step[2]):
                    ops = step[2][:j] + step[2][j + 1:]
                    cand = Schedule(cur.steps[:i]
                                    + [("submit", step[1], ops)]
                                    + cur.steps[i + 1:])
                    if still_fails(cand):
                        cur, changed = cand, True
                        step = cur.steps[i]
                    else:
                        j += 1
            i += 1
    return cur


def run_and_check(schedule: Schedule, **run_kw) -> Trace:
    """Execute + full linearizability check — the single entry point the
    property suites call (and ``shrink_schedule`` predicates wrap)."""
    trace = run_schedule(schedule, **run_kw)
    check_trace_linearizable(trace)
    return trace
