"""Test-support utilities that must live importable under ``repro``.

``proptest`` is a minimal, dependency-free stand-in for the subset of the
``hypothesis`` API the test-suite uses. Tests import hypothesis when it is
installed and fall back to this module otherwise (the CI container bakes in
the jax toolchain but not hypothesis, and installing packages is not an
option there).
"""
from repro.testing import proptest  # noqa: F401
