"""Deterministic micro-implementation of the hypothesis API surface we use.

Covers exactly what the suite needs — ``given``, ``settings`` and the
strategies ``integers``, ``sampled_from``, ``tuples``, ``lists`` — drawing
``max_examples`` pseudo-random examples from a fixed seed so failures are
reproducible run-to-run. It does NOT shrink counterexamples or persist a
failure database; when the real hypothesis is installed the tests prefer it
(see the try/except imports in tests/).
"""
from __future__ import annotations

import random
from typing import Any, Callable

DEFAULT_MAX_EXAMPLES = 25
_SEED = 0xC0FFEE


class Strategy:
    """A strategy is just a draw(rng) -> value callable with combinators."""

    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)


def integers(min_value: int = 0, max_value: int = 1 << 16) -> Strategy:
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(options) -> Strategy:
    opts = list(options)
    return Strategy(lambda rng: rng.choice(opts))


def tuples(*strats: Strategy) -> Strategy:
    return Strategy(lambda rng: tuple(s.draw(rng) for s in strats))


def lists(elements: Strategy, *, min_size: int = 0, max_size: int = 10) -> Strategy:
    def draw(rng: random.Random):
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]

    return Strategy(draw)


def settings(*, max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Decorator recording the example budget on the test function."""

    def wrap(fn):
        fn._proptest_max_examples = max_examples
        return fn

    return wrap


def given(*strats: Strategy):
    """Run the test once per drawn example (seeded => deterministic order).

    Applied below ``settings`` like hypothesis; reads the budget the
    ``settings`` decorator stored (which wraps the function *after* given in
    the conventional ``@settings`` / ``@given`` stacking order, so given
    re-reads it lazily at call time via the outer wrapper attribute).
    """

    def deco(fn):
        # NOTE: no functools.wraps — the runner must present a ZERO-argument
        # signature to pytest, or the strategy-filled parameters would be
        # collected as (missing) fixtures.
        def runner():
            n = getattr(runner, "_proptest_max_examples",
                        getattr(fn, "_proptest_max_examples", DEFAULT_MAX_EXAMPLES))
            rng = random.Random(_SEED)
            for i in range(n):
                example = [s.draw(rng) for s in strats]
                try:
                    fn(*example)
                except Exception as e:  # pragma: no cover - failure path
                    raise AssertionError(
                        f"falsifying example #{i}: {example!r}"
                    ) from e

        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner

    return deco


class strategies:  # noqa: N801 - namespace mimicking `hypothesis.strategies`
    integers = staticmethod(integers)
    sampled_from = staticmethod(sampled_from)
    tuples = staticmethod(tuples)
    lists = staticmethod(lists)
