"""Batched serving loop co-hosting LM decode and snapshot graph queries.

The serving runtime owns two resources:
  * an LM decode engine (prefill -> iterated decode_step over a KV cache)
  * a live concurrent graph (core/): mutator batches are applied between
    decode steps, and GetPath queries run the paper's double-collect
    protocol against the latest published state — non-blocking co-serving:
    queries never lock out mutations and vice versa (DESIGN.md §5(ii)).
    Query batches go through the fused multi-source BFS engine — Q
    reachability queries per shared double collect (DESIGN.md §7).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    R_TABLE_FULL,
    GraphState,
    OpBatch,
    PathResult,
    apply_ops_fast,
    get_path_session,
    get_paths_session,
    grow,
    make_graph,
    make_op_batch,
)
from repro.core import partition


@dataclass
class ServeStats:
    decode_steps: int = 0
    decode_tokens: int = 0
    graph_ops: int = 0
    getpath_calls: int = 0
    getpath_rounds: int = 0
    grow_events: int = 0
    wall_s: float = 0.0


class GraphCoServer:
    """Owns the live graph; publishes functional snapshots to queries.

    ``mesh=`` places the state as a ``ShardedGraphState`` (adjacency rows
    partitioned over the 1-D device mesh, DESIGN.md §8): mutation batches go
    through the distributed disjoint-access engine and query batches through
    the distributed fused multi-source BFS — bit-identical results to the
    single-device server, scaled past one chip's HBM.

    ``auto_grow`` (default on) realizes the paper's "unbounded" property at
    the serving surface: any R_TABLE_FULL lane triggers a capacity doubling
    and a replay of the whole batch against the grown pre-batch state, so
    ``submit`` never surfaces slot exhaustion to clients — directly or as
    cascaded VERTEX-NOT-PRESENT failures — and the returned results are
    one clean lane-order linearization.
    """

    def __init__(self, capacity: int = 256, query_engine: str = "fused",
                 mesh=None, auto_grow: bool = True):
        self.mesh = mesh
        self.auto_grow = auto_grow
        self.query_engine = query_engine
        self.grow_events = 0
        dense = make_graph(capacity)
        self.state = partition.shard_state(mesh, dense) if mesh is not None else dense

    def _apply(self, state, batch: OpBatch):
        if self.mesh is not None:
            return partition.apply_ops_fast(state, batch)
        return apply_ops_fast(state, batch)

    def _grow(self, state, new_capacity: int):
        if self.mesh is not None:
            return partition.grow(state, new_capacity)
        return grow(state, new_capacity)

    def submit(self, ops: list) -> np.ndarray:
        batch = make_op_batch(ops)
        base = self.state                    # pre-batch snapshot (functional)
        state, res = self._apply(base, batch)
        res = np.asarray(res)
        while self.auto_grow and (res == R_TABLE_FULL).any():
            # Discard the starved application entirely, grow the PRE-batch
            # state, and replay the whole batch: the visible history is one
            # clean lane-order linearization on the grown table (re-applying
            # only the starved lanes would order them after lanes that
            # observed their absence — a history no linearization allows).
            base = self._grow(base, 2 * state.capacity)
            self.grow_events += 1
            state, res = self._apply(base, batch)
            res = np.asarray(res)
        self.state = state
        return res

    def get_path(self, k: int, l: int, max_rounds: int = 64):
        if self.mesh is None:
            return get_path_session(lambda: self.state, k, l, max_rounds=max_rounds)
        out, rounds = self.get_paths([(k, l)], max_rounds=max_rounds)
        found, keys = out[0]
        pad = np.full((self.state.capacity,), -1, np.int32)
        pad[: len(keys)] = keys
        return PathResult(jnp.asarray(found), jnp.int32(len(keys)),
                          jnp.asarray(pad), jnp.int32(rounds))

    def get_paths(self, pairs: list, max_rounds: int = 64):
        """Batched reachability: Q queries answered under ONE shared double
        collect, traversed by the fused multi-source BFS engine (DESIGN.md
        §7; distributed per-shard form on a mesh, DESIGN.md §8) — the
        serving-side surface a query front-end batches into.
        Returns ([(found, keys)] per pair, rounds)."""
        return get_paths_session(lambda: self.state, pairs,
                                 max_rounds=max_rounds,
                                 engine=self.query_engine)


def serve(model, params, prompts: np.ndarray, *, max_new_tokens: int,
          cache_len: int, graph: GraphCoServer | None = None,
          mutator=None, query_stream=None, temperature: float = 0.0):
    """Greedy batched decoding with interleaved graph traffic.

    prompts: int32 [B, P]. Returns (generated [B, max_new_tokens], stats).
    """
    t0 = time.time()
    stats = ServeStats()
    b, p = prompts.shape
    last, caches = model.prefill(params, {"tokens": jnp.asarray(prompts)})
    caches = model.cache_from_prefill(caches, cache_len)
    jdecode = jax.jit(model.decode_step)

    out = np.zeros((b, max_new_tokens), np.int32)
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    for i in range(max_new_tokens):
        out[:, i] = np.asarray(tok)
        # interleave graph traffic between decode steps (non-blocking co-serving)
        if graph is not None and mutator is not None:
            ops = mutator(i)
            if ops:
                graph.submit(ops)
                stats.graph_ops += len(ops)
        if graph is not None and query_stream is not None:
            q = query_stream(i)
            if q is not None and len(q) > 0:
                # a batch is a sequence OF (k, l) pairs (list/tuple/ndarray);
                # a lone pair — any length-2 sequence of scalars — stays on
                # the single-query path. Scalars have no __len__.
                if hasattr(q[0], "__len__"):
                    # one fused multi-query session for the whole batch;
                    # every query in it shares the session's round count, so
                    # rounds-per-call stays comparable with the single path
                    _, rounds = graph.get_paths(
                        [(int(p[0]), int(p[1])) for p in q])
                    stats.getpath_calls += len(q)
                    stats.getpath_rounds += rounds * len(q)
                else:
                    res = graph.get_path(int(q[0]), int(q[1]))
                    stats.getpath_calls += 1
                    stats.getpath_rounds += int(res.rounds)
        tok_logits, caches = jdecode(params, caches, tok, jnp.int32(p + i))
        tok = jnp.argmax(tok_logits, axis=-1).astype(jnp.int32)
        stats.decode_steps += 1
        stats.decode_tokens += b
    if graph is not None:
        stats.grow_events = graph.grow_events
    stats.wall_s = time.time() - t0
    return out, stats
