"""Batched serving loop co-hosting LM decode and snapshot graph queries.

The serving runtime owns two resources:
  * an LM decode engine (prefill -> iterated decode_step over a KV cache)
  * a live concurrent graph (core/): mutator batches are applied between
    decode steps, and GetPath queries run the paper's double-collect
    protocol against the latest published state — non-blocking co-serving:
    queries never lock out mutations and vice versa (DESIGN.md §5(ii)).
    Query batches go through the fused multi-source BFS engine — Q
    reachability queries per shared double collect (DESIGN.md §7).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    GraphState,
    OpBatch,
    apply_ops_fast,
    get_path_session,
    get_paths_session,
    make_graph,
    make_op_batch,
)


@dataclass
class ServeStats:
    decode_steps: int = 0
    decode_tokens: int = 0
    graph_ops: int = 0
    getpath_calls: int = 0
    getpath_rounds: int = 0
    wall_s: float = 0.0


class GraphCoServer:
    """Owns the live graph; publishes functional snapshots to queries."""

    def __init__(self, capacity: int = 256, query_engine: str = "fused"):
        self.state = make_graph(capacity)
        self.query_engine = query_engine

    def submit(self, ops: list) -> np.ndarray:
        batch = make_op_batch(ops)
        self.state, res = apply_ops_fast(self.state, batch)
        return np.asarray(res)

    def get_path(self, k: int, l: int, max_rounds: int = 64):
        return get_path_session(lambda: self.state, k, l, max_rounds=max_rounds)

    def get_paths(self, pairs: list, max_rounds: int = 64):
        """Batched reachability: Q queries answered under ONE shared double
        collect, traversed by the fused multi-source BFS engine (DESIGN.md
        §7) — the serving-side surface a query front-end batches into.
        Returns ([(found, keys)] per pair, rounds)."""
        return get_paths_session(lambda: self.state, pairs,
                                 max_rounds=max_rounds,
                                 engine=self.query_engine)


def serve(model, params, prompts: np.ndarray, *, max_new_tokens: int,
          cache_len: int, graph: GraphCoServer | None = None,
          mutator=None, query_stream=None, temperature: float = 0.0):
    """Greedy batched decoding with interleaved graph traffic.

    prompts: int32 [B, P]. Returns (generated [B, max_new_tokens], stats).
    """
    t0 = time.time()
    stats = ServeStats()
    b, p = prompts.shape
    last, caches = model.prefill(params, {"tokens": jnp.asarray(prompts)})
    caches = model.cache_from_prefill(caches, cache_len)
    jdecode = jax.jit(model.decode_step)

    out = np.zeros((b, max_new_tokens), np.int32)
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    for i in range(max_new_tokens):
        out[:, i] = np.asarray(tok)
        # interleave graph traffic between decode steps (non-blocking co-serving)
        if graph is not None and mutator is not None:
            ops = mutator(i)
            if ops:
                graph.submit(ops)
                stats.graph_ops += len(ops)
        if graph is not None and query_stream is not None:
            q = query_stream(i)
            if q is not None and len(q) > 0:
                # a batch is a sequence OF (k, l) pairs (list/tuple/ndarray);
                # a lone pair — any length-2 sequence of scalars — stays on
                # the single-query path. Scalars have no __len__.
                if hasattr(q[0], "__len__"):
                    # one fused multi-query session for the whole batch;
                    # every query in it shares the session's round count, so
                    # rounds-per-call stays comparable with the single path
                    _, rounds = graph.get_paths(
                        [(int(p[0]), int(p[1])) for p in q])
                    stats.getpath_calls += len(q)
                    stats.getpath_rounds += rounds * len(q)
                else:
                    res = graph.get_path(int(q[0]), int(q[1]))
                    stats.getpath_calls += 1
                    stats.getpath_rounds += int(res.rounds)
        tok_logits, caches = jdecode(params, caches, tok, jnp.int32(p + i))
        tok = jnp.argmax(tok_logits, axis=-1).astype(jnp.int32)
        stats.decode_steps += 1
        stats.decode_tokens += b
    stats.wall_s = time.time() - t0
    return out, stats
