"""Batched serving loop co-hosting LM decode and snapshot graph queries.

The serving runtime owns two resources:
  * an LM decode engine (prefill -> iterated decode_step over a KV cache)
  * a live concurrent graph (core/): mutator batches are applied between
    decode steps, and GetPath queries run the paper's double-collect
    protocol against the latest published state — non-blocking co-serving:
    queries never lock out mutations and vice versa (DESIGN.md §5(ii)).
    Query batches go through the fused multi-source BFS engine — Q
    reachability queries per shared double collect (DESIGN.md §7).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    R_RECOVERING,
    R_TABLE_FULL,
    EpochEvictedError,
    GraphState,
    OpBatch,
    PathResult,
    apply_ops_fast,
    get_path_session,
    get_paths_session,
    grow,
    make_graph,
    make_op_batch,
)
from repro.core import partition
from repro.index import (
    build_index,
    index_fresh,
    reach_counts_session,
    reach_session,
    refresh,
)
from repro.obs import trace as _trace
from repro.obs.metrics import StatsView
from repro.obs.metrics import global_registry as _obs_registry
from repro.runtime.fault import SimulatedCrash


class ServeStats(StatsView):
    """Per-``serve()``-call observability (DESIGN.md §12, §13, §14).

    A ``MetricsRegistry``-backed view (fields stored under
    ``serve.<field>``): every field reports THIS call's activity — server-
    lifetime counters are snapshotted at serve start and reported as
    deltas, except the ``*_max`` high-water marks, which stay lifetime
    values (a max has no meaningful delta).
    """

    _PREFIX = "serve"
    _SPEC = {
        "decode_steps": ("counter", 0),
        "decode_tokens": ("counter", 0),
        "graph_ops": ("counter", 0),
        "getpath_calls": ("counter", 0),
        "getpath_rounds": ("counter", 0),
        "getpath_starved": ("gauge", 0),  # sessions whose collects never matched
        "epoch_resolved": ("gauge", 0),   # starved sessions resolved wait-free
        "tt_calls": ("gauge", 0),         # time-travel queries served
        "tt_evicted": ("gauge", 0),       # time-travel past the window
        "epoch_diff_calls": ("gauge", 0),  # epoch-diff audit queries served
        "grow_events": ("gauge", 0),      # auto-grows during THIS serve call
        "index_hits": ("gauge", 0),       # index fast-path answers
        "index_misses": ("gauge", 0),     # fused-BFS fallbacks
        "index_refreshes": ("gauge", 0),  # index builds/refreshes
        # -- multi-tenant admission observability (DESIGN.md §12) -----------
        "ingest_batches": ("gauge", 0),       # client batches applied
        "ingest_fused_calls": ("gauge", 0),   # coalesced device applies
        "ingest_coalesce_max": ("gauge", 0),  # max batches in one fused call
        "ingest_retries": ("gauge", 0),       # rounds lost to conflicts
        "ingest_wait_s": ("gauge", 0.0),      # total enqueue->admission wait
        "ingest_wait_max_s": ("gauge", 0.0),
        "ingest_queue_depth_max": ("gauge", 0),
        "ingest_epochs": ("gauge", 0),        # snapshot epochs published
        # -- durability / degraded mode (DESIGN.md §16) ---------------------
        "degraded_reads": ("gauge", 0),       # reads served off the pinned epoch
        "rejected_writes": ("gauge", 0),      # R_RECOVERING typed rejections
        "recoveries": ("gauge", 0),           # restart-from-recovery completions
        "wall_s": ("gauge", 0.0),
    }


@dataclass
class TimeTravelResult:
    """Typed answer of the time-travel reachability endpoint (DESIGN.md §13).

    ``evicted=True`` means the requested epoch left the bounded retention
    window (``window`` says what is still addressable) — the typed
    "epoch evicted" outcome, never an exception at the serving surface.
    """

    epoch: int
    evicted: bool
    window: tuple
    found: list = field(default_factory=list)    # [bool] per pair
    paths: list = field(default_factory=list)    # [(found, keys)] per pair


@dataclass
class EpochDiffResult:
    """Typed answer of the epoch-diff endpoint (DESIGN.md §13): which rows
    (and the keys occupying them at each end) changed between two retained
    epochs. ``evicted=True`` when either endpoint left the window."""

    e_from: int
    e_to: int
    evicted: bool
    window: tuple
    rows: list = field(default_factory=list)
    keys_before: list = field(default_factory=list)
    keys_after: list = field(default_factory=list)


class GraphCoServer:
    """Owns the live graph; publishes functional snapshots to queries.

    ``mesh=`` places the state as a ``ShardedGraphState`` (adjacency rows
    partitioned over the 1-D device mesh, DESIGN.md §8): mutation batches go
    through the distributed disjoint-access engine and query batches through
    the distributed fused multi-source BFS — bit-identical results to the
    single-device server, scaled past one chip's HBM.

    ``auto_grow`` (default on) realizes the paper's "unbounded" property at
    the serving surface: any R_TABLE_FULL lane triggers a capacity doubling
    and a replay of the whole batch against the grown pre-batch state, so
    ``submit`` never surfaces slot exhaustion to clients — directly or as
    cascaded VERTEX-NOT-PRESENT failures — and the returned results are
    one clean lane-order linearization.

    ``ingest=True`` attaches the multi-tenant admission pool
    (runtime/ingest.py, DESIGN.md §12): ``submit_client`` enqueues per-
    client batches, ``pump``/``flush`` run conflict-detected admission
    rounds that coalesce non-conflicting batches into fused applies, and
    ``state`` becomes the pool's double-buffered published snapshot epoch —
    readers never block behind admission.

    ``index=True`` maintains a versioned 2-hop reachability index
    (DESIGN.md §9): ``get_reach``/``get_reach_counts`` answer from the
    index whenever its epoch stamp matches the live version metadata (the
    freshness check doubles as the snapshot validation) and fall back to
    the fused BFS double collect otherwise — the index is an accelerator,
    never a consistency dependency, so mutations proceed untouched.
    ``index_tick()`` (called between decode steps by ``serve``) refreshes a
    stale index in the background of the serving loop: refresh runs on a
    functional snapshot and lands as a reference swap, so queries racing
    it simply keep falling back (non-blocking co-serving, DESIGN.md §5(ii)).
    """

    def __init__(self, capacity: int = 256, query_engine: str = "fused",
                 mesh=None, auto_grow: bool = True, index: bool = False,
                 index_landmarks: int | None = None, ingest: bool = False,
                 max_inflight: int = 8, max_coalesce_lanes: int = 256,
                 fault=None, on_conflict: str | None = None,
                 retain_epochs: int = 64, wal_dir: str | None = None,
                 ckpt_every: int = 0, heartbeat=None, failure_policy=None):
        self.mesh = mesh
        self.auto_grow = auto_grow
        self.query_engine = query_engine
        self.grow_events = 0
        self.index_enabled = bool(index)
        self.index_landmarks = index_landmarks
        self.index = None
        self.index_hits = 0
        self.index_misses = 0
        self.index_refreshes = 0
        # wait-free snapshot observability (DESIGN.md §13) — lifetime
        # counters, surfaced as per-serve deltas like the index ones
        self.getpath_starved = 0
        self.epoch_resolved = 0
        self.tt_calls = 0
        self.tt_evicted = 0
        self.epoch_diff_calls = 0
        # durability + degraded mode (DESIGN.md §16): while recovering,
        # reads pin to the last published epoch and writes get typed
        # R_RECOVERING rejections; Heartbeat suspects and SimulatedCrash
        # both funnel into the backoff-budgeted restart-from-recovery path
        self.degraded = False
        self.degraded_reads = 0
        self.rejected_writes = 0
        self.recoveries = 0
        self.heartbeat = heartbeat
        self.failure_policy = failure_policy
        self._pinned = None            # (epoch, state) while degraded
        self._capacity = int(capacity)
        self._retain_epochs = int(retain_epochs)
        self._max_inflight = int(max_inflight)
        self._max_coalesce_lanes = int(max_coalesce_lanes)
        self._fault = fault
        self._wal_dir = wal_dir
        self._ckpt_every = int(ckpt_every)
        self._ckpt = None
        dense = make_graph(capacity)
        self._state = partition.shard_state(mesh, dense) if mesh is not None else dense
        self.pool = None
        if ingest:
            from repro.runtime.ingest import IngestPool

            def bump_grow():
                self.grow_events += 1

            self._bump_grow = bump_grow
            wal = None
            if wal_dir is not None:
                from repro.runtime.recovery import GraphCheckpointer
                from repro.runtime.wal import WriteAheadLog

                wal = WriteAheadLog(f"{wal_dir}/wal.log")
                self._ckpt = GraphCheckpointer(f"{wal_dir}/ckpt")
            self.pool = IngestPool(
                self._state, mesh=mesh, auto_grow=auto_grow,
                max_inflight=max_inflight,
                max_coalesce_lanes=max_coalesce_lanes, fault=fault,
                on_grow=bump_grow, retain_epochs=retain_epochs,
                wal=wal, ckpt=self._ckpt, ckpt_every=ckpt_every)
        # default conflict policy: a pool-backed server resolves starved
        # query sessions wait-free against its published epoch ring
        # (DESIGN.md §13); a bare server keeps the capped-retry deviation
        self.on_conflict = on_conflict if on_conflict is not None else (
            "epoch" if self.pool is not None else "retry")

    @property
    def state(self):
        """Latest published state. With the ingest pool enabled this is the
        double-buffered snapshot epoch — readers never observe (or wait on)
        a round mid-admission (DESIGN.md §12). While DEGRADED, reads pin to
        the epoch published before the failure (DESIGN.md §16)."""
        if self.degraded and self._pinned is not None:
            return self._pinned[1]
        return self.pool.snapshot() if self.pool is not None else self._state

    @state.setter
    def state(self, value):
        if self.pool is not None:
            raise AttributeError(
                "state is pool-owned under multi-tenant ingestion; "
                "mutate through submit()/submit_client() (DESIGN.md §12)")
        self._state = value

    def _apply(self, state, batch: OpBatch):
        if self.mesh is not None:
            return partition.apply_ops_fast(state, batch)
        return apply_ops_fast(state, batch)

    def _grow(self, state, new_capacity: int):
        if self.mesh is not None:
            return partition.grow(state, new_capacity)
        return grow(state, new_capacity)

    def submit(self, ops: list) -> np.ndarray:
        if self.degraded:
            # typed rejection: every lane answers R_RECOVERING; the client
            # retries after recovery instead of blocking on it (DESIGN.md §16)
            self.rejected_writes += 1
            with _trace.span("serve.reject_write", lanes=len(ops)):
                return np.full((len(ops),), R_RECOVERING, np.int32)
        if self.pool is not None:
            # single-tenant surface on the multi-tenant pool: enqueue as one
            # anonymous client and drain — same results, one linearization
            # log shared with every concurrent client (DESIGN.md §12)
            ticket = self.pool.submit("_direct", ops)
            self.pool.flush()
            return np.asarray(ticket.results)
        batch = make_op_batch(ops)
        base = self.state                    # pre-batch snapshot (functional)
        state, res = self._apply(base, batch)
        res = np.asarray(res)
        while self.auto_grow and (res == R_TABLE_FULL).any():
            # Discard the starved application entirely, grow the PRE-batch
            # state, and replay the whole batch: the visible history is one
            # clean lane-order linearization on the grown table (re-applying
            # only the starved lanes would order them after lanes that
            # observed their absence — a history no linearization allows).
            base = self._grow(base, 2 * state.capacity)
            self.grow_events += 1
            state, res = self._apply(base, batch)
            res = np.asarray(res)
        self.state = state
        return res

    # -- multi-tenant admission surface (DESIGN.md §12) ---------------------
    def submit_client(self, client_id: str, ops: list):
        """Enqueue one client's mutation batch; returns its ``Ticket``.

        Requires ``ingest=True``. The batch is admitted by a later
        ``pump()`` once its entity footprint stops colliding with in-flight
        batches; results land on the ticket (DESIGN.md §12)."""
        if self.pool is None:
            raise RuntimeError("GraphCoServer(ingest=True) required for "
                               "multi-tenant submission")
        if self.degraded:
            # typed rejection ticket: never enqueued, resolved immediately
            # with R_RECOVERING lanes (DESIGN.md §16)
            from repro.runtime.ingest import Ticket, batch_footprint

            footprint, exclusive = batch_footprint(ops)
            self.rejected_writes += 1
            with _trace.span("serve.reject_write", lanes=len(ops)):
                return Ticket(-1, str(client_id), list(ops), footprint,
                              exclusive, self.pool.clock(),
                              status="rejected",
                              results=np.full((len(ops),), R_RECOVERING,
                                              np.int32))
        return self.pool.submit(client_id, ops)

    def pump(self) -> int:
        """One admission round of the ingest pool (DESIGN.md §12)."""
        return self.pool.pump() if self.pool is not None else 0

    def flush(self) -> int:
        """Drain the ingest queue (DESIGN.md §12)."""
        return self.pool.flush() if self.pool is not None else 0

    # -- durability / degraded mode (DESIGN.md §16) -------------------------
    def worker_tick(self, worker: str = "ingest", now: float | None = None):
        """Heartbeat tick for an in-process worker (the serve loop ticks
        ``"ingest"`` every decode step)."""
        if self.heartbeat is not None:
            self.heartbeat.tick(worker, now)

    def check_health(self, now: float | None = None) -> list:
        """Suspect scan: a worker past the heartbeat timeout triggers the
        backoff-budgeted restart-from-recovery path. Returns the suspects."""
        if self.heartbeat is None:
            return []
        suspects = self.heartbeat.suspects(now)
        if suspects and not self.degraded:
            self.handle_crash()
            # the restarted worker is live again: reset its heartbeat so one
            # stale timestamp cannot re-trigger recovery every scan
            for w in suspects:
                self.heartbeat.tick(w, now)
        return suspects

    def enter_degraded(self) -> None:
        """Pin the last published epoch and start rejecting writes."""
        if self.pool is not None:
            self._pinned = self.pool.snapshot_epoch()
        else:
            self._pinned = (0, self._state)
        self.degraded = True
        if _trace.enabled():
            _obs_registry().set("serve.degraded", 1)
            _trace.counter("serve.degraded", 1)

    def recover_now(self) -> None:
        """Restart-from-recovery: rebuild the pool from checkpoint + WAL
        replay; reads un-pin, writes are accepted again (DESIGN.md §16)."""
        if self.pool is None or self._wal_dir is None:
            # nothing durable to recover from: just un-pin
            self.degraded = False
            self._pinned = None
            return
        from repro.runtime.recovery import recover, resume_pool
        from repro.runtime.wal import WriteAheadLog

        with _trace.span("serve.recover"):
            old = self.pool
            wal = WriteAheadLog(f"{self._wal_dir}/wal.log")
            rec = recover(self._ckpt, wal, capacity=self._capacity,
                          mesh=self.mesh, auto_grow=self.auto_grow,
                          retain_epochs=self._retain_epochs)
            self.pool = resume_pool(
                rec, mesh=self.mesh, auto_grow=self.auto_grow,
                max_inflight=self._max_inflight,
                max_coalesce_lanes=self._max_coalesce_lanes,
                fault=self._fault, on_grow=self._bump_grow,
                retain_epochs=self._retain_epochs, wal=wal, ckpt=self._ckpt,
                ckpt_every=self._ckpt_every)
            # carry forward what recovery cannot know: tickets already
            # resolved before the crash (clients hold references to them)
            self.pool.tickets.update(old.tickets)
            self.pool.index_stamp = old.index_stamp
        self.degraded = False
        self._pinned = None
        self.recoveries += 1
        if _trace.enabled():
            _obs_registry().set("serve.degraded", 0)
            _trace.counter("serve.degraded", 0)

    def handle_crash(self, exc=None) -> float:
        """One suspect/crash -> degrade -> backoff -> recover cycle.
        Returns the backoff the FailurePolicy budgeted (0.0 without one);
        raises once the restart budget is exhausted — a crash loop must
        page a human, not spin."""
        self.enter_degraded()
        wait = 0.0
        if self.failure_policy is not None:
            wait = self.failure_policy.on_failure()
        self.recover_now()
        return wait

    def _fetch_epoch(self):
        """(epoch, state) pin source for wait-free resolution — the pool's
        published slot when ingesting, None otherwise (DESIGN.md §13).
        While degraded, sessions pin to the frozen pre-failure epoch."""
        if self.degraded and self._pinned is not None:
            return lambda: self._pinned
        return self.pool.snapshot_epoch if self.pool is not None else None

    def _note_session(self, stats: dict):
        if stats.get("starved"):
            self.getpath_starved += 1
        if stats.get("resolved") == "epoch":
            self.epoch_resolved += 1

    def get_path(self, k: int, l: int, max_rounds: int = 64):
        if self.degraded and self.mesh is None:
            self.degraded_reads += 1   # the mesh path counts via get_paths
        if self.mesh is None:
            pr = get_path_session(lambda: self.state, k, l,
                                  max_rounds=max_rounds,
                                  on_conflict=self.on_conflict,
                                  fetch_epoch=self._fetch_epoch())
            if bool(pr.starved):
                self.getpath_starved += 1
                if self.on_conflict == "epoch":
                    self.epoch_resolved += 1
            return pr
        out, rounds = self.get_paths([(k, l)], max_rounds=max_rounds)
        found, keys = out[0]
        pad = np.full((self.state.capacity,), -1, np.int32)
        pad[: len(keys)] = keys
        return PathResult(jnp.asarray(found), jnp.int32(len(keys)),
                          jnp.asarray(pad), jnp.int32(rounds))

    def get_paths(self, pairs: list, max_rounds: int = 64):
        """Batched reachability: Q queries answered under ONE shared double
        collect, traversed by the fused multi-source BFS engine (DESIGN.md
        §7; distributed per-shard form on a mesh, DESIGN.md §8) — the
        serving-side surface a query front-end batches into. A session that
        exhausts its retry budget under sustained mutation follows the
        server's ``on_conflict`` policy — pool-backed servers resolve
        wait-free against the published epoch ring (DESIGN.md §13).
        Returns ([(found, keys)] per pair, rounds)."""
        if self.degraded and self._pinned is not None:
            self.degraded_reads += 1
        st: dict = {}
        out, rounds = get_paths_session(lambda: self.state, pairs,
                                        max_rounds=max_rounds,
                                        engine=self.query_engine,
                                        on_conflict=self.on_conflict,
                                        fetch_epoch=self._fetch_epoch(),
                                        stats=st)
        self._note_session(st)
        return out, rounds

    # -- retained-epoch endpoints (DESIGN.md §13) --------------------------
    def epoch_window(self) -> tuple:
        """(oldest addressable, newest published) epoch of the ring."""
        if self.pool is None:
            raise RuntimeError("GraphCoServer(ingest=True) required for "
                               "epoch-ring endpoints")
        return self.pool.epoch_window()

    def get_reach_at(self, pairs: list, epoch: int) -> TimeTravelResult:
        """Time-travel reachability: "was u→w reachable at epoch e?" —
        answered by a single collect over the ring's bit-identical
        reconstruction of that published epoch (a frozen functional state,
        so one collect is trivially consistent). Epochs past the bounded
        retention window return a typed evicted result (DESIGN.md §13)."""
        if self.pool is None:
            raise RuntimeError("GraphCoServer(ingest=True) required for "
                               "epoch-ring endpoints")
        self.tt_calls += 1
        try:
            state_e = self.pool.state_at(epoch)
        except EpochEvictedError as err:
            self.tt_evicted += 1
            return TimeTravelResult(int(epoch), True, err.window)
        out, _rounds = get_paths_session(lambda: state_e, pairs,
                                         engine=self.query_engine)
        return TimeTravelResult(int(epoch), False, self.pool.epoch_window(),
                                [f for f, _ in out], out)

    def epoch_diff(self, e1: int, e2: int) -> EpochDiffResult:
        """Audit/forensics: which rows (and keys) changed between epochs
        e1 and e2 — read straight off the retained delta records, no
        traversal (DESIGN.md §13). Typed evicted result past the window."""
        if self.pool is None:
            raise RuntimeError("GraphCoServer(ingest=True) required for "
                               "epoch-ring endpoints")
        self.epoch_diff_calls += 1
        try:
            d = self.pool.epoch_diff(e1, e2)
        except EpochEvictedError as err:
            return EpochDiffResult(int(e1), int(e2), True, err.window)
        return EpochDiffResult(d.e_from, d.e_to, False,
                               self.pool.epoch_window(),
                               [int(r) for r in d.rows],
                               [int(k) for k in d.keys_before],
                               [int(k) for k in d.keys_after])

    # -- reachability index surface (DESIGN.md §9) -------------------------
    def index_tick(self) -> bool:
        """Build/refresh the index if enabled and stale; returns True when
        a refresh ran. ``serve`` calls this between decode steps so the
        index converges back to fresh in the gaps of the decode schedule."""
        if not self.index_enabled:
            return False
        if self.index is None:
            self.index = build_index(self.state, self.index_landmarks)
        elif not index_fresh(self.index, self.state):
            self.index, _ = refresh(self.index, self.state)
        else:
            return False
        self.index_refreshes += 1
        if self.pool is not None:
            # freshness stamp rides the next graph checkpoint: after
            # recovery the server knows which epoch the on-disk index
            # labels were built against (DESIGN.md §16)
            self.pool.index_stamp = {"epoch": int(self.pool.epoch),
                                     "refreshes": int(self.index_refreshes)}
        return True

    def get_reach(self, pairs: list, max_rounds: int = 64):
        """Batched reachability WITHOUT paths — the read-heavy fast path.
        Index-served when fresh (answers linearize at the freshness check);
        stale epochs and undecided pairs transparently fall back to the
        fused BFS double collect. Returns a ``ReachSessionResult`` whose
        ``.paths()`` lazily materializes witness paths on demand."""
        res = reach_session(lambda: self.state,
                            self.index if self.index_enabled else None,
                            pairs, engine=self.query_engine,
                            max_rounds=max_rounds,
                            on_conflict=self.on_conflict,
                            fetch_epoch=self._fetch_epoch(),
                            ring=self.pool.ring if self.pool is not None
                            else None)
        if self.degraded:
            # answered off the pinned pre-failure epoch: flag it so clients
            # can tell a degraded answer from a live one (DESIGN.md §16)
            res.degraded = True
            self.degraded_reads += 1
        if self.index_enabled:   # a server without an index has no misses
            self.index_hits += res.from_index
            self.index_misses += res.fellback
        if res.starved:
            self.getpath_starved += 1
            if self.on_conflict == "epoch":
                self.epoch_resolved += 1
        return res

    def get_reach_counts(self, keys: list) -> np.ndarray:
        """Batched ``core.bfs.reachable_count`` endpoint: |reachable set|
        per source key, answered from the index when fresh (one [Q,L]@[L,V]
        label product) and by one fused multi-BFS otherwise."""
        if self.degraded:
            self.degraded_reads += 1
        counts, from_index = reach_counts_session(
            lambda: self.state, self.index if self.index_enabled else None,
            keys)
        if self.index_enabled:
            if from_index:
                self.index_hits += len(counts)
            else:
                self.index_misses += len(counts)
        return counts

    # -- metrics endpoint (DESIGN.md §14) ----------------------------------
    def get_metrics(self) -> dict:
        """One flat name -> value snapshot of everything the server can
        observe (DESIGN.md §14): its lifetime counters (``server.*``), the
        ingest pool's registry (``ingest.*``) plus ring window, and the
        process-global tracing metrics (``bfs.*``, ``index.*``, ``ring.*``,
        ``ingest.*_s`` histograms). Histograms are {count, sum, min, max}
        sub-dicts; everything is plain JSON-serializable."""
        out = {
            "server.grow_events": self.grow_events,
            "server.index_hits": self.index_hits,
            "server.index_misses": self.index_misses,
            "server.index_refreshes": self.index_refreshes,
            "server.getpath_starved": self.getpath_starved,
            "server.epoch_resolved": self.epoch_resolved,
            "server.tt_calls": self.tt_calls,
            "server.tt_evicted": self.tt_evicted,
            "server.epoch_diff_calls": self.epoch_diff_calls,
            "server.degraded": int(self.degraded),
            "server.degraded_reads": self.degraded_reads,
            "server.rejected_writes": self.rejected_writes,
            "server.recoveries": self.recoveries,
        }
        if self.pool is not None:
            out.update(self.pool.registry.snapshot())
            lo, hi = self.pool.epoch_window()
            out["ring.window_lo"] = int(lo)
            out["ring.window_hi"] = int(hi)
        out.update(_obs_registry().snapshot())
        return out


def serve(model, params, prompts: np.ndarray, *, max_new_tokens: int,
          cache_len: int, graph: GraphCoServer | None = None,
          mutator=None, query_stream=None, clients=None,
          temperature: float = 0.0):
    """Greedy batched decoding with interleaved graph traffic.

    prompts: int32 [B, P]. Returns (generated [B, max_new_tokens], stats).

    ``clients`` (requires ``GraphCoServer(ingest=True)``) is the multi-
    tenant mutation stream: a callable ``step -> [(client_id, ops), ...]``.
    Each step's batches are enqueued and one admission round runs —
    non-conflicting batches coalesce into one fused apply while the read
    stream keeps hitting the last published snapshot epoch (DESIGN.md §12);
    the queue is drained after the last decode step.
    """
    t0 = time.time()
    stats = ServeStats()
    # server counters are lifetime-cumulative; ServeStats reports per-serve
    # deltas, so EVERY lifetime counter gets a start-of-serve snapshot —
    # grow_events included (it used to leak the lifetime total into the
    # second and later serve() calls)
    grow0 = graph.grow_events if graph is not None else 0
    idx0 = ((graph.index_hits, graph.index_misses, graph.index_refreshes)
            if graph is not None else (0, 0, 0))
    ring0 = ((graph.getpath_starved, graph.epoch_resolved, graph.tt_calls,
              graph.tt_evicted, graph.epoch_diff_calls)
             if graph is not None else (0, 0, 0, 0, 0))
    rec0 = ((graph.degraded_reads, graph.rejected_writes, graph.recoveries)
            if graph is not None else (0, 0, 0))
    pool = graph.pool if graph is not None else None
    if clients is not None and pool is None:
        raise RuntimeError("clients= stream requires GraphCoServer(ingest=True)")
    ing0 = ((pool.stats.applied, pool.stats.fused_calls, pool.stats.retries,
             pool.stats.wait_s, pool.stats.epochs)
            if pool is not None else (0, 0, 0, 0.0, 0))
    b, p = prompts.shape
    _session = _trace.span("serve.session", batch=b,
                           max_new_tokens=max_new_tokens)
    _session.__enter__()
    with _trace.span("serve.prefill", batch=b, prompt_len=p):
        last, caches = model.prefill(params, {"tokens": jnp.asarray(prompts)})
        caches = model.cache_from_prefill(caches, cache_len)
        _trace.fence(last)
    jdecode = jax.jit(model.decode_step)

    out = np.zeros((b, max_new_tokens), np.int32)
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    for i in range(max_new_tokens):
        out[:, i] = np.asarray(tok)
        # interleave graph traffic between decode steps (non-blocking co-serving)
        if graph is not None and mutator is not None:
            ops = mutator(i)
            if ops:
                graph.submit(ops)
                stats.graph_ops += len(ops)
        if graph is not None and clients is not None:
            for client_id, ops in clients(i) or ():
                if ops:
                    graph.submit_client(client_id, ops)
                    stats.graph_ops += len(ops)
            # one admission round per decode step: coalesced fused apply of
            # whatever non-conflicting batches are queued (DESIGN.md §12)
            try:
                graph.pump()
            except SimulatedCrash:
                # worker died mid-round: degrade, spend one restart-budget
                # slot, recover from checkpoint + WAL (DESIGN.md §16); the
                # FailurePolicy raises past its budget — that propagates
                graph.handle_crash()
        if graph is not None:
            # heartbeat: the ingest worker ticks every decode step; a
            # missing tick past the timeout trips check_health into the
            # same restart-from-recovery path (DESIGN.md §16)
            graph.worker_tick("ingest")
            graph.check_health()
        if graph is not None:
            # background index refresh between decode steps: co-serving
            # stays non-blocking — queries racing a stale index fall back
            # to BFS and mutations never wait (DESIGN.md §5(ii), §9)
            graph.index_tick()
        if graph is not None and query_stream is not None:
            q = query_stream(i)
            if q is not None and len(q) > 0:
                # a batch is a sequence OF (k, l) pairs (list/tuple/ndarray);
                # a lone pair — any length-2 sequence of scalars — stays on
                # the single-query path. Scalars have no __len__.
                if hasattr(q[0], "__len__"):
                    # one fused multi-query session for the whole batch;
                    # every query in it shares the session's round count, so
                    # rounds-per-call stays comparable with the single path.
                    # With the index enabled, the batch goes through the
                    # reachability fast path instead (DESIGN.md §9) — serve
                    # only consumes found/rounds, so nothing is lost and
                    # fresh-epoch batches skip the BFS entirely.
                    batch_pairs = [(int(p[0]), int(p[1])) for p in q]
                    stats.getpath_calls += len(q)
                    if graph.index_enabled:
                        res = graph.get_reach(batch_pairs)
                        # rounds accounting is PER PAIR, and only the pairs
                        # that actually took the BFS fallback session spent
                        # them — index-served pairs cost 0 rounds. Charging
                        # rounds * len(q) here would bill index hits for a
                        # session they never entered (stale-epoch batches
                        # still charge every pair: fellback == len(q)).
                        stats.getpath_rounds += res.rounds * res.fellback
                    else:
                        _, rounds = graph.get_paths(batch_pairs)
                        # every pair shares the one session's double collect
                        stats.getpath_rounds += rounds * len(q)
                elif graph.index_enabled:
                    res = graph.get_reach([(int(q[0]), int(q[1]))])
                    stats.getpath_calls += 1
                    stats.getpath_rounds += res.rounds
                else:
                    res = graph.get_path(int(q[0]), int(q[1]))
                    stats.getpath_calls += 1
                    stats.getpath_rounds += int(res.rounds)
        with _trace.span("serve.decode_step", step=i):
            tok_logits, caches = jdecode(params, caches, tok, jnp.int32(p + i))
            tok = jnp.argmax(tok_logits, axis=-1).astype(jnp.int32)
            _trace.fence(tok)
        stats.decode_steps += 1
        stats.decode_tokens += b
    if pool is not None:
        try:
            graph.flush()                    # drain whatever is still queued
        except SimulatedCrash:
            graph.handle_crash()
            graph.flush()
        pool = graph.pool                    # recovery may have replaced it
        stats.ingest_batches = pool.stats.applied - ing0[0]
        stats.ingest_fused_calls = pool.stats.fused_calls - ing0[1]
        stats.ingest_retries = pool.stats.retries - ing0[2]
        stats.ingest_wait_s = pool.stats.wait_s - ing0[3]
        stats.ingest_epochs = pool.stats.epochs - ing0[4]
        # high-water marks are lifetime values (a max has no meaningful delta)
        stats.ingest_coalesce_max = pool.stats.coalesce_max
        stats.ingest_wait_max_s = pool.stats.wait_max_s
        stats.ingest_queue_depth_max = pool.stats.queue_depth_max
    if graph is not None:
        stats.grow_events = graph.grow_events - grow0
        stats.index_hits = graph.index_hits - idx0[0]
        stats.index_misses = graph.index_misses - idx0[1]
        stats.index_refreshes = graph.index_refreshes - idx0[2]
        stats.getpath_starved = graph.getpath_starved - ring0[0]
        stats.epoch_resolved = graph.epoch_resolved - ring0[1]
        stats.tt_calls = graph.tt_calls - ring0[2]
        stats.tt_evicted = graph.tt_evicted - ring0[3]
        stats.epoch_diff_calls = graph.epoch_diff_calls - ring0[4]
        stats.degraded_reads = graph.degraded_reads - rec0[0]
        stats.rejected_writes = graph.rejected_writes - rec0[1]
        stats.recoveries = graph.recoveries - rec0[2]
    stats.wall_s = time.time() - t0
    _session.set(decode_steps=stats.decode_steps,
                 getpath_calls=stats.getpath_calls,
                 graph_ops=stats.graph_ops)
    _session.__exit__(None, None, None)
    return out, stats
