"""Batched serving loop co-hosting LM decode and snapshot graph queries.

The serving runtime owns two resources:
  * an LM decode engine (prefill -> iterated decode_step over a KV cache)
  * a live concurrent graph (core/): mutator batches are applied between
    decode steps, and GetPath queries run the paper's double-collect
    protocol against the latest published state — non-blocking co-serving:
    queries never lock out mutations and vice versa (DESIGN.md §5(ii)).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    GraphState,
    OpBatch,
    apply_ops_fast,
    get_path_session,
    make_graph,
    make_op_batch,
)


@dataclass
class ServeStats:
    decode_steps: int = 0
    decode_tokens: int = 0
    graph_ops: int = 0
    getpath_calls: int = 0
    getpath_rounds: int = 0
    wall_s: float = 0.0


class GraphCoServer:
    """Owns the live graph; publishes functional snapshots to queries."""

    def __init__(self, capacity: int = 256):
        self.state = make_graph(capacity)

    def submit(self, ops: list) -> np.ndarray:
        batch = make_op_batch(ops)
        self.state, res = apply_ops_fast(self.state, batch)
        return np.asarray(res)

    def get_path(self, k: int, l: int, max_rounds: int = 64):
        return get_path_session(lambda: self.state, k, l, max_rounds=max_rounds)


def serve(model, params, prompts: np.ndarray, *, max_new_tokens: int,
          cache_len: int, graph: GraphCoServer | None = None,
          mutator=None, query_stream=None, temperature: float = 0.0):
    """Greedy batched decoding with interleaved graph traffic.

    prompts: int32 [B, P]. Returns (generated [B, max_new_tokens], stats).
    """
    t0 = time.time()
    stats = ServeStats()
    b, p = prompts.shape
    last, caches = model.prefill(params, {"tokens": jnp.asarray(prompts)})
    caches = model.cache_from_prefill(caches, cache_len)
    jdecode = jax.jit(model.decode_step)

    out = np.zeros((b, max_new_tokens), np.int32)
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    for i in range(max_new_tokens):
        out[:, i] = np.asarray(tok)
        # interleave graph traffic between decode steps (non-blocking co-serving)
        if graph is not None and mutator is not None:
            ops = mutator(i)
            if ops:
                graph.submit(ops)
                stats.graph_ops += len(ops)
        if graph is not None and query_stream is not None:
            q = query_stream(i)
            if q is not None:
                res = graph.get_path(*q)
                stats.getpath_calls += 1
                stats.getpath_rounds += int(res.rounds)
        tok_logits, caches = jdecode(params, caches, tok, jnp.int32(p + i))
        tok = jnp.argmax(tok_logits, axis=-1).astype(jnp.int32)
        stats.decode_steps += 1
        stats.decode_tokens += b
    stats.wall_s = time.time() - t0
    return out, stats
