"""Fault-tolerant training loop: checkpoint/restart, stragglers, elastic resume.

The loop is deliberately restart-idempotent: all state lives in
(params, opt_state, step); data is a pure function of step; a crash at any
point resumes from the last published checkpoint with identical semantics.
``simulate_failure_at`` injects a crash for the fault-tolerance tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.launch import steps as steps_mod
from repro.optim import adamw
from repro.runtime.fault import FailurePolicy, Heartbeat, StragglerDetector


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    microbatches: int = 1
    lr: float = 3e-4
    simulate_failure_at: int | None = None
    straggler_sleep_at: int | None = None  # inject a slow step (tests)


class SimulatedFailure(RuntimeError):
    pass


def train(model, data_source, *, batch_size: int, seq_len: int,
          cfg: TrainLoopConfig, params=None, mesh=None, shardings=None,
          log=print):
    """Runs/resumes training; returns (params, opt_state, history)."""
    ckpt = Checkpointer(cfg.checkpoint_dir)
    step0 = 0
    if params is None:
        params = model.init(jax.random.PRNGKey(0))
    opt_state = steps_mod.init_opt_state(params)

    latest = ckpt.latest_step()
    if latest is not None:
        (params, opt_state), manifest = ckpt.restore(
            (params, opt_state), shardings=shardings)
        step0 = manifest["step"]
        log(f"[train] resumed from step {step0}")

    train_step = steps_mod.make_train_step(
        model, lr=cfg.lr, microbatches=cfg.microbatches, remat=True)
    jstep = jax.jit(train_step, donate_argnums=(0, 1))

    hb, straggler, policy = Heartbeat(), StragglerDetector(), FailurePolicy()
    history = []
    step = step0
    while step < cfg.total_steps:
        t0 = time.time()
        tokens = data_source.batch(step, batch_size, seq_len)
        if cfg.straggler_sleep_at == step:
            time.sleep(0.2)  # injected slow data read
        batch = {"tokens": jax.numpy.asarray(tokens)}
        params, opt_state, metrics = jstep(params, opt_state, batch)
        if cfg.simulate_failure_at == step:
            raise SimulatedFailure(f"injected failure at step {step}")
        dt = time.time() - t0
        hb.tick("worker0")
        if straggler.observe(dt):
            log(f"[train] step {step}: straggler ({dt:.3f}s vs ewma "
                f"{straggler.ewma_s:.3f}s) — mitigation: skip-and-log")
        step += 1
        if step % cfg.log_every == 0 or step == cfg.total_steps:
            loss = float(metrics["loss"])
            history.append((step, loss, dt))
            log(f"[train] step {step} loss {loss:.4f} ({dt*1000:.0f} ms)")
        if step % cfg.checkpoint_every == 0 or step == cfg.total_steps:
            ckpt.save(step, (params, opt_state))
    ckpt.wait()
    return params, opt_state, history
