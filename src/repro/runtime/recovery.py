"""Crash recovery for the serving stack: checkpoint + WAL replay
(DESIGN.md §16).

``GraphCheckpointer`` wraps the generic sharded ``Checkpointer`` with the
graph-specific tree: the six ``GraphState`` device fields (both packed
adjacency mirrors included), every retained ``EpochRing`` record, and the
pool's logical registers (linearization log, epoch->prefix map, ticket id
counter, index freshness stamp) as JSON extra.  The ring makes the leaf
count variable per checkpoint, which is why ``Checkpointer`` grew
``restore_raw``.

``recover`` rebuilds the pre-crash published prefix: load the newest
checkpoint, then replay every WAL record with a newer epoch through the
SAME fused ``apply_ops_fast`` path (same lane padding, same auto-grow
replay discipline) the live pool used — so the recovered state is
bit-identical, not merely equivalent.  Replay is idempotent: records at
or below the checkpointed epoch are skipped (the ``wal-fsync`` crash can
leave a durable record the checkpoint already covers), and each record's
stored result codes are cross-checked against the replayed ones — a
mismatch means log/checkpoint corruption and raises ``RecoveryError``
rather than silently serving wrong state.

``resume_pool`` turns a ``Recovered`` into a live ``IngestPool`` whose
published epoch, linearization log, and epoch ring continue exactly where
the dead process stopped.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import R_TABLE_FULL, apply_ops_fast, grow, make_graph, \
    make_op_batch
from repro.core import partition
from repro.core.epochs import EpochRing
from repro.core.graph import GraphState
from repro.checkpoint import Checkpointer
from repro.obs import trace as _trace
from repro.obs.metrics import global_registry as _obs_registry
from repro.runtime.wal import WriteAheadLog

_STATE_FIELDS = ("vkey", "valive", "vver", "ecnt", "adj_packed",
                 "adj_in_packed")


class RecoveryError(RuntimeError):
    """Checkpoint/WAL contents contradict each other — refuse to serve."""


@dataclass
class Recovered:
    """Everything ``recover`` reconstructed from disk."""

    state: GraphState | object        # dense, or sharded when mesh given
    epoch: int
    linearization: list = field(default_factory=list)
    epoch_log: dict = field(default_factory=dict)
    next_batch_id: int = 0
    ring: EpochRing = field(default_factory=EpochRing)
    replayed_rounds: int = 0          # WAL records applied on top of the ckpt
    skipped_records: int = 0          # idempotence: records the ckpt covered
    ckpt_step: int | None = None      # checkpoint epoch loaded (None = fresh)
    index_stamp: dict | None = None
    restore_s: float = 0.0


class GraphCheckpointer:
    """Graph-aware snapshots at a round cadence, truncating the WAL behind
    them (the checkpoint-truncation invariant: every epoch is covered by
    the checkpoint XOR the WAL tail, never neither)."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.inner = Checkpointer(directory, keep=keep)

    def _leaves_manifest(self, *, epoch, state, ring, linearization,
                         epoch_log, next_batch_id, index_stamp):
        dense = partition.unshard(state) if hasattr(state, "mesh") else state
        leaves = [np.asarray(getattr(dense, f)) for f in _STATE_FIELDS]
        ring_leaves, ring_meta = ring.dump()
        extra = {
            "kind": "graph",
            "epoch": int(epoch),
            "capacity": int(dense.capacity),
            "n_state_leaves": len(_STATE_FIELDS),
            "ring_meta": ring_meta,
            "linearization": [int(b) for b in linearization],
            "epoch_log": {str(k): int(v) for k, v in epoch_log.items()},
            "next_batch_id": int(next_batch_id),
            "index_stamp": index_stamp,
        }
        return leaves + ring_leaves, extra

    def save_graph(self, *, epoch, state, ring, linearization, epoch_log,
                   next_batch_id, index_stamp=None, blocking=True) -> None:
        """One durable graph snapshot, published atomically at step=epoch."""
        leaves, extra = self._leaves_manifest(
            epoch=epoch, state=state, ring=ring, linearization=linearization,
            epoch_log=epoch_log, next_batch_id=next_batch_id,
            index_stamp=index_stamp)
        with _trace.span("ckpt.save", epoch=int(epoch), leaves=len(leaves)):
            t0 = time.perf_counter()
            self.inner.save(int(epoch), leaves, extra=extra,
                            blocking=blocking)
            if blocking and _trace.enabled():
                _obs_registry().observe("ckpt.save_s",
                                        time.perf_counter() - t0)

    def save_torn(self, *, epoch, state, ring, linearization, epoch_log,
                  next_batch_id, index_stamp=None) -> None:
        """The ``ckpt-mid-write`` crash: the tmp dir is fully written but
        the rename never happens — ``restore`` must load the PREVIOUS
        step (tests pin this on the generic checkpointer too)."""
        self.inner.wait()
        leaves, extra = self._leaves_manifest(
            epoch=epoch, state=state, ring=ring, linearization=linearization,
            epoch_log=epoch_log, next_batch_id=next_batch_id,
            index_stamp=index_stamp)
        manifest = {
            "step": int(epoch),
            "treedef": "torn",
            "n_leaves": len(leaves),
            "shapes": [list(x.shape) for x in leaves],
            "dtypes": [str(x.dtype) for x in leaves],
            "shard_hint": "torn write (crash simulation)",
            "extra": extra,
            "time": time.time(),
        }
        self.inner._write(int(epoch), leaves, manifest, publish=False)

    def latest_step(self) -> int | None:
        return self.inner.latest_step()

    def restore_graph(self, *, step=None):
        """(dense GraphState, EpochRing, extra dict) of a published step."""
        leaves, manifest = self.inner.restore_raw(step=step)
        extra = manifest["extra"]
        if extra.get("kind") != "graph":
            raise RecoveryError(f"checkpoint step {manifest['step']} is not "
                                f"a graph snapshot")
        n = int(extra["n_state_leaves"])
        state = GraphState(*[jnp.asarray(x) for x in leaves[:n]])
        ring = EpochRing.load(leaves[n:], extra["ring_meta"])
        return state, ring, extra


def _replay_apply(base, batch, *, mesh, auto_grow):
    """The pool's fused-apply-with-grow discipline, replicated exactly so
    replayed epochs are bit-identical to the ones the dead pool published."""
    grows = 0
    if mesh is not None:
        state, res = partition.apply_ops_fast(base, batch)
    else:
        state, res = apply_ops_fast(base, batch)
    res = np.asarray(res)
    while auto_grow and (res == R_TABLE_FULL).any():
        if mesh is not None:
            base = partition.grow(base, 2 * base.capacity)
            state, res = partition.apply_ops_fast(base, batch)
        else:
            base = grow(base, 2 * base.capacity)
            state, res = apply_ops_fast(base, batch)
        res = np.asarray(res)
        grows += 1
    return state, res, grows


def recover(ckpt: GraphCheckpointer | str | None, wal: WriteAheadLog | str | None,
            *, capacity: int = 32, mesh=None, auto_grow: bool = True,
            retain_epochs: int = 64, verify_results: bool = True) -> Recovered:
    """Latest checkpoint + WAL replay -> the pre-crash published prefix.

    ``ckpt``/``wal`` accept live objects or paths (or None: recover from
    the other alone; both None yields a fresh empty graph).  ``capacity``
    only seats the fresh-graph case — a checkpoint carries its own.
    """
    t0 = time.perf_counter()
    if isinstance(ckpt, str):
        ckpt = GraphCheckpointer(ckpt)
    if isinstance(wal, str):
        wal = WriteAheadLog(wal)

    with _trace.span("recovery.restore") as sp:
        out = Recovered(state=None, epoch=0, ring=EpochRing(retain_epochs))
        # 1) newest durable checkpoint (a torn tmp dir is invisible: only
        #    renamed step_* dirs are addressable)
        dense = None
        if ckpt is not None and ckpt.latest_step() is not None:
            dense, ring, extra = ckpt.restore_graph()
            out.epoch = int(extra["epoch"])
            out.linearization = list(extra["linearization"])
            out.epoch_log = {int(k): int(v)
                             for k, v in extra["epoch_log"].items()}
            out.next_batch_id = int(extra["next_batch_id"])
            out.index_stamp = extra.get("index_stamp")
            out.ring = ring
            out.ckpt_step = int(extra["epoch"])
        if dense is None:
            dense = make_graph(capacity)
            out.epoch_log = {0: 0}
            out.ring = EpochRing(retain_epochs)
            out.ring.reset(0, dense)

        state = partition.shard_state(mesh, dense) if mesh is not None \
            else dense

        # 2) idempotent WAL replay of every epoch past the checkpoint
        if wal is not None:
            for rec in wal.records():
                if rec.epoch <= out.epoch:
                    out.skipped_records += 1     # ckpt already covers it
                    continue
                if rec.epoch != out.epoch + 1:
                    raise RecoveryError(
                        f"WAL gap: have epoch {out.epoch}, next record is "
                        f"epoch {rec.epoch}")
                batch = make_op_batch(rec.ops, lanes=rec.pad)
                state, res, _ = _replay_apply(state, batch, mesh=mesh,
                                              auto_grow=auto_grow)
                if verify_results and rec.results:
                    got = [int(x) for x in np.asarray(res)[:rec.lanes]]
                    if got != [int(x) for x in rec.results]:
                        raise RecoveryError(
                            f"replay divergence at epoch {rec.epoch}: "
                            f"logged {rec.results} got {got}")
                out.linearization.extend(int(b) for b in rec.batch_ids)
                out.epoch = rec.epoch
                out.epoch_log[rec.epoch] = len(out.linearization)
                out.ring.push(rec.epoch, state)
                if rec.batch_ids:
                    out.next_batch_id = max(out.next_batch_id,
                                            max(rec.batch_ids) + 1)
                out.replayed_rounds += 1

        out.state = state
        out.restore_s = time.perf_counter() - t0
        sp.set(epoch=out.epoch, replayed=out.replayed_rounds,
               skipped=out.skipped_records)
        if _trace.enabled():
            _obs_registry().observe("recovery.restore_s", out.restore_s)
    return out


def resume_pool(recovered: Recovered, **pool_kwargs):
    """Construct an IngestPool that continues from a ``Recovered`` point:
    same published epoch, linearization log, epoch ring, and ticket-id
    counter as the dead process."""
    from repro.runtime.ingest import IngestPool

    pool = IngestPool(recovered.state, **pool_kwargs)
    pool._slots = [(recovered.epoch, recovered.state),
                   (recovered.epoch, recovered.state)]
    pool._cur = 0
    pool._head = recovered.state
    pool.ring = recovered.ring
    pool.linearization = list(recovered.linearization)
    pool.epoch_log = dict(recovered.epoch_log)
    pool._next_id = int(recovered.next_batch_id)
    pool.stats.epochs = recovered.epoch
    pool.stats.epochs_retained = len(pool.ring) + 1
    pool.stats.epochs_evicted = pool.ring.evicted
    return pool
