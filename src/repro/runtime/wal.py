"""Write-ahead log for the multi-tenant ingest pool (DESIGN.md §16).

Durability contract: every admitted fused round appends exactly ONE
record — the linearized op list plus the admission outcome (client ids,
lanes, epoch, per-ticket result codes) — and the pool may acknowledge
the round to clients only after that record is fsync-durable.  The
ordering is therefore

    append -> flush -> fsync -> publish epoch -> ack clients

so a kill -9 at any point loses only *unacknowledged* work.  Recovery
(``runtime/recovery.py``) replays the tail of this log on top of the
latest graph checkpoint through the same ``apply_ops_fast`` kernel the
live pool uses, which makes the recovered state bit-identical to the
pre-crash published prefix.

Record framing (all little-endian):

    MAGIC (4 bytes, b"RWAL") | length u32 | crc32 u32 | payload JSON

The CRC covers the payload bytes only.  A torn tail — short frame,
magic mismatch, or checksum mismatch — marks the end of the valid
prefix: ``open`` truncates the file back to the last whole record, so a
crash mid-append (``wal-append`` stage) can never resurrect a
half-written round.  Truncation behind a checkpoint keeps the log
bounded: ``truncate_through(epoch)`` atomically rewrites the log with
only the records strictly newer than the checkpointed epoch.
"""
from __future__ import annotations

import json
import os
import pathlib
import struct
import zlib
from dataclasses import dataclass, field
from typing import Iterator, Optional

MAGIC = b"RWAL"
_HEADER = struct.Struct("<4sII")  # magic, payload length, crc32


@dataclass
class WalStats:
    """Counters the ingest pool folds into ``IngestStats``."""

    records: int = 0          # records appended this process lifetime
    bytes: int = 0            # bytes appended (headers included)
    truncations: int = 0      # truncate_through calls
    torn_drops: int = 0       # torn-tail bytes discarded on open
    append_s: float = 0.0     # cumulative wall time inside append()


@dataclass
class WalRecord:
    """One durable fused round, exactly as replay needs it."""

    epoch: int                      # epoch published for this round
    ops: list                       # [[opcode, k1, k2], ...] linearized order
    pad: int                        # lane count the fused batch was padded to
    clients: list = field(default_factory=list)   # client id per admitted batch
    batch_ids: list = field(default_factory=list)  # pool ticket ids, ack order
    results: list = field(default_factory=list)   # per-op result codes
    lanes: int = 0                  # real (unpadded) op count

    def to_payload(self) -> bytes:
        return json.dumps({
            "epoch": self.epoch, "ops": self.ops, "pad": self.pad,
            "clients": self.clients, "batch_ids": self.batch_ids,
            "results": self.results, "lanes": self.lanes,
        }, separators=(",", ":")).encode("utf-8")

    @classmethod
    def from_payload(cls, payload: bytes) -> "WalRecord":
        d = json.loads(payload.decode("utf-8"))
        return cls(epoch=int(d["epoch"]), ops=[list(o) for o in d["ops"]],
                   pad=int(d["pad"]), clients=list(d.get("clients", [])),
                   batch_ids=list(d.get("batch_ids", [])),
                   results=list(d.get("results", [])),
                   lanes=int(d.get("lanes", len(d["ops"]))))


class WriteAheadLog:
    """Append-only checksummed log with torn-tail recovery.

    Opening an existing log scans it front to back; the first frame that
    fails magic/length/CRC validation ends the valid prefix and the file
    is truncated there (the ``wal-append`` crash leaves exactly such a
    tail).  Appends are ``write + flush + fsync`` before returning — the
    caller's ack must happen after ``append`` returns, never before.
    """

    def __init__(self, path, *, clock=None):
        self.path = pathlib.Path(path)
        self.stats = WalStats()
        self._clock = clock  # perf counter for append_s; None = time.perf_counter
        self.path.parent.mkdir(parents=True, exist_ok=True)
        valid_end, n = self._scan()
        size = self.path.stat().st_size if self.path.exists() else 0
        if size > valid_end:
            # torn tail: drop everything past the last whole record
            self.stats.torn_drops += size - valid_end
            with open(self.path, "r+b") as f:
                f.truncate(valid_end)
                f.flush()
                os.fsync(f.fileno())
        self._n_records = n
        self._f = open(self.path, "ab")

    # -- internal ---------------------------------------------------------
    def _scan(self) -> tuple[int, int]:
        """Return (byte offset of valid prefix end, record count)."""
        if not self.path.exists():
            return 0, 0
        end = 0
        n = 0
        with open(self.path, "rb") as f:
            while True:
                header = f.read(_HEADER.size)
                if len(header) < _HEADER.size:
                    break
                magic, length, crc = _HEADER.unpack(header)
                if magic != MAGIC:
                    break
                payload = f.read(length)
                if len(payload) < length or zlib.crc32(payload) != crc:
                    break
                try:
                    WalRecord.from_payload(payload)
                except (ValueError, KeyError):
                    break
                end = f.tell()
                n += 1
        return end, n

    def _frame(self, payload: bytes) -> bytes:
        return _HEADER.pack(MAGIC, len(payload), zlib.crc32(payload)) + payload

    # -- public API -------------------------------------------------------
    def __len__(self) -> int:
        return self._n_records

    def append(self, record: WalRecord) -> None:
        """Durably append one record: write, flush, fsync.  Only after
        this returns may the caller publish the epoch and ack clients
        (the ``durable-ack`` lint rule enforces the call-site ordering)."""
        import time
        clock = self._clock or time.perf_counter
        t0 = clock()
        frame = self._frame(record.to_payload())
        self._f.write(frame)
        self._f.flush()
        os.fsync(self._f.fileno())
        self._n_records += 1
        self.stats.records += 1
        self.stats.bytes += len(frame)
        self.stats.append_s += clock() - t0

    def append_torn(self, record: WalRecord, keep_bytes: Optional[int] = None
                    ) -> None:
        """Simulate the ``wal-append`` crash: write a PARTIAL frame (no
        fsync of a whole record) so the next open sees a torn tail and
        truncates it.  ``keep_bytes`` defaults to header + half the
        payload."""
        frame = self._frame(record.to_payload())
        if keep_bytes is None:
            keep_bytes = _HEADER.size + max(1, (len(frame) - _HEADER.size) // 2)
        keep_bytes = max(1, min(keep_bytes, len(frame) - 1))
        self._f.write(frame[:keep_bytes])
        self._f.flush()
        os.fsync(self._f.fileno())

    def records(self) -> Iterator[WalRecord]:
        """Iterate the valid records currently on disk (front to back)."""
        self._f.flush()
        with open(self.path, "rb") as f:
            while True:
                header = f.read(_HEADER.size)
                if len(header) < _HEADER.size:
                    return
                magic, length, crc = _HEADER.unpack(header)
                if magic != MAGIC:
                    return
                payload = f.read(length)
                if len(payload) < length or zlib.crc32(payload) != crc:
                    return
                yield WalRecord.from_payload(payload)

    def truncate_through(self, epoch: int) -> int:
        """Drop every record with ``record.epoch <= epoch`` (they are
        covered by a durable checkpoint).  Atomic: rewrites to a temp
        file and renames over the log.  Returns records kept."""
        kept = [r for r in self.records() if r.epoch > epoch]
        tmp = self.path.with_name(self.path.name + ".tmp")
        with open(tmp, "wb") as f:
            for r in kept:
                f.write(self._frame(r.to_payload()))
            f.flush()
            os.fsync(f.fileno())
        self._f.close()
        os.rename(tmp, self.path)
        dirfd = os.open(str(self.path.parent), os.O_RDONLY)
        try:
            os.fsync(dirfd)
        finally:
            os.close(dirfd)
        self._f = open(self.path, "ab")
        self._n_records = len(kept)
        self.stats.truncations += 1
        return len(kept)

    def size_bytes(self) -> int:
        self._f.flush()
        return self.path.stat().st_size if self.path.exists() else 0

    def close(self) -> None:
        try:
            self._f.close()
        except OSError:  # pragma: no cover - best effort on shutdown
            pass
