"""Fault tolerance primitives: heartbeats, straggler detection, restart policy.

Single-process simulation of the fleet-level mechanisms (interfaces are the
real ones; the transport is in-memory):

  * Heartbeat: every worker ticks per step; a missing tick past ``timeout``
    marks the worker suspect -> the controller triggers checkpoint-restore
    on the survivors (elastic restore handles the smaller mesh).
  * StragglerDetector: per-step wall-time EWMA; steps slower than
    ``factor`` x EWMA are flagged. Mitigation at scale = redundant data
    loading + skipping the straggler's microbatch (data-parallel redundancy);
    here we log and expose the decision.
  * FailurePolicy: exponential-backoff restart budget, the controller-side
    guard against crash loops.
  * FaultInjector: a deterministic kill-plan for the multi-tenant ingest
    pool (runtime/ingest.py) — a client batch can be made to die at a named
    admission stage; the pool must release its entity locks and keep the
    published state reachable by the completed batches alone
    (DESIGN.md §12).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class Heartbeat:
    timeout_s: float = 30.0
    last: dict = field(default_factory=dict)

    def tick(self, worker: str, now: float | None = None):
        self.last[worker] = now if now is not None else time.time()

    def suspects(self, now: float | None = None) -> list[str]:
        now = now if now is not None else time.time()
        return [w for w, t in self.last.items() if now - t > self.timeout_s]


@dataclass
class StragglerDetector:
    factor: float = 3.0
    alpha: float = 0.1
    ewma_s: float | None = None
    flagged: int = 0

    def observe(self, step_s: float) -> bool:
        """Returns True if this step is a straggler."""
        if self.ewma_s is None:
            self.ewma_s = step_s
            return False
        is_straggler = step_s > self.factor * self.ewma_s
        if is_straggler:
            self.flagged += 1
        else:  # stragglers don't poison the baseline
            self.ewma_s = (1 - self.alpha) * self.ewma_s + self.alpha * step_s
        return is_straggler


@dataclass
class FaultInjector:
    """Deterministic crash plan for ingest admission (DESIGN.md §12).

    ``plan`` is a list of (client_id, stage) pairs; each entry kills that
    client's NEXT batch reaching that stage, once. Stages the ingest pool
    probes:

      * ``"admit"`` — after the batch's sorted entity locks are acquired,
        before its lanes enter the fused batch;
      * ``"apply"`` — after the fused ``apply_ops_fast`` result (which
        includes the batch's lanes) is computed, before it is published —
        the torn-write window the pool must recompute its way out of.

    ``fired`` records consumed entries for assertions.
    """

    plan: list = field(default_factory=list)
    fired: list = field(default_factory=list)

    def should_die(self, client_id: str, stage: str) -> bool:
        key = (client_id, stage)
        if key in self.plan:
            self.plan.remove(key)
            self.fired.append(key)
            return True
        return False


@dataclass
class FailurePolicy:
    max_restarts: int = 5
    backoff_s: float = 1.0
    restarts: int = 0

    def on_failure(self) -> float:
        """Returns backoff seconds, raises when the budget is exhausted."""
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise RuntimeError("restart budget exhausted; paging a human")
        return self.backoff_s * (2 ** (self.restarts - 1))
