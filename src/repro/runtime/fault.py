"""Fault tolerance primitives: heartbeats, straggler detection, restart policy.

Single-process simulation of the fleet-level mechanisms (interfaces are the
real ones; the transport is in-memory):

  * Heartbeat: every worker ticks per step; a missing tick past ``timeout``
    marks the worker suspect -> the controller triggers checkpoint-restore
    on the survivors (elastic restore handles the smaller mesh).
  * StragglerDetector: per-step wall-time EWMA; steps slower than
    ``factor`` x EWMA are flagged. Mitigation at scale = redundant data
    loading + skipping the straggler's microbatch (data-parallel redundancy);
    here we log and expose the decision.
  * FailurePolicy: exponential-backoff restart budget, the controller-side
    guard against crash loops.
  * FaultInjector: a deterministic kill-plan for the multi-tenant ingest
    pool (runtime/ingest.py) — a client batch can be made to die at a named
    admission stage; the pool must release its entity locks and keep the
    published state reachable by the completed batches alone
    (DESIGN.md §12).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


class SimulatedCrash(RuntimeError):
    """Raised by the ingest pool when a FaultInjector durability stage
    fires: models a process kill -9 at that exact point.  The pool is
    dead after this — the chaos harness recovers a fresh pool from the
    WAL + checkpoint and proves equivalence (DESIGN.md §16).

    ``stage`` names where the kill landed; ``epoch`` is the epoch the
    dying round WOULD have published (for harness assertions).
    """

    def __init__(self, stage: str, epoch: int = -1):
        super().__init__(f"simulated kill -9 at stage {stage!r}")
        self.stage = stage
        self.epoch = epoch


@dataclass
class Heartbeat:
    timeout_s: float = 30.0
    last: dict = field(default_factory=dict)

    def tick(self, worker: str, now: float | None = None):
        self.last[worker] = now if now is not None else time.time()

    def suspects(self, now: float | None = None) -> list[str]:
        now = now if now is not None else time.time()
        return [w for w, t in self.last.items() if now - t > self.timeout_s]


@dataclass
class StragglerDetector:
    factor: float = 3.0
    alpha: float = 0.1
    ewma_s: float | None = None
    flagged: int = 0

    def observe(self, step_s: float) -> bool:
        """Returns True if this step is a straggler."""
        if self.ewma_s is None:
            self.ewma_s = step_s
            return False
        is_straggler = step_s > self.factor * self.ewma_s
        if is_straggler:
            self.flagged += 1
        else:  # stragglers don't poison the baseline
            self.ewma_s = (1 - self.alpha) * self.ewma_s + self.alpha * step_s
        return is_straggler


@dataclass
class FaultInjector:
    """Deterministic crash plan for ingest admission (DESIGN.md §12).

    ``plan`` is a list of (client_id, stage) pairs; each entry kills that
    client's NEXT batch reaching that stage, once. Stages the ingest pool
    probes:

      * ``"admit"`` — after the batch's sorted entity locks are acquired,
        before its lanes enter the fused batch;
      * ``"apply"`` — after the fused ``apply_ops_fast`` result (which
        includes the batch's lanes) is computed, before it is published —
        the torn-write window the pool must recompute its way out of.

    The four DURABILITY stages (DESIGN.md §16) model a whole-process
    kill -9 instead of a single batch abort — the pool raises
    ``SimulatedCrash`` and the chaos harness must recover a fresh pool
    from checkpoint + WAL.  The client_id for these is the sentinel
    ``"*"`` (the crash is not attributable to one client):

      * ``"wal-append"`` — mid-append: a torn, checksum-invalid frame is
        on disk; recovery must truncate it (round unacked -> no loss);
      * ``"wal-fsync"`` — the record is fully durable but the epoch was
        never published and no client was acked; replay must be
        idempotent (the recovered log may extend the published prefix);
      * ``"ckpt-mid-write"`` — checkpoint tmp dir written, rename never
        happened; recovery must load the PREVIOUS checkpoint;
      * ``"post-publish-pre-ack"`` — record durable AND epoch published,
        but clients were never acked; recovery re-derives the identical
        state and the harness treats the round as durable-but-unacked.

    ``fired`` records consumed entries for assertions.  ``delays`` maps a
    plan entry to the number of probes of that (client, stage) pair to let
    PASS before it becomes eligible — ``delays[("*", "wal-fsync")] = 3``
    arms the kill at the 4th round reaching the fsync point, which is how
    the chaos suite sweeps a crash across every round of a schedule.
    """

    plan: list = field(default_factory=list)
    fired: list = field(default_factory=list)
    delays: dict = field(default_factory=dict)

    def should_die(self, client_id: str, stage: str) -> bool:
        key = (client_id, stage)
        if key in self.plan:
            left = self.delays.get(key, 0)
            if left > 0:
                self.delays[key] = left - 1
                return False
            self.plan.remove(key)
            self.fired.append(key)
            return True
        return False


@dataclass
class FailurePolicy:
    max_restarts: int = 5
    backoff_s: float = 1.0
    restarts: int = 0

    def on_failure(self) -> float:
        """Returns backoff seconds, raises when the budget is exhausted."""
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise RuntimeError("restart budget exhausted; paging a human")
        return self.backoff_s * (2 ** (self.restarts - 1))
