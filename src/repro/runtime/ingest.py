"""Multi-tenant bounded-concurrency ingestion for the graph server.

The serving story so far admitted ONE mutation batch at a time
(``GraphCoServer.submit``). A real deployment has many clients submitting
overlapping batches plus a heavy read stream. This layer (DESIGN.md §12)
adds the admission machinery between the client surface and the fused
``apply_ops_fast`` engines:

  * **Conflict detection + sorted entity-ID locks.** Every client batch
    declares its entity footprint (the vertex keys its ops name). Admission
    try-acquires one lock per entity in ASCENDING entity-ID order —
    deadlock-free by construction (all acquirers order locks identically,
    so no wait cycle can form) — and releases in descending order. Batches
    whose footprints collide with an already-admitted batch simply stay
    queued for the next round (a retry, counted), never blocking the round.
  * **Coalescing.** All batches admitted in one round are pairwise
    entity-disjoint, so they commute; their lanes are concatenated (in
    submission order) into ONE fused ``apply_ops_fast`` call — the batch-
    granularity restatement of the engine's own disjoint-access argument
    (DESIGN.md §3). Lane padding to power-of-two buckets bounds the number
    of distinct jit shapes the coalescer can produce.
  * **Epoch double-buffering.** The writer side mutates a private head;
    each fused apply lands as a write into the non-current snapshot slot
    followed by one atomic slot flip. Readers (``get_paths``/``get_reach``)
    always see the last PUBLISHED epoch and never wait on admission —
    non-blocking co-serving at serving scale (DESIGN.md §5(ii), §12).
  * **Retained epoch ring.** Every publish also lands one
    ``(epoch, version_vector, packed row delta)`` record in a bounded
    ``core.epochs.EpochRing`` (DESIGN.md §13): queries starved by a
    mutator that commits every round resolve wait-free against the pinned
    published epoch (``snapshot_epoch``), and ``state_at``/``epoch_diff``
    serve time-travel reachability and audit diffs over the retention
    window. ``epoch_log`` is pruned to the same window — the unbounded
    epoch->prefix dict previously leaked one entry per published epoch.
  * **Linearization log.** The pool records the serial order it claims
    (admission order within a round, round order across rounds, per-client
    program order preserved). The schedule-exploring property harness
    (repro.testing.schedules) replays that order through the sequential
    oracle and the reference engine: the admitted parallel execution must
    be bit-identical to it — the paper's linearizability claim restated at
    serving scale.

Batches containing RemoveVertex (or naming negative keys) take an
EXCLUSIVE footprint: RemoveVertex bumps the ``ecnt`` of every in-edge
source — a cross-key effect no per-entity footprint can cover — so such a
batch is admitted alone, mirroring ``ops.py`` routing RemoveVertex lanes
to the serial pass (DESIGN.md §3, §12).

Fault tolerance: an optional ``FaultInjector`` (runtime/fault.py) can kill
a client batch mid-admission. A batch that dies after acquiring locks but
before its round publishes is aborted: its locks are released, the fused
result that included its lanes is DISCARDED and recomputed from the same
pre-round state without it — the published epoch is always a state some
serial order of the *completed* batches alone produces (no torn fused
apply). The auto-grow replay (R_TABLE_FULL) likewise re-applies the whole
fused batch on the grown pre-round state, exactly like the single-tenant
server path.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import numpy as np

from repro.core import (
    OP_ADD_E,
    OP_ADD_V,
    OP_CON_E,
    OP_CON_V,
    OP_REM_E,
    OP_REM_V,
    R_TABLE_FULL,
    apply_ops_fast,
    grow,
    make_op_batch,
)
from repro.core import partition
from repro.core.epochs import EpochEvictedError, EpochRing
from repro.obs import trace as _trace
from repro.obs.metrics import MetricsRegistry, StatsView
from repro.obs.metrics import global_registry as _obs_registry
from repro.runtime.fault import SimulatedCrash
from repro.runtime.wal import WalRecord

_VERTEX_OPS = (OP_ADD_V, OP_REM_V, OP_CON_V)
_EDGE_OPS = (OP_ADD_E, OP_REM_E, OP_CON_E)


def batch_footprint(ops) -> tuple[frozenset, bool]:
    """(entity footprint, exclusive) of a client batch.

    The footprint is the set of vertex keys the ops name — the entities the
    batch's locks cover. ``exclusive`` marks batches whose effects a
    per-entity footprint can NOT cover (RemoveVertex's cross-key in-edge
    ecnt bumps; negative keys aliasing slot-table sentinels): they are
    admitted alone (DESIGN.md §12).
    """
    keys: set[int] = set()
    exclusive = False
    for op in ops:
        opc = op[0]
        k1 = op[1] if len(op) > 1 else -1
        k2 = op[2] if len(op) > 2 else -1
        if opc in _VERTEX_OPS:
            keys.add(int(k1))
            if opc == OP_REM_V or k1 < 0:
                exclusive = True
        elif opc in _EDGE_OPS:
            keys.add(int(k1))
            keys.add(int(k2))
            if k1 < 0 or k2 < 0:
                exclusive = True
    return frozenset(keys), exclusive


class EntityLockTable:
    """Per-entity try-locks acquired in sorted entity-ID order.

    All acquirers sort their footprint ascending and release descending, so
    the waits-for graph is acyclic and admission is deadlock-free by
    construction (DESIGN.md §12). ``try_acquire_sorted`` is all-or-nothing:
    on the first busy entity it backs out everything it took.
    """

    def __init__(self):
        self._locks: dict[int, threading.Lock] = {}
        self._guard = threading.Lock()

    def _lock_for(self, entity: int) -> threading.Lock:
        with self._guard:
            lk = self._locks.get(entity)
            if lk is None:
                lk = self._locks[entity] = threading.Lock()
            return lk

    def try_acquire_sorted(self, footprint) -> bool:
        taken = []
        for entity in sorted(footprint):
            lk = self._lock_for(entity)
            if lk.acquire(blocking=False):
                taken.append(lk)
            else:
                for held in reversed(taken):
                    held.release()
                return False
        return True

    def release_sorted(self, footprint) -> None:
        for entity in sorted(footprint, reverse=True):
            self._locks[entity].release()

    def held(self, entity: int) -> bool:
        with self._guard:
            lk = self._locks.get(entity)
        return lk is not None and lk.locked()


@dataclass
class Ticket:
    """One client batch's journey through admission (returned by submit)."""

    batch_id: int
    client_id: str
    ops: list
    footprint: frozenset
    exclusive: bool
    enqueue_t: float
    status: str = "queued"            # queued -> applied | aborted
    results: np.ndarray | None = None
    epoch: int = 0                    # publish epoch the batch landed in
    wait_s: float = 0.0               # enqueue -> admission
    retries: int = 0                  # rounds it lost conflict detection

    @property
    def lanes(self) -> int:
        return len(self.ops)


class IngestStats(StatsView):
    """Admission observability (surfaced through ServeStats and the
    ``get_metrics`` endpoint, DESIGN.md §12, §14).

    A ``MetricsRegistry``-backed view: each field below is stored under
    ``ingest.<field>`` in the pool's registry, while every pre-existing
    ``stats.field`` read/write keeps its exact dataclass semantics.
    """

    _PREFIX = "ingest"
    _SPEC = {
        "submitted": ("counter", 0),
        "applied": ("counter", 0),
        "aborted": ("counter", 0),
        "fused_calls": ("counter", 0),         # fused apply_ops_fast calls
        "coalesced_batches": ("counter", 0),   # client batches they carried
        "coalesce_max": ("gauge", 0),          # max batches in one fused call
        "coalesce_lanes_max": ("gauge", 0),    # max fused lanes (pre-padding)
        "retries": ("counter", 0),             # admission round losses
        "wait_s": ("counter", 0.0),            # total enqueue->admission wait
        "wait_max_s": ("gauge", 0.0),
        "queue_depth_max": ("gauge", 0),
        "queue_depth": ("gauge", 0),           # depth at the last pump
        "epochs": ("gauge", 0),                # snapshot epochs published
        "grow_events": ("counter", 0),         # R_TABLE_FULL auto-grow replays
        "epochs_retained": ("gauge", 0),       # epochs addressable in the ring
        "epochs_evicted": ("gauge", 0),        # deltas dropped by retention
        "wal_records": ("gauge", 0),           # WAL records appended (lifetime)
        "wal_bytes": ("gauge", 0),             # WAL bytes appended (lifetime)
        "wal_append_s": ("counter", 0.0),      # wall time inside WAL appends
        "wal_truncations": ("gauge", 0),       # checkpoint-driven truncations
        "ckpt_saves": ("counter", 0),          # graph checkpoints published
    }


def _next_pow2(n: int, floor: int = 8) -> int:
    p = floor
    while p < n:
        p <<= 1
    return p


class IngestPool:
    """Bounded-concurrency multi-tenant admission onto one graph state.

    Cooperative driver: ``submit`` enqueues and returns a ``Ticket``;
    ``pump`` runs one admission round (conflict detection, sorted-lock
    acquisition, coalesced fused apply, epoch publish); ``flush`` pumps
    until the queue drains. The serving loop calls ``pump`` between decode
    steps; the schedule harness calls it wherever the schedule under test
    says (DESIGN.md §12).

    Thread-safe: ``submit`` may be called from many client threads; rounds
    are serialized by an admission mutex while the entity locks keep any
    overlapping acquirers deadlock-free.
    """

    def __init__(self, state, *, mesh=None, auto_grow: bool = True,
                 max_inflight: int = 8, max_coalesce_lanes: int = 256,
                 pad_lanes: bool = True, fault=None, on_grow=None,
                 clock=time.monotonic, retain_epochs: int = 64,
                 registry: MetricsRegistry | None = None,
                 wal=None, ckpt=None, ckpt_every: int = 0):
        self.mesh = mesh if mesh is not None else getattr(state, "mesh", None)
        self.auto_grow = auto_grow
        self.max_inflight = int(max_inflight)
        self.max_coalesce_lanes = int(max_coalesce_lanes)
        self.pad_lanes = pad_lanes
        self.fault = fault
        self.on_grow = on_grow
        self.clock = clock
        # durability (DESIGN.md §16): a WriteAheadLog makes every acked
        # round replayable; a GraphCheckpointer at a round cadence bounds
        # the log (ckpt_every=0 disables cadence checkpoints)
        self.wal = wal
        self.ckpt = ckpt
        self.ckpt_every = int(ckpt_every)
        self._rounds_since_ckpt = 0
        # the owning server stamps its index freshness here so cadence
        # checkpoints carry it (runtime/serve_loop.py index_tick)
        self.index_stamp: dict | None = None
        self.locks = EntityLockTable()
        # pool-local registry (shareable with the owning server's ServeStats
        # so one snapshot serves both, DESIGN.md §14)
        self.registry = registry if registry is not None else MetricsRegistry()
        self.stats = IngestStats(self.registry)
        self.linearization: list[int] = []   # batch_ids in claimed serial order
        self.tickets: dict[int, Ticket] = {}
        self.epoch_log: dict[int, int] = {0: 0}  # epoch -> linearization prefix
        # bounded retained epoch history (wait-free snapshots + time travel,
        # DESIGN.md §13); epoch_log is pruned to its window (the unbounded
        # dict was a one-entry-per-epoch leak on long-running servers)
        self.ring = EpochRing(retain_epochs)
        self.ring.reset(0, state)
        self._head = state                   # writer-private latest state
        # double-buffered (epoch, state) snapshot slots; _cur flips atomically
        self._slots = [(0, state), (0, state)]
        self._cur = 0
        self._queue: list[Ticket] = []
        # queue/stats guard and one-admission-round guard: with-managed
        # MODULE locks, not entity locks  # repro-lint: allow(lock-order)
        self._mutex = threading.Lock()
        self._admission = threading.Lock()   # repro-lint: allow(lock-order)
        self._next_id = 0

    # -- read side (never blocks behind writers) ----------------------------
    def snapshot(self):
        """Latest PUBLISHED state — one read of the current slot, no lock."""
        return self._slots[self._cur][1]

    def snapshot_epoch(self):
        """(epoch, state) of the current published slot."""
        return self._slots[self._cur]

    @property
    def epoch(self) -> int:
        return self._slots[self._cur][0]

    def _publish(self, state) -> int:
        nxt = 1 - self._cur
        epoch = self._slots[self._cur][0] + 1
        self._slots[nxt] = (epoch, state)
        self._cur = nxt                      # the one atomic flip readers see
        self._head = state
        self.stats.epochs = epoch
        self.epoch_log[epoch] = len(self.linearization)
        # retained-ring maintenance (DESIGN.md §13): record the delta (a
        # capacity change resets the ring — every row-shaped delta is void)
        # and prune epoch_log to the addressable window, fixing the
        # unbounded per-epoch leak
        self.ring.push(epoch, state)
        oldest = self.ring.window()[0]
        for e in [e for e in self.epoch_log if e < oldest]:
            del self.epoch_log[e]
        self.stats.epochs_retained = len(self.ring) + 1
        self.stats.epochs_evicted = self.ring.evicted
        return epoch

    # -- retained-epoch read surface (DESIGN.md §13) ------------------------
    def epoch_window(self) -> tuple[int, int]:
        """(oldest addressable, newest published) epoch, inclusive."""
        return self.ring.window()

    def state_at(self, epoch: int):
        """The published state of a retained epoch — the current slot for
        the newest, a bit-identical ring reconstruction (dense) for older
        ones. Raises ``EpochEvictedError`` outside the retention window."""
        cur_epoch, cur_state = self._slots[self._cur]
        if int(epoch) == cur_epoch:
            return cur_state
        return self.ring.state_at(epoch)

    def epoch_diff(self, e1: int, e2: int):
        """Rows/keys touched between two retained epochs (``EpochDiff``);
        typed ``EpochEvictedError`` when either endpoint left the window."""
        return self.ring.diff(e1, e2)

    def linearization_prefix(self, epoch: int) -> int:
        """Length of the linearization prefix epoch ``epoch`` published.
        Raises ``EpochEvictedError`` for epochs pruned out of the window."""
        try:
            return self.epoch_log[int(epoch)]
        except KeyError:
            raise EpochEvictedError(epoch, self.ring.window()) from None

    # -- write side ---------------------------------------------------------
    def submit(self, client_id: str, ops) -> Ticket:
        """Enqueue one client batch; returns its Ticket (resolved by pump)."""
        if not ops:
            raise ValueError("empty client batch")
        footprint, exclusive = batch_footprint(ops)
        with self._mutex:
            t = Ticket(self._next_id, str(client_id), list(ops), footprint,
                       exclusive, self.clock())
            self._next_id += 1
            self.tickets[t.batch_id] = t
            self._queue.append(t)
            self.stats.submitted += 1
            self.stats.queue_depth = len(self._queue)
            self.stats.queue_depth_max = max(self.stats.queue_depth_max,
                                             len(self._queue))
        return t

    def queue_depth(self) -> int:
        with self._mutex:
            return len(self._queue)

    def _fault_fires(self, ticket: Ticket, stage: str) -> bool:
        return self.fault is not None and self.fault.should_die(
            ticket.client_id, stage)

    def _admit(self) -> list[Ticket]:
        """Conflict-detection scan: admit a pairwise-disjoint queue subset.

        FIFO scan; per-client program order is preserved by blocking a
        client's later batches the moment one of its batches is skipped.
        Entity locks are HELD by the returned tickets (released by the
        round, success or abort).
        """
        admitted: list[Ticket] = []
        lanes = 0
        blocked_clients: set[str] = set()
        with self._mutex:
            queue = list(self._queue)
            self.stats.queue_depth = len(queue)
            self.stats.queue_depth_max = max(self.stats.queue_depth_max,
                                             len(queue))
        for t in queue:
            if len(admitted) >= self.max_inflight:
                break
            if t.client_id in blocked_clients:
                continue

            def skip(t=t):
                t.retries += 1
                with self._mutex:
                    self.stats.retries += 1
                blocked_clients.add(t.client_id)

            if admitted and (t.exclusive or any(a.exclusive for a in admitted)):
                skip()                       # exclusive batches run alone
                continue
            if lanes + t.lanes > self.max_coalesce_lanes and admitted:
                skip()                       # coalesce budget exhausted
                continue
            if not self.locks.try_acquire_sorted(t.footprint):
                skip()                       # entity conflict -> next round
                continue
            if self._fault_fires(t, "admit"):
                # died holding its locks: release and abort before it ever
                # reaches the fused batch
                self.locks.release_sorted(t.footprint)
                self._abort(t)
                blocked_clients.add(t.client_id)
                continue
            admitted.append(t)
            lanes += t.lanes
            if t.exclusive:
                break
        return admitted

    def _abort(self, t: Ticket) -> None:
        t.status = "aborted"
        with self._mutex:
            self.stats.aborted += 1
            if t in self._queue:
                self._queue.remove(t)

    def _apply_with_grow(self, base, batch):
        if self.mesh is not None:
            state, res = partition.apply_ops_fast(base, batch)
        else:
            state, res = apply_ops_fast(base, batch)
        res = np.asarray(res)
        while self.auto_grow and (res == R_TABLE_FULL).any():
            # grow the PRE-round state and replay the WHOLE fused batch: the
            # visible history stays one clean linearization on the grown
            # table — identical to the single-tenant auto-grow contract.
            if self.mesh is not None:
                base = partition.grow(base, 2 * base.capacity)
                state, res = partition.apply_ops_fast(base, batch)
            else:
                base = grow(base, 2 * base.capacity)
                state, res = apply_ops_fast(base, batch)
            res = np.asarray(res)
            with self._mutex:
                self.stats.grow_events += 1
            _trace.counter("ingest.grow_events", self.stats.grow_events)
            if self.on_grow is not None:
                self.on_grow()
        return state, res

    def pump(self) -> int:
        """One admission round; returns the number of batches applied.

        Traced as one ``ingest.round`` span enclosing ``ingest.admit`` and
        the round's ``ingest.fused_apply`` (DESIGN.md §14); wall seconds
        land in the ``ingest.round_s`` histogram when tracing is on.
        """
        with self._admission, _trace.span("ingest.round") as sp:
            t0 = time.perf_counter()
            with _trace.span("ingest.admit"):
                admitted = self._admit()
            if not admitted:
                return 0
            try:
                applied = self._run_round(admitted)
            finally:
                for t in admitted:
                    if t.status != "aborted":  # aborted already released
                        self.locks.release_sorted(t.footprint)
            sp.set(admitted=len(admitted), applied=applied,
                   epoch=self.epoch)
            if _trace.enabled():
                _obs_registry().observe("ingest.round_s",
                                        time.perf_counter() - t0)
            return applied

    def _run_round(self, admitted: list[Ticket]) -> int:
        base = self._head
        while True:
            live = [t for t in admitted if t.status != "aborted"]
            if not live:
                return 0
            fused = [op for t in live for op in t.ops]
            lanes = len(fused)
            pad = _next_pow2(lanes) if self.pad_lanes else lanes
            batch = make_op_batch(fused, lanes=pad)
            with _trace.span("ingest.fused_apply", lanes=lanes, pad=pad,
                             batches=len(live)):
                t0 = time.perf_counter()
                state, res = self._apply_with_grow(base, batch)
                _trace.fence(state)
            if _trace.enabled():
                _obs_registry().observe("ingest.fused_apply_s",
                                        time.perf_counter() - t0)
            # post-apply fault window: a batch dying here has its lanes in
            # the fused result — that result must be thrown away, never
            # published (no torn apply_ops_fast; DESIGN.md §12)
            died = [t for t in live if self._fault_fires(t, "apply")]
            if died:
                for t in died:
                    self.locks.release_sorted(t.footprint)
                    self._abort(t)
                continue                     # recompute from the same base
            now = self.clock()
            # durability point (DESIGN.md §16): the round's WAL record is
            # fsync-durable BEFORE the epoch flips and BEFORE any client is
            # acked — a kill -9 past this line loses nothing acknowledged
            self._wal_commit(live, res, lanes, pad)
            with self._mutex:
                for t in live:
                    # linearization order is part of the published prefix
                    # (epoch_log maps the new epoch to this length), so it
                    # must be appended before _publish
                    self.linearization.append(t.batch_id)
                self.stats.fused_calls += 1
                self.stats.coalesced_batches += len(live)
                self.stats.coalesce_max = max(self.stats.coalesce_max, len(live))
                self.stats.coalesce_lanes_max = max(
                    self.stats.coalesce_lanes_max, lanes)
                epoch = self._publish(state)
                if self.wal is not None:
                    self.stats.wal_records = self.wal.stats.records
                    self.stats.wal_bytes = self.wal.stats.bytes
                    self.stats.wal_append_s = self.wal.stats.append_s
            if self._crash_fires("post-publish-pre-ack"):
                # epoch durable AND published, clients never acked: recovery
                # must reproduce it bit-identically (durable-but-unacked)
                raise SimulatedCrash("post-publish-pre-ack", epoch)
            off = 0
            with self._mutex:
                for t in live:
                    t.results = res[off: off + t.lanes].copy()
                    off += t.lanes
                    t.status = "applied"
                    t.wait_s = max(0.0, now - t.enqueue_t)
                    self.stats.wait_s += t.wait_s
                    self.stats.wait_max_s = max(self.stats.wait_max_s, t.wait_s)
                    self.stats.applied += 1
                    self._queue.remove(t)
                self.stats.queue_depth = len(self._queue)
            for t in live:
                t.epoch = epoch
            self._maybe_checkpoint(epoch, state)
            return len(live)

    def _crash_fires(self, stage: str) -> bool:
        """Durability crash stages are process-level, not per-client: the
        FaultInjector plan names them under the sentinel client ``"*"``."""
        return self.fault is not None and self.fault.should_die("*", stage)

    def _wal_commit(self, live: list[Ticket], res, lanes: int, pad: int
                    ) -> None:
        """Append-fsync the round's linearized record (DESIGN.md §16).

        This is the durability point the ``durable-ack`` lint rule keys
        on: every ``_publish`` / ticket-ack site in this file must be
        dominated by this call.  No-op without a WAL (the ordering
        obligation still structures the code); ``wal-append`` and
        ``wal-fsync`` crash stages land here.
        """
        epoch = self._slots[self._cur][0] + 1
        if self.wal is None:
            # still honor a planned crash so schedules can kill an
            # undurable pool and prove the acked prefix needs no WAL
            if (self._crash_fires("wal-append")
                    or self._crash_fires("wal-fsync")):
                raise SimulatedCrash("wal-append", epoch)
            return
        record = WalRecord(
            epoch=epoch,
            ops=[[int(x) for x in op] for t in live for op in t.ops],
            pad=int(pad),
            clients=[t.client_id for t in live],
            batch_ids=[t.batch_id for t in live],
            results=[int(x) for x in res[:lanes]],
            lanes=int(lanes),
        )
        if self._crash_fires("wal-append"):
            # kill mid-append: a torn, checksum-invalid frame hits disk;
            # reopen must truncate it (the round was never acked)
            self.wal.append_torn(record)
            raise SimulatedCrash("wal-append", epoch)
        before_s = self.wal.stats.append_s
        with _trace.span("wal.append", epoch=epoch, lanes=lanes):
            self.wal.append(record)
        if _trace.enabled():
            _obs_registry().observe("wal.append_s",
                                    self.wal.stats.append_s - before_s)
        if self._crash_fires("wal-fsync"):
            # record fully durable, epoch never published, nobody acked:
            # recovery replay must be idempotent about it
            raise SimulatedCrash("wal-fsync", epoch)

    def _maybe_checkpoint(self, epoch: int, state) -> None:
        """Cadence checkpoint + WAL truncation behind it (the checkpoint-
        truncation invariant: every epoch is covered by the checkpoint XOR
        the WAL tail)."""
        if self.ckpt is None or self.ckpt_every <= 0:
            return
        self._rounds_since_ckpt += 1
        if self._rounds_since_ckpt < self.ckpt_every:
            return
        self.checkpoint_now(epoch=epoch, state=state)

    def checkpoint_now(self, *, epoch: int | None = None, state=None) -> None:
        """Force one durable graph checkpoint of the published head (used
        by the cadence path, the serve loop on shutdown, and benchmarks)."""
        if self.ckpt is None:
            return
        if epoch is None or state is None:
            epoch, state = self.snapshot_epoch()
        kwargs = dict(epoch=epoch, state=state, ring=self.ring,
                      linearization=self.linearization,
                      epoch_log=self.epoch_log, next_batch_id=self._next_id,
                      index_stamp=self.index_stamp)
        if self._crash_fires("ckpt-mid-write"):
            # tmp dir fully written, rename never happens: recovery must
            # load the PREVIOUS published step
            self.ckpt.save_torn(**kwargs)
            raise SimulatedCrash("ckpt-mid-write", epoch)
        self.ckpt.save_graph(blocking=True, **kwargs)
        self._rounds_since_ckpt = 0
        with self._mutex:
            self.stats.ckpt_saves += 1
            if self.wal is not None:
                self.wal.truncate_through(epoch)
                self.stats.wal_truncations = self.wal.stats.truncations

    def flush(self) -> int:
        """Pump until the queue drains; returns total batches applied.

        Progress guarantee: the first queued ticket always admits (every
        entity lock is free at round start), so each round with a non-empty
        queue applies or aborts at least one batch.
        """
        total = 0
        while True:
            before = self.queue_depth()
            if before == 0:
                return total
            total += self.pump()
            # progress = the queue shrank (applied OR aborted batches both
            # leave it); a round that moves nothing would loop forever
            if self.queue_depth() >= before:  # pragma: no cover
                raise RuntimeError("ingest pool wedged: non-empty queue, "
                                   "zero admissions")
