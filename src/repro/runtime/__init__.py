from repro.runtime import fault, serve_loop, train_loop  # noqa: F401
