from repro.runtime import fault, ingest, serve_loop, train_loop  # noqa: F401
