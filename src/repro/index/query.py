"""Index-side query answering: one label-intersection contraction per batch
(DESIGN.md §9).

A batch of Q (src, dst) slot pairs is answered by gathering the sources'
OUT labels and the destinations' IN labels into two [Q, ceil(L/32)]
PACKED word slabs (DESIGN.md §10) and intersecting them along the landmark
axis — the packed ``kernels/label_join`` Pallas kernel
(``backend="pallas"``) or its packed jnp reference (``backend="jnp"``):
hits is a popcount of AND-ed words, the witness hub a count-trailing-zeros.
Cost: O(Q·L/32) words touched, no traversal, no adjacency stream — this is
the fast path the whole subsystem exists for.

Answer semantics mirror ``core.bfs.multi_bfs`` exactly: a query with an
absent (slot < 0) or dead endpoint is unreachable by definition (and
*decided* — the fused engine returns found=False for those too). A
nonempty intersection is a 2-hop witness src →* hub →* dst, so
``reach=True`` answers are exact unconditionally. Empty intersections are
exact only for a ``complete`` index (see labels.py); otherwise they come
back ``decided=False`` and the session layer (freshness.py) routes them to
the BFS fallback.

NOTE: these helpers answer *against the index epoch*. Callers must have
validated the epoch against the live state (``freshness.index_fresh``)
for the answers to be linearizable — the validation IS the double collect.
"""
from __future__ import annotations

import jax.numpy as jnp


def _join(out_words, in_words, backend: str):
    if backend == "jnp":
        from repro.kernels.label_join.ref import label_join_packed_ref

        return label_join_packed_ref(out_words, in_words)
    if backend == "pallas":
        from repro.kernels.label_join.ops import label_join_packed

        return label_join_packed(out_words, in_words)
    raise ValueError(f"unknown label_join backend {backend!r}")


def _endpoint_ok(index, slots):
    v = index.capacity
    return (slots >= 0) & index.alive[jnp.clip(slots, 0, v - 1)]


def query_reach(index, src_slots, dst_slots, *, backend: str = "jnp"):
    """Batched reachability probe.

    src_slots/dst_slots: int32[Q] (slot ids, -1 = absent). Returns
    (reach bool[Q], decided bool[Q], hub int32[Q]): ``reach[q]`` matches
    ``multi_bfs(...).found[q]`` wherever ``decided[q]``; ``hub[q]`` is the
    canonical 2-hop witness as an INDEX into ``index.landmarks`` (-1 if
    none) — slot ``index.landmarks[hub[q]]`` is the vertex a witness path
    can be stitched through when the caller materializes one.
    """
    src_slots = jnp.asarray(src_slots, jnp.int32)
    dst_slots = jnp.asarray(dst_slots, jnp.int32)
    v = index.capacity
    sok = _endpoint_ok(index, src_slots)
    dok = _endpoint_ok(index, dst_slots)
    a = jnp.where(sok[:, None],
                  index.out_label[jnp.clip(src_slots, 0, v - 1)],
                  jnp.uint32(0))
    b = jnp.where(dok[:, None],
                  index.in_label[jnp.clip(dst_slots, 0, v - 1)],
                  jnp.uint32(0))
    hits, hub = _join(a, b, backend)
    hit = hits > 0
    # hit => reachable, always. Empty intersection decides only when the
    # landmark set covers every alive vertex; absent/dead endpoints are
    # decided unreachable by the same rule the BFS engine applies.
    decided = hit | ~sok | ~dok | jnp.asarray(index.complete)
    return hit, decided, hub


def reach_sets(index, src_slots):
    """Full reachable sets: bool[Q, V] via one [Q, L] @ [L, V] product.

    Returns (sets bool[Q,V], decided bool[Q]) — rows are exact where
    decided (complete index, or absent/dead source whose set is empty).
    """
    src_slots = jnp.asarray(src_slots, jnp.int32)
    v = index.capacity
    sok = _endpoint_ok(index, src_slots)
    a = (index.out_label_bits[jnp.clip(src_slots, 0, v - 1)]
         & sok[:, None]).astype(jnp.float32)
    sets = (a @ index.in_label_bits.T.astype(jnp.float32)) > 0
    sets = sets & index.alive[None, :]
    decided = jnp.asarray(index.complete) | ~sok
    return sets, decided


def reach_counts(index, src_slots):
    """|reachable set| per source — the index-served form of
    ``core.bfs.reachable_count`` (int32[Q], decided bool[Q])."""
    sets, decided = reach_sets(index, src_slots)
    return jnp.sum(sets.astype(jnp.int32), axis=1), decided
