"""Epoch validation: serving from the index as a cached double collect
(DESIGN.md §9).

The index was built from a consistent snapshot and stamped with that
state's full ``(ecnt, vver)`` version vector. At serve time we read the
LIVE replicated version metadata and compare — exactly the check
``compare_collects`` performs between two collects, with the index stamp
playing the role of the first collect. Equality proves the graph is
byte-identical to the build state (counters are monotone, so equal
versions cannot hide an intervening mutate-and-undo), hence every
index answer is linearizable at the comparison point. The check is O(V)
replicated compute on dense AND mesh-sharded states (the metadata is
replicated by the DESIGN.md §8 placement) — no adjacency traffic at all.

On mismatch the session transparently falls back to the fused BFS double
collect (``get_paths_session``), which is always correct — the index is a
pure accelerator, never a semantic dependency. Undecided queries of a
partial (non-complete) index take the same fallback.

``refresh`` restores freshness incrementally: rows whose versions advanced
("dirty") implicate only the landmarks whose closures could have changed,
and the implication argument is direction-asymmetric because versions
stamp SOURCE rows:

  * forward closures: any new/removed edge on a path from landmark i has a
    dirty source that i already reached, so
    ``affected_fwd[i] = any(dirty & fwd[i])`` (plus i's own slot) suffices;
  * backward closures additionally need a new-edge term — in the reverse
    graph the dirty endpoint of an edge is its HEAD, so a freshly attached
    chain x → y →* i is invisible to the first test when x never reached i
    before. But every newly-reaching path runs through a dirty source, so
    the extra affected landmarks are exactly those inside the NEW-graph
    forward closure of the dirty rows: one fused BFS with Q = |dirty|.

Only the affected rows are re-traversed (two fused multi-BFS calls with
Q = |affected|); the landmark list and hence the canonical pruning order
stay fixed, so the refreshed index is bit-identical to a full rebuild
over the same landmarks (tests/test_index.py asserts this).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core.bfs import multi_bfs
from repro.obs import trace as _trace
from repro.obs.metrics import global_registry as _obs_registry
from repro.core.graph import find_slots, version_vector
from repro.core.snapshot import get_paths_session
from repro.index.labels import (
    ReachIndex,
    _as_dense,
    build_index,
    coverage_complete,
    pad8,
    rebuild_rows,
)
from repro.index.query import query_reach, reach_counts


def index_fresh(index: ReachIndex | None, state) -> bool:
    """True iff the live version metadata equals the index's build stamp —
    the second half of the double collect (DESIGN.md §9). Capacity change
    (grow) is a trivial mismatch."""
    if index is None:
        return False
    if state.capacity != index.capacity:
        return False
    return bool(jnp.all(version_vector(state) == index.versions))


def affected_landmarks(index: ReachIndex, state, *,
                       backend: str | None = None):
    """(aff_fwd bool[L], aff_bwd bool[L], dirty bool[V]) — the provably
    sufficient sets of landmark closures to re-traverse (module docstring
    has the soundness argument for each term)."""
    vv = np.asarray(version_vector(state))
    dirty = (vv != np.asarray(index.versions)).any(axis=1)
    lm = np.asarray(index.landmarks)
    fwd = np.asarray(index.fwd)
    bwd = np.asarray(index.bwd)
    aff_fwd = (fwd & dirty[None, :]).any(axis=1) | dirty[lm]
    aff_bwd = (bwd & dirty[None, :]).any(axis=1) | dirty[lm]
    if dirty.any() and lm.size:
        # new-edge term (reverse-graph asymmetry, see above): any NEWLY
        # reaching path u →* v_i runs through a dirty source, so the
        # affected backward closures are exactly the landmarks inside the
        # NEW-graph forward closure of the dirty rows — one fused BFS with
        # Q = |dirty| (tiny), instead of a conservative adjacency product
        # that would implicate every landmark near a dirty hub
        dslots = pad8(np.nonzero(dirty)[0].astype(np.int32))
        res = multi_bfs(_as_dense(state), jnp.asarray(dslots),
                        jnp.full((dslots.size,), -1, jnp.int32),
                        backend=backend, parents=False)
        reach_from_dirty = np.asarray((res.dist >= 0).any(axis=0))
        aff_bwd |= reach_from_dirty[lm]
    return aff_fwd, aff_bwd, dirty


def refresh(index: ReachIndex, state, *, backend: str | None = None,
            full_threshold: float = 0.5):
    """Bring a stale index up to the state's epoch. Returns
    (index, info) with info = {"mode": "noop"|"incremental"|"full",
    "rebuilt": #landmark closures re-traversed}.

    ``state`` is a functional snapshot, so build-time consistency is free;
    the caller swaps the returned index in atomically (a reference swap —
    queries racing the refresh keep validating against the OLD stamp and
    simply fall back, which is the non-blocking property at this layer).
    Rebuilds from scratch (fresh landmark pick) when capacity grew or more
    than ``full_threshold`` of the closures are affected anyway.
    """
    if state.capacity != index.capacity:
        return (build_index(state, index.requested, backend=backend),
                {"mode": "full", "rebuilt": index.num_landmarks})
    aff_fwd, aff_bwd, dirty = affected_landmarks(index, state,
                                                 backend=backend)
    if not dirty.any():
        return index, {"mode": "noop", "rebuilt": 0}
    if index.requested is None and not coverage_complete(
            np.asarray(index.landmarks), state.valive, index.capacity):
        # complete-coverage index: a new alive vertex outside the landmark
        # set would leave negatives undecided forever — re-pick landmarks
        # (a pinned or budgeted index keeps its landmark budget instead)
        return (build_index(state, None, backend=backend),
                {"mode": "full", "rebuilt": index.num_landmarks})
    n = int(aff_fwd.sum()) + int(aff_bwd.sum())
    if index.num_landmarks and n > full_threshold * 2 * index.num_landmarks:
        return (build_index(state, index.requested, backend=backend),
                {"mode": "full", "rebuilt": index.num_landmarks})
    return (rebuild_rows(index, state, aff_fwd, aff_bwd, backend=backend),
            {"mode": "incremental", "rebuilt": n})


@dataclass
class ReachSessionResult:
    """Batched reachability answers plus lazy path materialization.

    ``found[q]`` is linearizable: either at the freshness-check point
    (index-served) or inside its BFS double-collect session (fallback).
    ``paths()`` runs a fresh fused-BFS session over ALL pairs on demand —
    an index hit proves reachability without paying for a tree, so the
    witness path is materialized only when asked for (and linearizes at
    materialization time, like any later GetPath on a live graph).
    """

    found: list[bool]
    from_index: int   # queries answered on the index fast path
    fellback: int     # queries answered by the BFS double-collect session
    stale: bool       # an epoch mismatch forced the whole batch to BFS
    rounds: int       # collect rounds spent in the BFS session (0 if none)
    _materialize: Callable = field(repr=False, default=lambda: [])
    pinned_epoch: int | None = None  # retained epoch the answers linearize
    # at when the ring validated a stale-at-head index (DESIGN.md §13)
    starved: bool = False            # the BFS session exhausted its retry
    # budget (wait-free epoch resolution or capped-retry, per on_conflict)
    degraded: bool = False           # answered off the server's pinned
    # pre-failure epoch while it recovers (DESIGN.md §16)

    def paths(self):
        """[(found, keys)] per pair — lazy witness paths via fused BFS."""
        return self._materialize()


def index_fresh_at(index: ReachIndex | None, ring) -> int | None:
    """The newest RETAINED epoch whose version vector equals the index's
    build stamp, or None (DESIGN.md §13). A live-head mismatch no longer
    condemns the whole batch: if the ring still retains the epoch the index
    was built from, every decided index answer is exact *at that epoch* —
    the freshness comparison of DESIGN.md §9 relocated from the live head
    to the query's admitted epoch."""
    if index is None or ring is None:
        return None
    return ring.epoch_of_versions(np.asarray(index.versions), index.capacity)


def reach_session(fetch_state, index: ReachIndex | None, pairs, *,
                  engine: str = "fused", backend: str | None = None,
                  join_backend: str = "jnp", max_rounds: int = 64,
                  on_conflict: str = "retry", fetch_epoch=None, ring=None
                  ) -> ReachSessionResult:
    """Answer Q (k, l) key-pair reachability queries against a live state
    reference, preferring the index (DESIGN.md §9).

    Fresh index: slot lookup + one label_join contraction answers every
    decided query — no traversal; the freshness comparison doubles as the
    snapshot validation. Undecided queries (partial landmark sets) run the
    ``get_paths_session`` fallback.

    Stale-at-head index + ``ring`` + ``on_conflict="epoch"``: if the ring
    still retains the epoch the index was built from AND that epoch is at
    or after the query's ADMITTED epoch (the epoch published when the
    session started, read via ``fetch_epoch``), the batch is PINNED to it
    (DESIGN.md §13) — decided pairs come off the index, genuinely-
    undecided pairs take a single collect over the frozen reconstruction,
    and ``pinned_epoch`` reports where the answers linearize. The admitted-
    epoch guard is what keeps the pin linearizable: only mutations racing
    the session may be absorbed by it — an index made stale by a mutation
    that happened-before the query must not serve, since its epoch
    predates every point of the query's invocation window. Only when no
    retained epoch qualifies does the whole batch fall back to the BFS
    session, which itself follows ``on_conflict`` (wait-free epoch
    resolution or capped retry) at its budget.
    """
    pairs = list(pairs)
    q = len(pairs)

    def materialize():
        out, _ = get_paths_session(fetch_state, pairs, max_rounds=max_rounds,
                                   backend=backend, engine=engine,
                                   on_conflict=on_conflict,
                                   fetch_epoch=fetch_epoch)
        return out

    def _index_serve(idx_state, fallback_fetch, pinned_epoch):
        ks = jnp.asarray([p[0] for p in pairs], jnp.int32)
        ls = jnp.asarray([p[1] for p in pairs], jnp.int32)
        reach, decided, _ = query_reach(
            index, find_slots(idx_state, ks), find_slots(idx_state, ls),
            backend=join_backend)
        dec = np.asarray(decided)
        found = [bool(x) for x in np.asarray(reach)]
        und = np.nonzero(~dec)[0]
        rounds = 0
        starved = False
        if und.size:
            st: dict = {}
            out, rounds = get_paths_session(
                fallback_fetch, [pairs[i] for i in und],
                max_rounds=max_rounds, backend=backend, engine=engine,
                on_conflict=on_conflict, fetch_epoch=fetch_epoch, stats=st)
            starved = bool(st.get("starved", False))
            for i, (f, _keys) in zip(und, out):
                found[int(i)] = bool(f)
        return ReachSessionResult(found, q - int(und.size), int(und.size),
                                  False, rounds, materialize,
                                  pinned_epoch=pinned_epoch, starved=starved)

    if q == 0:
        return ReachSessionResult([], 0, 0, False, 0, materialize)

    def _session_body():
        # the admitted epoch is read BEFORE the state fetch: it bounds the
        # query's invocation from below, so any pin >= it is a moment inside
        # the invocation window (fetch_epoch returns the published
        # (epoch, state) slot)
        admitted = fetch_epoch()[0] if fetch_epoch is not None else None
        state = fetch_state()
        if index_fresh(index, state):
            return _index_serve(state, fetch_state, None)
        if on_conflict == "epoch" and admitted is not None:
            with _trace.span("index.ring_validate", admitted=admitted):
                t0 = time.perf_counter()
                pin = index_fresh_at(index, ring)
                ok = pin is not None and pin >= admitted
                pinned = ring.state_at(pin) if ok else None
                if _trace.enabled():
                    _obs_registry().observe("index.ring_validate_s",
                                            time.perf_counter() - t0)
            if ok:
                # only a RACING mutation separates the index from the head:
                # decided pairs are exact at the pinned epoch, and undecided
                # pairs collect over the frozen reconstruction (one
                # consistent state — two rounds, no race)
                return _index_serve(pinned, lambda: pinned, pin)
        st: dict = {}
        with _trace.span("index.fallback", pairs=q):
            t0 = time.perf_counter()
            out, rounds = get_paths_session(fetch_state, pairs,
                                            max_rounds=max_rounds,
                                            backend=backend, engine=engine,
                                            on_conflict=on_conflict,
                                            fetch_epoch=fetch_epoch, stats=st)
            if _trace.enabled():
                _obs_registry().observe("index.fallback_s",
                                        time.perf_counter() - t0)
        return ReachSessionResult([bool(f) for f, _ in out], 0, q,
                                  index is not None, rounds, materialize,
                                  pinned_epoch=st.get("epoch"),
                                  starved=bool(st.get("starved", False)))

    with _trace.span("index.query", pairs=q) as sp:
        t0 = time.perf_counter()
        res = _session_body()
        sp.set(from_index=res.from_index, fellback=res.fellback,
               stale=res.stale, pinned=res.pinned_epoch)
        if _trace.enabled():
            _obs_registry().observe("index.query_s",
                                    time.perf_counter() - t0)
        return res


def reach_counts_session(fetch_state, index: ReachIndex | None, keys, *,
                         backend: str | None = None):
    """Batched ``core.bfs.reachable_count``: (counts int64 np[Q],
    served_from_index bool). Index-served when fresh and every count is
    decided (complete cover); otherwise one fused multi-BFS in
    full-reachable-set mode over the fetched snapshot (a functional
    snapshot, so a single fetch is already consistent)."""
    from repro.core import partition
    from repro.core.partition import ShardedGraphState

    state = fetch_state()
    slots = find_slots(state, jnp.asarray(list(keys), jnp.int32))
    if index_fresh(index, state):
        counts, decided = reach_counts(index, slots)
        if bool(jnp.all(decided)):
            return np.asarray(counts), True
    if isinstance(state, ShardedGraphState):
        res = partition.multi_bfs(state, slots, jnp.full_like(slots, -1),
                                  backend=backend)
    else:  # closure-only: counts never need the BFS tree
        res = multi_bfs(state, slots, jnp.full_like(slots, -1),
                        backend=backend, parents=False)
    return np.asarray(jnp.sum((res.dist >= 0).astype(jnp.int32), axis=1)), False
