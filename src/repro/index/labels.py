"""Pruned 2-hop landmark labeling built over the fused BFS engine
(DESIGN.md §9).

A ``ReachIndex`` precomputes reachability through a set of L *landmark*
vertices (picked by degree — hubs first):

  fwd[i, v] = landmark i reaches v      (forward closure)
  bwd[i, v] = v reaches landmark i      (backward closure)

Both closures come from the EXISTING fused multi-source BFS: one
``core.bfs.multi_bfs`` with Q = L sources on the graph for ``fwd`` and one
on the MAINTAINED in-adjacency (an O(1) ``_reversed`` field swap, no
transpose — DESIGN.md §11) for ``bwd`` — the index build is just two
batched traversals, so every engine property (alive-masked edges, Pallas
superstep, mesh-sharded form) is inherited rather than re-implemented.

The 2-hop labels are the transposed closures with *canonical-hub pruning*
(the pruned-landmark-labeling rule applied post-hoc): label entry
(v, landmark k) is dropped when an earlier landmark j < k already covers
the (v, v_k) pair via v →* v_j →* v_k (OUT side) or v_k →* v_j →* v (IN
side). Pruning preserves exactly the *canonical hub* — the smallest-index
landmark on any s →* hub →* t path — of every covered pair:

  if the canonical hub c of (s, t) lost its OUT bit at s, some j < c had
  s →* v_j →* v_c, but then v_j →* v_c →* t makes j a smaller hub —
  contradiction (symmetrically for the IN bit).

So the pruned labels decide the same pairs as the unpruned closures with
far fewer bits, concentrated on the few high-degree hubs — which is what
makes the label_join kernel's @pl.when pruned-tile skip effective. The
surviving label bits are STORED word-packed over the landmark axis
(uint32[V, ceil(L/32)], DESIGN.md §10): one bit per (vertex, landmark)
pair, joined by popcount over AND-ed words.

Decidability: a nonempty label intersection proves reachability outright.
An EMPTY intersection proves unreachability only when the landmark set is
``complete`` (every alive vertex is a landmark — the default build): then
t itself is a landmark and s →* t →* t would be a hub. With a partial
landmark set, empty-intersection queries are *undecided* and the query
layer reports them for BFS fallback (index/freshness.py).

The index is stamped with the full ``(ecnt, vver)`` version vector of the
state it was built from: a transitive closure depends on every adjacency
row, so its "dependency set" is all V slots — the freshness check in
index/freshness.py compares the stamp against the live metadata exactly
like the second half of a double collect (DESIGN.md §9).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bfs import multi_bfs
from repro.core.graph import (
    GraphState,
    pack_bits,
    packed_width,
    unpack_bits,
    version_vector,
)


class ReachIndex(NamedTuple):
    """Versioned 2-hop reachability index (DESIGN.md §9).

    Array fields are device arrays; ``complete`` and ``requested`` are host
    metadata (the index is orchestrated host-side like the double-collect
    sessions, with jitted array helpers underneath). The pruned labels are
    stored WORD-PACKED over the landmark axis (uint32[V, ceil(L/32)],
    DESIGN.md §10): a label probe gathers 32x fewer bytes per query row and
    the label join is a popcount over AND-ed words.
    """

    landmarks: jax.Array   # int32[L]   — landmark slot ids, degree-ordered
    out_label: jax.Array   # uint32[V, ceil(L/32)] — packed: v reaches lm i
    in_label: jax.Array    # uint32[V, ceil(L/32)] — packed: lm i reaches v
    fwd: jax.Array         # bool[L, V] — unpruned forward closures (refresh)
    bwd: jax.Array         # bool[L, V] — unpruned backward closures (refresh)
    alive: jax.Array       # bool[V]    — liveness at build time
    versions: jax.Array    # int32[V,2] — (ecnt, vver) build epoch stamp
    complete: bool         # every alive vertex at build is a landmark
    requested: int | None  # landmark budget for full rebuilds: None means
    #                        complete coverage (refresh escalates to keep
    #                        it complete); an int — including the pinned
    #                        landmark_slots count — caps rebuild cost

    @property
    def capacity(self) -> int:
        return self.alive.shape[0]

    @property
    def num_landmarks(self) -> int:
        return self.landmarks.shape[0]

    @property
    def out_label_bits(self) -> jax.Array:
        """Unpacked bool[V, L] view of the packed OUT labels."""
        return unpack_bits(self.out_label, self.num_landmarks)

    @property
    def in_label_bits(self) -> jax.Array:
        """Unpacked bool[V, L] view of the packed IN labels."""
        return unpack_bits(self.in_label, self.num_landmarks)


def _as_dense(state) -> GraphState:
    """Dense view of a dense or mesh-sharded state (index build gathers:
    the backward traversal needs the transposed adjacency, and a transpose
    of a row-sharded matrix is a full exchange anyway — DESIGN.md §9)."""
    from repro.core.partition import ShardedGraphState, unshard

    if isinstance(state, ShardedGraphState):
        return unshard(state)
    return state


def _reversed(state: GraphState) -> GraphState:
    """The reverse graph: same slots/versions, out- and in-adjacency
    SWAPPED. BFS on it from landmark i yields {v : v reaches i} = bwd[i].

    A pure O(1) field swap (DESIGN.md §11): the maintained in-adjacency IS
    the transposed adjacency (the transpose invariant core/ops.py upholds),
    so backward closures drive ``multi_bfs`` directly on the stored words —
    build and ``refresh()`` perform NO unpack -> T -> repack anywhere.
    tests/test_hybrid.py pins both the aliasing (the reverse graph's rows
    ARE ``adj_in_packed``) and bit-identity of the rebuilt index against
    the old explicit-transpose oracle path."""
    return state._replace(adj_packed=state.adj_in_packed,
                          adj_in_packed=state.adj_packed)


def pad8(idx: np.ndarray) -> np.ndarray:
    """Pad an index list up to a multiple of 8 by repeating its first entry
    (a duplicated BFS source recomputes an identical row — harmless), so
    varying affected/dirty counts reuse a handful of multi_bfs jit shapes
    across refreshes instead of recompiling per count."""
    pad = (-len(idx)) % 8
    if pad:
        idx = np.concatenate([idx, np.full((pad,), idx[0], idx.dtype)])
    return idx


def coverage_complete(landmarks: np.ndarray, alive, capacity: int) -> bool:
    """Every alive vertex is a landmark — the condition under which an
    empty label intersection is an exact negative (module docstring)."""
    is_lm = np.zeros((capacity,), bool)
    is_lm[landmarks] = True
    return bool(np.all(~np.asarray(alive) | is_lm))


def pick_landmarks(state, num_landmarks: int | None = None) -> np.ndarray:
    """Degree-ordered landmark selection (hubs first, ties by slot).

    ``None`` selects EVERY alive vertex — the complete (exact) index. The
    degree order then still matters: canonical-hub pruning keeps the
    smallest-index cover, so hub-heavy orderings concentrate the surviving
    label bits on the first few landmark columns.
    """
    dense = _as_dense(state)
    alive = np.asarray(dense.valive)
    m = alive[:, None] & alive[None, :]
    adj = np.asarray(dense.adj) * m
    deg = adj.sum(axis=1) + adj.sum(axis=0)
    slots = np.arange(alive.shape[0])
    order = np.lexsort((slots, -deg))          # degree desc, slot asc
    order = order[alive[order]]                # alive only
    if num_landmarks is not None:
        order = order[: max(0, int(num_landmarks))]
    return order.astype(np.int32)


@jax.jit
def _prune(fwd, bwd, landmarks):
    """Canonical-hub pruning: one [L,L] landmark-closure matrix and two
    [L,L] @ [L,V] cover products (see module docstring for the exactness
    argument). Returns (out_label bool[V,L], in_label bool[V,L])."""
    lgl = fwd[:, landmarks]                    # lgl[k, j] = v_k reaches v_j
    lt = jnp.tril(jnp.ones_like(lgl), k=-1)    # j < k mask
    f32 = jnp.float32
    # IN bit (k, u) = fwd[k, u] redundant iff exists j < k: v_k →* v_j →* u
    cover_in = ((lgl.astype(f32) * lt.astype(f32)) @ fwd.astype(f32)) > 0
    # OUT bit (k, u) = bwd[k, u] redundant iff exists j < k: u →* v_j →* v_k
    cover_out = ((lgl.T.astype(f32) * lt.astype(f32)) @ bwd.astype(f32)) > 0
    return (bwd & ~cover_out).T, (fwd & ~cover_in).T


def _closures(dense: GraphState, lm: jax.Array, backend: str | None):
    """Forward and backward closures of the landmark set: two fused
    multi-BFS calls (Q = L, full-reachable-set mode dst = -1); the backward
    one runs on the maintained in-adjacency via the ``_reversed`` field
    swap — transpose-free (DESIGN.md §11)."""
    dsts = jnp.full((lm.shape[0],), -1, jnp.int32)
    f = multi_bfs(dense, lm, dsts, backend=backend, parents=False)
    b = multi_bfs(_reversed(dense), lm, dsts, backend=backend,
                  parents=False)
    return f.dist >= 0, b.dist >= 0


def build_index(state, num_landmarks: int | None = None, *,
                landmark_slots=None,
                backend: str | None = None) -> ReachIndex:
    """Construct a ``ReachIndex`` from a state snapshot (DESIGN.md §9).

    ``state`` is a functional snapshot (dense ``GraphState`` or sharded
    ``core.partition.ShardedGraphState``), so a single fetch is already a
    consistent Collect — the concurrent-validation burden moves entirely to
    serve time, where index/freshness.py compares the stamp taken here
    against the live metadata like the second collect of a double collect.

    ``num_landmarks=None`` (default) indexes every alive vertex: the index
    is then *complete* — label intersection decides every pair exactly.
    A smaller budget trades coverage for build cost; undecided pairs fall
    back to the fused BFS. ``landmark_slots`` pins an explicit slot list
    (refresh and tests use it to rebuild with a fixed landmark set).
    """
    dense = _as_dense(state)
    v = dense.capacity
    if landmark_slots is not None:
        lm = np.asarray(landmark_slots, np.int32)
    else:
        lm = pick_landmarks(dense, num_landmarks)
    n = lm.shape[0]
    lm_j = jnp.asarray(lm, jnp.int32)
    if n == 0:
        fwd = jnp.zeros((0, v), jnp.bool_)
        bwd = jnp.zeros((0, v), jnp.bool_)
    else:
        fwd, bwd = _closures(dense, lm_j, backend)
    out_bits, in_bits = _prune(fwd, bwd, lm_j) if n else (
        jnp.zeros((v, 0), jnp.bool_), jnp.zeros((v, 0), jnp.bool_))
    alive = dense.valive
    complete = coverage_complete(lm, alive, v)
    return ReachIndex(
        landmarks=lm_j,
        out_label=pack_bits(out_bits),
        in_label=pack_bits(in_bits),
        fwd=fwd,
        bwd=bwd,
        alive=alive,
        versions=version_vector(dense),
        complete=complete,
        requested=num_landmarks if landmark_slots is None else int(n),
    )


@functools.partial(jax.jit, static_argnames=())
def _scatter_rows(mat, rows_idx, rows):
    return mat.at[rows_idx].set(rows)


def rebuild_rows(index: ReachIndex, state, aff_fwd: np.ndarray,
                 aff_bwd: np.ndarray,
                 backend: str | None = None) -> ReachIndex:
    """Recompute only the given landmark rows against ``state`` and
    re-prune — the array half of ``freshness.refresh`` (which supplies the
    provably-sufficient affected sets). Landmark list, and therefore the
    canonical-hub pruning order, stays fixed, so the result is bit-identical
    to a full ``build_index(state, landmark_slots=index.landmarks)``."""
    dense = _as_dense(state)
    lm = np.asarray(index.landmarks)

    def recompute(mask, mat, g):
        idx = np.nonzero(mask)[0]
        if idx.size == 0:
            return mat
        idx = pad8(idx)
        srcs = jnp.asarray(lm[idx], jnp.int32)
        res = multi_bfs(g, srcs, jnp.full((idx.size,), -1, jnp.int32),
                        backend=backend, parents=False)
        return _scatter_rows(mat, jnp.asarray(idx), res.dist >= 0)

    fwd = recompute(aff_fwd, index.fwd, dense)
    bwd = recompute(aff_bwd, index.bwd, _reversed(dense))
    out_bits, in_bits = _prune(fwd, bwd, index.landmarks)
    alive = dense.valive
    complete = coverage_complete(lm, alive, index.capacity)
    return index._replace(
        out_label=pack_bits(out_bits), in_label=pack_bits(in_bits),
        fwd=fwd, bwd=bwd,
        alive=alive, versions=version_vector(dense), complete=complete)
