"""Versioned reachability-index subsystem (DESIGN.md §9).

Public surface:
  ReachIndex, build_index, pick_landmarks, rebuild_rows      (labels.py)
  query_reach, reach_sets, reach_counts                      (query.py)
  index_fresh, refresh, affected_landmarks,
  reach_session, reach_counts_session, ReachSessionResult    (freshness.py)
"""
from repro.index.labels import (  # noqa: F401
    ReachIndex,
    build_index,
    pick_landmarks,
    rebuild_rows,
)
from repro.index.query import (  # noqa: F401
    query_reach,
    reach_counts,
    reach_sets,
)
from repro.index.freshness import (  # noqa: F401
    ReachSessionResult,
    affected_landmarks,
    index_fresh,
    reach_counts_session,
    reach_session,
    refresh,
)
