"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: x -> [branch a: linear -> conv1d(4) -> RG-LRU] ⊙ gelu(branch b) -> out.
RG-LRU per channel:  r_t = σ(W_a x_t + b_a);  i_t = σ(W_x x_t + b_x)
                     a_t = exp(c·softplus(Λ)·(-r_t))        (c = 8)
                     h_t = a_t h_{t-1} + sqrt(1 - a_t²)·(i_t ⊙ x_t)

Train/prefill uses an associative scan over the diagonal linear recurrence
(log-depth on TPU); decode is one elementwise update — constant state, so
the hybrid runs ``long_500k``. Hybrid stacking (2 recurrent : 1 local-attn)
lives in transformer.py via cfg.block_pattern.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, dtype_of

_C = 8.0


def init_rglru(key, cfg):
    dt = dtype_of(cfg)
    d, dl = cfg.d_model, cfg.d_lru
    ks = jax.random.split(key, 6)
    return {
        "in_x": dense_init(ks[0], (d, dl), dt),     # recurrent branch input
        "in_g": dense_init(ks[1], (d, dl), dt),     # multiplicative gate branch
        "conv_w": dense_init(ks[2], (cfg.ssm_conv, dl), dt, scale=0.5),
        "conv_b": jnp.zeros((dl,), jnp.float32),
        "w_a": dense_init(ks[3], (dl, dl), dt),
        "b_a": jnp.zeros((dl,), jnp.float32),
        "w_i": dense_init(ks[4], (dl, dl), dt),
        "b_i": jnp.zeros((dl,), jnp.float32),
        "lam": jnp.full((dl,), 0.7, jnp.float32),
        "out": dense_init(ks[5], (dl, d), dt),
    }


def _gates(p, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(uf @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i * uf)
    return a, gated


def _conv(p, u, tail=None):
    """Causal depthwise conv, optionally warm-started with cached tail."""
    w = p["conv_w"].astype(jnp.float32)
    k = w.shape[0]
    uf = u.astype(jnp.float32)
    if tail is None:
        pad = jnp.zeros((u.shape[0], k - 1, u.shape[2]), jnp.float32)
    else:
        pad = tail.astype(jnp.float32)
    seq = jnp.concatenate([pad, uf], axis=1)
    out = sum(seq[:, i : i + u.shape[1], :] * w[i] for i in range(k))
    return (out + p["conv_b"]).astype(u.dtype), seq[:, -(k - 1):, :].astype(u.dtype)


def apply_rglru(cfg, p, x, h0=None):
    """x: [B,S,d] -> (y [B,S,d], h_last [B,d_lru], conv_tail [B,K-1,d_lru])."""
    b, s, _ = x.shape
    u = x @ p["in_x"]
    g = jax.nn.gelu((x @ p["in_g"]).astype(jnp.float32))
    u, conv_tail = _conv(p, u)
    a, gated = _gates(p, u)                      # [B,S,dl] each (f32)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    aT = jnp.moveaxis(a, 1, 0)
    gT = jnp.moveaxis(gated, 1, 0)
    if h0 is not None:
        gT = gT.at[0].add(aT[0] * h0.astype(jnp.float32))
    _, hs = jax.lax.associative_scan(combine, (aT, gT), axis=0)
    h = jnp.moveaxis(hs, 0, 1)                   # [B,S,dl]
    y = (h * g).astype(x.dtype) @ p["out"]
    return y, h[:, -1, :], conv_tail


def apply_rglru_decode(cfg, p, x, h, conv_cache):
    """One-token update. x: [B,1,d]; h: [B,d_lru]; conv_cache: [B,K-1,d_lru]."""
    u = x @ p["in_x"]
    g = jax.nn.gelu((x @ p["in_g"]).astype(jnp.float32))
    u, conv_cache = _conv(p, u, tail=conv_cache)
    a, gated = _gates(p, u)                      # [B,1,dl]
    h = a[:, 0] * h.astype(jnp.float32) + gated[:, 0]
    y = (h[:, None, :] * g).astype(x.dtype) @ p["out"]
    return y, h, conv_cache
