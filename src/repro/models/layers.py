"""Shared building blocks: norms, MLPs, embeddings, rotary, init helpers.

Pure-functional: ``init_*`` returns a param dict, ``apply`` functions take
(params, x). Layer-stacked params (leading ``L`` axis) are consumed via
``lax.scan`` in transformer.py to keep HLO size and compile time flat in
depth — essential for 46-80 layer archs on the 512-device dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------
def init_norm(cfg, dim: int):
    if not cfg.parametric_norm:
        return {}
    return {"scale": jnp.ones((dim,), jnp.float32)}


def apply_norm(cfg, params, x):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        xf = xf - jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + cfg.norm_eps)
    if cfg.parametric_norm and params:
        xf = xf * params["scale"]
    return xf.astype(x.dtype)


# ----------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ----------------------------------------------------------------------------
def init_mlp(key, cfg, d_in: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    dt = dtype_of(cfg)
    return {
        "wi": dense_init(k1, (d_in, d_ff), dt),
        "wg": dense_init(k2, (d_in, d_ff), dt),
        "wo": dense_init(k3, (d_ff, d_in), dt),
    }


def apply_mlp(cfg, params, x):
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    h = act(x @ params["wg"]) * (x @ params["wi"])
    return h @ params["wo"]


# ----------------------------------------------------------------------------
# rotary position embeddings
# ----------------------------------------------------------------------------
def rope_freqs(cfg, hd: int):
    half = hd // 2
    return cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(cfg, x, positions):
    """x: [..., S, H, hd]; positions: int32 broadcastable to [..., S]."""
    hd = x.shape[-1]
    inv = rope_freqs(cfg, hd)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, hd/2]
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap else x


def sinusoidal_positions(length: int, dim: int):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    inv = 10000.0 ** (-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------------
# embeddings / unembedding
# ----------------------------------------------------------------------------
def init_embed(key, cfg):
    dt = dtype_of(cfg)
    p = {"tok": embed_init(key, (cfg.vocab, cfg.d_model), dt)}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["unembed"] = dense_init(k2, (cfg.d_model, cfg.vocab), dt)
    return p


def embed_tokens(cfg, params, tokens):
    return jnp.take(params["tok"], tokens, axis=0)


def unembed(cfg, params, x):
    if cfg.tie_embeddings:
        logits = x @ params["tok"].T
    else:
        logits = x @ params["unembed"]
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)
