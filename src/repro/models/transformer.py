"""Decoder-only LM trunk: pattern-grouped layer stacking under lax.scan.

Layer heterogeneity (gemma2 local/global alternation, Griffin rec/rec/attn
triples) is expressed as a *group pattern*: params are stacked over groups
and scanned, so HLO size and compile time are O(group) not O(depth) — the
46-80 layer archs compile in the same ballpark as the 16-layer ones on the
512-device dry-run.

Layer kinds (cfg.family -> pattern, see _pattern()):
  "global"     pre-norm GQA attention (full causal) + MLP
  "local"      same with sliding-window mask
  "moe"        attention + MoE FFN
  "ssm"        mamba2 SSD mixer only (no MLP)
  "rec"        RG-LRU temporal block + MLP
Caches per kind: attention -> (k, v); ssm -> (state, conv); rec -> (h, conv).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru
from repro.models import ssm as ssm_mod


# ----------------------------------------------------------------------------
# patterns
# ----------------------------------------------------------------------------
_KIND_ALIASES = {"attn_local": "local", "attn": "global"}


def _norm_kind(kind: str) -> str:
    return _KIND_ALIASES.get(kind, kind)


def _pattern(cfg) -> list[tuple[tuple[str, ...], int]]:
    """[(group_pattern, n_groups), ...] covering cfg.n_layers layers."""
    if cfg.family == "ssm":
        return [(("ssm",), cfg.n_layers)]
    if cfg.family == "hybrid":
        pat = tuple(_norm_kind(k) for k in cfg.block_pattern) or ("rec",)
        n_groups, rem = divmod(cfg.n_layers, len(pat))
        out = [(pat, n_groups)] if n_groups else []
        if rem:
            out.append((pat[:rem], 1))
        return out
    if cfg.local_global_period == 2 and cfg.sliding_window:
        assert cfg.n_layers % 2 == 0
        return [(("local", "global"), cfg.n_layers // 2)]
    kind = "moe" if cfg.n_experts else "global"
    return [((kind,), cfg.n_layers)]


def _layer_kind_window(cfg, kind: str) -> int:
    if kind == "local":
        return cfg.sliding_window
    if kind == "attn_local":
        return cfg.sliding_window
    return 0


# ----------------------------------------------------------------------------
# per-layer init / forward / decode
# ----------------------------------------------------------------------------
def _init_layer(key, cfg, kind: str):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": L.init_norm(cfg, cfg.d_model)}
    if kind in ("global", "local", "moe"):
        p["attn"] = attn.init_attn(ks[0], cfg)
        p["norm2"] = L.init_norm(cfg, cfg.d_model)
        if kind == "moe":
            p["moe"] = moe_mod.init_moe(ks[1], cfg)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg, cfg.d_model, cfg.d_ff)
        if cfg.sandwich_norm:
            p["post1"] = L.init_norm(cfg, cfg.d_model)
            p["post2"] = L.init_norm(cfg, cfg.d_model)
    elif kind == "ssm":
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg)
    elif kind == "rec":
        p["rec"] = rglru.init_rglru(ks[0], cfg)
        p["norm2"] = L.init_norm(cfg, cfg.d_model)
        p["mlp"] = L.init_mlp(ks[1], cfg, cfg.d_model, cfg.d_ff)
    else:
        raise ValueError(kind)
    return p


def _layer_fwd(cfg, kind, p, x, positions, *, want_cache: bool):
    """Full-sequence layer. Returns (x', cache_entry, aux_loss)."""
    aux = jnp.float32(0.0)
    h = L.apply_norm(cfg, p["norm1"], x)
    if kind in ("global", "local", "moe"):
        window = cfg.sliding_window if kind == "local" else 0
        a, kvc = attn.attn_forward(cfg, p["attn"], h, positions, causal=True, window=window)
        if cfg.sandwich_norm:
            a = L.apply_norm(cfg, p["post1"], a)
        x = x + a
        h2 = L.apply_norm(cfg, p["norm2"], x)
        if kind == "moe":
            f, aux = moe_mod.apply_moe(cfg, p["moe"], h2)
        else:
            f = L.apply_mlp(cfg, p["mlp"], h2)
        if cfg.sandwich_norm:
            f = L.apply_norm(cfg, p["post2"], f)
        x = x + f
        cache = kvc if want_cache else None
    elif kind == "ssm":
        y, state, conv = ssm_mod.apply_ssm(cfg, p["ssm"], h)
        x = x + y
        cache = (state, conv) if want_cache else None
    elif kind == "rec":
        y, hlast, conv = rglru.apply_rglru(cfg, p["rec"], h)
        x = x + y
        h2 = L.apply_norm(cfg, p["norm2"], x)
        x = x + L.apply_mlp(cfg, p["mlp"], h2)
        cache = (hlast, conv) if want_cache else None
    else:
        raise ValueError(kind)
    return x, cache, aux


def _layer_decode(cfg, kind, p, x, cache, pos):
    """One-token layer step. Returns (x', cache')."""
    h = L.apply_norm(cfg, p["norm1"], x)
    if kind in ("global", "local", "moe"):
        window = cfg.sliding_window if kind == "local" else 0
        ck, cv = cache
        a, ck, cv = attn.attn_decode(cfg, p["attn"], h, ck, cv, pos, window=window)
        if cfg.sandwich_norm:
            a = L.apply_norm(cfg, p["post1"], a)
        x = x + a
        h2 = L.apply_norm(cfg, p["norm2"], x)
        if kind == "moe":
            f, _ = moe_mod.apply_moe(cfg, p["moe"], h2)
        else:
            f = L.apply_mlp(cfg, p["mlp"], h2)
        if cfg.sandwich_norm:
            f = L.apply_norm(cfg, p["post2"], f)
        x = x + f
        return x, (ck, cv)
    if kind == "ssm":
        state, conv = cache
        y, state, conv = ssm_mod.apply_ssm_decode(cfg, p["ssm"], h, state, conv)
        return x + y, (state, conv)
    if kind == "rec":
        hr, conv = cache
        y, hr, conv = rglru.apply_rglru_decode(cfg, p["rec"], h, hr, conv)
        x = x + y
        h2 = L.apply_norm(cfg, p["norm2"], x)
        x = x + L.apply_mlp(cfg, p["mlp"], h2)
        return x, (hr, conv)
    raise ValueError(kind)


# ----------------------------------------------------------------------------
# trunk init
# ----------------------------------------------------------------------------
def init_trunk(key, cfg):
    """Params: {"stacks": [ {str(i): stacked-layer-params} per stack ],
    "final_norm": ...}. Each stack's leaves carry a leading group axis."""
    stacks = []
    for si, (pat, n_groups) in enumerate(_pattern(cfg)):
        group = {}
        for li, kind in enumerate(pat):
            def one(g, _li=li, _kind=kind, _si=si):
                k = jax.random.fold_in(key, _si * 1000 + g * 10 + _li)
                return _init_layer(k, cfg, _kind)

            leaves = [one(g) for g in range(n_groups)]
            group[str(li)] = jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)
        stacks.append(group)
    return {"stacks": stacks, "final_norm": L.init_norm(cfg, cfg.d_model)}


# ----------------------------------------------------------------------------
# trunk forward (train / prefill)
# ----------------------------------------------------------------------------
def trunk_fwd(cfg, params, x, positions, *, want_cache: bool, remat: bool = False):
    """x: [B,S,d] -> (x', caches per stack (stacked over groups) | None, aux)."""
    aux_total = jnp.float32(0.0)
    all_caches = []
    for (pat, n_groups), gp in zip(_pattern(cfg), params["stacks"]):

        def group_fwd(carry, gparams, _pat=pat):
            xg, aux = carry
            from repro.parallel import sharding as _sh

            xg = _sh.shard_activation(xg, "hidden")
            caches = {}
            for li, kind in enumerate(_pat):
                xg, cache, a = _layer_fwd(cfg, kind, gparams[str(li)], xg, positions,
                                          want_cache=want_cache)
                caches[str(li)] = cache
                aux = aux + a
            return (xg, aux), (caches if want_cache else None)

        f = group_fwd
        if remat:
            f = jax.checkpoint(group_fwd, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux_total), caches = jax.lax.scan(f, (x, aux_total), gp)
        all_caches.append(caches)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, (all_caches if want_cache else None), aux_total


# ----------------------------------------------------------------------------
# trunk decode (one token)
# ----------------------------------------------------------------------------
def trunk_decode(cfg, params, x, caches, pos, *, unroll: bool = False):
    """x: [B,1,d]; caches as returned by init_cache/prefill. -> (x', caches').

    ``unroll=True`` (§Perf iteration A1) replaces the group scan with a
    Python loop over groups: a lax.scan must re-materialize every group's
    cache through its stacked ys (a full KV-cache copy per decode step —
    observed 5-10x the irreducible decode HBM traffic on the dry-run);
    unrolled layers let XLA donate and update the caches in place. HLO size
    grows O(depth) — acceptable for the serve step, which is small per layer.
    """
    new_caches = []
    for (pat, n_groups), gp, gc in zip(_pattern(cfg), params["stacks"], caches):
        if unroll:
            # read one group's slice, compute, write the slice back in place
            # (donated stacked buffers + disjoint indices -> no cache copy)
            for gi in range(n_groups):
                gparams = jax.tree.map(lambda a: a[gi], gp)
                gcache = jax.tree.map(lambda a: a[gi], gc)
                upd = {}
                for li, kind in enumerate(pat):
                    x, c = _layer_decode(cfg, kind, gparams[str(li)], x,
                                         gcache[str(li)], pos)
                    upd[str(li)] = c
                gc = jax.tree.map(lambda full, u: full.at[gi].set(u), gc, upd)
            nc = gc
        else:
            def group_step(carry, xs, _pat=pat):
                xg = carry
                gparams, gcache = xs
                out_caches = {}
                for li, kind in enumerate(_pat):
                    xg, c = _layer_decode(cfg, kind, gparams[str(li)], xg,
                                          gcache[str(li)], pos)
                    out_caches[str(li)] = c
                return xg, out_caches

            x, nc = jax.lax.scan(group_step, x, (gp, gc))
        new_caches.append(nc)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, new_caches


# ----------------------------------------------------------------------------
# cache construction
# ----------------------------------------------------------------------------
def init_cache(cfg, batch: int, cache_len: int, dtype):
    """Zeroed decode caches matching trunk_decode's expectations."""
    caches = []
    for (pat, n_groups) in _pattern(cfg):
        group = {}
        for li, kind in enumerate(pat):
            if kind in ("global", "local", "moe"):
                ln = cache_len
                if kind == "local" and cfg.sliding_window:
                    ln = min(cache_len, _window_cache_len(cfg, cache_len))
                shape = (n_groups, batch, ln, cfg.n_kv, cfg.hd)
                group[str(li)] = (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
            elif kind == "ssm":
                din = cfg.ssm_expand * cfg.d_model
                nh = din // cfg.ssm_headdim
                group[str(li)] = (
                    jnp.zeros((n_groups, batch, nh, cfg.ssm_state, cfg.ssm_headdim), jnp.float32),
                    jnp.zeros((n_groups, batch, cfg.ssm_conv - 1, din), dtype),
                )
            elif kind == "rec":
                group[str(li)] = (
                    jnp.zeros((n_groups, batch, cfg.d_lru), jnp.float32),
                    jnp.zeros((n_groups, batch, cfg.ssm_conv - 1, cfg.d_lru), dtype),
                )
        caches.append(group)
    return caches


def _window_cache_len(cfg, cache_len: int) -> int:
    # local-attention layers never need more than the window (+1 slot)
    return min(cache_len, cfg.sliding_window)
