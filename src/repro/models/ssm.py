"""Mamba-2 SSD (state-space duality) block — chunked train/prefill + O(1) decode.

Follows the SSD dual form (arXiv:2405.21060): within a chunk of length Q the
output is a masked quadratic form (MXU-friendly), across chunks a linear
state recurrence carries [H, hd, N] states. Decode is a single recurrent
update — constant memory in context length, which is why mamba2 runs the
``long_500k`` cell the full-attention archs skip.

Layout: d_inner = expand * d_model; H = d_inner / headdim heads; state N.
Params per layer: in_proj d->(2*d_inner + 2*N + H), depthwise conv (causal,
width 4) on x-branch, per-head A (scalar decay), D skip, gated RMSNorm-free
output via silu(z), out_proj d_inner->d.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, dtype_of


def _dims(cfg):
    din = cfg.ssm_expand * cfg.d_model
    nh = din // cfg.ssm_headdim
    return din, nh, cfg.ssm_headdim, cfg.ssm_state


def init_ssm(key, cfg):
    dt = dtype_of(cfg)
    d = cfg.d_model
    din, nh, hd, n = _dims(cfg)
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * din + 2 * n + nh), dt),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, din), dt, scale=0.5),
        "conv_b": jnp.zeros((din,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "dskip": jnp.ones((nh,), jnp.float32),
        "out_proj": dense_init(ks[2], (din, d), dt),
    }


def _split_proj(cfg, zxbcdt):
    din, nh, hd, n = _dims(cfg)
    z = zxbcdt[..., :din]
    x = zxbcdt[..., din : 2 * din]
    bmat = zxbcdt[..., 2 * din : 2 * din + n]
    cmat = zxbcdt[..., 2 * din + n : 2 * din + 2 * n]
    dt = zxbcdt[..., 2 * din + 2 * n :]
    return z, x, bmat, cmat, dt


def _causal_conv(cfg, p, x):
    """Depthwise causal conv along time. x: [B, S, din]."""
    w = p["conv_w"].astype(jnp.float32)  # [K, din]
    k = w.shape[0]
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + p["conv_b"]).astype(x.dtype)


def apply_ssm(cfg, p, x):
    """Chunked SSD forward. x: [B, S, d] -> (y [B, S, d], final_state, conv_tail).

    final_state: [B, H, hd, N]; conv_tail: [B, K-1, din] (decode warm-start).
    """
    b, s, d = x.shape
    din, nh, hd, n = _dims(cfg)
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    zxbcdt = x @ p["in_proj"]
    z, xb_raw, bmat, cmat, dtr = _split_proj(cfg, zxbcdt)
    # decode warm-start caches the PRE-conv tail (the conv consumes raw inputs)
    conv_tail = xb_raw[:, s - (cfg.ssm_conv - 1):, :]
    xb = _causal_conv(cfg, p, xb_raw)

    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])        # [B,S,H]
    a = -jnp.exp(p["a_log"])                                            # [H]
    da = dt * a                                                         # [B,S,H] (log decay)
    xh = xb.astype(jnp.float32).reshape(b, s, nh, hd)
    bmat = bmat.astype(jnp.float32)
    cmat = cmat.astype(jnp.float32)

    # chunk views
    xc = xh.reshape(b, nc, q, nh, hd)
    bc = bmat.reshape(b, nc, q, n)
    cc = cmat.reshape(b, nc, q, n)
    dac = da.reshape(b, nc, q, nh)
    dtc = dt.reshape(b, nc, q, nh)

    seg = jnp.cumsum(dac, axis=2)                                        # [B,nc,Q,H]
    # intra-chunk: L[i,j] = exp(seg_i - seg_j) for i >= j.
    # Mask BEFORE exp: for j > i the difference is positive and exp overflows;
    # an overflow inside the unselected where-branch poisons the gradient
    # (inf * 0 = NaN in the VJP), which NaN'd mamba2's first train step.
    li = seg[:, :, :, None, :] - seg[:, :, None, :, :]                   # [B,nc,Q,Q,H]
    tri = jnp.tril(jnp.ones((q, q), jnp.bool_))
    li = jnp.where(tri[None, None, :, :, None], li, -jnp.inf)
    lmask = jnp.exp(li)
    # pin the dominant intra-chunk tensor's layout: batch over data, heads
    # over model (GSPMD loses the head sharding through the cumsum/tril path
    # and replicates ~GBs per layer otherwise; §Perf cell B3)
    from repro.parallel import sharding as _sh
    lmask = _sh.shard_activation(lmask, "ssm_intra")
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)                           # [B,nc,Q,Q]
    att = cb[..., None] * lmask                                          # [B,nc,Q,Q,H]
    att = _sh.shard_activation(att, "ssm_intra")
    y_intra = jnp.einsum("bcijh,bcjhd,bcjh->bcihd", att, xc, dtc)

    # chunk-final states: S_c = sum_j exp(seg_Q - seg_j) * dt_j * B_j x_j^T
    decay_to_end = jnp.exp(seg[:, :, -1:, :] - seg)                      # [B,nc,Q,H]
    sstates = jnp.einsum("bcjh,bcjn,bcjhd->bchnd", decay_to_end * dtc, bc, xc)
    chunk_decay = jnp.exp(seg[:, :, -1, :])                              # [B,nc,H]

    def scan_fn(h0, xs):
        s_c, g_c = xs  # [B,H,N,hd], [B,H]
        h1 = h0 * g_c[..., None, None] + s_c
        return h1, h0  # emit state BEFORE the chunk

    h_init = jnp.zeros((b, nh, n, hd), jnp.float32)
    h_last, h_before = jax.lax.scan(
        scan_fn,
        h_init,
        (jnp.moveaxis(sstates, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_before = jnp.moveaxis(h_before, 0, 1)                              # [B,nc,H,N,hd]

    # inter-chunk contribution: y_j += C_j exp(seg_j) h_before
    y_inter = jnp.einsum("bcin,bcih,bchnd->bcihd", cc, jnp.exp(seg), h_before)

    y = (y_intra + y_inter).reshape(b, s, nh, hd)
    y = y + xh * p["dskip"][None, None, :, None]
    y = (y.reshape(b, s, din) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p["out_proj"], h_last, conv_tail


def apply_ssm_decode(cfg, p, x, state, conv_cache):
    """One-token recurrent update.

    x: [B,1,d]; state: [B,H,N,hd]; conv_cache: [B,K-1,din]
    -> (y [B,1,d], state', conv_cache')
    """
    b = x.shape[0]
    din, nh, hd, n = _dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z, xb, bmat, cmat, dtr = _split_proj(cfg, zxbcdt)

    # conv with cached tail
    w = p["conv_w"].astype(jnp.float32)
    k = w.shape[0]
    seq = jnp.concatenate([conv_cache.astype(jnp.float32), xb.astype(jnp.float32)], axis=1)
    conv_out = jnp.einsum("bkd,kd->bd", seq[:, -k:, :], w) + p["conv_b"]
    xcv = jax.nn.silu(conv_out)                                         # [B,din]
    conv_cache = seq[:, -(k - 1):, :].astype(conv_cache.dtype)

    dt = jax.nn.softplus(dtr[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = -jnp.exp(p["a_log"])
    g = jnp.exp(dt * a)                                                 # [B,H]
    xh = xcv.reshape(b, nh, hd)
    bv = bmat[:, 0].astype(jnp.float32)                                 # [B,N]
    cv = cmat[:, 0].astype(jnp.float32)
    state = state * g[..., None, None] + jnp.einsum(
        "bh,bn,bhd->bhnd", dt, bv, xh
    )
    y = jnp.einsum("bn,bhnd->bhd", cv, state) + xh * p["dskip"][None, :, None]
    y = (y.reshape(b, 1, din) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p["out_proj"], state, conv_cache
