"""GQA attention: chunked online-softmax forward, KV-cache decode, options.

One implementation serves all assigned archs via config flags:
  qk_norm (qwen3) · qkv_bias (qwen2) · attn_softcap (gemma2) ·
  sliding_window + local/global alternation (gemma2, recurrentgemma) ·
  MQA kv=1 (recurrentgemma) · non-causal / cross attention (whisper).

The train/prefill path is memory-efficient (flash-style): KV is consumed in
chunks under a lax.scan with running (max, denom, acc) — no S x S score
materialization, which is what lets 32k prefill and 4k x 256 training fit the
v5e HBM budget in the dry-run. The baseline masks instead of skipping
acausal KV chunks (2x causal FLOP overcount, visible in §Roofline's
useful-FLOPs ratio); §Perf hillclimbs this.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_norm, apply_rope, dense_init, dtype_of, softcap

NEG_INF = -2.3819763e38


# ----------------------------------------------------------------------------
# params
# ----------------------------------------------------------------------------
def init_attn(key, cfg, *, cross: bool = False):
    dt = dtype_of(cfg)
    d, hd = cfg.d_model, cfg.hd
    qd, kvd = cfg.n_heads * hd, cfg.n_kv * hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, qd), dt),
        "wk": dense_init(ks[1], (d, kvd), dt),
        "wv": dense_init(ks[2], (d, kvd), dt),
        "wo": dense_init(ks[3], (qd, d), dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((qd,), jnp.float32)
        p["bk"] = jnp.zeros((kvd,), jnp.float32)
        p["bv"] = jnp.zeros((kvd,), jnp.float32)
    if cfg.qk_norm and not cross:
        p["qnorm"] = jnp.ones((hd,), jnp.float32)
        p["knorm"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_q(cfg, p, x):
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
    b, s, _ = q.shape
    q = q.reshape(b, s, cfg.n_heads, cfg.hd)
    if "qnorm" in p:
        q = _headnorm(cfg, q, p["qnorm"])
    return q


def _project_kv(cfg, p, x):
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bk" in p:
        k, v = k + p["bk"].astype(k.dtype), v + p["bv"].astype(v.dtype)
    b, s, _ = k.shape
    k = k.reshape(b, s, cfg.n_kv, cfg.hd)
    v = v.reshape(b, s, cfg.n_kv, cfg.hd)
    if "knorm" in p:
        k = _headnorm(cfg, k, p["knorm"])
    return k, v


def _headnorm(cfg, x, scale):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + cfg.norm_eps) * scale).astype(x.dtype)


def _qscale(cfg):
    return cfg.query_scale if cfg.query_scale else cfg.hd ** -0.5


# ----------------------------------------------------------------------------
# chunked attention core (train / prefill)
# ----------------------------------------------------------------------------
def _shard_act(x, kind):
    from repro.parallel import sharding as _sh

    return _sh.shard_activation(x, kind)


def _pick_chunk(t: int, chunk: int) -> int:
    """Largest divisor of t that is <= chunk (KV-chunk length)."""
    if t <= chunk:
        return t
    for c in range(chunk, 0, -1):
        if t % c == 0:
            return c
    return t


def _attend_chunked(cfg, q, k, v, *, causal: bool, window: int, q_pos0=0, chunk: int = 1024):
    """q: [B,S,H,hd], k/v: [B,T,Kv,hd] -> [B,S,H,hd].

    Online-softmax scan over KV chunks. GQA is made uniform by repeating KV
    heads to full H *after* projection (cheap per chunk; keeps every einsum
    head-major so the head axis shards over "model" whenever H divides the
    TP size — the sharding hooks fall back to sequence sharding otherwise).
    ``window``>0 restricts to a trailing window (sliding-window attention).
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    g = h // cfg.n_kv
    ck = _pick_chunk(t, chunk)
    nck = t // ck

    # Keep q/k/v in the model dtype (bf16 on TPU) and accumulate in f32 via
    # preferred_element_type — an f32 cast here materializes 2x-size copies
    # of the full K/V (§Perf iteration D2).
    cdt = k.dtype
    qf = (q.astype(jnp.float32) * _qscale(cfg)).astype(cdt)
    qf = _shard_act(qf, "attn_q")                    # [B,S,H,hd]
    kr = jnp.repeat(k, g, axis=2)                    # [B,T,H,hd]
    vr = jnp.repeat(v, g, axis=2)
    kr = _shard_act(kr, "attn_kv")
    vr = _shard_act(vr, "attn_kv")
    kc = jnp.moveaxis(kr.reshape(b, nck, ck, h, hd), 1, 0)  # [nck,B,ck,H,hd]
    vc = jnp.moveaxis(vr.reshape(b, nck, ck, h, hd), 1, 0)

    q_ids = q_pos0 + jnp.arange(s, dtype=jnp.int32)

    def step(carry, xs):
        m, l, acc = carry
        kci, vci, c = xs
        sc = jnp.einsum("bshd,bchd->bshc", qf, kci,
                        preferred_element_type=jnp.float32)  # [B,S,H,ck] f32
        sc = softcap(sc, cfg.attn_softcap)
        kv_ids = c * ck + jnp.arange(ck, dtype=jnp.int32)
        mask = jnp.ones((s, ck), jnp.bool_)
        if causal:
            mask &= kv_ids[None, :] <= q_ids[:, None]
        if window:
            mask &= (q_ids[:, None] - kv_ids[None, :]) < window
        sc = jnp.where(mask[None, :, None, :], sc, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bshc,bchd->bshd", p.astype(cdt), vci,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((b, s, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, s, h), jnp.float32)
    a0 = jnp.zeros((b, s, h, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc, vc, jnp.arange(nck, dtype=jnp.int32))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# ----------------------------------------------------------------------------
# public forward paths
# ----------------------------------------------------------------------------
def attn_forward(cfg, p, x, positions, *, causal=True, window=0, memory=None, use_rope=True):
    """Full-sequence attention (train / prefill / encoder / cross).

    x: [B,S,d]; memory: [B,T,d] for cross attention (kv source).
    Returns (out [B,S,d], (k, v) fp cache entries [B,T,Kv,hd]).
    """
    q = _project_q(cfg, p, x)
    src = memory if memory is not None else x
    k, v = _project_kv(cfg, p, src)
    if use_rope and memory is None:
        q = apply_rope(cfg, q, positions[None, :])
        k = apply_rope(cfg, k, positions[None, :])
    out = _attend_chunked(cfg, q, k, v, causal=causal, window=window)
    b, s = x.shape[0], x.shape[1]
    out = out.reshape(b, s, cfg.n_heads * cfg.hd) @ p["wo"]
    return out, (k, v)


def attn_decode(cfg, p, x, cache_k, cache_v, pos, *, window=0, use_rope=True, update_cache=True):
    """Single-token decode. x: [B,1,d]; cache_k/v: [B,L,Kv,hd]; pos: int32 scalar.

    Global layers: cache is absolute-position indexed (L >= pos+1); mask is
    ids <= pos. Sliding-window layers with L < full context use the cache as
    a RING buffer of size L == window: the new token writes slot pos % L,
    keys carry their absolute RoPE rotation, and after warm-up every slot is
    in-window (mask = slot <= pos covers warm-up) — O(window) memory at any
    context length.
    """
    b, _, d = x.shape
    L = cache_k.shape[1]
    ring = bool(window) and window <= L and L != 0 and window == L
    q = _project_q(cfg, p, x)            # [B,1,H,hd]
    k_new, v_new = _project_kv(cfg, p, x)  # [B,1,Kv,hd]
    if use_rope:
        ppos = jnp.full((1,), pos, jnp.int32)
        q = apply_rope(cfg, q, ppos[None, :])
        k_new = apply_rope(cfg, k_new, ppos[None, :])
    widx = (pos % L) if ring else pos
    if update_cache:
        cache_k = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype), (0, widx, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype), (0, widx, 0, 0))

    kv = cfg.n_kv
    g = cfg.n_heads // kv
    qf = (q.astype(jnp.float32) * _qscale(cfg)).astype(cache_k.dtype)
    qf = qf.reshape(b, kv, g, cfg.hd)
    # Contract against the cache IN ITS STORED DTYPE with f32 accumulation —
    # an .astype(f32) here materializes a 2x-size copy of the whole cache
    # every decode step (§Perf iteration A1: dominant decode HBM term).
    sc = jnp.einsum("bkgd,blkd->bkgl", qf, cache_k,
                    preferred_element_type=jnp.float32)
    sc = softcap(sc, cfg.attn_softcap)
    ids = jnp.arange(L, dtype=jnp.int32)
    mask = ids <= pos                    # ring: warm-up gate; then all-valid
    if window and not ring:
        mask &= (pos - ids) < window
    sc = jnp.where(mask[None, None, None, :], sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgl,blkd->bkgd", pr.astype(cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, cfg.n_heads * cfg.hd).astype(x.dtype) @ p["wo"]
    return out, cache_k, cache_v
