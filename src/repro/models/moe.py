"""Mixture-of-Experts FFN: top-k token-choice routing, GROUPED sort dispatch.

TPU-friendly dropped-token MoE. Routing/sort/scatter are performed per
*group* (one batch row = one group), so every data shard dispatches its own
tokens with purely local sorts and scatters — a global sort would make the
scatter output unshardable and replicate the [E, C, d] dispatch buffers on
every device (observed 36 GB/device for a single olmoe layer on the 256-chip
dry-run). Expert compute is one batched einsum over [G, E, C, d] with E
sharded over "model" when divisible (olmoe 64/16 -> EP), else TP inside the
expert ffn dim (granite 40e, ff 512/16).

Capacity per group C = gs*k/E * cf (cf=1.25) with token dropping; small
groups (decode steps, tests) get drop-free capacity so decode == forward on
undropped tokens.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, dtype_of


def init_moe(key, cfg):
    dt = dtype_of(cfg)
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_ff
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "wi": dense_init(ks[1], (e, d, f), dt),
        "wg": dense_init(ks[2], (e, d, f), dt),
        "wo": dense_init(ks[3], (e, f, d), dt),
    }


def _dispatch_group(cfg, xg, router, cap):
    """One group's routing. xg: [gs, d] -> (xin [E,C,d], st, sw, keep, slot, aux)."""
    e, k = cfg.n_experts, cfg.top_k
    gs, d = xg.shape
    logits = xg.astype(jnp.float32) @ router                    # [gs, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)

    flat_e = top_e.reshape(-1)                                   # [gs*k]
    flat_t = jnp.repeat(jnp.arange(gs, dtype=jnp.int32), k)
    flat_w = top_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.bincount(se, length=e)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(gs * k, dtype=jnp.int32) - starts[se]
    keep = rank < cap
    slot = jnp.where(keep, se * cap + jnp.clip(rank, 0, cap - 1), e * cap)

    xin = jnp.zeros((e * cap, d), xg.dtype).at[slot].set(
        jnp.where(keep[:, None], xg[st], 0), mode="drop"
    ).reshape(e, cap, d)
    return xin, st, sw, keep, slot, aux


def apply_moe(cfg, p, x, *, capacity_factor: float = 1.25):
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar). Group = batch row."""
    from repro.parallel import sharding as _sh

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    gs = s
    if gs * k <= 4096:
        cap = gs * k                    # drop-free for small groups
    else:
        cap = int(max(1, round(gs * k / e * capacity_factor)))

    xin, st, sw, keep, slot, aux = jax.vmap(
        lambda xg: _dispatch_group(cfg, xg, p["router"], cap)
    )(x)
    # xin: [B, E, C, d]
    xin = _sh.shard_activation(xin, "moe_dispatch4")

    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("gecd,edf->gecf", xin, p["wg"])) * jnp.einsum(
        "gecd,edf->gecf", xin, p["wi"]
    )
    yo = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    yo = _sh.shard_activation(yo, "moe_dispatch4").reshape(b, e * cap, d)

    def combine(yg, stg, swg, keepg, slotg):
        return jnp.zeros((gs, d), x.dtype).at[jnp.where(keepg, stg, gs)].add(
            yg[jnp.clip(slotg, 0, e * cap - 1)] * swg[:, None].astype(x.dtype),
            mode="drop",
        )

    y = jax.vmap(combine)(yo, st, sw, keep, slot)
    return y.reshape(b, s, d), jnp.mean(aux) * cfg.router_aux_coef
