"""Unified model API: build(config) -> Model with init/loss/prefill/decode.

The three step functions lowered by the dry-run (launch/dryrun.py):
  train:   loss_and_metrics(params, batch)         batch from input_specs
  prefill: prefill(params, batch) -> (logits, caches)
  decode:  decode_step(params, caches, tokens, pos) -> (logits, caches)

``input_specs(shape_name)`` returns jax.ShapeDtypeStruct stand-ins for every
input — weak-type-correct, shardable, zero allocation — including modality
stubs (whisper frames, internvl2 patch embeddings).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, shape_for
from repro.models import encdec as ed
from repro.models import layers as L
from repro.models import transformer as T


def _sharding():
    from repro.parallel import sharding as _sh

    return _sh


def cross_entropy(logits, targets, mask=None):
    """logits: [B,S,V]; targets: [B,S] int32; mask: [B,S] or None.

    Sharding-friendly form: no gather over the (model-sharded) vocab axis —
    logsumexp reduces over V locally + psum, and the target logit comes from
    a fused one-hot contraction. take_along_axis here would force GSPMD to
    all-gather the full [B,S,V] logits per device (observed: 182 GB/device
    temp on the 256-chip dry-run; this form brings it back to ~C/shards).
    """
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    oh = jax.nn.one_hot(targets, logits.shape[-1], dtype=lf.dtype)
    tgt = jnp.sum(lf * oh, axis=-1)
    ll = tgt - lse
    if mask is None:
        return -jnp.mean(ll)
    m = mask.astype(jnp.float32)
    return -jnp.sum(ll * m) / jnp.maximum(jnp.sum(m), 1.0)


class Model:
    """Decoder-only LM families (dense / moe / ssm / hybrid / vlm-backbone)."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- params ---------------------------------------------------------------
    def init(self, rng) -> dict:
        cfg = self.cfg
        ke, kt = jax.random.split(rng)
        return {"embed": L.init_embed(ke, cfg), "trunk": T.init_trunk(kt, cfg)}

    def init_abstract(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # -- forward --------------------------------------------------------------
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        x = L.embed_tokens(cfg, params["embed"], batch["tokens"])
        if cfg.n_vis_tokens:
            vis = batch["vis_embeds"].astype(x.dtype)
            x = jnp.concatenate([vis, x], axis=1)
        return _sharding().shard_activation(x, "hidden")

    def forward(self, params, batch, *, want_cache=False, remat=False,
                last_only=False):
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        s = x.shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
        x, caches, aux = T.trunk_fwd(cfg, params["trunk"], x, positions,
                                     want_cache=want_cache, remat=remat)
        if cfg.n_vis_tokens:
            x = x[:, cfg.n_vis_tokens:, :]
        if last_only:
            # prefill only needs the final position's logits; unembedding the
            # whole sequence materializes a [B,S,V] f32 tensor for nothing
            # (§Perf iteration D1: 2.1 GB/chip on internvl2 prefill_32k)
            x = x[:, -1:, :]
        logits = L.unembed(cfg, params["embed"], x)
        logits = _sharding().shard_activation(logits, "logits")
        return logits, caches, aux

    def loss_and_metrics(self, params, batch, *, remat=True):
        logits, _, aux = self.forward(params, batch, remat=remat)
        tok = batch["tokens"]
        loss = cross_entropy(logits[:, :-1], tok[:, 1:]) + aux
        return loss, {"loss": loss, "aux": aux}

    # -- serving --------------------------------------------------------------
    def prefill(self, params, batch):
        logits, caches, _ = self.forward(params, batch, want_cache=True,
                                         last_only=True)
        return logits[:, -1, :], caches

    def decode_step(self, params, caches, tokens, pos, *, unroll: bool = False):
        """tokens: int32[B]; pos: int32 scalar. -> (logits [B,V], caches')."""
        cfg = self.cfg
        x = L.embed_tokens(cfg, params["embed"], tokens[:, None])
        x, caches = T.trunk_decode(cfg, params["trunk"], x, caches, pos, unroll=unroll)
        logits = L.unembed(cfg, params["embed"], x)[:, 0]
        return logits, caches

    def init_cache(self, batch: int, cache_len: int):
        return T.init_cache(self.cfg, batch, cache_len, L.dtype_of(self.cfg))

    def cache_from_prefill(self, caches, cache_len: int):
        """Convert prefill caches (length S entries) into decode caches of
        ``cache_len``. Attention entries are padded on the length axis (ring
        layers scatter the last `window` positions to slot p % window);
        ssm/rec entries pass through."""
        cfg = self.cfg
        out = []
        for (pat, _), gc in zip(T._pattern(cfg), caches):
            group = {}
            for li, kind in enumerate(pat):
                entry = gc[str(li)]
                if kind in ("global", "local", "moe"):
                    k, v = entry
                    s = k.shape[2]
                    ln = cache_len
                    if kind == "local" and cfg.sliding_window:
                        ln = min(cache_len, cfg.sliding_window)
                    if ln >= s:
                        pad = [(0, 0), (0, 0), (0, ln - s), (0, 0), (0, 0)]
                        group[str(li)] = (jnp.pad(k, pad), jnp.pad(v, pad))
                    else:  # ring: keep last ln positions at slot p % ln
                        pos = jnp.arange(s - ln, s)
                        slots = pos % ln
                        zk = jnp.zeros(k.shape[:2] + (ln,) + k.shape[3:], k.dtype)
                        group[str(li)] = (
                            zk.at[:, :, slots].set(k[:, :, s - ln:]),
                            zk.at[:, :, slots].set(v[:, :, s - ln:]),
                        )
                else:
                    group[str(li)] = entry
            out.append(group)
        return out

    def abstract_cache(self, batch: int, cache_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch, cache_len))

    # -- dry-run inputs ---------------------------------------------------------
    def input_specs(self, shape_name: str) -> dict[str, Any]:
        cfg = self.cfg
        sh = shape_for(shape_name)
        b, s = sh["global_batch"], sh["seq_len"]
        kind = sh["kind"]
        tok = jnp.int32
        if kind in ("train", "prefill"):
            s_text = s - cfg.n_vis_tokens
            specs = {"tokens": jax.ShapeDtypeStruct((b, s_text), tok)}
            if cfg.n_vis_tokens:
                specs["vis_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_vis_tokens, cfg.d_model), L.dtype_of(cfg))
            return specs
        # decode: one new token against a cache of length s
        return {
            "tokens": jax.ShapeDtypeStruct((b,), tok),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }


class EncDecModel:
    """Whisper-style enc-dec; frames stub via input_specs."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def init(self, rng) -> dict:
        return ed.init_encdec(rng, self.cfg)

    def init_abstract(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def loss_and_metrics(self, params, batch, *, remat=True):
        cfg = self.cfg
        enc = ed.encode(cfg, params, batch["frames"])
        logits, _ = ed.decode_fwd(cfg, params, batch["tokens"], enc, want_cache=False)
        loss = cross_entropy(logits[:, :-1], batch["tokens"][:, 1:])
        return loss, {"loss": loss, "aux": jnp.float32(0.0)}

    def prefill(self, params, batch):
        cfg = self.cfg
        enc = ed.encode(cfg, params, batch["frames"])
        logits, caches = ed.decode_fwd(cfg, params, batch["tokens"], enc, want_cache=True)
        return logits[:, -1, :], caches

    def decode_step(self, params, caches, tokens, pos):
        self_c, cross_c = caches
        logits, new_self = ed.decode_step(self.cfg, params, tokens, self_c, cross_c, pos)
        return logits, (new_self, cross_c)

    def init_cache(self, batch: int, cache_len: int):
        return ed.init_dec_cache(self.cfg, batch, cache_len, L.dtype_of(self.cfg))

    def abstract_cache(self, batch: int, cache_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch, cache_len))

    def input_specs(self, shape_name: str):
        cfg = self.cfg
        sh = shape_for(shape_name)
        b, s = sh["global_batch"], sh["seq_len"]
        kind = sh["kind"]
        if kind in ("train", "prefill"):
            return {
                "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                "frames": jax.ShapeDtypeStruct((b, cfg.enc_frames, cfg.d_model),
                                               L.dtype_of(cfg)),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }


def build_model(cfg: ArchConfig):
    if cfg.family == "encdec":
        return EncDecModel(cfg)
    return Model(cfg)
