"""Whisper-style encoder-decoder backbone (conv audio frontend STUBBED).

``input_specs`` supplies precomputed frame embeddings [B, F, d] (the conv1d
x2 + GELU frontend of Whisper is a modality stub per the assignment); the
encoder is a bidirectional transformer over frames with sinusoidal
positions, the decoder a causal transformer with cross-attention. Decode
carries a self-attn KV cache plus precomputed cross-attention K/V.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L


def _enc_cfg(cfg):
    return cfg  # same widths for enc/dec in whisper-base


def init_encdec(key, cfg):
    ks = jax.random.split(key, 6)

    def stack(k, n, maker):
        leaves = [maker(jax.random.fold_in(k, i)) for i in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": L.init_norm(cfg, cfg.d_model),
            "attn": attn.init_attn(k1, cfg),
            "norm2": L.init_norm(cfg, cfg.d_model),
            "mlp": L.init_mlp(k2, cfg, cfg.d_model, cfg.d_ff),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": L.init_norm(cfg, cfg.d_model),
            "self": attn.init_attn(k1, cfg),
            "norm_x": L.init_norm(cfg, cfg.d_model),
            "cross": attn.init_attn(k2, cfg, cross=True),
            "norm2": L.init_norm(cfg, cfg.d_model),
            "mlp": L.init_mlp(k3, cfg, cfg.d_model, cfg.d_ff),
        }

    return {
        "embed": L.init_embed(ks[0], cfg),
        "enc": stack(ks[1], cfg.enc_layers, enc_layer),
        "enc_norm": L.init_norm(cfg, cfg.d_model),
        "dec": stack(ks[2], cfg.n_layers, dec_layer),
        "dec_norm": L.init_norm(cfg, cfg.d_model),
    }


def encode(cfg, params, frames):
    """frames: [B, F, d] (stub embeddings) -> [B, F, d]."""
    f = frames.shape[1]
    x = frames + L.sinusoidal_positions(f, cfg.d_model).astype(frames.dtype)[None]
    positions = jnp.arange(f, dtype=jnp.int32)

    def layer(x, p):
        h = L.apply_norm(cfg, p["norm1"], x)
        a, _ = attn.attn_forward(cfg, p["attn"], h, positions, causal=False, use_rope=False)
        x = x + a
        h = L.apply_norm(cfg, p["norm2"], x)
        return x + L.apply_mlp(cfg, p["mlp"], h), None

    x, _ = jax.lax.scan(layer, x, params["enc"])
    return L.apply_norm(cfg, params["enc_norm"], x)


def decode_fwd(cfg, params, tokens, enc_out, *, want_cache: bool):
    """Full decoder pass. tokens: [B,S] -> (logits [B,S,V], caches|None)."""
    x = L.embed_tokens(cfg, params["embed"], tokens)
    s = tokens.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)

    def layer(x, p):
        h = L.apply_norm(cfg, p["norm1"], x)
        a, kv = attn.attn_forward(cfg, p["self"], h, positions, causal=True, use_rope=True)
        x = x + a
        h = L.apply_norm(cfg, p["norm_x"], x)
        c, ckv = attn.attn_forward(cfg, p["cross"], h, positions, causal=False,
                                   memory=enc_out, use_rope=False)
        x = x + c
        h = L.apply_norm(cfg, p["norm2"], x)
        x = x + L.apply_mlp(cfg, p["mlp"], h)
        return x, ((kv, ckv) if want_cache else None)

    x, caches = jax.lax.scan(layer, x, params["dec"])
    x = L.apply_norm(cfg, params["dec_norm"], x)
    return L.unembed(cfg, params["embed"], x), caches


def decode_step(cfg, params, tokens, caches, cross_kv, pos):
    """One-token decode. tokens: [B]; caches: stacked (k,v) self caches;
    cross_kv: stacked (k,v) over enc frames. -> (logits [B,V], caches')."""
    x = L.embed_tokens(cfg, params["embed"], tokens[:, None])

    def layer(x, xs):
        p, (ck, cv), (xk, xv) = xs
        h = L.apply_norm(cfg, p["norm1"], x)
        a, ck, cv = attn.attn_decode(cfg, p["self"], h, ck, cv, pos)
        x = x + a
        h = L.apply_norm(cfg, p["norm_x"], x)
        # cross attention against fixed encoder K/V
        b = x.shape[0]
        q = h @ p["cross"]["wq"]
        q = q.reshape(b, 1, cfg.n_heads, cfg.hd)
        kv, g = cfg.n_kv, cfg.n_heads // cfg.n_kv
        qf = q.astype(jnp.float32).reshape(b, kv, g, cfg.hd) * (cfg.hd ** -0.5)
        sc = jnp.einsum("bkgd,blkd->bkgl", qf, xk.astype(jnp.float32))
        pr = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bkgl,blkd->bkgd", pr, xv.astype(jnp.float32))
        o = o.reshape(b, 1, cfg.n_heads * cfg.hd).astype(x.dtype) @ p["cross"]["wo"]
        x = x + o
        h = L.apply_norm(cfg, p["norm2"], x)
        x = x + L.apply_mlp(cfg, p["mlp"], h)
        return x, (ck, cv)

    x, new_caches = jax.lax.scan(layer, x, (params["dec"], caches, cross_kv))
    x = L.apply_norm(cfg, params["dec_norm"], x)
    return L.unembed(cfg, params["embed"], x)[:, 0], new_caches


def init_dec_cache(cfg, batch: int, cache_len: int, dtype):
    shape = (cfg.n_layers, batch, cache_len, cfg.n_kv, cfg.hd)
    xshape = (cfg.n_layers, batch, cfg.enc_frames, cfg.n_kv, cfg.hd)
    return (
        (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)),
        (jnp.zeros(xshape, dtype), jnp.zeros(xshape, dtype)),
    )
