"""Sharded, async, elastic checkpointing.

Layout: one directory per step —
    <dir>/step_000010/
        manifest.json      tree structure, shapes, dtypes, step, extra meta
        leaf_000000.npy    one file per pytree leaf (host-gathered here;
        ...                per-shard files on a real multi-host fleet, see
                           the `shard_hint` field kept in the manifest)

Properties needed at 1000-node scale, all implemented:
  * atomic publish: write to `<dir>/.tmp_step_x`, fsync, rename; a crashed
    writer never corrupts the latest checkpoint.
  * async save: device->host transfer happens synchronously (cheap), file
    I/O in a background thread; ``wait()`` joins before the next save.
  * elastic restore: leaves are loaded as global arrays and re-placed under
    ANY target sharding/mesh (reshard-on-load), so a 512-chip checkpoint
    restores onto 256 chips and vice versa.
  * retention: keep the last K steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree, *, extra: dict | None = None, blocking: bool = False):
        """Snapshot ``tree`` at ``step``. Returns immediately unless blocking."""
        self.wait()
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]   # device -> host now
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "n_leaves": len(host_leaves),
            "shapes": [list(x.shape) for x in host_leaves],
            "dtypes": [str(x.dtype) for x in host_leaves],
            "shard_hint": "host-gathered (single-process); per-shard on fleet",
            "extra": extra or {},
            "time": time.time(),
        }

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step:09d}")
            final = os.path.join(self.dir, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            for i, leaf in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"leaf_{i:06d}.npy"), leaf)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._retain()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, *, step: int | None = None, shardings=None):
        """Load into the structure of ``template`` (values ignored).

        ``shardings``: optional tree of NamedShardings for elastic re-placement
        on the current mesh (may differ from the saving mesh).
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_t, treedef = _flatten(template)
        assert manifest["n_leaves"] == len(leaves_t), "tree structure changed"
        out = []
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
        )
        for i, tmpl in enumerate(leaves_t):
            arr = np.load(os.path.join(path, f"leaf_{i:06d}.npy"))
            if hasattr(tmpl, "dtype"):
                arr = arr.astype(tmpl.dtype)
            if shard_leaves is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out), manifest
