"""Sharded, async, elastic checkpointing.

Layout: one directory per step —
    <dir>/step_000010/
        manifest.json      tree structure, shapes, dtypes, step, extra meta
        leaf_000000.npy    one file per pytree leaf (host-gathered here;
        ...                per-shard files on a real multi-host fleet, see
                           the `shard_hint` field kept in the manifest)

Properties needed at 1000-node scale, all implemented:
  * atomic publish: write to `<dir>/.tmp_step_x`, fsync, rename; a crashed
    writer never corrupts the latest checkpoint.
  * async save: device->host transfer happens synchronously (cheap), file
    I/O in a background thread; ``wait()`` joins before the next save.
  * elastic restore: leaves are loaded as global arrays and re-placed under
    ANY target sharding/mesh (reshard-on-load), so a 512-chip checkpoint
    restores onto 256 chips and vice versa.
  * retention: keep the last K steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _fsync_dir(path: str) -> None:
    """fsync a directory so renames/creates inside it are durable."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


# tmp dirs with a LIVE writer thread in this process: the stale-tmp sweep
# below must not reap a write that is still going to publish (a simulated
# in-process crash leaves the background writer running; a real kill -9
# leaves no writer, so its debris is always sweepable)
_live_tmp_lock = threading.Lock()
_live_tmp: set[str] = set()


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        os.makedirs(directory, exist_ok=True)
        # a crashed writer (kill between tmp write and rename) leaves a
        # stale .tmp_step_* dir; it never shadows a published step, but
        # clean it so retention math and disk usage stay honest
        with _live_tmp_lock:
            live = set(_live_tmp)
        for name in os.listdir(directory):
            path = os.path.join(directory, name)
            if name.startswith(".tmp_step_") and path not in live:
                shutil.rmtree(path, ignore_errors=True)

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree, *, extra: dict | None = None, blocking: bool = False):
        """Snapshot ``tree`` at ``step``. Returns immediately unless blocking.

        ``blocking=True`` joins the writer thread before returning, so the
        checkpoint is fully published (fsynced + renamed) on return — the
        guarantee recovery cadence and WAL truncation build on.
        """
        self.wait()
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]   # device -> host now
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "n_leaves": len(host_leaves),
            "shapes": [list(x.shape) for x in host_leaves],
            "dtypes": [str(x.dtype) for x in host_leaves],
            "shard_hint": "host-gathered (single-process); per-shard on fleet",
            "extra": extra or {},
            "time": time.time(),
        }

        # register the tmp path BEFORE the thread starts: a concurrently
        # constructed Checkpointer on the same directory must never sweep
        # a write that is still going to publish
        tmp = os.path.join(self.dir, f".tmp_step_{step:09d}")
        with _live_tmp_lock:
            _live_tmp.add(tmp)

        def write():
            try:
                self._write(step, host_leaves, manifest)
            except BaseException as e:  # surfaced by the next wait()/save()
                self._error = e
            finally:
                with _live_tmp_lock:
                    _live_tmp.discard(tmp)

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, host_leaves, manifest: dict,
               publish: bool = True):
        """Write tmp dir, fsync every file + the dirs, then atomic rename.
        ``publish=False`` stops before the rename — the ``ckpt-mid-write``
        crash stage in the chaos harness."""
        tmp = os.path.join(self.dir, f".tmp_step_{step:09d}")
        final = os.path.join(self.dir, f"step_{step:09d}")
        os.makedirs(tmp, exist_ok=True)
        for i, leaf in enumerate(host_leaves):
            p = os.path.join(tmp, f"leaf_{i:06d}.npy")
            with open(p, "wb") as f:
                np.save(f, leaf)
                f.flush()
                os.fsync(f.fileno())
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        _fsync_dir(tmp)
        if not publish:
            return
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _fsync_dir(self.dir)
        self._retain()

    def wait(self):
        """Join the in-flight writer; re-raise any background failure (a
        silently-dropped checkpoint must not look like a durable one)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("background checkpoint write failed") from err

    def _retain(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, *, step: int | None = None, shardings=None):
        """Load into the structure of ``template`` (values ignored).

        ``shardings``: optional tree of NamedShardings for elastic re-placement
        on the current mesh (may differ from the saving mesh).
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_t, treedef = _flatten(template)
        assert manifest["n_leaves"] == len(leaves_t), "tree structure changed"
        out = []
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
        )
        for i, tmpl in enumerate(leaves_t):
            arr = np.load(os.path.join(path, f"leaf_{i:06d}.npy"))
            if hasattr(tmpl, "dtype"):
                arr = arr.astype(tmpl.dtype)
            if shard_leaves is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out), manifest

    def restore_raw(self, *, step: int | None = None
                    ) -> tuple[list[np.ndarray], dict]:
        """Load the raw host leaves + manifest without a template.

        The graph-aware wrapper (runtime/recovery.py) needs this: its
        trees carry a VARIABLE number of leaves (epoch-ring records vary
        per checkpoint), so the template-based ``restore`` leaf-count
        assertion cannot apply.
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves = [np.load(os.path.join(path, f"leaf_{i:06d}.npy"))
                  for i in range(manifest["n_leaves"])]
        return leaves, manifest
