"""Shared AST helpers for ``repro.analysis`` rules (DESIGN.md §15).

Pure ``ast``-level utilities: dotted-name rendering, call resolution, and
the intra-module jit-reachability walk the trace-purity rule is built on.
No imports of the analyzed code ever happen here — rules that need live
objects (metrics-doc) do their own importing and say so.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional


def dotted(node: ast.AST) -> str:
    """Render a Name/Attribute chain as 'a.b.c' ('' when not a plain
    chain). Subscripts and calls inside the chain end the rendering at
    that point — good enough for pattern rules."""
    parts: list[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return ""


def call_name(call: ast.Call) -> str:
    """Dotted name of a call's callee ('' when dynamic)."""
    return dotted(call.func)


def keyword_names(call: ast.Call) -> set[str]:
    return {kw.arg for kw in call.keywords if kw.arg is not None}


def parent(node: ast.AST) -> Optional[ast.AST]:
    """Parent link attached by ``FileContext.tree``."""
    return getattr(node, "_repro_parent", None)


def enclosing_class(node: ast.AST) -> Optional[ast.ClassDef]:
    cur = parent(node)
    while cur is not None:
        if isinstance(cur, ast.ClassDef):
            return cur
        cur = parent(cur)
    return None


def enclosing_function(node: ast.AST) -> Optional[ast.AST]:
    cur = parent(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = parent(cur)
    return None


def module_functions(tree: ast.Module) -> dict[str, ast.FunctionDef]:
    """All function defs in the module keyed by BARE name (module level,
    methods and nested defs alike — bare-name resolution is the documented
    heuristic of the reachability walk; a miss only widens the scanned
    set, never narrows it)."""
    out: dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            out.setdefault(node.name, node)
    return out


# --- jit-entry detection ----------------------------------------------------
# Decorator spellings that make a function a traced/compiled entry point.
_JIT_DECOR_SUFFIXES = ("jit", "pallas_call", "shard_map", "pmap")
# Call targets whose FUNCTION ARGUMENTS are traced (loop bodies etc.).
_TRACED_ARG_CALLS = ("while_loop", "fori_loop", "cond", "scan", "switch",
                     "pallas_call", "shard_map", "vmap", "grad", "jit")


def _decorator_is_jit(dec: ast.AST) -> bool:
    name = dotted(dec)
    if name and name.split(".")[-1] in _JIT_DECOR_SUFFIXES:
        return True
    if isinstance(dec, ast.Call):
        # functools.partial(jax.jit, ...) and jax.jit(...) spellings
        callee = dotted(dec.func)
        if callee and callee.split(".")[-1] in _JIT_DECOR_SUFFIXES:
            return True
        if callee.split(".")[-1] == "partial" and dec.args:
            inner = dotted(dec.args[0])
            if inner and inner.split(".")[-1] in _JIT_DECOR_SUFFIXES:
                return True
    return False


def jit_entry_names(tree: ast.Module) -> set[str]:
    """Functions that are traced entry points: decorated with jax.jit /
    pallas_call / shard_map (any partial spelling), or passed by name into
    a tracing combinator (lax.while_loop / cond / scan / fori_loop /
    pallas_call / shard_map / vmap / jit)."""
    entries: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            if any(_decorator_is_jit(d) for d in node.decorator_list):
                entries.add(node.name)
        elif isinstance(node, ast.Call):
            callee = call_name(node)
            if callee and callee.split(".")[-1] in _TRACED_ARG_CALLS:
                for arg in list(node.args) + [kw.value for kw in node.keywords]:
                    if isinstance(arg, ast.Name):
                        entries.add(arg.id)
                    elif isinstance(arg, ast.Call):
                        # functools.partial(fn, ...) passed as traced arg
                        inner = dotted(arg.func)
                        if inner.split(".")[-1] == "partial" and arg.args:
                            nm = dotted(arg.args[0])
                            if nm and "." not in nm:
                                entries.add(nm)
    return entries


def jit_reachable_functions(tree: ast.Module) -> dict[str, ast.FunctionDef]:
    """Transitive closure of module functions reachable from the jit
    entries via bare-name calls. Nested defs are covered implicitly (a
    FunctionDef's walk includes its nested bodies)."""
    funcs = module_functions(tree)
    work = [n for n in jit_entry_names(tree) if n in funcs]
    reached: dict[str, ast.FunctionDef] = {}
    while work:
        name = work.pop()
        if name in reached:
            continue
        fn = funcs.get(name)
        if fn is None:
            continue
        reached[name] = fn
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                callee = call_name(node)
                if callee and "." not in callee and callee in funcs \
                        and callee not in reached:
                    work.append(callee)
    return reached


def iter_calls(node: ast.AST) -> Iterator[ast.Call]:
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            yield n


def literal_assignment(tree: ast.Module, name: str):
    """(value, node) of a module-level ``NAME = <literal>`` assignment;
    (None, node) when present but not a pure literal; (None, None) when
    absent. Used by the kernel-shape sanitizer to read KERNEL_META without
    importing the package."""
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
        for t in targets:
            if isinstance(t, ast.Name) and t.id == name:
                try:
                    return ast.literal_eval(node.value), node
                except (ValueError, SyntaxError):
                    return None, node
    return None, None
