"""Module walking + import preflight (DESIGN.md §15).

The analysis framework's one *runtime* helper: enumerate the modules of a
package directory and verify each imports cleanly (optionally exposing a
required attribute) BEFORE anything expensive consumes them. First
consumer: ``benchmarks/run.py --smoke`` preflights every registered
figure module so a broken import fails the gate in milliseconds instead
of mid-sweep.
"""
from __future__ import annotations

import importlib
import traceback
from pathlib import Path
from typing import Iterator, Optional, Sequence


def iter_package_modules(pkg_dir: Path, pkg_name: str
                         ) -> Iterator[tuple[str, Path]]:
    """Yield (dotted module name, path) for every .py module under a
    package directory (subpackages included, __init__ as the package
    itself). Pure filesystem walk — nothing is imported."""
    pkg_dir = Path(pkg_dir)
    for path in sorted(pkg_dir.rglob("*.py")):
        rel = path.relative_to(pkg_dir)
        parts = list(rel.parts[:-1])
        stem = rel.stem
        if stem != "__init__":
            parts.append(stem)
        name = ".".join([pkg_name] + parts) if parts else pkg_name
        yield name, path


def preflight_imports(modules: Sequence[str],
                      require_attr: Optional[str] = None) -> list[str]:
    """Import every named module; return human-readable errors (empty =
    all clean). ``require_attr`` additionally asserts each module exposes
    that attribute — e.g. the ``main`` entry point the benchmark driver
    is about to call."""
    errors: list[str] = []
    for name in modules:
        try:
            mod = importlib.import_module(name)
        except BaseException as e:  # noqa: BLE001 - report, never crash
            tb = traceback.format_exception_only(type(e), e)[-1].strip()
            errors.append(f"{name}: import failed — {tb}")
            continue
        if require_attr is not None and not hasattr(mod, require_attr):
            errors.append(f"{name}: imports but has no {require_attr!r} "
                          f"attribute")
    return errors
