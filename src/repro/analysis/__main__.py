"""``python -m repro.analysis`` — the CI gate entry point (DESIGN.md §15)."""
import sys

from repro.analysis.cli import main

sys.exit(main())
