"""Committed finding baseline for ``repro.analysis`` (DESIGN.md §15).

The baseline (``analysis_baseline.json`` at the repo root) grandfathers
pre-existing findings that are *correct code* the heuristic rules cannot
see through — never newly written violations. Policy:

  * every entry carries a one-line ``why`` justification (enforced here);
  * entries pin (rule, path, line) plus a ``contains`` substring of the
    message, so an entry silences exactly the finding it was written for
    and nothing that later drifts onto the same line;
  * an entry that matches NO current finding is *stale* and reported as a
    finding itself — the baseline can only shrink or be re-justified,
    never rot;
  * the gate (and tests/test_analysis.py) keeps the file at <= 10 entries.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Optional

from repro.analysis.framework import Finding

DEFAULT_BASELINE = "analysis_baseline.json"
MAX_ENTRIES = 10

_REQUIRED = ("rule", "path", "why")


@dataclass
class BaselineEntry:
    rule: str
    path: str
    why: str
    line: Optional[int] = None       # None = any line in the file
    contains: str = ""               # substring the message must contain

    def matches(self, f: Finding) -> bool:
        return (f.rule == self.rule and f.path == self.path
                and (self.line is None or f.line == self.line)
                and (self.contains in f.message))

    def describe(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"[{self.rule}] {loc}"


class Baseline:
    """Loaded baseline; ``apply`` partitions findings into live /
    grandfathered and reports stale entries."""

    def __init__(self, entries: list[BaselineEntry], path: Optional[Path] = None):
        self.entries = entries
        self.path = path

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        path = Path(path)
        if not path.exists():
            return cls([], path)
        data = json.loads(path.read_text(encoding="utf-8"))
        raw = data["entries"] if isinstance(data, dict) else data
        entries = []
        problems = []
        for i, e in enumerate(raw):
            missing = [k for k in _REQUIRED if not e.get(k)]
            if missing:
                problems.append(
                    f"baseline entry {i} missing/empty {missing} — every "
                    f"entry needs a rule, a path and a one-line why")
                continue
            entries.append(BaselineEntry(
                rule=e["rule"], path=e["path"], why=e["why"],
                line=e.get("line"), contains=e.get("contains", "")))
        if len(raw) > MAX_ENTRIES:
            problems.append(
                f"baseline has {len(raw)} entries — policy caps it at "
                f"{MAX_ENTRIES}; fix findings instead of accumulating them")
        bl = cls(entries, path)
        bl._load_problems = problems  # surfaced by apply()
        return bl

    _load_problems: list = []

    def apply(self, findings: list[Finding],
              active: Optional[set] = None
              ) -> tuple[list[Finding], list[Finding], list[Finding]]:
        """(live, grandfathered, stale+malformed-as-findings).

        ``active`` is the set of rule names that actually ran: entries for
        rules OUTSIDE it are neither matched nor stale (a single-rule run
        must not call every other rule's baseline entries dead)."""
        rel = self.path.name if self.path else DEFAULT_BASELINE
        live: list[Finding] = []
        grandfathered: list[Finding] = []
        hit = [0] * len(self.entries)
        for f in findings:
            for i, e in enumerate(self.entries):
                if e.matches(f):
                    hit[i] += 1
                    grandfathered.append(f)
                    break
            else:
                live.append(f)
        stale = [
            Finding("stale-baseline", rel, 0,
                    f"{e.describe()} matches no current finding — remove "
                    f"the entry (was justified: {e.why})")
            for i, e in enumerate(self.entries)
            if not hit[i] and (active is None or e.rule in active)
        ]
        stale += [Finding("stale-baseline", rel, 0, msg)
                  for msg in getattr(self, "_load_problems", [])]
        return live, grandfathered, stale
