"""repro.analysis — the repo's invariant linter + Pallas kernel sanitizer
(DESIGN.md §15).

``python -m repro.analysis`` runs every registered rule over the tree and
exits non-zero on live findings; CI gates on it. See ``framework.py`` for
the rule/suppression/baseline model and ``rules/`` for the invariants.
"""
from repro.analysis.framework import (  # noqa: F401
    AnalysisResult,
    FileContext,
    Finding,
    RepoContext,
    Rule,
    all_rules,
    get_rule,
    register,
    run,
)

__all__ = [
    "AnalysisResult", "FileContext", "Finding", "RepoContext", "Rule",
    "all_rules", "get_rule", "register", "run",
]
