"""CLI for the invariant linter: ``python -m repro.analysis`` (DESIGN.md §15).

Exit status is the gate: 0 when no live findings, 1 otherwise. ``--json``
emits the machine-readable result (uploaded as a CI artifact); explicit
PATH arguments bypass the per-rule default filters (how the fixture tests
point one rule at one deliberately-bad file).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import framework
from repro.analysis.baseline import DEFAULT_BASELINE, Baseline


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="invariant linter + Pallas kernel sanitizer")
    p.add_argument("paths", nargs="*", type=Path,
                   help="explicit files to scan (default: the standard "
                        "root walk; explicit paths bypass per-rule scopes)")
    p.add_argument("--root", type=Path, default=Path.cwd(),
                   help="repo root (default: cwd)")
    p.add_argument("--rule", action="append", dest="rules", metavar="NAME",
                   help="run only this rule (repeatable)")
    p.add_argument("--json", action="store_true",
                   help="emit the machine-readable result on stdout")
    p.add_argument("--baseline", type=Path, default=None,
                   help=f"baseline file (default: <root>/{DEFAULT_BASELINE})")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore the committed baseline")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule table and exit")
    return p


def _list_rules() -> int:
    for rule in framework.all_rules():
        scope = f"{rule.scope}-scoped"
        origin = f" [{rule.origin}]" if rule.origin else ""
        print(f"{rule.name:24s} ({rule.severity}, {scope}){origin}\n"
              f"    {rule.invariant}")
    return 0


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        return _list_rules()

    rules = None
    if args.rules:
        rules = [framework.get_rule(n) for n in args.rules]

    baseline = None
    if not args.no_baseline:
        bpath = args.baseline or (args.root / DEFAULT_BASELINE)
        baseline = Baseline.load(bpath)

    result = framework.run(args.root, paths=args.paths or None,
                           rules=rules, baseline=baseline)

    if args.json:
        print(json.dumps(result.to_json(), indent=2))
    else:
        for f in result.findings:
            print(f.render())
        parts = [f"{result.files_scanned} files",
                 f"{len(result.rules_run)} rules",
                 f"{len(result.findings)} findings"]
        if result.suppressed:
            parts.append(f"{len(result.suppressed)} suppressed")
        if result.baselined:
            parts.append(f"{len(result.baselined)} baselined")
        status = "ok" if result.ok else "FAIL"
        print(f"repro.analysis: {', '.join(parts)} — {status}",
              file=sys.stderr if not result.ok else sys.stdout)
    return 0 if result.ok else 1


if __name__ == "__main__":  # pragma: no cover - exercised via __main__
    sys.exit(main())
