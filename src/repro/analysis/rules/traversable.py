"""traversable-predicate: no raw adjacency liveness tests (DESIGN.md §1, §15).

PR 4's parent-scan drift — ``bfs_step_jnp`` testing ``adj > 0`` bare
while the expansion applied the endpoint-liveness mask — is the bug class
this rule kills: exactly ONE predicate, ``core.graph.traversable`` (and
its packed twin), may decide whether an edge is logically present. Any
other comparison of an adjacency expression against a constant is either
a liveness test that forgot the alive mask, or physical-bit bookkeeping
that must say so with an inline allow.

Heuristic: a Compare / BinOp whose operand's dotted source involves a
name containing ``adj`` (``adj``, ``adj_packed``, ``adj_in``, ``adj_l``,
``adjw_ref``, ...) tested against a numeric constant, outside the
predicate's home ``core/graph.py`` and the host-side spec oracle.
"""
from __future__ import annotations

import ast

from repro.analysis.framework import FileContext, Finding, Rule, register

# files allowed to test adjacency raw: the predicate definition site and
# the host-side python spec oracle (definitionally correct by inspection)
ALLOWED = ("core/graph.py", "core/oracle.py")


def _mentions_adj(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and "adj" in n.id:
            return True
        if isinstance(n, ast.Attribute) and "adj" in n.attr:
            return True
    return False


def _is_const_num(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) and isinstance(
        node.value, (int, float)) and not isinstance(node.value, bool)


def check(ctx: FileContext) -> list[Finding]:
    if ctx.relpath.endswith(ALLOWED):
        return []
    out: list[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Compare):
            continue
        sides = [node.left] + list(node.comparators)
        if not any(_is_const_num(s) for s in sides):
            continue
        exprs = [s for s in sides if not _is_const_num(s)]
        if any(_mentions_adj(e) for e in exprs):
            out.append(ctx.finding(
                RULE, node,
                "raw adjacency test — edge liveness must come from "
                "core.graph.traversable()/traversable_packed() (or be an "
                "explicitly allowed physical-bit read); the PR 4 "
                "parent-scan drift is exactly this pattern"))
    return out


RULE = register(Rule(
    name="traversable-predicate",
    invariant="edge liveness is decided only by core.graph.traversable / "
              "traversable_packed",
    check=check,
    origin="PR 4 parent-scan liveness drift",
    default_filter=lambda rel: rel.startswith(("src/", "benchmarks/")),
))
