"""trace-purity: no host syncs or wall-clock reads in traced code
(DESIGN.md §10, §14, §15).

Functions that run under ``jax.jit`` / ``shard_map`` / ``pl.pallas_call``
(or are passed into ``lax.while_loop`` / ``cond`` / ``scan`` bodies) are
traced: a ``time.*`` or ``random.*`` call silently bakes one sample into
the compiled artifact, and host-sync idioms — ``.item()``,
``bool(array)``, ``np.asarray(...)`` — either crash on tracers or,
worse, force a device round-trip per call when tracing is avoided. The
obs layer's null-span path (``_trace.span`` / ``enabled`` / ``fence``)
is explicitly exempt: its disabled path is host-free by construction and
pinned by tests/test_obs.py, and the ``repro/obs`` package itself is the
one place allowed to read clocks.

Detection: intra-module call graph from the jit entry points
(``astutil.jit_reachable_functions`` — bare-name resolution, documented
heuristic), then flag the banned call patterns inside reached bodies.
``jnp.asarray`` is fine (a traced op); ``np.asarray`` is not.
"""
from __future__ import annotations

import ast

from repro.analysis import astutil
from repro.analysis.framework import FileContext, Finding, Rule, register

# dotted-prefix bans: wall clocks and host RNGs inside traced code
BANNED_PREFIXES = (
    ("time.", "wall-clock read"),
    ("random.", "host RNG draw"),
    ("np.random.", "host RNG draw"),
    ("numpy.random.", "host RNG draw"),
)
# exact-callee bans: host-sync conversions
BANNED_CALLS = {
    "np.asarray": "host-sync materialization (np.asarray forces the device "
                  "buffer to host; use jnp.asarray)",
    "numpy.asarray": "host-sync materialization (use jnp.asarray)",
    "bool": "host-sync truthiness (bool(traced array) blocks or raises "
            "under tracing)",
}
BANNED_METHODS = {
    "item": "host-sync scalar read (.item() blocks on the device value)",
    "block_until_ready": "explicit host sync inside traced code",
}
# the obs layer's null-span surface is exempt (host-free disabled path)
EXEMPT_PREFIXES = ("_trace.",)


def _banned(call: ast.Call) -> str | None:
    name = astutil.call_name(call)
    if not name:
        return None
    if any(name.startswith(p) for p in EXEMPT_PREFIXES):
        return None
    if name in BANNED_CALLS:
        return f"{name}() — {BANNED_CALLS[name]}"
    for prefix, why in BANNED_PREFIXES:
        if name.startswith(prefix):
            return f"{name}() — {why}"
    meth = name.split(".")[-1]
    if isinstance(call.func, ast.Attribute) and meth in BANNED_METHODS:
        return f".{meth}() — {BANNED_METHODS[meth]}"
    return None


def check(ctx: FileContext) -> list[Finding]:
    if ctx.relpath.startswith("src/repro/obs/"):
        return []  # the obs layer owns the clocks (null-span exemption)
    reached = astutil.jit_reachable_functions(ctx.tree)
    if not reached:
        return []
    out: list[Finding] = []
    seen_lines: set[int] = set()
    for fname, fn in sorted(reached.items()):
        for call in astutil.iter_calls(fn):
            why = _banned(call)
            if why is None or call.lineno in seen_lines:
                continue
            seen_lines.add(call.lineno)
            out.append(ctx.finding(
                RULE, call,
                f"{why} inside {fname}(), which is reachable from a "
                f"jit/shard_map/pallas_call hot loop (DESIGN.md §14 "
                f"trace-purity)"))
    return out


RULE = register(Rule(
    name="trace-purity",
    invariant="no time/random/host-sync calls in functions reachable from "
              "jit, shard_map or pallas_call entry points",
    check=check,
    origin="PR 8 obs-layer zero-overhead pins",
    default_filter=lambda rel: rel.startswith("src/"),
))
