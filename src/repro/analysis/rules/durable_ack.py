"""durable-ack: no ack/epoch-flip without a preceding WAL append (§16, §15).

The crash-recovery guarantee (DESIGN.md §16) hangs on ONE ordering
discipline in ``runtime/ingest.py``: a round's WAL record is append-fsync
durable BEFORE the epoch flips (``self._publish(...)``) and BEFORE any
client ticket is acknowledged (``t.status = "applied"``). A refactor that
moves either site above the ``self._wal_commit(...)`` call reintroduces
acknowledged-batch loss — the exact bug class the WAL exists to kill —
and no test catches it deterministically unless the kill lands in the
reordered window. This rule makes the ordering structural: inside any
function that flips the epoch or acks a ticket, a ``_wal_commit`` call
must appear on an earlier line (straight-line dominance; the admission
loop is one basic block between these points).

Functions that neither publish nor ack are ignored, as are the
``_publish``/``_wal_commit`` definitions themselves. The recovery path
intentionally bypasses admission: it rebuilds pool slots directly
(``resume_pool``) and re-appends nothing, so it never trips this rule.
"""
from __future__ import annotations

import ast

from repro.analysis import astutil
from repro.analysis.framework import FileContext, Finding, Rule, register


def _wal_commit_lines(fn: ast.AST) -> list[int]:
    return [c.lineno for c in astutil.iter_calls(fn)
            if astutil.call_name(c).split(".")[-1] == "_wal_commit"]


def check(ctx: FileContext) -> list[Finding]:
    out: list[Finding] = []
    commit_lines: dict[ast.AST, list[int]] = {}

    def dominated(node: ast.AST) -> bool:
        fn = astutil.enclosing_function(node)
        if fn is None:
            return False
        if fn not in commit_lines:
            commit_lines[fn] = _wal_commit_lines(fn)
        return any(line < node.lineno for line in commit_lines[fn])

    for call in astutil.iter_calls(ctx.tree):
        if astutil.call_name(call).split(".")[-1] != "_publish":
            continue
        if not dominated(call):
            out.append(ctx.finding(
                RULE, call,
                "epoch flip (._publish) not dominated by a _wal_commit "
                "call — a kill -9 here loses the round after clients "
                "could observe it (DESIGN.md §16)"))
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Assign):
            continue
        if not (isinstance(node.value, ast.Constant)
                and node.value.value == "applied"):
            continue
        if not any(isinstance(t, ast.Attribute) and t.attr == "status"
                   for t in node.targets):
            continue
        if not dominated(node):
            out.append(ctx.finding(
                RULE, node,
                "ticket ack (.status = \"applied\") not dominated by a "
                "_wal_commit call — an acked batch must already be "
                "fsync-durable (DESIGN.md §16)"))
    return out


RULE = register(Rule(
    name="durable-ack",
    invariant="every epoch flip / ticket ack in runtime/ingest.py is "
              "dominated by a WAL append-fsync",
    check=check,
    origin="DESIGN.md §16 WAL ordering discipline",
    default_filter=lambda rel: rel == "src/repro/runtime/ingest.py",
))
