"""epoch-freshness: index label reads flow through freshness validation
(DESIGN.md §9, §13, §15).

The 2-hop label matrices are only meaningful at the epoch they were built
from; ``index/freshness.py`` owns the validation (live version-vector
compare, epoch-ring pinning, BFS fallback). A consumer that imports
``repro.index.query`` directly — or calls ``query_reach`` /
``reach_counts`` outside the index package — serves answers with no
staleness story at all: exactly the silent-stale-read class the
freshness layer exists to kill. Consumers use ``reach_session`` /
``reach_counts_session`` / ``index_fresh`` instead.
"""
from __future__ import annotations

import ast

from repro.analysis import astutil
from repro.analysis.framework import FileContext, Finding, Rule, register

# the raw-label surface only index/ itself may touch
RAW_CALLS = ("query_reach", "reach_counts")
RAW_MODULE = "repro.index.query"
INDEX_PKG = "src/repro/index/"


def check(ctx: FileContext) -> list[Finding]:
    if ctx.relpath.startswith(INDEX_PKG):
        return []
    out: list[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == RAW_MODULE or (mod == "repro.index"
                                     and any(a.name == "query"
                                             for a in node.names)):
                out.append(ctx.finding(
                    RULE, node,
                    f"direct import of {RAW_MODULE} outside the index "
                    f"package — label reads must flow through "
                    f"index/freshness.py (reach_session / "
                    f"reach_counts_session / index_fresh), which owns "
                    f"epoch validation (DESIGN.md §9)"))
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name == RAW_MODULE:
                    out.append(ctx.finding(
                        RULE, node,
                        f"direct import of {RAW_MODULE} outside the index "
                        f"package — use the freshness-validated sessions "
                        f"(DESIGN.md §9)"))
        elif isinstance(node, ast.Call):
            name = astutil.call_name(node).split(".")[-1]
            if name in RAW_CALLS:
                out.append(ctx.finding(
                    RULE, node,
                    f"{name}() called outside the index package — raw "
                    f"label joins skip epoch validation; route through "
                    f"index/freshness.py sessions (DESIGN.md §9)"))
    return out


RULE = register(Rule(
    name="epoch-freshness",
    invariant="index label reads outside src/repro/index/ go through "
              "freshness-validated sessions",
    check=check,
    origin="PR 3/PR 7 stale-index fallback design",
    default_filter=lambda rel: rel.startswith(("src/", "benchmarks/",
                                               "tools/")),
))
