"""metrics-doc: every declared metric is documented in DESIGN.md §14.

Absorbed from ``tools/check_metrics_doc.py`` (now a thin wrapper over
this rule). The metric surface is declared in exactly three places
(DESIGN.md §14): ``repro.obs.metrics.OBS_METRICS``,
``IngestStats._SPEC`` (``ingest.<field>``) and ``ServeStats._SPEC``
(``serve.<field>``); every qualified name must appear verbatim in the
§14 table so the doc can never silently drift from the code.

Unlike the AST rules this one IMPORTS the live modules (the specs are
data, not syntax) — which is also why it is repo-scoped and why the
pure comparison core (``missing_metrics``) is split out for the fixture
tests to exercise without the imports.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

from repro.analysis.framework import Finding, RepoContext, Rule, register

SECTION_RE = re.compile(r"^##\s+§14\b.*?(?=^##\s+§|\Z)", re.M | re.S)


def section_14(design_text: str) -> str:
    m = SECTION_RE.search(design_text)
    return m.group(0) if m else ""


def missing_metrics(names: list[str], design_text: str) -> list[str]:
    """Pure core: declared metric names absent from the §14 section text
    (all of them when the section itself is missing)."""
    sec = section_14(design_text)
    if not sec:
        return sorted(names)
    return sorted(n for n in names if n not in sec)


def declared_metrics(root: Path) -> list[str]:
    src = str(root / "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    from repro.obs.metrics import OBS_METRICS
    from repro.runtime.ingest import IngestStats
    from repro.runtime.serve_loop import ServeStats

    names = set(OBS_METRICS)
    for view in (IngestStats, ServeStats):
        names.update(view._qual(f) for f in view._SPEC)
    return sorted(names)


def check(ctx: RepoContext) -> list[Finding]:
    design = ctx.root / "DESIGN.md"
    if not design.is_file():
        return [ctx.finding(RULE, design, 0, "DESIGN.md does not exist")]
    text = design.read_text(encoding="utf-8")
    m = SECTION_RE.search(text)
    if not m:
        return [ctx.finding(RULE, design, 0,
                            "DESIGN.md has no `## §14` section — the "
                            "metric table lives there")]
    heading_line = text[:m.start()].count("\n") + 1
    try:
        names = declared_metrics(ctx.root)
    except Exception as e:  # import failure IS a finding, not a crash
        return [ctx.finding(RULE, design, 0,
                            f"could not import the metric specs: {e!r}")]
    return [ctx.finding(RULE, design, heading_line,
                        f"declared metric {n!r} missing from the "
                        f"DESIGN.md §14 table — document it or drop the "
                        f"declaration")
            for n in missing_metrics(names, text)]


RULE = register(Rule(
    name="metrics-doc",
    invariant="every metric declared by OBS_METRICS / IngestStats._SPEC / "
              "ServeStats._SPEC appears verbatim in DESIGN.md §14",
    check=check,
    scope="repo",
    origin="PR 8 obs metric registry (tools/check_metrics_doc.py)",
))
