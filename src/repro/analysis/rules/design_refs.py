"""design-refs: every ``DESIGN.md §N`` citation resolves (DESIGN.md §15).

Absorbed from ``tools/check_design_refs.py`` (now a thin wrapper over this
rule). Source docstrings cite the design document by section
(``DESIGN.md §4``, ``DESIGN.md §5(ii)``, ...); a citation of a section
that does not exist means either the code drifted or the doc did —
both are diff-time errors:

  * ``§N``      -> a ``## §N`` heading must exist;
  * ``§N(sub)`` -> a ``### §N(sub)`` heading, or ``## §N`` plus the
    literal ``§N(sub)`` anywhere in the doc.
"""
from __future__ import annotations

import re
from pathlib import Path
from typing import Optional

from repro.analysis.framework import FileContext, Finding, Rule, register

CITE = re.compile(r"DESIGN\.md\s+(§\d+(?:\([a-z]+\))?)")
HEADING = re.compile(r"^#{2,3}\s+(§\d+(?:\([a-z]+\))?)(?=[\s—-]|$)", re.M)

# per-root cache: root -> (headings, full text), or None when DESIGN.md is
# missing
_CACHE: dict[Path, Optional[tuple[set, str]]] = {}


def _design(root: Path) -> Optional[tuple[set, str]]:
    if root not in _CACHE:
        path = root / "DESIGN.md"
        if not path.is_file():
            _CACHE[root] = None
        else:
            text = path.read_text(encoding="utf-8")
            _CACHE[root] = (set(HEADING.findall(text)), text)
    return _CACHE[root]


def check(ctx: FileContext) -> list[Finding]:
    doc = _design(ctx.root)
    out: list[Finding] = []
    for lineno, line in enumerate(ctx.lines, 1):
        for ref in CITE.findall(line):
            if doc is None:
                out.append(ctx.finding(
                    RULE, lineno,
                    f"cites DESIGN.md {ref} but DESIGN.md does not exist"))
                continue
            headings, text = doc
            base = ref.split("(")[0]
            ok = ref in headings or (
                "(" in ref and base in headings and ref in text)
            if not ok:
                out.append(ctx.finding(
                    RULE, lineno,
                    f"cites DESIGN.md {ref} but no such section heading — "
                    f"the code or the doc drifted"))
    return out


RULE = register(Rule(
    name="design-refs",
    invariant="every DESIGN.md §N citation in the tree resolves to a real "
              "section heading",
    check=check,
    origin="PR 5 docs gate (tools/check_design_refs.py)",
    default_filter=lambda rel: rel.startswith(("src/", "benchmarks/",
                                               "tests/", "examples/")),
))
