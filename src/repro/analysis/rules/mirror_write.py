"""mirror-write: adjacency mirrors must be written together (DESIGN.md §11, §15).

``GraphState.adj_in_packed`` is maintained FIRST-CLASS — every mutation
path that writes ``adj_packed`` must mirror the write into
``adj_in_packed`` or the transpose invariant
(``core.graph.transpose_invariant``) silently breaks and every pull-phase
BFS and backward index closure reads garbage. PR 5 established the
invariant; this rule makes it un-regressable: any ``GraphState(...)``
construction or ``._replace(...)`` that names one packed-adjacency field
must name the other.

Positional ``GraphState(...)`` calls must either cover both trailing
fields (>= 6 positional args, or a *args splat) or pass both as
keywords. Constructions that touch NEITHER field (metadata-only
``_replace``) are fine — the mirrors move together or not at all.
"""
from __future__ import annotations

import ast

from repro.analysis import astutil
from repro.analysis.framework import FileContext, Finding, Rule, register

FIELDS = ("adj_packed", "adj_in_packed")
# positions of (adj_packed, adj_in_packed) in the GraphState NamedTuple
ADJ_POS, ADJ_IN_POS = 4, 5


def check(ctx: FileContext) -> list[Finding]:
    out: list[Finding] = []
    for call in astutil.iter_calls(ctx.tree):
        name = astutil.call_name(call).split(".")[-1]
        if name == "_replace":
            kws = astutil.keyword_names(call)
            for present, missing in ((FIELDS[0], FIELDS[1]),
                                     (FIELDS[1], FIELDS[0])):
                if present in kws and missing not in kws:
                    out.append(ctx.finding(
                        RULE, call,
                        f"._replace writes {present} without {missing} — "
                        f"mirrored adjacency updates must move together "
                        f"(transpose invariant, DESIGN.md §11)"))
        elif name in ("GraphState", "ShardedGraphState"):
            if any(isinstance(a, ast.Starred) for a in call.args):
                continue  # splat: the full field tuple is forwarded
            kws = astutil.keyword_names(call)
            # ShardedGraphState prepends a mesh argument before the fields
            off = 1 if name == "ShardedGraphState" else 0
            writes_adj = FIELDS[0] in kws or len(call.args) > ADJ_POS + off
            writes_in = FIELDS[1] in kws or len(call.args) > ADJ_IN_POS + off
            if writes_adj and not writes_in:
                out.append(ctx.finding(
                    RULE, call,
                    f"{name} constructed with {FIELDS[0]} but no "
                    f"{FIELDS[1]} — the in-adjacency mirror must be "
                    f"written by every mutation path (DESIGN.md §11)"))
    return out


RULE = register(Rule(
    name="mirror-write",
    invariant="every GraphState construction/_replace writing adj_packed "
              "also writes adj_in_packed",
    check=check,
    origin="PR 5 transpose invariant",
    default_filter=lambda rel: rel.startswith(("src/", "benchmarks/",
                                               "tools/")),
))
