"""lock-order: entity locks route through EntityLockTable (DESIGN.md §12, §15).

Admission is deadlock-free *by construction* only because every acquirer
orders its entity locks identically — ascending acquire, descending
release, all-or-nothing backout — and that discipline lives in exactly
one class, ``runtime.ingest.EntityLockTable``. A new bare
``.acquire()`` / ``.release()`` site (or a privately constructed
``threading.Lock`` pool) in the runtime layer reopens the wait-cycle
argument the proof closed, so every such site outside the table class is
flagged at diff time.

``with lock:`` blocks are exempt: context-managed guards cannot leak a
partial acquire and are how the table protects its own dict.
"""
from __future__ import annotations

import ast

from repro.analysis import astutil
from repro.analysis.framework import FileContext, Finding, Rule, register

TABLE_CLASS = "EntityLockTable"
_LOCK_METHODS = ("acquire", "release")


def _inside_table(node: ast.AST) -> bool:
    cls = astutil.enclosing_class(node)
    return cls is not None and cls.name == TABLE_CLASS


def check(ctx: FileContext) -> list[Finding]:
    out: list[Finding] = []
    for call in astutil.iter_calls(ctx.tree):
        if not isinstance(call.func, ast.Attribute):
            continue
        meth = call.func.attr
        if meth in _LOCK_METHODS and not _inside_table(call):
            out.append(ctx.finding(
                RULE, call,
                f"bare .{meth}() outside {TABLE_CLASS} — entity locks "
                f"must go through the table's sorted ascending-acquire/"
                f"descending-release discipline (deadlock-freedom proof, "
                f"DESIGN.md §12)"))
        elif meth == "Lock" and astutil.dotted(call.func).startswith(
                "threading") and not _inside_table(call):
            out.append(ctx.finding(
                RULE, call,
                f"threading.Lock() constructed outside {TABLE_CLASS} — "
                f"new lock pools bypass the sorted-entity discipline "
                f"(DESIGN.md §12); add the lock to the table or justify "
                f"with an inline allow"))
    return out


RULE = register(Rule(
    name="lock-order",
    invariant="entity-lock acquire/release sites live only inside "
              "EntityLockTable's sorted discipline",
    check=check,
    origin="PR 6 admission deadlock-freedom proof",
    default_filter=lambda rel: rel.startswith("src/repro/runtime/"),
))
