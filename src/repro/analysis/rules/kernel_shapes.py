"""kernel-shape: Pallas kernel packages carry checkable metadata
(DESIGN.md §10, §15).

Every kernel package (a directory with ``kernel.py`` + ``ops.py``) must
ship a ``meta.py`` whose module-level ``KERNEL_META`` is a PURE LITERAL
describing the package's kernels — tile defaults, block shapes, dtypes,
divisibility guards, packed padding strategy and a static VMEM budget.
The sanitizer cross-checks that declaration against the actual source,
so the metadata cannot drift from the code it describes:

  * ``tiles`` must match the kernel wrapper's keyword-only defaults
    (tile-default drift is how a "tuning" commit silently changes the
    divisibility contract every caller pads against);
  * ``tiles % align == 0`` — sublane/lane alignment for the backend;
  * every ``divides`` entry must be enforced by an ``assert`` in the
    wrapper mentioning ``<dim> % <tile>`` (the grid is only total when
    the operand extent divides by the block);
  * declared output dtypes must agree with the wrapper's
    ``jax.ShapeDtypeStruct`` list (``"*"`` = dtype passthrough);
  * the oracle named by ``ref`` must exist in ``ref.py`` with the same
    positional arity as the wrapper (contract drift: an operand added to
    the kernel but not the oracle);
  * ``packed`` kernels must declare how uint32 padding bits stay safe:
    ``pad_safety: "slice"`` (the named ops.py wrapper depads with a
    bounded slice) or ``"mask"`` (the kernel body writes single-bit
    masks built by shifting, never whole padded words);
  * the static VMEM footprint — sum of resolved block sizes times dtype
    width, plus ``scratch_bytes`` — must fit ``vmem_budget_bytes``.

``KERNEL_META`` schema (all sizes plain int literals — ``ast.literal_eval``
is the parser, so no ``16 * 2**20`` arithmetic)::

    KERNEL_META = {
        "package": "bfs_step",
        "vmem_budget_bytes": {"tpu": 16777216},
        "dims": {"q": 64},            # assumed sizes of non-tile block dims
        "kernels": {
            "bfs_step_pallas": {
                "tiles": {"tr": 256, "tc": 256},
                "align": {"tr": 8, "tc": 128},
                "divides": {"v": ["tr", "tc"]},
                "operands": {"frontier": {"block": ["tr"],
                                          "dtype": "float32"}, ...},
                "outputs": {"new": {"block": ["tc"], "dtype": "int32"}, ...},
                "packed": False,
                "pad_safety": None,   # "slice" | "mask" for packed kernels
                "wrapper": "bfs_step",  # ops.py depad entry (pad_safety=slice)
                "ref": "bfs_step_ref",
                "scratch_bytes": 0,
            },
        },
    }
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Optional

from repro.analysis import astutil
from repro.analysis.framework import Finding, RepoContext, Rule, register

DTYPE_BYTES = {
    "int8": 1, "uint8": 1, "bool": 1, "bool_": 1,
    "int16": 2, "uint16": 2, "bfloat16": 2, "float16": 2,
    "int32": 4, "uint32": 4, "float32": 4,
    "int64": 8, "uint64": 8, "float64": 8,
    "*": 4,  # dtype passthrough: budget conservatively as a 4-byte word
}
PAD_SAFETY = ("slice", "mask")
_TOP_KEYS = ("package", "vmem_budget_bytes", "kernels")


def _parse(path: Path) -> Optional[ast.Module]:
    try:
        return ast.parse(path.read_text(encoding="utf-8"),
                         filename=str(path))
    except (OSError, SyntaxError):
        return None


def _fn_defs(tree: ast.Module) -> dict[str, ast.FunctionDef]:
    return {n.name: n for n in tree.body if isinstance(n, ast.FunctionDef)}


def _kwonly_defaults(fn: ast.FunctionDef) -> dict[str, object]:
    """{kwonly arg name: literal default} (non-constant defaults omitted)."""
    out: dict[str, object] = {}
    for arg, default in zip(fn.args.kwonlyargs, fn.args.kw_defaults):
        if isinstance(default, ast.Constant):
            out[arg.arg] = default.value
    return out


def _shape_struct_dtypes(fn: ast.FunctionDef) -> list[str]:
    """Dtype names of the wrapper's ShapeDtypeStruct outputs, in source
    order. ``jnp.int32`` -> "int32"; an ``x.dtype`` passthrough -> "*"."""
    out: list[str] = []
    for call in astutil.iter_calls(fn):
        if astutil.call_name(call).split(".")[-1] != "ShapeDtypeStruct":
            continue
        if len(call.args) < 2:
            out.append("?")
            continue
        d = call.args[1]
        name = astutil.dotted(d)
        if name.endswith(".dtype"):
            out.append("*")
        elif name:
            out.append(name.split(".")[-1])
        else:
            out.append("?")
    return out


def _has_bounded_slice(fn: ast.FunctionDef) -> bool:
    """True when the function subscripts with a Slice whose upper bound is
    set — the ``out[:q]`` / ``.at[:v].set`` depad idiom."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Slice) and node.upper is not None:
            return True
    return False


def _has_shift(tree: ast.AST) -> bool:
    return any(isinstance(n, ast.BinOp) and isinstance(n.op, ast.LShift)
               for n in ast.walk(tree))


def _assert_sources(fn: ast.FunctionDef) -> list[str]:
    return [ast.unparse(n.test) for n in ast.walk(fn)
            if isinstance(n, ast.Assert)]


class _Pkg:
    """One kernel package directory's parsed members."""

    def __init__(self, ctx: RepoContext, directory: Path,
                 members: dict[str, Path]):
        self.ctx = ctx
        self.dir = directory
        self.members = members          # filename -> Path
        self.findings: list[Finding] = []

    def flag(self, filename: str, line: int, msg: str) -> None:
        self.findings.append(self.ctx.finding(
            RULE, self.members.get(filename, self.dir / filename), line, msg))

    # -- schema --------------------------------------------------------------
    def load_meta(self) -> Optional[dict]:
        if "meta.py" not in self.members:
            self.flag("kernel.py", 0,
                      "kernel package has no meta.py — declare KERNEL_META "
                      "(tiles, blocks, dtypes, VMEM budget) so the shape "
                      "sanitizer can gate drift (DESIGN.md §15)")
            return None
        tree = _parse(self.members["meta.py"])
        if tree is None:
            self.flag("meta.py", 0, "meta.py unreadable or syntactically "
                                    "invalid")
            return None
        meta, node = astutil.literal_assignment(tree, "KERNEL_META")
        if node is None:
            self.flag("meta.py", 0, "meta.py defines no KERNEL_META")
            return None
        if meta is None:
            self.flag("meta.py", node.lineno,
                      "KERNEL_META must be a pure literal (plain ints, no "
                      "arithmetic or names) — ast.literal_eval is the parser")
            return None
        if not isinstance(meta, dict) or not all(k in meta for k in _TOP_KEYS):
            self.flag("meta.py", node.lineno,
                      f"KERNEL_META missing required keys {_TOP_KEYS}")
            return None
        budget = meta["vmem_budget_bytes"]
        if (not isinstance(budget, dict) or not budget
                or not all(isinstance(v, int) and v > 0
                           for v in budget.values())):
            self.flag("meta.py", node.lineno,
                      "vmem_budget_bytes must map backend -> positive int "
                      "bytes")
            return None
        if not isinstance(meta["kernels"], dict) or not meta["kernels"]:
            self.flag("meta.py", node.lineno,
                      "KERNEL_META['kernels'] must be a non-empty dict")
            return None
        return meta

    # -- per-kernel checks ---------------------------------------------------
    def check_kernel(self, meta: dict, name: str, entry: dict,
                     kernel_tree: ast.Module,
                     kernel_fns: dict[str, ast.FunctionDef],
                     ops_fns: dict[str, ast.FunctionDef],
                     ref_fns: dict[str, ast.FunctionDef]) -> None:
        fn = kernel_fns.get(name)
        if fn is None:
            self.flag("meta.py", 0,
                      f"KERNEL_META declares {name} but kernel.py defines "
                      f"no such function")
            return
        tiles = entry.get("tiles", {})
        align = entry.get("align", {})
        dims = dict(meta.get("dims", {}))

        # tile-default drift vs the wrapper's keyword-only defaults
        defaults = _kwonly_defaults(fn)
        for t, val in tiles.items():
            if t not in defaults:
                self.flag("kernel.py", fn.lineno,
                          f"{name}: declared tile {t!r} is not a "
                          f"keyword-only arg with a literal default")
            elif defaults[t] != val:
                self.flag("kernel.py", fn.lineno,
                          f"{name}: tile default drift — meta.py says "
                          f"{t}={val}, kernel.py says {t}={defaults[t]}; "
                          f"update KERNEL_META with the retuned value")

        # alignment: tiles must honor the declared sublane/lane multiples
        for t, val in tiles.items():
            if not isinstance(val, int) or val <= 0:
                self.flag("meta.py", 0, f"{name}: tile {t}={val!r} must be "
                                        f"a positive int")
                continue
            a = align.get(t)
            if isinstance(a, int) and a > 0 and val % a != 0:
                self.flag("meta.py", 0,
                          f"{name}: tile {t}={val} violates its declared "
                          f"alignment {a} ({val} % {a} != 0)")

        # divisibility guards: each declared dim % tile must be asserted
        asserts = " ; ".join(_assert_sources(fn))
        for dim, guarded in entry.get("divides", {}).items():
            for t in guarded:
                if f"{dim} % {t}" not in asserts:
                    self.flag("kernel.py", fn.lineno,
                              f"{name}: KERNEL_META declares the grid "
                              f"needs {dim} % {t} == 0 but no assert in "
                              f"the wrapper enforces it — a ragged last "
                              f"block would read out of bounds")

        # output dtype agreement with the wrapper's ShapeDtypeStruct list
        declared = [(k, v.get("dtype", "?"))
                    for k, v in entry.get("outputs", {}).items()]
        actual = _shape_struct_dtypes(fn)
        if len(declared) != len(actual):
            self.flag("kernel.py", fn.lineno,
                      f"{name}: KERNEL_META declares {len(declared)} "
                      f"outputs, kernel.py builds {len(actual)} "
                      f"ShapeDtypeStruct out_shapes")
        else:
            for (oname, want), got in zip(declared, actual):
                if want != got and "*" not in (want, got):
                    self.flag("kernel.py", fn.lineno,
                              f"{name}: output {oname!r} dtype drift — "
                              f"meta.py says {want}, kernel.py's "
                              f"ShapeDtypeStruct says {got}")

        # oracle: must exist in ref.py with the wrapper's positional arity
        ref_name = entry.get("ref")
        if ref_name:
            ref = ref_fns.get(ref_name)
            if ref is None:
                self.flag("ref.py", 0,
                          f"{name}: declared oracle {ref_name}() not found "
                          f"in ref.py — every kernel ships a pure-jnp "
                          f"oracle (DESIGN.md §10)")
            elif len(ref.args.args) != len(fn.args.args):
                self.flag("ref.py", ref.lineno,
                          f"{ref_name}() takes {len(ref.args.args)} "
                          f"positional operands but {name} takes "
                          f"{len(fn.args.args)} — kernel/oracle contract "
                          f"drift")

        # packed padding-bit safety
        if entry.get("packed"):
            safety = entry.get("pad_safety")
            if safety not in PAD_SAFETY:
                self.flag("meta.py", 0,
                          f"{name}: packed kernel must declare pad_safety "
                          f"in {PAD_SAFETY} — uint32 padding bits need an "
                          f"explicit story")
            elif safety == "slice":
                wrapper = ops_fns.get(entry.get("wrapper", ""))
                if wrapper is None:
                    self.flag("ops.py", 0,
                              f"{name}: pad_safety='slice' names ops.py "
                              f"wrapper {entry.get('wrapper')!r}, which "
                              f"does not exist")
                elif not _has_bounded_slice(wrapper):
                    self.flag("ops.py", wrapper.lineno,
                              f"{entry.get('wrapper')}(): pad_safety="
                              f"'slice' but no bounded slice ([:v]-style "
                              f"depad) found — padded lanes would leak to "
                              f"callers")
            elif safety == "mask" and not _has_shift(kernel_tree):
                # the shift lives in the private kernel body, so scan the
                # whole module, not just the wrapper
                self.flag("kernel.py", fn.lineno,
                          f"{name}: pad_safety='mask' but kernel.py "
                          f"builds no shifted bit masks (<<) — whole-word "
                          f"writes would clobber padding bits")

        # static VMEM footprint vs the per-backend budget
        total = entry.get("scratch_bytes", 0)
        bad_dim = False
        for group in ("operands", "outputs"):
            for oname, spec in entry.get(group, {}).items():
                width = DTYPE_BYTES.get(spec.get("dtype", "?"))
                if width is None:
                    self.flag("meta.py", 0,
                              f"{name}: {oname!r} has unknown dtype "
                              f"{spec.get('dtype')!r}")
                    bad_dim = True
                    continue
                n = 1
                for d in spec.get("block", []):
                    size = d if isinstance(d, int) else tiles.get(
                        d, dims.get(d))
                    if not isinstance(size, int):
                        self.flag("meta.py", 0,
                                  f"{name}: block dim {d!r} of {oname!r} "
                                  f"is neither a tile nor in "
                                  f"KERNEL_META['dims']")
                        bad_dim = True
                        size = 1
                    n *= size
                total += n * width
        if not bad_dim:
            backend, budget = min(meta["vmem_budget_bytes"].items(),
                                  key=lambda kv: kv[1])
            if total > budget:
                self.flag("meta.py", 0,
                          f"{name}: static VMEM footprint {total} bytes "
                          f"exceeds the {backend} budget {budget} — "
                          f"shrink the tiles or raise the budget with a "
                          f"justification")


def check(ctx: RepoContext) -> list[Finding]:
    # group scanned files into kernel-package directories
    dirs: dict[Path, dict[str, Path]] = {}
    for p in ctx.files:
        if p.name in ("kernel.py", "ops.py", "ref.py", "meta.py"):
            dirs.setdefault(p.parent, {})[p.name] = p
    out: list[Finding] = []
    for directory in sorted(dirs):
        members = dirs[directory]
        if "kernel.py" not in members or "ops.py" not in members:
            continue  # not a kernel package (e.g. a lone helper file)
        pkg = _Pkg(ctx, directory, members)
        meta = pkg.load_meta()
        if meta is not None:
            ktree = _parse(members["kernel.py"])
            otree = _parse(members["ops.py"])
            rtree = _parse(members["ref.py"]) if "ref.py" in members else None
            if ktree is None:
                pkg.flag("kernel.py", 0, "kernel.py unparseable")
            else:
                kernel_fns = _fn_defs(ktree)
                ops_fns = _fn_defs(otree) if otree else {}
                ref_fns = _fn_defs(rtree) if rtree else {}
                for name, entry in meta["kernels"].items():
                    if not isinstance(entry, dict):
                        pkg.flag("meta.py", 0,
                                 f"kernel entry {name!r} must be a dict")
                        continue
                    pkg.check_kernel(meta, name, entry, ktree, kernel_fns,
                                     ops_fns, ref_fns)
        out.extend(pkg.findings)
    return out


RULE = register(Rule(
    name="kernel-shape",
    invariant="every kernel package's KERNEL_META agrees with its "
              "kernel.py/ops.py/ref.py: tile defaults, divisibility "
              "guards, output dtypes, packed padding safety and the "
              "static VMEM budget",
    check=check,
    scope="repo",
    origin="PR 2/PR 4 Pallas tiling contracts",
    default_filter=lambda rel: rel.startswith("src/repro/kernels/"),
))
