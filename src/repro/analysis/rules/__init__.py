"""Rule registry population: importing this package registers every rule
(DESIGN.md §15). Add new rules by creating a module here that calls
``framework.register`` at import time and listing it below."""
from repro.analysis.rules import (  # noqa: F401  (registration side effects)
    design_refs,
    durable_ack,
    epoch_freshness,
    kernel_shapes,
    lock_order,
    metrics_doc,
    mirror_write,
    trace_purity,
    traversable,
)

__all__ = [
    "design_refs",
    "durable_ack",
    "epoch_freshness",
    "kernel_shapes",
    "lock_order",
    "metrics_doc",
    "mirror_write",
    "trace_purity",
    "traversable",
]
