"""Rule registry, suppression and baseline plumbing for ``repro.analysis``.

The repo's correctness argument rests on hand-maintained structural
invariants (mirrored adjacency writes, the single ``traversable``
predicate, sorted entity-lock discipline, epoch-validated index reads,
...). Each invariant is encoded here as a *rule* — a pure function from
parsed source to findings — so violations are caught at diff time instead
of waiting for a property test to hit the bad interleaving
(DESIGN.md §15).

Vocabulary:

  * A **rule** has a unique kebab-case name, a severity, a one-line
    invariant statement, and a ``check`` callback. File-scoped rules run
    once per scanned file (``FileContext``); repo-scoped rules run once
    per analysis (``RepoContext``) and walk whatever they need.
  * A **finding** is (rule, path, line, message). Findings are what the
    CLI prints, ``--json`` serializes, and CI gates on.
  * An inline ``repro-lint: allow(rule-a, rule-b)`` comment (written
    after a ``#``) — on the offending line or the line directly above
    it — suppresses matching
    findings. Suppressions that silence nothing are themselves reported
    (rule name ``unused-suppression``) so dead allows cannot accumulate.
  * The committed **baseline** (``analysis_baseline.json``) grandfathers
    pre-existing, justified findings; see ``baseline.py``.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import TYPE_CHECKING, Callable, Iterable, Optional, Union

if TYPE_CHECKING:  # pragma: no cover - import cycle is type-only
    from repro.analysis.baseline import Baseline

SEVERITIES = ("error", "warning")

# Inline suppression syntax. Intentionally strict: exactly this spelling,
# so grep finds every allow in the tree.
ALLOW_RE = re.compile(r"#\s*repro-lint:\s*allow\(([a-z0-9_,\-\s]+)\)")

# Paths never scanned by the default walk: deliberate-violation fixtures.
GLOBAL_EXCLUDES = ("tests/lint_fixtures",)

# Default scan roots, relative to the analysis root (usually the repo).
DEFAULT_ROOTS = ("src", "tools", "benchmarks", "examples", "tests")

UNUSED_SUPPRESSION = "unused-suppression"


@dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a file and line."""

    rule: str
    path: str          # repo-relative, forward slashes
    line: int          # 1-indexed; 0 = whole-file finding
    message: str
    severity: str = "error"

    def key(self) -> tuple:
        return (self.rule, self.path, self.line, self.message)

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message, "severity": self.severity}

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.rule}] {self.message}"


class FileContext:
    """Everything a file-scoped rule may look at for one source file."""

    def __init__(self, root: Path, path: Path, source: str):
        self.root = root
        self.path = path
        self.relpath = _rel(root, path)
        self.source = source
        self.lines = source.splitlines()
        self._tree: Optional[ast.AST] = None
        self._parse_error: Optional[SyntaxError] = None

    @property
    def tree(self) -> Optional[ast.Module]:
        """Parsed AST with parent links, or None on a syntax error (the
        runner reports unparseable files once, as a framework finding)."""
        if self._tree is None and self._parse_error is None:
            try:
                tree = ast.parse(self.source, filename=str(self.path))
            except SyntaxError as e:  # pragma: no cover - defensive
                self._parse_error = e
                return None
            for node in ast.walk(tree):
                for child in ast.iter_child_nodes(node):
                    child._repro_parent = node  # type: ignore[attr-defined]
            self._tree = tree
        return self._tree  # type: ignore[return-value]

    def finding(self, rule: "Rule", node_or_line, message: str) -> Finding:
        line = getattr(node_or_line, "lineno", node_or_line)
        return Finding(rule.name, self.relpath, int(line), message,
                       rule.severity)


class RepoContext:
    """What a repo-scoped rule sees: the root and the scanned file set."""

    def __init__(self, root: Path, files: list[Path]):
        self.root = root
        self.files = files

    def rel(self, path: Path) -> str:
        return _rel(self.root, path)

    def finding(self, rule: "Rule", path: Path, line: int,
                message: str) -> Finding:
        return Finding(rule.name, _rel(self.root, path), int(line), message,
                       rule.severity)


CheckFn = Callable[[Union[FileContext, RepoContext]], Iterable[Finding]]


@dataclass
class Rule:
    """A registered invariant check (DESIGN.md §15).

    ``default_filter`` restricts which files the rule sees during a
    DEFAULT root walk (repo gate); files passed explicitly to ``run`` are
    always offered to every file-scoped rule, so fixtures under tests/
    can exercise rules whose default scope excludes tests.
    """

    name: str
    invariant: str                      # one-line statement of the invariant
    check: CheckFn
    scope: str = "file"                 # "file" | "repo"
    severity: str = "error"
    origin: str = ""                    # PR / bug class that motivated it
    default_filter: Callable[[str], bool] = lambda rel: True

    def __post_init__(self):
        assert self.scope in ("file", "repo"), self.scope
        assert self.severity in SEVERITIES, self.severity


_REGISTRY: dict[str, Rule] = {}


def register(rule: Rule) -> Rule:
    """Add a rule to the global registry (import-time side effect of the
    ``repro.analysis.rules`` package)."""
    if rule.name in _REGISTRY:
        raise ValueError(f"duplicate rule name {rule.name!r}")
    _REGISTRY[rule.name] = rule
    return rule


def all_rules() -> list[Rule]:
    """Registered rules, sorted by name (imports the rules package once)."""
    import repro.analysis.rules  # noqa: F401  (registration side effect)

    return [(_REGISTRY[k]) for k in sorted(_REGISTRY)]


def get_rule(name: str) -> Rule:
    import repro.analysis.rules  # noqa: F401

    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown rule {name!r} (known: {known})") from None


# ----------------------------------------------------------------------------
# Suppressions
# ----------------------------------------------------------------------------
class Suppressions:
    """Parsed ``# repro-lint: allow(...)`` comments of one file.

    An allow on line N silences findings of the named rules on line N and
    line N+1 (i.e. the comment sits on the offending line or directly
    above it). ``unused`` reports allows that silenced nothing.
    """

    def __init__(self, source: str):
        # line -> set of rule names allowed there
        self.allows: dict[int, set[str]] = {}
        self._used: dict[int, set[str]] = {}
        for i, text in enumerate(source.splitlines(), 1):
            m = ALLOW_RE.search(text)
            if m:
                names = {n.strip() for n in m.group(1).split(",") if n.strip()}
                self.allows[i] = names

    def suppresses(self, finding: Finding) -> bool:
        for line in (finding.line, finding.line - 1):
            names = self.allows.get(line)
            if names and finding.rule in names:
                self._used.setdefault(line, set()).add(finding.rule)
                return True
        return False

    def unused(self, relpath: str,
               active: Optional[set] = None) -> list[Finding]:
        """Allows that silenced nothing. ``active`` restricts the check to
        the rules that actually ran — a single-rule run must not call every
        other rule's allows dead."""
        out = []
        for line, names in sorted(self.allows.items()):
            dead = names - self._used.get(line, set())
            if active is not None:
                dead &= active
            for name in sorted(dead):
                out.append(Finding(
                    UNUSED_SUPPRESSION, relpath, line,
                    f"allow({name}) suppresses nothing — remove it",
                    "error"))
        return out


# ----------------------------------------------------------------------------
# Running
# ----------------------------------------------------------------------------
@dataclass
class AnalysisResult:
    """Everything one analysis run produced, pre-gating."""

    findings: list[Finding] = field(default_factory=list)       # live
    suppressed: list[Finding] = field(default_factory=list)     # via allow()
    baselined: list[Finding] = field(default_factory=list)      # via baseline
    files_scanned: int = 0
    rules_run: list[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_json(self) -> dict:
        return {
            "ok": self.ok,
            "files_scanned": self.files_scanned,
            "rules": self.rules_run,
            "findings": [f.to_json() for f in self.findings],
            "suppressed": [f.to_json() for f in self.suppressed],
            "baselined": [f.to_json() for f in self.baselined],
        }


def _rel(root: Path, path: Path) -> str:
    try:
        return path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        return path.as_posix()


def default_files(root: Path) -> list[Path]:
    """The default scan set: every .py under the scan roots, minus the
    deliberate-violation fixtures."""
    out: list[Path] = []
    for d in DEFAULT_ROOTS:
        base = root / d
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.py")):
            rel = _rel(root, p)
            if any(rel.startswith(x) for x in GLOBAL_EXCLUDES):
                continue
            out.append(p)
    return out


def run(root: Path, paths: Optional[list[Path]] = None,
        rules: Optional[list[Rule]] = None,
        baseline: Optional["Baseline"] = None) -> AnalysisResult:
    """Run ``rules`` (default: all registered) over ``paths`` (default:
    the standard root walk) and fold in suppressions and the baseline.

    Explicit ``paths`` bypass the per-rule default filters — that is how
    the fixture tests point one rule at one deliberately-bad file.
    """
    root = Path(root)
    explicit = paths is not None
    files = [Path(p) for p in paths] if explicit else default_files(root)
    rules = list(rules) if rules is not None else all_rules()

    result = AnalysisResult(files_scanned=len(files),
                            rules_run=[r.name for r in rules])
    raw: list[Finding] = []
    contexts: dict[str, FileContext] = {}

    file_rules = [r for r in rules if r.scope == "file"]
    repo_rules = [r for r in rules if r.scope == "repo"]

    for path in files:
        try:
            source = path.read_text(encoding="utf-8")
        except OSError as e:
            raw.append(Finding("framework", _rel(root, path), 0,
                               f"unreadable: {e}"))
            continue
        ctx = FileContext(root, path, source)
        contexts[ctx.relpath] = ctx
        if path.suffix != ".py":
            continue
        if ctx.tree is None:
            raw.append(Finding("framework", ctx.relpath, 0,
                               "syntax error — file not analyzable"))
            continue
        for rule in file_rules:
            if not explicit and not rule.default_filter(ctx.relpath):
                continue
            raw.extend(rule.check(ctx))

    repo_ctx = RepoContext(root, files)
    for rule in repo_rules:
        raw.extend(rule.check(repo_ctx))

    # de-dup (a rule revisiting a node must not double-report), stable order
    seen: set[tuple] = set()
    ordered: list[Finding] = []
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.rule, f.message)):
        if f.key() not in seen:
            seen.add(f.key())
            ordered.append(f)

    # suppressions: parsed per file that actually has findings
    supp_cache: dict[str, Suppressions] = {}
    live: list[Finding] = []
    for f in ordered:
        supp = supp_cache.get(f.path)
        if supp is None:
            ctx = contexts.get(f.path)
            if ctx is None:
                fpath = root / f.path
                try:
                    src = fpath.read_text(encoding="utf-8")
                except OSError:
                    src = ""
            else:
                src = ctx.source
            supp = supp_cache[f.path] = Suppressions(src)
        if supp.suppresses(f):
            result.suppressed.append(f)
        else:
            live.append(f)

    # dead allows: checked for every SCANNED file (not only files with
    # findings), so a stale allow() cannot hide forever
    active = {r.name for r in rules}
    for relpath, ctx in contexts.items():
        supp = supp_cache.get(relpath) or Suppressions(ctx.source)
        live.extend(supp.unused(relpath, active))

    if baseline is not None:
        live, grandfathered, stale = baseline.apply(live, active)
        result.baselined = grandfathered
        live.extend(stale)

    result.findings = sorted(live, key=lambda f: (f.path, f.line, f.rule))
    return result
