#!/usr/bin/env python3
"""Offline viewer for repro trace files (DESIGN.md §14).

The observability recorder (``repro.obs.trace``, armed by ``REPRO_TRACE=1``)
writes Chrome trace-event JSON that https://ui.perfetto.dev loads directly.
This tool reads the same file without a browser:

  python tools/trace_view.py repro_trace.json               # dump events
  python tools/trace_view.py --summarize repro_trace.json   # per-span table

``--summarize`` prints one row per span name — count, total/mean/max wall —
plus counter series and the tag breakdown of ``bfs.superstep`` directions;
the obs-tests CI step round-trips a recorded trace through it to keep the
export format honest.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter, defaultdict


def load(path: str) -> list[dict]:
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    if not isinstance(events, list):
        raise ValueError(f"{path}: no traceEvents array")
    return events


def summarize(events: list[dict]) -> dict:
    """Aggregate a trace into {spans, counters, directions} (all plain
    dicts — the shape tests/test_obs.py asserts on)."""
    spans: dict[str, dict] = defaultdict(
        lambda: {"count": 0, "total_us": 0.0, "max_us": 0.0})
    counters: dict[str, int] = Counter()
    directions: dict[str, int] = Counter()
    for ev in events:
        ph = ev.get("ph")
        if ph == "X":
            s = spans[ev["name"]]
            dur = float(ev.get("dur", 0.0))
            s["count"] += 1
            s["total_us"] += dur
            s["max_us"] = max(s["max_us"], dur)
            if ev["name"] == "bfs.superstep":
                d = ev.get("args", {}).get("direction")
                if d is not None:
                    directions[d] += 1
        elif ph == "C":
            counters[ev["name"]] += 1
    return {"spans": dict(spans), "counters": dict(counters),
            "directions": dict(directions)}


def print_summary(summary: dict, out=sys.stdout) -> None:
    spans = summary["spans"]
    if not spans:
        print("(no spans)", file=out)
        return
    w = max(len(n) for n in spans) + 2
    print(f"{'span':<{w}}{'count':>7}{'total ms':>12}{'mean ms':>10}"
          f"{'max ms':>10}", file=out)
    for name in sorted(spans, key=lambda n: -spans[n]["total_us"]):
        s = spans[name]
        tot, mx = s["total_us"] / 1e3, s["max_us"] / 1e3
        print(f"{name:<{w}}{s['count']:>7}{tot:>12.3f}"
              f"{tot / s['count']:>10.3f}{mx:>10.3f}", file=out)
    if summary["directions"]:
        tags = ", ".join(f"{k}={v}" for k, v in
                         sorted(summary["directions"].items()))
        print(f"\nbfs.superstep directions: {tags}", file=out)
    if summary["counters"]:
        tags = ", ".join(f"{k} x{v}" for k, v in
                         sorted(summary["counters"].items()))
        print(f"counter series: {tags}", file=out)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", help="trace JSON written by repro.obs.trace")
    ap.add_argument("--summarize", action="store_true",
                    help="per-span aggregate table instead of an event dump")
    args = ap.parse_args()
    events = load(args.path)
    if args.summarize:
        print_summary(summarize(events))
        return 0
    for ev in events:
        ts = ev.get("ts", 0.0) / 1e3
        if ev.get("ph") == "X":
            print(f"{ts:12.3f}ms +{ev.get('dur', 0.0) / 1e3:.3f}ms "
                  f"{ev['name']} {ev.get('args', '')}")
        else:
            print(f"{ts:12.3f}ms {ev.get('ph')} {ev['name']} "
                  f"{ev.get('args', '')}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
