#!/usr/bin/env python3
"""Fail if any declared metric is missing from DESIGN.md §14's table.

The metric surface is declared in exactly three places (DESIGN.md §14):

  * ``repro.obs.metrics.OBS_METRICS`` — the tracing-only global registry;
  * ``IngestStats._SPEC`` — the admission view (``ingest.<field>``);
  * ``ServeStats._SPEC``  — the per-serve view (``serve.<field>``).

Every qualified name must appear verbatim in DESIGN.md §14 so the doc's
metric table can never silently drift from the code. Run from the repo
root (the obs-tests CI step does): python tools/check_metrics_doc.py
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def declared_metrics() -> list[str]:
    sys.path.insert(0, str(ROOT / "src"))
    from repro.obs.metrics import OBS_METRICS
    from repro.runtime.ingest import IngestStats
    from repro.runtime.serve_loop import ServeStats

    names = set(OBS_METRICS)
    for view in (IngestStats, ServeStats):
        names.update(view._qual(f) for f in view._SPEC)
    return sorted(names)


def section_14(text: str) -> str:
    m = re.search(r"^##\s+§14\b.*?(?=^##\s+§|\Z)", text, re.M | re.S)
    return m.group(0) if m else ""

def main() -> int:
    design = (ROOT / "DESIGN.md").read_text(encoding="utf-8")
    sec = section_14(design)
    if not sec:
        print("check_metrics_doc: DESIGN.md has no `## §14` section",
              file=sys.stderr)
        return 1
    missing = [n for n in declared_metrics() if n not in sec]
    if missing:
        print("check_metrics_doc: metrics missing from DESIGN.md §14:",
              file=sys.stderr)
        for n in missing:
            print(f"  {n}", file=sys.stderr)
        return 1
    print(f"check_metrics_doc: {len(declared_metrics())} metrics all "
          "documented in DESIGN.md §14")
    return 0


if __name__ == "__main__":
    sys.exit(main())
