#!/usr/bin/env python3
"""Fail if any declared metric is missing from DESIGN.md §14's table.

Thin wrapper kept for the old CLI entry point: the check itself is the
``metrics-doc`` rule of ``repro.analysis`` (DESIGN.md §15) and normally
runs inside ``python -m repro.analysis`` — the static-analysis CI gate.

Run from the repo root: python tools/check_metrics_doc.py
"""
from __future__ import annotations

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

# old in-process API, kept for callers of the original tool
from repro.analysis.rules.metrics_doc import (  # noqa: E402
    missing_metrics,
    section_14,
)


def declared_metrics(root: pathlib.Path = ROOT) -> list[str]:
    from repro.analysis.rules.metrics_doc import declared_metrics as impl
    return impl(root)


def main() -> int:
    from repro.analysis import framework, get_rule

    rule = get_rule("metrics-doc")
    result = framework.run(ROOT, rules=[rule])
    for f in result.findings:
        print(f.render(), file=sys.stderr)
    if result.findings:
        return 1
    print(f"check_metrics_doc: {len(declared_metrics(ROOT))} metrics all "
          "documented in DESIGN.md §14 (via repro.analysis metrics-doc)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
