#!/usr/bin/env python3
"""Fail if any `DESIGN.md §N` citation points at a missing section.

Thin wrapper kept for the old CLI entry point: the check itself is the
``design-refs`` rule of ``repro.analysis`` (DESIGN.md §15) and normally
runs inside ``python -m repro.analysis`` — the static-analysis CI gate.

Run from the repo root: python tools/check_design_refs.py
"""
from __future__ import annotations

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))


def main() -> int:
    from repro.analysis import framework, get_rule

    rule = get_rule("design-refs")
    result = framework.run(ROOT, rules=[rule])
    for f in result.findings:
        print(f.render(), file=sys.stderr)
    if result.findings:
        return 1
    print(f"check_design_refs: {result.files_scanned} files scanned — "
          f"all citations resolve (via repro.analysis design-refs)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
