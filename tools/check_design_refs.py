#!/usr/bin/env python3
"""Fail if any `DESIGN.md §N` citation points at a missing section.

Source docstrings cite the design document by section (`DESIGN.md §4`,
`DESIGN.md §5(ii)`, ...). This check greps the code tree for those
citations and verifies each resolves to a real heading in DESIGN.md:

  * `§N`      -> a `## §N` heading must exist
  * `§N(sub)` -> a `### §N(sub)` heading (or, failing that, `## §N`
                 followed by the literal `§N(sub)` anywhere in the doc)

Run from the repo root (CI does): python tools/check_design_refs.py
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "benchmarks", "tests", "examples")
CITE = re.compile(r"DESIGN\.md\s+(§\d+(?:\([a-z]+\))?)")
HEADING = re.compile(r"^#{2,3}\s+(§\d+(?:\([a-z]+\))?)(?=[\s—-]|$)", re.M)


def main() -> int:
    design = ROOT / "DESIGN.md"
    if not design.exists():
        print("check_design_refs: DESIGN.md does not exist", file=sys.stderr)
        return 1
    text = design.read_text(encoding="utf-8")
    headings = set(HEADING.findall(text))

    failures = []
    n_cites = 0
    for d in SCAN_DIRS:
        for path in sorted((ROOT / d).rglob("*.py")):
            for lineno, line in enumerate(
                    path.read_text(encoding="utf-8").splitlines(), 1):
                for ref in CITE.findall(line):
                    n_cites += 1
                    base = ref.split("(")[0]
                    ok = ref in headings or (
                        "(" in ref and base in headings and ref in text)
                    if not ok:
                        failures.append(
                            f"{path.relative_to(ROOT)}:{lineno}: cites "
                            f"DESIGN.md {ref} but no such section heading")

    if failures:
        print("\n".join(failures), file=sys.stderr)
        return 1
    print(f"check_design_refs: {n_cites} citations, "
          f"{len(headings)} sections — all resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
