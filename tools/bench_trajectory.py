#!/usr/bin/env python3
"""Perf trajectory report: committed BENCH_*.json across git history.

The full benchmark run (``python -m benchmarks.run --json ...``) commits
one ``BENCH_<figure>.json`` per figure at the repo root — the longitudinal
perf record (DESIGN.md §14). This tool walks the git history of those
files and reports, per (figure, engine) series, how the headline
``steps_per_s`` (and ``speedup_vs_baseline``) moved commit over commit:

  python tools/bench_trajectory.py              # all figures, full history
  python tools/bench_trajectory.py --max-commits 20
  python tools/bench_trajectory.py --figure multiquery

NON-GATING by design: the bench-smoke CI step runs it as a report. Missing
records, unreadable history, or a shallow clone produce notes, never a
non-zero exit — the trajectory is evidence for humans reading the CI log,
not a regression oracle (quick/smoke numbers never land in BENCH files,
so history points are always full-run measurements).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
from collections import defaultdict

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _git(*args: str) -> str:
    return subprocess.run(["git", *args], cwd=ROOT, check=True,
                          capture_output=True, text=True).stdout


def bench_files_in_history() -> list[str]:
    """Every BENCH_*.json path that ever existed in the history."""
    try:
        out = _git("log", "--name-only", "--pretty=format:", "--",
                   "BENCH_*.json")
    except subprocess.CalledProcessError:
        return []
    names = {line.strip() for line in out.splitlines() if line.strip()}
    names |= {p.name for p in ROOT.glob("BENCH_*.json")}
    return sorted(n for n in names if n.startswith("BENCH_"))


def history_of(path: str, max_commits: int) -> list[dict]:
    """[{sha, when, rows}] oldest -> newest for one BENCH file (skips
    commits where the blob is unreadable/invalid)."""
    try:
        log = _git("log", f"--max-count={max_commits}",
                   "--pretty=format:%h %cs", "--", path)
    except subprocess.CalledProcessError:
        return []
    points = []
    for line in log.splitlines():
        sha, _, when = line.strip().partition(" ")
        if not sha:
            continue
        try:
            rows = json.loads(_git("show", f"{sha}:{path}"))
        except (subprocess.CalledProcessError, json.JSONDecodeError):
            continue
        if isinstance(rows, list) and rows:
            points.append({"sha": sha, "when": when, "rows": rows})
    return list(reversed(points))


def series(points: list[dict]) -> dict:
    """(figure, variant, engine, q) -> [(sha, when, steps_per_s, speedup)]
    oldest -> newest. One BENCH file can carry several sweep variants of
    one figure — distinct record ``figure`` strings, per-``mix`` rows,
    per-``density`` rows — and collapsing them would fabricate movement
    inside a single commit, so every discriminator a record carries joins
    the key."""
    out: dict = defaultdict(list)
    for pt in points:
        for rec in pt["rows"]:
            try:
                variant = "/".join(str(rec[k]) for k in ("mix", "density")
                                   if k in rec)
                key = (str(rec["figure"]), variant,
                       str(rec["engine"]), int(rec["q"]))
                out[key].append((pt["sha"], pt["when"],
                                 float(rec["steps_per_s"]),
                                 float(rec["speedup_vs_baseline"])))
            except (KeyError, TypeError, ValueError):
                continue
    return dict(out)


def report(figure_filter: str | None, max_commits: int,
           out=sys.stdout) -> int:
    """Print the trajectory tables; returns the number of history points
    found (0 = nothing to report, still exit 0)."""
    files = bench_files_in_history()
    if figure_filter:
        files = [f for f in files if figure_filter in f]
    if not files:
        print("bench_trajectory: no BENCH_*.json in history yet "
              "(a full `benchmarks/run.py --json` run creates them)",
              file=out)
        return 0
    total = 0
    for path in files:
        pts = history_of(path, max_commits)
        if not pts:
            print(f"{path}: no readable history points", file=out)
            continue
        total += len(pts)
        fig = path[len("BENCH_"):-len(".json")]
        print(f"\n{fig}: {len(pts)} committed run(s), "
              f"{pts[0]['when']} .. {pts[-1]['when']}", file=out)
        for (rfig, variant, engine, q), samples in sorted(series(pts).items()):
            first, last = samples[0], samples[-1]
            drift = ((last[2] / first[2] - 1.0) * 100.0
                     if first[2] else float("nan"))
            line = " -> ".join(f"{s[2]:.3g}" for s in samples[-6:])
            label = engine if rfig == fig else f"{rfig}/{engine}"
            if variant:
                label = f"{label}[{variant}]"
            print(f"  {label} q={q}: steps/s {line} "
                  f"({drift:+.1f}% vs oldest; speedup now {last[3]:.2f}x)",
                  file=out)
    return total


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--figure", default=None,
                    help="only figures whose name contains this substring")
    ap.add_argument("--max-commits", type=int, default=50,
                    help="history depth per BENCH file (default 50)")
    args = ap.parse_args()
    try:
        report(args.figure, args.max_commits)
    except Exception as e:  # non-gating: a broken report is a note
        print(f"bench_trajectory: report failed non-fatally: {e}",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
