"""Durable crash recovery: WAL framing, checkpoint+replay, chaos schedules,
degraded-mode serving, and the real kill -9 round-trip (DESIGN.md §16).

Layers, bottom up:

  * WAL unit — checksummed framing round-trips; a torn tail (the
    ``wal-append`` kill window) is truncated on reopen; ``truncate_through``
    drops exactly the checkpointed prefix.
  * recovery equivalence — the schedule harness kills the pool at each of
    the four durability stages and ``check_recovery_equivalent`` proves the
    recovered state, linearization, and epoch ring are bit-identical to the
    pre-crash published prefix (randomized sweep over seeds × stages ×
    crash rounds; sharded variants are ``slow`` / mesh-tests).
  * serving — degraded mode pins reads and rejects writes with
    R_RECOVERING; FailurePolicy budgets the restart loop; a subprocess
    ``launch/serve.py`` run is SIGKILLed for real and must come back with
    zero acknowledged-batch loss.
"""
import json
import os
import random
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (R_EDGE_ADDED, R_RECOVERING, R_TRUE,
                        RESULT_NAMES)
from repro.runtime.fault import FailurePolicy, FaultInjector, Heartbeat, SimulatedCrash
from repro.runtime.recovery import (
    GraphCheckpointer,
    RecoveryError,
    recover,
    resume_pool,
)
from repro.runtime.serve_loop import GraphCoServer
from repro.runtime.wal import WalRecord, WriteAheadLog
from repro.testing.schedules import (
    check_recovery_equivalent,
    check_trace_linearizable,
    gen_client_programs,
    random_schedule,
    run_schedule,
)

STAGES = ["wal-append", "wal-fsync", "ckpt-mid-write", "post-publish-pre-ack"]


def _rec(epoch, ops, clients=("c0",), results=None):
    results = results if results is not None else [int(R_TRUE)] * len(ops)
    return WalRecord(epoch=epoch, ops=[list(o) for o in ops], pad=len(ops),
                     clients=list(clients), batch_ids=[epoch - 1],
                     results=results, lanes=len(ops))


# -- WAL framing ------------------------------------------------------------
def test_wal_roundtrip(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    recs = [_rec(e, [[1, e, 0, 0], [4, e, e + 1, 0]]) for e in (1, 2, 3)]
    for r in recs:
        wal.append(r)
    assert len(wal) == 3
    wal.close()
    back = list(WriteAheadLog(path).records())
    assert [r.epoch for r in back] == [1, 2, 3]
    for a, b in zip(back, recs):
        assert a.ops == b.ops and a.results == b.results
        assert a.clients == b.clients and a.pad == b.pad


def test_wal_torn_tail_truncated_on_reopen(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.append(_rec(1, [[1, 5, 0, 0]]))
    wal.append_torn(_rec(2, [[1, 6, 0, 0]]))       # the wal-append window
    size_torn = os.path.getsize(path)
    wal.close()
    wal2 = WriteAheadLog(path)                      # reopen scans + truncates
    assert [r.epoch for r in wal2.records()] == [1]
    assert wal2.stats.torn_drops > 0        # bytes of torn tail discarded
    assert os.path.getsize(path) < size_torn
    # the truncated log accepts fresh appends at the cut point
    wal2.append(_rec(2, [[1, 6, 0, 0]]))
    assert [r.epoch for r in wal2.records()] == [1, 2]


def test_wal_corrupt_payload_truncates_from_there(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    for e in (1, 2, 3):
        wal.append(_rec(e, [[1, e, 0, 0]]))
    wal.close()
    # flip one byte inside record 2's payload: crc must reject it and
    # everything after it (a prefix property, like a real WAL)
    data = bytearray(open(path, "rb").read())
    first_len = len(WriteAheadLog(path)._frame(_rec(1, [[1, 1, 0, 0]]).to_payload()))
    data[first_len + 20] ^= 0xFF
    open(path, "wb").write(bytes(data))
    wal2 = WriteAheadLog(path)
    assert [r.epoch for r in wal2.records()] == [1]


def test_wal_truncate_through_drops_checkpointed_prefix(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    for e in range(1, 6):
        wal.append(_rec(e, [[1, e, 0, 0]]))
    kept = wal.truncate_through(3)
    assert kept == 2
    assert [r.epoch for r in wal.records()] == [4, 5]
    assert wal.stats.truncations == 1
    # appends continue seamlessly after the rewrite
    wal.append(_rec(6, [[1, 6, 0, 0]]))
    assert [r.epoch for r in wal.records()] == [4, 5, 6]


# -- recovery equivalence at every kill stage -------------------------------
def _crash_trace(stage, *, seed=7, delay=0, ckpt_every=2, durable_dir=None,
                 mesh=None, capacity=8):
    rng = random.Random(seed)
    progs = gen_client_programs(rng, clients=3, batches_per_client=4,
                                max_lanes=3, conflict_rate=0.5)
    sched = random_schedule(random.Random(seed + 1), progs)
    fi = FaultInjector(plan=[("*", stage)], delays={("*", stage): delay})
    return run_schedule(sched, capacity=capacity, fault=fi, mesh=mesh,
                        durable_dir=durable_dir, ckpt_every=ckpt_every)


@pytest.mark.parametrize("stage", STAGES)
def test_recovery_equivalent_at_stage(tmp_path, stage):
    tr = _crash_trace(stage, durable_dir=str(tmp_path))
    assert tr.crash is not None and tr.crash.stage == stage
    rec = check_recovery_equivalent(tr)
    # stage-specific guarantees on top of the six shared obligations:
    if stage == "wal-append":
        # torn frame on disk, round unacked -> recovery lands exactly at
        # the pre-crash published epoch, losing nothing acked
        assert rec.epoch == tr.crash.published_epoch
    if stage in ("wal-fsync", "post-publish-pre-ack"):
        # record durable but unacked -> replay may extend the prefix by
        # exactly that round, never more
        assert rec.epoch - tr.crash.published_epoch in (0, 1)


def test_recovery_without_fault_roundtrips(tmp_path):
    """No crash at all: recover() from a cleanly closed WAL reproduces the
    final pool state (the restart-idempotence baseline)."""
    tr = _crash_trace("none", durable_dir=str(tmp_path), ckpt_every=3)
    assert tr.crash is None
    check_trace_linearizable(tr)
    wal = WriteAheadLog(os.path.join(str(tmp_path), "wal.log"))
    ckpt = GraphCheckpointer(os.path.join(str(tmp_path), "ckpt"))
    rec = recover(ckpt, wal, capacity=tr.capacity,
                  retain_epochs=tr.pool.ring.retain)
    assert rec.epoch == tr.pool.epoch
    assert list(rec.linearization) == list(tr.pool.linearization)
    for f in rec.state._fields:
        np.testing.assert_array_equal(np.asarray(getattr(rec.state, f)),
                                      np.asarray(getattr(tr.pool._head, f)))


def test_recovery_is_idempotent(tmp_path):
    """Recovering twice from the same WAL+checkpoint yields bit-identical
    results — replay must not consume or mutate the durable artifacts."""
    tr = _crash_trace("post-publish-pre-ack", delay=2,
                      durable_dir=str(tmp_path))
    rec1 = check_recovery_equivalent(tr)
    rec2 = check_recovery_equivalent(tr)
    assert rec1.epoch == rec2.epoch
    assert list(rec1.linearization) == list(rec2.linearization)
    for f in rec1.state._fields:
        np.testing.assert_array_equal(np.asarray(getattr(rec1.state, f)),
                                      np.asarray(getattr(rec2.state, f)))


def test_checkpoint_truncates_wal_behind_it(tmp_path):
    """Cadence invariant: after a checkpoint at epoch E the WAL holds only
    records with epoch > E, so recovery replays just the suffix."""
    tr = _crash_trace("post-publish-pre-ack", delay=4, ckpt_every=2,
                      durable_dir=str(tmp_path))
    assert tr.crash is not None
    ckpt = GraphCheckpointer(os.path.join(str(tmp_path), "ckpt"))
    step = ckpt.latest_step()
    assert step is not None and step > 0
    wal = WriteAheadLog(os.path.join(str(tmp_path), "wal.log"))
    for r in wal.records():
        assert r.epoch > step
    rec = recover(ckpt, wal, capacity=tr.capacity,
                  retain_epochs=tr.pool.ring.retain)
    assert rec.ckpt_step == step
    assert rec.replayed_rounds == sum(1 for _ in wal.records())


def test_wal_gap_is_a_recovery_error(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.append(_rec(1, [[1, 3, 0, 0]]))
    wal.append(_rec(3, [[1, 4, 0, 0]]))            # epoch 2 missing
    with pytest.raises(RecoveryError, match="gap"):
        recover(None, wal, capacity=8)


def test_replay_divergence_is_a_recovery_error(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    # claim OP_ADD_E(5, 6) succeeded — on an empty graph both endpoints are
    # missing, so honest replay disagrees with the stored result code
    wal.append(_rec(1, [[4, 5, 6, 0]], results=[int(R_EDGE_ADDED)]))
    with pytest.raises(RecoveryError, match="divergence"):
        recover(None, wal, capacity=8)
    # verify_results=False downgrades the cross-check for forensic loads
    rec = recover(None, WriteAheadLog(path), capacity=8,
                  verify_results=False)
    assert rec.epoch == 1


def test_resume_pool_continues_publishing(tmp_path):
    tr = _crash_trace("post-publish-pre-ack", delay=1,
                      durable_dir=str(tmp_path))
    rec = check_recovery_equivalent(tr)
    pool = resume_pool(rec)
    t = pool.submit("c9", [(1, 900), (1, 901), (4, 900, 901)])
    pool.flush()
    assert t.status == "applied"
    assert pool.epoch == rec.epoch + 1
    assert t.batch_id == rec.next_batch_id      # id-space continues, no reuse
    assert list(pool.linearization) == list(rec.linearization) + [t.batch_id]


# -- randomized chaos sweep -------------------------------------------------
@pytest.mark.parametrize("seed", range(4))
def test_chaos_recovery_sweep_dense(tmp_path, seed):
    """Kill the pool at a randomized (stage, round) and prove equivalence —
    the paper-scale claim that durability holds at EVERY kill point, not
    just the handcrafted ones."""
    rng = random.Random(100 + seed)
    for trial in range(4):
        stage = rng.choice(STAGES)
        delay = rng.randrange(0, 6)
        d = str(tmp_path / f"t{trial}")
        tr = _crash_trace(stage, seed=200 + 10 * seed + trial, delay=delay,
                          ckpt_every=rng.choice([0, 2, 3]), durable_dir=d)
        if tr.crash is None:
            check_trace_linearizable(tr)        # armed too late: clean run
            continue
        check_recovery_equivalent(tr)


@pytest.mark.slow
@pytest.mark.parametrize("stage", STAGES)
def test_chaos_recovery_sharded(tmp_path, stage):
    """Sharded pool killed at each stage: recovery reshards the checkpoint
    onto the mesh and the equivalence obligations hold on unsharded bits."""
    from repro.core.distributed import make_graph_mesh

    mesh = make_graph_mesh()
    tr = _crash_trace(stage, delay=1, durable_dir=str(tmp_path), mesh=mesh,
                      capacity=16)
    assert tr.crash is not None
    check_recovery_equivalent(tr)


# -- degraded-mode serving --------------------------------------------------
def _warm_server(tmp_path, **kw):
    srv = GraphCoServer(capacity=32, ingest=True, wal_dir=str(tmp_path),
                        ckpt_every=kw.pop("ckpt_every", 0), **kw)
    srv.submit_client("c0", [(1, 0), (1, 1), (1, 2)])
    srv.submit_client("c1", [(4, 0, 1), (4, 1, 2)])
    srv.flush()
    return srv


def test_degraded_mode_pins_reads_and_rejects_writes(tmp_path):
    srv = _warm_server(tmp_path)
    fi = FaultInjector()
    srv.pool.fault = fi
    fi.plan.append(("*", "post-publish-pre-ack"))
    with pytest.raises(SimulatedCrash):
        srv.submit_client("c0", [(1, 7)])
        srv.flush()
    srv.enter_degraded()
    pinned_epoch = srv._pinned[0]
    # writes: typed rejection on BOTH surfaces, counted
    res = srv.submit([(1, 8), (1, 9)])
    assert list(res) == [R_RECOVERING, R_RECOVERING]
    assert RESULT_NAMES[int(res[0])] == "RECOVERING"
    t = srv.submit_client("c2", [(1, 10)])
    assert t.status == "rejected" and t.batch_id == -1
    assert list(t.results) == [R_RECOVERING]
    assert srv.rejected_writes == 2
    # reads: served from the pinned epoch, counted as degraded
    r = srv.get_reach([(0, 2)])
    assert r.found == [True]
    assert r.degraded is True
    assert srv.degraded_reads >= 1
    assert srv._pinned[0] == pinned_epoch
    m = srv.get_metrics()
    assert m["server.degraded"] == 1 and m["server.rejected_writes"] == 2
    # recover: the crashed-but-published round is re-derived, writes resume
    srv.recover_now()
    assert not srv.degraded
    assert srv.recoveries == 1
    res = srv.submit([(1, 8)])
    assert list(res) == [R_TRUE]


def test_handle_crash_respects_restart_budget(tmp_path):
    srv = _warm_server(tmp_path,
                       failure_policy=FailurePolicy(max_restarts=2,
                                                    backoff_s=0.25))
    assert srv.handle_crash() == 0.25
    assert srv.handle_crash() == 0.5
    assert srv.recoveries == 2 and not srv.degraded
    # budget exhausted: the crash loop pages a human instead of spinning,
    # and the server STAYS degraded (no recovery happened)
    with pytest.raises(RuntimeError, match="restart budget exhausted"):
        srv.handle_crash()
    assert srv.degraded


def test_heartbeat_timeout_triggers_recovery(tmp_path):
    srv = _warm_server(tmp_path, heartbeat=Heartbeat(timeout_s=5.0),
                       failure_policy=FailurePolicy(max_restarts=3,
                                                    backoff_s=0.0))
    srv.worker_tick("ingest", now=100.0)
    assert srv.check_health(now=104.0) == []
    assert srv.check_health(now=106.0) == ["ingest"]
    assert srv.recoveries == 1 and not srv.degraded
    # the restarted worker's heartbeat was re-ticked: no recovery storm
    assert srv.check_health(now=107.0) == []
    assert srv.recoveries == 1


def test_recovery_preserves_server_state_bits(tmp_path):
    srv = _warm_server(tmp_path)
    before = {f: np.asarray(getattr(srv.state, f)).copy()
              for f in srv.state._fields}
    lin_before = list(srv.pool.linearization)
    srv.enter_degraded()
    srv.recover_now()
    assert list(srv.pool.linearization) == lin_before
    for f, want in before.items():
        np.testing.assert_array_equal(np.asarray(getattr(srv.state, f)), want)
    # queries observe the identical graph after the restart
    r = srv.get_reach([(0, 2)])
    assert r.found == [True]


# -- subprocess kill -9 round-trip ------------------------------------------
@pytest.mark.slow
def test_subprocess_sigkill_roundtrip(tmp_path):
    """launch/serve.py is SIGKILLed for real mid-run; the restarted process
    must recover every acknowledged round (zero acked-batch loss) and keep
    serving past the crash epoch."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = os.path.join(root, "launch", "serve.py")
    wal_dir = str(tmp_path / "durable")
    report = str(tmp_path / "report.jsonl")
    base = [sys.executable, script, "--wal-dir", wal_dir,
            "--report", report, "--ckpt-every", "3"]
    env = {**os.environ, "PYTHONPATH": os.path.join(root, "src")}

    p = subprocess.run(base + ["--steps", "10", "--crash-at-step", "6"],
                       env=env, capture_output=True, text=True, timeout=600)
    assert p.returncode == -9, (p.returncode, p.stderr)   # died by SIGKILL

    p2 = subprocess.run(base + ["--recover", "--steps", "3"],
                        env=env, capture_output=True, text=True, timeout=600)
    assert p2.returncode == 0, p2.stderr

    lines = [json.loads(l) for l in open(report)]
    acked, last_epoch = set(), 0
    for rec in lines:
        if rec["type"] == "recovered":
            break
        acked.update(rec["acked"])
        last_epoch = rec["epoch"]
    recovered = next(r for r in lines if r["type"] == "recovered")
    done = next(r for r in lines if r["type"] == "done")
    assert acked <= set(recovered["linearization"])       # zero acked loss
    assert recovered["epoch"] >= last_epoch
    assert done["epoch"] > recovered["epoch"]             # serving resumed
    assert set(recovered["linearization"]) <= set(done["linearization"])
