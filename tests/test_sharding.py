"""Sharding rules: every arch's full-size param tree gets valid, divisible
specs on the production meshes (no jax device allocation — specs only)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config
from repro.models.model import build_model
from repro.parallel import sharding


class FakeMesh:
    """Shape-only stand-in so spec construction needs no 256 devices."""

    def __init__(self, shape_dict):
        self.shape = shape_dict
        self.axis_names = tuple(shape_dict)


MESHES = {
    "single": FakeMesh({"data": 16, "model": 16}),
    "multi": FakeMesh({"pod": 2, "data": 16, "model": 16}),
}


def _check_spec(spec, shape, mesh):
    assert len(spec) <= len(shape), (spec, shape)
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        assert dim % size == 0, f"dim {dim} not divisible by {axes} ({size})"


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh_kind", ["single", "multi"])
@pytest.mark.parametrize("mode", ["train", "serve"])
def test_param_specs_divisible(arch, mesh_kind, mode):
    mesh = MESHES[mesh_kind]
    cfg = get_config(arch)
    model = build_model(cfg)
    params_abs = model.init_abstract()
    specs = sharding.param_specs(params_abs, mesh, mode)
    leaves_p = jax.tree.leaves(params_abs)
    leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_p) == len(leaves_s)
    for p, s in zip(leaves_p, leaves_s):
        _check_spec(s, p.shape, mesh)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma2-27b", "mamba2-780m",
                                  "recurrentgemma-9b", "whisper-base"])
def test_cache_specs_divisible(arch):
    mesh = MESHES["single"]
    cfg = get_config(arch)
    model = build_model(cfg)
    cache_abs = model.abstract_cache(128, 32768)
    specs = sharding.cache_specs(cache_abs, mesh)
    leaves_c = jax.tree.leaves(cache_abs)
    leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for c, s in zip(leaves_c, leaves_s):
        _check_spec(s, c.shape, mesh)


def test_train_mode_shards_over_data_and_model():
    """FSDP x TP: large 2-D weights must shard on both axis groups."""
    mesh = MESHES["single"]
    cfg = get_config("internvl2-76b")
    model = build_model(cfg)
    specs = sharding.param_specs(model.init_abstract(), mesh, "train")
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    both = sum(1 for s in flat
               if any(e in ("data", ("data",)) or e == ("pod", "data") for e in s)
               and any(e == "model" for e in s))
    # stacked params yield ONE leaf per param name; internvl2 has ~9 big 2-D
    # weights, all of which must be FSDP x TP sharded
    assert both >= 7, f"expected FSDP x TP sharded weights, got {both}"


def test_serve_mode_replicates_over_data():
    mesh = MESHES["single"]
    cfg = get_config("qwen3-4b")
    model = build_model(cfg)
    specs = sharding.param_specs(model.init_abstract(), mesh, "serve")
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for s in flat:
        assert all(e is None or e == "model" for e in s), s


def test_batch_specs():
    mesh = MESHES["multi"]
    spec = sharding.batch_spec(mesh, "tokens", (256, 4096))
    assert spec[0] == ("pod", "data")
    spec1 = sharding.batch_spec(mesh, "tokens", (1, 524288))
    assert spec1[0] is None  # batch=1 cannot shard
