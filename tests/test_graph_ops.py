"""Unit tests: ADT semantics of the concurrent graph (paper §2.1)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    OP_ADD_E, OP_ADD_V, OP_CON_E, OP_CON_V, OP_REM_E, OP_REM_V,
    R_CAS_FAIL, R_EDGE_ADDED, R_EDGE_NOT_PRESENT, R_EDGE_PRESENT,
    R_EDGE_REMOVED, R_FALSE, R_TABLE_FULL, R_TRUE, R_VERTEX_NOT_PRESENT,
    add_edge, add_vertex, apply_ops, apply_ops_fast, compact, contains_edge,
    contains_vertex, grow, make_graph, make_op_batch, num_edges, num_vertices,
    remove_edge, remove_vertex,
)


def build(keys=(), edges=()):
    g = make_graph(32)
    for k in keys:
        g, r = add_vertex(g, k)
        assert int(r) == R_TRUE
    for (a, b) in edges:
        g, r = add_edge(g, a, b)
        assert int(r) == R_EDGE_ADDED
    return g


def test_add_vertex_semantics():
    g = build()
    g, r = add_vertex(g, 5)
    assert int(r) == R_TRUE
    g, r = add_vertex(g, 5)            # duplicate -> false (paper ADT 1)
    assert int(r) == R_FALSE
    assert bool(contains_vertex(g, 5))
    assert not bool(contains_vertex(g, 6))


def test_remove_vertex_removes_incident_edges():
    g = build([1, 2, 3], [(1, 2), (2, 3), (3, 1)])
    g, r = remove_vertex(g, 2)
    assert int(r) == R_TRUE
    # paper ADT 2: all (j,2), (2,l) logically removed
    assert int(contains_edge(g, 1, 2)) == R_VERTEX_NOT_PRESENT
    assert int(contains_edge(g, 3, 1)) == R_EDGE_PRESENT
    assert int(num_vertices(g)) == 2 and int(num_edges(g)) == 1
    g, r = remove_vertex(g, 2)
    assert int(r) == R_FALSE


def test_edge_requires_both_vertices():
    g = build([1])
    g, r = add_edge(g, 1, 9)
    assert int(r) == R_VERTEX_NOT_PRESENT
    g, r = remove_edge(g, 9, 1)
    assert int(r) == R_VERTEX_NOT_PRESENT


def test_edge_add_remove_cycle():
    g = build([1, 2])
    g, r = add_edge(g, 1, 2)
    assert int(r) == R_EDGE_ADDED
    g, r = add_edge(g, 1, 2)
    assert int(r) == R_EDGE_PRESENT
    g, r = remove_edge(g, 1, 2)
    assert int(r) == R_EDGE_REMOVED
    g, r = remove_edge(g, 1, 2)
    assert int(r) == R_EDGE_NOT_PRESENT


def test_ecnt_faa_on_edge_mutations():
    """The paper's FetchAndAdd on ecnt (lines 57/93): one bump per effective op."""
    g = build([1, 2])
    s1 = int(g.ecnt[0])
    g, _ = add_edge(g, 1, 2)
    g, _ = add_edge(g, 1, 2)  # EDGE PRESENT: no bump
    g, _ = remove_edge(g, 1, 2)
    slot = int(np.argmax(np.asarray(g.vkey) == 1))
    assert int(g.ecnt[slot]) == s1 + 2


def test_versioned_cas_semantics():
    g = build([1, 2])
    slot = int(np.argmax(np.asarray(g.vkey) == 1))
    cur = int(g.ecnt[slot])
    ops = make_op_batch([(OP_ADD_E, 1, 2, cur + 7)])
    g, res = apply_ops(g, ops)
    assert int(res[0]) == R_CAS_FAIL              # stale expectation
    ops = make_op_batch([(OP_ADD_E, 1, 2, cur)])
    g, res = apply_ops(g, ops)
    assert int(res[0]) == R_EDGE_ADDED            # matching expectation


def test_capacity_and_grow_unbounded():
    g = make_graph(4)
    for k in range(4):
        g, r = add_vertex(g, k)
        assert int(r) == R_TRUE
    g, r = add_vertex(g, 99)
    assert int(r) == R_TABLE_FULL
    g = grow(g, 8)                                 # the 'unbounded' part
    g, r = add_vertex(g, 99)
    assert int(r) == R_TRUE
    assert int(num_vertices(g)) == 5


def test_compact_frees_slots_and_preserves_live_edges():
    g = make_graph(4)
    for k in range(4):
        g, _ = add_vertex(g, k)
    g, _ = add_edge(g, 0, 1)
    g, _ = remove_vertex(g, 2)
    g, r = add_vertex(g, 7)
    assert int(r) == R_TABLE_FULL                  # dead slot still occupied
    g = compact(g)                                 # physical removal (helping)
    g, r = add_vertex(g, 7)
    assert int(r) == R_TRUE
    assert int(contains_edge(g, 0, 1)) == R_EDGE_PRESENT


def test_vertex_readd_gets_fresh_edges():
    g = build([1, 2], [(1, 2)])
    g, _ = remove_vertex(g, 1)
    g, r = add_vertex(g, 1)
    assert int(r) == R_TRUE
    assert int(contains_edge(g, 1, 2)) == R_EDGE_NOT_PRESENT  # no stale ENodes


def test_engines_match_on_conflicting_batch():
    ops = make_op_batch([
        (OP_ADD_V, 1), (OP_ADD_V, 1), (OP_ADD_V, 2), (OP_ADD_E, 1, 2),
        (OP_REM_V, 1), (OP_ADD_E, 1, 2), (OP_CON_V, 1), (OP_CON_E, 1, 2),
    ])
    g1, r1 = apply_ops(make_graph(16), ops)
    g2, r2 = apply_ops_fast(make_graph(16), ops)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    for f in g1._fields:
        np.testing.assert_array_equal(np.asarray(getattr(g1, f)),
                                      np.asarray(getattr(g2, f)))
