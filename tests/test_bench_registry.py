"""Registry validation for benchmarks/run.py (the --smoke / --json gate).

Covers the fresh-clone case the gate must survive: a registered figure with
no committed BENCH_<figure>.json yet is a NOTE, never an abort — only
records that exist but are unreadable or schema-invalid fail.
"""
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

from benchmarks.run import (  # noqa: E402
    FIGURES,
    check_committed_records,
    preflight,
    validate_records,
    write_bench_files,
)


def _rec(figure="fig9_throughput", **over):
    rec = {
        "figure": figure,
        "q": 4,
        "engine": "nonblocking",
        "seconds": 0.5,
        "steps": 1024,
        "steps_per_s": 2048.0,
        "speedup_vs_baseline": 2.0,
    }
    rec.update(over)
    return rec


def test_validate_records_accepts_schema_and_prefix_figures():
    records = [_rec(), _rec(figure="sharded_apply"), _rec(figure="sharded_bfs")]
    assert validate_records(records, ["fig9_throughput", "sharded"]) == []


def test_validate_records_reports_missing_keys_and_figures():
    errors = validate_records([_rec()], ["fig9_throughput", "multiquery"])
    assert any("multiquery" in e for e in errors)
    bad = _rec()
    del bad["steps_per_s"]
    bad["seconds"] = "fast"
    errors = validate_records([bad], ["fig9_throughput"])
    assert any("steps_per_s" in e for e in errors)
    assert any("seconds" in e for e in errors)


def test_missing_committed_records_are_notes_not_errors(tmp_path):
    """Fresh clone: NO BENCH_<figure>.json exists — quick/smoke must not
    abort; every registered figure surfaces as a note."""
    errors, notes = check_committed_records(root=tmp_path)
    assert errors == []
    assert len(notes) == len(FIGURES)
    assert all("fresh clone" in n for n in notes)


def test_committed_record_schema_is_enforced_when_present(tmp_path):
    # valid record (written the way run.py writes it) -> clean
    write_bench_files([_rec()], root=tmp_path)
    errors, notes = check_committed_records(["fig9_throughput"], root=tmp_path)
    assert errors == [] and notes == []
    # schema-invalid record -> error names the file
    (tmp_path / "BENCH_multiquery.json").write_text(
        json.dumps([{"figure": "multiquery"}]), encoding="utf-8")
    errors, _ = check_committed_records(["multiquery"], root=tmp_path)
    assert errors and all("BENCH_multiquery.json" in e for e in errors)
    # unreadable JSON -> error, not crash
    (tmp_path / "BENCH_index.json").write_text("{not json", encoding="utf-8")
    errors, _ = check_committed_records(["index"], root=tmp_path)
    assert errors and "unreadable" in errors[0]
    # empty list -> error (a committed record must carry rows)
    (tmp_path / "BENCH_fig10_getpath.json").write_text("[]", encoding="utf-8")
    errors, _ = check_committed_records(["fig10_getpath"], root=tmp_path)
    assert errors and "non-empty" in errors[0]


def test_prefix_figures_resolve_committed_files(tmp_path):
    """fig_sharded registers as prefix 'sharded' but writes
    BENCH_sharded_apply/BENCH_sharded_bfs — both must be found and checked."""
    write_bench_files([_rec(figure="sharded_apply"),
                       _rec(figure="sharded_bfs")], root=tmp_path)
    errors, notes = check_committed_records(["sharded"], root=tmp_path)
    assert errors == [] and notes == []


def test_registry_matches_committed_bench_records_in_repo():
    """The real repo state: whatever BENCH files are committed must be
    schema-valid; figures without records are tolerated (fresh-clone rule)."""
    errors, _notes = check_committed_records()
    assert errors == [], errors


def test_preflight_accepts_the_committed_registry():
    """Every module registered in FIGURES exists under benchmarks/, imports
    cleanly, and exposes main() — the --smoke import-and-registry gate."""
    assert preflight() == []


def test_preflight_catches_registry_typos_and_bad_entries(monkeypatch):
    import benchmarks.run as run

    monkeypatch.setattr(run, "FIGURES", (
        ("ghost", "fig_ghost", "module that does not exist"),
        ("driver", "run", "imports fine but exposes no figure entry"),
    ))
    errors = run.preflight()
    assert any("benchmarks.fig_ghost" in e and "no such module" in e
               for e in errors), errors
    # prove require_attr is really checked: benchmarks.analytic imports
    # fine but exposes no main() figure entry
    monkeypatch.setattr(run, "FIGURES", (
        ("analytic", "analytic", "no main() entry"),))
    errors = run.preflight()
    assert errors and "main" in errors[0], errors


def test_roofline_records_ride_the_bench_schema():
    """benchmarks/roofline.py feeds the same long-format record stream as
    the sweep figures (DESIGN.md §14): every runnable (arch x shape) cell
    must emit one schema-valid record, skipped cells none, and ``seconds``
    must be the binding roofline term."""
    from benchmarks import roofline

    rows = roofline.build_table()
    recs = roofline.records(rows)
    assert len(recs) == sum(not r.get("skipped") for r in rows)
    assert validate_records(recs, ["roofline"]) == []
    for rec in recs:
        assert rec["figure"] == "roofline"
        assert rec["seconds"] == max(rec["compute_s"], rec["memory_s"],
                                     rec["collective_s"])
        assert rec["steps_per_s"] > 0
        assert 0.0 <= rec["speedup_vs_baseline"] <= 1.0
