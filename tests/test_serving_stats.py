"""Regression pins for the multi-tenant admission observability (DESIGN.md §12).

The new ``ServeStats``/``IngestStats`` fields are CONTRACT, not decoration:
dashboards and the serving benchmark read them, so their values on a
scripted workload are pinned exactly — a refactor that silently changes
what "retries" or "coalesce_max" counts fails here, not in production.

The scripted 3-client workload: A and B are entity-disjoint (coalesce into
one fused apply); C collides with both (loses round 1, applies alone in
round 2). A fake deterministic clock makes the wait-time accounting exact.
Also pinned: the R_TABLE_FULL auto-grow replay path (tests/test_grow.py)
now RACING a second client fused into the same round, and ``index_tick``
running between admission rounds (the index is an accelerator, never a
consistency dependency — queued batches are invisible to it).
"""
import itertools

import numpy as np

from repro.core import (
    OP_ADD_E, OP_ADD_V, R_EDGE_ADDED, R_TRUE,
)
from repro.core.distributed import make_graph_mesh
from repro.runtime.serve_loop import GraphCoServer

A_OPS = [(OP_ADD_V, 1), (OP_ADD_V, 2), (OP_ADD_E, 1, 2)]        # {1, 2}
B_OPS = [(OP_ADD_V, 11), (OP_ADD_V, 12), (OP_ADD_E, 11, 12)]    # {11, 12}
C_OPS = [(OP_ADD_V, 5), (OP_ADD_E, 1, 12)]                      # {5, 1, 12}


def _fake_clock():
    """Deterministic monotonic clock: 0.0, 1.0, 2.0, ... per call."""
    counter = itertools.count()
    return lambda: float(next(counter))


def test_scripted_three_client_stats_pinned():
    """Every IngestStats field on the scripted A/B-coalesce, C-retry run.

    Clock calls land at: submit A (t=0), submit B (t=1), submit C (t=2),
    round 1 publish (t=3: A waited 3, B waited 2), round 2 publish (t=4:
    C waited 2). So wait_s == 7.0 and wait_max_s == 3.0, exactly.
    """
    srv = GraphCoServer(capacity=32, ingest=True)
    srv.pool.clock = _fake_clock()
    ta = srv.submit_client("A", A_OPS)
    tb = srv.submit_client("B", B_OPS)
    tc = srv.submit_client("C", C_OPS)

    assert srv.pump() == 2          # A + B coalesce; C lost conflict detection
    assert tc.status == "queued" and tc.retries == 1
    assert srv.pump() == 1          # C alone
    assert srv.pump() == 0          # queue drained: a pump is a no-op

    s = srv.pool.stats
    assert s.submitted == 3
    assert s.applied == 3
    assert s.aborted == 0
    assert s.fused_calls == 2
    assert s.coalesced_batches == 3
    assert s.coalesce_max == 2
    assert s.coalesce_lanes_max == 6      # A(3) + B(3) lanes, pre-padding
    assert s.retries == 1
    assert s.queue_depth_max == 3
    assert s.queue_depth == 0
    assert s.epochs == 2
    assert s.grow_events == 0
    assert s.wait_s == 7.0
    assert s.wait_max_s == 3.0

    assert (ta.status, tb.status, tc.status) == ("applied",) * 3
    assert (ta.epoch, tb.epoch, tc.epoch) == (1, 1, 2)
    assert (ta.wait_s, tb.wait_s, tc.wait_s) == (3.0, 2.0, 2.0)
    assert srv.pool.linearization == [ta.batch_id, tb.batch_id, tc.batch_id]

    # the admitted history really happened: C's edge bridges A into B
    out, _ = srv.get_paths([(1, 12), (5, 5), (12, 1)])
    assert out[0] == (True, [1, 12])
    assert out[1] == (True, [5])
    assert out[2] == (False, [])


def test_serve_stats_surface_three_clients():
    """The same scripted workload driven through ``serve(clients=...)``:
    the ServeStats ingest_* fields must carry the pool's pinned values."""
    import jax

    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.runtime.serve_loop import serve

    cfg = get_config("olmo-1b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.zeros((1, 8), np.int32)

    srv = GraphCoServer(capacity=32, ingest=True)

    def clients(step):
        if step == 0:
            return [("A", A_OPS), ("B", B_OPS), ("C", C_OPS)]
        return []

    out, stats = serve(model, params, prompts, max_new_tokens=4,
                       cache_len=16, graph=srv, clients=clients)
    assert out.shape == (1, 4)
    # step 0's pump admits A+B (C conflicts); step 1's pump admits C;
    # steps 2-3 pump an empty queue; the final flush finds nothing left
    assert stats.ingest_batches == 3
    assert stats.ingest_fused_calls == 2
    assert stats.ingest_coalesce_max == 2
    assert stats.ingest_retries == 1
    assert stats.ingest_queue_depth_max == 3
    assert stats.ingest_epochs == 2
    assert stats.graph_ops == len(A_OPS) + len(B_OPS) + len(C_OPS)
    assert 0.0 <= stats.ingest_wait_max_s <= stats.ingest_wait_s
    assert stats.grow_events == 0
    out_paths, _ = srv.get_paths([(1, 12)])
    assert out_paths[0] == (True, [1, 12])


def test_serve_stats_deltas_reset_between_serve_calls():
    """Two consecutive ``serve()`` calls on one server: ServeStats is a
    PER-CALL report, so a grow (or any other lifetime event) in the first
    call must not leak into the second call's stats. Regression for
    ``grow_events`` reporting the server's lifetime total instead of the
    start-of-serve delta every other counter already used."""
    import jax

    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.runtime.serve_loop import serve

    cfg = get_config("olmo-1b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.zeros((1, 8), np.int32)

    srv = GraphCoServer(capacity=4, ingest=True)

    def growing_clients(step):
        # 6 vertices into a capacity-4 table: forces >= 1 auto-grow replay
        if step == 0:
            return [("A", [(OP_ADD_V, k) for k in range(6)])]
        return []

    _, s1 = serve(model, params, prompts, max_new_tokens=2, cache_len=16,
                  graph=srv, clients=growing_clients)
    assert s1.grow_events >= 1
    assert s1.ingest_batches == 1

    _, s2 = serve(model, params, prompts, max_new_tokens=2, cache_len=16,
                  graph=srv, clients=lambda i: [])
    assert s2.grow_events == 0       # was: lifetime total leaked in
    assert s2.ingest_batches == 0
    assert s2.ingest_epochs == 0


def test_serve_rejects_clients_without_ingest_pool():
    import pytest

    from repro.runtime.serve_loop import serve

    srv = GraphCoServer(capacity=8)          # no pool
    with pytest.raises(RuntimeError, match="ingest=True"):
        serve(None, None, np.zeros((1, 4), np.int32), max_new_tokens=1,
              cache_len=8, graph=srv, clients=lambda i: [])


def test_autogrow_replay_races_second_client():
    """R_TABLE_FULL auto-grow (tests/test_grow.py) under admission: client A
    fills the capacity-4 table; disjoint client B is fused into the SAME
    round, so the fused apply starves, grows, and replays BOTH batches on
    the grown pre-round state. Every lane must come back clean, the growth
    must be counted once, and exactly one epoch publishes (the starved
    attempt never surfaces)."""
    srv = GraphCoServer(capacity=4, ingest=True)
    ta = srv.submit_client("A", [(OP_ADD_V, k) for k in range(4)])
    tb = srv.submit_client("B", [(OP_ADD_V, 8), (OP_ADD_V, 9),
                                 (OP_ADD_E, 8, 9)])
    assert srv.pump() == 2                    # one fused round, grown inside

    s = srv.pool.stats
    assert s.grow_events == 1
    assert srv.grow_events == 1               # surfaced via on_grow
    assert s.fused_calls == 1                 # the grow replay is NOT a new call
    assert s.coalesce_max == 2
    assert s.epochs == 1
    assert s.retries == 0
    assert srv.state.capacity == 8
    assert [int(x) for x in ta.results] == [R_TRUE] * 4
    assert [int(x) for x in tb.results] == [R_TRUE, R_TRUE, R_EDGE_ADDED]
    assert (ta.epoch, tb.epoch) == (1, 1)
    out, _ = srv.get_paths([(8, 9), (0, 8)])
    assert out[0] == (True, [8, 9])
    assert out[1] == (False, [])


def test_autogrow_replay_races_second_client_sharded():
    mesh = make_graph_mesh()
    size = int(mesh.shape["rows"])
    cap0 = max(4, size)                       # a shard multiple, and full-able
    srv = GraphCoServer(capacity=cap0, mesh=mesh, ingest=True)
    ta = srv.submit_client("A", [(OP_ADD_V, k) for k in range(cap0)])
    tb = srv.submit_client("B", [(OP_ADD_V, cap0 + 4), (OP_ADD_V, cap0 + 5),
                                 (OP_ADD_E, cap0 + 4, cap0 + 5)])
    assert srv.pump() == 2
    assert srv.pool.stats.grow_events >= 1
    assert srv.state.capacity >= cap0 + 2
    assert srv.state.capacity % size == 0
    assert [int(x) for x in ta.results] == [R_TRUE] * cap0
    assert [int(x) for x in tb.results] == [R_TRUE, R_TRUE, R_EDGE_ADDED]
    out, _ = srv.get_paths([(cap0 + 4, cap0 + 5)])
    assert out[0] == (True, [cap0 + 4, cap0 + 5])


def test_index_tick_tolerates_concurrent_admission():
    """index_tick() interleaved with admission rounds: the index covers the
    last PUBLISHED epoch only — queued batches are invisible to it, a pump
    makes it stale (queries fall back, still correct), the next tick
    re-freshens it. The index never blocks or corrupts admission."""
    srv = GraphCoServer(capacity=32, ingest=True, index=True)
    srv.submit_client("A", A_OPS)
    srv.submit_client("B", B_OPS)
    assert srv.pump() == 2
    assert srv.index_tick() is True           # first build, on epoch 1
    res = srv.get_reach([(1, 2), (11, 12), (1, 12)])
    assert res.found == [True, True, False]
    assert res.from_index == 3 and res.fellback == 0

    # a QUEUED batch must be invisible to both the index and its freshness
    srv.submit_client("C", [(OP_ADD_E, 2, 11)])
    assert srv.index_tick() is False          # published epoch unchanged
    res = srv.get_reach([(2, 11)])
    assert res.found == [False] and res.from_index == 1

    assert srv.pump() == 1                    # C lands; index now stale
    res = srv.get_reach([(2, 11), (1, 12)])
    assert res.found == [True, True]          # correct via BFS fallback
    assert res.from_index == 0 and res.fellback == 2

    assert srv.index_tick() is True           # refresh onto epoch 2
    res = srv.get_reach([(1, 12)])
    assert res.found == [True] and res.from_index == 1
    assert srv.index_tick() is False          # fresh and quiescent: no-op


def test_pool_owned_state_rejects_direct_assignment():
    """With the pool attached, ``server.state = ...`` would bypass the
    linearization log and the epoch buffer — it must refuse."""
    import pytest

    from repro.core import make_graph

    srv = GraphCoServer(capacity=8, ingest=True)
    with pytest.raises(AttributeError, match="pool-owned"):
        srv.state = make_graph(8)


def test_direct_submit_surface_routes_through_pool():
    """``submit()`` (the single-tenant surface) on an ingest server shares
    the pool's linearization log with concurrent clients."""
    srv = GraphCoServer(capacity=16, ingest=True)
    tb = srv.submit_client("B", B_OPS)        # queued ahead of the direct call
    res = srv.submit(A_OPS)                   # enqueues + flushes everything
    assert [int(x) for x in res] == [R_TRUE, R_TRUE, R_EDGE_ADDED]
    assert tb.status == "applied"             # the flush drained B too
    assert srv.pool.stats.applied == 2
    out, _ = srv.get_paths([(1, 2), (11, 12)])
    assert out == [(True, [1, 2]), (True, [11, 12])]
