"""Fused multi-source BFS engine vs per-query bfs and the sequential oracle.

The contract under test (DESIGN.md §7): ``multi_bfs`` over Q (src, dst)
pairs is bit-identical per query to ``bfs`` run Q times — found, parent
tree, depths, dependency set (expanded) and step count — on both the jnp
and pallas(interpret) backends, including dead endpoints, absent slots,
Q > alive vertices, and per-query early-exit masking.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from repro.testing.proptest import given, settings, strategies as st

from repro.core import (
    OP_ADD_E, OP_ADD_V, OP_REM_E, OP_REM_V,
    GraphOracle, apply_ops, bfs, collect_batch, compare_collect_batches,
    find_slot, make_graph, make_op_batch, multi_bfs,
)


def _build(edge_ops, nv=8, cap=32):
    g = make_graph(cap)
    oracle = GraphOracle(cap)
    ops = [(OP_ADD_V, k, -1, -1) for k in range(nv)]
    ops += [(op, u, v, -1) for (op, u, v) in edge_ops]
    g, _ = apply_ops(g, make_op_batch(ops))
    oracle.apply_batch(ops)
    return g, oracle


def _slots(g, keys):
    return jnp.asarray([int(find_slot(g, k)) for k in keys], jnp.int32)


def _assert_matches_single(g, srcs, dsts, backend):
    m = multi_bfs(g, srcs, dsts, backend=backend)
    for qi in range(len(srcs)):
        s = bfs(g, srcs[qi], dsts[qi], backend="jnp")
        assert bool(m.found[qi]) == bool(s.found), (backend, qi)
        np.testing.assert_array_equal(np.asarray(m.parent[qi]), np.asarray(s.parent))
        np.testing.assert_array_equal(np.asarray(m.dist[qi]), np.asarray(s.dist))
        np.testing.assert_array_equal(np.asarray(m.expanded[qi]), np.asarray(s.expanded))
        assert int(m.steps[qi]) == int(s.steps), (backend, qi)
    return m


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("q", [1, 4, 16, 64])
def test_multi_bfs_matches_vmapped_single_query(backend, q):
    rng = np.random.default_rng(q)
    nv = 12
    edge_ops = [(OP_ADD_E, int(a), int(b))
                for a, b in rng.integers(0, nv, (3 * nv, 2))]
    g, _ = _build(edge_ops, nv=nv, cap=32)
    keys = rng.integers(0, nv, (q, 2))
    srcs = _slots(g, keys[:, 0])
    dsts = _slots(g, keys[:, 1])
    _assert_matches_single(g, srcs, dsts, backend)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_multi_bfs_dead_endpoints_and_absent_slots(backend):
    g, _ = _build([(OP_ADD_E, 0, 1), (OP_ADD_E, 1, 2), (OP_ADD_E, 2, 3)])
    g, _ = apply_ops(g, make_op_batch([(OP_REM_V, 2, -1, -1)]))
    s0, s1, s3 = (int(find_slot(g, k)) for k in (0, 1, 3))
    srcs = jnp.asarray([s0, s1, -1, s3, 31], jnp.int32)   # -1 absent, 31 dead slot
    dsts = jnp.asarray([s3, s1, s0, -1, s0], jnp.int32)
    m = _assert_matches_single(g, srcs, dsts, backend)
    assert not bool(m.found[0])        # path 0->3 severed by removing 2
    assert bool(m.found[1])            # self-reachability of an alive vertex
    assert not bool(m.found[2]) and not bool(m.found[3]) and not bool(m.found[4])


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_multi_bfs_more_queries_than_alive_vertices(backend):
    g, _ = _build([(OP_ADD_E, 0, 1), (OP_ADD_E, 1, 2)], nv=4, cap=16)
    rng = np.random.default_rng(7)
    q = 24                              # Q >> 4 alive vertices
    keys = rng.integers(-1, 6, (q, 2))  # includes absent keys
    srcs = _slots(g, keys[:, 0])
    dsts = _slots(g, keys[:, 1])
    _assert_matches_single(g, srcs, dsts, backend)


def test_multi_bfs_early_exit_masking_freezes_finished_queries():
    """A short query must stop contributing supersteps: its steps count is
    its own BFS depth, not the slowest query's."""
    # chain 0->1->...->7 : query (0,1) finishes at step 1, (0,7) needs 7
    g, _ = _build([(OP_ADD_E, k, k + 1) for k in range(7)])
    srcs = _slots(g, [0, 0])
    dsts = _slots(g, [1, 7])
    m = multi_bfs(g, srcs, dsts)
    assert int(m.steps[0]) == 1
    assert int(m.steps[1]) == 7
    assert int(m.supersteps) == 7       # shared loop ran to the slowest query
    # the short query's tree stays frozen at its exit point: only vertex 1
    # (plus the root) is in its visited set at depth 1
    assert int(jnp.sum(m.dist[0] >= 0)) == 2


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.sampled_from([OP_ADD_E, OP_REM_E]),
                          st.integers(0, 7), st.integers(0, 7)),
                min_size=0, max_size=14))
def test_multi_bfs_reachability_matches_oracle(edge_ops):
    g, oracle = _build(edge_ops)
    pairs = [(a, b) for a in (0, 3, 6) for b in (1, 5, 7)]
    srcs = _slots(g, [p[0] for p in pairs])
    dsts = _slots(g, [p[1] for p in pairs])
    for backend in ("jnp", "pallas"):
        m = multi_bfs(g, srcs, dsts, backend=backend)
        for qi, (a, b) in enumerate(pairs):
            assert bool(m.found[qi]) == oracle.reachable(a, b), (backend, a, b)


@settings(max_examples=8, deadline=None)
@given(st.lists(st.tuples(st.sampled_from([OP_ADD_E, OP_REM_E]),
                          st.integers(0, 7), st.integers(0, 7)),
                min_size=0, max_size=12))
def test_fused_collect_batch_matches_vmap_engine(edge_ops):
    """The fused and vmap collect_batch engines produce matching Collects —
    same dependency sets, trees and version snapshots — so either side of a
    double collect may be computed by either engine."""
    g, _ = _build(edge_ops)
    ks = [0, 1, 5, 6]
    ls = [7, 3, 5, 0]
    fused = collect_batch(g, ks, ls, engine="fused")
    vmapped = collect_batch(g, ks, ls, engine="vmap")
    assert bool(compare_collect_batches(fused, vmapped))
