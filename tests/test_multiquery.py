"""Beyond-paper feature: batched multi-query GetPath under one shared
double collect (consistent multi-query snapshot)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from repro.testing.proptest import given, settings, strategies as st

from repro.core import (
    OP_ADD_E, OP_ADD_V, OP_REM_E,
    GraphOracle, apply_ops, apply_ops_fast, collect_batch,
    compare_collect_batches, get_paths_session, make_graph, make_op_batch,
)


def _build(edge_ops, nv=8, cap=32):
    g = make_graph(cap)
    oracle = GraphOracle(cap)
    ops = [(OP_ADD_V, k, -1, -1) for k in range(nv)]
    ops += [(op, u, v, -1) for (op, u, v) in edge_ops]
    g, _ = apply_ops(g, make_op_batch(ops))
    oracle.apply_batch(ops)
    return g, oracle


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.sampled_from([OP_ADD_E, OP_REM_E]),
                          st.integers(0, 7), st.integers(0, 7)),
                min_size=0, max_size=12))
def test_multiquery_matches_oracle(edge_ops):
    g, oracle = _build(edge_ops)
    pairs = [(0, 7), (1, 3), (5, 5), (6, 0)]
    for engine in ("fused", "vmap"):
        out, rounds = get_paths_session(lambda: g, pairs, engine=engine)
        assert rounds == 2
        for (found, keys), (s, d) in zip(out, pairs):
            assert found == oracle.reachable(s, d), (engine, s, d)
            if found:
                assert oracle.is_valid_path(keys, s, d)


def test_multiquery_fused_engine_pallas_backend():
    """The production path end-to-end: fused multi-source BFS supersteps
    through the bfs_multi_step pallas kernel under one shared double
    collect."""
    g, oracle = _build([(OP_ADD_E, 0, 1), (OP_ADD_E, 1, 2), (OP_ADD_E, 2, 7),
                        (OP_ADD_E, 5, 6), (OP_REM_E, 1, 2)])
    pairs = [(0, 7), (0, 1), (5, 6), (7, 0)]
    out, rounds = get_paths_session(lambda: g, pairs,
                                    engine="fused", backend="pallas")
    assert rounds == 2
    for (found, keys), (s, d) in zip(out, pairs):
        assert found == oracle.reachable(s, d), (s, d)
        if found:
            assert oracle.is_valid_path(keys, s, d)


def test_multiquery_fused_and_vmap_rounds_interchangeable():
    """Collects from the two engines validate against EACH OTHER: a fused
    first collect matched by a vmap second collect is a legal double
    collect (identical dependency sets and version snapshots)."""
    g, _ = _build([(OP_ADD_E, 0, 1), (OP_ADD_E, 1, 2), (OP_ADD_E, 5, 6)])
    ks, ls = [0, 5], [2, 6]
    fused = collect_batch(g, ks, ls, engine="fused")
    vmapped = collect_batch(g, ks, ls, engine="vmap")
    assert bool(compare_collect_batches(fused, vmapped))
    g2, _ = apply_ops_fast(g, make_op_batch([(OP_REM_E, 1, 2)]))
    assert not bool(compare_collect_batches(
        fused, collect_batch(g2, ks, ls, engine="vmap")))


def test_multiquery_shared_validation_catches_any_mutation():
    """A mutation relevant to only ONE query's dependency set must invalidate
    the shared round (all answers linearize at the same point)."""
    g, oracle = _build([(OP_ADD_E, 0, 1), (OP_ADD_E, 1, 2), (OP_ADD_E, 5, 6)])
    pairs = [(0, 2), (5, 6)]
    c1 = collect_batch(g, [p[0] for p in pairs], [p[1] for p in pairs])
    g2, _ = apply_ops_fast(g, make_op_batch([(OP_REM_E, 5, 6)]))
    g3, _ = apply_ops_fast(g2, make_op_batch([(OP_ADD_E, 5, 6)]))
    c2 = collect_batch(g3, [p[0] for p in pairs], [p[1] for p in pairs])
    assert not bool(compare_collect_batches(c1, c2))


def test_multiquery_retries_then_completes():
    g, oracle = _build([(OP_ADD_E, 0, 1), (OP_ADD_E, 1, 2)])
    states = [g]
    calls = {"n": 0}

    def fetch():
        if calls["n"] == 1:  # one mutation mid-session forces one retry
            states.append(apply_ops_fast(
                states[-1], make_op_batch([(OP_ADD_E, 2, 3)]))[0])
        calls["n"] += 1
        return states[-1]

    out, rounds = get_paths_session(fetch, [(0, 2), (0, 3)], max_rounds=16)
    assert rounds >= 3
    assert out[0] == (True, [0, 1, 2])
    assert out[1] == (True, [0, 1, 2, 3])
