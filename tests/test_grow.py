"""grow() and auto-grow coverage (the paper's "unbounded" property).

Capacity doubling must preserve keys, edges, ecnt/vver (so outstanding
double collects stay valid over the surviving slots) and reachability
answers — on dense AND mesh-partitioned state — and the serving surface
(GraphCoServer.submit) must grow instead of surfacing R_TABLE_FULL.
"""
import numpy as np

from repro.core import (
    OP_ADD_E, OP_ADD_V, R_TABLE_FULL, R_TRUE,
    apply_ops_fast, get_path, grow, make_graph, make_op_batch,
    num_edges, num_vertices,
)
from repro.core import partition
from repro.core.distributed import make_graph_mesh
from repro.runtime.serve_loop import GraphCoServer


def _ring(n, cap):
    ops = [(OP_ADD_V, k) for k in range(n)]
    ops += [(OP_ADD_E, k, (k + 1) % n) for k in range(n)]
    g, res = apply_ops_fast(make_graph(cap), make_op_batch(ops))
    assert not (np.asarray(res) == R_TABLE_FULL).any()
    return g


def test_grow_preserves_state_and_reachability():
    g = _ring(8, 8)  # full table
    g2 = grow(g, 32)
    assert g2.capacity == 32
    # surviving slots keep keys, liveness, versions and edges bit-for-bit
    for name, a, b in zip(g._fields, g, g2):
        a, b = np.asarray(a), np.asarray(b)
        if a.ndim == 1:
            np.testing.assert_array_equal(a, b[:8], err_msg=name)
        else:
            np.testing.assert_array_equal(a, b[:8, :8], err_msg=name)
    assert int(num_vertices(g2)) == 8 and int(num_edges(g2)) == 8
    pr = get_path(g2, 0, 5)
    assert bool(pr.found) and int(pr.length) == 6  # around the ring
    # new slots are free and usable
    g3, res = apply_ops_fast(g2, make_op_batch([(OP_ADD_V, 100), (OP_ADD_E, 100, 0)]))
    assert [int(x) for x in np.asarray(res)][0] == R_TRUE
    assert bool(get_path(g3, 100, 5).found)


def test_grow_noop_when_not_larger():
    g = _ring(4, 16)
    assert grow(g, 8) is g


def test_sharded_grow_matches_dense_and_preserves_sharding():
    mesh = make_graph_mesh()
    g = _ring(8, 8)
    s = partition.shard_state(mesh, g)
    s2 = partition.grow(s, 32)
    assert isinstance(s2, partition.ShardedGraphState)
    assert s2.capacity == 32 and s2.mesh is mesh
    d2 = grow(g, 32)
    for name, a, b in zip(d2._fields, d2, partition.unshard(s2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)
    # growth target is rounded up to a shard multiple
    s3 = partition.grow(s, 33)
    assert s3.capacity % s3.num_shards == 0 and s3.capacity >= 33


def test_server_submit_autogrows_instead_of_failing():
    srv = GraphCoServer(capacity=4)
    res = srv.submit([(OP_ADD_V, k) for k in range(10)])
    assert not (res == R_TABLE_FULL).any()
    assert (res == R_TRUE).all()          # every starved lane was re-applied
    assert srv.state.capacity >= 10 and srv.grow_events >= 1
    res = srv.submit([(OP_ADD_E, k, k + 1) for k in range(9)])
    assert not (res == R_TABLE_FULL).any()
    out, _ = srv.get_paths([(0, 9)])
    assert out[0] == (True, list(range(10)))


def test_server_submit_autogrow_replays_dependent_lanes():
    """Regression: a lane that failed only because an earlier lane in the
    SAME batch was starved of slots must succeed after the auto-grow replay
    — no cascaded VERTEX-NOT-PRESENT leaks to the client."""
    from repro.core import R_EDGE_ADDED

    srv = GraphCoServer(capacity=4)
    srv.submit([(OP_ADD_V, k) for k in range(4)])       # table now full
    res = srv.submit([(OP_ADD_V, 9), (OP_ADD_E, 9, 0)])
    assert [int(x) for x in res] == [R_TRUE, R_EDGE_ADDED]
    out, _ = srv.get_paths([(9, 0)])
    assert out[0] == (True, [9, 0])


def test_server_submit_mixed_batch_autogrows_to_full_success():
    """Vertices and their edges in ONE batch across a grow boundary."""
    srv = GraphCoServer(capacity=4)
    res = srv.submit([(OP_ADD_V, k) for k in range(10)]
                     + [(OP_ADD_E, k, k + 1) for k in range(9)])
    assert not (res == R_TABLE_FULL).any()
    assert (res == R_TRUE)[:10].all()
    out, _ = srv.get_paths([(0, 9)])
    assert out[0] == (True, list(range(10)))


def test_server_submit_autogrow_disabled_surfaces_table_full():
    srv = GraphCoServer(capacity=4, auto_grow=False)
    res = srv.submit([(OP_ADD_V, k) for k in range(6)])
    assert (res == R_TABLE_FULL).any()
    assert srv.state.capacity == 4


def test_sharded_server_submit_autogrows():
    mesh = make_graph_mesh()
    size = int(mesh.shape["rows"])
    cap0 = 8 * size
    srv = GraphCoServer(capacity=cap0, mesh=mesh)
    n = cap0 + 3
    res = srv.submit([(OP_ADD_V, k) for k in range(n)])
    assert not (res == R_TABLE_FULL).any()
    assert (res == R_TRUE).all()
    res = srv.submit([(OP_ADD_E, k, k + 1) for k in range(n - 1)])
    assert not (res == R_TABLE_FULL).any()
    assert srv.state.capacity >= n
    assert srv.state.capacity % size == 0
    out, _ = srv.get_paths([(0, n - 1), (n - 1, 0)])
    assert out[0] == (True, list(range(n)))
    assert out[1] == (False, [])
    # single-query surface on the sharded server
    pr = srv.get_path(0, n - 1)
    assert bool(pr.found) and int(pr.length) == n
