"""Mesh-partitioned graph state tests (core/partition.py, DESIGN.md §8).

In-process tests run on the ambient mesh (1 device in the plain container;
8 shards under CI's ``--xla_force_host_platform_device_count=8`` job); the
subprocess test forces 8 shards regardless, mirroring tests/test_distributed.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    OP_ADD_E, OP_ADD_V, OP_REM_E, OP_REM_V,
    GraphOracle, apply_ops_fast, collect_batch, compare_collect_batches,
    get_paths_session, make_graph, make_op_batch,
)
from repro.core import partition
from repro.core.distributed import AXIS, make_graph_mesh
from repro.parallel.sharding import graph_state_specs


def _chain_batches(n):
    return ([(OP_ADD_V, k) for k in range(n)]
            + [(OP_ADD_E, k, k + 1) for k in range(n - 1)])


def test_shard_state_roundtrip_and_specs():
    mesh = make_graph_mesh()
    g, _ = apply_ops_fast(make_graph(32), make_op_batch(_chain_batches(6)))
    s = partition.shard_state(mesh, g)
    specs = graph_state_specs()
    assert specs["adj_packed"] == type(specs["adj_packed"])(AXIS, None)
    back = partition.unshard(s)
    for name, a, b in zip(g._fields, g, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


def test_shard_state_rejects_indivisible_capacity():
    mesh = make_graph_mesh()
    size = int(mesh.shape[AXIS])
    if size == 1:
        pytest.skip("every capacity divides a 1-device mesh")
    with pytest.raises(ValueError):
        partition.shard_state(mesh, make_graph(size * 8 + 1))


def test_sharded_query_session_matches_oracle():
    mesh = make_graph_mesh()
    oracle = GraphOracle(32)
    ops = _chain_batches(6) + [(OP_ADD_E, 5, 0), (OP_REM_E, 2, 3)]
    oracle.apply_batch([op + (-1,) * (4 - len(op)) for op in ops])
    g, _ = apply_ops_fast(make_graph(32), make_op_batch(ops))
    s = partition.shard_state(mesh, g)
    pairs = [(0, 5), (3, 1), (4, 4), (0, 9)]
    out, rounds = get_paths_session(lambda: s, pairs)
    assert rounds == 2
    for (found, keys), (a, b) in zip(out, pairs):
        assert found == oracle.reachable(a, b), (a, b)
        if found:
            assert oracle.is_valid_path(keys, a, b)


def test_sharded_collect_mutation_between_collects_forces_retry():
    """A mutation landing between the two collects must flip the comparison
    false on sharded state (the §3.5 adversary, replicated-metadata form)."""
    mesh = make_graph_mesh()
    g, _ = apply_ops_fast(make_graph(32), make_op_batch(_chain_batches(5)))
    s1 = partition.shard_state(mesh, g)
    c1 = collect_batch(s1, [0], [4])
    s2, _ = partition.apply_ops_fast(s1, make_op_batch([(OP_REM_E, 2, 3)]))
    s3, _ = partition.apply_ops_fast(s2, make_op_batch([(OP_ADD_E, 2, 3)]))
    # adjacency restored bit-identically — only the version vector moved
    np.testing.assert_array_equal(
        np.asarray(partition.unshard(s1).adj), np.asarray(partition.unshard(s3).adj))
    c2 = collect_batch(s3, [0], [4])
    assert not bool(compare_collect_batches(c1, c2))
    c3 = collect_batch(s3, [0], [4])
    assert bool(compare_collect_batches(c2, c3))


def test_sharded_session_retries_until_quiescent():
    mesh = make_graph_mesh()
    g, _ = apply_ops_fast(make_graph(32), make_op_batch(_chain_batches(5)))
    states = [partition.shard_state(mesh, g)]
    toggles = [(OP_REM_E, 2, 3), (OP_ADD_E, 2, 3)]
    calls = {"n": 0}

    def fetch():
        i = calls["n"]
        calls["n"] += 1
        if 0 < i <= len(toggles):
            st, _ = partition.apply_ops_fast(states[-1], make_op_batch([toggles[i - 1]]))
            states.append(st)
        return states[-1]

    out, rounds = get_paths_session(fetch, [(0, 4)], max_rounds=32)
    assert out[0][0] and out[0][1] == [0, 1, 2, 3, 4]
    assert rounds == 4  # c1!=c2 (rem), c2!=c3 (add), c3==c4 (quiet)


def test_sharded_compact_frees_slots():
    mesh = make_graph_mesh()
    ops = _chain_batches(4) + [(OP_REM_V, 1)]
    g, _ = apply_ops_fast(make_graph(32), make_op_batch(ops))
    s, _ = partition.apply_ops_fast(
        partition.shard_state(mesh, make_graph(32)), make_op_batch(ops))
    from repro.core.ops import compact as dense_compact

    dc = dense_compact(g)
    sc = partition.compact(s)
    for name, a, b in zip(dc._fields, dc, partition.unshard(sc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


def test_sharded_multi_bfs_pallas_backend_per_shard():
    """backend="pallas" drives the bfs_multi_step kernel on each shard's row
    slice; results must equal the jnp sharded path bit for bit."""
    mesh = make_graph_mesh()
    ops = _chain_batches(8) + [(OP_ADD_E, 7, 0), (OP_REM_E, 3, 4)]
    s, _ = partition.apply_ops_fast(
        partition.shard_state(mesh, make_graph(32)), make_op_batch(ops))
    srcs = np.asarray([0, 2, 5], np.int32)
    dsts = np.asarray([7, -1, 1], np.int32)
    a = partition.multi_bfs(s, srcs, dsts, backend="jnp")
    b = partition.multi_bfs(s, srcs, dsts, backend="pallas")
    for name, xa, xb in zip(a._fields, a, b):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb), err_msg=name)


_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import numpy as np, random
    import jax
    from repro.core import *
    from repro.core import partition
    from repro.core.distributed import make_graph_mesh
    assert len(jax.devices()) == 8, jax.devices()
    mesh = make_graph_mesh()
    random.seed(7)
    CAP = 64
    gd = make_graph(CAP)
    gs = partition.shard_state(mesh, gd)
    for _ in range(6):
        ops = [(random.choice([OP_ADD_V, OP_REM_V, OP_ADD_E, OP_REM_E]),
                random.randrange(12), random.randrange(12), -1)
               for _ in range(12)]
        b = make_op_batch(ops)
        gd, rd = apply_ops_fast(gd, b)
        gs, rs = partition.apply_ops_fast(gs, b)
        assert np.array_equal(np.asarray(rd), np.asarray(rs)), (np.asarray(rd), np.asarray(rs))
    for name, a, c in zip(gd._fields, gd, partition.unshard(gs)):
        assert np.array_equal(np.asarray(a), np.asarray(c)), name
    pairs = [(0, 7), (3, 11), (5, 5), (2, 9)]
    out_d, _ = get_paths_session(lambda: gd, pairs)
    out_s, _ = get_paths_session(lambda: gs, pairs)
    assert out_d == out_s, (out_d, out_s)
    gg = partition.grow(gs, 100)       # rounds up to 104 = 8 * 13
    assert gg.capacity % 8 == 0 and gg.capacity >= 100
    print("PARTITION_SUBPROCESS_OK")
""")


@pytest.mark.slow
def test_eight_shard_partition_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PARTITION_SUBPROCESS_OK" in r.stdout
