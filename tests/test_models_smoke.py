"""Per-arch smoke tests: reduced configs, one forward + one train step on CPU,
shape and finiteness assertions; prefill+decode == full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch import steps as steps_mod
from repro.models.model import build_model

RNG = jax.random.PRNGKey(0)


def _batch(cfg, b, s):
    toks = jax.random.randint(jax.random.PRNGKey(7), (b, s - cfg.n_vis_tokens),
                              0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.n_vis_tokens:
        batch["vis_embeds"] = jax.random.normal(
            jax.random.PRNGKey(8), (b, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(7), (b, s), 0, cfg.vocab),
                 "frames": jax.random.normal(jax.random.PRNGKey(9),
                                             (b, cfg.enc_frames, cfg.d_model), jnp.float32)}
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_train_step(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(RNG)
    b, s = 2, 32
    batch = _batch(cfg, b, s)

    loss, metrics = model.loss_and_metrics(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"

    step = steps_mod.make_train_step(model, lr=1e-3)
    opt = steps_mod.init_opt_state(params)
    p2, opt2, m2 = jax.jit(step)(params, opt, batch)
    assert jnp.isfinite(m2["loss"])
    # params actually changed and stayed finite
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(bb))
        for a, bb in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert changed, f"{arch}: train step was a no-op"
    for leaf in jax.tree.leaves(p2):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), f"{arch}: NaN params"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_forward(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    toks = batch["tokens"]
    s_tok = toks.shape[1]

    if cfg.family == "encdec":
        from repro.models import encdec as ed
        enc = ed.encode(cfg, params, batch["frames"])
        logits_full, _ = ed.decode_fwd(cfg, params, toks, enc, want_cache=False)
    else:
        logits_full, _, _ = model.forward(params, batch)

    p = s_tok - 4
    pb = dict(batch)
    pb["tokens"] = toks[:, :p]
    last, caches = model.prefill(params, pb)
    np.testing.assert_allclose(np.asarray(last), np.asarray(logits_full[:, p - 1]),
                               rtol=2e-3, atol=2e-3)
    if cfg.family == "encdec":
        (sk, sv), cross = caches
        pad = [(0, 0), (0, 0), (0, 32 - p), (0, 0), (0, 0)]
        caches = ((jnp.pad(sk, pad), jnp.pad(sv, pad)), cross)
    else:
        caches = model.cache_from_prefill(caches, cache_len=32)
    off = cfg.n_vis_tokens
    for t in range(p, s_tok):
        lg, caches = model.decode_step(params, caches, toks[:, t], jnp.int32(t + off))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full[:, t]),
                                   rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_input_specs_cover_all_runnable_shapes(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    from repro.configs import SHAPES
    for shape in SHAPES:
        if shape in cfg.skip_shapes:
            continue
        specs = model.input_specs(shape)
        assert "tokens" in specs
        for v in specs.values():
            assert isinstance(v, jax.ShapeDtypeStruct)


def test_param_counts_sane():
    """Analytic N vs actual leaf-count for the reduced configs (<2% off)."""
    for arch in ARCHS:
        cfg = get_config(arch).smoke()
        model = build_model(cfg)
        params_abs = model.init_abstract()
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params_abs))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.10, (
            f"{arch}: analytic {analytic} vs actual {actual}")
