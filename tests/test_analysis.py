"""Tests for repro.analysis (DESIGN.md §15).

Every rule fires on its deliberately-bad fixture at the marked lines
(``# LINT-EXPECT: <rule>``), every suppressed twin is silent, unused
allows and the baseline machinery behave, and the repo itself gates
clean — the same invocation CI runs.
"""
from __future__ import annotations

import json
import re
from pathlib import Path

import pytest

from repro.analysis import framework, get_rule
from repro.analysis.baseline import MAX_ENTRIES, Baseline, BaselineEntry
from repro.analysis.framework import Finding
from repro.analysis.rules.metrics_doc import missing_metrics, section_14

ROOT = Path(__file__).resolve().parent.parent
FIX = ROOT / "tests" / "lint_fixtures"
EXPECT_RE = re.compile(r"#\s*LINT-EXPECT:\s*([a-z\-]+)")

# (rule name, fixture stem) for the single-file rules
FILE_RULES = [
    ("mirror-write", "mirror_write"),
    ("traversable-predicate", "traversable"),
    ("lock-order", "lock_order"),
    ("trace-purity", "trace_purity"),
    ("epoch-freshness", "epoch_freshness"),
    ("design-refs", "design_refs"),
    ("durable-ack", "durable_ack"),
]
KERNEL_BAD = sorted((FIX / "kernel_pkg_bad").glob("*.py"))
KERNEL_SUP = sorted((FIX / "kernel_pkg_sup").glob("*.py"))


def expected_lines(path: Path, rule: str) -> list[int]:
    out = []
    for i, line in enumerate(path.read_text().splitlines(), 1):
        m = EXPECT_RE.search(line)
        if m and m.group(1) == rule:
            out.append(i)
    return out


def run_rule(rule: str, paths: list[Path]):
    return framework.run(ROOT, paths=paths, rules=[get_rule(rule)])


# ---------------------------------------------------------------------------
# every rule fires on its fixture, at exactly the marked lines
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rule,stem", FILE_RULES)
def test_rule_fires_at_marked_lines(rule, stem):
    bad = FIX / f"{stem}_bad.py"
    want = expected_lines(bad, rule)
    assert want, f"fixture {bad} has no LINT-EXPECT markers"
    result = run_rule(rule, [bad])
    got = sorted(f.line for f in result.findings)
    assert got == sorted(want), [f.render() for f in result.findings]
    assert all(f.rule == rule for f in result.findings)


@pytest.mark.parametrize("rule,stem", FILE_RULES)
def test_rule_suppressed_variant_is_silent(rule, stem):
    sup = FIX / f"{stem}_sup.py"
    result = run_rule(rule, [sup])
    assert result.findings == [], [f.render() for f in result.findings]
    assert result.suppressed, "the allow() should have caught a finding"


def test_kernel_shape_fires_on_drifted_package():
    result = run_rule("kernel-shape", KERNEL_BAD)
    messages = " | ".join(f.message for f in result.findings)
    assert "tile default drift" in messages
    assert "no assert in the wrapper enforces it" in messages
    assert "dtype drift" in messages
    assert "not found in ref.py" in messages
    assert "pad_safety" in messages
    assert "exceeds the tpu budget" in messages
    # the kernel.py-anchored findings land on the marked def line
    want = expected_lines(FIX / "kernel_pkg_bad" / "kernel.py",
                          "kernel-shape")
    kernel_lines = {f.line for f in result.findings
                    if f.path.endswith("kernel_pkg_bad/kernel.py")}
    assert set(want) <= kernel_lines


def test_kernel_shape_suppressed_variant_is_silent():
    result = run_rule("kernel-shape", KERNEL_SUP)
    assert result.findings == [], [f.render() for f in result.findings]
    assert result.suppressed, "the tile drift should be allow()-suppressed"


def test_unused_suppression_is_flagged():
    path = FIX / "unused_allow.py"
    want = expected_lines(path, "unused-suppression")
    result = run_rule("mirror-write", [path])
    assert [f.line for f in result.findings] == want
    assert result.findings[0].rule == framework.UNUSED_SUPPRESSION


def test_unused_check_scoped_to_active_rules():
    # running a DIFFERENT rule must not call the mirror-write allow dead
    result = run_rule("design-refs", [FIX / "unused_allow.py"])
    assert result.findings == [], [f.render() for f in result.findings]


# ---------------------------------------------------------------------------
# baseline machinery
# ---------------------------------------------------------------------------
def _finding(line=10):
    return Finding("traversable-predicate", "src/x.py", line,
                   "raw adjacency test — fixture")


def test_baseline_grandfathers_matching_finding():
    bl = Baseline([BaselineEntry(rule="traversable-predicate",
                                 path="src/x.py", why="fixture", line=10,
                                 contains="raw adjacency")])
    live, grand, stale = bl.apply([_finding()])
    assert live == [] and len(grand) == 1 and stale == []


def test_baseline_stale_entry_is_a_finding():
    bl = Baseline([BaselineEntry(rule="traversable-predicate",
                                 path="src/x.py", why="fixture")])
    live, grand, stale = bl.apply([])
    assert live == [] and grand == []
    assert [f.rule for f in stale] == ["stale-baseline"]


def test_baseline_stale_check_scoped_to_active_rules():
    bl = Baseline([BaselineEntry(rule="traversable-predicate",
                                 path="src/x.py", why="fixture")])
    _, _, stale = bl.apply([], active={"mirror-write"})
    assert stale == []


def test_baseline_cap_and_missing_why(tmp_path):
    entries = [{"rule": "r", "path": "p", "why": f"e{i}"}
               for i in range(MAX_ENTRIES + 1)]
    entries.append({"rule": "r", "path": "p"})  # no why
    f = tmp_path / "bl.json"
    f.write_text(json.dumps({"entries": entries}))
    bl = Baseline.load(f)
    _, _, stale = bl.apply([], active=set())
    msgs = " | ".join(x.message for x in stale)
    assert "caps it at" in msgs and "one-line why" in msgs


# ---------------------------------------------------------------------------
# metrics-doc pure core
# ---------------------------------------------------------------------------
def test_missing_metrics_core():
    doc = "## §14 — metrics\n\n| `ingest.batches` |\n\n## §1 — other\n"
    assert section_14(doc).startswith("## §14")
    assert missing_metrics(["ingest.batches"], doc) == []
    assert missing_metrics(["serve.lost"], doc) == ["serve.lost"]
    assert missing_metrics(["a", "b"], "no section") == ["a", "b"]


# ---------------------------------------------------------------------------
# CLI + the repo-wide gate (the exact CI invocation)
# ---------------------------------------------------------------------------
def test_cli_json_output(capsys):
    from repro.analysis.cli import main
    rc = main(["--root", str(ROOT), "--rule", "mirror-write",
               "--no-baseline", "--json",
               str(FIX / "mirror_write_bad.py")])
    data = json.loads(capsys.readouterr().out)
    assert rc == 1 and data["ok"] is False and data["findings"]


def test_cli_list_rules(capsys):
    from repro.analysis.cli import main
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for name in ("mirror-write", "kernel-shape", "metrics-doc"):
        assert name in out


def test_repo_gates_clean():
    """The acceptance criterion itself: the committed tree, with its
    committed baseline, has zero live findings."""
    bl = Baseline.load(ROOT / "analysis_baseline.json")
    assert len(bl.entries) <= MAX_ENTRIES
    result = framework.run(ROOT, baseline=bl)
    assert result.findings == [], [f.render() for f in result.findings]
    assert result.files_scanned > 100
    assert len(result.rules_run) >= 8
