"""Observability subsystem contract (DESIGN.md §14).

Three layers of guarantee, tiered by cost:

  * the DISABLED path is free — zero extra jit retraces on the scripted
    3-client ingest round (cache-key pin) and a <5% wall budget for the
    no-op span shells;
  * the ENABLED path is honest — traced ``multi_bfs`` / ``collect_batch``
    are bit-identical to their jitted forms (the spans move the jit
    boundary, never the math), and span nesting follows trace-event
    timestamp containment;
  * the EXPORTS round-trip — recorder -> Perfetto JSON ->
    ``tools/trace_view.py`` summary, ``get_metrics`` is JSON-serializable,
    and the DESIGN.md §14 metric table covers every declared name
    (tools/check_metrics_doc.py, exercised here so the drift check cannot
    itself drift out of CI).
"""
import importlib.util
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    OP_ADD_E, OP_ADD_V, apply_ops, collect_batch, find_slot, make_graph,
    make_op_batch, multi_bfs,
)
# the package re-exports the bfs() FUNCTION under the submodule's name,
# so fetch the modules themselves for the jit-cache pins
bfs_mod = importlib.import_module("repro.core.bfs")
snapshot_mod = importlib.import_module("repro.core.snapshot")
from repro.obs import trace
from repro.obs.metrics import (
    GLOBAL, OBS_METRICS, MetricsRegistry, StatsView, global_registry,
)
from repro.runtime.ingest import IngestStats
from repro.runtime.serve_loop import GraphCoServer

from tests.test_serving_stats import A_OPS, B_OPS, C_OPS, _fake_clock

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _load_tool(name):
    """Import a tools/ script by file path (tools/ is not a package)."""
    spec = importlib.util.spec_from_file_location(
        name, ROOT / "tools" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _build(nv=10, extra_edges=(), cap=32):
    g = make_graph(cap)
    ops = [(OP_ADD_V, k, -1, -1) for k in range(nv)]
    ops += [(OP_ADD_E, k, k + 1, -1) for k in range(nv - 1)]
    ops += [(op, u, v, -1) for (op, u, v) in extra_edges]
    g, _ = apply_ops(g, make_op_batch(ops))
    return g


def _scripted_round(clock=None):
    """The scripted 3-client admission round from tests/test_serving_stats
    plus one GetPath batch — the workload both overhead pins run."""
    srv = GraphCoServer(capacity=32, ingest=True)
    if clock is not None:
        srv.pool.clock = clock
    srv.submit_client("A", A_OPS)
    srv.submit_client("B", B_OPS)
    srv.submit_client("C", C_OPS)
    assert srv.pump() == 2
    assert srv.pump() == 1
    out, _ = srv.get_paths([(1, 12), (5, 5)])
    assert out[0] == (True, [1, 12])
    return srv


# -- export round-trip ------------------------------------------------------

def test_trace_roundtrip_through_trace_view(tmp_path):
    with trace.capture() as rec:
        with trace.span("outer", kind="test"):
            with trace.span("inner", step=0):
                pass
            with trace.span("inner", step=1):
                pass
        trace.counter("ring.occupancy", 3)
        path = rec.save(str(tmp_path / "t.json"))

    doc = json.loads(pathlib.Path(path).read_text())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    assert len(doc["traceEvents"]) == 4

    tv = _load_tool("trace_view")
    events = tv.load(path)
    summ = tv.summarize(events)
    assert summ["spans"]["inner"]["count"] == 2
    assert summ["spans"]["outer"]["count"] == 1
    assert summ["spans"]["outer"]["total_us"] > 0
    assert summ["counters"]["ring.occupancy"] == 1
    tv.print_summary(summ)  # must not raise on a real summary


def test_trace_view_accepts_bare_event_list(tmp_path):
    tv = _load_tool("trace_view")
    p = tmp_path / "bare.json"
    p.write_text(json.dumps([{"name": "x", "ph": "X", "ts": 0.0,
                              "dur": 1.0, "pid": 1, "tid": 1}]))
    assert tv.summarize(tv.load(str(p)))["spans"]["x"]["count"] == 1


def test_span_nesting_is_timestamp_containment():
    with trace.capture() as rec:
        with trace.span("parent"):
            with trace.span("child"):
                time.sleep(0.001)
    by_name = {e["name"]: e for e in rec.events()}
    p, c = by_name["parent"], by_name["child"]
    assert p["tid"] == c["tid"]
    assert p["ts"] <= c["ts"]
    assert c["ts"] + c["dur"] <= p["ts"] + p["dur"]


def test_capture_restores_disabled_state_and_isolates_events():
    assert not trace.enabled()
    with trace.capture() as rec:
        assert trace.enabled()
        with trace.span("only"):
            pass
        assert [e["name"] for e in rec.events()] == ["only"]
    assert not trace.enabled()
    with trace.span("dropped"):   # disabled: the null span records nothing
        pass
    with trace.capture() as rec2:  # fresh capture starts empty
        assert rec2.events() == []


# -- metrics registry + stat views -----------------------------------------

def test_metrics_registry_typed_behaviour():
    reg = MetricsRegistry()
    reg.declare("a.count", "counter")
    reg.declare("a.count", "counter")            # idempotent
    with pytest.raises(ValueError, match="re-declared"):
        reg.declare("a.count", "gauge")
    with pytest.raises(ValueError, match="unknown metric kind"):
        reg.declare("a.bad", "timer")

    reg.declare("a.lat_s", "histogram")
    with pytest.raises(TypeError, match="observe"):
        reg.set("a.lat_s", 1.0)
    reg.observe("a.lat_s", 2.0)
    reg.observe("a.lat_s", 0.5)
    assert reg.get("a.lat_s") == {"count": 2, "sum": 2.5,
                                  "min": 0.5, "max": 2.0}

    reg.inc("a.count", 3)
    assert reg.get("a.count") == 3
    assert reg.names() == ["a.count", "a.lat_s"]
    snap = reg.snapshot()
    snap["a.lat_s"]["count"] = 99                # snapshot is a copy
    assert reg.get("a.lat_s")["count"] == 2


def test_stats_view_routes_fields_to_registry():
    reg = MetricsRegistry()
    s = IngestStats(reg)
    s.submitted += 2
    s.wait_max_s = 3.5
    assert reg.get("ingest.submitted") == 2
    assert reg.get("ingest.wait_max_s") == 3.5
    assert s.snapshot()["submitted"] == 2
    assert set(s.snapshot()) == set(IngestStats._SPEC)
    assert "submitted=2" in repr(s)
    with pytest.raises(AttributeError, match="no field"):
        s.nonexistent_field


def test_global_registry_predeclares_every_obs_metric():
    assert global_registry() is GLOBAL
    for name, (kind, _doc) in OBS_METRICS.items():
        assert GLOBAL.kind(name) == kind


def test_metrics_doc_drift_check_passes_on_this_repo():
    """The CI drift gate, run in-process: every declared metric name is in
    DESIGN.md §14's table, and the §14 extractor actually isolates §14."""
    cmd = _load_tool("check_metrics_doc")
    sec = cmd.section_14((ROOT / "DESIGN.md").read_text(encoding="utf-8"))
    assert sec.startswith("## §14")
    assert "## §13" not in sec
    names = cmd.declared_metrics()
    assert "bfs.supersteps" in names and "serve.wall_s" in names
    assert [n for n in names if n not in sec] == []
    assert cmd.main() == 0


# -- disabled path is free --------------------------------------------------

def test_disabled_tracing_adds_zero_jit_retraces():
    """Cache-key pin: with tracing disabled, re-running the scripted
    ingest round + GetPath batch hits the existing jit caches — the
    instrumentation never perturbs a traced signature (DESIGN.md §14)."""
    assert not trace.enabled()
    _scripted_round(_fake_clock())              # warm every cache
    sizes = {f.__name__: f._cache_size() for f in
             (bfs_mod._multi_bfs_jit, bfs_mod._multi_superstep_jit,
              snapshot_mod._collect_batch_jit,
              snapshot_mod._collect_batch_finish_jit)}
    assert sizes["_collect_batch_jit"] >= 1
    assert sizes["_multi_superstep_jit"] == 0   # traced-only entry point
    _scripted_round(_fake_clock())              # identical second run
    for fn in (bfs_mod._multi_bfs_jit, bfs_mod._multi_superstep_jit,
               snapshot_mod._collect_batch_jit,
               snapshot_mod._collect_batch_finish_jit):
        assert fn._cache_size() == sizes[fn.__name__], fn.__name__


def test_disabled_span_overhead_under_5pct_of_ingest_round():
    """The wall budget: (cost of one disabled span shell) x (number of
    spans the workload would emit) must stay under 5% of the workload's
    measured wall. Span count comes from an enabled capture of the SAME
    scripted workload; the fake pool clock keeps admission deterministic."""
    with trace.capture() as rec:
        _scripted_round(_fake_clock())
        n_spans = len(rec.events())
    assert n_spans >= 10                         # the workload is instrumented

    assert not trace.enabled()
    reps = 20000
    t0 = time.perf_counter()
    for _ in range(reps):
        with trace.span("x", a=1):
            pass
    per_span = (time.perf_counter() - t0) / reps

    t0 = time.perf_counter()
    _scripted_round(_fake_clock())
    wall = time.perf_counter() - t0

    overhead = per_span * n_spans
    assert overhead < 0.05 * wall, (
        f"{n_spans} disabled spans cost {overhead*1e6:.1f}us "
        f"vs round wall {wall*1e3:.1f}ms")


# -- enabled path is honest -------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "hybrid", "packed"])
def test_traced_multi_bfs_bit_identical_to_jit(backend):
    g = _build(nv=10, extra_edges=[(OP_ADD_E, 9, 0), (OP_ADD_E, 2, 7)])
    srcs = jnp.asarray([int(find_slot(g, k)) for k in (0, 3, 9, 5)], jnp.int32)
    dsts = jnp.asarray([int(find_slot(g, k)) for k in (9, 3, 1, 0)], jnp.int32)

    base = multi_bfs(g, srcs, dsts, backend=backend)
    with trace.capture() as rec:
        traced = multi_bfs(g, srcs, dsts, backend=backend)
        steps = [e for e in rec.events() if e["name"] == "bfs.superstep"]
        sessions = [e for e in rec.events() if e["name"] == "bfs.session"]

    for f in base._fields:
        np.testing.assert_array_equal(np.asarray(getattr(base, f)),
                                      np.asarray(getattr(traced, f)), f)
    assert len(sessions) == 1
    assert len(steps) == int(base.supersteps)
    assert sessions[0]["args"]["supersteps"] == int(base.supersteps)
    dirs = {e["args"]["direction"] for e in steps}
    if backend != "hybrid":
        assert dirs == {"push"}                  # non-hybrid never pulls
    assert dirs <= {"push", "pull"}


def test_traced_multi_bfs_updates_global_superstep_counters():
    g = _build(nv=8)
    s = jnp.asarray([int(find_slot(g, 0))], jnp.int32)
    d = jnp.asarray([int(find_slot(g, 7))], jnp.int32)
    before = GLOBAL.get("bfs.supersteps")
    with trace.capture():
        res = multi_bfs(g, s, d, backend="jnp")
    assert GLOBAL.get("bfs.supersteps") - before == int(res.supersteps)


def test_traced_collect_batch_bit_identical_to_jit():
    g = _build(nv=10, extra_edges=[(OP_ADD_E, 4, 0)])
    ks = jnp.asarray([0, 5, 9], jnp.int32)
    ls = jnp.asarray([9, 2, 0], jnp.int32)

    base = collect_batch(g, ks, ls, engine="fused")
    with trace.capture() as rec:
        traced = collect_batch(g, ks, ls, engine="fused")
        assert any(e["name"] == "bfs.session" for e in rec.events())

    for f in base._fields:
        np.testing.assert_array_equal(np.asarray(getattr(base, f)),
                                      np.asarray(getattr(traced, f)), f)


def test_traced_get_paths_session_spans_and_answers():
    srv = GraphCoServer(capacity=32, ingest=True)
    srv.submit(A_OPS + B_OPS + C_OPS)
    with trace.capture() as rec:
        out, rounds = srv.get_paths([(1, 12), (11, 12), (12, 1)])
    assert out[0] == (True, [1, 12])
    assert out[1] == (True, [11, 12])
    assert out[2] == (False, [])
    names = [e["name"] for e in rec.events()]
    sess = [e for e in rec.events() if e["name"] == "session.get_paths"]
    assert len(sess) == 1
    assert sess[0]["args"]["pairs"] == 3
    assert sess[0]["args"]["rounds"] == rounds
    assert sess[0]["args"]["resolved"] in ("match", "epoch", "budget")
    assert names.count("collect.round") >= 2     # the double collect


# -- serving endpoint -------------------------------------------------------

def test_get_metrics_endpoint_snapshot():
    srv = GraphCoServer(capacity=32, ingest=True, index=True)
    srv.submit_client("A", A_OPS)
    srv.submit_client("B", B_OPS)
    assert srv.pump() == 2
    assert srv.index_tick() is True
    srv.get_reach([(1, 2), (11, 12)])

    m = srv.get_metrics()
    assert m["server.index_refreshes"] == 1
    assert m["server.index_hits"] == 2
    assert m["ingest.submitted"] == 2
    assert m["ingest.epochs"] == 1
    # epoch 0 (the empty initial state) is retained too
    assert (m["ring.window_lo"], m["ring.window_hi"]) == (0, 1)
    # every global tracing metric rides along, histogram or scalar
    for name in OBS_METRICS:
        assert name in m
    json.dumps(m)                                # plain JSON-serializable


def test_get_metrics_shares_pool_registry_with_stats_view():
    srv = GraphCoServer(capacity=16, ingest=True)
    srv.submit_client("A", A_OPS)
    srv.pump()
    assert srv.get_metrics()["ingest.applied"] == srv.pool.stats.applied == 1
