"""Property-based linearizability tests (paper Thm 4.1), via hypothesis.

Every batched execution must be equivalent to the sequential oracle replay
in the linearization order. For ``apply_ops`` that order is lane order by
construction; for ``apply_ops_fast`` the disjoint-access argument (clean
lanes commute with every lane) implies lane-order equivalence as well — so
both engines must match the oracle exactly, results and final state.
"""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from repro.testing.proptest import given, settings, strategies as st

from repro.core import (
    OP_ADD_E, OP_ADD_V, OP_CON_E, OP_CON_V, OP_NOP, OP_REM_E, OP_REM_V,
    GraphOracle, apply_ops, apply_ops_fast, make_graph, make_op_batch,
)

KEYS = st.integers(min_value=0, max_value=7)
OPC = st.sampled_from([OP_ADD_V, OP_REM_V, OP_CON_V, OP_ADD_E, OP_REM_E, OP_CON_E])
OP = st.tuples(OPC, KEYS, KEYS, st.sampled_from([-1, -1, -1, 0, 1, 2]))
CAP = 32


def _alive_keys_and_state(g):
    vkey = np.asarray(g.vkey)
    valive = np.asarray(g.valive)
    adj = np.asarray(g.adj)
    ecnt = np.asarray(g.ecnt)
    keys = {}
    edges = set()
    for i in range(len(vkey)):
        if valive[i]:
            keys[int(vkey[i])] = int(ecnt[i])
    for i in range(len(vkey)):
        if not valive[i]:
            continue
        for j in np.nonzero(adj[i])[0]:
            if valive[j]:
                edges.add((int(vkey[i]), int(vkey[j])))
    return keys, edges


def _run_and_check(op_lists, engine):
    g = make_graph(CAP)
    oracle = GraphOracle(CAP)
    for ops in op_lists:
        batch = make_op_batch(ops)
        g, res = engine(g, batch)
        want = oracle.apply_batch(ops)
        got = [int(x) for x in np.asarray(res)]
        assert got == want, f"results diverge: {got} vs {want} for {ops}"
    keys, edges = _alive_keys_and_state(g)
    assert keys == oracle.ecnt, f"ecnt/alive mismatch: {keys} vs {oracle.ecnt}"
    assert edges == oracle.edges, f"edges mismatch: {edges} vs {oracle.edges}"


@settings(max_examples=30, deadline=None)
@given(st.lists(st.lists(OP, min_size=1, max_size=8), min_size=1, max_size=4))
def test_serial_engine_linearizable(op_lists):
    _run_and_check(op_lists, apply_ops)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.lists(OP, min_size=1, max_size=8), min_size=1, max_size=4))
def test_fast_engine_linearizable(op_lists):
    _run_and_check(op_lists, apply_ops_fast)


@settings(max_examples=15, deadline=None)
@given(st.lists(OP, min_size=1, max_size=24))
def test_engines_agree(ops):
    """Serial and disjoint-access engines produce identical histories.

    Results must match exactly; final states are compared as ABSTRACT state
    (alive keys + ecnt + edges) — concrete slot placement may differ because
    the fast engine allocates clean lanes before conflicting ones.
    """
    batch = make_op_batch(ops)
    g1, r1 = apply_ops(make_graph(CAP), batch)
    g2, r2 = apply_ops_fast(make_graph(CAP), batch)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    assert _alive_keys_and_state(g1) == _alive_keys_and_state(g2)
