"""Extensions: undirected mode (paper footnote a) + wait-free neighborhood
queries (the traversal-return missing from Kallimanis & Kanellou 2015)."""
import numpy as np

from repro.core import (
    R_EDGE_ADDED, R_EDGE_PRESENT, R_EDGE_REMOVED, R_VERTEX_NOT_PRESENT,
    add_edge, add_edge_undirected, add_vertex, collect, compare_collects,
    degree, get_path, make_graph, neighbors, remove_edge_undirected,
)


def build(n=6):
    g = make_graph(32)
    for k in range(n):
        g, _ = add_vertex(g, k)
    return g


def test_undirected_add_creates_both_directions():
    g = build()
    g, r = add_edge_undirected(g, 1, 4)
    assert int(r) == R_EDGE_ADDED
    assert bool(get_path(g, 1, 4).found) and bool(get_path(g, 4, 1).found)
    g, r = add_edge_undirected(g, 1, 4)
    assert int(r) == R_EDGE_PRESENT
    g, r = remove_edge_undirected(g, 4, 1)     # removable from either end
    assert int(r) == R_EDGE_REMOVED
    assert not bool(get_path(g, 1, 4).found)
    assert not bool(get_path(g, 4, 1).found)


def test_undirected_bumps_both_endpoint_versions():
    """Double collects through EITHER endpoint must observe the mutation."""
    g = build()
    g, _ = add_edge(g, 0, 1)
    c_from_1 = collect(g, 1, 5)                 # expands row 1
    g2, _ = add_edge_undirected(g, 2, 1)        # touches rows 2 AND 1
    g3, _ = remove_edge_undirected(g2, 2, 1)    # restore the edge set
    c2 = collect(g3, 1, 5)
    assert not bool(compare_collects(c_from_1, c2))


def test_undirected_missing_vertex():
    g = build()
    g, r = add_edge_undirected(g, 0, 99)
    assert int(r) == R_VERTEX_NOT_PRESENT


def test_neighbors_and_degree():
    g = build()
    for dst in (1, 3, 5):
        g, _ = add_edge(g, 0, dst)
    g, _ = add_edge(g, 2, 0)
    n, keys = neighbors(g, 0)
    assert int(n) == 3
    assert sorted(int(k) for k in np.asarray(keys)[:3]) == [1, 3, 5]
    out_d, in_d = degree(g, 0)
    assert (int(out_d), int(in_d)) == (3, 1)
    out_d, in_d = degree(g, 42)
    assert (int(out_d), int(in_d)) == (-1, -1)


def test_neighbors_excludes_dead_vertices():
    from repro.core import remove_vertex
    g = build()
    g, _ = add_edge(g, 0, 1)
    g, _ = add_edge(g, 0, 2)
    g, _ = remove_vertex(g, 1)                  # lazy ENode: row bit remains
    n, keys = neighbors(g, 0)
    assert int(n) == 1 and int(keys[0]) == 2    # marked ptv filtered out
