"""Property suite: BIT-identity of the fast and sharded engines (DESIGN.md §8).

Stronger than tests/test_linearizability.py (which checks result codes and
ABSTRACT state): here the engines must agree on the concrete arrays — slot
placement, ecnt, vver, adjacency bits — under deliberately colliding key
workloads. Three properties:

  1. ``apply_ops_fast`` == ``apply_ops`` (the sequential spec), results and
     final state, bit for bit. This is what licenses swapping the engines
     anywhere, including under an outstanding double collect: equal version
     vectors then really mean equal states.
  2. The mesh-partitioned ``partition.apply_ops_fast`` == the dense fast
     engine, bit for bit (after unshard).
  3. The mesh-partitioned ``partition.multi_bfs`` == the dense fused BFS,
     every result field bit for bit, and the path results delivered through
     the shared-double-collect session agree.

Op/batch generation comes from the shared schedule driver
(``repro.testing.schedules``): keys are drawn from a tiny space (0..5) so
most batches collide; ``expect`` values exercise the CAS path; capacity-6
cases force the R_TABLE_FULL overflow fallback. Under CI's 8-virtual-device
job the mesh really has 8 shards; in a single-device container it
degenerates (the subprocess test in tests/test_partition.py covers 8 shards
regardless).
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from repro.testing.proptest import given, settings, strategies as st

from repro.core import (
    OP_ADD_E, OP_ADD_V, OP_REM_V,
    apply_ops, apply_ops_fast, make_graph, make_op_batch, multi_bfs,
)
from repro.core import partition
from repro.core.distributed import make_graph_mesh
from repro.testing.schedules import batch_lists_strategy, batch_strategy

BATCHES = batch_lists_strategy(st)   # tiny key space => many collisions
CAP = 32


def _assert_states_bitwise_equal(a, b, ctx=""):
    for name, xa, xb in zip(a._fields, a, b):
        np.testing.assert_array_equal(
            np.asarray(xa), np.asarray(xb),
            err_msg=f"{ctx}field {name!r} diverges")


@settings(max_examples=30, deadline=None)
@given(BATCHES)
def test_fast_engine_bitwise_equals_sequential_spec(op_lists):
    g_spec = g_fast = make_graph(CAP)
    for ops in op_lists:
        batch = make_op_batch(ops)
        g_spec, r_spec = apply_ops(g_spec, batch)
        g_fast, r_fast = apply_ops_fast(g_fast, batch)
        np.testing.assert_array_equal(
            np.asarray(r_spec), np.asarray(r_fast),
            err_msg=f"result codes diverge for {ops}")
    _assert_states_bitwise_equal(g_spec, g_fast)


def test_cas_lane_observes_earlier_remove_vertex_bump():
    """Regression: a CAS edge lane key-disjoint from an earlier RemoveVertex
    must still observe the RemoveVertex's in-edge ecnt bump (the one
    cross-key ecnt write). Setup: edge 0->1 alive, ecnt[0]=1; batch
    [(REM_V 1), (ADD_E 0,2 expect=1)] — removing 1 bumps ecnt[0] to 2, so
    the CAS must fail in every engine."""
    from repro.core import R_CAS_FAIL, R_TRUE

    setup = [(OP_ADD_V, 0), (OP_ADD_V, 1), (OP_ADD_V, 2), (OP_ADD_E, 0, 1)]
    g, _ = apply_ops(make_graph(CAP), make_op_batch(setup))
    batch = make_op_batch([(OP_REM_V, 1, -1, -1), (OP_ADD_E, 0, 2, 1)])
    g_spec, r_spec = apply_ops(g, batch)
    assert [int(x) for x in np.asarray(r_spec)] == [R_TRUE, R_CAS_FAIL]
    g_fast, r_fast = apply_ops_fast(g, batch)
    np.testing.assert_array_equal(np.asarray(r_spec), np.asarray(r_fast))
    _assert_states_bitwise_equal(g_spec, g_fast, ctx="cas-after-remv ")
    mesh = make_graph_mesh()
    g_shard, r_shard = partition.apply_ops_fast(
        partition.shard_state(mesh, g), batch)
    np.testing.assert_array_equal(np.asarray(r_spec), np.asarray(r_shard))
    _assert_states_bitwise_equal(g_spec, partition.unshard(g_shard),
                                 ctx="sharded cas-after-remv ")


@settings(max_examples=15, deadline=None)
@given(batch_strategy(st, max_size=16))
def test_fast_engine_bitwise_under_table_full(ops):
    """Capacity 6 < distinct keys: the overflow fallback must stay bit-exact
    through R_TABLE_FULL results."""
    batch = make_op_batch(ops)
    g_spec, r_spec = apply_ops(make_graph(6), batch)
    g_fast, r_fast = apply_ops_fast(make_graph(6), batch)
    np.testing.assert_array_equal(np.asarray(r_spec), np.asarray(r_fast))
    _assert_states_bitwise_equal(g_spec, g_fast, ctx="table-full ")


@settings(max_examples=20, deadline=None)
@given(BATCHES)
def test_sharded_engine_bitwise_equals_dense(op_lists):
    mesh = make_graph_mesh()
    g_dense = make_graph(CAP)
    g_shard = partition.shard_state(mesh, g_dense)
    for ops in op_lists:
        batch = make_op_batch(ops)
        g_dense, r_dense = apply_ops_fast(g_dense, batch)
        g_shard, r_shard = partition.apply_ops_fast(g_shard, batch)
        np.testing.assert_array_equal(
            np.asarray(r_dense), np.asarray(r_shard),
            err_msg=f"sharded result codes diverge for {ops}")
    _assert_states_bitwise_equal(g_dense, partition.unshard(g_shard),
                                 ctx="sharded ")


@settings(max_examples=12, deadline=None)
@given(batch_strategy(st, max_size=20),
       st.lists(st.tuples(st.integers(0, 5), st.integers(0, 5)),
                min_size=1, max_size=4))
def test_sharded_multi_bfs_bitwise_equals_dense(ops, pairs):
    mesh = make_graph_mesh()
    g_dense, _ = apply_ops_fast(make_graph(CAP), make_op_batch(ops))
    g_shard = partition.shard_state(mesh, g_dense)
    srcs = np.asarray([p[0] for p in pairs], np.int32)
    dsts = np.asarray([p[1] for p in pairs], np.int32)
    # query by SLOT: map keys through the (replicated) slot table
    from repro.core import find_slots
    sk = np.asarray(find_slots(g_dense, srcs))
    sl = np.asarray(find_slots(g_dense, dsts))
    dense = multi_bfs(g_dense, sk, sl)
    shard = partition.multi_bfs(g_shard, sk, sl)
    for name, xa, xb in zip(dense._fields, dense, shard):
        np.testing.assert_array_equal(
            np.asarray(xa), np.asarray(xb),
            err_msg=f"multi_bfs field {name!r} diverges")


@settings(max_examples=8, deadline=None)
@given(batch_strategy(st, max_size=20),
       st.lists(st.tuples(st.integers(0, 5), st.integers(0, 5)),
                min_size=1, max_size=3))
def test_sharded_getpaths_session_equals_dense(ops, pairs):
    """End-to-end: the shared-double-collect session returns identical
    (found, path keys) on dense and sharded state."""
    from repro.core import get_paths_session

    mesh = make_graph_mesh()
    g_dense, _ = apply_ops_fast(make_graph(CAP), make_op_batch(ops))
    g_shard = partition.shard_state(mesh, g_dense)
    out_d, rounds_d = get_paths_session(lambda: g_dense, pairs)
    out_s, rounds_s = get_paths_session(lambda: g_shard, pairs)
    assert out_d == out_s
    assert rounds_d == rounds_s == 2
