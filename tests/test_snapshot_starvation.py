"""Double-collect starvation regression: wait-free epoch resolution.

The paper's GetPath is obstruction-free only — a mutator that commits
between every pair of collects starves the query FOREVER under the old
``max_rounds=None`` default (the PR-6 liveness hole). This suite pins the
fix (DESIGN.md §13) at every layer:

  * the session layer terminates BOUNDED under the worst-case adversary
    (a mutation in the query's dependency set on every single fetch), in
    both conflict modes: "retry" (bounded give-up, ``starved=True``) and
    "epoch" (wait-free resolution against one pinned published epoch);
  * the epoch-pinned answer is CORRECT: it equals the sequential oracle
    replay of the pool's linearization prefix at the pinned epoch;
  * dense and sharded states behave identically;
  * the serving layer (``GraphCoServer``) surfaces the events through
    ``ServeStats`` and takes the ring-validated index path when the index
    is stale at head but its build epoch is still retained.
"""
import numpy as np
import pytest

from repro.core import (
    OP_ADD_E,
    OP_ADD_V,
    OP_REM_E,
    GraphOracle,
    get_path_session,
    get_paths_session,
    make_graph,
)
from repro.core.distributed import make_graph_mesh
from repro.runtime.ingest import IngestPool
from repro.runtime.serve_loop import GraphCoServer, serve

CHAIN = 6


def _chain_pool(mesh=None, capacity=64, retain=64) -> IngestPool:
    """Pool holding the chain 0 -> 1 -> ... -> CHAIN-1."""
    from repro.core import partition

    dense = make_graph(capacity)
    state = partition.shard_state(mesh, dense) if mesh is not None else dense
    pool = IngestPool(state, mesh=mesh, retain_epochs=retain)
    for k in range(CHAIN):
        pool.submit("seed", [(OP_ADD_V, k)])
    for k in range(CHAIN - 1):
        pool.submit("seed", [(OP_ADD_E, k, k + 1)])
    pool.flush()
    return pool


def _hostile_fetch(pool, src=0):
    """State fetch that first commits a mutation bumping ``src``'s ecnt —
    the §3.5 adversary at maximum rate: NO two consecutive collects can
    ever match, so an unbounded retry loop would spin forever."""
    def fetch():
        fresh = 1000 + pool.stats.submitted   # unique across sessions
        pool.submit("_adv", [(OP_ADD_V, fresh), (OP_ADD_E, src, fresh)])
        pool.flush()
        return pool.snapshot()

    return fetch


def _oracle_at(pool, epoch) -> GraphOracle:
    """Sequential oracle replay of the linearization prefix ``epoch``
    published — the serial state the pinned answer must agree with."""
    prefix = pool.linearization_prefix(epoch)
    oracle = GraphOracle(pool.snapshot().capacity)
    for bid in pool.linearization[:prefix]:
        for op in pool.tickets[bid].ops:    # ops may be short tuples
            k1 = op[1] if len(op) > 1 else -1
            k2 = op[2] if len(op) > 2 else -1
            ex = op[3] if len(op) > 3 else -1
            oracle.apply(op[0], k1, k2, ex)
    return oracle


@pytest.mark.parametrize("sharded", [False, True], ids=["dense", "sharded"])
def test_starved_session_resolves_waitfree_with_correct_epoch_answer(sharded):
    """THE regression: mutator commits on every fetch; the query must
    terminate in <= max_rounds + 1 collects and its epoch-pinned answers
    must equal the oracle at the pinned linearization prefix."""
    mesh = make_graph_mesh() if sharded else None
    pool = _chain_pool(mesh)
    pairs = [(0, CHAIN - 1), (CHAIN - 1, 0), (0, 3)]
    st: dict = {}
    out, rounds = get_paths_session(
        _hostile_fetch(pool), pairs, max_rounds=4, on_conflict="epoch",
        fetch_epoch=pool.snapshot_epoch, stats=st)
    assert rounds == 5                    # budget 4 + the one pinned collect
    assert st["starved"] and st["resolved"] == "epoch"
    assert st["epoch"] is not None
    oracle = _oracle_at(pool, st["epoch"])
    for (k, l), (found, keys) in zip(pairs, out):
        assert found == oracle.reachable(k, l), (k, l)
        if found:
            assert oracle.is_valid_path(keys, k, l)
    assert out[0][0] is True and out[1][0] is False


def test_retry_mode_terminates_bounded_and_reports_starved():
    """The pre-ring deviation stays available: on_conflict="retry" gives up
    at the budget with (False, []) per pair and starved=True — bounded, so
    callers can resubmit instead of hanging the serving loop."""
    pool = _chain_pool()
    st: dict = {}
    out, rounds = get_paths_session(
        _hostile_fetch(pool), [(0, CHAIN - 1)], max_rounds=3,
        on_conflict="retry", stats=st)
    assert rounds == 3
    assert out == [(False, [])]
    assert st["starved"] and st["resolved"] == "budget"


def test_default_max_rounds_is_bounded_not_infinite():
    """Satellite bugfix: the old default (max_rounds=None) spun forever
    under sustained mutation. The default budget must terminate the session
    on its own — this test HANGS on the old code."""
    pool = _chain_pool()
    out, rounds = get_paths_session(_hostile_fetch(pool), [(0, 1)])
    assert rounds == 16                   # the new bounded default
    pr = get_path_session(_hostile_fetch(pool), 0, 1)
    assert int(pr.rounds) == 16
    assert bool(pr.starved)


def test_single_path_session_epoch_mode_pins_answer():
    pool = _chain_pool()
    pr = get_path_session(_hostile_fetch(pool), 0, CHAIN - 1, max_rounds=3,
                          on_conflict="epoch",
                          fetch_epoch=pool.snapshot_epoch)
    assert bool(pr.found)
    assert bool(pr.starved)
    assert int(pr.rounds) == 4
    keys = [int(x) for x in np.asarray(pr.keys)[: int(pr.length)]]
    assert keys == list(range(CHAIN))     # the chain is the only path


def test_unknown_on_conflict_mode_rejected():
    g = make_graph(8)
    with pytest.raises(ValueError):
        get_paths_session(lambda: g, [(0, 1)], on_conflict="banana")
    with pytest.raises(ValueError):
        get_path_session(lambda: g, 0, 1, on_conflict="banana")


def test_quiet_session_matches_without_touching_the_budget():
    """No mutation => the second collect matches and neither conflict mode
    changes anything (the fix costs nothing on the fast path)."""
    pool = _chain_pool()
    for mode in ("retry", "epoch"):
        st: dict = {}
        out, rounds = get_paths_session(
            lambda: pool.snapshot(), [(0, CHAIN - 1)], max_rounds=4,
            on_conflict=mode, fetch_epoch=pool.snapshot_epoch, stats=st)
        assert rounds == 2
        assert out[0][0] is True
        assert not st["starved"] and st["resolved"] == "match"


def _hostile_server(index=False, retain=64):
    """Ingest-backed server whose published snapshot is re-mutated on every
    read — the server-level restatement of the hostile fetch."""
    srv = GraphCoServer(capacity=64, ingest=True, index=index,
                        retain_epochs=retain)
    for k in range(CHAIN):
        srv.submit([(OP_ADD_V, k)])
    for k in range(CHAIN - 1):
        srv.submit([(OP_ADD_E, k, k + 1)])
    if index:
        srv.index_tick()
    orig = srv.pool.snapshot

    def hostile_snapshot():
        fresh = 2000 + srv.pool.stats.submitted   # unique across sessions
        srv.pool.submit("_adv", [(OP_ADD_V, fresh), (OP_ADD_E, 0, fresh)])
        srv.pool.pump()
        return orig()

    srv.pool.snapshot = hostile_snapshot
    return srv


def test_server_get_paths_resolves_waitfree_and_counts_events():
    srv = _hostile_server()
    assert srv.on_conflict == "epoch"     # pool-backed default
    out, rounds = srv.get_paths([(0, CHAIN - 1)], max_rounds=3)
    assert out[0][0] is True
    assert srv.getpath_starved == 1
    assert srv.epoch_resolved == 1


def test_server_get_path_singleton_starved_counters():
    srv = _hostile_server()
    pr = srv.get_path(0, CHAIN - 1, max_rounds=3)
    assert bool(pr.found) and bool(pr.starved)
    assert srv.getpath_starved == 1
    assert srv.epoch_resolved == 1


def test_server_ring_validates_stale_index_pins_epoch():
    """Satellite bugfix: an index made stale by a mutation RACING the
    session (published between the session's admitted-epoch read and its
    state fetch) must keep serving decided pairs, pinned to the still-
    retained build epoch, instead of dumping the whole batch to the BFS
    fallback — index_hits stays pinned for the decided pairs."""
    srv = GraphCoServer(capacity=64, ingest=True, index=True,
                        retain_epochs=64)
    for k in range(CHAIN):
        srv.submit([(OP_ADD_V, k)])
    for k in range(CHAIN - 1):
        srv.submit([(OP_ADD_E, k, k + 1)])
    srv.index_tick()                      # index fresh at this epoch
    orig = srv.pool.snapshot

    def racing_snapshot():
        # fires INSIDE the session, after fetch_epoch() admitted it: the
        # head moves but the index's epoch is within the invocation window
        srv.pool.submit("_adv", [(OP_ADD_V, 50), (OP_ADD_E, 50, 0)])
        srv.pool.pump()
        return orig()

    srv.pool.snapshot = racing_snapshot
    res = srv.get_reach([(0, CHAIN - 1), (CHAIN - 1, 0), (50, 1)])
    assert res.pinned_epoch is not None
    assert not res.stale                  # the batch did NOT go whole-stale
    # answers pin to the admitted epoch: vertex 50 did not exist there
    assert res.found == [True, False, False]
    assert res.from_index + res.fellback == 3
    assert srv.index_hits == res.from_index
    assert srv.index_misses == res.fellback
    # oracle agreement at the pinned epoch
    oracle = _oracle_at(srv.pool, res.pinned_epoch)
    for (k, l), found in zip([(0, CHAIN - 1), (CHAIN - 1, 0), (50, 1)],
                             res.found):
        assert found == oracle.reachable(k, l)


def test_index_stale_before_invocation_never_pins():
    """The admitted-epoch guard: a mutation that happened-BEFORE the query
    (published, epoch advanced, then the query starts) must not be absorbed
    by a pin — the index's epoch predates the invocation window, so the
    batch takes the whole-stale BFS fallback and answers at the head."""
    srv = GraphCoServer(capacity=64, ingest=True, index=True,
                        retain_epochs=64)
    for k in range(CHAIN):
        srv.submit([(OP_ADD_V, k)])
    for k in range(CHAIN - 1):
        srv.submit([(OP_ADD_E, k, k + 1)])
    srv.index_tick()
    srv.submit([(OP_ADD_V, 50), (OP_ADD_E, 50, 0)])   # happens-before
    res = srv.get_reach([(50, 1), (0, CHAIN - 1)])
    assert res.pinned_epoch is None and res.stale
    assert res.found == [True, True]      # the new edge IS visible
    assert res.from_index == 0 and res.fellback == 2


def test_server_without_ring_match_keeps_plain_fallback():
    """If the index's epoch has aged out of a tiny ring, the batch falls
    back whole (stale=True) — exactly the old behavior, now the exception
    rather than the rule."""
    srv = GraphCoServer(capacity=64, ingest=True, index=True, retain_epochs=2)
    for k in range(CHAIN):
        srv.submit([(OP_ADD_V, k)])
    srv.index_tick()
    for k in range(CHAIN - 1):            # > retain publishes age the stamp out
        srv.submit([(OP_ADD_E, k, k + 1)])
    res = srv.get_reach([(0, CHAIN - 1)])
    assert res.pinned_epoch is None
    assert res.stale                      # genuine whole-batch fallback
    assert res.found == [True]            # served correctly by the BFS session
    assert srv.index_misses == 1


def test_serve_loop_surfaces_ring_stats():
    """End-to-end: a serve() run against the hostile server reports the
    starvation/resolution/time-travel counters as per-serve deltas."""

    class TinyModel:
        def prefill(self, params, batch):
            import jax.numpy as jnp
            tokens = batch["tokens"]
            return jnp.zeros((tokens.shape[0], 8)), {}

        def cache_from_prefill(self, caches, cache_len):
            return caches

        def decode_step(self, params, caches, tok, pos):
            import jax.numpy as jnp
            return jnp.zeros((tok.shape[0], 8)), caches

    srv = _hostile_server()
    prompts = np.zeros((2, 4), np.int32)

    def queries(i):
        return (0, CHAIN - 1) if i % 2 == 0 else None

    out, stats = serve(TinyModel(), None, prompts, max_new_tokens=4,
                       cache_len=16, graph=srv, query_stream=queries)
    assert stats.getpath_calls == 2
    assert stats.getpath_starved >= 1
    assert stats.epoch_resolved >= 1
    # ring endpoints also flow through the stats deltas
    tt = srv.get_reach_at([(0, CHAIN - 1)], srv.epoch_window()[1])
    assert tt.found == [True]
    assert srv.tt_calls == 1
    d = srv.epoch_diff(*srv.epoch_window())
    assert srv.epoch_diff_calls == 1 and not d.evicted
