"""Checkpointing + fault tolerance: save/restore, retention, crash-resume,
elastic resharding, straggler detection."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import SyntheticLMData
from repro.models.model import build_model
from repro.runtime.fault import FailurePolicy, Heartbeat, StragglerDetector
from repro.runtime.train_loop import SimulatedFailure, TrainLoopConfig, train


def tree_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"a": jnp.arange(10), "b": [jnp.ones((3, 4)), jnp.zeros(2)]}
    ck.save(5, tree, blocking=True)
    out, manifest = ck.restore(tree)
    assert manifest["step"] == 5
    assert tree_equal(tree, out)


def test_async_save_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.ones((8, 8))}
    for s in (1, 2, 3, 4):
        ck.save(s, jax.tree.map(lambda x: x * s, tree))
    ck.wait()
    assert ck.all_steps() == [3, 4]
    out, m = ck.restore(tree)
    assert m["step"] == 4
    assert float(out["w"][0, 0]) == 4.0


def test_atomic_publish_no_partial_checkpoints(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.ones(4)}, blocking=True)
    # temp dirs never visible as steps
    assert all(n.startswith("step_") for n in os.listdir(tmp_path))


def test_crash_resume_identical_losses(tmp_path):
    """A run that crashes at step 7 and resumes must follow the same loss
    trajectory as an uninterrupted run (restart-idempotence)."""
    cfg = get_config("olmo-1b").smoke()
    model = build_model(cfg)
    data = SyntheticLMData(cfg.vocab, seed=3)

    base = TrainLoopConfig(total_steps=12, checkpoint_every=5, log_every=1,
                           checkpoint_dir=str(tmp_path / "a"))
    _, _, hist_clean = train(model, data, batch_size=2, seq_len=32, cfg=base,
                             log=lambda *_: None)

    crashing = TrainLoopConfig(total_steps=12, checkpoint_every=5, log_every=1,
                               checkpoint_dir=str(tmp_path / "b"),
                               simulate_failure_at=7)
    with pytest.raises(SimulatedFailure):
        train(model, data, batch_size=2, seq_len=32, cfg=crashing,
              log=lambda *_: None)
    resumed = TrainLoopConfig(total_steps=12, checkpoint_every=5, log_every=1,
                              checkpoint_dir=str(tmp_path / "b"))
    _, _, hist_resumed = train(model, data, batch_size=2, seq_len=32,
                               cfg=resumed, log=lambda *_: None)
    clean = {s: l for s, l, _ in hist_clean}
    res = {s: l for s, l, _ in hist_resumed}
    for s in res:
        assert abs(clean[s] - res[s]) < 1e-4, (s, clean[s], res[s])


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Checkpoint written replicated restores under a different sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, tree, blocking=True)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    out, _ = ck.restore(tree, shardings=shardings)
    assert tree_equal(tree, out)
    assert out["w"].sharding == shardings["w"]


def test_straggler_detector():
    sd = StragglerDetector(factor=3.0)
    for _ in range(10):
        assert not sd.observe(0.1)
    assert sd.observe(1.0)          # 10x ewma -> straggler
    assert not sd.observe(0.11)     # baseline not poisoned
    assert sd.flagged == 1


def test_heartbeat_suspects():
    hb = Heartbeat(timeout_s=5.0)
    hb.tick("w0", now=100.0)
    hb.tick("w1", now=103.0)
    assert hb.suspects(now=104.0) == []
    assert hb.suspects(now=106.5) == ["w0"]


def test_failure_policy_budget():
    fp = FailurePolicy(max_restarts=2, backoff_s=1.0)
    assert fp.on_failure() == 1.0
    assert fp.on_failure() == 2.0
    with pytest.raises(RuntimeError):
        fp.on_failure()


# -- durability: torn writes, blocking publish, surfaced failures -----------
def test_crash_mid_write_restores_previous_step(tmp_path):
    """A writer killed between tmp write and rename must leave the prior
    checkpoint as latest; the stale tmp dir is swept on the next boot."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.arange(4)}, blocking=True)
    # simulate the kill: full tmp dir on disk, rename never happens
    ck._write(2, [np.arange(4) * 9],
              {"step": 2, "n_leaves": 1, "extra": {}}, publish=False)
    assert any(n.startswith(".tmp_step_") for n in os.listdir(tmp_path))
    assert ck.latest_step() == 1
    out, m = ck.restore({"w": jnp.zeros(4, jnp.int32)})
    assert m["step"] == 1
    assert list(np.asarray(out["w"])) == [0, 1, 2, 3]
    # restart: a fresh Checkpointer sweeps the torn tmp, keeps step 1
    ck2 = Checkpointer(str(tmp_path))
    assert not any(n.startswith(".tmp_step_") for n in os.listdir(tmp_path))
    assert ck2.latest_step() == 1


def test_save_blocking_publishes_before_return(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(3, {"w": jnp.ones(2)}, blocking=True)
    # on return the rename already happened: no tmp dir, step visible
    names = os.listdir(tmp_path)
    assert "step_000000003" in names
    assert not any(n.startswith(".tmp_step_") for n in names)


def test_background_write_failure_surfaces_on_wait(tmp_path, monkeypatch):
    ck = Checkpointer(str(tmp_path))

    def boom(*a, **k):
        raise OSError("disk gone")

    monkeypatch.setattr(ck, "_write", boom)
    ck.save(1, {"w": jnp.ones(2)})          # async: failure lands later
    with pytest.raises(RuntimeError, match="background checkpoint write"):
        ck.wait()
    # the error is consumed: the checkpointer is usable again
    monkeypatch.undo()
    ck.save(2, {"w": jnp.ones(2)}, blocking=True)
    assert ck.latest_step() == 2


def test_restore_raw_loads_variable_leaf_count(tmp_path):
    ck = Checkpointer(str(tmp_path))
    leaves = [np.arange(3), np.eye(2), np.array([7])]
    ck.save(4, leaves, blocking=True)
    raw, manifest = ck.restore_raw()
    assert manifest["step"] == 4
    assert len(raw) == 3
    for a, b in zip(raw, leaves):
        np.testing.assert_array_equal(a, b)
