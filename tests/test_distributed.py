"""Distributed (row-sharded) graph tests.

The in-process test uses the 1-device degenerate mesh; the 8-device test
re-execs in a subprocess with XLA_FLAGS so the main test process keeps its
single-device view (per the dry-run isolation rule).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    OP_ADD_E, OP_ADD_V, OP_REM_E, OP_REM_V,
    GraphOracle, make_graph, make_op_batch,
)
from repro.core.distributed import (
    dapply_ops, dcollect, dcompare, dget_path_session, make_graph_mesh,
    shard_graph,
)


def test_single_device_matches_oracle():
    mesh = make_graph_mesh()
    g = shard_graph(mesh, make_graph(32))
    oracle = GraphOracle(32)
    rng = np.random.default_rng(0)
    ops_all = [(OP_ADD_V, k, -1, -1) for k in range(12)]
    for _ in range(30):
        u, v = rng.integers(0, 12, 2)
        op = [OP_ADD_E, OP_REM_E, OP_REM_V][rng.integers(0, 3)] if rng.random() < 0.9 else OP_ADD_V
        ops_all.append((op, int(u), int(v), -1))
    for i in range(0, len(ops_all), 7):
        chunk = ops_all[i:i + 7]
        g, res = dapply_ops(mesh, g, make_op_batch(chunk))
        assert [int(x) for x in np.asarray(res)] == oracle.apply_batch(chunk)


def test_single_device_getpath():
    mesh = make_graph_mesh()
    g = shard_graph(mesh, make_graph(32))
    ops = [(OP_ADD_V, k) for k in range(6)] + [(OP_ADD_E, k, k + 1) for k in range(5)]
    g, _ = dapply_ops(mesh, g, make_op_batch(ops))
    ok, n, keys, rounds = dget_path_session(mesh, lambda: g, 0, 5)
    assert ok and keys == [0, 1, 2, 3, 4, 5] and rounds == 2


def test_double_collect_detects_concurrent_mutation():
    mesh = make_graph_mesh()
    g = shard_graph(mesh, make_graph(32))
    ops = [(OP_ADD_V, k) for k in range(4)] + [(OP_ADD_E, 0, 1), (OP_ADD_E, 1, 2)]
    g, _ = dapply_ops(mesh, g, make_op_batch(ops))
    c1 = dcollect(mesh, g, 0, 2)
    g2, _ = dapply_ops(mesh, g, make_op_batch([(OP_REM_E, 1, 2)]))
    g3, _ = dapply_ops(mesh, g2, make_op_batch([(OP_ADD_E, 1, 2)]))
    c2 = dcollect(mesh, g3, 0, 2)  # same edge set, mutated ecnt
    assert not bool(dcompare(mesh, c1, c2))


_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import numpy as np, random
    import jax
    from repro.core import *
    from repro.core.distributed import *
    assert len(jax.devices()) == 8, jax.devices()
    mesh = make_graph_mesh()
    g = shard_graph(mesh, make_graph(64))
    random.seed(0)
    oracle = GraphOracle(64)
    ops = [(OP_ADD_V, k, -1, -1) for k in range(24)]
    ops += [(random.choice([OP_ADD_E, OP_ADD_E, OP_REM_E]),
             random.randrange(24), random.randrange(24), -1) for _ in range(60)]
    ops += [(OP_REM_V, 3, -1, -1), (OP_ADD_E, 2, 3, -1)]
    for i in range(0, len(ops), 10):
        chunk = ops[i:i+10]
        g, res = dapply_ops(mesh, g, make_op_batch(chunk))
        got = [int(x) for x in np.asarray(res)]
        want = oracle.apply_batch(chunk)
        assert got == want, (got, want)
    hits = 0
    for (s, d) in [(0, 13), (1, 20), (5, 6), (9, 2)]:
        ok, n, keys, rounds = dget_path_session(mesh, lambda: g, s, d)
        assert ok == oracle.reachable(s, d), (s, d)
        if ok:
            assert oracle.is_valid_path(keys, s, d)
            hits += 1
    print("SUBPROCESS_OK hits=", hits)
""")


@pytest.mark.slow
def test_eight_shard_graph_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "SUBPROCESS_OK" in r.stdout
