"""Direction-optimizing BFS + maintained in-adjacency suite (DESIGN.md §11).

Four contracts:

  1. Transpose invariant: ``adj_in_packed == pack_transpose(adj_packed)``
     after ARBITRARY interleaved AddVertex/RemoveVertex/AddEdge/RemoveEdge
     streams with grow/compact (and undirected ops), on dense AND
     mesh-sharded state — the in-adjacency is maintained by mirrored RMWs,
     never derived, so this is the property that keeps every pull-side
     consumer (hybrid BFS, index backward closures, degree) honest.
  2. All SIX BFS backends (jnp, pallas, packed, packed_pallas, hybrid,
     hybrid_pallas) bit-identical to one numpy oracle, parents included.
  3. The index's reverse graph is an O(1) FIELD SWAP and the rebuilt index
     is bit-identical to the deleted unpack→T→repack oracle path on a
     randomized mutation stream (regression for ``_transposed``'s removal).
  4. ``default_backend()`` resolves to "hybrid" (env-overridable) and every
     threaded call site defaults to it (``backend=None``).
"""
import inspect

import numpy as np

import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from repro.testing.proptest import given, settings, strategies as st

from repro.core import (
    OP_ADD_E, OP_ADD_V, OP_REM_E, OP_REM_V,
    apply_ops, apply_ops_fast, find_slots, make_graph, make_op_batch,
    multi_bfs, pack_transpose, transpose_invariant,
)
from repro.core import bfs as bfs_mod
from repro.core import partition, snapshot
from repro.core.bfs import (
    HYBRID_BACKENDS, bfs, ctz32, default_backend, pick_direction,
    reachable_count,
)
from repro.core.distributed import make_graph_mesh
from repro.core.graph import grow as dense_grow
from repro.core.ops import add_edge_undirected, compact as dense_compact
from repro.core.ops import remove_edge_undirected
from repro.index import labels as labels_mod
from repro.index.freshness import reach_counts_session, refresh
from repro.index.labels import build_index

RNG = np.random.default_rng(23)
CAP = 32
ALL_BACKENDS = ("jnp", "pallas", "packed", "packed_pallas") + HYBRID_BACKENDS


def _random_state(nv=12, cap=CAP, n_edges=40, n_dead=3, seed=0):
    rng = np.random.default_rng(seed)
    g = make_graph(cap)
    ops = [(OP_ADD_V, k) for k in range(nv)]
    ops += [(OP_ADD_E, int(a), int(b))
            for a, b in rng.integers(0, nv, (n_edges, 2))]
    g, _ = apply_ops(g, make_op_batch(ops))
    dead = rng.choice(nv, size=n_dead, replace=False)
    g, _ = apply_ops(g, make_op_batch([(OP_REM_V, int(k)) for k in dead]))
    return g


# ----------------------------------------------------------------------------
# helpers under test
# ----------------------------------------------------------------------------
def test_ctz32_matches_numpy():
    x = np.r_[RNG.integers(1, 2**32, 200), [1, 2**31, 2**32 - 1]] \
        .astype(np.uint32)
    got = np.asarray(ctz32(jnp.asarray(x)))
    want = np.array([int(v & -v).bit_length() - 1 for v in x.astype(object)])
    np.testing.assert_array_equal(got, want)
    # zero words report 32 (callers mask them out)
    assert int(ctz32(jnp.asarray([0], dtype=jnp.uint32))[0]) == 32


def test_pick_direction_thresholds():
    # sparse frontier from push mode stays push
    assert not bool(pick_direction(jnp.asarray(False), jnp.int32(1),
                                   jnp.int32(100), 128, 4, 24))
    # dense frontier trips the alpha threshold
    assert bool(pick_direction(jnp.asarray(False), jnp.int32(30),
                               jnp.int32(100), 128, 4, 24))
    # hysteresis: in pull mode we stay until the frontier shrinks below V/beta
    assert bool(pick_direction(jnp.asarray(True), jnp.int32(10),
                               jnp.int32(100), 128, 4, 24))
    assert not bool(pick_direction(jnp.asarray(True), jnp.int32(2),
                                   jnp.int32(100), 128, 4, 24))


def test_pull_kernel_matches_ref():
    from repro.kernels.bfs_pull_step.kernel import bfs_pull_step_pallas
    from repro.kernels.bfs_pull_step.ref import bfs_pull_step_ref

    rng = np.random.default_rng(7)
    q, r, w = 8, 64, 2
    fw = jnp.asarray(rng.integers(0, 2**32, (q, w), dtype=np.uint32))
    adjin = jnp.asarray(rng.integers(0, 2**32, (r, w), dtype=np.uint32))
    alive = jnp.asarray(rng.random(r) < 0.8).astype(jnp.int32)
    vis = jnp.asarray(rng.random((q, r)) < 0.3).astype(jnp.int32)
    want = bfs_pull_step_ref(fw, adjin, alive, vis)
    for budget in (None, 0):  # broadcast path and fori fallback path
        kw = {} if budget is None else {"pull_bcast_budget": budget}
        got = bfs_pull_step_pallas(fw, adjin, alive, vis, tr=32, **kw)
        for name, a, b in zip(("new", "parent"), got, want):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)


# ----------------------------------------------------------------------------
# 1. Transpose invariant under arbitrary op streams (dense + sharded)
# ----------------------------------------------------------------------------
KEYS = st.integers(min_value=0, max_value=9)
OPC = st.sampled_from([OP_ADD_V, OP_REM_V, OP_ADD_E, OP_REM_E])
OP = st.tuples(OPC, KEYS, KEYS)
STREAM = st.lists(st.lists(OP, min_size=1, max_size=8), min_size=1, max_size=3)


@settings(max_examples=8, deadline=None)
@given(STREAM)
def test_transpose_invariant_over_mutation_stream(op_lists):
    mesh = make_graph_mesh()
    g = make_graph(CAP)
    gs = partition.shard_state(mesh, g)
    seedb = make_op_batch([(OP_ADD_V, k) for k in range(8)])
    g, _ = apply_ops_fast(g, seedb)
    gs, _ = partition.apply_ops_fast(gs, seedb)
    for step, ops in enumerate(op_lists):
        batch = make_op_batch([(op, a, b, -1) for (op, a, b) in ops])
        g, _ = apply_ops_fast(g, batch)
        gs, _ = partition.apply_ops_fast(gs, batch)
        if step == 1:  # exercise grow + compact mid-stream
            g = dense_grow(dense_compact(g), CAP * 2)
            gs = partition.grow(partition.compact(gs), CAP * 2)
        assert bool(transpose_invariant(g)), f"dense, step {step}"
        assert bool(transpose_invariant(partition.unshard(gs))), \
            f"sharded, step {step}"
    # serial reference engine + undirected extension preserve it too
    g, _ = apply_ops(g, make_op_batch([(OP_ADD_E, 0, 5), (OP_REM_V, 1),
                                       (OP_ADD_V, 1)]))
    g, _ = add_edge_undirected(g, 0, 5)
    assert bool(transpose_invariant(g))
    g, _ = remove_edge_undirected(g, 0, 5)
    assert bool(transpose_invariant(g))


# ----------------------------------------------------------------------------
# 2. Six-backend bit-identity against one numpy oracle (parents included)
# ----------------------------------------------------------------------------
def _np_traversable(g):
    adj = np.asarray(g.adj) > 0
    alive = np.asarray(g.valive)
    return adj & alive[:, None] & alive[None, :]


def _np_bfs_full(t, alive, src):
    """(dist, parent) of a full-exploration BFS with smallest-frontier-index
    parents — the per-step contract every backend implements."""
    v = t.shape[0]
    dist = np.full(v, -1, np.int32)
    parent = np.full(v, -1, np.int32)
    if src < 0 or not alive[src]:
        return dist, parent
    dist[src] = 0
    frontier = np.zeros(v, bool)
    frontier[src] = True
    visited = frontier.copy()
    d = 0
    while frontier.any():
        new = t[frontier].any(axis=0) & ~visited
        for j in np.nonzero(new)[0]:
            parent[j] = np.nonzero(frontier & t[:, j])[0].min()
        dist[new] = d + 1
        visited |= new
        frontier = new
        d += 1
    return dist, parent


def _assert_backends_match_oracle(g, srcs):
    t = _np_traversable(g)
    alive = np.asarray(g.valive)
    want = [_np_bfs_full(t, alive, int(s)) for s in srcs]
    dsts = jnp.full((len(srcs),), -1, jnp.int32)
    ref = None
    for backend in ALL_BACKENDS:
        m = multi_bfs(g, jnp.asarray(srcs, jnp.int32), dsts, backend=backend)
        for qi, (dist, parent) in enumerate(want):
            np.testing.assert_array_equal(np.asarray(m.dist[qi]), dist,
                                          err_msg=f"{backend} dist q{qi}")
            np.testing.assert_array_equal(np.asarray(m.parent[qi]), parent,
                                          err_msg=f"{backend} parent q{qi}")
        r = bfs(g, jnp.int32(int(srcs[0])), jnp.int32(-1), backend=backend)
        np.testing.assert_array_equal(np.asarray(r.dist), want[0][0],
                                      err_msg=f"{backend} bfs dist")
        np.testing.assert_array_equal(np.asarray(r.parent), want[0][1],
                                      err_msg=f"{backend} bfs parent")
        if ref is None:
            ref = m
        else:  # full-result bit-identity (expanded/steps/supersteps too)
            for name, xa, xb in zip(ref._fields, ref, m):
                np.testing.assert_array_equal(
                    np.asarray(xa), np.asarray(xb),
                    err_msg=f"{backend} field {name}")


def test_six_backends_bit_identical_vs_numpy_oracle():
    g = _random_state(seed=13)
    srcs = np.nonzero(np.asarray(g.valive))[0][:8].astype(np.int32)
    _assert_backends_match_oracle(g, srcs)


@pytest.mark.slow
def test_six_backends_large_v_dense_frontier():
    """Large-V variant: a dense random digraph whose frontier covers most of
    the graph after one hop, forcing the hybrid backends through BOTH
    directions (push on step 1, pull once the alpha threshold trips)."""
    rng = np.random.default_rng(31)
    nv, cap = 180, 256
    g = make_graph(cap)
    ops = [(OP_ADD_V, k) for k in range(nv)]
    g, _ = apply_ops_fast(g, make_op_batch(ops))
    edges = [(OP_ADD_E, int(a), int(b))
             for a, b in rng.integers(0, nv, (nv * 8, 2))]
    for i in range(0, len(edges), 256):
        g, _ = apply_ops_fast(g, make_op_batch(edges[i:i + 256], 256))
    g, _ = apply_ops_fast(
        g, make_op_batch([(OP_REM_V, int(k))
                          for k in rng.choice(nv, 12, replace=False)]))
    srcs = np.nonzero(np.asarray(g.valive))[0][:8].astype(np.int32)
    _assert_backends_match_oracle(g, srcs)


def test_hybrid_closure_mode_and_sharded_bit_identical():
    g = _random_state(seed=17)
    mesh = make_graph_mesh()
    gs = partition.shard_state(mesh, g)
    srcs = np.nonzero(np.asarray(g.valive))[0][:8].astype(np.int32)
    sj = jnp.asarray(srcs, jnp.int32)
    dsts = jnp.full((len(srcs),), -1, jnp.int32)
    ref = multi_bfs(g, sj, dsts, backend="jnp")
    for backend in HYBRID_BACKENDS:
        c = multi_bfs(g, sj, dsts, backend=backend, parents=False)
        np.testing.assert_array_equal(np.asarray(c.dist), np.asarray(ref.dist),
                                      err_msg=f"{backend} closure dist")
        assert (np.asarray(c.parent) == -1).all()
        s = partition.multi_bfs(gs, sj, dsts, backend=backend)
        for name, xa, xb in zip(ref._fields, ref, s):
            np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb),
                                          err_msg=f"sharded {backend} {name}")


# ----------------------------------------------------------------------------
# 3. Index: reverse graph is a field swap; rebuilt index == transpose oracle
# ----------------------------------------------------------------------------
def test_reversed_is_an_O1_field_swap():
    g = _random_state(seed=19)
    rev = labels_mod._reversed(g)
    assert rev.adj_packed is g.adj_in_packed   # aliased, not recomputed
    assert rev.adj_in_packed is g.adj_packed
    np.testing.assert_array_equal(
        np.asarray(rev.adj_packed),
        np.asarray(pack_transpose(g.adj_packed, g.capacity)))


def test_index_bit_identical_to_pre_deletion_transpose_oracle(monkeypatch):
    """The deleted ``_transposed`` oracle path (unpack → T → repack) must
    produce the exact same index as the maintained-in-adjacency build, on a
    randomized mutation stream including refresh."""
    rng = np.random.default_rng(41)
    g = make_graph(CAP)
    g, _ = apply_ops_fast(g, make_op_batch(
        [(OP_ADD_V, k) for k in range(10)]))

    def transpose_oracle(state):  # the pre-deletion implementation
        return state._replace(
            adj_packed=pack_transpose(state.adj_packed, state.capacity),
            adj_in_packed=pack_transpose(state.adj_in_packed,
                                         state.capacity))

    for step in range(3):
        ops = [(int(rng.choice([OP_ADD_E, OP_REM_E, OP_REM_V, OP_ADD_V])),
                int(rng.integers(0, 10)), int(rng.integers(0, 10)))
               for _ in range(8)]
        g, _ = apply_ops_fast(g, make_op_batch(ops))
        new_idx = build_index(g)
        with monkeypatch.context() as mp:
            mp.setattr(labels_mod, "_reversed", transpose_oracle)
            oracle_idx = build_index(g)
        for name, xa, xb in zip(new_idx._fields, new_idx, oracle_idx):
            np.testing.assert_array_equal(
                np.asarray(xa), np.asarray(xb),
                err_msg=f"step {step} field {name}")
    # refresh stays bit-identical to a rebuild PINNED to the landmark set
    # the refreshed index actually carries — a valid oracle for BOTH the
    # incremental and the full path, so the comparison is never vacuous
    idx = build_index(g)
    g, _ = apply_ops_fast(g, make_op_batch([(OP_ADD_E, 2, 6),
                                            (OP_REM_V, 4)]))
    idx2, info = refresh(idx, g)
    assert info["mode"] != "noop"
    full = build_index(g, landmark_slots=np.asarray(idx2.landmarks))
    for name, xa, xb in zip(idx2._fields, idx2, full):
        if name == "requested":  # landmark-budget metadata, not index state
            continue
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb),
                                      err_msg=f"refresh field {name}")


# ----------------------------------------------------------------------------
# 4. default_backend resolution + threading
# ----------------------------------------------------------------------------
def test_default_backend_resolution(monkeypatch):
    assert default_backend() == "hybrid"
    monkeypatch.setenv("REPRO_BFS_BACKEND", "packed")
    assert default_backend() == "packed"
    monkeypatch.delenv("REPRO_BFS_BACKEND")
    assert default_backend() == "hybrid"


def test_default_backend_threaded_everywhere():
    """Every traversal surface defaults its ``backend`` to None, i.e. to
    ``default_backend()`` — the fastest engine is the default everywhere."""
    from repro.data.pathgen import PathTaskGenerator
    from repro.index.freshness import affected_landmarks, reach_session
    from repro.index.labels import rebuild_rows

    sites = [bfs, multi_bfs, reachable_count, partition.multi_bfs,
             snapshot.collect, snapshot.get_path, snapshot.collect_batch,
             snapshot.get_paths_session, snapshot.get_path_session,
             snapshot.interleaved_getpath, build_index, rebuild_rows,
             refresh, affected_landmarks, reach_session,
             reach_counts_session, PathTaskGenerator.__init__]
    for fn in sites:
        target = getattr(fn, "__wrapped__", fn)
        default = inspect.signature(target).parameters["backend"].default
        assert default is None, f"{fn} does not thread default_backend()"


def test_default_backend_results_match_explicit_hybrid():
    g = _random_state(seed=29)
    srcs = np.nonzero(np.asarray(g.valive))[0][:4].astype(np.int32)
    sj = jnp.asarray(srcs, jnp.int32)
    dsts = jnp.full((4,), -1, jnp.int32)
    a = multi_bfs(g, sj, dsts)                       # default → hybrid
    b = multi_bfs(g, sj, dsts, backend="hybrid")
    for name, xa, xb in zip(a._fields, a, b):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb),
                                      err_msg=name)
    n = reachable_count(g, jnp.int32(int(srcs[0])))
    r = bfs(g, jnp.int32(int(srcs[0])), jnp.int32(-1), backend="jnp")
    assert int(n) == int((np.asarray(r.dist) >= 0).sum())
    keys = np.asarray(g.vkey)[srcs]
    pairs = [(int(keys[0]), int(keys[1])), (int(keys[2]), int(keys[3]))]
    out, _rounds = snapshot.get_paths_session(lambda: g, pairs)
    ref = snapshot.get_paths_session(lambda: g, pairs, backend="jnp")[0]
    assert [f for f, _ in out] == [f for f, _ in ref]
