"""Retained epoch ring: bit-identical history, bounded eviction, diffs.

The wait-free snapshot story (DESIGN.md §13) stands on one claim: for any
epoch still inside the retention window, ``EpochRing.state_at(e)`` is BYTE
identical to the state the pool published at epoch e. This suite pins that
claim against the actually-published states (captured as the schedule
runs), plus the boundary behavior that makes the ring safe to lean on:
eviction at exactly ``retain`` epochs, the grow barrier (a capacity change
resets retention), ``epoch_of_versions`` (the index-stamp lookup),
``epoch_diff``, and the ``epoch_log`` prune that fixes the unbounded
per-epoch dict leak.
"""
import numpy as np
import pytest

from repro.core import (
    OP_ADD_E,
    OP_ADD_V,
    OP_REM_E,
    OP_REM_V,
    EpochEvictedError,
    EpochRing,
    make_graph,
    version_vector,
)
from repro.core.distributed import make_graph_mesh
from repro.runtime.ingest import IngestPool

FIELDS = ("vkey", "valive", "vver", "ecnt", "adj_packed", "adj_in_packed")


def _assert_states_equal(a, b, msg=""):
    for f in FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{msg} field {f!r}")


def _mutation_stream(n):
    """n single-op batches with adds, removes and edge churn."""
    ops = []
    for i in range(n):
        k = i % 8
        if i % 7 == 6:
            ops.append([(OP_REM_E, k, (k + 1) % 8)])
        elif i % 5 == 4:
            ops.append([(OP_REM_V, k)])
        elif i % 2 == 0:
            ops.append([(OP_ADD_V, k)])
        else:
            ops.append([(OP_ADD_E, k, (k + 1) % 8)])
    return ops


def _pump_stream(pool, ops):
    """Apply each batch as its own publish; return {epoch: published state}."""
    published = {pool.epoch: pool.snapshot()}
    for batch in ops:
        pool.submit("c", batch)
        pool.flush()
        published[pool.epoch] = pool.snapshot()
    return published


def test_reconstruction_bit_identical_to_published_states():
    pool = IngestPool(make_graph(32), retain_epochs=64)
    published = _pump_stream(pool, _mutation_stream(20))
    lo, hi = pool.epoch_window()
    assert (lo, hi) == (0, 20)
    for e in range(lo, hi + 1):
        _assert_states_equal(pool.state_at(e), published[e],
                             f"epoch {e} reconstruction diverges:")


def test_state_at_newest_is_the_published_slot_itself():
    pool = IngestPool(make_graph(16), retain_epochs=8)
    _pump_stream(pool, _mutation_stream(3))
    assert pool.state_at(pool.epoch) is pool.snapshot()


def test_eviction_window_boundaries_retain_4():
    pool = IngestPool(make_graph(32), retain_epochs=4)
    published = _pump_stream(pool, _mutation_stream(10))
    lo, hi = pool.epoch_window()
    assert (lo, hi) == (7, 10)          # exactly retain=4 addressable epochs
    # inside the window: exact; first epoch past it: typed eviction
    for e in range(lo, hi + 1):
        _assert_states_equal(pool.state_at(e), published[e])
    with pytest.raises(EpochEvictedError) as exc:
        pool.state_at(lo - 1)
    assert exc.value.epoch == lo - 1
    assert exc.value.window == (lo, hi)
    assert pool.stats.epochs_retained == 4
    assert pool.stats.epochs_evicted == 7  # epochs 0..6 aged out


def test_retain_one_keeps_only_the_newest_epoch():
    pool = IngestPool(make_graph(16), retain_epochs=1)
    _pump_stream(pool, _mutation_stream(5))
    assert pool.epoch_window() == (5, 5)
    with pytest.raises(EpochEvictedError):
        pool.state_at(4)
    _assert_states_equal(pool.state_at(5), pool.snapshot())


def test_grow_is_a_retention_barrier():
    """A capacity change voids every row-shaped delta: the ring resets at
    the grown epoch and pre-grow epochs report eviction even though they
    were inside the nominal retain count."""
    pool = IngestPool(make_graph(4), retain_epochs=64, auto_grow=True)
    # capacity 4 and 6 distinct keys forces at least one R_TABLE_FULL grow
    for k in range(6):
        pool.submit("c", [(OP_ADD_V, 10 * k)])
        pool.flush()
    assert pool.stats.grow_events >= 1
    lo, hi = pool.epoch_window()
    assert lo > 0                        # pre-grow epochs were dropped
    _assert_states_equal(pool.state_at(hi), pool.snapshot())
    with pytest.raises(EpochEvictedError):
        pool.state_at(lo - 1)


def test_epoch_log_pruned_to_ring_window():
    """Satellite bugfix: epoch_log leaked one entry per published epoch;
    it must now track exactly the addressable window."""
    pool = IngestPool(make_graph(32), retain_epochs=4)
    _pump_stream(pool, _mutation_stream(12))
    lo, hi = pool.epoch_window()
    assert sorted(pool.epoch_log) == list(range(lo, hi + 1))
    # retained epochs answer; evicted epochs raise the typed error
    assert pool.linearization_prefix(hi) == len(pool.linearization)
    with pytest.raises(EpochEvictedError) as exc:
        pool.linearization_prefix(lo - 1)
    assert exc.value.window == (lo, hi)


def test_epoch_diff_reports_touched_rows_and_keys():
    pool = IngestPool(make_graph(32), retain_epochs=64)
    pool.submit("c", [(OP_ADD_V, 1), (OP_ADD_V, 2)])
    pool.flush()                          # epoch 1
    pool.submit("c", [(OP_ADD_E, 1, 2)])
    pool.flush()                          # epoch 2
    pool.submit("c", [(OP_ADD_V, 3)])
    pool.flush()                          # epoch 3
    d = pool.epoch_diff(1, 3)
    # rows touched after epoch 1: vertex 1's row (new out-edge bumps its
    # ecnt/adjacency), vertex 2's row (in-edge bookkeeping), vertex 3's slot
    state = pool.snapshot()
    vkey = np.asarray(state.vkey)
    keys_after = {int(vkey[r]) for r in d.rows}
    assert {1, 3} <= keys_after
    assert d.e_from == 1 and d.e_to == 3
    # endpoints are order-normalized
    d2 = pool.epoch_diff(3, 1)
    np.testing.assert_array_equal(d.rows, d2.rows)
    # identical endpoints: empty diff
    assert pool.epoch_diff(2, 2).rows.size == 0
    # evicted endpoint: typed error
    small = IngestPool(make_graph(32), retain_epochs=2)
    _pump_stream(small, _mutation_stream(6))
    with pytest.raises(EpochEvictedError):
        small.epoch_diff(0, small.epoch)


def test_epoch_of_versions_finds_the_stamped_epoch():
    pool = IngestPool(make_graph(32), retain_epochs=64)
    published = _pump_stream(pool, _mutation_stream(8))
    for e, state in published.items():
        vv = np.asarray(version_vector(state))
        got = pool.ring.epoch_of_versions(vv, state.capacity)
        # the NEWEST matching epoch is returned (a failed/no-op publish can
        # leave versions unchanged, so got may exceed e) — what matters is
        # that equal versions imply a byte-identical graph, so pinning to
        # the returned epoch answers exactly as the stamped one would
        assert got is not None and got >= e
        _assert_states_equal(pool.state_at(got), state,
                             f"versions matched epoch {got} but states differ:")
    # an alien version vector (or capacity) matches nothing
    alien = np.full_like(np.asarray(version_vector(pool.snapshot())), 7)
    assert pool.ring.epoch_of_versions(alien, pool.snapshot().capacity) is None
    assert pool.ring.epoch_of_versions(
        np.asarray(version_vector(pool.snapshot())), 999) is None


def test_ring_push_rejects_epoch_gaps():
    ring = EpochRing(retain=4)
    state = make_graph(8)
    ring.reset(0, state)
    with pytest.raises(ValueError):
        ring.push(2, state)               # 0 -> 2 skips epoch 1


def test_ring_retain_validation():
    with pytest.raises(ValueError):
        EpochRing(retain=0)


def test_sharded_pool_ring_reconstructs_dense_bit_identical():
    """A sharded pool's ring records host gathers; reconstruction is the
    dense form of every published epoch (time-travel is read-only, so the
    gathered dense answer is the contract)."""
    from repro.core import partition

    mesh = make_graph_mesh()
    state = partition.shard_state(mesh, make_graph(32))
    pool = IngestPool(state, mesh=mesh, retain_epochs=64)
    published = _pump_stream(pool, _mutation_stream(8))
    lo, hi = pool.epoch_window()
    assert (lo, hi) == (0, 8)
    # np.asarray gathers sharded fields, so one comparison covers both the
    # dense reconstructions (older epochs) and the sharded newest slot
    for e in range(lo, hi + 1):
        _assert_states_equal(pool.state_at(e), published[e],
                             f"epoch {e} (sharded pool):")
