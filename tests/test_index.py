"""Versioned reachability index vs the fused BFS engine and the oracle.

The contract under test (DESIGN.md §9):

  1. On a fresh index, index-served answers are IDENTICAL to
     ``multi_bfs`` and the sequential ``core.oracle`` for every (src, dst)
     pair — including absent keys and dead endpoints — on both label_join
     backends (jnp reference and Pallas kernel).
  2. A mutation between build and query makes the epoch stale: the session
     provably takes the BFS fallback (``fellback > 0``) and the answers
     are still correct; ``refresh()`` restores index hits.
  3. Incremental refresh is bit-identical to a full rebuild over the same
     landmark list (the affected-landmark sets are sufficient).
  4. Partial landmark sets never lie: decided answers match the oracle,
     positive answers are exact, undecided queries fall back.
  5. The property holds across random mutation streams on dense AND
     mesh-sharded state.
"""
import numpy as np

import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from repro.testing.proptest import given, settings, strategies as st

from repro.core import (
    OP_ADD_E, OP_ADD_V, OP_REM_E, OP_REM_V,
    GraphOracle, apply_ops, apply_ops_fast, find_slots, make_graph,
    make_op_batch, multi_bfs,
)
from repro.core import partition
from repro.core.bfs import reachable_count
from repro.core.distributed import make_graph_mesh
from repro.index import (
    build_index,
    index_fresh,
    query_reach,
    reach_counts,
    reach_session,
    refresh,
)

NV, CAP = 10, 32


def _build(edge_ops, nv=NV, cap=CAP):
    g = make_graph(cap)
    oracle = GraphOracle(cap)
    ops = [(OP_ADD_V, k, -1, -1) for k in range(nv)]
    ops += [(op, u, v, -1) for (op, u, v) in edge_ops]
    g, _ = apply_ops(g, make_op_batch(ops))
    oracle.apply_batch(ops)
    return g, oracle


def _all_pairs(nv=NV, extra=None):
    keys = list(range(nv)) + list((-5, nv + 3) if extra is None else extra)
    return [(a, b) for a in keys for b in keys]


def _slots(g, pairs):
    sk = find_slots(g, jnp.asarray([p[0] for p in pairs], jnp.int32))
    sl = find_slots(g, jnp.asarray([p[1] for p in pairs], jnp.int32))
    return sk, sl


def _assert_index_exact(g, oracle, idx, pairs, backend):
    sk, sl = _slots(g, pairs)
    reach, decided, hub = query_reach(idx, sk, sl, backend=backend)
    m = multi_bfs(g, sk, sl)
    reach, decided = np.asarray(reach), np.asarray(decided)
    assert decided.all(), "complete index must decide every pair"
    np.testing.assert_array_equal(reach, np.asarray(m.found))
    for (a, b), r in zip(pairs, reach):
        assert bool(r) == oracle.reachable(a, b), (backend, a, b)
    # every positive has a 2-hop witness landmark on an s ->* hub ->* t path
    hub = np.asarray(hub)
    lm = np.asarray(idx.landmarks)
    fwd, bwd = np.asarray(idx.fwd), np.asarray(idx.bwd)
    sk_np, sl_np = np.asarray(sk), np.asarray(sl)
    for qi in np.nonzero(reach)[0]:
        h = hub[qi]
        assert h >= 0
        assert bwd[h, sk_np[qi]] and fwd[h, sl_np[qi]], (qi, lm[h])


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_index_matches_engine_and_oracle_all_pairs(backend, seed):
    rng = np.random.default_rng(seed)
    edge_ops = [(OP_ADD_E, int(a), int(b))
                for a, b in rng.integers(0, NV, (2 * NV, 2))]
    g, oracle = _build(edge_ops)
    idx = build_index(g)
    assert idx.complete and index_fresh(idx, g)
    _assert_index_exact(g, oracle, idx, _all_pairs(), backend)


def test_index_dead_endpoints_and_absent_keys():
    g, oracle = _build([(OP_ADD_E, 0, 1), (OP_ADD_E, 1, 2), (OP_ADD_E, 2, 3)])
    g, _ = apply_ops(g, make_op_batch([(OP_REM_V, 2, -1, -1)]))
    oracle.remove_vertex(2)
    idx = build_index(g)          # built AFTER the removal: fresh & exact
    _assert_index_exact(g, oracle, idx, _all_pairs(), "jnp")


def test_pruning_is_canonical_and_lossless():
    """Pruned labels decide exactly the pairs the raw closures cover, with
    (usually far) fewer bits — the canonical-hub argument of labels.py."""
    rng = np.random.default_rng(7)
    edge_ops = [(OP_ADD_E, int(a), int(b))
                for a, b in rng.integers(0, NV, (3 * NV, 2))]
    g, _ = _build(edge_ops)
    idx = build_index(g)
    out_l = np.asarray(idx.out_label_bits)   # unpack the uint32 bitsets
    in_l = np.asarray(idx.in_label_bits)
    fwd, bwd = np.asarray(idx.fwd), np.asarray(idx.bwd)
    assert out_l.sum() <= bwd.sum() and in_l.sum() <= fwd.sum()
    # decided sets are equal: exists-hub via pruned == via unpruned
    pruned = (out_l.astype(np.int32) @ in_l.T.astype(np.int32)) > 0
    raw = (bwd.T.astype(np.int32) @ fwd.astype(np.int32)) > 0
    np.testing.assert_array_equal(pruned, raw)


def test_staleness_forces_fallback_and_refresh_restores_hits():
    g, oracle = _build([(OP_ADD_E, k, k + 1) for k in range(NV - 1)])
    idx = build_index(g)
    pairs = [(0, NV - 1), (NV - 1, 0), (3, 7)]

    # mutation between build and query: sever the chain at 8 -> 9
    g2, _ = apply_ops(g, make_op_batch([(OP_REM_E, 8, 9, -1)]))
    oracle.remove_edge(8, 9)
    assert not index_fresh(idx, g2)
    res = reach_session(lambda: g2, idx, pairs)
    assert res.stale and res.fellback == len(pairs) and res.from_index == 0
    assert res.found == [oracle.reachable(a, b) for a, b in pairs] \
        == [False, False, True]

    idx2, info = refresh(idx, g2)
    assert info["mode"] != "noop" and index_fresh(idx2, g2)
    res2 = reach_session(lambda: g2, idx2, pairs)
    assert not res2.stale and res2.from_index == len(pairs) \
        and res2.fellback == 0
    assert res2.found == res.found
    # lazily materialized witness paths agree with the found flags
    paths = res2.paths()
    assert [f for f, _ in paths] == res2.found
    assert paths[2][1] == [3, 4, 5, 6, 7]


def test_incremental_refresh_bitwise_equals_full_rebuild():
    rng = np.random.default_rng(5)
    edge_ops = [(OP_ADD_E, int(a), int(b))
                for a, b in rng.integers(0, NV, (2 * NV, 2))]
    g, oracle = _build(edge_ops)
    idx = build_index(g)
    for step in range(6):
        op = (int(rng.choice([OP_ADD_E, OP_REM_E, OP_REM_V])),
              int(rng.integers(0, NV)), int(rng.integers(0, NV)))
        g, _ = apply_ops(g, make_op_batch([op]))
        oracle.apply(op[0], op[1], op[2])
        idx, info = refresh(idx, g, full_threshold=1.1)  # force incremental
        assert info["mode"] in ("incremental", "noop")
        assert index_fresh(idx, g)
        ref = build_index(g, landmark_slots=np.asarray(idx.landmarks))
        for f in ("out_label", "in_label", "fwd", "bwd", "alive", "versions"):
            np.testing.assert_array_equal(
                np.asarray(getattr(idx, f)), np.asarray(getattr(ref, f)),
                err_msg=f"step {step} field {f} after {op}")
        if idx.complete:
            _assert_index_exact(g, oracle, idx, _all_pairs(), "jnp")


def test_refresh_repicks_landmarks_when_new_vertex_appears():
    """A complete-by-default index must stay complete: AddVertex of a new
    key escalates refresh to a full rebuild that picks up the new slot."""
    g, oracle = _build([(OP_ADD_E, 0, 1)])
    idx = build_index(g)
    batch = [(OP_ADD_V, NV, -1, -1), (OP_ADD_E, 1, NV, -1)]
    g, _ = apply_ops(g, make_op_batch(batch))
    oracle.apply_batch(batch)
    idx, info = refresh(idx, g)
    assert info["mode"] == "full" and idx.complete and index_fresh(idx, g)
    _assert_index_exact(g, oracle, idx, _all_pairs(nv=NV + 1), "jnp")


@pytest.mark.parametrize("num_landmarks", [0, 1, 3])
def test_partial_landmark_index_never_lies(num_landmarks):
    rng = np.random.default_rng(13)
    edge_ops = [(OP_ADD_E, int(a), int(b))
                for a, b in rng.integers(0, NV, (2 * NV, 2))]
    g, oracle = _build(edge_ops)
    idx = build_index(g, num_landmarks)
    assert idx.num_landmarks == num_landmarks and not idx.complete
    pairs = _all_pairs()
    sk, sl = _slots(g, pairs)
    reach, decided, _ = query_reach(idx, sk, sl)
    for (a, b), r, d in zip(pairs, np.asarray(reach), np.asarray(decided)):
        if d:
            assert bool(r) == oracle.reachable(a, b), (a, b)
        if r:  # positives are exact even when undecidedness exists
            assert oracle.reachable(a, b), (a, b)
    # the session transparently patches undecided queries via BFS
    res = reach_session(lambda: g, idx, pairs)
    assert res.found == [oracle.reachable(a, b) for a, b in pairs]
    assert res.from_index == int(np.asarray(decided).sum())
    assert res.fellback == len(pairs) - res.from_index


def test_reach_counts_matches_reachable_count():
    rng = np.random.default_rng(21)
    edge_ops = [(OP_ADD_E, int(a), int(b))
                for a, b in rng.integers(0, NV, (2 * NV, 2))]
    g, _ = _build(edge_ops)
    idx = build_index(g)
    keys = list(range(NV)) + [-2, NV + 5]
    slots = find_slots(g, jnp.asarray(keys, jnp.int32))
    counts, decided = reach_counts(idx, slots)
    assert bool(np.asarray(decided).all())
    for i, _k in enumerate(keys):
        assert int(counts[i]) == int(reachable_count(g, slots[i])), keys[i]


def test_label_join_pallas_matches_ref():
    from repro.kernels.label_join.ops import label_join
    from repro.kernels.label_join.ref import label_join_ref

    rng = np.random.default_rng(3)
    for q, l in ((1, 1), (5, 7), (16, 130), (33, 256)):
        a = jnp.asarray(rng.random((q, l)) < 0.2)
        b = jnp.asarray(rng.random((q, l)) < 0.2)
        hk, uk = label_join(a, b)
        hr, ur = label_join_ref(a.astype(jnp.int32), b.astype(jnp.int32))
        np.testing.assert_array_equal(np.asarray(hk), np.asarray(hr), err_msg=f"{q},{l}")
        np.testing.assert_array_equal(np.asarray(uk), np.asarray(ur), err_msg=f"{q},{l}")


def test_server_index_surface_counts_hits_and_misses():
    from repro.runtime.serve_loop import GraphCoServer

    srv = GraphCoServer(capacity=64, index=True)
    srv.submit([(OP_ADD_V, k) for k in range(8)])
    srv.submit([(OP_ADD_E, a, a + 1) for a in range(7)])
    assert srv.index_tick() and not srv.index_tick()
    res = srv.get_reach([(0, 7), (7, 0)])
    assert res.found == [True, False] and srv.index_hits == 2
    srv.submit([(OP_REM_E, 3, 4)])   # mutation between build and query
    res = srv.get_reach([(0, 7), (0, 3)])
    assert res.stale and srv.index_misses == 2
    assert res.found == [False, True]  # fallback answers are still correct
    assert srv.index_tick()            # background refresh restores hits
    res = srv.get_reach([(0, 7), (0, 3)])
    assert res.found == [False, True] and res.from_index == 2
    # batched reachable-count endpoint, index-served when fresh
    counts = srv.get_reach_counts([0, 4, 99])
    assert list(counts) == [4, 4, 0]
    before = srv.index_misses
    srv.submit([(OP_ADD_E, 3, 4)])
    counts = srv.get_reach_counts([0, 4, 99])  # stale -> fused BFS fallback
    assert list(counts) == [8, 4, 0] and srv.index_misses == before + 3


def test_server_mixed_batch_stats_count_per_pair():
    """A fresh PARTIAL index serves some pairs and falls back for the rest
    in the same batch: hits/misses must be counted PER PAIR (decided pairs
    are hits, undecided pairs are misses — never the whole batch on either
    side), and repeated calls must accumulate without double counting."""
    from repro.runtime.serve_loop import GraphCoServer

    srv = GraphCoServer(capacity=64, index=True, index_landmarks=2)
    srv.submit([(OP_ADD_V, k) for k in range(8)])
    srv.submit([(OP_ADD_E, a, a + 1) for a in range(7)])
    assert srv.index_tick()
    assert not srv.index.complete
    pairs = [(0, 7), (1, 6), (7, 0), (6, 1)]   # 2 decided + 2 undecided
    res = srv.get_reach(pairs)
    assert not res.stale
    assert res.found == [True, True, False, False]
    assert res.from_index == 2 and res.fellback == 2
    assert srv.index_hits == 2 and srv.index_misses == 2
    # second identical batch: per-pair accumulation, no double counting
    res2 = srv.get_reach(pairs)
    assert srv.index_hits == 4 and srv.index_misses == 4
    assert res2.from_index == 2 and res2.fellback == 2
    # undecided-pair fallback spends one clean double collect (2 rounds),
    # which is attributed to the SESSION, not multiplied across index hits
    assert res.rounds == 2


def test_server_stale_batch_stats_count_per_pair():
    """The OTHER fallback reason: a stale epoch sends the whole batch to
    BFS — every pair is one miss, hits untouched, and again no per-batch
    multiplication on repeats."""
    from repro.runtime.serve_loop import GraphCoServer

    srv = GraphCoServer(capacity=64, index=True)
    srv.submit([(OP_ADD_V, k) for k in range(6)])
    srv.submit([(OP_ADD_E, a, a + 1) for a in range(5)])
    srv.index_tick()
    srv.submit([(OP_REM_E, 2, 3)])            # stale now
    pairs = [(0, 5), (0, 2), (3, 5)]
    res = srv.get_reach(pairs)
    assert res.stale and res.fellback == len(pairs) and res.from_index == 0
    assert srv.index_misses == 3 and srv.index_hits == 0
    res = srv.get_reach(pairs)                # still stale: +3, not +9
    assert srv.index_misses == 6 and srv.index_hits == 0


class _StubDecoder:
    """Minimal decode engine for serve(): the graph side is what's under
    test, the LM side just has to produce tokens."""

    def prefill(self, params, batch):
        b = batch["tokens"].shape[0]
        return jnp.zeros((b, 4), jnp.float32), {}

    def cache_from_prefill(self, caches, cache_len):
        return caches

    def decode_step(self, params, caches, tok, pos):
        return jnp.zeros((tok.shape[0], 4), jnp.float32), caches


def test_serve_rounds_attributed_to_fallback_pairs_only():
    """Regression (per-batch vs per-pair accounting): with the index
    enabled, getpath_rounds must charge the BFS session's rounds only to
    the pairs that actually fell back — an index hit costs 0 rounds. The
    old accounting multiplied rounds by the WHOLE batch size."""
    from repro.runtime.serve_loop import GraphCoServer, serve

    graph = GraphCoServer(capacity=64, index=True, index_landmarks=2)
    graph.submit([(OP_ADD_V, k) for k in range(8)])
    graph.submit([(OP_ADD_E, a, a + 1) for a in range(7)])
    graph.index_tick()
    assert not graph.index.complete
    # 1 decided pair (index hit, 0 rounds) + 1 undecided (2-round session)
    streams = {0: [(0, 7), (7, 0)]}
    prompts = np.zeros((1, 4), np.int32)
    _, stats = serve(_StubDecoder(), None, prompts, max_new_tokens=2,
                     cache_len=8, graph=graph,
                     query_stream=lambda i: streams.get(i))
    assert stats.getpath_calls == 2
    assert stats.index_hits == 1 and stats.index_misses == 1
    assert stats.getpath_rounds == 2   # 2 rounds x 1 fallback pair, not x2


def test_server_auto_grow_keeps_index_correct():
    from repro.runtime.serve_loop import GraphCoServer

    srv = GraphCoServer(capacity=8, index=True)
    srv.submit([(OP_ADD_V, k) for k in range(6)])
    srv.index_tick()
    srv.submit([(OP_ADD_V, k) for k in range(6, 20)])   # forces grow()
    assert srv.grow_events > 0
    srv.submit([(OP_ADD_E, a, a + 1) for a in range(19)])
    srv.index_tick()
    res = srv.get_reach([(0, 19), (19, 0)])
    assert res.found == [True, False] and res.from_index == 2


# ----------------------------------------------------------------------------
# Property: random mutation streams on dense and sharded state
# ----------------------------------------------------------------------------
KEYS = st.integers(min_value=0, max_value=7)
OPC = st.sampled_from([OP_ADD_V, OP_REM_V, OP_ADD_E, OP_REM_E])
OP = st.tuples(OPC, KEYS, KEYS)
STREAM = st.lists(st.lists(OP, min_size=1, max_size=6), min_size=1, max_size=3)


def _run_stream(op_lists, make_state, apply_fn, to_probe):
    """Shared property body: replay a mutation stream, refreshing the index
    after every batch; all-pairs index answers must match the oracle."""
    g = make_state()
    oracle = GraphOracle(CAP)
    setup = [(OP_ADD_V, k, -1, -1) for k in range(8)]
    g, _ = apply_fn(g, make_op_batch(setup))
    oracle.apply_batch(setup)
    idx = build_index(g)
    pairs = [(a, b) for a in range(8) for b in range(8)]
    for ops in op_lists:
        batch = [(op, a, b, -1) for (op, a, b) in ops]
        g, _ = apply_fn(g, make_op_batch(batch))
        oracle.apply_batch(batch)
        stale = not index_fresh(idx, g)
        # stale-but-unrefreshed sessions must fall back and stay correct
        res = reach_session(lambda: g, idx, pairs[:8])
        assert res.found == [oracle.reachable(a, b) for a, b in pairs[:8]]
        assert not stale or res.fellback > 0
        idx, _ = refresh(idx, g)
        assert index_fresh(idx, g)
        probe = to_probe(g)
        sk, sl = _slots(probe, pairs)
        reach, decided, _ = query_reach(idx, sk, sl)
        m = multi_bfs(probe, sk, sl)
        np.testing.assert_array_equal(np.asarray(reach), np.asarray(m.found))
        for (a, b), r, d in zip(pairs, np.asarray(reach),
                                np.asarray(decided)):
            assert bool(d), (a, b)
            assert bool(r) == oracle.reachable(a, b), (a, b)


@settings(max_examples=10, deadline=None)
@given(STREAM)
def test_index_tracks_mutation_stream_dense(op_lists):
    _run_stream(op_lists, lambda: make_graph(CAP),
                apply_ops_fast, lambda g: g)


@settings(max_examples=6, deadline=None)
@given(STREAM)
def test_index_tracks_mutation_stream_sharded(op_lists):
    mesh = make_graph_mesh()
    _run_stream(
        op_lists,
        lambda: partition.shard_state(mesh, make_graph(CAP)),
        partition.apply_ops_fast,
        partition.unshard,
    )
