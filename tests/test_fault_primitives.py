"""Fault primitives pinned in isolation (DESIGN.md §16).

The chaos/recovery suites build on four small mechanisms; this file pins
their exact contracts so a regression surfaces here — as one obvious
failing assert — rather than as a flaky recovery test three layers up:

  * ``FailurePolicy`` — exponential backoff sequence and hard budget;
  * ``Heartbeat``    — suspect detection under an injected fake clock;
  * ``StragglerDetector`` — EWMA baseline that stragglers cannot poison;
  * ``FaultInjector`` — one-shot plan consumption, probe-delay arming,
    and the ``SimulatedCrash`` it makes the ingest pool raise.
"""
import pytest

from repro.runtime.fault import (
    FailurePolicy,
    FaultInjector,
    Heartbeat,
    SimulatedCrash,
    StragglerDetector,
)


# -- FailurePolicy ----------------------------------------------------------
def test_failure_policy_backoff_doubles_each_restart():
    fp = FailurePolicy(max_restarts=5, backoff_s=0.5)
    assert [fp.on_failure() for _ in range(5)] == [0.5, 1.0, 2.0, 4.0, 8.0]
    assert fp.restarts == 5


def test_failure_policy_budget_exhaustion_raises():
    fp = FailurePolicy(max_restarts=2, backoff_s=1.0)
    fp.on_failure()
    fp.on_failure()
    with pytest.raises(RuntimeError, match="restart budget exhausted"):
        fp.on_failure()
    # the failed attempt still counted: the policy stays exhausted
    with pytest.raises(RuntimeError):
        fp.on_failure()


# -- Heartbeat --------------------------------------------------------------
def test_heartbeat_suspects_with_fake_clock():
    hb = Heartbeat(timeout_s=5.0)
    hb.tick("ingest", now=100.0)
    hb.tick("index", now=102.0)
    assert hb.suspects(now=104.0) == []
    assert hb.suspects(now=105.5) == ["ingest"]
    assert sorted(hb.suspects(now=110.0)) == ["index", "ingest"]


def test_heartbeat_retick_clears_suspicion():
    hb = Heartbeat(timeout_s=5.0)
    hb.tick("ingest", now=0.0)
    assert hb.suspects(now=6.0) == ["ingest"]
    hb.tick("ingest", now=6.0)      # the recovery path re-ticks survivors
    assert hb.suspects(now=10.0) == []


def test_heartbeat_boundary_is_strictly_greater():
    hb = Heartbeat(timeout_s=5.0)
    hb.tick("w", now=0.0)
    assert hb.suspects(now=5.0) == []       # exactly at timeout: alive
    assert hb.suspects(now=5.0001) == ["w"]


# -- StragglerDetector ------------------------------------------------------
def test_straggler_flagged_without_poisoning_baseline():
    sd = StragglerDetector(factor=3.0, alpha=0.1)
    for _ in range(20):
        assert not sd.observe(0.1)
    base = sd.ewma_s
    # a burst of 10x stragglers is flagged AND leaves the baseline intact,
    # so the next normal step is not mis-classified
    for _ in range(5):
        assert sd.observe(1.0)
    assert sd.flagged == 5
    assert sd.ewma_s == base
    assert not sd.observe(0.11)


def test_straggler_first_observation_seeds_baseline():
    sd = StragglerDetector(factor=3.0)
    assert not sd.observe(2.0)      # nothing to compare against yet
    assert sd.ewma_s == 2.0
    assert not sd.observe(2.5)      # within factor of the seed


# -- FaultInjector + SimulatedCrash -----------------------------------------
def test_injector_plan_entry_fires_once():
    fi = FaultInjector(plan=[("c0", "admit")])
    assert not fi.should_die("c1", "admit")     # wrong client
    assert not fi.should_die("c0", "apply")     # wrong stage
    assert fi.should_die("c0", "admit")
    assert fi.fired == [("c0", "admit")]
    assert not fi.should_die("c0", "admit")     # consumed — one-shot
    assert fi.plan == []


def test_injector_delay_arms_at_nth_probe():
    key = ("*", "wal-fsync")
    fi = FaultInjector(plan=[key], delays={key: 3})
    probes = [fi.should_die(*key) for _ in range(5)]
    assert probes == [False, False, False, True, False]
    assert fi.fired == [key]


def test_injector_durability_stages_use_sentinel_client():
    # the four §16 kill stages are probed with client "*": an entry
    # planned for a named client must never fire there
    fi = FaultInjector(plan=[("c0", "post-publish-pre-ack")])
    assert not fi.should_die("*", "post-publish-pre-ack")
    assert fi.plan == [("c0", "post-publish-pre-ack")]


def test_simulated_crash_carries_stage_and_epoch():
    exc = SimulatedCrash("wal-append", epoch=7)
    assert isinstance(exc, RuntimeError)
    assert exc.stage == "wal-append"
    assert exc.epoch == 7
    assert "wal-append" in str(exc)
    assert SimulatedCrash("ckpt-mid-write").epoch == -1
