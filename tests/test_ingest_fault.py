"""Fault injection into the multi-tenant admission pool (DESIGN.md §12).

A client batch that dies mid-admission must (a) release its sorted entity
locks, (b) leave the published state EXACTLY what the completed batches
alone produce — no torn fused ``apply_ops_fast`` where some of the dead
batch's lanes landed — and (c) leave the surviving batches' results and
the linearization log untouched by the abort. Both fault windows are
covered (``runtime.fault.FaultInjector`` stages):

  * "admit"  — dies holding its locks, before entering the fused batch;
  * "apply"  — dies AFTER the fused result including its lanes was
    computed: the pool must discard that result and recompute from the
    same pre-round state without it (the torn-write window).

Dense and mesh-sharded backends take the identical contract.
"""
import numpy as np

from repro.core import (
    OP_ADD_E, OP_ADD_V, R_TRUE, GraphOracle,
)
from repro.core.distributed import make_graph_mesh
from repro.runtime.fault import FaultInjector
from repro.testing import schedules as sch

CAP = 32


def _three_client_steps():
    """Three disjoint-footprint batches -> one fused round when healthy."""
    return [
        ("submit", "A", [(OP_ADD_V, 1, -1, -1), (OP_ADD_V, 2, -1, -1),
                         (OP_ADD_E, 1, 2, -1)]),
        ("submit", "B", [(OP_ADD_V, 11, -1, -1), (OP_ADD_V, 12, -1, -1),
                         (OP_ADD_E, 11, 12, -1)]),
        ("submit", "C", [(OP_ADD_V, 21, -1, -1), (OP_ADD_V, 22, -1, -1),
                         (OP_ADD_E, 21, 22, -1)]),
        ("pump",),
        ("read", [(1, 2), (11, 12), (21, 22)]),
    ]


def _expect_only_survivors(trace, dead_keys, alive_pairs):
    """Dead batch invisible; survivors fully applied; full lin check."""
    sch.check_aborted_invisible(trace)
    oracle = GraphOracle(CAP)
    for bid in trace.linearization:
        oracle.apply_batch(trace.pool.tickets[bid].ops)
    for k in dead_keys:
        assert k not in oracle.ecnt, f"dead batch's vertex {k} leaked"
    for (k, l) in alive_pairs:
        assert oracle.reachable(k, l)


def test_batch_dies_at_admit_releases_locks_dense():
    fault = FaultInjector(plan=[("B", "admit")])
    trace = sch.run_schedule(sch.Schedule(_three_client_steps()),
                             capacity=CAP, fault=fault)
    assert fault.fired == [("B", "admit")]
    tickets = {t.client_id: t for t in trace.pool.tickets.values()}
    assert tickets["B"].status == "aborted"
    assert tickets["A"].status == tickets["C"].status == "applied"
    assert (np.asarray(tickets["A"].results) == R_TRUE)[:2].all()
    _expect_only_survivors(trace, dead_keys=(11, 12),
                           alive_pairs=[(1, 2), (21, 22)])
    # the read in the schedule saw B's edge as absent
    assert trace.reads[0].results[1] == (False, [])


def test_batch_dies_at_apply_discards_torn_fused_result_dense():
    """B's lanes were IN the computed fused result; publishing it would be
    a torn write. The pool must recompute the round from the same pre-round
    state without B."""
    fault = FaultInjector(plan=[("B", "apply")])
    trace = sch.run_schedule(sch.Schedule(_three_client_steps()),
                             capacity=CAP, fault=fault)
    assert fault.fired == [("B", "apply")]
    tickets = {t.client_id: t for t in trace.pool.tickets.values()}
    assert tickets["B"].status == "aborted" and tickets["B"].results is None
    assert tickets["A"].status == tickets["C"].status == "applied"
    # exactly ONE epoch published for the round: the torn one never surfaced
    assert trace.pool.stats.epochs == 1
    assert trace.pool.stats.fused_calls == 1
    _expect_only_survivors(trace, dead_keys=(11, 12),
                           alive_pairs=[(1, 2), (21, 22)])
    assert trace.reads[0].results == [(True, [1, 2]), (False, []),
                                      (True, [21, 22])]


def test_batch_dies_at_apply_sharded():
    mesh = make_graph_mesh()
    fault = FaultInjector(plan=[("B", "apply")])
    trace = sch.run_schedule(sch.Schedule(_three_client_steps()),
                             capacity=CAP, mesh=mesh, fault=fault)
    assert fault.fired == [("B", "apply")]
    tickets = {t.client_id: t for t in trace.pool.tickets.values()}
    assert tickets["B"].status == "aborted"
    _expect_only_survivors(trace, dead_keys=(11, 12),
                           alive_pairs=[(1, 2), (21, 22)])


def test_batch_dies_at_admit_sharded():
    mesh = make_graph_mesh()
    fault = FaultInjector(plan=[("C", "admit")])
    trace = sch.run_schedule(sch.Schedule(_three_client_steps()),
                             capacity=CAP, mesh=mesh, fault=fault)
    assert fault.fired == [("C", "admit")]
    _expect_only_survivors(trace, dead_keys=(21, 22),
                           alive_pairs=[(1, 2), (11, 12)])


def test_dead_batchs_entities_remain_lockable():
    """After an abort, another client can immediately claim the dead
    batch's entities — the locks really were released, not leaked."""
    fault = FaultInjector(plan=[("B", "apply")])
    steps = _three_client_steps() + [
        ("submit", "D", [(OP_ADD_V, 11, -1, -1), (OP_ADD_V, 12, -1, -1),
                         (OP_ADD_E, 11, 12, -1)]),   # B's exact footprint
        ("pump",),
        ("read", [(11, 12)]),
    ]
    trace = sch.run_schedule(sch.Schedule(steps), capacity=CAP, fault=fault)
    tickets = {t.client_id: t for t in trace.pool.tickets.values()}
    assert tickets["D"].status == "applied"
    assert trace.reads[-1].results[0] == (True, [11, 12])
    sch.check_aborted_invisible(trace)


def test_whole_round_dies_publishes_nothing():
    """Every admitted batch dies at the apply stage: the round must publish
    NO epoch (state unchanged), and the queue must end drained."""
    fault = FaultInjector(plan=[("A", "apply"), ("B", "apply"),
                                ("C", "apply")])
    trace = sch.run_schedule(sch.Schedule(_three_client_steps()),
                             capacity=CAP, fault=fault)
    assert len(fault.fired) == 3
    assert trace.pool.stats.epochs == 0
    assert trace.pool.stats.applied == 0
    assert trace.pool.stats.aborted == 3
    assert trace.linearization == []
    assert trace.reads[0].results == [(False, []), (False, []), (False, [])]
    sch.check_aborted_invisible(trace)


def test_fault_then_healthy_resubmission_same_client():
    """The injector kills ONE batch, not the client: the same client's next
    batch (queued behind the dead one) applies normally in a later round."""
    fault = FaultInjector(plan=[("A", "admit")])
    steps = [
        ("submit", "A", [(OP_ADD_V, 1, -1, -1)]),    # dies
        ("submit", "A", [(OP_ADD_V, 2, -1, -1)]),    # must still land
        ("flush",),
        ("read", [(2, 2)]),
    ]
    trace = sch.run_schedule(sch.Schedule(steps), capacity=CAP, fault=fault)
    a_tickets = sorted((t for t in trace.pool.tickets.values()),
                       key=lambda t: t.batch_id)
    assert [t.status for t in a_tickets] == ["aborted", "applied"]
    assert trace.reads[0].results[0] == (True, [2])
    sch.check_aborted_invisible(trace)
