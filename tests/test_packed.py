"""Packed adjacency engine suite (DESIGN.md §10).

Three contracts:

  1. Encoding: pack/unpack roundtrip, the padding invariant (bits at column
     positions >= V stay zero through every mutation), and grow's in-place
     word extension.
  2. ONE traversable-edge predicate: every engine's edge view — num_edges,
     degree/neighbors, all four BFS backends, the sharded engine, the index
     closures — equals the view derived from ``core.graph.traversable`` on
     the same state (the differential test that pins the call sites so the
     predicate cannot drift between re-implementations again).
  3. Bit-identity under mutation streams: random add/remove vertex/edge
     batches interleaved with grow and compact, after each of which the
     packed backends ("packed", "packed_pallas") must produce bit-identical
     BFSResults / MultiBFSResults and version vectors to the float32 path
     ("jnp", "pallas"), on dense AND mesh-sharded state.
"""
import numpy as np

import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from repro.testing.proptest import given, settings, strategies as st

from repro.core import (
    OP_ADD_E, OP_ADD_V, OP_REM_E, OP_REM_V,
    apply_ops, apply_ops_fast, find_slots, make_graph, make_op_batch,
    multi_bfs, num_edges, version_vector,
)
from repro.core import partition
from repro.core.bfs import HYBRID_BACKENDS, PACKED_BACKENDS, bfs
from repro.core.distributed import make_graph_mesh
from repro.core.graph import (
    WORD_BITS,
    or_reduce,
    pack_bits,
    packed_width,
    traversable,
    traversable_packed,
    unpack_bits,
)
from repro.core.graph import grow as dense_grow
from repro.core.ops import compact as dense_compact
from repro.core.ops import degree, neighbors

RNG = np.random.default_rng(11)
CAP = 32
ALL_BACKENDS = ("jnp", "pallas") + PACKED_BACKENDS + HYBRID_BACKENDS


# ----------------------------------------------------------------------------
# 1. Encoding
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("v", [1, 6, 31, 32, 33, 64, 100, 256])
def test_pack_unpack_roundtrip(v):
    bits = jnp.asarray(RNG.random((5, v)) < 0.4)
    words = pack_bits(bits)
    assert words.shape == (5, packed_width(v)) and words.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(unpack_bits(words, v)),
                                  np.asarray(bits))
    # padding invariant: bits at positions >= v are zero
    full = unpack_bits(words, packed_width(v) * WORD_BITS)
    assert not np.asarray(full)[:, v:].any()


def test_or_reduce_matches_numpy():
    x = jnp.asarray(RNG.integers(0, 2**32, (7, 3), dtype=np.uint32))
    np.testing.assert_array_equal(
        np.asarray(or_reduce(x, 0)),
        np.bitwise_or.reduce(np.asarray(x), axis=0))
    np.testing.assert_array_equal(
        np.asarray(or_reduce(x, 1)),
        np.bitwise_or.reduce(np.asarray(x), axis=1))


def _random_state(nv=12, cap=CAP, n_edges=40, n_dead=3, seed=0):
    """A graph with live edges AND stale adjacency bits under dead slots
    (RemoveVertex leaves rows/columns lazily — the adversarial case for the
    traversable predicate)."""
    rng = np.random.default_rng(seed)
    g = make_graph(cap)
    ops = [(OP_ADD_V, k) for k in range(nv)]
    ops += [(OP_ADD_E, int(a), int(b))
            for a, b in rng.integers(0, nv, (n_edges, 2))]
    g, _ = apply_ops(g, make_op_batch(ops))
    dead = rng.choice(nv, size=n_dead, replace=False)
    g, _ = apply_ops(g, make_op_batch([(OP_REM_V, int(k)) for k in dead]))
    return g


def test_grow_preserves_packed_bits_and_padding():
    g = _random_state(seed=3)
    for new_cap in (CAP + 1, 70, 256):
        gg = dense_grow(g, new_cap)
        assert gg.adj_packed.shape == (new_cap, packed_width(new_cap))
        np.testing.assert_array_equal(
            np.asarray(gg.adj)[: g.capacity, : g.capacity], np.asarray(g.adj))
        # grown rows/columns are empty; padding bits stay zero
        assert not np.asarray(gg.adj)[g.capacity:].any()
        assert not np.asarray(gg.adj)[:, g.capacity:].any()
        full = unpack_bits(gg.adj_packed, gg.words * WORD_BITS)
        assert not np.asarray(full)[:, new_cap:].any()
        assert int(num_edges(gg)) == int(num_edges(g))


# ----------------------------------------------------------------------------
# 2. The ONE traversable-edge predicate, pinned differentially
# ----------------------------------------------------------------------------
def _np_traversable(g):
    adj = np.asarray(g.adj) > 0
    alive = np.asarray(g.valive)
    return adj & alive[:, None] & alive[None, :]


def _np_closure(t):
    """Boolean transitive closure rows of the traversable matrix."""
    v = t.shape[0]
    reach = np.eye(v, dtype=bool)
    for _ in range(v):
        nxt = reach | (reach @ t)
        if (nxt == reach).all():
            break
        reach = nxt
    return reach


def test_traversable_helpers_agree():
    g = _random_state(seed=5)
    t_np = _np_traversable(g)
    t = traversable(g.adj, g.valive)
    np.testing.assert_array_equal(np.asarray(t), t_np)
    tw = traversable_packed(g.adj_packed, g.valive, g.alive_words)
    np.testing.assert_array_equal(
        np.asarray(unpack_bits(tw, g.capacity)), t_np)
    # row-slice form (the sharded engines' view)
    r0, r1 = 8, 24
    np.testing.assert_array_equal(
        np.asarray(traversable(g.adj[r0:r1], g.valive[r0:r1], g.valive)),
        t_np[r0:r1])


def test_all_call_sites_pin_to_traversable():
    """num_edges, degree, neighbors, every BFS backend, the sharded engine
    and the index closures must all see exactly the traversable() edges."""
    g = _random_state(seed=7)
    t = _np_traversable(g)
    closure = _np_closure(t)
    vkey = np.asarray(g.vkey)
    alive = np.asarray(g.valive)

    assert int(num_edges(g)) == int(t.sum())

    for s in np.nonzero(alive)[0]:
        out_d, in_d = degree(g, int(vkey[s]))
        assert int(out_d) == int(t[s].sum()), s
        assert int(in_d) == int(t[:, s].sum()), s
        n, keys = neighbors(g, int(vkey[s]))
        assert sorted(int(k) for k in keys[: int(n)]) \
            == sorted(int(vkey[j]) for j in np.nonzero(t[s])[0]), s

    srcs = np.nonzero(alive)[0].astype(np.int32)
    dsts = np.full_like(srcs, -1)
    for backend in ALL_BACKENDS:
        m = multi_bfs(g, srcs, dsts, backend=backend)
        np.testing.assert_array_equal(
            np.asarray(m.dist >= 0), closure[srcs],
            err_msg=f"multi_bfs[{backend}] closure")
        r = bfs(g, jnp.int32(int(srcs[0])), jnp.int32(-1), backend=backend)
        np.testing.assert_array_equal(
            np.asarray(r.dist >= 0), closure[srcs[0]],
            err_msg=f"bfs[{backend}] closure")

    # sharded engine (ambient mesh: 1 shard in the container, 8 under CI)
    mesh = make_graph_mesh()
    gs = partition.shard_state(mesh, g)
    for backend in ("jnp", "packed"):
        ms = partition.multi_bfs(gs, srcs, dsts, backend=backend)
        np.testing.assert_array_equal(
            np.asarray(ms.dist >= 0), closure[srcs],
            err_msg=f"partition.multi_bfs[{backend}] closure")

    # index closures are BFS-inherited — fwd rows ARE traversable closures
    from repro.index import build_index

    idx = build_index(g)
    lm = np.asarray(idx.landmarks)
    np.testing.assert_array_equal(
        np.asarray(idx.fwd) | np.eye(g.capacity, dtype=bool)[lm],
        closure[lm], err_msg="index fwd closure")


def test_parent_scan_masks_endpoint_liveness():
    """Regression for the pre-unification drift: the jnp parent scan used
    ``adj > 0`` without re-masking liveness. A dead destination whose
    stale adjacency bit survives must never be handed a parent."""
    g = _random_state(nv=10, n_edges=30, n_dead=4, seed=9)
    alive = np.asarray(g.valive)
    stale = (np.asarray(g.adj) > 0) & ~(_np_traversable(g))
    assert stale.any(), "fixture must contain stale (dead-endpoint) bits"
    srcs = np.nonzero(alive)[0].astype(np.int32)
    for backend in ALL_BACKENDS:
        m = multi_bfs(g, srcs, np.full_like(srcs, -1), backend=backend)
        parent = np.asarray(m.parent)
        dist = np.asarray(m.dist)
        # dead slots are never visited and never parented
        assert not (dist[:, ~alive] >= 0).any(), backend
        assert (parent[:, ~alive] == -1).all(), backend
        # every assigned parent is an alive vertex with a traversable edge
        t = _np_traversable(g)
        for qi in range(len(srcs)):
            for j in np.nonzero(parent[qi] >= 0)[0]:
                p = parent[qi, j]
                assert alive[p] and t[p, j], (backend, qi, j, p)


# ----------------------------------------------------------------------------
# 3. Mutation-stream bit-identity property (dense + sharded, all backends)
# ----------------------------------------------------------------------------
KEYS = st.integers(min_value=0, max_value=9)
OPC = st.sampled_from([OP_ADD_V, OP_REM_V, OP_ADD_E, OP_REM_E])
OP = st.tuples(OPC, KEYS, KEYS)
STREAM = st.lists(st.lists(OP, min_size=1, max_size=8), min_size=1, max_size=3)


def _assert_results_bitwise_equal(a, b, ctx=""):
    for name, xa, xb in zip(a._fields, a, b):
        np.testing.assert_array_equal(
            np.asarray(xa), np.asarray(xb),
            err_msg=f"{ctx}field {name!r} diverges")


@settings(max_examples=8, deadline=None)
@given(STREAM)
def test_packed_engines_bit_identical_over_mutation_stream(op_lists):
    mesh = make_graph_mesh()
    g = make_graph(CAP)
    gs = partition.shard_state(mesh, g)
    g, _ = apply_ops_fast(g, make_op_batch([(OP_ADD_V, k) for k in range(8)]))
    gs, _ = partition.apply_ops_fast(
        gs, make_op_batch([(OP_ADD_V, k) for k in range(8)]))
    pairs = [(0, 7), (3, 1), (5, 5), (2, 9)]
    for step, ops in enumerate(op_lists):
        batch = make_op_batch([(op, a, b, -1) for (op, a, b) in ops])
        g, rd = apply_ops_fast(g, batch)
        gs, rs = partition.apply_ops_fast(gs, batch)
        np.testing.assert_array_equal(np.asarray(rd), np.asarray(rs))
        if step == 1:  # exercise grow + compact mid-stream
            g = dense_grow(dense_compact(g), CAP * 2)
            gs = partition.grow(partition.compact(gs), CAP * 2)
        np.testing.assert_array_equal(
            np.asarray(version_vector(g)),
            np.asarray(version_vector(gs.as_dense())),
            err_msg="version vectors diverge")
        sk = find_slots(g, jnp.asarray([p[0] for p in pairs], jnp.int32))
        sl = find_slots(g, jnp.asarray([p[1] for p in pairs], jnp.int32))
        ref = multi_bfs(g, sk, sl, backend="jnp")
        for backend in ("packed", "packed_pallas"):
            _assert_results_bitwise_equal(
                ref, multi_bfs(g, sk, sl, backend=backend),
                ctx=f"dense[{backend}] ")
        for backend in ("packed", "packed_pallas"):
            _assert_results_bitwise_equal(
                ref, partition.multi_bfs(gs, sk, sl, backend=backend),
                ctx=f"sharded[{backend}] ")
        r_ref = bfs(g, sk[0], sl[0], backend="jnp")
        for backend in PACKED_BACKENDS:
            _assert_results_bitwise_equal(
                r_ref, bfs(g, sk[0], sl[0], backend=backend),
                ctx=f"bfs[{backend}] ")
    # final states agree bit for bit (packed words included)
    for name, xa, xb in zip(g._fields, g, partition.unshard(gs)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb),
                                      err_msg=name)
