"""End-to-end system tests: train loop drives losses down on the graph
path-task; serving co-hosts LM decode with snapshot graph queries."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import OP_ADD_E, OP_ADD_V
from repro.data.pipeline import GraphPathData, SyntheticLMData
from repro.models.model import build_model
from repro.runtime.serve_loop import GraphCoServer, serve
from repro.runtime.train_loop import TrainLoopConfig, train


def test_train_loss_decreases(tmp_path):
    cfg = get_config("olmo-1b").smoke()
    model = build_model(cfg)
    data = SyntheticLMData(64, seed=0)  # low-entropy vocab subset: learnable
    tl = TrainLoopConfig(total_steps=30, checkpoint_every=100, log_every=1,
                         checkpoint_dir=str(tmp_path), lr=1e-3)
    _, _, hist = train(model, data, batch_size=4, seq_len=32, cfg=tl,
                       log=lambda *_: None)
    losses = [l for _, l, _ in hist]
    assert losses[-1] < losses[0], losses
    assert np.isfinite(losses).all()


def test_train_on_graph_path_task(tmp_path):
    """The paper-integration workload end to end: corpus generated from the
    concurrent graph engine's GetPath answers."""
    cfg = get_config("qwen2-1.5b").smoke()
    model = build_model(cfg)
    data = GraphPathData(n_vertices=8, seed=0)
    tl = TrainLoopConfig(total_steps=8, checkpoint_every=100, log_every=1,
                         checkpoint_dir=str(tmp_path), lr=1e-3)
    _, _, hist = train(model, data, batch_size=2, seq_len=96, cfg=tl,
                       log=lambda *_: None)
    assert np.isfinite([l for _, l, _ in hist]).all()


def test_serve_with_graph_coserving():
    cfg = get_config("olmo-1b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32)

    graph = GraphCoServer(capacity=64)
    graph.submit([(OP_ADD_V, k) for k in range(8)])

    def mutator(i):
        u, v = rng.integers(0, 8, 2)
        return [(OP_ADD_E, int(u), int(v))]

    def queries(i):
        if i % 3 == 0:
            return 0, 5
        return None

    out, stats = serve(model, params, prompts, max_new_tokens=6,
                       cache_len=32, graph=graph, mutator=mutator,
                       query_stream=queries)
    assert out.shape == (2, 6)
    assert stats.decode_tokens == 12
    assert stats.getpath_calls == 2
    assert stats.graph_ops > 0


def test_serve_with_batched_graph_queries():
    """The fused multi-query path through serve(): a query_stream may return
    a BATCH of (k, l) pairs (list/tuple/ndarray), answered under one shared
    double collect, with rounds accounted per query so avg rounds-per-call
    keeps its '2.0 = clean double collect' meaning."""
    cfg = get_config("olmo-1b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (1, 8)).astype(np.int32)

    graph = GraphCoServer(capacity=64)
    graph.submit([(OP_ADD_V, k) for k in range(8)])
    graph.submit([(OP_ADD_E, 0, 1), (OP_ADD_E, 1, 5)])

    # every container shape a stream might produce
    streams = {
        0: [(0, 5), (5, 0), (2, 2)],          # list of pairs
        1: ((0, 1), (1, 5)),                  # tuple of pairs
        2: np.array([3, 4]),                  # single pair as ndarray
        3: np.array([[0, 5], [1, 1]]),        # ndarray batch
        4: [],                                # empty batch: no traffic
    }
    out, stats = serve(model, params, prompts, max_new_tokens=6,
                       cache_len=32, graph=graph,
                       query_stream=lambda i: streams.get(i))
    assert out.shape == (1, 6)
    assert stats.getpath_calls == 3 + 2 + 1 + 2
    # graph is quiescent (no mutator): every session is a clean double
    # collect, so the documented metric must sit exactly at 2.0
    assert stats.getpath_rounds / stats.getpath_calls == 2.0

    # and the direct batched surface answers correctly
    res, rounds = graph.get_paths([(0, 5), (5, 0), (99, 0)])
    assert rounds == 2
    assert res == [(True, [0, 1, 5]), (False, []), (False, [])]
