"""Pallas kernel validation: shape/dtype sweeps, assert_allclose vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import pack_bits
from repro.kernels.bfs_step.kernel import bfs_step_pallas
from repro.kernels.bfs_step.ops import bfs_step, bfs_step_packed
from repro.kernels.bfs_step.ref import bfs_step_ref
from repro.kernels.bfs_multi_step.kernel import multi_bfs_step_pallas
from repro.kernels.bfs_multi_step.ops import (
    multi_bfs_step,
    multi_bfs_step_packed,
)
from repro.kernels.bfs_multi_step.ref import multi_bfs_step_ref
from repro.kernels.edge_update.kernel import edge_update_pallas
from repro.kernels.edge_update.ops import edge_update, edge_update_packed
from repro.kernels.edge_update.ref import edge_update_packed_ref, edge_update_ref

RNG = np.random.default_rng(42)


def _graph_inputs(v, density, adtype):
    adj = (RNG.random((v, v)) < density).astype(adtype)
    frontier = RNG.random(v) < 0.15
    alive = RNG.random(v) < 0.9
    visited = frontier | (RNG.random(v) < 0.2)
    return adj, frontier, alive, visited


@pytest.mark.parametrize("v", [16, 64, 128, 256, 512])
@pytest.mark.parametrize("density", [0.0, 0.05, 0.5])
def test_bfs_step_shapes(v, density):
    adj, frontier, alive, visited = _graph_inputs(v, density, np.uint8)
    nf_k, par_k = bfs_step(jnp.asarray(frontier), jnp.asarray(adj),
                           jnp.asarray(alive), jnp.asarray(visited))
    nf_r, par_r = bfs_step_ref(jnp.asarray(frontier, jnp.float32), jnp.asarray(adj),
                               jnp.asarray(alive, jnp.int32),
                               jnp.asarray(visited, jnp.int32))
    np.testing.assert_allclose(np.asarray(nf_k, np.int32), np.asarray(nf_r))
    np.testing.assert_allclose(np.asarray(par_k), np.asarray(par_r))


@pytest.mark.parametrize("adtype", [np.uint8, np.int8])
def test_bfs_step_dtypes(adtype):
    adj, frontier, alive, visited = _graph_inputs(128, 0.05, adtype)
    nf_k, par_k = bfs_step_pallas(
        jnp.asarray(frontier, jnp.float32), jnp.asarray(adj),
        jnp.asarray(alive, jnp.int32), jnp.asarray(visited, jnp.int32),
        tr=64, tc=64)
    nf_r, par_r = bfs_step_ref(
        jnp.asarray(frontier, jnp.float32), jnp.asarray(adj),
        jnp.asarray(alive, jnp.int32), jnp.asarray(visited, jnp.int32))
    np.testing.assert_allclose(np.asarray(nf_k), np.asarray(nf_r))
    np.testing.assert_allclose(np.asarray(par_k), np.asarray(par_r))


@pytest.mark.parametrize("tr,tc", [(8, 8), (32, 128), (128, 32), (128, 128)])
def test_bfs_step_block_shapes(tr, tc):
    v = 256
    adj, frontier, alive, visited = _graph_inputs(v, 0.05, np.uint8)
    nf_k, par_k = bfs_step_pallas(
        jnp.asarray(frontier, jnp.float32), jnp.asarray(adj),
        jnp.asarray(alive, jnp.int32), jnp.asarray(visited, jnp.int32),
        tr=tr, tc=tc)
    nf_r, par_r = bfs_step_ref(
        jnp.asarray(frontier, jnp.float32), jnp.asarray(adj),
        jnp.asarray(alive, jnp.int32), jnp.asarray(visited, jnp.int32))
    np.testing.assert_allclose(np.asarray(nf_k), np.asarray(nf_r))
    np.testing.assert_allclose(np.asarray(par_k), np.asarray(par_r))


def test_bfs_step_empty_frontier():
    v = 128
    adj = (RNG.random((v, v)) < 0.1).astype(np.uint8)
    nf, par = bfs_step(jnp.zeros(v, bool), jnp.asarray(adj),
                       jnp.ones(v, bool), jnp.zeros(v, bool))
    assert not bool(jnp.any(nf))
    assert bool(jnp.all(par == -1))


def _multi_inputs(q, v, density):
    adj = (RNG.random((v, v)) < density).astype(np.uint8)
    f = (RNG.random((q, v)) < 0.15).astype(np.float32)
    alive = (RNG.random(v) < 0.9).astype(np.int32)
    visited = ((f > 0) | (RNG.random((q, v)) < 0.2)).astype(np.int32)
    return [jnp.asarray(x) for x in (f, adj, alive, visited)]


@pytest.mark.parametrize("q", [1, 8, 64])
@pytest.mark.parametrize("v", [64, 256])
@pytest.mark.parametrize("density", [0.0, 0.05, 0.5])
def test_multi_bfs_step_shapes(q, v, density):
    f, adj, alive, visited = _multi_inputs(q, v, density)
    nf_k, par_k = multi_bfs_step(f > 0, adj, alive > 0, visited > 0)
    nf_r, par_r = multi_bfs_step_ref(f, adj, alive, visited)
    np.testing.assert_allclose(np.asarray(nf_k, np.int32), np.asarray(nf_r))
    np.testing.assert_allclose(np.asarray(par_k), np.asarray(par_r))


@pytest.mark.parametrize("tr,tc", [(32, 32), (32, 128), (128, 32)])
def test_multi_bfs_step_block_shapes(tr, tc):
    f, adj, alive, visited = _multi_inputs(8, 128, 0.05)
    nf_k, par_k = multi_bfs_step_pallas(f, adj, alive, visited, tr=tr, tc=tc)
    nf_r, par_r = multi_bfs_step_ref(f, adj, alive, visited)
    np.testing.assert_allclose(np.asarray(nf_k), np.asarray(nf_r))
    np.testing.assert_allclose(np.asarray(par_k), np.asarray(par_r))


def test_multi_bfs_step_parent_loop_fallback():
    """Large query slabs switch the parent masked-min to the per-query
    fori_loop that bounds VMEM; both strategies must agree with the ref.
    The budget is a static jit argument, so passing 0 pins this
    compilation to the fori_loop path regardless of trace caching."""
    f, adj, alive, visited = _multi_inputs(16, 128, 0.08)
    ref = multi_bfs_step_ref(f, adj, alive, visited)
    out = multi_bfs_step_pallas(f, adj, alive, visited, tr=64, tc=64,
                                parent_bcast_budget=0)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]))
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(ref[1]))


def test_multi_bfs_step_empty_frontier():
    v, q = 128, 5
    adj = (RNG.random((v, v)) < 0.1).astype(np.uint8)
    nf, par = multi_bfs_step(jnp.zeros((q, v), bool), jnp.asarray(adj),
                             jnp.ones(v, bool), jnp.zeros((q, v), bool))
    assert not bool(jnp.any(nf))
    assert bool(jnp.all(par == -1))


@pytest.mark.parametrize("v,b", [(16, 4), (64, 32), (128, 64), (256, 256)])
def test_edge_update_shapes(v, b):
    adj = (RNG.random((v, v)) < 0.05).astype(np.uint8)
    ecnt = RNG.integers(0, 5, v).astype(np.int32)
    rows = RNG.integers(0, v, b).astype(np.int32)
    cols = RNG.integers(0, v, b).astype(np.int32)
    vals = RNG.integers(0, 2, b).astype(np.int32)
    mask = RNG.integers(0, 2, b).astype(np.int32)
    args = [jnp.asarray(x) for x in (adj, ecnt, rows, cols, vals, mask)]
    a_k, e_k = edge_update(*args)
    a_r, e_r = edge_update_ref(*args)
    np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_r))
    np.testing.assert_allclose(np.asarray(e_k), np.asarray(e_r))


def test_edge_update_duplicate_targets_last_wins():
    v = 16
    adj = np.zeros((v, v), np.uint8)
    ecnt = np.zeros(v, np.int32)
    rows = np.array([3, 3, 3], np.int32)
    cols = np.array([5, 5, 5], np.int32)
    vals = np.array([1, 0, 1], np.int32)   # last lane sets 1
    mask = np.ones(3, np.int32)
    a_k, e_k = edge_update(*[jnp.asarray(x) for x in (adj, ecnt, rows, cols, vals, mask)])
    assert int(a_k[3, 5]) == 1
    assert int(e_k[3]) == 3                 # one FAA per fired op


def test_edge_update_tile_sweep():
    v, b = 64, 32
    adj = (RNG.random((v, v)) < 0.1).astype(np.uint8)
    ecnt = np.zeros(v, np.int32)
    rows = RNG.integers(0, v, b).astype(np.int32)
    cols = RNG.integers(0, v, b).astype(np.int32)
    vals = RNG.integers(0, 2, b).astype(np.int32)
    mask = np.ones(b, np.int32)
    ref = edge_update_ref(*[jnp.asarray(x) for x in (adj, ecnt, rows, cols, vals, mask)])
    for tr in (2, 4, 8, 16):
        out = edge_update_pallas(
            jnp.asarray(adj), jnp.asarray(ecnt), jnp.asarray(rows),
            jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(mask), tr=tr)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]))
        np.testing.assert_allclose(np.asarray(out[1]), np.asarray(ref[1]))


# ----------------------------------------------------------------------------
# Packed-word kernel variants (DESIGN.md §10): the kernel and its jnp ref must
# agree with the DENSE kernel on the packed form of the same inputs — frontier
# rows restricted to alive vertices, the precondition every engine guarantees.
# ----------------------------------------------------------------------------
def _packed_graph_inputs(v, density):
    adjb = RNG.random((v, v)) < density
    alive = RNG.random(v) < 0.9
    frontier = (RNG.random(v) < 0.15) & alive
    visited = frontier | ((RNG.random(v) < 0.2) & alive)
    return adjb, frontier, alive, visited


@pytest.mark.parametrize("v", [6, 64, 200, 256])
@pytest.mark.parametrize("density", [0.0, 0.05, 0.5])
def test_bfs_step_packed_matches_dense(v, density):
    adjb, frontier, alive, visited = _packed_graph_inputs(v, density)
    nf_d, par_d = bfs_step(jnp.asarray(frontier), jnp.asarray(adjb, jnp.uint8),
                           jnp.asarray(alive), jnp.asarray(visited))
    nf_p, par_p = bfs_step_packed(jnp.asarray(frontier),
                                  pack_bits(jnp.asarray(adjb)),
                                  jnp.asarray(alive), jnp.asarray(visited))
    np.testing.assert_array_equal(np.asarray(nf_d), np.asarray(nf_p))
    np.testing.assert_array_equal(np.asarray(par_d), np.asarray(par_p))


@pytest.mark.parametrize("q,v", [(1, 64), (5, 200), (8, 256)])
def test_multi_bfs_step_packed_matches_dense(q, v):
    adjb = RNG.random((v, v)) < 0.08
    alive = RNG.random(v) < 0.9
    f = (RNG.random((q, v)) < 0.15) & alive[None, :]
    visited = f | ((RNG.random((q, v)) < 0.2) & alive[None, :])
    args_d = (jnp.asarray(f), jnp.asarray(adjb, jnp.uint8),
              jnp.asarray(alive), jnp.asarray(visited))
    nf_d, par_d = multi_bfs_step(*args_d)
    nf_p, par_p = multi_bfs_step_packed(
        jnp.asarray(f), pack_bits(jnp.asarray(adjb)),
        jnp.asarray(alive), jnp.asarray(visited))
    np.testing.assert_array_equal(np.asarray(nf_d), np.asarray(nf_p))
    np.testing.assert_array_equal(np.asarray(par_d), np.asarray(par_p))


def test_multi_bfs_step_packed_row_slice():
    """The sharded engine hands the packed kernel a contiguous ROW SLICE;
    parent ids come back slice-relative, like the dense kernel's."""
    v, rows, q = 64, 16, 4
    adjb = jnp.asarray(RNG.random((rows, v)) < 0.1)
    f = jnp.asarray(RNG.random((q, rows)) < 0.3)
    alive = jnp.asarray(RNG.random(v) < 0.9)
    visited = jnp.asarray(RNG.random((q, v)) < 0.2)
    nf_p, par_p = multi_bfs_step_packed(f, pack_bits(adjb), alive, visited)
    nf_d, par_d = multi_bfs_step(f, adjb.astype(jnp.uint8), alive, visited)
    np.testing.assert_array_equal(np.asarray(nf_p), np.asarray(nf_d))
    np.testing.assert_array_equal(np.asarray(par_p), np.asarray(par_d))


@pytest.mark.parametrize("v,b", [(16, 4), (64, 32), (128, 64)])
def test_edge_update_packed_matches_dense_and_ref(v, b):
    adjb = RNG.random((v, v)) < 0.05
    adjp = pack_bits(jnp.asarray(adjb))
    ecnt = jnp.asarray(RNG.integers(0, 5, v), jnp.int32)
    rows = jnp.asarray(RNG.integers(0, v, b), jnp.int32)
    cols = jnp.asarray(RNG.integers(0, v, b), jnp.int32)
    vals = jnp.asarray(RNG.integers(0, 2, b), jnp.int32)
    mask = jnp.asarray(RNG.integers(0, 2, b), jnp.int32)
    a_d, e_d = edge_update(jnp.asarray(adjb, jnp.uint8), ecnt,
                           rows, cols, vals, mask)
    a_p, e_p = edge_update_packed(adjp, ecnt, rows, cols, vals, mask)
    a_r, e_r = edge_update_packed_ref(adjp, ecnt, rows, cols, vals, mask)
    np.testing.assert_array_equal(
        np.asarray(pack_bits(a_d.astype(jnp.bool_))), np.asarray(a_p))
    np.testing.assert_array_equal(np.asarray(a_p), np.asarray(a_r))
    np.testing.assert_array_equal(np.asarray(e_d), np.asarray(e_p))
    np.testing.assert_array_equal(np.asarray(e_d), np.asarray(e_r))


def test_label_join_packed_matches_dense():
    from repro.kernels.label_join.ops import label_join_packed
    from repro.kernels.label_join.ref import label_join_packed_ref, label_join_ref

    for q, l in ((1, 1), (5, 7), (16, 130), (33, 256)):
        a = jnp.asarray(RNG.random((q, l)) < 0.2)
        b = jnp.asarray(RNG.random((q, l)) < 0.2)
        hd, ud = label_join_ref(a.astype(jnp.int32), b.astype(jnp.int32))
        hp, up = label_join_packed(pack_bits(a), pack_bits(b))
        hr, ur = label_join_packed_ref(pack_bits(a), pack_bits(b))
        for got_h, got_u in ((hp, up), (hr, ur)):
            np.testing.assert_array_equal(np.asarray(hd), np.asarray(got_h),
                                          err_msg=f"{q},{l}")
            np.testing.assert_array_equal(np.asarray(ud), np.asarray(got_u),
                                          err_msg=f"{q},{l}")


def test_pallas_backend_full_bfs_matches_jnp():
    from repro.core import add_edge, add_vertex, get_path, make_graph
    g = make_graph(64)
    for k in range(12):
        g, _ = add_vertex(g, k)
    for (a, b) in [(0, 1), (1, 2), (2, 3), (3, 11), (0, 5), (5, 11), (4, 0)]:
        g, _ = add_edge(g, a, b)
    for (s, d) in [(0, 11), (4, 3), (11, 0), (6, 7)]:
        pj = get_path(g, s, d, backend="jnp")
        pp = get_path(g, s, d, backend="pallas")
        assert bool(pj.found) == bool(pp.found)
        np.testing.assert_array_equal(np.asarray(pj.keys), np.asarray(pp.keys))
