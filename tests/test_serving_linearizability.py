"""Serving-scale linearizability: N-client schedules through the ingest pool.

The paper claims every graph operation is linearizable under true
concurrency; PR 6 exercises that claim at serving scale (DESIGN.md §12):
client batches with colliding entity IDs are admitted concurrently
(conflict-detected, sorted-entity-lock, coalesced into fused applies) while
reads hit published snapshot epochs. Every explored schedule must satisfy
``repro.testing.schedules.check_trace_linearizable``:

  * the final state is BIT-identical to the pool's claimed serial order of
    the client batches replayed through the sequential reference engine;
  * every delivered result code matches the sequential oracle in that order;
  * every read is explained by the linearization prefix at its epoch;
  * batches fused into one round commute (any permutation is an equally
    valid serial order).

Failures minimize deterministically: ``shrink_schedule`` deletes steps and
lanes while the failure reproduces, so a falsified property surfaces as a
readable counterexample schedule, not a 40-step transcript.
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from repro.testing.proptest import given, settings, strategies as st

from repro.core import OP_ADD_E, OP_ADD_V
from repro.core.distributed import make_graph_mesh
from repro.testing import schedules as sch

CAP = 32
# conflict-rate sweep: disjoint footprints (maximal coalescing) through
# all-hot-keys (maximal contention, the interesting failure modes)
RATES = (0.0, 0.3, 0.7, 1.0)


def _run_with_shrink(schedule: sch.Schedule, **run_kw):
    """Check a schedule; on failure, shrink deterministically and raise the
    minimized counterexample (the suite's readable-failure contract)."""
    try:
        return sch.run_and_check(schedule, **run_kw)
    except AssertionError as err:

        def fails(candidate: sch.Schedule) -> bool:
            try:
                sch.run_and_check(candidate, **run_kw)
                return False
            except AssertionError:
                return True

        small = sch.shrink_schedule(schedule, fails)
        raise AssertionError(
            "linearizability violated; minimized schedule:\n"
            f"{small.pretty()}\noriginal failure: {err}") from err


def _schedule_for_seed(seed: int, *, clients=3, batches_per_client=2,
                       max_lanes=5) -> sch.Schedule:
    rng = random.Random(seed)
    programs = sch.gen_client_programs(
        rng, clients=clients, batches_per_client=batches_per_client,
        max_lanes=max_lanes, conflict_rate=RATES[seed % len(RATES)])
    return sch.random_schedule(rng, programs)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_random_multiclient_schedules_linearizable_dense(seed):
    _run_with_shrink(_schedule_for_seed(seed), capacity=CAP)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_random_multiclient_schedules_linearizable_sharded(seed):
    mesh = make_graph_mesh()
    _run_with_shrink(_schedule_for_seed(seed), capacity=CAP, mesh=mesh)


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_small_capacity_schedules_exercise_autogrow(seed):
    """Capacity 6 < the key space: fused rounds hit R_TABLE_FULL and take
    the grow-and-replay path; the grown execution must STILL be bit-
    identical to its serial order (which grows at the same points)."""
    trace = _run_with_shrink(_schedule_for_seed(seed), capacity=6)
    assert trace.pool._head.capacity >= 6


def test_enumerated_interleavings_two_clients():
    """EXACT exploration: every merge order of two 2-batch client programs
    (hot shared keys) is executed and checked — the enumerated complement
    to the randomized sweep."""
    rng = random.Random(1234)
    programs = sch.gen_client_programs(
        rng, clients=2, batches_per_client=2, max_lanes=4, conflict_rate=0.8)
    n = 0
    for schedule in sch.enumerate_interleavings(programs, limit=16):
        _run_with_shrink(schedule, capacity=CAP)
        n += 1
    assert n == 6   # 4!/(2!*2!) merge orders, fully enumerated


def test_disjoint_clients_coalesce_into_one_fused_call():
    """conflict_rate=0 ==> pairwise entity-disjoint batches: one pump must
    admit every client in a single fused apply (and the trace still passes
    the full linearizability check, including commutation)."""
    programs = {f"c{i}": [[(OP_ADD_V, 10 * i + 1, -1, -1),
                           (OP_ADD_V, 10 * i + 2, -1, -1),
                           (OP_ADD_E, 10 * i + 1, 10 * i + 2, -1)]]
                for i in range(4)}
    steps = [("submit", c, programs[c][0]) for c in sorted(programs)]
    steps += [("pump",), ("read", [(1, 2), (11, 12), (21, 1)])]
    trace = sch.run_and_check(sch.Schedule(steps), capacity=CAP)
    assert trace.pool.stats.fused_calls == 1
    assert trace.pool.stats.coalesce_max == 4
    assert trace.pool.stats.retries == 0
    groups = sch.fused_groups(trace)
    assert [len(g) for g in groups] == [4]
    # the read observed the fully-applied epoch
    assert trace.reads[0].results[0] == (True, [1, 2])
    assert trace.reads[0].results[2] == (False, [])


def test_colliding_clients_serialize_with_retries():
    """All clients hammer the same two entities: admission must serialize
    them (one batch per round) and count the conflict losses as retries."""
    programs = {f"c{i}": [[(OP_ADD_V, 0, -1, -1), (OP_ADD_E, 0, 1, -1)]]
                for i in range(3)}
    steps = [("submit", c, programs[c][0]) for c in sorted(programs)]
    steps += [("pump",), ("pump",), ("pump",)]
    trace = sch.run_and_check(sch.Schedule(steps), capacity=CAP)
    assert trace.pool.stats.fused_calls == 3       # one round each
    assert trace.pool.stats.coalesce_max == 1
    assert trace.pool.stats.retries >= 3           # c1+c2 lost round 1, c2 round 2


def test_reads_observe_intermediate_epochs_not_queue():
    """A read between rounds sees the last PUBLISHED epoch — batches still
    queued are invisible (the double-buffer contract: readers never wait
    on, or observe, a round mid-admission)."""
    steps = [
        ("submit", "a", [(OP_ADD_V, 1, -1, -1), (OP_ADD_V, 2, -1, -1),
                         (OP_ADD_E, 1, 2, -1)]),
        ("pump",),
        ("read", [(1, 2)]),
        ("submit", "b", [(OP_ADD_E, 2, 1, -1)]),
        ("read", [(2, 1)]),              # b is queued, NOT applied
        ("pump",),
        ("read", [(2, 1)]),
    ]
    trace = sch.run_and_check(sch.Schedule(steps), capacity=CAP)
    assert trace.reads[0].epoch == 1
    assert trace.reads[0].results[0] == (True, [1, 2])
    assert trace.reads[1].epoch == 1                 # still epoch 1
    assert trace.reads[1].results[0] == (False, [])  # queued write invisible
    assert trace.reads[2].epoch == 2
    assert trace.reads[2].results[0] == (True, [2, 1])


def test_shrink_minimizes_to_readable_counterexample():
    """The deterministic shrinker reduces a 20+-step schedule to the single
    step a (synthetic) failure predicate needs — pinning that real failures
    arrive minimized, and that shrinking is deterministic for a fixed
    input."""
    rng = random.Random(99)
    programs = sch.gen_client_programs(rng, clients=3, batches_per_client=3,
                                       conflict_rate=0.5)
    schedule = sch.random_schedule(rng, programs)
    assert len(schedule.steps) > 8

    def fails(s: sch.Schedule) -> bool:   # "bug": any AddE lane by client c1
        return any(step[0] == "submit" and step[1] == "c1"
                   and any(op[0] == OP_ADD_E for op in step[2])
                   for step in s.steps)

    assert fails(schedule)
    small = sch.shrink_schedule(schedule, fails)
    small2 = sch.shrink_schedule(schedule, fails)
    assert [s for s in small.steps] == [s for s in small2.steps]  # deterministic
    assert len(small.steps) == 1
    step = small.steps[0]
    assert step[0] == "submit" and step[1] == "c1" and len(step[2]) == 1
    assert step[2][0][0] == OP_ADD_E


def _epoch_schedule_for_seed(seed: int) -> sch.Schedule:
    """Schedules sprinkled with hostile wait-free reads and time-travel
    reads (DESIGN.md §13) on top of the usual mutation interleavings."""
    rng = random.Random(seed)
    programs = sch.gen_client_programs(
        rng, clients=3, batches_per_client=2,
        conflict_rate=RATES[seed % len(RATES)])
    return sch.random_schedule(rng, programs, epoch_read_rate=0.5,
                               tt_read_rate=0.3)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_epoch_resolved_reads_linearizable_dense(seed):
    """Wait-free epoch-resolved reads (the double collect CANNOT match: a
    mutation lands in the dependency set on every fetch) and time-travel
    reads must still satisfy obligation (4): every observation equals BFS
    over the oracle at its epoch's linearization prefix — i.e. the §13
    answers are bit-consistent with a serial replay."""
    # capacity 128 headroom: the hostile reads add fresh sink vertices, and
    # an auto-grow mid-schedule would reset the ring (tested elsewhere)
    _run_with_shrink(_epoch_schedule_for_seed(seed), capacity=128)


@settings(max_examples=4, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_epoch_resolved_reads_linearizable_sharded(seed):
    mesh = make_graph_mesh()
    _run_with_shrink(_epoch_schedule_for_seed(seed), capacity=128, mesh=mesh)


def test_hostile_epoch_read_starves_and_pins_a_serial_prefix():
    """Deterministic core of the sweep above: the hostile read exhausts its
    budget (starved=True), resolves at a pinned epoch, and its answers are
    exactly the pinned prefix's (check_trace_linearizable obligation 4)."""
    steps = [
        ("submit", "a", [(OP_ADD_V, 0, -1, -1), (OP_ADD_V, 1, -1, -1),
                         (OP_ADD_E, 0, 1, -1)]),
        ("pump",),
        ("read_epoch", [(0, 1), (1, 0)]),
        ("flush",),
    ]
    trace = sch.run_and_check(sch.Schedule(steps), capacity=128)
    obs = trace.reads[0]
    assert obs.mode == "epoch"
    assert obs.starved                     # the adversary really starved it
    assert obs.results[0] == (True, [0, 1])
    assert obs.results[1][0] is False
    # the pinned epoch is a real published epoch with a recorded prefix
    assert obs.epoch in trace.pool.epoch_log


def test_time_travel_reads_observe_past_epochs():
    """tt steps answer from the ring's reconstruction: the SAME pair flips
    found across epochs exactly at the publish that added the edge."""
    steps = [
        ("submit", "a", [(OP_ADD_V, 1, -1, -1), (OP_ADD_V, 2, -1, -1)]),
        ("pump",),                                      # epoch 1
        ("submit", "a", [(OP_ADD_E, 1, 2, -1)]),
        ("pump",),                                      # epoch 2
        ("tt", 1, [(1, 2)]),                            # back 1 -> epoch 1
        ("tt", 0, [(1, 2)]),                            # back 0 -> epoch 2
    ]
    trace = sch.run_and_check(sch.Schedule(steps), capacity=CAP)
    assert [o.mode for o in trace.reads] == ["tt", "tt"]
    assert trace.reads[0].epoch == 1
    assert trace.reads[0].results[0] == (False, [])     # edge not yet live
    assert trace.reads[1].epoch == 2
    assert trace.reads[1].results[0] == (True, [1, 2])


def test_zero_epoch_rates_leave_seeded_schedules_identical():
    """Back-compat guard: epoch_read_rate=0/tt_read_rate=0 must not draw
    from the rng, so every pre-existing seeded schedule stays byte-equal."""
    for seed in (0, 7, 991):
        rng1 = random.Random(seed)
        p1 = sch.gen_client_programs(rng1)
        s1 = sch.random_schedule(rng1, p1)
        rng2 = random.Random(seed)
        p2 = sch.gen_client_programs(rng2)
        s2 = sch.random_schedule(rng2, p2, epoch_read_rate=0.0,
                                 tt_read_rate=0.0)
        assert s1.steps == s2.steps


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_large_schedules_linearizable_dense_slow(seed):
    """5 clients x 4 batches, bigger lanes — the serving-tests CI job's
    deep exploration (kept out of default tier-1 by the slow marker)."""
    _run_with_shrink(
        _schedule_for_seed(seed, clients=5, batches_per_client=4,
                           max_lanes=8),
        capacity=CAP)


@pytest.mark.slow
@settings(max_examples=4, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_large_schedules_linearizable_sharded_slow(seed):
    mesh = make_graph_mesh()
    _run_with_shrink(
        _schedule_for_seed(seed, clients=4, batches_per_client=3,
                           max_lanes=6),
        capacity=CAP, mesh=mesh)
