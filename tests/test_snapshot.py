"""Double-collect GetPath tests: paper §3.5 incl. the adversary argument."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from repro.testing.proptest import given, settings, strategies as st

from repro.core import (
    OP_ADD_E, OP_ADD_V, OP_NOP, OP_REM_E,
    GraphOracle, add_edge, add_vertex, collect, compare_collects, get_path,
    get_path_session, interleaved_getpath, make_graph, make_op_batch,
    remove_edge,
)


def chain(n, cap=32):
    g = make_graph(cap)
    for k in range(n):
        g, _ = add_vertex(g, k)
    for k in range(n - 1):
        g, _ = add_edge(g, k, k + 1)
    return g


def test_get_path_static():
    g = chain(6)
    pr = get_path(g, 0, 5)
    assert bool(pr.found) and int(pr.length) == 6
    assert [int(x) for x in np.asarray(pr.keys)[:6]] == [0, 1, 2, 3, 4, 5]
    assert not bool(get_path(g, 5, 0).found)          # directed
    assert not bool(get_path(g, 0, 99).found)         # absent vertex


def test_compare_collects_detects_mutation():
    g = chain(4)
    c1 = collect(g, 0, 3)
    g2, _ = add_edge(g, 0, 2)                          # touched row mutated
    c2 = collect(g2, 0, 3)
    assert not bool(compare_collects(c1, c2))
    c3 = collect(g2, 0, 3)
    assert bool(compare_collects(c2, c3))              # quiescent -> match


def test_adversary_mutate_and_restore_is_caught():
    """Paper §3.5: add edge (vi, l), remove it between collects. The edge
    SET is identical at both collects, but ecnt must expose the mutation."""
    g = chain(3)                                        # 0 -> 1 -> 2
    c1 = collect(g, 0, 2)
    g2, _ = remove_edge(g, 1, 2)                        # break the path
    g3, _ = add_edge(g2, 1, 2)                          # restore it
    # adjacency is now bit-identical to g
    np.testing.assert_array_equal(np.asarray(g.adj), np.asarray(g3.adj))
    c2 = collect(g3, 0, 2)
    assert bool(c1.found) and bool(c2.found)
    assert not bool(compare_collects(c1, c2)), \
        "mutate-and-restore adversary must invalidate the double collect"


def test_session_completes_under_quiescence():
    g = chain(5)
    pr = get_path_session(lambda: g, 0, 4)
    assert bool(pr.found) and int(pr.rounds) == 2       # one double collect


def test_session_retries_until_mutations_stop():
    g = chain(5)
    states = [g]
    # a mutator that toggles an edge for 3 fetches, then goes quiet
    toggles = [(OP_REM_E, 2, 3), (OP_ADD_E, 2, 3), (OP_REM_E, 0, 4)]

    calls = {"n": 0}

    def fetch():
        from repro.core import apply_ops_fast
        i = calls["n"]
        calls["n"] += 1
        if i > 0 and i <= len(toggles):
            batch = make_op_batch([toggles[i - 1]])
            states.append(apply_ops_fast(states[-1], batch)[0])
        return states[-1]

    pr = get_path_session(fetch, 0, 4, max_rounds=32)
    assert bool(pr.found)
    assert int(pr.rounds) >= 3                          # forced restarts


def test_interleaved_getpath_in_program():
    """One jitted program: mutation batches interleave with the query."""
    g = chain(4, cap=16)
    lanes = 4
    # rounds: 2 active mutation rounds (toggling an off-path edge), then quiet
    rounds = [
        [(OP_ADD_E, 3, 0)],
        [(OP_REM_E, 3, 0)],
        [(OP_NOP,)],
        [(OP_NOP,)],
        [(OP_NOP,)],
    ]
    batches = [make_op_batch(r, lanes) for r in rounds]
    batch_t = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
    state, pr, mut_res = interleaved_getpath(g, batch_t, 0, 3)
    assert bool(pr.found)
    assert [int(x) for x in np.asarray(pr.keys)[: int(pr.length)]] == [0, 1, 2, 3]
    assert int(pr.rounds) >= 2


def test_interleaved_getpath_mutation_between_collects_forces_retry():
    """Satellite of DESIGN.md §8 hardening: every round whose mutation batch
    lands in the query's dependency set must flip compare_collects false, so
    the answer only freezes once the graph goes quiet — the exact round
    count is observable in pr.rounds (collects = rounds + the initial one).
    """
    g = chain(4, cap=16)
    lanes = 4
    rounds = [
        [(OP_REM_E, 1, 2)],   # break the path        -> c1 != c0
        [(OP_ADD_E, 1, 2)],   # restore it (same adj) -> c2 != c1 (ecnt moved)
        [(OP_NOP,)],          # quiet                 -> c3 == c2: freeze
        [(OP_NOP,)],
    ]
    batches = [make_op_batch(r, lanes) for r in rounds]
    batch_t = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
    state, pr, _ = interleaved_getpath(g, batch_t, 0, 3)
    assert bool(pr.found)
    assert [int(x) for x in np.asarray(pr.keys)[: int(pr.length)]] == [0, 1, 2, 3]
    # matched at the 3rd mutation round: c0..c3 -> 4 collects
    assert int(pr.rounds) == 4


def test_interleaved_getpath_quiescent_matches_first_double_collect():
    """Control for the retry test: with no effective mutations the very
    first double collect matches (2 collects)."""
    g = chain(4, cap=16)
    lanes = 2
    batches = [make_op_batch([(OP_NOP,)], lanes) for _ in range(3)]
    batch_t = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
    _, pr, _ = interleaved_getpath(g, batch_t, 0, 3)
    assert bool(pr.found) and int(pr.rounds) == 2


def test_session_mutation_between_collects_forces_exact_retry():
    """Host-level form: one mutation lands between collect 1 and collect 2,
    so the session needs exactly 3 collects (c1 != c2, c2 == c3)."""
    g = chain(5)
    g2, _ = apply_ops_like(g, [(OP_ADD_E, 0, 2)])
    seq = [g, g2, g2, g2]
    calls = {"n": 0}

    def fetch():
        s = seq[min(calls["n"], len(seq) - 1)]
        calls["n"] += 1
        return s

    pr = get_path_session(fetch, 0, 4, max_rounds=16)
    assert bool(pr.found) and int(pr.rounds) == 3


def apply_ops_like(g, ops):
    from repro.core import apply_ops_fast
    return apply_ops_fast(g, make_op_batch(ops))


@settings(max_examples=12, deadline=None)
@given(st.lists(st.tuples(st.sampled_from([OP_ADD_E, OP_REM_E]),
                          st.integers(0, 5), st.integers(0, 5)),
                min_size=0, max_size=10),
       st.integers(0, 5), st.integers(0, 5))
def test_getpath_matches_oracle_reachability(edge_ops, src, dst):
    """Static GetPath found/path-validity vs the oracle (property)."""
    g = make_graph(16)
    oracle = GraphOracle(16)
    for k in range(6):
        g, _ = add_vertex(g, k)
        oracle.add_vertex(k)
    for (op, u, v) in edge_ops:
        batch = make_op_batch([(op, u, v)])
        from repro.core import apply_ops
        g, _ = apply_ops(g, batch)
        oracle.apply(op, u, v)
    pr = get_path(g, src, dst)
    assert bool(pr.found) == oracle.reachable(src, dst)
    if bool(pr.found):
        keys = [int(x) for x in np.asarray(pr.keys)[: int(pr.length)]]
        assert oracle.is_valid_path(keys, src, dst)
        # BFS gives a shortest path
        assert len(keys) == oracle.shortest_path_len(src, dst)
