"""Data pipeline tests: path-task generation validity, determinism."""
import numpy as np

from repro.data.pathgen import PathTaskGenerator
from repro.data.pipeline import GraphPathData, SyntheticLMData
from repro.data import tokenizer as tok


def test_pathgen_examples_decode_and_are_consistent():
    gen = PathTaskGenerator(n_vertices=10, capacity=32, seed=1)
    for _ in range(5):
        ex = gen.example()
        assert ex[0] == tok.BOS and ex[-1] == tok.EOS
        s = tok.decode(ex)
        assert "?" in s
        assert ("=>" in s) or ("=>NONE" in s)


def test_pathgen_path_answers_are_real_paths():
    gen = PathTaskGenerator(n_vertices=8, capacity=32, seed=2)
    found_any = False
    for _ in range(20):
        ex = gen.example()
        s = tok.decode(ex)
        if "=>NONE" not in s and "=>" in s:
            found_any = True
            # verify against current edge set
            from repro.core.graph import to_networkx_like
            verts, edges = to_networkx_like(gen.state)
            path_part = s.split("=>")[1]
            nodes = [int(x) for x in path_part.split("|") if x.isdigit()]
            assert len(nodes) >= 1
            for a, b in zip(nodes, nodes[1:]):
                assert (a, b) in set(edges), (nodes, edges)
    assert found_any, "no positive examples generated in 20 draws"


def test_synthetic_determinism():
    d = SyntheticLMData(vocab=100, seed=5)
    a = d.batch(3, 4, 16)
    b = d.batch(3, 4, 16)
    np.testing.assert_array_equal(a, b)
    c = d.batch(4, 4, 16)
    assert not np.array_equal(a, c)


def test_graph_data_batch_shapes():
    d = GraphPathData(n_vertices=8, seed=0)
    b = d.batch(0, 2, 64)
    assert b.shape == (2, 64) and b.dtype == np.int32
    assert (b >= 0).all()
