"""Kernel package with ONE deliberate drift, suppressed inline (fixture)."""
import jax
import jax.numpy as jnp


# deliberate tile drift, pinned by the suppression test
# repro-lint: allow(kernel-shape)
def toy_pallas(x, *, tr: int = 128):
    v = x.shape[0]
    assert v % tr == 0
    return jax.ShapeDtypeStruct((v,), jnp.int32)
