"""KERNEL_META whose only disagreement (tile default) is suppressed in
kernel.py (fixture)."""

KERNEL_META = {
    "package": "kernel_pkg_sup",
    "vmem_budget_bytes": {"tpu": 16777216},
    "dims": {},
    "kernels": {
        "toy_pallas": {
            "tiles": {"tr": 256},
            "align": {"tr": 2},
            "divides": {"v": ["tr"]},
            "operands": {"x": {"block": ["tr"], "dtype": "int32"}},
            "outputs": {"y": {"block": ["tr"], "dtype": "int32"}},
            "packed": False,
            "pad_safety": None,
            "wrapper": "toy",
            "ref": "toy_ref",
            "scratch_bytes": 0,
        },
    },
}
