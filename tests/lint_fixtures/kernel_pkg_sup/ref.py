"""Oracle module of the suppressed fixture package."""


def toy_ref(x):
    return x
