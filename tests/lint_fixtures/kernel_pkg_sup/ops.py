"""Wrapper module of the suppressed fixture package."""


def toy(x):
    return x
