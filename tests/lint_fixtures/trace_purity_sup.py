"""Suppressed trace-purity violation (lint fixture)."""
import time

import jax


@jax.jit
def traced_entry(x):
    # deliberate: pins that inline allows reach jit-reachable bodies
    t = time.time()  # repro-lint: allow(trace-purity)
    return x + t
