"""Deliberate mirror-write violations (lint fixture, DESIGN.md §15 —
excluded from the default walk by GLOBAL_EXCLUDES)."""


def bad_replace(state, adj):
    return state._replace(adj_packed=adj)  # LINT-EXPECT: mirror-write


def bad_construct(GraphState, vkey, valive, vver, ecnt, adj):
    return GraphState(vkey, valive, vver, ecnt, adj_packed=adj)  # LINT-EXPECT: mirror-write


def fine_metadata_only(state, ver):
    return state._replace(vver=ver)


def fine_both(state, adj, adj_in):
    return state._replace(adj_packed=adj, adj_in_packed=adj_in)
