"""Deliberate trace-purity violations (lint fixture)."""
import time

import jax
import numpy as np


def helper(x):
    t = time.perf_counter()  # LINT-EXPECT: trace-purity
    return x + t


@jax.jit
def traced_entry(x):
    x = helper(x)
    host = np.asarray(x)  # LINT-EXPECT: trace-purity
    return x + host.sum()


def host_only(x):
    # NOT jit-reachable: clocks are fine here
    return time.time() + float(np.asarray(x).sum())
