"""Suppressed raw adjacency test (lint fixture)."""


def allowed_physical_read(adj_packed, u, w):
    # physical-bit bookkeeping, not a liveness decision
    return adj_packed[u, w] > 0  # repro-lint: allow(traversable-predicate)
