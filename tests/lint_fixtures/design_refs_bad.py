"""Deliberate dangling design citation (lint fixture).

See DESIGN.md §99 for a section that does not exist."""  # LINT-EXPECT: design-refs
