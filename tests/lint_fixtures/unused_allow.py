"""An allow that silences nothing (lint fixture)."""

X = 1  # repro-lint: allow(mirror-write)  # LINT-EXPECT: unused-suppression
