"""Suppressed raw index read (lint fixture)."""


def allowed_reach(query_mod, idx, s, t):
    # differential harness: compares raw vs session answers on purpose
    return query_mod.query_reach(idx, s, t)  # repro-lint: allow(epoch-freshness)
