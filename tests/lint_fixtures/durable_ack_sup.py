"""Suppressed durable-ack violation (lint fixture)."""


class AllowedPool:
    def replay_publish(self, state, live):
        # recovery replay re-publishes already-durable rounds on purpose
        epoch = self._publish(state)  # repro-lint: allow(durable-ack)
        for t in live:
            t.status = "applied"  # repro-lint: allow(durable-ack)
        return epoch
