"""Deliberate raw index-read violations (lint fixture)."""
from repro.index.query import query_reach  # LINT-EXPECT: epoch-freshness


def bad_reach(idx, s, t):
    return query_reach(idx, s, t)  # LINT-EXPECT: epoch-freshness
