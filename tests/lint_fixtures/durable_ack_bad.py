"""Deliberate durable-ack violations (lint fixture, DESIGN.md §15 —
excluded from the default walk by GLOBAL_EXCLUDES)."""


class BadPool:
    def run_round_publish_first(self, live, res, lanes, pad, state):
        epoch = self._publish(state)  # LINT-EXPECT: durable-ack
        self._wal_commit(live, res, lanes, pad)
        return epoch

    def ack_without_wal(self, live, res):
        for t in live:
            t.status = "applied"  # LINT-EXPECT: durable-ack
        return res

    def fine_round(self, live, res, lanes, pad, state):
        self._wal_commit(live, res, lanes, pad)
        epoch = self._publish(state)
        for t in live:
            t.status = "applied"
        return epoch

    def fine_unrelated_status(self, t):
        t.status = "aborted"
        return t
