"""Suppressed lock-order violation (lint fixture)."""
import threading


class Harness:
    def __init__(self):
        # module guard, not an entity lock
        self.mu = threading.Lock()  # repro-lint: allow(lock-order)
