"""Suppressed mirror-write violation (lint fixture)."""


def allowed_replace(state, adj):
    # one-sided on purpose: this fixture pins that inline allows work
    return state._replace(adj_packed=adj)  # repro-lint: allow(mirror-write)
