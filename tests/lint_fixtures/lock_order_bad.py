"""Deliberate entity-lock discipline violations (lint fixture)."""
import threading


class NotTheTable:
    def __init__(self):
        self.lk = threading.Lock()  # LINT-EXPECT: lock-order

    def grab(self):
        self.lk.acquire()  # LINT-EXPECT: lock-order

    def drop(self):
        self.lk.release()  # LINT-EXPECT: lock-order


class EntityLockTable:
    """Same name as the real table: its own sites are exempt."""

    def __init__(self):
        self._guard = threading.Lock()

    def try_one(self, lk):
        return lk.acquire(blocking=False)
