"""Deliberate raw adjacency liveness test (lint fixture)."""


def bad_edge_present(adj, u, w):
    return adj[u, w] > 0  # LINT-EXPECT: traversable-predicate


def fine_unrelated(x):
    return x > 0
