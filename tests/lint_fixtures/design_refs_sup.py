"""Suppressed dangling design citation (lint fixture)."""

# historical section, kept for the suppression test
X = "DESIGN.md §99"  # repro-lint: allow(design-refs)
