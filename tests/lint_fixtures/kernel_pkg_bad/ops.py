"""Wrapper module of the drifted fixture package."""


def toy(x):
    return x
