"""Deliberately drifted kernel package (lint fixture): tile default,
output dtype, missing divisibility assert, missing oracle, pad_safety and
VMEM budget all disagree with meta.py."""
import jax
import jax.numpy as jnp


def toy_pallas(x, *, tr: int = 128):  # LINT-EXPECT: kernel-shape
    v = x.shape[0]
    return jax.ShapeDtypeStruct((v,), jnp.float32)
