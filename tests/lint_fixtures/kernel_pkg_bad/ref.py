"""Oracle module of the drifted fixture package — toy_ref is MISSING."""
