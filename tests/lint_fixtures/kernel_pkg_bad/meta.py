"""KERNEL_META that disagrees with its kernel.py on purpose (fixture)."""

KERNEL_META = {
    "package": "kernel_pkg_bad",
    "vmem_budget_bytes": {"tpu": 64},
    "dims": {},
    "kernels": {
        "toy_pallas": {
            "tiles": {"tr": 256},
            "align": {"tr": 8},
            "divides": {"v": ["tr"]},
            "operands": {"x": {"block": ["tr"], "dtype": "int32"}},
            "outputs": {"y": {"block": ["tr"], "dtype": "int32"}},
            "packed": True,
            "pad_safety": None,
            "wrapper": "toy",
            "ref": "toy_ref",
            "scratch_bytes": 0,
        },
    },
}
