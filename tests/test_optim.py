"""Optimizer + schedule + gradient-compression tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, grad_compress, schedule


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw.init(params)

    def loss(p):
        return jnp.sum((p["w"] - jnp.array([1.0, 2.0])) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt = adamw.update(params, g, opt, lr=5e-2, weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 2.0], atol=1e-2)


def test_adamw_grad_clip():
    params = {"w": jnp.ones(4)}
    opt = adamw.init(params)
    huge = {"w": jnp.full(4, 1e9)}
    p2, _ = adamw.update(params, huge, opt, lr=1e-3, grad_clip=1.0, weight_decay=0.0)
    assert np.isfinite(np.asarray(p2["w"])).all()
    assert float(jnp.max(jnp.abs(p2["w"] - params["w"]))) < 0.1


def test_schedule_warmup_cosine():
    lr0 = schedule.warmup_cosine(0, peak_lr=1.0, warmup_steps=10, total_steps=100)
    lr10 = schedule.warmup_cosine(10, peak_lr=1.0, warmup_steps=10, total_steps=100)
    lr100 = schedule.warmup_cosine(100, peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr0) == 0.0
    assert abs(float(lr10) - 1.0) < 1e-6
    assert float(lr100) <= 0.11


def test_grad_compress_error_feedback():
    """Quantize-dequantize with EF: the *accumulated* compressed sum tracks
    the true gradient sum (the EF invariant), even when single-step error
    is large."""
    params = {"w": jnp.zeros(64)}
    ef = grad_compress.init(params)
    rng = np.random.default_rng(0)
    true_sum = np.zeros(64)
    comp_sum = np.zeros(64)
    for _ in range(50):
        g = {"w": jnp.asarray(rng.normal(size=64) * 0.1, jnp.float32)}
        true_sum += np.asarray(g["w"])
        dq, ef = grad_compress.compress_decompress(g, ef)
        comp_sum += np.asarray(dq["w"])
    resid = np.asarray(ef.residual["w"])
    np.testing.assert_allclose(comp_sum + resid, true_sum, atol=1e-3)


def test_microbatch_equals_full_batch():
    """Grad accumulation over M microbatches == single-batch gradients."""
    from repro.configs import get_config
    from repro.launch import steps as steps_mod
    from repro.models.model import build_model

    cfg = get_config("olmo-1b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks}

    s1 = steps_mod.make_train_step(model, lr=1e-2, microbatches=1, remat=False)
    s2 = steps_mod.make_train_step(model, lr=1e-2, microbatches=2, remat=False)
    o1 = steps_mod.init_opt_state(params)
    o2 = steps_mod.init_opt_state(params)
    p1, _, m1 = jax.jit(s1)(params, o1, batch)
    p2, _, m2 = jax.jit(s2)(params, o2, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)
