"""Scale-out figure: mesh-partitioned engines vs dense, Q and lane sweeps.

The partitioned fused BFS (core.partition.multi_bfs, DESIGN.md §8) replaces
the dense [Q,V] @ [V,V] superstep with a per-shard [Q,V/S] @ [V/S,V] product
plus ONE psum frontier exchange; the partitioned mutation engine applies
conflict-free lanes shard-locally. This benchmark runs both against their
dense counterparts on the ambient mesh and reports wall time plus derived
query-supersteps per second (the same unit as fig_multiquery) for the BFS
sweep and lanes-per-second for the mutation sweep.

On the 1-device CPU container the sharded engines degenerate (the numbers
measure partitioning overhead ~= 1x); run with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — or on a real pod —
to see the scaling shape. Rows use the fig_multiquery schema (same keys,
``json_rows`` emits the identical long-format records) so benchmarks/run.py
aggregates every figure uniformly.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import apply_ops_fast, make_op_batch, multi_bfs
from repro.core import partition
from repro.core.distributed import AXIS, make_graph_mesh
from benchmarks.fig9_throughput import gen_ops, seed_graph

QS = (4, 16, 64)
ENGINES = ("sharded", "dense")


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps, out


def run_sweep(*, backend="jnp", reps=3, seed=5, quick=False):
    """BFS sweep: rows carry the fig_multiquery schema with engine columns
    (sharded, dense) in place of (fused, vmap)."""
    g, _, nv = seed_graph()
    mesh = make_graph_mesh()
    gs = partition.shard_state(mesh, g)
    rng = np.random.default_rng(seed)
    rows = []
    for q in QS[:1] if quick else QS:
        keys = rng.integers(0, nv, (q, 2))
        srcs = jnp.asarray(keys[:, 0], jnp.int32)
        dsts = jnp.asarray(keys[:, 1], jnp.int32)
        sharded_fn = jax.jit(lambda s, d: partition.multi_bfs(gs, s, d, backend=backend))
        dense_fn = jax.jit(lambda s, d: multi_bfs(g, s, d, backend=backend))
        t_shard, ms = _time(sharded_fn, srcs, dsts, reps=reps)
        t_dense, md = _time(dense_fn, srcs, dsts, reps=reps)
        steps = int(jnp.sum(ms.steps))
        assert steps == int(jnp.sum(md.steps)), "engines disagree on work"
        rows.append({
            "q": q,
            "sharded_s": t_shard,
            "dense_s": t_dense,
            "steps": steps,
            "sharded_steps_per_s": steps / t_shard,
            "dense_steps_per_s": steps / t_dense,
            "speedup": t_dense / t_shard,
        })
    return rows


def run_apply_sweep(*, lanes=64, batches=16, reps=3, seed=6, quick=False):
    """Mutation sweep: the partitioned disjoint-access engine vs dense."""
    g, _, nv = seed_graph()
    mesh = make_graph_mesh()
    gs = partition.shard_state(mesh, g)
    rng = np.random.default_rng(seed)
    nb = 4 if quick else batches
    mix = (1, 1, 2, 4, 2, 2)  # (addv, remv, conv, adde, reme, cone)
    ops = [make_op_batch(gen_ops(rng, mix, lanes, nv), lanes)
           for _ in range(nb)]

    def run_dense():
        st = g
        for b in ops:
            st, _ = apply_ops_fast(st, b)
        return st.ecnt

    def run_sharded():
        st = gs
        for b in ops:
            st, _ = partition.apply_ops_fast(st, b)
        return st.ecnt

    t_dense, _ = _time(run_dense, reps=reps)
    t_shard, _ = _time(run_sharded, reps=reps)
    total = lanes * nb
    return [{
        "q": lanes,  # lane count plays the batch-size role of q
        "sharded_s": t_shard,
        "dense_s": t_dense,
        "steps": total,
        "sharded_steps_per_s": total / t_shard,
        "dense_steps_per_s": total / t_dense,
        "speedup": t_dense / t_shard,
    }]


def json_rows(rows, figure="sharded", engines=ENGINES):
    """Normalize wide rows to the long-format JSON schema shared with
    fig_multiquery (one record per engine per sweep point), so
    benchmarks/run.py --json aggregates all figures uniformly."""
    out = []
    for r in rows:
        base_s = r[f"{engines[-1]}_s"]
        for eng in engines:
            out.append({
                "figure": figure,
                "q": r["q"],
                "engine": eng,
                "seconds": r[f"{eng}_s"],
                "steps": r["steps"],
                "steps_per_s": r[f"{eng}_steps_per_s"],
                "speedup_vs_baseline": base_s / r[f"{eng}_s"],
            })
    return out


def main(quick=False, rows_out=None):
    mesh = make_graph_mesh()
    shards = int(mesh.shape[AXIS])
    out = []
    print(f"mesh: {shards} shard(s) on axis {AXIS!r}")
    print(f'{"Q":>4s} {"engine":>8s} {"ms/batch":>10s} {"qsteps/s":>12s} '
          f'{"speedup":>8s}')
    bfs_rows = run_sweep(quick=quick)
    for r in bfs_rows:
        print(f'{r["q"]:4d} {"sharded":>8s} {r["sharded_s"]*1e3:10.2f} '
              f'{r["sharded_steps_per_s"]:12.0f} {r["speedup"]:7.2f}x')
        print(f'{r["q"]:4d} {"dense":>8s} {r["dense_s"]*1e3:10.2f} '
              f'{r["dense_steps_per_s"]:12.0f} {"":>8s}')
        out.append(f'sharded/bfs/s{shards}/q{r["q"]},{r["sharded_s"]*1e6:.1f},'
                   f'qsteps_per_s={r["sharded_steps_per_s"]:.0f};'
                   f'speedup_vs_dense={r["speedup"]:.2f}')
        out.append(f'sharded/bfs_dense_ref/q{r["q"]},{r["dense_s"]*1e6:.1f},'
                   f'qsteps_per_s={r["dense_steps_per_s"]:.0f}')
    apply_rows = run_apply_sweep(quick=quick)
    for r in apply_rows:
        print(f'{r["q"]:4d} {"s-apply":>8s} {r["sharded_s"]*1e3:10.2f} '
              f'{r["sharded_steps_per_s"]:12.0f} {r["speedup"]:7.2f}x')
        out.append(f'sharded/apply/s{shards}/b{r["q"]},{r["sharded_s"]*1e6:.1f},'
                   f'lanes_per_s={r["sharded_steps_per_s"]:.0f};'
                   f'speedup_vs_dense={r["speedup"]:.2f}')
    if rows_out is not None:
        rows_out.extend(json_rows(bfs_rows, figure="sharded_bfs"))
        rows_out.extend(json_rows(apply_rows, figure="sharded_apply"))
    return out


if __name__ == "__main__":
    main()
